"""Shared benchmark utilities."""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

ROOT = Path(__file__).resolve().parents[1]
ARTIFACTS = ROOT / "artifacts"


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of a jitted callable, in microseconds."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


# Every row emit() printed this process, in order — the run.py harness
# consolidates them into artifacts/BENCH_step_time.json after the sweep.
EMITTED = []


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV rows (and accumulate them
    for the consolidated harness artifact)."""
    for name, us, derived in rows:
        EMITTED.append({"name": str(name), "us": float(us),
                        "derived": str(derived)})
        print(f"{name},{us:.1f},{derived}")


def tiny_paper_model(name: str = "moe-transformerxl", num_experts: int = 8,
                     d_model: int = 256, num_layers: int = 6):
    """Reduced-but-structurally-faithful paper model for CPU runs."""
    import dataclasses
    from repro.config import reduced
    from repro.configs import get_config
    cfg = get_config(name, num_experts=num_experts)
    cfg = reduced(cfg, num_layers=num_layers, d_model=d_model,
                  max_experts=num_experts)
    return cfg
