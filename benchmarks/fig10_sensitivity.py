"""Paper Fig. 10 — sensitivity of (a) the migration candidate size q,
(b) the attention cost model accuracy (Eq. 1, fit on REAL timed attention
runs), and (c) the fast-similarity thresholds S1/S2 (measured fraction).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit


def _q_sweep():
    from repro.core import migration as mig
    r = np.random.default_rng(0)
    M, n_per = 8, 4
    n_slots = M * n_per
    counts = (r.random((n_slots, M)) ** 3)
    counts = counts / counts.sum(1, keepdims=True) * 200
    # bimodal lengths: the paper's padding argument — q>1 lets similar
    # lengths co-locate, q=1 chases traffic only and mixes them
    lens = r.choice([64, 256], n_slots, p=[0.5, 0.5])
    rows = []
    for q in (1, 2, 3, 4):
        plan = mig.plan_migration_np(counts, lens, n_per, q=q,
                                     d_model=1024, speed=1e12)
        # attention cost with the resulting placement
        att = 0.0
        for dev in range(M):
            ls = lens[np.asarray(plan.assign) == dev]
            if len(ls):
                att += float(mig.t_att(len(ls), ls.max(), 1024, 1e12))
        rows.append((f"fig10a/q{q}", 0.0,
                     f"traffic={float(plan.traffic_after):.0f} "
                     f"t_att={att*1e3:.2f}ms"))
    return rows


def _cost_model_accuracy(fast: bool):
    """Time real attention (jit, CPU) over (B, L) grid; fit P; report
    mean relative error of Eq. 1 — the paper reports ~5%."""
    import jax
    import jax.numpy as jnp
    from repro.core.migration import t_att
    d, H = 512, 8
    hd = d // H
    # matmul-dominated sizes (the Eq. 1 regime; tiny cases are CPU
    # overhead-bound and the paper's 5% error is a GPU number)
    cases = [(1, 512), (2, 512), (4, 512), (1, 1024), (2, 1024)]
    if not fast:
        cases += [(4, 1024), (1, 2048), (2, 2048)]

    def attn(q, k, v):
        lg = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(lg, -1), v)

    times, preds = [], []
    for B, L in cases:
        r = np.random.default_rng(0)
        q = jnp.asarray(r.standard_normal((B, L, H, hd)), jnp.float32)
        f = jax.jit(attn)
        us = timeit(f, q, q, q, warmup=2, iters=5)
        times.append(us)
        preds.append(float(t_att(B, L, d, 1.0)))   # unnormalized FLOPs
    times = np.asarray(times)
    preds = np.asarray(preds)
    speed = float(np.sum(preds * times) / np.sum(times * times))  # lsq P
    est = preds / speed
    err = np.abs(est - times) / times
    return [("fig10b/cost_model", float(times.mean()),
             f"mean_rel_err={100*float(err.mean()):.1f}% "
             f"fit_P={speed:.3g}FLOP/us")]


def _s1s2_sweep():
    import jax.numpy as jnp
    from repro.core.condensation import fast_similarity
    r = np.random.default_rng(0)
    G, d = 128, 64
    x = jnp.asarray(r.standard_normal((G, d)), jnp.float32)
    e = jnp.asarray(r.integers(0, 4, G), jnp.int32)
    s_prev = jnp.asarray(r.random((G, G)), jnp.float32)
    rows = []
    for s1, s2 in ((0.9, 0.1), (0.8, 0.2), (0.7, 0.3), (0.6, 0.4)):
        _, measured = fast_similarity(x, e, s_prev, s1, s2)
        rows.append((f"fig10c/S1{s1}_S2{s2}", 0.0,
                     f"measured_frac={float(measured):.3f}"))
    return rows


def run(fast: bool = True):
    rows = _q_sweep() + _cost_model_accuracy(fast) + _s1s2_sweep()
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
