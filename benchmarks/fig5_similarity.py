"""Paper Fig. 5 + Fig. 7 — token-similarity statistics measured on THIS
system: (a) the fraction of same-expert token pairs above the similarity
threshold per block (deeper blocks more similar); (b) similarity
preservation through the expert FFN; (c) cross-block persistence (the
§V-A history rule's justification).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, tiny_paper_model


def _probe_states(steps: int = 12):
    """Train briefly, then capture per-block pre-MoE hidden states."""
    import jax
    import jax.numpy as jnp
    from repro import optim, train_lib
    from repro.config import LuffyConfig, OptimConfig, ShapeConfig
    from repro.core.moe_layer import capacity_for, _rms
    from repro.core.gating import gate_apply
    from repro.data import SyntheticLM
    from repro.dist import single_device
    from repro.models import blocks as bk
    from repro.models import transformer as tf
    from repro.models.model import build_model

    cfg = tiny_paper_model("moe-transformerxl", num_experts=4,
                           d_model=128, num_layers=6)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("b", 128, 8, "train")
    data = SyntheticLM(cfg, shape)
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False)
    ocfg = OptimConfig(total_steps=steps, warmup_steps=2, lr=1e-3)
    cap = capacity_for(cfg.moe, 8 * 128, cfg.moe.num_experts)
    dist = single_device()
    step = jax.jit(train_lib.make_train_step(cfg, luffy, ocfg, dist, cap))
    ost = optim.init_opt_state(params, ocfg)
    lst = train_lib.init_luffy_state()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, ost, lst, _ = step(params, ost, lst, b)

    # manual layer walk capturing pre-MoE states + routing per block
    b = {k: jnp.asarray(v) for k, v in data.batch(999).items()}
    x = tf.embed_tokens(params, cfg, b["tokens"])
    states, experts, params_by_layer = [], [], []
    sb = {"labels": b["labels"], "seq_len": b["seq_len"].astype(jnp.int32)}
    stacked = params["layers"][0]
    n_groups = cfg.num_layers
    for g in range(n_groups):
        p = jax.tree.map(lambda a: a[g], stacked)
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
        x, _ = tf._token_mixer_full(p, cfg, x, positions, 0, causal=True,
                                    enc_out=None, enc_pos=None, dist=dist)
        xf = x.reshape(-1, cfg.d_model)
        xn = _rms(xf, p["moe"]["norm"]["scale"])
        gate = gate_apply(p["moe"]["router"], xn, cfg.moe.top_k)
        states.append(np.asarray(xn))
        experts.append(np.asarray(gate.expert_idx[:, 0]))
        params_by_layer.append(p)
        from repro.core.moe_layer import moe_core
        y, sb, _, _ = moe_core(p["moe"], x, dict(sb), cfg, luffy,
                               mode="vanilla", capacity=cap,
                               axis_name=None, threshold=jnp.float32(1.0))
        x = y
    return cfg, states, experts, params_by_layer


def _pair_sims(xn, experts, n_pairs=4000, rng=None):
    rng = rng or np.random.default_rng(0)
    n = xn.shape[0]
    i = rng.integers(0, n, n_pairs)
    j = rng.integers(0, n, n_pairs)
    same = experts[i] == experts[j]
    i, j = i[same], j[same]
    a = xn[i] / np.linalg.norm(xn[i], axis=1, keepdims=True)
    b = xn[j] / np.linalg.norm(xn[j], axis=1, keepdims=True)
    return (np.sum(a * b, axis=1) + 1) / 2, i, j


def run(fast: bool = True):
    import jax.numpy as jnp
    from repro.models import blocks as bk
    cfg, states, experts, pbl = _probe_states(steps=8 if fast else 30)
    rows = []
    fracs = []
    for blk in (0, len(states) // 2, len(states) - 1):
        sims, i, j = _pair_sims(states[blk], experts[blk])
        frac = float(np.mean(sims > 0.75))
        fracs.append(frac)
        rows.append((f"fig5a/block{blk}", 0.0,
                     f"frac_pairs_sim>0.75={frac:.2f} "
                     f"median={np.median(sims):.2f}"))
    rows.append(("fig5a/deeper_more_similar", 0.0,
                 f"{fracs[-1] >= fracs[0] - 0.05}"))

    # same-expert similarity deciles + the capacity bucket they support
    # (the similarity_quantiles → pick_rate_bucket host path the adaptive
    # threshold uses; quantiles over off-diagonal same-expert pairs only)
    from repro.core.condensation import (pairwise_cosine, pick_rate_bucket,
                                         similarity_quantiles)
    blk = len(states) - 1
    G = min(128, states[blk].shape[0])
    sim = np.asarray(pairwise_cosine(jnp.asarray(states[blk][:G])))
    q = similarity_quantiles(sim, expert_idx=experts[blk][:G])
    bucket = pick_rate_bucket(0.75, q, (0.0, 0.25, 0.5))
    rows.append(("fig5a/same_expert_deciles", 0.0,
                 f"q50={q[5]:.2f} q90={q[9]:.2f} bucket={bucket}"))

    # Fig 5b: similarity preservation through the expert
    blk = len(states) - 1
    sims, i, j = _pair_sims(states[blk], experts[blk])
    hi = sims > 0.75
    if hi.sum() >= 10:
        import jax
        p = pbl[blk]["moe"]["experts"]
        xn = states[blk]
        e = experts[blk]
        from repro.kernels.ref import expert_ffn_ref
        # push each selected token through its own expert
        sel = np.flatnonzero(hi)[:500]
        ii, jj = i[sel], j[sel]
        h = jnp.asarray(np.stack([xn[ii], xn[jj]]))   # [2, n, d]
        out = []
        for row in range(2):
            idx = (ii if row == 0 else jj)
            y = np.zeros((len(idx), cfg.d_model), np.float32)
            for ex in range(cfg.moe.num_experts):
                m = e[idx] == ex
                if m.any():
                    yy = expert_ffn_ref(
                        jnp.asarray(xn[idx][m])[None],
                        p["w_up"][ex][None], p["w_gate"][ex][None],
                        p["w_down"][ex][None])
                    y[m] = np.asarray(yy[0])
            out.append(y)
        a = out[0] / (np.linalg.norm(out[0], axis=1, keepdims=True) + 1e-9)
        bb = out[1] / (np.linalg.norm(out[1], axis=1, keepdims=True) + 1e-9)
        post = (np.sum(a * bb, axis=1) + 1) / 2
        delta = np.abs(post - sims[sel])
        rows.append(("fig5b/preservation", 0.0,
                     f"frac_delta<0.2={float(np.mean(delta < 0.2)):.2f}"))

    # Fig 7: cross-block persistence of similar pairs
    s0, i0, j0 = _pair_sims(states[-2], experts[-2])
    pairs_hi = np.flatnonzero(s0 > 0.8)
    if len(pairs_hi) >= 10:
        xn1 = states[-1]
        a = xn1[i0[pairs_hi]]
        b = xn1[j0[pairs_hi]]
        a /= np.linalg.norm(a, axis=1, keepdims=True) + 1e-9
        b /= np.linalg.norm(b, axis=1, keepdims=True) + 1e-9
        s1 = (np.sum(a * b, axis=1) + 1) / 2
        rows.append(("fig7/persistence_hi", 0.0,
                     f"frac_still>0.8={float(np.mean(s1 > 0.8)):.2f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
