"""Paper Fig. 8 + Table III — end-to-end speedup of LUFFY/EXT/HYT over
Vanilla for every (model × #experts), predicted by the calibrated model
and validated against the paper's own numbers.

The faithful-reproduction check: with the paper's measured condensation
rates / locality (Fig. 5-derived), the model must land within tolerance
of the paper's reported speedups.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import commsim


def paper_speedup(model, E, system):
    vc, vm = commsim.PAPER_VANILLA[model][E]
    c, m = commsim.PAPER_TABLE3[model][system][E]
    return (vc + vm) / (c + m)


def run(fast: bool = True, measured_rates=None):
    rows = []
    errs = []
    for model in commsim.PAPER_VANILLA:
        rates = dict(commsim.PAPER_RATES[model])
        if measured_rates and model in measured_rates:
            rates = measured_rates[model]
        for E in (2, 4, 8, 16):
            cfg = get_config(model, num_experts=E)
            setup = commsim.PaperSetup(cfg=cfg)
            vc, vm = commsim.PAPER_VANILLA[model][E]
            cal = commsim.calibrate(setup, vc, vm)
            base = commsim.predict(setup, cal, system="vanilla")
            base_t = base["comp_ms"] + base["comm_ms"]
            for system in ("luffy", "ext", "hyt"):
                p = commsim.predict(setup, cal, system=system, **rates)
                ours = base_t / (p["comp_ms"] + p["comm_ms"])
                paper = paper_speedup(model, E, system)
                err = abs(ours - paper) / paper
                errs.append(err)
                rows.append((
                    f"fig8/{model}/E{E}/{system}", 0.0,
                    f"speedup_model={ours:.2f}x speedup_paper={paper:.2f}x "
                    f"rel_err={100*err:.0f}%"))
    mean_err = sum(errs) / len(errs)
    rows.append(("fig8/mean_rel_err", 0.0, f"{100*mean_err:.1f}%"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
