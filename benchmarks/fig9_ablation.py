"""Paper Fig. 9 — ablation: condensation-only vs migration-only vs full
LUFFY. The LUFFY inputs (condensation rate, migration locality gain) are
MEASURED on this system (8-host-device training, aux ledger), then fed to
the Table-III-calibrated comm model to get speedups comparable with the
paper's figure.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import ROOT, emit
from repro.configs import get_config
from repro.core import commsim

_MEASURE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro import optim, train_lib
from repro.config import reduced, LuffyConfig, OptimConfig, ShapeConfig
from repro.configs import get_config
from repro.core.moe_layer import capacity_for
from repro.data import SyntheticLM
from repro.dist import DistContext
from repro.models.model import build_model

cfg = reduced(get_config("moe-transformerxl", num_experts=8),
              num_layers=2, d_model=128, max_experts=8)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
shape = ShapeConfig("b", 256, 8, "train")
data = SyntheticLM(cfg, shape)
from repro.comm import make_mesh
mesh = make_mesh((1, 8), ("data", "model"))
dist = DistContext(mesh, batch_axes=("data", "model"), seq_axis=None,
                   fsdp_axes=("data",))
luffy = LuffyConfig(condense_group=64, combine_slack=2.0)
cap = capacity_for(cfg.moe, 256, cfg.moe.num_experts)
ocfg = OptimConfig(total_steps=%(steps)d, warmup_steps=2, lr=1e-3)
step = jax.jit(train_lib.make_train_step(cfg, luffy, ocfg, dist, cap))
ost = optim.init_opt_state(params, ocfg)
lst = train_lib.init_luffy_state()
rates, locals_, tb, ta = [], [], [], []
for i in range(%(steps)d):
    b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
    params, ost, lst, m = step(params, ost, lst, b)
    rates.append(float(m["condense_rate"]))
    locals_.append(float(m["local_frac"]))
    tb.append(float(m["traffic_before"])); ta.append(float(m["traffic_after"]))
n = max(1, len(rates) // 2)
r = sum(rates[-n:]) / n
lf = sum(locals_[-n:]) / n
base_local = 1.0 / 8
loc_gain = max(0.0, (lf - base_local) / max(1e-9, 1.0 - base_local))
tr = 1.0 - (sum(ta[-n:]) / max(1e-9, sum(tb[-n:])))
print(json.dumps({"r_cond": r, "local_frac": lf,
                  "locality_gain": loc_gain, "traffic_reduction": tr}))
"""


def measure(steps: int = 8):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", _MEASURE % {"steps": steps}],
                         capture_output=True, text=True, env=env,
                         cwd=str(ROOT), timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(fast: bool = True):
    m = measure(steps=6 if fast else 20)
    rows = [("fig9/measured", 0.0,
             f"r_cond={m['r_cond']:.2f} local_frac={m['local_frac']:.2f} "
             f"traffic_reduction={m['traffic_reduction']:.2f}")]
    for model in commsim.PAPER_VANILLA:
        cfg = get_config(model, num_experts=8)
        setup = commsim.PaperSetup(cfg=cfg)
        vc, vm = commsim.PAPER_VANILLA[model][8]
        cal = commsim.calibrate(setup, vc, vm)
        base = commsim.predict(setup, cal, system="vanilla")
        bt = base["comp_ms"] + base["comm_ms"]
        variants = {
            "tc_only": {"r_cond": m["r_cond"], "locality": 0.0},
            "sm_only": {"r_cond": 0.0,
                        "locality": max(m["traffic_reduction"], 0.0)},
            "full": {"r_cond": m["r_cond"],
                     "locality": max(m["traffic_reduction"], 0.0)},
        }
        for name, rates in variants.items():
            p = commsim.predict(setup, cal, system="luffy", **rates)
            sp = bt / (p["comp_ms"] + p["comm_ms"])
            rows.append((f"fig9/{model}/{name}", 0.0,
                         f"speedup={sp:.2f}x"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
