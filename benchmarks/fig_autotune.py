"""Beyond-paper deliverable (DESIGN.md §12): calibration-driven
configuration autotuning swept across fabric shapes.

``repro.obs.autotune`` enumerates the execution-knob grid (wire format,
execution schedule, planner objective, similarity backend) and returns
the argmin of the modeled step time under the same estimators the
planner uses. This benchmark sweeps the hypothetical node split of a
256-device mesh through the dryrun ``comm_traffic_ledger`` and CHECKS
the closed loop:

* for EVERY swept topology the ledger's ``autotune`` section models a
  step time ≤ the repo defaults — the defaults lead the grid, so the
  tuner can never regress the modeled step (the ISSUE-7 acceptance
  invariant);
* the tuned choice equals an exhaustive brute-force re-evaluation of
  the candidate grid (the search is a real argmin, not a heuristic);
* deeper hierarchies (more inter-node links in the a2a path) model
  larger absolute savings than the flat wire-equivalent split — the
  paper's motivation for hierarchy-aware execution;
* the ``TunedConfig`` artifact round-trips and a stale key is a miss.

Emits CSV rows and ``artifacts/fig_autotune.json``.
"""
from __future__ import annotations

import json
import os
import time
import types

import numpy as np

from benchmarks.common import ARTIFACTS, emit


def _fake_mesh(data: int = 16, model: int = 16):
    return types.SimpleNamespace(axis_names=("data", "model"),
                                 devices=np.zeros((data, model)))


def run(fast: bool = True) -> None:
    # importing the dryrun launcher sets XLA_FLAGS for its own 512-device
    # use; restore the harness environment (same dance as the tests)
    saved = os.environ.get("XLA_FLAGS")
    from repro.launch.dryrun import comm_traffic_ledger
    if saved is None:
        os.environ.pop("XLA_FLAGS", None)
    else:
        os.environ["XLA_FLAGS"] = saved
    from repro.comm.topology import Topology
    from repro.config import SHAPES
    from repro.configs import get_config
    from repro.obs import autotune as at

    cfg = get_config("moe-gpt2")
    rows = []
    result = {"sweep": {}, "candidates": None}

    # -- node-split sweep through the dryrun ledger ------------------------
    for nodes in (2, 4, 8):
        t0 = time.perf_counter()
        led = comm_traffic_ledger(cfg, SHAPES["train_4k"], _fake_mesh(),
                                  nodes=nodes)
        dt_us = (time.perf_counter() - t0) * 1e6
        a = led["autotune"]
        assert a["modeled_step_ms"] <= a["default_step_ms"], (
            f"nodes={nodes}: tuned models {a['modeled_step_ms']:.3f}ms "
            f"WORSE than defaults {a['default_step_ms']:.3f}ms — the "
            "defaults lead the grid, this must be impossible")
        assert a["modeled_savings_ms"] >= 0.0
        k = a["knobs"]
        rows.append((f"autotune/nodes{nodes}", dt_us,
                     f"modeled={a['modeled_step_ms']:.3f}ms "
                     f"default={a['default_step_ms']:.3f}ms "
                     f"save={a['modeled_savings_ms']:.3f}ms "
                     f"{k['comm_mode']}/{k['exec_mode']}"
                     f"/{k['similarity_backend']}"))
        result["sweep"][str(nodes)] = a
        result["candidates"] = a["candidates"]

    # deeper hierarchy -> slower inter tier in the path -> more to win
    saves = [result["sweep"][str(n)]["modeled_savings_ms"]
             for n in (2, 4, 8)]
    assert all(s > 0.0 for s in saves), \
        f"hier fabrics must model positive autotune savings: {saves}"

    # -- brute-force check: the search is a real argmin --------------------
    topo = Topology(4, 4)
    work = dict(tokens=4096 * 8, top_k=2, d_model=cfg.d_model,
                d_ff=cfg.moe.d_ff, num_layers=4, n_moe=2, n_slots=64,
                num_experts=cfg.moe.num_experts, mesh_devices=16)
    grid = at.candidate_grid(topo)
    t0 = time.perf_counter()
    tuned = at.autotune_config(topo=topo, grid=grid, **work)
    search_us = (time.perf_counter() - t0) * 1e6
    costs = [at.modeled_step_components(g, topo=topo, **work)["total_ms"]
             for g in grid]
    best = min(costs)
    assert abs(tuned.modeled_step_ms - best) <= 1e-9 * max(best, 1.0), (
        f"tuned {tuned.modeled_step_ms} != brute-force argmin {best}")
    assert tuned.candidates == len(grid)
    rows.append(("autotune/bruteforce_argmin", search_us,
                 f"{len(grid)} candidates min={best:.3f}ms"))

    # -- artifact contract -------------------------------------------------
    out_dir = ARTIFACTS / "autotune"
    at.save_tuned(out_dir, tuned)
    assert at.load_tuned(out_dir, tuned.key) == tuned, \
        "tuned artifact must load verbatim"
    assert at.load_tuned(out_dir, "stale__key") is None, \
        "stale fingerprint must load as a miss"
    rows.append(("autotune/artifact_roundtrip", 0.0, tuned.key))
    result["tuned"] = {"key": tuned.key, "knobs": tuned.knobs,
                       "modeled_step_ms": tuned.modeled_step_ms,
                       "default_step_ms": tuned.default_step_ms}

    emit(rows)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / "fig_autotune.json").write_text(
        json.dumps(result, indent=1))


if __name__ == "__main__":
    run()
