"""Beyond-paper deliverable (DESIGN.md §11): measured-vs-predicted
calibration of the cost model on the running backend.

``repro.obs.calibrate`` times real collectives (tiled all_to_all per
link tier, psum), the dependency-chained pipeline issue overhead, the
host migration planner, the similarity Gram build and the expert FFN,
and fits the cost-model constants the planner/estimator otherwise takes
on faith. This benchmark runs the fit, then CHECKS it:

* held-out prediction — an all_to_all payload size the fit never saw
  must be predicted by ``lat + bytes/bw`` within ``TOL``× (generous: CPU
  collectives jitter, but a fit that is off by an order of magnitude
  would silently mis-rank migration plans);
* compute fits are stable across shape — re-measuring the FFN/similarity
  speed at a different shape stays within ``TOL``× of the fitted speed;
* the artifact round-trips through its versioned serializer, a stale
  topology fingerprint / bumped schema loads as a MISS, and the
  load-before-measure path returns the persisted fit verbatim;
* the ``phase()`` trace hook costs <5% on an untraced step (the
  ``--trace`` overhead budget: production steps pay one module-global
  comparison per hook).

Emits CSV rows and ``artifacts/fig_calibration.json``; the artifact
itself lands in ``artifacts/calib/<key>.calib.json``.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import ARTIFACTS, emit

TOL = 4.0          # held-out prediction tolerance (ratio, either way)
HOOK_BUDGET = 0.05  # phase() overhead budget on an untraced step


def _ratio(pred: float, meas: float) -> float:
    lo = max(min(pred, meas), 1e-12)
    return max(pred, meas) / lo


def _held_out_link(calib, mesh, axis: str, bw: float, lat: float):
    """Predict one all_to_all the fit never saw (rows=512) on ``axis``."""
    from repro.obs.calibrate import measure_all_to_all
    ((off_bytes, t_meas),) = measure_all_to_all(mesh, axis, [512])
    t_pred = lat + off_bytes / bw
    return off_bytes, t_meas, t_pred


def _hook_overhead_ratio() -> float:
    """Relative cost of the phase() hook with NO tracer active, around
    a real jitted step (best-of medians; min damps scheduler noise)."""
    import jax
    import jax.numpy as jnp
    from repro.obs import trace as obs_trace
    obs_trace.deactivate()
    x = jnp.ones((256, 256), jnp.float32)
    step = jax.jit(lambda a: a @ a.T + 1.0)
    jax.block_until_ready(step(x))

    def loop_plain():
        y = x
        for _ in range(20):
            y = step(y)
        jax.block_until_ready(y)

    def loop_hooked():
        y = x
        for _ in range(20):
            with obs_trace.phase("step") as sp:
                y = sp.fence(step(y))
        jax.block_until_ready(y)

    def best(fn, reps: int = 7) -> float:
        fn()
        out = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            out = min(out, time.perf_counter() - t0)
        return out

    return best(loop_hooked) / best(loop_plain)


def run(fast: bool = True) -> None:
    import jax
    from repro.launch.mesh import make_host_mesh, topology_for_mesh
    from repro.obs.calibrate import (Calibration, load_calibration,
                                     run_calibration)

    mesh = topo = None
    if len(jax.devices()) >= 4:
        nodes = 2
        model = min(4, len(jax.devices()))
        mesh = make_host_mesh(model=model, nodes=nodes)
        topo = topology_for_mesh(mesh)

    out_dir = ARTIFACTS / "calib"
    t0 = time.time()
    calib = run_calibration(mesh, topo, out_dir=out_dir, quick=fast)
    fit_s = time.time() - t0
    rows = [("calibration/fit", fit_s * 1e6, calib.key)]
    result = {"key": calib.key, "fit_s": fit_s, "tolerance": TOL,
              "intra_bw": calib.intra_bw, "inter_bw": calib.inter_bw,
              "chunk_overhead_ms": calib.chunk_overhead_ms,
              "plan_step_us": calib.plan_step_us,
              "sim_speed": calib.sim_speed,
              "ffn_speed": calib.ffn_speed, "held_out": {}}

    # -- held-out predicted vs measured (collectives: hier mesh only) ------
    if mesh is not None:
        for axis, bw, lat in (("local", calib.intra_bw, calib.intra_lat),
                              ("node", calib.inter_bw, calib.inter_lat)):
            off_bytes, t_meas, t_pred = _held_out_link(
                calib, mesh, axis, bw, lat)
            r = _ratio(t_pred, t_meas)
            rows.append((f"calibration/held_out_{axis}", t_meas * 1e6,
                         f"pred={t_pred*1e6:.1f}us ratio={r:.2f}"))
            result["held_out"][axis] = {
                "bytes": off_bytes, "measured_s": t_meas,
                "predicted_s": t_pred, "ratio": r}
            assert r <= TOL, (
                f"{axis} all_to_all held-out prediction off {r:.1f}x "
                f"(> {TOL}x): measured {t_meas:.2e}s vs predicted "
                f"{t_pred:.2e}s for {off_bytes:.0f}B")

    # -- compute fits stable across shape ----------------------------------
    from repro.obs.calibrate import measure_ffn_speed, measure_sim_speed
    ffn2, _ = measure_ffn_speed(rows=256, d=256, d_ff=1024)
    sim2, _ = measure_sim_speed(group=128, d=256)
    for name, fitted, again in (("ffn_speed", calib.ffn_speed, ffn2),
                                ("sim_speed", calib.sim_speed, sim2)):
        r = _ratio(fitted, again)
        rows.append((f"calibration/{name}_stability", 0.0,
                     f"fit={fitted:.3g} heldout={again:.3g} "
                     f"ratio={r:.2f}"))
        result["held_out"][name] = {"fitted": fitted,
                                    "remeasured": again, "ratio": r}
        assert r <= TOL, \
            f"{name} unstable across shapes: {fitted:.3g} vs {again:.3g}"

    # -- artifact contract -------------------------------------------------
    back = Calibration.from_json(calib.to_json(), expect_key=calib.key)
    assert back == calib, "calibration artifact does not round-trip"
    stale_key = calib.key.replace("__", "STALE__", 1)
    assert Calibration.from_json(calib.to_json(),
                                 expect_key=stale_key) is None, \
        "stale topology fingerprint must load as a miss"
    cached = load_calibration(out_dir, calib.key)
    assert cached == calib, "persisted artifact must load verbatim"
    assert run_calibration(mesh, topo, out_dir=out_dir) == calib, \
        "load-before-measure must return the persisted fit"
    rows.append(("calibration/artifact_roundtrip", 0.0, "ok"))

    # -- trace-hook overhead budget ----------------------------------------
    overhead = min(_hook_overhead_ratio() for _ in range(3)) - 1.0
    rows.append(("calibration/phase_hook_overhead", 0.0,
                 f"{overhead*100:.2f}%"))
    result["phase_hook_overhead"] = overhead
    assert overhead < HOOK_BUDGET, (
        f"untraced phase() hook overhead {overhead*100:.1f}% exceeds "
        f"the {HOOK_BUDGET*100:.0f}% budget")

    emit(rows)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / "fig_calibration.json").write_text(
        json.dumps(result, indent=1))


if __name__ == "__main__":
    run()
