"""Beyond-paper deliverable (DESIGN.md §10): similarity-backend sweep —
group size × backend through the real ``repro.condense`` path.

For each group size ``G`` the sweep condenses (a) a random token batch
and (b) a duplicate-heavy batch (4 exact clones per unique token)
through both registered backends and records the measured-pair count
(the O(G²·d) Gram work §V-A actually performs), the fraction of
[128,128] kernel tiles the mask leaves live (the Pallas early-out win),
the condense rate, and the modeled build time
(``repro.plan.estimate_similarity_ms``).

CI smoke-checks the backend contracts (ISSUE 5): the LSH backend's
measured-pair count is strictly below exact for every ``G ≥ 256`` on
random tokens, and its condense rate is *identical* to exact on the
duplicate-heavy batches (identical tokens always share a bucket).
Emits CSV rows and ``artifacts/fig_condense_backend.json``.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import ARTIFACTS, emit

GROUPS_FAST = (64, 128, 256)
GROUPS_SLOW = (64, 128, 256, 512)
D_MODEL = 64
N_EXPERTS = 4
LSH_BITS = 8
THRESHOLD = 0.9
BACKENDS = ("exact", "lsh")


def _random_batch(rng, G: int):
    x = rng.standard_normal((G, D_MODEL)).astype(np.float32)
    e = rng.integers(0, N_EXPERTS, G).astype(np.int32)
    return x, e


def _duplicate_batch(rng, G: int, clones: int = 4):
    """Random uniques, each repeated ``clones`` times — every clone pair
    has similarity 1.0; random cross pairs sit near 0.5 in the
    normalized [0,1] scale, far under the threshold (random d=64
    gaussians never reach cosine 0.8; works for any G, unlike an
    identity basis which runs out of orthogonal rows past d)."""
    n_uniq = G // clones
    uniq = rng.standard_normal((n_uniq, D_MODEL)).astype(np.float32)
    x = np.repeat(uniq, clones, axis=0)
    e = np.repeat(rng.integers(0, N_EXPERTS, n_uniq), clones).astype(
        np.int32)
    return x, e


def _condense(x, e, backend: str):
    import jax.numpy as jnp
    from repro.condense import condense_tokens, fast_similarity
    from repro.kernels.similarity import mask_tile_fraction
    G = x.shape[0]
    out = condense_tokens(jnp.asarray(x), jnp.asarray(e), THRESHOLD,
                          group_size=G, backend=backend,
                          lsh_bits=LSH_BITS)
    # the live tile fraction the kernel's early-out sees (mask only)
    _, measured_frac = fast_similarity(
        jnp.asarray(x), jnp.asarray(e), None, 0.8, 0.2, backend=backend,
        lsh_bits=LSH_BITS)
    same = e[:, None] == e[None, :]
    if backend == "lsh":
        from repro.condense import lsh_codes
        code = np.asarray(lsh_codes(jnp.asarray(x), bits=LSH_BITS))
        mask = same & (code[:, None] == code[None, :])
    else:
        mask = same
    return {
        "measured_pairs": float(out.measured_pairs),
        "measured_frac": float(measured_frac),
        "tile_frac": mask_tile_fraction(mask),
        "rate": float(out.rate),
    }


def sweep(groups):
    from repro.condense import expected_measured_pairs
    from repro.plan import estimate_similarity_ms
    rng = np.random.default_rng(0)
    out = {"d_model": D_MODEL, "num_experts": N_EXPERTS,
           "lsh_bits": LSH_BITS, "threshold": THRESHOLD, "cells": {}}
    for G in groups:
        xr, er = _random_batch(rng, G)
        xd, ed = _duplicate_batch(rng, G)
        cell = {"G": G}
        for b in BACKENDS:
            r = _condense(xr, er, b)
            d = _condense(xd, ed, b)
            cell[b] = {
                "random": r, "duplicate": d,
                "modeled_pairs": expected_measured_pairs(
                    G, G, N_EXPERTS, backend=b, lsh_bits=LSH_BITS),
                "modeled_build_ms": estimate_similarity_ms(
                    r["measured_pairs"], D_MODEL),
            }
        out["cells"][f"G{G}"] = cell
    return out


def run(fast: bool = True) -> None:
    out = sweep(GROUPS_FAST if fast else GROUPS_SLOW)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / "fig_condense_backend.json"
    path.write_text(json.dumps(out, indent=1))

    rows = []
    ok_fewer = True
    ok_rate = True
    for name, c in out["cells"].items():
        ex, ls = c["exact"], c["lsh"]
        cut = ex["random"]["measured_pairs"] / max(
            ls["random"]["measured_pairs"], 1.0)
        rows.append((f"condense_backend/{name}/measured_pairs", 0.0,
                     f"exact={ex['random']['measured_pairs']:.0f} "
                     f"lsh={ls['random']['measured_pairs']:.0f} "
                     f"({cut:.1f}x fewer)"))
        rows.append((f"condense_backend/{name}/dup_rate", 0.0,
                     f"exact={ex['duplicate']['rate']:.3f} "
                     f"lsh={ls['duplicate']['rate']:.3f}"))
        # the CI contracts (ISSUE 5 satellite)
        if c["G"] >= 256:
            ok_fewer &= (ls["random"]["measured_pairs"]
                         < ex["random"]["measured_pairs"])
        ok_rate &= ls["duplicate"]["rate"] == ex["duplicate"]["rate"]
    rows.append(("condense_backend/lsh_fewer_pairs_ge256", 0.0,
                 str(ok_fewer)))
    rows.append(("condense_backend/dup_rate_identical", 0.0,
                 str(ok_rate)))
    rows.append(("condense_backend/json", 0.0, str(path)))
    emit(rows)
    if not (ok_fewer and ok_rate):
        raise AssertionError(
            f"condense-backend contract violated: fewer_pairs={ok_fewer} "
            f"dup_rate_identical={ok_rate}")


if __name__ == "__main__":
    run()
