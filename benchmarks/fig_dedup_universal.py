"""Beyond-paper deliverable (DESIGN.md §15): the universal dedup wire
swept across execution mode × expert skew.

Two contracts, both pinned at modeled-pricing level (the executed twins
live in ``tests/test_wire_dtype.py`` / ``tests/test_condense.py``):

* **mode sweep** — with ``hier_dedup="on"`` the shipped inter-node
  bytes drop STRICTLY below the dense (flat) wire in every execution
  mode — vanilla, migrate, pipelined — and the three per-mode ledger
  numbers coincide (dispatch dedup is mode-independent: experts never
  move, so the (token, node) unique packing is the same). With the
  wire off, shipped == flat in all three.
* **skew sweep** — the "replicate" planner objective (HierMoE-style
  intra-node hot-expert replication) is NEVER worse than the
  migration-only "traffic" objective under the modeled exposed time,
  and STRICTLY better once the hottest expert's demand reaches
  ``REPLICATE_SKEW_MIN`` (2×) the mean — the regime where re-homing
  whole sequences cannot split one expert's serialized demand. The
  model is exactly the planner's own arithmetic: relief
  ``ffn_ms · hot_share / 2`` against
  ``repro.plan.estimate.replica_consistency_ms``.

Emits CSV rows and ``artifacts/fig_dedup_universal.json``.
"""
from __future__ import annotations

import json
import os
import time
import types

import numpy as np

from benchmarks.common import ARTIFACTS, emit


def _fake_mesh(data: int = 16, model: int = 16):
    return types.SimpleNamespace(axis_names=("data", "model"),
                                 devices=np.zeros((data, model)))


def run(fast: bool = True) -> None:
    # importing the dryrun launcher sets XLA_FLAGS for its own 512-device
    # use; restore the harness environment (same dance as the tests)
    saved = os.environ.get("XLA_FLAGS")
    from repro.launch.dryrun import comm_traffic_ledger
    if saved is None:
        os.environ.pop("XLA_FLAGS", None)
    else:
        os.environ["XLA_FLAGS"] = saved
    import jax.numpy as jnp

    from repro.comm.topology import Topology
    from repro.config import SHAPES
    from repro.configs import get_config
    from repro.plan.estimate import replica_consistency_ms
    from repro.plan.objectives import (REPLICATE_SKEW_MIN,
                                       plan_expert_replicas)

    cfg = get_config("moe-gpt2")
    rows = []
    result = {"modes": {}, "skew": {}}

    # ---- mode sweep: the dedup wire is universal -------------------------
    MODE_KEYS = ("shipped_vanilla_bytes", "shipped_migrate_bytes",
                 "shipped_pipelined_bytes")
    for nodes in (2, 4, 8):
        t0 = time.perf_counter()
        on = comm_traffic_ledger(cfg, SHAPES["train_4k"], _fake_mesh(),
                                 nodes=nodes, hier_dedup="on")
        off = comm_traffic_ledger(cfg, SHAPES["train_4k"], _fake_mesh(),
                                  nodes=nodes)
        dt_us = (time.perf_counter() - t0) * 1e6
        dense = off["buckets"]["0.0"]["flat"]["inter_bytes"]
        shipped = [on["wire"][k] for k in MODE_KEYS]
        # one number covers vanilla + migrate + pipelined …
        assert len(set(shipped)) == 1, (nodes, shipped)
        # … and it drops STRICTLY below the dense wire in every mode
        for k, s in zip(MODE_KEYS, shipped):
            assert s < dense, (nodes, k, s, dense)
        # wire off: every mode ships the dense bytes
        assert all(off["wire"][k] == dense for k in MODE_KEYS), nodes
        factor = dense / max(shipped[0], 1.0)
        rows.append((f"dedup_universal/nodes{nodes}", dt_us,
                     f"dense={dense:.3g}B shipped={shipped[0]:.3g}B "
                     f"x{factor:.2f}"))
        result["modes"][str(nodes)] = {
            "dense_inter_bytes": dense,
            "shipped_inter_bytes": shipped[0],
            "dedup_factor": factor,
        }

    # ---- skew sweep: replication vs migration-only -----------------------
    # The planner's own exposed-time arithmetic: the hottest expert
    # serializes ffn_ms·(load/total) of the FFN stage; a replica halves
    # that at replica_consistency_ms per step. "traffic" (migration
    # only) cannot split one expert's demand, so its exposed time IS the
    # unrelieved hot share.
    topo = Topology(2, 4)
    e_local = 2
    E = e_local * topo.num_devices
    d, dff = cfg.d_model, cfg.moe.d_ff
    cost_ms = replica_consistency_ms(1, d, dff, topo=topo)
    ffn_ms = 3.0 * E * cost_ms     # relief at 2x skew = 3·cost > cost
    base = 100.0
    for skew in (1.0, 1.5, 2.0, 4.0, 8.0):
        # hot/mean == skew exactly: hot = skew·b·(E-1)/(E-skew)
        hot = skew * base * (E - 1) / (E - skew)
        load = np.full((E,), base, np.float32)
        load[0] = hot
        t0 = time.perf_counter()
        rep = np.asarray(plan_expert_replicas(
            jnp.asarray(load), e_local=e_local, topo=topo, ffn_ms=ffn_ms,
            d_model=d, d_ff=dff))
        dt_us = (time.perf_counter() - t0) * 1e6
        n_rep = int((rep >= 0).sum())
        hot_share = float(load.max() / load.sum())
        t_traffic = ffn_ms * hot_share
        relief = ffn_ms * hot_share / 2.0
        t_rep = t_traffic - (relief - cost_ms * n_rep if n_rep else 0.0)
        # never worse than migration-only …
        assert t_rep <= t_traffic + 1e-9, (skew, t_rep, t_traffic)
        if skew >= REPLICATE_SKEW_MIN:
            # … and strictly better at >= 2x skew
            assert n_rep >= 1 and t_rep < t_traffic, (skew, n_rep)
        else:
            # below the gate nothing replicates (consistency not paid)
            assert n_rep == 0 and t_rep == t_traffic, (skew, n_rep)
        rows.append((f"dedup_universal/skew{skew:g}", dt_us,
                     f"replicas={n_rep} traffic={t_traffic:.3f}ms "
                     f"replicate={t_rep:.3f}ms"))
        result["skew"][f"{skew:g}"] = {
            "replicas": n_rep, "exposed_traffic_ms": t_traffic,
            "exposed_replicate_ms": t_rep,
            "consistency_ms": cost_ms * n_rep,
        }

    emit(rows)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / "fig_dedup_universal.json").write_text(
        json.dumps(result, indent=1))


if __name__ == "__main__":
    run()
