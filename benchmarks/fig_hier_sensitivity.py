"""Hierarchy sensitivity (beyond-paper deliverable, DESIGN.md §5):
predicted AND simulated speedup of hierarchical two-phase dispatch over
flat all-to-all as the intra/inter bandwidth ratio sweeps 1×–16×.

Two independent estimates per ratio:

* ``pred`` — the calibrated analytic model (``commsim.predict`` with the
  ``vanilla-hier``/``luffy-hier`` systems): closed-form dedup factor,
  uniform routing;
* ``sim`` — a monte-carlo routing simulation
  (``repro.comm.simulate_dispatch_rows``): sampled top-k expert draws,
  exact per-node dedup counting, timed on the same topology.

Their agreement is the cross-check that the closed form used by the
migration planner and the dry-run ledger is trustworthy.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit

RATIOS = (1.0, 2.0, 4.0, 8.0, 16.0)


def _sim_speedup(topo, tokens: int, top_k: int,
                 d_model: int, r_cond: float, seed: int = 0) -> float:
    """Simulated flat/hier dispatch time ratio for one source device."""
    from repro.comm import a2a_time_s, simulate_dispatch_rows
    rng = np.random.default_rng(seed)
    flat_rows, dedup_rows, intra_rows = simulate_dispatch_rows(
        rng, tokens, top_k, topo, r_cond=r_cond)
    row = d_model * 4
    # flat path: every remote copy crosses whatever link reaches it
    t_flat = a2a_time_s(intra_rows * row, flat_rows * row, topo)
    # hier path: copies move once on the cheap axis, deduped across nodes
    kept = tokens * (1.0 - r_cond) * top_k
    t_hier = a2a_time_s(kept * row * (1.0 - 1.0 / topo.num_devices),
                        dedup_rows * row, topo)
    return t_flat / t_hier


def run(fast: bool = True) -> None:
    from repro.comm import Topology
    from repro.configs import get_config
    from repro.core import commsim

    cfg = get_config("moe-gpt2", num_experts=8)
    setup = commsim.PaperSetup(cfg=cfg)
    comp_ms, comm_ms = commsim.PAPER_VANILLA["moe-gpt2"][8]
    cal = commsim.calibrate(setup, comp_ms, comm_ms)
    rates = commsim.PAPER_RATES["moe-gpt2"]
    tokens = 2048 if fast else 16384

    rows = []
    for ratio in RATIOS:
        topo = Topology(num_nodes=2, devices_per_node=4,
                        intra_bw=ratio, inter_bw=1.0)
        # predicted: flat vs two-phase dispatch on the SAME fabric
        # (closed-form dedup factor; link_bw cancels in the ratio)
        from repro.comm import a2a_time_s, dispatch_bytes
        fi, fe = dispatch_bytes(setup.tokens, setup.top_k, cfg.d_model,
                                topo=topo)
        hi, he = dispatch_bytes(setup.tokens, setup.top_k, cfg.d_model,
                                topo=topo, dedup=True)
        pred_v = a2a_time_s(fi, fe, topo) / a2a_time_s(hi, he, topo)
        sim_v = _sim_speedup(topo, tokens, setup.top_k,
                             cfg.d_model, 0.0)
        sim_l = _sim_speedup(topo, tokens, setup.top_k,
                             cfg.d_model, rates["r_cond"])
        # end-to-end calibrated model: luffy on this fabric
        lh = commsim.predict(
            setup, cal, system="luffy-hier",
            topo=commsim.default_topology(8, nodes=2, bw_ratio=ratio),
            r_cond=rates["r_cond"], locality=rates["locality"])
        rows.append((f"hier_sens/ratio{ratio:g}/pred_vanilla", 0.0,
                     f"{pred_v:.3f}"))
        rows.append((f"hier_sens/ratio{ratio:g}/sim_vanilla", 0.0,
                     f"{sim_v:.3f}"))
        rows.append((f"hier_sens/ratio{ratio:g}/sim_luffy", 0.0,
                     f"{sim_l:.3f}"))
        rows.append((f"hier_sens/ratio{ratio:g}/pred_luffy_comm_ms", 0.0,
                     f"{lh['comm_ms']:.1f}"))
    emit(rows)


if __name__ == "__main__":
    run()
