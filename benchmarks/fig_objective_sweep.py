"""Beyond-paper deliverable (DESIGN.md §7): planner-objective sweep —
``"traffic"`` vs ``"overlap"`` migration plans priced by commsim's
calibrated model on a 2-node hierarchical fabric.

For each intra/inter bandwidth ratio, the calibrated analytic model
(``commsim`` moe-gpt2 setup) fixes the plan-invariant pipeline context
(expert-FFN stage time, dispatch phase times per tier, executed chunk
count); skewed synthetic routing instances are then planned under BOTH
registered objectives and evaluated with the phase-decomposed
exposed-time model (``repro.plan.objectives.plan_exposed_ms``). Emits
CSV rows and writes ``artifacts/fig_objective_sweep.json`` so CI can
assert the objective contract: the ``"overlap"`` plan's modeled exposed
time is **never worse** than the ``"traffic"`` plan's at any ratio, and
strictly better somewhere on the sweep (the portfolio selection must
actually fire, not just tie).
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import ARTIFACTS, emit

RATIOS = (1.0, 2.0, 4.0, 8.0, 16.0)
PAPER_BW_RATIO = 4.0
N_INSTANCES = 24
CHUNKS = 4


def _instances(n_slots, M, n):
    """Skewed routing: each sequence's expert copies concentrate on a few
    devices (the regime migration exists for)."""
    out = []
    for seed in range(n):
        r = np.random.default_rng(seed)
        counts = r.random((n_slots, M)) ** 3
        counts = counts / counts.sum(1, keepdims=True) * 100
        counts = counts + r.random(counts.shape) * 1e-3
        lens = r.integers(10, 100, n_slots).astype(np.float64)
        out.append((counts.astype(np.float64), lens))
    return out


def sweep(model: str = "moe-gpt2", num_experts: int = 8, nodes: int = 2,
          chunks: int = CHUNKS, n_instances: int = N_INSTANCES):
    from repro.comm import Topology
    from repro.configs import get_config
    from repro.core import commsim
    from repro.plan import ObjectiveContext, plan_migration_with_objective
    from repro.plan.objectives import combine_tier_ms, plan_exposed_ms

    cfg = get_config(model, num_experts=num_experts)
    setup = commsim.PaperSetup(cfg=cfg)
    comp_ms, comm_ms = commsim.PAPER_VANILLA[model][num_experts]
    cal = commsim.calibrate(setup, comp_ms, comm_ms)
    n_per_dev = 2
    n_slots = num_experts * n_per_dev
    insts = _instances(n_slots, num_experts, n_instances)
    row_bytes = float(cfg.d_model * commsim.BYTES)
    home = np.arange(n_slots) // n_per_dev

    out = {"model": model, "num_experts": num_experts, "nodes": nodes,
           "chunks": chunks, "n_instances": n_instances,
           "paper_bw_ratio": PAPER_BW_RATIO, "ratios": {}}
    for ratio in RATIOS:
        # calibrated pricing: the paper's effective all-to-all bandwidth
        # on the expensive tier, `ratio`× faster inside a node
        topo = Topology(nodes, num_experts // nodes,
                        intra_bw=cal.link_bw * ratio,
                        inter_bw=cal.link_bw)
        t_tr, t_ov = [], []
        for counts, lens in insts:
            # plan-invariant stages priced on THIS instance's routing:
            # dispatch ships the same rows the identity-plan combine
            # would, and the expert FFN covers every dispatched row at
            # the calibrated compute throughput
            d_i, d_e = combine_tier_ms(counts, home, topo, row_bytes)
            ffn_ms = float(counts.sum()) * 4.0 * cfg.d_model \
                * cfg.moe.d_ff / cal.speed * 1e3
            ctx = ObjectiveContext(
                topo=topo, ffn_ms=ffn_ms, dispatch_intra_ms=float(d_i),
                dispatch_inter_ms=float(d_e), chunks=chunks,
                row_bytes=row_bytes)
            p_t = plan_migration_with_objective(
                counts, lens, n_per_dev, objective="traffic", ctx=ctx)
            p_o = plan_migration_with_objective(
                counts, lens, n_per_dev, objective="overlap", ctx=ctx)
            t_tr.append(float(plan_exposed_ms(
                counts, np.asarray(p_t.assign), ctx)))
            t_ov.append(float(plan_exposed_ms(
                counts, np.asarray(p_o.assign), ctx)))
        t_tr, t_ov = np.asarray(t_tr), np.asarray(t_ov)
        out["ratios"][f"{ratio:g}"] = {
            "traffic_exposed_ms_mean": float(t_tr.mean()),
            "overlap_exposed_ms_mean": float(t_ov.mean()),
            "never_worse": bool((t_ov <= t_tr + 1e-9).all()),
            "strictly_better_frac": float((t_ov < t_tr - 1e-9).mean()),
            "max_regression_ms": float((t_ov - t_tr).max()),
        }
    return out


def run(fast: bool = True) -> None:
    out = sweep()
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / "fig_objective_sweep.json"
    path.write_text(json.dumps(out, indent=1))

    rows = []
    for ratio, rec in out["ratios"].items():
        rows.append((f"objective/ratio{ratio}/traffic_exposed_ms", 0.0,
                     f"{rec['traffic_exposed_ms_mean']:.2f}"))
        rows.append((f"objective/ratio{ratio}/overlap_exposed_ms", 0.0,
                     f"{rec['overlap_exposed_ms_mean']:.2f} "
                     f"better_frac={rec['strictly_better_frac']:.2f}"))
    # the contract CI smoke-checks (ISSUE acceptance): overlap-objective
    # plans never model MORE exposed time than traffic plans, and the
    # portfolio actually wins somewhere on the sweep
    ok_never_worse = all(rec["never_worse"]
                         for rec in out["ratios"].values())
    ok_wins = any(rec["strictly_better_frac"] > 0.0
                  for rec in out["ratios"].values())
    rows.append(("objective/never_worse", 0.0, str(ok_never_worse)))
    rows.append(("objective/strictly_better_somewhere", 0.0, str(ok_wins)))
    rows.append(("objective/json", 0.0, str(path)))
    emit(rows)
    if not (ok_never_worse and ok_wins):
        raise AssertionError(
            f"planner objective contract violated: never_worse="
            f"{ok_never_worse} wins_somewhere={ok_wins}")


if __name__ == "__main__":
    run()
