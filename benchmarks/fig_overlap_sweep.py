"""Beyond-paper deliverable (DESIGN.md §6): chunk-count × bandwidth-ratio
sweep of the pipelined MoE executor's modeled step time.

For each intra/inter bandwidth ratio, the calibrated analytic model
(``commsim`` ``vanilla-overlap`` / ``luffy-overlap``) prices one training
step with the dispatch/FFN/combine pipeline split into 1..N capacity
chunks (``repro.sched.cost.overlap_ms``). Emits CSV rows and writes the
full sweep to ``artifacts/fig_overlap_sweep.json`` so CI can assert the
model's two contracts: step time is monotonically non-increasing from
1 chunk to the optimal chunk count, and the predicted speedup at the
paper's bandwidth ratio (4×: ~50 GB/s ICI over ~12 GB/s DCN) is ≥ 1.2×.
"""
from __future__ import annotations

import json

from benchmarks.common import ARTIFACTS, emit

RATIOS = (1.0, 2.0, 4.0, 8.0, 16.0)
CHUNKS = (1, 2, 3, 4, 6, 8, 12, 16)
PAPER_BW_RATIO = 4.0          # DEFAULT_INTRA_BW / DEFAULT_INTER_BW
SYSTEMS = ("vanilla-overlap", "luffy-overlap")


def sweep(model: str = "moe-gpt2", num_experts: int = 8, nodes: int = 2):
    from repro.configs import get_config
    from repro.core import commsim

    cfg = get_config(model, num_experts=num_experts)
    setup = commsim.PaperSetup(cfg=cfg)
    comp_ms, comm_ms = commsim.PAPER_VANILLA[model][num_experts]
    cal = commsim.calibrate(setup, comp_ms, comm_ms)
    rates = commsim.PAPER_RATES[model]

    out = {"model": model, "num_experts": num_experts, "nodes": nodes,
           "paper_bw_ratio": PAPER_BW_RATIO, "chunk_counts": list(CHUNKS),
           "ratios": {}}
    for ratio in RATIOS:
        topo = commsim.default_topology(num_experts, nodes=nodes,
                                        bw_ratio=ratio)
        entry = {}
        for system in SYSTEMS:
            kw = dict(system=system, topo=topo, r_cond=rates["r_cond"],
                      locality=rates["locality"])
            steps = [commsim.predict(setup, cal, chunks=n, **kw)["step_ms"]
                     for n in CHUNKS]
            opt = commsim.predict(setup, cal, chunks=None, **kw)
            entry[system] = {
                "step_ms": steps,
                "sync_ms": opt["sync_ms"],
                "opt_chunks": opt["chunks"],
                "opt_step_ms": opt["step_ms"],
                "speedup": opt["sync_ms"] / opt["step_ms"],
            }
        out["ratios"][f"{ratio:g}"] = entry
    return out


def run(fast: bool = True) -> None:
    out = sweep()
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / "fig_overlap_sweep.json"
    path.write_text(json.dumps(out, indent=1))

    rows = []
    for ratio, entry in out["ratios"].items():
        for system, rec in entry.items():
            tag = system.split("-")[0]
            rows.append((f"overlap/ratio{ratio}/{tag}/sync_ms", 0.0,
                         f"{rec['sync_ms']:.1f}"))
            rows.append((f"overlap/ratio{ratio}/{tag}/opt", 0.0,
                         f"chunks={rec['opt_chunks']} "
                         f"step_ms={rec['opt_step_ms']:.1f} "
                         f"speedup={rec['speedup']:.2f}"))
    # the two contracts CI smoke-checks (see ISSUE/acceptance): monotone
    # non-increasing step time up to the optimum, >=1.2x at the paper
    # ratio. Emitted as booleans so a regression is visible in the CSV.
    paper = out["ratios"][f"{out['paper_bw_ratio']:g}"]
    ok_speed = all(rec["speedup"] >= 1.2 for rec in paper.values())
    ok_mono = True
    for entry in out["ratios"].values():
        for rec in entry.values():
            upto = [s for n, s in zip(CHUNKS, rec["step_ms"])
                    if n <= rec["opt_chunks"]]
            ok_mono &= all(a >= b - 1e-9 for a, b in zip(upto, upto[1:]))
    rows.append(("overlap/monotone_to_opt", 0.0, str(ok_mono)))
    rows.append(("overlap/paper_ratio_speedup>=1.2", 0.0, str(ok_speed)))
    rows.append(("overlap/json", 0.0, str(path)))
    emit(rows)
    if not (ok_mono and ok_speed):
        raise AssertionError(
            f"overlap cost-model contract violated: mono={ok_mono} "
            f"speedup={ok_speed}")


if __name__ == "__main__":
    run()
