"""Beyond-paper deliverable (DESIGN.md §9): plan-reuse sweep — routing
stability × layer count, driven through the SAME revalidation predicate
the traced forward uses (``repro.plan.routing_signature_matches`` /
``next_signature`` on the host/numpy backend).

For each (stability, layers) cell a layer stack is simulated: layer 1
plans migration on a random routing instance; each later layer observes
either the carried plan's expected (frame-permuted) planner inputs
(stable, probability ``s``) or a fresh routing draw (drifted). The reuse
controller revalidates the carried signature and replans only on a
mismatch, counting planning calls and measuring the wall time of every
real ``plan_migration_with_objective`` call, next to the analytic
``estimate_planning_ms`` model the dryrun ledger reports.

Emits CSV rows and ``artifacts/fig_plan_reuse.json``; CI asserts the
reuse contract: under fully stable routing the planning-call count drops
≥2× vs replanning every sublayer (it is exactly 1 per forward), and a
reused plan's traffic ledger equals the replanned one bit-for-bit.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import ARTIFACTS, emit

STABILITIES = (1.0, 0.9, 0.5, 0.0)
LAYERS = (4, 12, 24)
M = 8
N_PER_DEV = 2
N_TRIALS = 8


def _routing(rng, n_slots: int):
    """Skewed per-slot expert-copy counts (the migration regime); lens
    strictly distinct so the greedy's length order is tie-free."""
    counts = rng.random((n_slots, M)) ** 3
    counts = np.floor(counts / counts.sum(1, keepdims=True) * 64.0)
    lens = rng.permutation(np.arange(32, 32 + n_slots)).astype(np.float64)
    return counts.astype(np.float64), lens


def _simulate(stability: float, n_layers: int, seed: int):
    """One forward through ``n_layers`` MoE sublayers with the reuse
    controller; returns (replans, reuses, mismatches, plan_wall_s,
    ledger_parity)."""
    from repro.core.migration import home_plan
    from repro.plan import (next_signature, plan_migration_with_objective,
                            routing_signature_matches)

    rng = np.random.default_rng(seed)
    n_slots = M * N_PER_DEV
    counts, lens = _routing(rng, n_slots)
    sig = None
    replans = reuses = mismatches = 0
    wall = 0.0
    parity = True
    for _ in range(n_layers):
        if sig is not None and bool(
                routing_signature_matches(sig, counts, lens)):
            reuses += 1
            plan = home_plan(counts, N_PER_DEV)
            # the reuse guarantee: the skipped greedy would have kept
            # every sequence home — its ledger must match bit-for-bit
            # (the check's own planner call is not counted as a replan)
            full = plan_migration_with_objective(counts, lens, N_PER_DEV)
            parity &= np.array_equal(np.asarray(full.assign),
                                     np.asarray(plan.assign))
            parity &= float(full.traffic_after) == float(
                plan.traffic_after)
        else:
            if sig is not None:
                mismatches += 1
            replans += 1
            t0 = time.perf_counter()
            plan = plan_migration_with_objective(counts, lens, N_PER_DEV)
            wall += time.perf_counter() - t0
        sig = next_signature(counts, lens, np.asarray(plan.perm))
        if rng.random() < stability:
            # stable: the next layer observes exactly the carried
            # expectation (routing rides with the migrated sequences)
            counts, lens = np.asarray(sig.counts), np.asarray(sig.lens)
        else:
            counts, lens = _routing(rng, n_slots)
    return replans, reuses, mismatches, wall, parity


def sweep():
    from repro.plan import estimate_planning_ms

    out = {"M": M, "n_per_dev": N_PER_DEV, "n_trials": N_TRIALS,
           "modeled_planning_ms": estimate_planning_ms(M * N_PER_DEV, M),
           "cells": {}}
    for s in STABILITIES:
        for L in LAYERS:
            rep = np.zeros(N_TRIALS)
            reu = np.zeros(N_TRIALS)
            mis = np.zeros(N_TRIALS)
            wall = 0.0
            parity = True
            for t in range(N_TRIALS):
                r, u, mm, w, p = _simulate(s, L, seed=1000 * t + L)
                rep[t], reu[t], mis[t] = r, u, mm
                wall += w
                parity &= p
            out["cells"][f"s{s:g}_L{L}"] = {
                "stability": s, "layers": L,
                "replans_mean": float(rep.mean()),
                "reuses_mean": float(reu.mean()),
                "mismatches_mean": float(mis.mean()),
                "replans_off": L,          # "off" replans every sublayer
                "speedup_planning_calls": float(L / max(rep.mean(), 1e-9)),
                "measured_plan_wall_s": wall,
                "reuse_ledger_parity": bool(parity),
            }
    return out


def run(fast: bool = True) -> None:
    out = sweep()
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / "fig_plan_reuse.json"
    path.write_text(json.dumps(out, indent=1))

    rows = []
    for name, c in out["cells"].items():
        rows.append((f"plan_reuse/{name}/replans", 0.0,
                     f"{c['replans_mean']:.2f}/of {c['layers']} "
                     f"({c['speedup_planning_calls']:.1f}x fewer calls)"))
    # the contracts CI smoke-checks (ISSUE acceptance): fully stable
    # routing plans ONCE per forward (>=2x fewer planning calls than
    # replanning each sublayer), and every reused plan's ledger matched
    # the full replan bit-for-bit
    stable = [c for c in out["cells"].values() if c["stability"] == 1.0]
    ok_once = all(c["replans_mean"] == 1.0 for c in stable)
    ok_2x = all(c["speedup_planning_calls"] >= 2.0 for c in stable
                if c["layers"] >= 2)
    ok_parity = all(c["reuse_ledger_parity"]
                    for c in out["cells"].values())
    rows.append(("plan_reuse/stable_plans_once", 0.0, str(ok_once)))
    rows.append(("plan_reuse/stable_ge_2x_fewer_calls", 0.0, str(ok_2x)))
    rows.append(("plan_reuse/reuse_ledger_parity", 0.0, str(ok_parity)))
    rows.append(("plan_reuse/json", 0.0, str(path)))
    emit(rows)
    if not (ok_once and ok_2x and ok_parity):
        raise AssertionError(
            f"plan-reuse contract violated: plans_once={ok_once} "
            f"ge2x={ok_2x} parity={ok_parity}")


if __name__ == "__main__":
    run()
