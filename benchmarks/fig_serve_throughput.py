"""Beyond-paper deliverable (DESIGN.md §13): continuous batching vs the
fixed-batch serving driver under bursty arrivals, plus the modeled
decode_overlap saving across fabric shapes.

Both drivers are simulated on a virtual clock (one decode step = one
tick) over the SAME synthetic bursty arrival trace with heterogeneous
prompt/generation lengths. The continuous driver is the REAL
``repro.serve.scheduler.ContinuousScheduler`` fed fake logits — the
decision logic under benchmark is the shipped one, only the model call
is stubbed. The fixed-batch baseline admits up to B requests, decodes
until the whole batch drains (every slot waits for the slowest member),
then refills — the pre-ISSUE-8 ``launch/serve.py`` behavior.

Checks (hard asserts, CI runs this module):

* continuous batching generates >= the fixed-batch tokens/step on the
  bursty trace, at a slot-churn fraction > 50% (most admissions recycle
  a previously-used slot — the regime the invariance test covers);
* per-request SLOs (queue/TTFT) improve: the continuous mean queue time
  is <= the fixed-batch mean (no convoy behind a drained batch);
* the modeled decode_overlap step never exceeds sync on any swept
  fabric, and saves exactly ``min(combine, shared_ffn)`` per sublayer
  (``sched.cost.decode_step_ms``).

Emits CSV rows and ``artifacts/fig_serve_throughput.json``.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import ARTIFACTS, emit

VOCAB = 16


def _bursty_trace(n_requests: int, seed: int = 0):
    """(arrival_tick, prompt_len, max_new) per request: bursts of 3
    landing together every 6 ticks, heterogeneous lengths so a fixed
    batch convoys behind its slowest member."""
    r = np.random.default_rng(seed)
    out = []
    for i in range(n_requests):
        out.append((float((i // 3) * 6),
                    int(r.integers(2, 7)),
                    int(r.integers(2, 11))))
    return out


def _run_continuous(trace, n_slots: int):
    """Drive the real scheduler with fake logits on a virtual clock."""
    from repro.serve.scheduler import ContinuousScheduler

    sched = ContinuousScheduler(n_slots)
    logits = np.zeros((n_slots, VOCAB), np.float32)
    step, submitted = 0, 0
    while True:
        now = float(step)
        while submitted < len(trace) and trace[submitted][0] <= now:
            _, plen, gen = trace[submitted]
            sched.submit(np.ones(plen, np.int32), gen, now=trace[submitted][0])
            submitted += 1
        if sched.all_done():
            if submitted >= len(trace):
                break
            step += 1
            continue
        sched.admit(now=now)
        sched.next_feed()
        sched.observe(logits, now=now + 1.0)
        step += 1
    qs = [r.queue_ms for r in sched.done if r.queue_ms is not None]
    return {"steps": step, "tokens": sched.generated_tokens,
            "churn": sched.slot_churn, "admitted": sched.admitted,
            "queue_mean_ms": float(np.mean(qs)) if qs else 0.0}


def _run_fixed(trace, n_slots: int):
    """Fixed-batch baseline: admit up to B, decode until the WHOLE batch
    drains, refill. Same token accounting as the continuous driver."""
    queue = list(range(len(trace)))
    batch = []                      # [remaining_steps, total_gen]
    step = tokens = 0
    queue_waits = []
    while queue or batch:
        now = float(step)
        if not batch:
            ready = [i for i in queue if trace[i][0] <= now]
            if not ready:
                step += 1
                continue
            for i in ready[:n_slots]:
                queue.remove(i)
                _, plen, gen = trace[i]
                queue_waits.append((now - trace[i][0]) * 1e3)
                batch.append([plen + gen, gen])
        for slot in batch:
            if slot[0] > 0:
                slot[0] -= 1
                if slot[0] < slot[1]:   # past the prompt: generating
                    tokens += 1
        if all(s[0] == 0 for s in batch):
            batch = []                  # drained: next group may enter
        step += 1
    return {"steps": step, "tokens": tokens,
            "queue_mean_ms": float(np.mean(queue_waits))}


def run(fast: bool = True) -> None:
    from repro.comm.topology import Topology
    from repro.sched.cost import decode_combine_ms, decode_step_ms

    n_requests, n_slots = (24, 4) if fast else (96, 8)
    trace = _bursty_trace(n_requests)
    cont = _run_continuous(trace, n_slots)
    fixed = _run_fixed(trace, n_slots)
    cont_tps = cont["tokens"] / cont["steps"]
    fixed_tps = fixed["tokens"] / fixed["steps"]
    churn_frac = cont["churn"] / max(1, cont["admitted"])

    # the acceptance triple: throughput, churn regime, SLO
    assert cont["tokens"] == fixed["tokens"] == \
        sum(g for _, _, g in trace)
    assert churn_frac > 0.5, churn_frac
    assert cont_tps >= fixed_tps, (cont_tps, fixed_tps)
    assert cont["queue_mean_ms"] <= fixed["queue_mean_ms"]

    rows = [
        ("serve_continuous_tok_per_step", cont_tps * 1e3,
         f"steps={cont['steps']} churn={churn_frac:.2f}"),
        ("serve_fixed_tok_per_step", fixed_tps * 1e3,
         f"steps={fixed['steps']}"),
        ("serve_queue_ms_continuous", cont["queue_mean_ms"],
         "mean over requests"),
        ("serve_queue_ms_fixed", fixed["queue_mean_ms"],
         "mean over requests"),
    ]

    # modeled decode_overlap across fabrics: never worse than sync
    overlap_sweep = {}
    for topo in (Topology.flat(8), Topology(2, 4), Topology(4, 4)):
        combine = decode_combine_ms(64, 1024, topo)
        shared = 64 * 4.0 * 1024 * 4096 / 1e13 * 1e3
        sync = decode_step_ms(combine_ms=combine, shared_ffn_ms=shared,
                              overlap=False)
        ovl = decode_step_ms(combine_ms=combine, shared_ffn_ms=shared,
                             overlap=True)
        assert ovl <= sync
        assert abs((sync - ovl) - min(combine, shared)) < 1e-9
        name = f"decode_overlap_{topo.num_nodes}x{topo.devices_per_node}"
        overlap_sweep[name] = {"sync_ms": sync, "overlap_ms": ovl,
                               "speedup": sync / max(ovl, 1e-12)}
        rows.append((name, ovl * 1e3,
                     f"sync={sync:.3f}ms x{sync / max(ovl, 1e-12):.2f}"))

    emit(rows)
    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "fig_serve_throughput.json").write_text(json.dumps({
        "trace": {"requests": n_requests, "slots": n_slots},
        "continuous": cont, "fixed": fixed,
        "tok_per_step": {"continuous": cont_tps, "fixed": fixed_tps},
        "churn_frac": churn_frac,
        "overlap_sweep": overlap_sweep}, indent=2))


if __name__ == "__main__":
    run()
