"""Beyond-paper deliverable (DESIGN.md §14): the compressed exchange
swept across wire dtype × node split.

``LuffyConfig.wire_dtype`` ships activation rows across node boundaries
at f32 (identity), bf16 (cast) or f8e4m3 (block-scaled), priced by ONE
function (``repro.comm.dtypes.wire_precision``) that the plan estimate,
the executed ledger and this benchmark all share. The sweep runs the
dryrun ``comm_traffic_ledger`` over dtype × node-split and CHECKS the
two pricing laws the tests pin at execution time:

* **exact byte scaling** — for every dtype and split, every modeled
  byte field equals the f32 ledger's value divided by exactly
  ``wire_precision(d_model, dtype, 4)``: the ledger contract
  ``bytes == flat / (dedup × precision)`` with the dedup factor
  untouched by the wire dtype;
* **monotone modeled step** — the tuned/modeled step time is monotone
  non-increasing from f32 toward fp8 (shipping fewer bytes over the
  same links can never model slower), per split.

Emits CSV rows and ``artifacts/fig_wire_dtype.json``.
"""
from __future__ import annotations

import json
import os
import time
import types

import numpy as np

from benchmarks.common import ARTIFACTS, emit


def _fake_mesh(data: int = 16, model: int = 16):
    return types.SimpleNamespace(axis_names=("data", "model"),
                                 devices=np.zeros((data, model)))


def run(fast: bool = True) -> None:
    # importing the dryrun launcher sets XLA_FLAGS for its own 512-device
    # use; restore the harness environment (same dance as the tests)
    saved = os.environ.get("XLA_FLAGS")
    from repro.launch.dryrun import comm_traffic_ledger
    if saved is None:
        os.environ.pop("XLA_FLAGS", None)
    else:
        os.environ["XLA_FLAGS"] = saved
    from repro.comm import dtypes as wdt
    from repro.config import SHAPES
    from repro.configs import get_config

    cfg = get_config("moe-gpt2")
    dtypes = ["f32", "bf16"] + (["f8e4m3"] if wdt.have_f8() else [])
    rows = []
    result = {"d_model": cfg.d_model, "dtypes": dtypes, "sweep": {}}

    for nodes in (2, 4, 8):
        base = None
        sync_ms = []
        for wd in dtypes:
            t0 = time.perf_counter()
            led = comm_traffic_ledger(cfg, SHAPES["train_4k"],
                                      _fake_mesh(), nodes=nodes,
                                      wire_dtype=wd)
            dt_us = (time.perf_counter() - t0) * 1e6
            prec = wdt.wire_precision(cfg.d_model, wd, 4)
            assert led["wire"]["dtype"] == wd
            assert led["wire"]["precision"] == prec
            if wd == "f32":
                assert prec == 1.0
                base = led
            # exact 1/precision scaling of EVERY modeled byte field,
            # dedup factor untouched: bytes == flat/(dedup × precision)
            for r in led["buckets"]:
                b, b0 = led["buckets"][r], base["buckets"][r]
                for tier in ("flat", "hier"):
                    for f in ("inter_bytes", "intra_bytes"):
                        got, want = b[tier][f], b0[tier][f] / prec
                        assert abs(got - want) <= 1e-9 * max(want, 1.0), (
                            f"nodes={nodes} {wd} {r} {tier}.{f}: "
                            f"{got} != f32/{prec} = {want}")
            assert led["dedup_factor"] == base["dedup_factor"]
            s = led["buckets"]["0.0"]["overlap"]["sync_ms"]
            sync_ms.append(s)
            rows.append((f"wire/{wd}/nodes{nodes}", dt_us,
                         f"precision={prec:.3f} "
                         f"inter={led['buckets']['0.0']['hier']['inter_bytes']:.3g}B "
                         f"sync={s:.3f}ms"))
            result["sweep"].setdefault(str(nodes), {})[wd] = {
                "precision": prec,
                "row_bytes": led["wire"]["row_bytes"],
                "inter_bytes_hier":
                    led["buckets"]["0.0"]["hier"]["inter_bytes"],
                "inter_bytes_flat":
                    led["buckets"]["0.0"]["flat"]["inter_bytes"],
                "sync_ms": s,
            }
        # modeled step monotone non-increasing toward fp8
        for a, b in zip(sync_ms, sync_ms[1:]):
            assert b <= a + 1e-12, (
                f"nodes={nodes}: modeled step must be monotone "
                f"non-increasing toward fp8, got {sync_ms}")

    emit(rows)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / "fig_wire_dtype.json").write_text(
        json.dumps(result, indent=1))


if __name__ == "__main__":
    run()
