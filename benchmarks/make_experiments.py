"""Assemble EXPERIMENTS.md from the dry-run / perf-variant artifacts.

    PYTHONPATH=src python -m benchmarks.make_experiments
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import ARTIFACTS, ROOT
from benchmarks.roofline import analyze

GiB = 2**30
MiB = 2**20


def load(mesh):
    recs = {}
    for f in sorted((ARTIFACTS / "dryrun").glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_bytes(b):
    return f"{b/GiB:.2f}"


def dryrun_table(recs16, recs2):
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    lines = [
        "| arch | shape | 16×16: status / temp GiB / analytic static GiB "
        "/ coll GiB | 2×16×16: status / temp GiB / coll GiB |",
        "|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(recs16.items(),
                                   key=lambda kv: (kv[0][0],
                                                   shapes.index(kv[0][1]))):
        r2 = recs2.get((arch, shape), {})

        def cell(rec, with_analytic=False):
            if not rec:
                return "—"
            if rec["status"] == "skipped":
                return "skipped (full-attn)"
            if rec["status"] != "ok":
                return "ERROR"
            m = rec["memory"]
            a = rec.get("analytic", {})
            stat = (a.get("param_bytes_per_device", 0)
                    + a.get("opt_moment_bytes_per_device", 0)
                    + a.get("cache_bytes_per_device", 0))
            coll = sum(v["wire_bytes"] for v in
                       rec.get("corrected", {}).get("collectives",
                                                    {}).values())
            base = (f"ok / {m['temp_bytes']/GiB:.1f}"
                    + (f" / {stat/GiB:.2f}" if with_analytic else "")
                    + f" / {coll/GiB:.2f}")
            return base

        lines.append(f"| {arch} | {shape} | {cell(r, True)} | "
                     f"{cell(r2)} |")
    return "\n".join(lines)


def roofline_table(recs16):
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    lines = [
        "| arch | shape | compute ms | memory ms (lo…hi) | collective ms |"
        " dominant | MODEL/HLO | next move |",
        "|---|---|---|---|---|---|---|---|"]
    doms = {}
    for (arch, shape), r in sorted(recs16.items(),
                                   key=lambda kv: (kv[0][0],
                                                   shapes.index(kv[0][1]))):
        if r["status"] != "ok":
            continue
        a = analyze(r)
        doms[(arch, shape)] = a
        lines.append(
            f"| {arch} | {shape} | {a['t_compute_s']*1e3:.1f} | "
            f"{a['t_memory_s']*1e3:.1f}…{a['t_memory_hi_s']*1e3:.0f} | "
            f"{a['t_collective_s']*1e3:.1f} | **{a['dominant']}** | "
            f"{min(a['useful_ratio'],9.99):.2f} | {a['hint']} |")
    return "\n".join(lines), doms


def perf_records():
    out = {}
    pdir = ARTIFACTS / "perf"
    if pdir.exists():
        for f in sorted(pdir.glob("*.json")):
            r = json.loads(f.read_text())
            out[(r["arch"], r["shape"], r["variant"])] = r
    return out


def perf_metrics(r):
    a = analyze(r)
    coll = sum(v["wire_bytes"] for v in
               r.get("corrected", {}).get("collectives", {}).values())
    return {
        "compute_ms": a["t_compute_s"] * 1e3,
        "mem_lo_ms": a["t_memory_s"] * 1e3,
        "mem_hi_ms": a["t_memory_hi_s"] * 1e3,
        "coll_ms": a["t_collective_s"] * 1e3,
        "wire_GiB": coll / GiB,
        "flops": r["corrected"]["flops"],
        "temp_GiB": r["memory"]["temp_bytes"] / GiB,
        "dominant": a["dominant"],
    }


def main():
    recs16 = load("16x16")
    recs2 = load("2x16x16")
    roof, doms = roofline_table(recs16)
    perf = perf_records()

    def pm(arch, shape, var):
        r = perf.get((arch, shape, var))
        return perf_metrics(r) if r and r.get("status") == "ok" else None

    sections = {
        "DRYRUN_TABLE": dryrun_table(recs16, recs2),
        "ROOFLINE_TABLE": roof,
        "N_OK_16": str(sum(1 for r in recs16.values()
                           if r["status"] == "ok")),
        "N_SKIP_16": str(sum(1 for r in recs16.values()
                             if r["status"] == "skipped")),
        "N_OK_2": str(sum(1 for r in recs2.values()
                          if r["status"] == "ok")),
    }
    # perf variant metric blobs for the narrative
    blob = {}
    for key in set((a, s) for a, s, v in perf):
        for v in ("band_off", "band_on", "decode2d_off", "decode2d_on",
                  "noluffy", "bucket0", "bucket1", "bucket2",
                  "unroll1", "unroll8"):
            m = pm(key[0], key[1], v)
            if m:
                blob[f"{key[0]}|{key[1]}|{v}"] = m
    (ARTIFACTS / "perf_metrics.json").write_text(
        json.dumps(blob, indent=1, default=float))
    tmpl_path = ROOT / "EXPERIMENTS.template.md"
    if tmpl_path.exists():
        text = tmpl_path.read_text()
        for k, v in sections.items():
            text = text.replace("{{" + k + "}}", v)
        # inline perf metrics: {{PERF:arch|shape|variant:field}}
        import re

        def sub(m):
            key, field = m.group(1), m.group(2)
            rec = blob.get(key)
            if not rec:
                return "n/a"
            v = rec[field]
            return f"{v:.2f}" if isinstance(v, float) else str(v)

        text = re.sub(r"\{\{PERF:([^:}]+):(\w+)\}\}", sub, text)
        (ROOT / "EXPERIMENTS.md").write_text(text)
        print("EXPERIMENTS.md written")
    else:
        print("no template; artifacts/perf_metrics.json written")


if __name__ == "__main__":
    main()
