"""Roofline analysis (deliverable g): three terms per (arch × shape) from
the dry-run artifacts.

    compute    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory     = HLO_bytes / HBM_bw               (per chip)
    collective = on-wire collective bytes / ICI_bw (per chip)

On-wire bytes per collective op (result bytes R, group size g):
    all-gather R·(g−1)/g · all-reduce 2R·(g−1)/g · all-to-all R·(g−1)/g ·
    reduce-scatter R·(g−1) · collective-permute R.

MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (inference);
the ratio to HLO_FLOPs exposes remat/padding waste. NOTE (DESIGN.md): the
CPU backend emulates bf16 dots via f32 staging, which inflates HLO bytes
(memory term) for bf16 archs; FLOPs and collective structure are
unaffected except f32-upcast weight gathers (flagged per-pair).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.common import ARTIFACTS, emit

PEAK = 197e12
HBM = 819e9
ICI = 4.9e10

_WIRE = {
    "all-gather": lambda R, g: R * (g - 1) / max(g, 1),
    "all-reduce": lambda R, g: 2 * R * (g - 1) / max(g, 1),
    "all-to-all": lambda R, g: R * (g - 1) / max(g, 1),
    "reduce-scatter": lambda R, g: R * (g - 1),
    "collective-permute": lambda R, g: R,
}


def collective_wire_bytes(coll: dict) -> float:
    total = 0.0
    for kind, rec in coll.items():
        ops = rec.get("ops", [])
        if ops and rec["count"] <= len(ops):
            for op in ops:
                g = op.get("groups") or 2
                total += _WIRE[kind](op["bytes"], g)
        elif rec["bytes"]:
            # sampled: apply the mean factor of the sampled ops
            if ops:
                f = sum(_WIRE[kind](o["bytes"], o.get("groups") or 2)
                        for o in ops) / max(sum(o["bytes"] for o in ops), 1)
            else:
                f = 1.0
            total += rec["bytes"] * f
    return total


def analyze(rec: dict) -> dict:
    cor = rec.get("corrected")
    if cor and cor.get("flops"):
        # loop-corrected analysis (hlo_analysis.py): scan bodies scaled by
        # trip counts; wire bytes with per-op (g-1)/g factors; f32 share
        # halved (CPU bf16-emulation converts would be bf16 on TPU).
        flops = cor["flops"]
        # memory: two estimates. upper = unfused 2x-result-bytes proxy
        # (every op result round-trips HBM); lower = XLA's fusion-aware
        # bytes_accessed scaled by the loop-correction ratio of the flops.
        upper = cor["bytes_touched"]
        raw_f = max(rec["cost"]["flops"], 1.0)
        lower = rec["cost"]["bytes_accessed"] * min(
            max(cor["flops"] / raw_f, 1.0), 1e6)
        hbm_bytes = (lower, min(upper, max(upper, lower)))
        wire = sum(v["wire_bytes"] - 0.5 * v.get("wire_bytes_f32", 0.0)
                   for v in cor["collectives"].values())
    else:
        flops = rec["cost"]["flops"]
        b = rec["cost"]["bytes_accessed"]
        hbm_bytes = (b, b)
        wire = collective_wire_bytes(rec["collectives"])
    t_c = flops / PEAK
    t_m = hbm_bytes[0] / HBM
    t_m_hi = max(hbm_bytes) / HBM
    t_n = wire / ICI
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
              key=lambda kv: kv[1])[0]
    n_dev = rec["num_devices"]
    shp = rec["shape"]
    tokens = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
              "decode_32k": 128, "long_500k": 1}[shp]
    mult = 6 if shp == "train_4k" else 2
    model_flops = mult * rec["model"]["active_params"] * tokens / n_dev
    ratio = model_flops / flops if flops else 0.0
    hints = {
        "compute": "shrink redundant FLOPs (remat policy, window band "
                   "skipping, condensation bucket >0 removes expert rows)",
        "memory": "fuse/bf16 the HBM-heavy ops; flash-attention / "
                  "chunked-scan kernels keep scores/state in VMEM",
        "collective": "MoE: migration locality + condensation bucket + "
                      "2D expert decode; dense: bf16/pinned KV & weight "
                      "gathers, neighbor-only window exchange",
    }
    return {"t_compute_s": t_c, "t_memory_s": t_m,
            "t_memory_hi_s": t_m_hi, "t_collective_s": t_n,
            "dominant": dom, "model_flops": model_flops,
            "useful_ratio": ratio, "hint": hints[dom]}


def load_records(mesh="16x16"):
    out = []
    for f in sorted(ARTIFACTS.glob(f"dryrun/*__{mesh}.json")):
        rec = json.loads(f.read_text())
        # skip the hyphen-named duplicates of early manual runs
        if "-" in rec["arch"] and (ARTIFACTS / "dryrun" /
                                   f"{rec['arch'].replace('-', '_').replace('.', 'p')}__{rec['shape']}__{mesh}.json").exists():
            continue
        out.append((f.name, rec))
    return out


def run(fast: bool = True):
    rows = []
    table = []
    for name, rec in load_records("16x16"):
        if rec["status"] == "skipped":
            rows.append((f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
                         "skipped:" + rec.get("reason", "")[:40]))
            continue
        if rec["status"] != "ok":
            rows.append((f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
                         "ERROR"))
            continue
        a = analyze(rec)
        rows.append((
            f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
            f"compute={a['t_compute_s']*1e3:.2f}ms "
            f"memory={a['t_memory_s']*1e3:.2f}ms"
            f"(hi {a['t_memory_hi_s']*1e3:.0f}) "
            f"collective={a['t_collective_s']*1e3:.2f}ms "
            f"dominant={a['dominant']} useful={a['useful_ratio']:.2f}"))
        table.append((rec, a))
    _write_markdown(table)
    emit(rows)
    return rows


def _write_markdown(table):
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | MODEL/HLO flops | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for rec, a in table:
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | "
            f"{a['t_compute_s']*1e3:.2f} | {a['t_memory_s']*1e3:.2f} | "
            f"{a['t_collective_s']*1e3:.2f} | **{a['dominant']}** | "
            f"{a['useful_ratio']:.2f} | {a['hint']} |")
    out = ARTIFACTS / "roofline.md"
    out.write_text("\n".join(lines) + "\n")


if __name__ == "__main__":
    run()
