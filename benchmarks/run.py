"""Benchmark harness — one module per paper table/figure (+ roofline).
Prints ``name,us_per_call,derived`` CSV. ``--slow`` runs the longer
convergence/ablation settings.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


MODULES = [
    "table1_bottleneck",    # paper Table I
    "table2_models",        # paper Table II (model sizes)
    "fig5_similarity",      # paper Fig. 5 + Fig. 7
    "fig8_speedup",         # paper Fig. 8
    "table3_breakdown",     # paper Table III
    "fig9_ablation",        # paper Fig. 9
    "table4_convergence",   # paper Table IV
    "fig10_sensitivity",    # paper Fig. 10
    "fig_hier_sensitivity",  # beyond-paper: bandwidth-hierarchy sweep
    "fig_overlap_sweep",    # beyond-paper: pipelined-overlap sweep
    "fig_objective_sweep",  # beyond-paper: traffic vs overlap objective
    "fig_plan_reuse",       # beyond-paper: plan-lifecycle reuse sweep
    "fig_condense_backend",  # beyond-paper: similarity-backend sweep
    "fig_calibration",      # beyond-paper: measured-vs-predicted fit
    "fig_autotune",         # beyond-paper: calibration-driven autotuning
    "fig_wire_dtype",       # beyond-paper: compressed-exchange wire sweep
    "fig_serve_throughput",  # beyond-paper: continuous batching + overlap
    "fig_dedup_universal",  # beyond-paper: universal dedup wire + replicas
    "roofline",             # deliverable (g)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slow", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings of module names")
    args = ap.parse_args()
    fast = not args.slow
    only = [s for s in (args.only or "").split(",") if s]
    failures = []
    for mod_name in MODULES:
        if only and not any(s in mod_name for s in only):
            continue
        print(f"# --- {mod_name} ---", flush=True)
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            mod.run(fast=fast)
        except Exception as e:  # keep the harness going
            traceback.print_exc()
            failures.append(mod_name)
            print(f"{mod_name}/FAILED,0.0,{type(e).__name__}")
    # consolidated timing artifact (written even on partial failure so
    # CI uploads whatever completed)
    from benchmarks.common import ARTIFACTS, EMITTED
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / "BENCH_step_time.json").write_text(json.dumps(
        {"schema_version": 1, "failures": failures, "rows": EMITTED},
        indent=1))
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
