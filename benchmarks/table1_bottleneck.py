"""Paper Table I — all-to-all data transfer size S and communication
ratio R for the three paper models.

S is OUR SYSTEM's real wire volume: the dispatch+combine buffer bytes of
``repro.core.moe_layer`` (capacity-bounded, (E−1)/E remote) summed over
layers; R comes from the Table-III-calibrated comm/comp model. The
``derived`` column compares against the paper's measured S.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.config import MoEConfig
from repro.configs import get_config
from repro.core import commsim
from repro.core.moe_layer import capacity_for

PAPER_S_GB = {  # paper Table I: (experts, batch) -> S in GB
    "moe-transformerxl": {(4, 8): 3.19, (4, 16): 6.15, (8, 8): 3.98},
    "moe-bert-large": {(4, 8): 6.73, (4, 16): 13.07, (8, 8): 7.92},
    "moe-gpt2": {(4, 8): 6.53, (4, 16): 12.13, (8, 8): 7.52},
}
LENGTHS = {"moe-transformerxl": 250, "moe-bert-large": 512,
           "moe-gpt2": 1024}


def our_a2a_bytes(cfg, batch, seq_len, num_gpus):
    """Bytes our expert-parallel layer moves per iteration (fwd+bwd):
    dispatch+combine buffers, remote fraction (E-1)/E, all MoE layers."""
    m = cfg.moe
    tokens_local = batch * seq_len // num_gpus
    C = capacity_for(m, tokens_local, m.num_experts)
    buf = m.num_experts * C * (cfg.d_model + 2) * 4     # payload rows, fp32
    remote = (m.num_experts - 1) / m.num_experts
    per_layer = 2 * buf * remote                        # dispatch+combine
    # backward mirrors both all-to-alls
    return 2 * per_layer * cfg.num_layers * num_gpus


def run(fast: bool = True):
    rows = []
    for model, cases in PAPER_S_GB.items():
        for (E, B), paper_s in cases.items():
            cfg = get_config(model, num_experts=E)
            s = our_a2a_bytes(cfg, B, LENGTHS[model], num_gpus=E) / 1e9
            setup = commsim.PaperSetup(cfg=cfg, batch=B)
            comp_ms, comm_ms = commsim.PAPER_VANILLA[model][E]
            cal = commsim.calibrate(setup, comp_ms, comm_ms)
            pred = commsim.predict(setup, cal, system="vanilla")
            ratio = pred["comm_ms"] / (pred["comm_ms"] + pred["comp_ms"])
            rows.append((
                f"table1/{model}/E{E}B{B}", 0.0,
                f"S_ours={s:.2f}GB S_paper={paper_s:.2f}GB "
                f"R_model={100*ratio:.1f}%"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
