"""Paper Table II — model specifications. Validates OUR model
definitions: the analytic parameter count of each (model × #experts)
must land on the paper's reported size (0.18B…3.36B)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config

PAPER_SIZES_B = {   # paper Table II "Size" column
    "moe-transformerxl": {2: 0.44, 4: 0.74, 8: 1.34, 16: 2.55},
    "moe-bert-large": {2: 0.54, 4: 0.94, 8: 1.74, 16: 3.36},
    "moe-gpt2": {2: 0.18, 4: 0.29, 8: 0.52, 16: 0.97},
}


def run(fast: bool = True):
    rows = []
    errs = []
    for model, sizes in PAPER_SIZES_B.items():
        for E, paper_b in sizes.items():
            cfg = get_config(model, num_experts=E)
            ours = cfg.param_count() / 1e9
            err = abs(ours - paper_b) / paper_b
            errs.append(err)
            rows.append((f"table2/{model}/E{E}", 0.0,
                         f"params_ours={ours:.2f}B paper={paper_b:.2f}B "
                         f"rel_err={100*err:.0f}%"))
    rows.append(("table2/mean_rel_err", 0.0,
                 f"{100*sum(errs)/len(errs):.1f}%"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
