"""Paper Table III — computation/communication breakdown for all four
systems, predicted by the calibrated model vs the paper's measurements.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import commsim


def run(fast: bool = True):
    rows = []
    for model in commsim.PAPER_VANILLA:
        rates = commsim.PAPER_RATES[model]
        for E in (2, 4, 8, 16):
            cfg = get_config(model, num_experts=E)
            setup = commsim.PaperSetup(cfg=cfg)
            vc, vm = commsim.PAPER_VANILLA[model][E]
            cal = commsim.calibrate(setup, vc, vm)
            for system in ("vanilla", "luffy", "ext", "hyt"):
                p = commsim.predict(setup, cal, system=system, **(
                    rates if system == "luffy" else {}))
                if system == "vanilla":
                    pc, pm = vc, vm
                else:
                    pc, pm = commsim.PAPER_TABLE3[model][system][E]
                rows.append((
                    f"table3/{model}/E{E}/{system}", 0.0,
                    f"comp={p['comp_ms']:.0f}ms(paper {pc}) "
                    f"comm={p['comm_ms']:.0f}ms(paper {pm})"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
