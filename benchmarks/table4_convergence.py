"""Paper Table IV — impact of token condensation on model quality.

REAL training runs on this system (reduced MoE-TransformerXL, synthetic
LM stream): Vanilla vs static thresholds h=0.3 / h=0.8 vs the adaptive
policy (Eq. 2). Reports final eval perplexity — the paper's finding is
the ORDER: h=0.3 hurts quality, h=0.8 nearly clean, adaptive ≈ vanilla
while condensing aggressively late in training.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, timeit, tiny_paper_model


def _train(variant: str, steps: int):
    import jax
    import jax.numpy as jnp
    from repro import optim, train_lib
    from repro.config import LuffyConfig, OptimConfig, ShapeConfig
    from repro.core.moe_layer import capacity_for
    from repro.data import SyntheticLM
    from repro.dist import single_device
    from repro.models.model import build_model

    cfg = tiny_paper_model("moe-transformerxl", num_experts=4,
                           d_model=128, num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("b", 128, 8, "train")
    data = SyntheticLM(cfg, shape)
    if variant == "vanilla":
        luffy = LuffyConfig(enable_condensation=False,
                            enable_migration=False)
    elif variant.startswith("h="):
        luffy = LuffyConfig(adaptive_threshold=False,
                            static_threshold=float(variant[2:]),
                            enable_migration=False, condense_group=64)
    else:
        luffy = LuffyConfig(enable_migration=False, condense_group=64)
    ocfg = OptimConfig(total_steps=steps, warmup_steps=5, lr=1e-3)
    cap = capacity_for(cfg.moe, 8 * 128, cfg.moe.num_experts)
    dist = single_device()
    step = jax.jit(train_lib.make_train_step(cfg, luffy, ocfg, dist, cap))
    ost = optim.init_opt_state(params, ocfg)
    lst = train_lib.init_luffy_state()
    rates, t0 = [], time.perf_counter()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, ost, lst, m = step(params, ost, lst, b)
        rates.append(float(m["condense_rate"]))
    train_t = time.perf_counter() - t0
    # eval: LUFFY off, held-out batches
    ev = jax.jit(train_lib.make_eval_step(
        cfg, dataclasses.replace(luffy, enable_condensation=False),
        dist, cap))
    losses = [float(ev(params, {k: jnp.asarray(v) for k, v in
                                data.batch(10_000 + i).items()})["loss"])
              for i in range(4)]
    return float(np.mean(losses)), float(np.mean(rates)), train_t


def run(fast: bool = True):
    steps = 25 if fast else 120
    rows = []
    results = {}
    for variant in ("vanilla", "h=0.3", "h=0.8", "adaptive"):
        loss, rate, t = _train(variant, steps)
        ppl = float(np.exp(min(loss, 20)))
        results[variant] = loss
        rows.append((f"table4/{variant}", t * 1e6 / steps,
                     f"eval_loss={loss:.3f} ppl={ppl:.1f} "
                     f"mean_condense_rate={rate:.2f}"))
    # the paper's qualitative claim: aggressive static threshold worst
    ok = results["h=0.3"] >= results["adaptive"] - 0.05
    rows.append(("table4/order_check", 0.0,
                 f"h0.3_worst_or_equal={ok}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
