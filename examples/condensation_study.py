"""Condensation anatomy: watch the adaptive threshold (paper Eq. 2), the
measured-pair fraction saved by the fast-similarity rules (§V-A), and the
capacity bucket the host loop would pick.

    PYTHONPATH=src python examples/condensation_study.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim, train_lib
from repro.config import LuffyConfig, OptimConfig, ShapeConfig, reduced
from repro.configs import get_config
from repro.core.condensation import adaptive_threshold
from repro.core.moe_layer import capacity_for
from repro.data import SyntheticLM
from repro.dist import single_device
from repro.models.model import build_model

cfg = reduced(get_config("moe-transformerxl", num_experts=4),
              num_layers=2, d_model=128)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
luffy = LuffyConfig(condense_group=64)
shape = ShapeConfig("study", 128, 8, "train")
data = SyntheticLM(cfg, shape)
ocfg = OptimConfig(total_steps=40, warmup_steps=2, lr=1e-3)
cap0 = capacity_for(cfg.moe, 8 * 128, cfg.moe.num_experts)
step = jax.jit(train_lib.make_train_step(cfg, luffy, ocfg,
                                         single_device(), cap0))
ost = optim.init_opt_state(params, ocfg)
lst = train_lib.init_luffy_state()
print("step  loss    thresh  rate   bucket  capacity")
rate_ema = 0.0
for i in range(25):
    b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
    params, ost, lst, m = step(params, ost, lst, b)
    th = (float(adaptive_threshold(lst.l_ini, lst.l_prev))
          if float(lst.l_ini) > 0 else 1.0)
    rate_ema = 0.8 * rate_ema + 0.2 * float(m["condense_rate"])
    bucket = train_lib.pick_bucket_host(luffy, th, rate_ema)
    cap_b = capacity_for(cfg.moe, 8 * 128, cfg.moe.num_experts,
                         rate=luffy.rate_buckets[bucket])
    print(f"{i:4d}  {float(m['loss']):.3f}  {th:.3f}  "
          f"{float(m['condense_rate']):.2f}   {bucket}      {cap_b}"
          f"  (vs {cap0} at bucket 0)")
print("\nthe bucket shrinks the dispatch/combine all-to-all operands by "
      "ceil(C*(1-rate)) — the TPU-static form of the paper's saving.")
