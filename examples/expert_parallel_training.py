"""End-to-end driver example: expert-parallel MoE training on an 8-way
host mesh (2 data x 4 model), with both LUFFY techniques and the
rate-bucket recompile loop — a scaled-down copy of the production path.

    python examples/expert_parallel_training.py [--steps 100]

(Spawns itself with XLA_FLAGS for 8 host devices.)
"""
import os
import subprocess
import sys

if os.environ.get("_EP_CHILD") != "1":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["_EP_CHILD"] = "1"
    env.setdefault("PYTHONPATH", "src")
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "moe-transformerxl", "--reduced", "--experts", "8",
         "--d-model", "256", "--layers", "2", "--global-batch", "16",
         "--seq-len", "256", "--mesh", "host", "--model-axis", "4",
         "--steps", (sys.argv[sys.argv.index("--steps") + 1]
                     if "--steps" in sys.argv else "60")],
        env=env))
