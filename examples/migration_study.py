"""Sequence-migration anatomy (paper §IV / Algorithm 1): take a real
routing snapshot from a tiny trained MoE, run the migration planner, and
show the traffic/attention-cost tradeoff across candidate sizes q.

    PYTHONPATH=src python examples/migration_study.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim, train_lib
from repro.config import LuffyConfig, OptimConfig, ShapeConfig, reduced
from repro.configs import get_config
from repro.core import migration as mig
from repro.core.gating import gate_apply
from repro.core.moe_layer import capacity_for, _rms
from repro.data import SyntheticLM
from repro.dist import single_device
from repro.models.model import build_model
from repro.models.transformer import embed_tokens

M, n_per = 8, 2               # 8 virtual devices, 2 sequence slots each
cfg = reduced(get_config("moe-transformerxl", num_experts=8),
              num_layers=2, d_model=128, max_experts=8)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
shape = ShapeConfig("mig", 256, M * n_per, "train")
data = SyntheticLM(cfg, shape)

# brief training so routing develops the paper's bias (Fig. 3)
luffy = LuffyConfig(enable_condensation=False, enable_migration=False)
ocfg = OptimConfig(total_steps=12, warmup_steps=2, lr=1e-3)
cap = capacity_for(cfg.moe, shape.global_batch * 256, cfg.moe.num_experts)
step = jax.jit(train_lib.make_train_step(cfg, luffy, ocfg,
                                         single_device(), cap))
ost = optim.init_opt_state(params, ocfg)
lst = train_lib.init_luffy_state()
for i in range(10):
    b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
    params, ost, lst, _ = step(params, ost, lst, b)

# routing snapshot at layer 0
b = data.batch(99)
x = embed_tokens(params, cfg, jnp.asarray(b["tokens"]))
p0 = jax.tree.map(lambda a: a[0], params["layers"][0])
xn = _rms(x.reshape(-1, cfg.d_model), p0["moe"]["norm"]["scale"])
gate = gate_apply(p0["moe"]["router"], xn, cfg.moe.top_k)
E_local = cfg.moe.num_experts // M
dev = np.asarray(gate.expert_idx) // E_local          # [T, k]
S = x.shape[1]
counts = np.zeros((M * n_per, M))
for s in range(M * n_per):
    for kk in range(cfg.moe.top_k):
        np.add.at(counts[s], dev[s * S:(s + 1) * S, kk], 1)
lens = np.asarray(b["seq_len"])

print("per-slot expert-device concentration (paper Fig. 3 analogue):")
top = counts.max(1) / counts.sum(1)
print("  mean top-device share:", f"{float(top.mean()):.2f}",
      "(uniform would be", f"{1/M:.2f})")
print(f"\n{'q':>3} {'traffic_before':>15} {'traffic_after':>14} "
      f"{'saved%':>7} {'t_att_ms':>9}")
for q in (1, 2, 3, 4):
    plan = mig.plan_migration_np(counts, lens, n_per, q=q,
                                 d_model=cfg.d_model, speed=1e12)
    a = np.asarray(plan.assign)
    att = sum(float(mig.t_att(int((a == d).sum()),
                              int(lens[a == d].max()), cfg.d_model, 1e12))
              for d in range(M) if (a == d).any())
    tb, ta = float(plan.traffic_before), float(plan.traffic_after)
    print(f"{q:>3} {tb:>15.0f} {ta:>14.0f} {100*(1-ta/tb):>6.1f}% "
          f"{att*1e3:>9.2f}")
print("\nq=1 minimizes token pulling; larger q trades a little traffic "
      "for attention-balance (Eq. 1) — the paper's Fig. 10a tradeoff.")

# The snapshot above often shows 0% saving: with *globally* hot experts
# every sequence prefers the SAME device, per-device capacity forces
# contention, and the identity-fallback guard (a beyond-paper safety; see
# DESIGN.md) rejects the plan. The paper's win needs *per-sequence*
# diversity (its Fig. 3) — demonstrate with a diverse-bias instance:
print("\nper-sequence-diverse bias (paper Fig. 3 regime):")
r = np.random.default_rng(0)
counts2 = np.full((M * n_per, M), 4.0)
for s in range(M * n_per):
    counts2[s, r.integers(0, M)] += 120        # each seq has its own home
lens2 = r.choice([64, 256], M * n_per)
print(f"{'q':>3} {'traffic_before':>15} {'traffic_after':>14} "
      f"{'saved%':>7} {'t_att_ms':>9}")
for q in (1, 2, 3, 4):
    plan = mig.plan_migration_np(counts2, lens2, n_per, q=q,
                                 d_model=cfg.d_model, speed=1e12)
    a = np.asarray(plan.assign)
    att = sum(float(mig.t_att(int((a == d).sum()),
                              int(lens2[a == d].max()), cfg.d_model, 1e12))
              for d in range(M) if (a == d).any())
    tb, ta = float(plan.traffic_before), float(plan.traffic_after)
    print(f"{q:>3} {tb:>15.0f} {ta:>14.0f} {100*(1-ta/tb):>6.1f}% "
          f"{att*1e3:>9.2f}")
