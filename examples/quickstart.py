"""Quickstart: train a tiny MoE with LUFFY (sequence migration + token
condensation) on CPU, single device — the 60-second tour of the API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import optim, train_lib
from repro.config import LuffyConfig, OptimConfig, ShapeConfig, reduced
from repro.configs import get_config
from repro.core.moe_layer import capacity_for
from repro.data import SyntheticLM
from repro.dist import single_device
from repro.models.model import build_model

# 1. pick an architecture from the registry and shrink it for CPU
cfg = reduced(get_config("olmoe-1b-7b"))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
print(f"{cfg.name}: {sum(x.size for x in jax.tree.leaves(params)):,} params")

# 2. LUFFY config: the paper's two techniques + the adaptive threshold
luffy = LuffyConfig(enable_condensation=True, enable_migration=True,
                    condense_group=64)

# 3. data + train step
shape = ShapeConfig("quickstart", seq_len=128, global_batch=8, mode="train")
data = SyntheticLM(cfg, shape)
ocfg = OptimConfig(total_steps=20, warmup_steps=2, lr=1e-3)
cap = capacity_for(cfg.moe, 8 * 128, cfg.moe.num_experts)
step = jax.jit(train_lib.make_train_step(cfg, luffy, ocfg,
                                         single_device(), cap))
opt_state = optim.init_opt_state(params, ocfg)
lstate = train_lib.init_luffy_state()

for i in range(10):
    batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
    params, opt_state, lstate, m = step(params, opt_state, lstate, batch)
    print(f"step {i}: loss={float(m['loss']):.4f} "
          f"condense_rate={float(m['condense_rate']):.2f} "
          f"aux={float(m['aux_loss']):.3f}")
print("done — loss should be falling and the condensation rate rising as "
      "the adaptive threshold (Eq. 2) relaxes.")
