"""Batched serving example: prefill + greedy decode on a small model.

    PYTHONPATH=src python examples/serve_batched.py
"""
import subprocess
import sys
import os

env = dict(os.environ)
env.setdefault("PYTHONPATH", "src")
raise SystemExit(subprocess.call(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "olmoe-1b-7b",
     "--reduced", "--batch", "4", "--prompt-len", "16", "--gen", "16"],
    env=env))
