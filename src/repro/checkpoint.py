"""Sharded pytree checkpointing: tensors to .npz shards + a JSON spec.

Writes one .npz per (up to ``shard_mb``) of leaves plus ``spec.json``
recording tree structure, dtypes, shapes and the PartitionSpec each leaf
had, so restore can re-place leaves on a (possibly different) mesh.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save(path: str, tree, *, pspecs=None, step: int = 0,
         shard_mb: int = 512) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, names, _ = _flatten(tree)
    spec: Dict[str, Any] = {"step": step, "leaves": []}
    shard, shard_bytes, shard_id = {}, 0, 0
    limit = shard_mb * (1 << 20)
    pleaves = (jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: x is None or hasattr(x, "index"))
        if pspecs is not None else [None] * len(leaves))

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if shard:
            np.savez(os.path.join(path, f"shard_{shard_id}.npz"), **shard)
            shard, shard_bytes, shard_id = {}, 0, shard_id + 1

    for i, (leaf, name) in enumerate(zip(leaves, names)):
        arr = np.asarray(jax.device_get(leaf))
        key = f"t{i}"
        spec["leaves"].append({
            "name": name, "key": key, "shard": shard_id,
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "pspec": (str(pleaves[i]) if i < len(pleaves)
                      and pleaves[i] is not None else None)})
        shard[key] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= limit:
            flush()
    flush()
    with open(os.path.join(path, "spec.json"), "w") as f:
        json.dump(spec, f, indent=1)


def restore(path: str, like, *, shardings=None):
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs). ``shardings``: matching pytree of NamedSharding."""
    with open(os.path.join(path, "spec.json")) as f:
        spec = json.load(f)
    shards: Dict[int, Any] = {}
    leaves, names, treedef = _flatten(like)
    sleaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None or hasattr(x, "mesh"))
        if shardings is not None else [None] * len(leaves))
    by_name = {e["name"]: e for e in spec["leaves"]}
    out = []
    for i, (leaf, name) in enumerate(zip(leaves, names)):
        e = by_name[name]
        sid = e["shard"]
        if sid not in shards:
            shards[sid] = np.load(os.path.join(path, f"shard_{sid}.npz"))
        arr = shards[sid][e["key"]]
        want = jnp.dtype(leaf.dtype)
        a = jnp.asarray(arr, want)
        if sleaves[i] is not None:
            a = jax.device_put(a, sleaves[i])
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out), spec["step"]
