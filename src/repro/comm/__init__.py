"""Topology-aware communication subsystem (DESIGN.md §5).

Single source of truth for *where bytes go and what they cost*:

* :mod:`repro.comm.topology` — the :class:`Topology` descriptor (nodes ×
  devices-per-node, per-link bandwidth/latency) every other layer prices
  links against;
* :mod:`repro.comm.hierarchical` — two-phase ``hier_all_to_all`` /
  ``hier_combine`` collectives and the :class:`CommContext` the MoE
  layer runs its dispatch/combine through;
* :mod:`repro.comm.ledger` — traced + analytic traffic accounting
  (flat vs per-node-deduplicated inter-node bytes);
* :mod:`repro.comm.compat` — jax version shims (shard_map / make_mesh /
  axis arithmetic) so the rest of the codebase never version-checks.
"""
from repro.comm.compat import (axis_index, axis_size, make_mesh, pmean_all,
                               pvary_all, shard_map)
from repro.comm.hierarchical import (CommContext, hier_all_to_all,
                                     hier_combine)
from repro.comm.ledger import (a2a_time_s, dispatch_bytes,
                               dispatch_node_ledger, expected_dedup_factor,
                               simulate_dispatch_rows)
from repro.comm.topology import Topology, model_axes_of

__all__ = [
    "CommContext", "Topology", "a2a_time_s", "axis_index", "axis_size",
    "dispatch_bytes", "dispatch_node_ledger", "expected_dedup_factor",
    "hier_all_to_all", "hier_combine", "make_mesh", "model_axes_of",
    "pmean_all", "pvary_all", "shard_map", "simulate_dispatch_rows",
]
