"""jax version shims for the comm subsystem.

The repo targets the modern jax API (``jax.shard_map``, ``jax.make_mesh``
with ``axis_types``, ``jax.lax.axis_size``) but must also run on the
0.4.x line shipped in some containers, where those spellings live under
``jax.experimental`` or do not exist. Everything mesh/collective-shaped
goes through this module so the rest of the codebase never version-checks.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

AxisName = Union[str, Tuple[str, ...]]


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` when available, else the experimental spelling.

    The old implementation's replication checker predates the vma type
    system and rejects valid programs our MoE layer emits (aux scalars
    pmean'd over all axes), so it is disabled there; new jax applies its
    own (sound) check.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def axis_size(axis_name: Optional[AxisName]) -> int:
    """Static size of a (possibly tuple) named axis inside shard_map."""
    if axis_name is None:
        return 1
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    # psum of a static python scalar folds to the axis size at trace time
    return jax.lax.psum(1, axis_name)


def axis_index(axis_name: AxisName):
    """Combined (major-to-minor) index along one or several named axes."""
    names = axis_name if isinstance(axis_name, (tuple, list)) else (axis_name,)
    idx = jnp.int32(0)
    for a in names:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx


@jax.custom_vjp
def optimization_barrier(xs):
    """Differentiable ``jax.lax.optimization_barrier`` over a pytree.

    0.4.x has no differentiation rule for the primitive, so the barrier
    is wrapped in a custom_vjp with identity cotangents — sound because
    the barrier only constrains *scheduling*, never values. Used by the
    ``repro.sched`` pipeline to pin collective issue order inside the
    differentiated train step.
    """
    return jax.lax.optimization_barrier(xs)


def _ob_fwd(xs):
    return optimization_barrier(xs), None


def _ob_bwd(_, ct):
    return (ct,)


optimization_barrier.defvjp(_ob_fwd, _ob_bwd)


def pvary_all(v, axes: Tuple[str, ...]):
    """Mark ``v`` varying over ``axes`` (new-jax vma types) — the value
    is unchanged. Used when a replicated-within-a-row value (e.g. the
    all-gathered plan-reuse signature) is returned through out_specs
    that treat it as per-device varying; old jax needs nothing."""
    typeof = getattr(jax, "typeof", None)
    if typeof is not None and hasattr(jax.lax, "pcast"):
        vma = getattr(typeof(v), "vma", frozenset())
        missing = tuple(a for a in axes if a not in vma)
        if missing:
            v = jax.lax.pcast(v, missing, to="varying")
    return v


def pmean_all(v, axes: Tuple[str, ...]):
    """pmean over all mesh axes regardless of the value's varying state.

    New jax tracks varying-manual-axes (vma) types: a value replicated
    over some axes must be pcast to varying before a pmean that names
    them. Old jax has no vma concept and the plain pmean is correct.
    """
    typeof = getattr(jax, "typeof", None)
    if typeof is not None and hasattr(jax.lax, "pcast"):
        vma = getattr(typeof(v), "vma", frozenset())
        missing = tuple(a for a in axes if a not in vma)
        if missing:
            v = jax.lax.pcast(v, missing, to="varying")
    return jax.lax.pmean(v, axes)
