"""Wire dtypes: the shared dtype-size table and the low-precision wire
codec (DESIGN.md §14).

Two consumers share this module:

* HLO byte accounting (:mod:`repro.launch.hlo_analysis`,
  :mod:`repro.launch.dryrun`) — ``DTYPE_BYTES`` maps HLO dtype names to
  their itemsize; previously each kept a private copy and they drifted
  (the f8 entries existed in one but not the other's history).
* The compressed exchange — ``LuffyConfig.wire_dtype`` selects the
  precision activation rows ship at when they cross a node boundary.
  Plan-time pricing (:func:`repro.plan.estimate.estimate_exchange`),
  executed accounting (``MoEAux.inter_bytes_shipped``) and the actual
  quantize/ship/dequantize all derive from the *same* three functions
  here (:func:`wire_itemsize` / :func:`wire_row_bytes` /
  :func:`wire_precision`), so the ledger contract
  ``bytes == flat / (dedup × precision)`` holds exactly by
  construction.

Wire formats (compute always stays at the model's compute dtype;
quantize happens immediately before the node-crossing collective,
dequantize immediately after):

``"f32"``
    Identity wire: rows ship at the compute dtype, byte-for-byte the
    historical behaviour.  Every pre-existing bitwise test pins this.
``"bf16"``
    Pure cast.  A cast commutes with permutation collectives, so the
    executed path is bit-identical to quantize-then-exchange.
``"f8e4m3"``
    float8_e4m3fn payload with one f32 scale per ``SCALE_BLOCK``
    contiguous elements shipped in a sideband array through the same
    collective.  ``scale = blockmax / F8_MAX`` (1.0 for all-zero
    blocks) keeps every quantized element inside the e4m3 range.
    Gated on :func:`have_f8` — never adds a dependency.

Integer route maps and per-sequence metadata never quantize: the dedup
wire's slot map carries indices whose exact reconstruction the
round-trip tests pin, and metadata bytes are negligible next to the
``d_model``-wide activation payload (selective precision, in
MegaScale-MoE's terms).
"""
from __future__ import annotations

import math

# HLO dtype-name → itemsize, used by the HLO collective parsers.  One
# table so fp8 payloads appearing in traced collectives are counted by
# every consumer (satellite of ISSUE 9: hlo_analysis and dryrun kept
# separate copies).
DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

WIRE_DTYPES = ("f32", "bf16", "f8e4m3")

# fp8 block-scale parameters.  32 elements per scale amortizes the f32
# sideband to one byte per 8 payload bytes; 448 is the largest finite
# e4m3fn value, so x/scale lands inside the representable range with
# the block max mapping exactly onto it.
SCALE_BLOCK = 32
F8_MAX = 448.0


def have_f8() -> bool:
    """True when the installed jax/ml_dtypes expose float8_e4m3fn."""
    try:
        import jax.numpy as jnp
        return hasattr(jnp, "float8_e4m3fn")
    except Exception:        # pragma: no cover - jax always importable here
        return False


def validate_wire_dtype(wire_dtype: str) -> str:
    """Reject unknown wire dtypes (and f8 on stacks without fp8 support)
    at plan-build time, before anything is traced."""
    if wire_dtype not in WIRE_DTYPES:
        raise ValueError(
            f"wire_dtype must be one of {WIRE_DTYPES}, got {wire_dtype!r}")
    if wire_dtype == "f8e4m3" and not have_f8():
        raise ValueError(
            "wire_dtype='f8e4m3' requires jax.numpy.float8_e4m3fn, which "
            "this jax/ml_dtypes stack does not expose")
    return wire_dtype


def wire_itemsize(wire_dtype: str, compute_itemsize: int) -> int:
    """Bytes per payload element on the wire.  ``f32`` is the identity
    wire (ship at the compute dtype); a wider wire than compute is never
    used (bf16 wire on a bf16 model ships 2, not 4)."""
    if wire_dtype == "f32":
        return compute_itemsize
    if wire_dtype == "bf16":
        return min(2, compute_itemsize)
    if wire_dtype == "f8e4m3":
        return 1
    raise ValueError(f"unknown wire_dtype {wire_dtype!r}")


def scale_bytes(d_model: int, wire_dtype: str) -> int:
    """Bytes of f32 block-scale sideband per shipped row (f8 only)."""
    if wire_dtype != "f8e4m3":
        return 0
    return 4 * math.ceil(d_model / SCALE_BLOCK)


def wire_row_bytes(d_model: int, wire_dtype: str,
                   compute_itemsize: int) -> float:
    """Bytes one activation row occupies on the node-crossing wire: the
    ``d_model`` payload at the wire itemsize, the f8 scale sideband, and
    the 2 side columns (gate weight + slot map share, DESIGN.md §10)
    which stay at the compute dtype."""
    return (d_model * wire_itemsize(wire_dtype, compute_itemsize)
            + scale_bytes(d_model, wire_dtype)
            + 2 * compute_itemsize)


def wire_precision(d_model: int, wire_dtype: str,
                   compute_itemsize: int) -> float:
    """Compression factor of the wire: full-precision row bytes over
    wire row bytes (>= 1.0; exactly 1.0 on the identity wire).  The
    single definition the modeled estimate, the executed ledger, and
    the benchmarks all divide by."""
    full = (d_model + 2) * compute_itemsize
    return full / wire_row_bytes(d_model, wire_dtype, compute_itemsize)


# ---------------------------------------------------------------------------
# Codec.  jnp is imported lazily so DTYPE_BYTES stays importable from
# byte-accounting code without touching jax.

def _f8_dtype():
    import jax.numpy as jnp
    return jnp.float8_e4m3fn


def pad_to_block(d_model: int) -> int:
    """Payload width after padding to a whole number of scale blocks."""
    return SCALE_BLOCK * math.ceil(d_model / SCALE_BLOCK)


def quantize_rows(x, wire_dtype: str):
    """Quantize ``[..., d]`` activation rows for the wire.

    Returns ``(q, scales)``:

    * ``f32``    → ``(x, None)`` — identity, same array object.
    * ``bf16``   → ``(x.astype(bf16), None)``.
    * ``f8e4m3`` → ``q: [..., d_pad] f8e4m3fn`` (zero-padded to a whole
      number of ``SCALE_BLOCK`` blocks) and ``scales: [..., d_pad/32]``
      f32, ``scale = max|block| / F8_MAX`` with all-zero blocks pinned
      to 1.0 so dequantize is exact on them.

    The formula (f32 accumulate → abs-max per block → guarded divide)
    is mirrored bit-for-bit by the fused pack kernel in
    :mod:`repro.kernels.pack`; keep the two in sync.
    """
    import jax.numpy as jnp
    if wire_dtype == "f32":
        return x, None
    if wire_dtype == "bf16":
        return x.astype(jnp.bfloat16), None
    if wire_dtype != "f8e4m3":
        raise ValueError(f"unknown wire_dtype {wire_dtype!r}")
    d = x.shape[-1]
    d_pad = pad_to_block(d)
    xf = x.astype(jnp.float32)
    if d_pad != d:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, d_pad - d)]
        xf = jnp.pad(xf, pad)
    blocks = xf.reshape(*xf.shape[:-1], d_pad // SCALE_BLOCK, SCALE_BLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=-1)
    # multiply by the reciprocal, NOT divide: XLA rewrites x/const to
    # x*(1/const) under jit but not eagerly, and the fused pack kernel
    # must reproduce these scales bit-for-bit in either mode
    scales = jnp.where(amax > 0, amax * (1.0 / F8_MAX), 1.0) \
        .astype(jnp.float32)
    q = (blocks / scales[..., None]).reshape(*xf.shape[:-1], d_pad)
    return q.astype(_f8_dtype()), scales


def dequantize_rows(q, scales, out_dtype, d_model: int):
    """Inverse of :func:`quantize_rows`: reconstruct ``[..., d_model]``
    rows at ``out_dtype``.  ``scales is None`` means a cast wire."""
    import jax.numpy as jnp
    if scales is None:
        return q.astype(out_dtype)
    d_pad = q.shape[-1]
    blocks = q.astype(jnp.float32).reshape(
        *q.shape[:-1], d_pad // SCALE_BLOCK, SCALE_BLOCK)
    x = (blocks * scales[..., None]).reshape(*q.shape[:-1], d_pad)
    return x[..., :d_model].astype(out_dtype)
