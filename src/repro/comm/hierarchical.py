"""Hierarchical two-phase collectives (DESIGN.md §5).

``hier_all_to_all`` decomposes a flat all-to-all over the combined
``(node, local)`` axis into an intra-node exchange (cheap links) followed
by an inter-node exchange (expensive links). For chunks laid out
node-major on dim 0 the result is **bit-identical** to
``jax.lax.all_to_all(x, ("node", "local"), 0, 0, tiled=True)`` — the
two-phase path is a drop-in relabeling, so the MoE layer's outputs do not
change when ``comm_mode`` flips.

What does change is the wire profile: every inter-node message now
aggregates the contributions of all ``L`` devices of the source node
(one large message per node pair per phase instead of ``L²`` small
ones). The per-node *payload dedup* (HierMoE-style: a token's payload
crossing once per node, not once per top-k copy) is a separate wire
format: :mod:`repro.condense.wire` ships it behind
``LuffyConfig.hier_dedup`` using the phase collectives below
(``node_all_to_all`` / ``local_all_gather`` / ``local_psum_scatter``);
:mod:`repro.comm.ledger` prices it, and with the dedup wire enabled the
modeled ``inter_bytes_dedup`` equals the bytes actually shipped.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.comm import compat
from repro.comm.topology import Topology

AxisName = Union[str, Tuple[str, ...]]


def hier_all_to_all(x, node_axis: str, local_axis: str):
    """Two-phase all-to-all; dim 0 holds one chunk per global device,
    node-major (chunk ``n*L + l`` is headed to device ``(n, l)``).

    Phase 1 (intra-node): exchange over ``local_axis`` keyed on the
    destination-local rank — afterwards device ``(n, l)`` holds, for each
    destination node, everything its node peers want to send to local
    rank ``l`` there. Phase 2 (inter-node): exchange over ``node_axis``
    keyed on the destination node — same-column devices talk, one
    aggregated message per node pair.
    """
    N = compat.axis_size(node_axis)
    L = compat.axis_size(local_axis)
    M = N * L
    assert x.shape[0] % M == 0, (x.shape, N, L)
    chunk = x.shape[0] // M
    b = x.reshape((N, L, chunk) + x.shape[1:])
    # phase 1: dim 1 (dest local rank) -> becomes source local rank
    b = jax.lax.all_to_all(b, local_axis, split_axis=1, concat_axis=1,
                           tiled=True)
    # phase 2: dim 0 (dest node) -> becomes source node
    b = jax.lax.all_to_all(b, node_axis, split_axis=0, concat_axis=0,
                           tiled=True)
    return b.reshape(x.shape)


def hier_combine(x, node_axis: str, local_axis: str):
    """Combine-direction two-phase exchange: aggregate within the node
    first (cheap links), then cross nodes once. As a slot permutation it
    is identical to :func:`hier_all_to_all` (the flat all-to-all is an
    involution, and both phase orders compose to the same global
    transpose), so it is also bit-compatible with the flat path."""
    return hier_all_to_all(x, node_axis, local_axis)


class CommContext(NamedTuple):
    """How the MoE layer should run its expert-parallel collectives.

    ``axes`` are the mesh axes spanning the expert-parallel dimension,
    node-major (("model",) flat, ("node", "local") hierarchical). A
    ``"local"`` context (no axes) is the single-device degenerate case:
    size 1, identity collectives — so executors can hold ONE non-optional
    comm handle instead of special-casing ``comm is None``.
    ``topology`` prices the links; None means uniform/unknown.
    """
    mode: str                           # "flat" | "hier" | "local"
    axes: Tuple[str, ...] = ()
    topology: Optional[Topology] = None

    @classmethod
    def build(cls, mode: str, model_axis: Optional[AxisName],
              topology: Optional[Topology] = None) -> Optional["CommContext"]:
        if model_axis is None:
            return None
        axes = (model_axis,) if isinstance(model_axis, str) \
            else tuple(model_axis)
        if mode == "hier" and len(axes) != 2:
            raise ValueError(
                f"comm_mode='hier' needs a (node, local) model axis pair, "
                f"got {axes}; build the mesh with nodes > 1")
        if mode not in ("flat", "hier"):
            raise ValueError(f"unknown comm_mode {mode!r}")
        return cls(mode, axes, topology)

    @classmethod
    def local(cls, topology: Optional[Topology] = None) -> "CommContext":
        """Single-device context: identity collectives, size 1."""
        return cls("local", (), topology)

    @classmethod
    def ensure(cls, comm: Optional["CommContext"],
               axis_name: Optional[AxisName] = None,
               topology: Optional[Topology] = None) -> "CommContext":
        """Normalize the historical ``(comm, axis_name)`` call boundary to
        one non-optional context: an existing context wins, a bare axis
        name becomes a flat context over it, neither becomes local."""
        if comm is not None:
            return comm
        if axis_name is not None:
            return cls.build("flat", axis_name, topology)
        return cls.local(topology)

    # -- axis arithmetic (shard_map-side) ------------------------------------
    @property
    def axis_name(self) -> Optional[AxisName]:
        if not self.axes:
            return None
        return self.axes[0] if len(self.axes) == 1 else self.axes

    def size(self) -> int:
        if self.mode == "local":
            return 1
        return compat.axis_size(self.axes)

    def index(self):
        if self.mode == "local":
            return 0
        return compat.axis_index(self.axes)

    @property
    def node_axis(self) -> str:
        assert len(self.axes) == 2, self.axes
        return self.axes[0]

    @property
    def local_axis(self) -> str:
        assert len(self.axes) == 2, self.axes
        return self.axes[1]

    # -- collectives ---------------------------------------------------------
    def all_to_all(self, x):
        """Dispatch-layout exchange: dim 0 = one chunk per device."""
        if self.mode == "local":
            return x
        if self.mode == "hier":
            return hier_all_to_all(x, self.node_axis, self.local_axis)
        return jax.lax.all_to_all(x, self.axis_name, split_axis=0,
                                  concat_axis=0, tiled=True)

    def combine(self, x):
        """Combine-layout exchange (same chunk convention)."""
        if self.mode == "local":
            return x
        if self.mode == "hier":
            return hier_combine(x, self.node_axis, self.local_axis)
        return jax.lax.all_to_all(x, self.axis_name, split_axis=0,
                                  concat_axis=0, tiled=True)

    # -- single-phase collectives (the dedup wire, repro.condense.wire) ------
    def node_all_to_all(self, x):
        """Inter-node exchange only: dim 0 = one chunk per NODE."""
        assert self.mode == "hier", self.mode
        return jax.lax.all_to_all(x, self.node_axis, split_axis=0,
                                  concat_axis=0, tiled=True)

    def local_all_gather(self, x):
        """Cheap-link fan-out: gather dim 0 across the node's devices."""
        assert self.mode == "hier", self.mode
        return jax.lax.all_gather(x, self.local_axis, axis=0, tiled=True)

    def local_psum_scatter(self, x):
        """Cheap-link reduction: sum across the node's devices, each
        keeping its dim-0 slice (dim 0 must be ``L`` chunks)."""
        assert self.mode == "hier", self.mode
        return jax.lax.psum_scatter(x, self.local_axis,
                                    scatter_dimension=0, tiled=True)

    def link_cost(self) -> Optional[jnp.ndarray]:
        """[M, M] f32 link-cost matrix for the migration planner, or
        None for uniform topologies (planners then use 1 - I)."""
        if self.topology is None or not self.topology.hierarchical:
            return None
        return jnp.asarray(self.topology.link_cost(), jnp.float32)
