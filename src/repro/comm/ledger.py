"""Traffic ledger: who pays for which byte (DESIGN.md §5).

Two halves:

* a **traced** ledger (:func:`dispatch_node_ledger`) the MoE layer runs
  on the actual routing decisions of every step — it reports, per
  device, the inter-node dispatch bytes a flat all-to-all would move vs.
  the per-node-deduplicated bytes the hierarchical path models (a token
  whose top-k experts land on the same remote node crosses the expensive
  link once, not top-k times; condensed tokens cross zero times);

* an **analytic** half (:func:`expected_dedup_factor`,
  :func:`dispatch_bytes`, :func:`simulate_dispatch_rows`) used by
  ``core/commsim.py``, the dry-run ledger and the hierarchy-sensitivity
  benchmark, where no router exists — uniform routing is assumed.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.topology import Topology


# ---------------------------------------------------------------------------
# traced (in-step) ledger
# ---------------------------------------------------------------------------

def dispatch_node_ledger(expert_idx, valid, my_device, *, e_local: int,
                         topo: Topology, row_bytes: float):
    """Per-device inter-node dispatch bytes, flat vs node-deduplicated.

    expert_idx: [T, k] global expert ids; valid: [T, k] rows that take a
    dispatch slot (condensed/dropped rows already excluded); my_device:
    scalar combined device index (node-major); e_local: experts per
    device; row_bytes: payload bytes per dispatched row.

    Returns (inter_bytes_flat, inter_bytes_dedup) f32 scalars.
    flat counts every valid row whose expert lives on another node;
    dedup counts distinct (token, remote node) pairs — the payload a
    node-deduplicating wire format ships across the expensive axis.
    NOTE: with ``LuffyConfig.hier_dedup="on"`` the dedup number is no
    longer just a model — ``repro.condense.wire`` packs exactly one
    payload row per (token, remote node), so the executor's
    ``inter_bytes_shipped`` ledger equals this value (asserted in the
    golden grid); with the dense wire (the default) it stays the
    sizing target the compressed format is priced against.
    """
    L = topo.devices_per_node
    N = topo.num_nodes
    dev_of = expert_idx // e_local                       # [T, k]
    node_of = dev_of // L
    my_node = my_device // L
    vf = valid.astype(jnp.float32)
    remote = (node_of != my_node) & valid
    flat_rows = jnp.sum(remote.astype(jnp.float32))
    # distinct remote nodes touched per token
    oh = jax.nn.one_hot(node_of, N, dtype=jnp.float32) * vf[..., None]
    present = jnp.sum(oh, axis=1) > 0                    # [T, N]
    not_mine = jnp.arange(N) != my_node                  # [N]
    dedup_rows = jnp.sum((present & not_mine[None, :]).astype(jnp.float32))
    return flat_rows * row_bytes, dedup_rows * row_bytes


# ---------------------------------------------------------------------------
# analytic model (uniform routing)
# ---------------------------------------------------------------------------

def expected_dedup_factor(top_k: int, topo: Topology) -> float:
    """E[deduped inter-node payloads] / E[flat inter-node payloads] per
    token under uniform routing of ``top_k`` independent expert draws
    (only the node count matters — experts are uniform over nodes).

    Flat: each of the k copies crossing to a remote node pays; expected
    remote copies = k * (N - 1) / N. Dedup: a remote node pays once if
    *any* copy lands there; expected distinct remote nodes =
    (N-1) * (1 - (1 - 1/N)^k). Equal at k=1; <1 for k>1; 1.0 for flat
    topologies (no hierarchy to exploit).
    """
    N = topo.num_nodes
    if N <= 1 or top_k <= 1:
        return 1.0
    flat = top_k * (N - 1) / N
    dedup = (N - 1) * (1.0 - (1.0 - 1.0 / N) ** top_k)
    return dedup / flat


def dispatch_bytes(tokens: int, top_k: int, d_model: int, *,
                   topo: Topology, r_cond: float = 0.0,
                   bytes_per_el: int = 4, num_layers: int = 1,
                   dedup: bool = False) -> Tuple[float, float]:
    """(intra_bytes, inter_bytes) of one dispatch pass, all devices.

    Uniform routing over ``topo.num_devices`` expert shards; condensation
    removes ``r_cond`` of the tokens before dispatch. With ``dedup`` the
    inter-node component is scaled by :func:`expected_dedup_factor`
    (payloads deduped per node); intra traffic is the two-phase cost —
    every dispatched copy moves at most once on the cheap axis.
    """
    M = topo.num_devices
    N, L = topo.num_nodes, topo.devices_per_node
    payload = tokens * (1.0 - r_cond) * top_k * d_model * bytes_per_el \
        * num_layers
    # fraction of copies staying on-device / in-node / crossing nodes
    intra = payload * (L - 1) / M
    inter = payload * (M - L) / M
    if dedup:
        inter *= expected_dedup_factor(top_k, topo)
        # the deduped payload still fans out to its target devices on the
        # destination node's cheap links (phase-2 redistribution)
        intra = payload * (1.0 - 1.0 / M)
    return intra, inter


def a2a_time_s(intra_bytes: float, inter_bytes: float,
               topo: Topology, *, messages_intra: int = 0,
               messages_inter: int = 0) -> float:
    """Bandwidth-latency time for one collective phase pair."""
    return (intra_bytes / topo.intra_bw + inter_bytes / topo.inter_bw
            + messages_intra * topo.intra_lat
            + messages_inter * topo.inter_lat)


def phase_messages(topo: Topology) -> Tuple[int, int]:
    """(intra, inter) messages one device sends per two-phase exchange —
    the per-collective latency term chunked pipelining multiplies (every
    capacity chunk re-pays it; ``repro.sched.cost`` prices the trade)."""
    return max(0, topo.devices_per_node - 1), max(0, topo.num_nodes - 1)


def chunk_latency_s(topo: Topology) -> float:
    """Latency one *chunked* collective pays on top of its bandwidth
    time: per-message latencies over both phases of the exchange."""
    mi, me = phase_messages(topo)
    return mi * topo.intra_lat + me * topo.inter_lat


def simulate_dispatch_rows(rng: np.random.Generator, tokens: int,
                           top_k: int, topo: Topology, *,
                           r_cond: float = 0.0):
    """Monte-carlo dispatch from one source device under uniform routing.

    Returns (flat_inter_rows, dedup_inter_rows, intra_rows) — row counts
    (multiply by the payload row size for bytes). Used by the
    hierarchy-sensitivity benchmark to cross-check the closed form.
    """
    M = topo.num_devices
    L = topo.devices_per_node
    kept = int(round(tokens * (1.0 - r_cond)))
    experts = rng.integers(0, M, size=(kept, top_k))
    # distinct experts per token (top-k samples without replacement)
    for t in range(kept):
        while len(set(experts[t])) < min(top_k, M):
            experts[t] = rng.integers(0, M, size=top_k)
    my_node = 0                                # wlog: source device 0
    nodes = experts // L
    remote = nodes != my_node
    flat_inter = int(remote.sum())
    dedup_inter = sum(len(set(nodes[t][remote[t]])) for t in range(kept))
    intra = int(((experts % L != 0) & ~remote).sum())   # in-node, off-device
    return flat_inter, dedup_inter, intra
