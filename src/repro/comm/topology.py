"""Physical network topology descriptor (DESIGN.md §5).

A :class:`Topology` is the single source of truth for link costs: the
migration planner weights its traffic objective by it, the analytic
model (``core/commsim.py``) prices hierarchical collectives with it, the
dry-run traffic ledger splits collective bytes into intra/inter-node
components with it, and the MoE layer's hierarchical dispatch/combine
path derives its (node, local) axis split from it.

Device order convention is **node-major**: global device
``d = node * devices_per_node + local`` — the same order a mesh with
axes ``("node", "local")`` enumerates, so combined-axis collectives and
topology arithmetic agree by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

# Default link bandwidths (bytes/s per link), TPU v5e-class: ~50 GB/s
# ICI within a node, ~12 GB/s DCN across nodes. Single source of truth —
# launch/mesh.py re-exports these for the roofline. These are HAND-SET
# planning defaults: a measured per-backend fit from
# ``repro.obs.calibrate`` replaces them via ``with_links`` (the
# launchers' ``--calibrate`` path), so every consumer above prices real
# links without knowing calibration exists.
DEFAULT_INTRA_BW = 4.9e10
DEFAULT_INTER_BW = 1.225e10


@dataclasses.dataclass(frozen=True)
class Topology:
    """nodes × devices-per-node with a two-level bandwidth hierarchy.

    Bandwidths are bytes/s per link. ``intra`` is the cheap in-node
    interconnect (NVLink / ICI), ``inter`` the expensive cross-node one
    (IB / DCN). Latencies (seconds per message) feed the analytic model's
    message-count term; they default to 0 (bandwidth-dominated regime).
    """
    num_nodes: int
    devices_per_node: int
    intra_bw: float = DEFAULT_INTRA_BW
    inter_bw: float = DEFAULT_INTER_BW
    intra_lat: float = 0.0
    inter_lat: float = 0.0

    def __post_init__(self):
        assert self.num_nodes >= 1 and self.devices_per_node >= 1
        assert self.intra_bw > 0 and self.inter_bw > 0

    # -- derived ------------------------------------------------------------
    @property
    def num_devices(self) -> int:
        return self.num_nodes * self.devices_per_node

    @property
    def bw_ratio(self) -> float:
        """Cost of an inter-node byte relative to an intra-node byte."""
        return self.intra_bw / self.inter_bw

    @property
    def hierarchical(self) -> bool:
        return self.num_nodes > 1 and self.devices_per_node > 1

    def node_of(self, device):
        """Node index of a (scalar or array) global device index."""
        return device // self.devices_per_node

    def with_links(self, *, intra_bw: Optional[float] = None,
                   inter_bw: Optional[float] = None,
                   intra_lat: Optional[float] = None,
                   inter_lat: Optional[float] = None) -> "Topology":
        """Same shape, different link constants — the calibration
        hand-off (``repro.obs.calibrate.Calibration.topology``): the
        fingerprint (``repro.plan.cache.topology_fingerprint``) changes
        with the speeds, so calibrated and default plans never share a
        cache entry."""
        return dataclasses.replace(
            self,
            intra_bw=self.intra_bw if intra_bw is None else intra_bw,
            inter_bw=self.inter_bw if inter_bw is None else inter_bw,
            intra_lat=self.intra_lat if intra_lat is None else intra_lat,
            inter_lat=self.inter_lat if inter_lat is None else inter_lat)

    # -- link cost ----------------------------------------------------------
    def link_cost(self) -> np.ndarray:
        """[M, M] relative per-byte cost between devices.

        0 on the diagonal (no wire), 1 within a node, ``bw_ratio``
        across nodes. A uniform (single-node or single-device-per-node)
        topology degenerates to ``1 - I`` — exactly the implicit cost
        matrix of the flat path, so planners fed this matrix reproduce
        their historical behavior bit-for-bit.
        """
        M = self.num_devices
        dev = np.arange(M)
        same_node = self.node_of(dev)[:, None] == self.node_of(dev)[None, :]
        cost = np.where(same_node, 1.0, float(self.bw_ratio))
        np.fill_diagonal(cost, 0.0)
        return cost.astype(np.float64)

    # -- constructors -------------------------------------------------------
    @classmethod
    def flat(cls, num_devices: int, bw: float = DEFAULT_INTRA_BW) -> "Topology":
        """Uniform single-node topology (every link the same cost)."""
        return cls(num_nodes=1, devices_per_node=num_devices,
                   intra_bw=bw, inter_bw=bw)

    @classmethod
    def from_mesh(cls, mesh, *, intra_bw: float = DEFAULT_INTRA_BW,
                  inter_bw: float = DEFAULT_INTER_BW) -> "Topology":
        """Derive the topology from mesh axis names.

        A mesh carrying ``("node", "local")`` axes maps onto a two-level
        hierarchy; any other mesh is flat over its ``model`` axis (or
        over all devices when no model axis exists).
        """
        names = tuple(mesh.axis_names)
        sizes = dict(zip(names, mesh.devices.shape))
        if "node" in names and "local" in names:
            return cls(num_nodes=sizes["node"],
                       devices_per_node=sizes["local"],
                       intra_bw=intra_bw, inter_bw=inter_bw)
        return cls.flat(sizes.get("model", mesh.devices.size), bw=intra_bw)


def model_axes_of(mesh_axis_names: Tuple[str, ...]):
    """The expert-parallel axis spelling for a mesh: ``"model"`` on flat
    meshes, ``("node", "local")`` on hierarchical ones, None if neither."""
    if "node" in mesh_axis_names and "local" in mesh_axis_names:
        return ("node", "local")
    if "model" in mesh_axis_names:
        return "model"
    return None
