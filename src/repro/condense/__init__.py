"""Token-condensation subsystem (paper §V; DESIGN.md §10).

Where :mod:`repro.plan` materializes the *exchange* decision as data,
``repro.condense`` owns the condensation half of that decision end to
end:

* :mod:`repro.condense.backends` — a similarity-backend registry
  (``LuffyConfig.similarity_backend``): ``"exact"`` reproduces the
  historical masked Gram path bit-for-bit, ``"lsh"`` buckets tokens by
  signed random projections and measures only intra-bucket pairs,
  cutting the measured-pair count for large groups (ROADMAP item).
* :mod:`repro.condense.plan` — the frozen :class:`CondensePlan` (rep
  map, similarity history, measured-pair ledger, reuse signature) built
  inside ``build_exchange_plan`` and carried in the
  :class:`~repro.plan.ExchangePlan`; ``condense_reuse`` revalidates a
  carried rep map across sublayers with a configurable staleness bound
  (``condense_reuse_max_age``) guarding §V-A freshness.
* :mod:`repro.condense.wire` — the deduplicated hierarchical wire
  format (``LuffyConfig.hier_dedup``): unique token payloads cross the
  inter-node links once per (token, node) with a re-expansion map, and
  combine pre-reduces per node with a sum-order-stable schedule —
  actually shipping the bytes the ledger's ``inter_bytes_dedup`` has
  priced since PR 1.
"""
from repro.condense.backends import (available_similarity_backends,
                                     expected_measured_pairs,
                                     fast_similarity, get_similarity_backend,
                                     lsh_codes, pairwise_cosine,
                                     register_similarity_backend)
from repro.condense.plan import (CondenseCarry, CondenseOutput, CondensePlan,
                                 CondenseSignature, adaptive_threshold,
                                 build_condense_plan, condense_tokens,
                                 identity_condense_plan, pick_rate_bucket,
                                 similarity_quantiles, uncondense)
from repro.condense.wire import (dedup_capacity, dedup_combine,
                                 dedup_dispatch)

__all__ = [
    "CondenseCarry", "CondenseOutput", "CondensePlan", "CondenseSignature",
    "adaptive_threshold", "available_similarity_backends",
    "build_condense_plan", "condense_tokens", "dedup_capacity",
    "dedup_combine", "dedup_dispatch", "expected_measured_pairs",
    "fast_similarity", "get_similarity_backend", "identity_condense_plan",
    "lsh_codes", "pairwise_cosine", "pick_rate_bucket",
    "register_similarity_backend", "similarity_quantiles", "uncondense",
]
