"""Pluggable similarity-measurement backends (paper §V-A; DESIGN.md §10).

The §V-A fast-measurement *skip rules* (cross-expert ⇒ 0, historical
similarity > S1 ⇒ 1, < S2 ⇒ 0) leave an *uncertain* pair mask that must
actually be measured. A **backend** decides which of those uncertain
pairs get a real Gram measurement and returns the measured values:

* ``"exact"`` — measure every uncertain pair. Bit-for-bit the
  historical path (full ``pairwise_cosine`` off-TPU, the masked Pallas
  Gram kernel with tile-level early-out when ``use_kernels``).
* ``"lsh"`` — signed-random-projection bucketing: tokens hash to an
  ``lsh_bits``-bit code (one bit per projection sign); only uncertain
  pairs in the *same bucket* are measured, the rest are declared
  dissimilar. Identical tokens always collide (identical projections ⇒
  identical signs), so duplicate-heavy batches condense at exactly the
  exact-backend rate, while random token pairs collide with probability
  ``≈ 2^-bits`` — the O(G²·d) measured-pair count drops toward O(G·d)
  for large groups (ROADMAP item). The projection matrix is a fixed
  host-side constant (``lsh_seed``), so the decision is deterministic
  and replicated across devices for free.

Backends register with :func:`register_similarity_backend` (mirroring
``repro.plan.objectives``) and are selected by
``LuffyConfig.similarity_backend``.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import numpy as np

import jax
import jax.numpy as jnp

Array = jnp.ndarray

# backend(x_group [G, d], uncertain [G, G], *, use_kernel, lsh_bits,
#         lsh_seed) -> (sim_values [G, G] f32, measured_mask [G, G] bool)
# ``sim_values`` need only be meaningful where ``measured_mask`` is True.
SimilarityBackend = Callable[..., Tuple[Array, Array]]

SIMILARITY_BACKENDS: Dict[str, SimilarityBackend] = {}


def register_similarity_backend(name: str):
    """Decorator: register a similarity backend under ``name``."""
    def deco(fn: SimilarityBackend) -> SimilarityBackend:
        SIMILARITY_BACKENDS[name] = fn
        return fn
    return deco


def available_similarity_backends():
    return sorted(SIMILARITY_BACKENDS)


def get_similarity_backend(name: str) -> SimilarityBackend:
    try:
        return SIMILARITY_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown similarity_backend {name!r}; registered backends: "
            f"{available_similarity_backends()}") from None


# ---------------------------------------------------------------------------
# shared measurement primitives
# ---------------------------------------------------------------------------

def pairwise_cosine(x, eps: float = 1e-8):
    """[G, d] -> [G, G] normalized cosine similarity in [0, 1]."""
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.sum(xf * xf, -1, keepdims=True) + eps)
    c = n @ n.T                      # [-1, 1]
    return (c + 1.0) * 0.5           # paper uses normalized cosine in [0,1]


def _measure(x, mask, use_kernel: bool):
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.masked_similarity(x, mask)
    return pairwise_cosine(x)


@functools.lru_cache(maxsize=32)
def _lsh_projections(d: int, bits: int, seed: int) -> np.ndarray:
    """Fixed [d, bits] signed-projection matrix — a host constant, so
    every device (and every trace) hashes identically."""
    r = np.random.default_rng(seed)
    return r.standard_normal((d, bits)).astype(np.float32)


def lsh_codes(x, *, bits: int = 8, seed: int = 0):
    """[G, d] -> [G] int32 bucket codes (one sign bit per projection)."""
    d = x.shape[-1]
    bits = max(1, min(int(bits), 30))
    proj = jnp.asarray(_lsh_projections(d, bits, seed))
    signs = (x.astype(jnp.float32) @ proj) >= 0.0          # [G, bits]
    weights = jnp.asarray(2 ** np.arange(bits), jnp.int32)
    return jnp.sum(signs.astype(jnp.int32) * weights[None, :], axis=-1)


# ---------------------------------------------------------------------------
# the backends
# ---------------------------------------------------------------------------

@register_similarity_backend("exact")
def exact_backend(x, uncertain, *, use_kernel: bool = False,
                  lsh_bits: int = 8, lsh_seed: int = 0):
    """Measure every uncertain pair — the historical path, exactly."""
    return _measure(x, uncertain, use_kernel), uncertain


@register_similarity_backend("lsh")
def lsh_backend(x, uncertain, *, use_kernel: bool = False,
                lsh_bits: int = 8, lsh_seed: int = 0):
    """Measure only uncertain pairs whose LSH codes collide; the
    bucket-restricted mask also feeds the Pallas kernel's tile-level
    early-out, so fewer tiles are computed, not just fewer reported."""
    code = lsh_codes(x, bits=lsh_bits, seed=lsh_seed)
    same_bucket = code[:, None] == code[None, :]
    measured = uncertain & same_bucket
    return _measure(x, measured, use_kernel), measured


# ---------------------------------------------------------------------------
# §V-A fast similarity (skip rules + backend measurement)
# ---------------------------------------------------------------------------

def fast_similarity(x_group, expert_group, s_prev, s1: float, s2: float,
                    use_kernel: bool = False, *, backend: str = "exact",
                    lsh_bits: int = 8, lsh_seed: int = 0):
    """§V-A fast similarity for one group.

    x_group: [G, d]; expert_group: [G] primary expert ids;
    s_prev: [G, G] similarity from the previous block (or None).
    Returns (sim [G,G], measured_frac [] — fraction of the G² pairs the
    backend actually measured).
    """
    G = x_group.shape[0]
    same_expert = expert_group[:, None] == expert_group[None, :]
    if s_prev is not None:
        known_hi = s_prev > s1
        known_lo = s_prev < s2
        uncertain = same_expert & ~known_hi & ~known_lo
    else:
        known_hi = jnp.zeros((G, G), bool)
        uncertain = same_expert
    fn = get_similarity_backend(backend)
    cos, measured = fn(x_group, uncertain, use_kernel=use_kernel,
                       lsh_bits=lsh_bits, lsh_seed=lsh_seed)
    sim = jnp.where(measured, cos, 0.0)
    sim = jnp.where(known_hi & same_expert, 1.0, sim)
    sim = jnp.where(~same_expert, 0.0, sim)
    measured_frac = jnp.mean(measured.astype(jnp.float32))
    return sim, measured_frac


# ---------------------------------------------------------------------------
# analytic measured-pair model (dry-run condensation ledger)
# ---------------------------------------------------------------------------

def expected_measured_pairs(tokens: int, group_size: int, num_experts: int,
                            *, backend: str = "exact",
                            lsh_bits: int = 8) -> float:
    """Expected pairs a backend measures on the *first* block (no
    similarity history yet) under uniform top-1 routing: per group,
    ``G`` diagonal pairs plus ``G·(G−1)/E`` same-expert off-diagonal
    pairs; the LSH backend scales the off-diagonal mass by the random
    bucket-collision probability ``2^-bits``. Host-side float — the
    dryrun ``comm_ledger.condensation`` section reports from it."""
    G = group_size
    n_groups = max(1, tokens // G)
    offdiag = G * (G - 1) / max(1, num_experts)
    if backend == "lsh":
        offdiag *= 0.5 ** max(1, min(int(lsh_bits), 30))
    elif backend != "exact":
        get_similarity_backend(backend)   # raise on unknown names
    return float(n_groups * (G + offdiag))
