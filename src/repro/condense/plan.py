"""The condensation decision as data (paper §V; DESIGN.md §10).

The paper builds a DGL similarity graph over all tokens headed to the
same expert and keeps one representative per connected component.
Dynamic graphs don't exist on TPU, so we adapt (see DESIGN.md §3):

* tokens are processed in fixed *condensation groups* of ``G`` tokens
  (consecutive tokens of the local shard) — similarity is a blocked
  ``[G, G]`` problem that maps onto the MXU (Pallas kernel in
  ``repro.kernels.similarity``), measured through the pluggable backend
  registry (:mod:`repro.condense.backends`);
* §V-A's skip rules become masks; connected components + highest-degree
  representative (§V-B) become ``ceil(log2(G))`` rounds of vectorized
  min-label propagation;
* the adaptive threshold (Eq. 2) is computed from the running loss and
  additionally quantized to a *rate bucket* that selects a compiled
  executable with capacity ``C' = ceil(C·(1−rate))``.

:func:`build_condense_plan` freezes one sublayer's decision as a
:class:`CondensePlan` — the record ``build_exchange_plan`` embeds in the
:class:`~repro.plan.ExchangePlan`. Like the migration plan (DESIGN.md
§9), a condense plan can be *reused* across sublayers: the
:class:`CondenseSignature` (the primary-expert assignment the rep map
was built on, per-sequence age/validity) threads through the layer scan,
and ``LuffyConfig.condense_reuse`` revalidates it instead of re-running
the O(G²·d) similarity build. Unlike migration reuse, a revalidated
condense plan is only *bit-identical to a rebuild when the rebuild would
produce the same rep map* (identical duplicate structure, or nothing
condensable); in general reuse trades §V-A freshness for planning time,
bounded by ``condense_reuse_max_age`` sublayers.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.condense import backends as sim_backends

Array = jnp.ndarray


class CondenseOutput(NamedTuple):
    rep_idx: jnp.ndarray      # [T] int32 — each token's representative (global)
    is_rep: jnp.ndarray       # [T] bool — True if token represents itself
    sim: jnp.ndarray          # [n_groups, G, G] f32 — similarity (for s_prev)
    rate: jnp.ndarray         # [] f32 — fraction of tokens condensed
    measured_pairs: jnp.ndarray = 0.0   # [] f32 — pairs actually measured


class CondenseSignature(NamedTuple):
    """What a carried rep map must revalidate against.

    ``expert`` is the primary-expert assignment the map was built on
    (merged tokens must still share an expert — §V skip rule 1);
    ``age``/``valid`` are per-*sequence* so they migrate with sequences
    under §IV re-homing. ``valid`` is pinned to 0 under
    ``condense_reuse="off"`` so the carry never revalidates while the
    compiled graph stays identical across modes (the graph-parity
    discipline of DESIGN.md §9)."""
    expert: Array             # [T] int32 — expected primary expert per token
    age: Array                # [n_seq] f32 — sublayers since the sim build
    valid: Array              # [n_seq] f32 — 1.0 once a plan was built


class CondenseCarry(NamedTuple):
    """The cross-sublayer reuse state threaded through the layer scan:
    the carried rep map (within-group positions, migration-safe) plus
    its signature fields, flattened per device."""
    rep: Array                # [T] int32 — rep position within the group
    expert: Array             # [T] int32
    age: Array                # [n_seq] f32
    valid: Array              # [n_seq] f32


class CondensePlan(NamedTuple):
    """One sublayer's frozen condensation decision (rides on the
    :class:`~repro.plan.ExchangePlan`). ``backend`` is static; array
    fields are traced. ``signature`` is None on plans built without a
    reuse carry (the historical graph); ``built``/``reused`` feed the
    MoEAux ledger."""
    backend: str
    rep_idx: Array            # [T] int32
    is_rep: Array             # [T] bool
    s_next: Optional[Array]   # [n_groups, G, G] f32 similarity history
    rate: Array               # [] f32
    measured_pairs: Array     # [] f32
    signature: Optional[CondenseSignature] = None
    built: Optional[Array] = None     # [] f32 — 1 when the sim build ran
    reused: Optional[Array] = None    # [] f32 — 1 when the carry was reused


def identity_condense_plan(T: int, backend: str = "exact") -> CondensePlan:
    """The condense-nothing plan (vanilla serving, decode, condensation
    off): every token represents itself."""
    idx = jnp.arange(T, dtype=jnp.int32)
    return CondensePlan(
        backend=backend, rep_idx=idx, is_rep=jnp.ones((T,), bool),
        s_next=None, rate=jnp.float32(0.0),
        measured_pairs=jnp.float32(0.0))


# ---------------------------------------------------------------------------
# Eq. 2 + rate buckets
# ---------------------------------------------------------------------------

def adaptive_threshold(l_ini, l_prev):
    """Paper Eq. (2): h_t = 1 / (1 + exp(l_norm))."""
    l_norm = (l_ini - l_prev) / jnp.maximum(l_ini, 1e-9)
    return 1.0 / (1.0 + jnp.exp(l_norm))


def pick_rate_bucket(threshold: float, sim_quantiles, buckets) -> int:
    """Host-side: choose the largest bucket whose condensable fraction
    (estimated from observed similarity quantiles) is supportable.

    sim_quantiles: callable q -> similarity value at quantile q, or an
    array of per-decile similarity values (len 11, deciles 0..100%).
    """
    import numpy as np
    q = np.asarray(sim_quantiles, dtype=np.float64)
    # fraction of pairs with similarity above threshold
    frac = float(np.mean(q >= threshold))
    best = 0
    for i, b in enumerate(buckets):
        if b <= frac + 1e-9:
            best = i
    return best


# ---------------------------------------------------------------------------
# components + representatives (§V-B)
# ---------------------------------------------------------------------------

def _components_and_reps(adj):
    """adj: [G, G] bool symmetric (no self loops needed). Returns rep [G]
    int32 — the index each node condenses to (highest-degree node of its
    connected component; §V-B).
    """
    G = adj.shape[0]
    idx = jnp.arange(G, dtype=jnp.int32)
    adj = adj | jnp.eye(G, dtype=bool)
    labels = idx
    # min-label propagation; diameter <= G but log2 rounds of
    # squaring-style propagation converge for the clustered graphs we see.
    n_iter = max(1, math.ceil(math.log2(G)) + 1)
    for _ in range(n_iter):
        neigh_min = jnp.min(jnp.where(adj, labels[None, :], G), axis=1)
        labels = jnp.minimum(labels, neigh_min.astype(jnp.int32))
        # propagate through current labels too (pointer jumping)
        labels = labels[labels]
    degree = jnp.sum(adj, axis=1).astype(jnp.int32)
    # highest degree in component, ties -> smallest index
    score = degree * G + (G - 1 - idx)               # larger is better
    same = labels[:, None] == labels[None, :]
    comp_scores = jnp.where(same, score[None, :], -1)
    rep = jnp.argmax(comp_scores, axis=1).astype(jnp.int32)
    return rep


def condense_tokens(x, primary_expert, threshold, *, group_size: int,
                    s_prev: Optional[jnp.ndarray] = None,
                    s1: float = 0.8, s2: float = 0.2,
                    use_kernel: bool = False, backend: str = "exact",
                    lsh_bits: int = 8, lsh_seed: int = 0) -> CondenseOutput:
    """Condense local tokens (paper §V).

    x: [T, d] token embeddings (router input); primary_expert: [T];
    threshold: scalar in [0,1] (runtime value — Eq. 2 or static);
    s_prev: [n_groups, G, G] similarity carried from the previous block;
    backend: similarity-backend registry name (``"exact"`` | ``"lsh"``).

    Returns global rep_idx over [T].
    """
    T, d = x.shape
    G = group_size
    assert T % G == 0, (T, G)
    n_groups = T // G
    xg = x.reshape(n_groups, G, d)
    eg = primary_expert.reshape(n_groups, G)

    def per_group(xb, ebb, spb):
        sim, measured = sim_backends.fast_similarity(
            xb, ebb, spb, s1, s2, use_kernel=use_kernel, backend=backend,
            lsh_bits=lsh_bits, lsh_seed=lsh_seed)
        adj = (sim >= threshold) & ~jnp.eye(G, dtype=bool)
        rep = _components_and_reps(adj)
        return sim, rep, measured

    if s_prev is None:
        sims, reps, measured = jax.vmap(
            lambda a, b: per_group(a, b, None))(xg, eg)
    else:
        sims, reps, measured = jax.vmap(per_group)(
            xg, eg, s_prev.astype(jnp.float32))

    offsets = (jnp.arange(n_groups, dtype=jnp.int32) * G)[:, None]
    rep_idx = (reps + offsets).reshape(T)
    is_rep = rep_idx == jnp.arange(T, dtype=jnp.int32)
    rate = 1.0 - jnp.mean(is_rep.astype(jnp.float32))
    pairs = jnp.sum(measured.astype(jnp.float32)) * float(G * G)
    return CondenseOutput(rep_idx, is_rep, sims, rate, pairs)


def uncondense(y, rep_idx):
    """y: [T, d] MoE outputs (garbage at condensed rows); copy each
    condensed token's value from its representative (token_to_token)."""
    return jnp.take(y, rep_idx, axis=0)


# ---------------------------------------------------------------------------
# plan build + cross-sublayer reuse
# ---------------------------------------------------------------------------

def build_condense_plan(x, primary_expert, threshold, *, group_size: int,
                        s_prev: Optional[Array] = None,
                        s1: float = 0.8, s2: float = 0.2,
                        use_kernel: bool = False, backend: str = "exact",
                        lsh_bits: int = 8, lsh_seed: int = 0,
                        carry: Optional[CondenseCarry] = None,
                        reuse_mode: str = "off",
                        max_age: int = 4) -> CondensePlan:
    """Decide one sublayer's condensation: either a full similarity
    build (:func:`condense_tokens` through the backend registry), or —
    when a threaded ``carry`` revalidates — the carried rep map with the
    similarity history passed through unchanged.

    Revalidation (``reuse_mode="signature"``): the carried map is
    trusted iff it exists, every sequence's age is under ``max_age``,
    and the current primary-expert assignment equals the one it was
    built on (merged tokens must still share an expert). ``"always"``
    skips the expert compare (age bound still applies); ``"off"`` emits
    carries whose valid flag is pinned to 0, so the cond machinery is
    compiled but never fires — keeping "off" and "signature" graphs
    structurally identical (the DESIGN.md §9 graph-parity discipline).

    The reuse machinery needs a similarity history to pass through, so
    it engages only when both ``carry`` and ``s_prev`` are given (the
    layer scan threads both whenever condensation is on); otherwise the
    historical cond-free graph is built.
    """
    T, _ = x.shape
    G = group_size
    e0 = primary_expert.astype(jnp.int32)

    def _full_build():
        return condense_tokens(
            x, e0, threshold, group_size=G, s_prev=s_prev, s1=s1, s2=s2,
            use_kernel=use_kernel, backend=backend, lsh_bits=lsh_bits,
            lsh_seed=lsh_seed)

    reuse_on = reuse_mode != "off"
    if carry is None or s_prev is None:
        out = _full_build()
        sig = None
        if carry is not None:
            # carry threaded but no history to reuse: emit a fixed-shape,
            # never-validating signature so the scan carry stays uniform
            n_seq = carry.age.shape[0]
            sig = CondenseSignature(e0, jnp.zeros((n_seq,), jnp.float32),
                                    jnp.zeros((n_seq,), jnp.float32))
        return CondensePlan(
            backend=backend, rep_idx=out.rep_idx, is_rep=out.is_rep,
            s_next=out.sim, rate=out.rate,
            measured_pairs=out.measured_pairs, signature=sig,
            built=jnp.float32(1.0), reused=jnp.float32(0.0))

    sp3 = s_prev.astype(jnp.float32).reshape(-1, G, G)
    n_seq = carry.age.shape[0]
    have = jnp.all(carry.valid > 0.5)
    fresh = jnp.all(carry.age < jnp.float32(max_age))
    if reuse_mode == "always":
        match = have & fresh
    else:                                   # "off" | "signature"
        match = have & fresh & jnp.all(carry.expert == e0)

    group_base = (jnp.arange(T, dtype=jnp.int32) // G) * G

    def _reuse(_):
        rep_idx = group_base + carry.rep
        is_rep = rep_idx == jnp.arange(T, dtype=jnp.int32)
        rate = 1.0 - jnp.mean(is_rep.astype(jnp.float32))
        return (rep_idx, is_rep, sp3, rate, jnp.float32(0.0))

    def _build(_):
        out = _full_build()
        return (out.rep_idx, out.is_rep, out.sim, out.rate,
                out.measured_pairs)

    rep_idx, is_rep, sims, rate, pairs = jax.lax.cond(
        match, _reuse, _build, 0)
    mf = match.astype(jnp.float32)
    age_out = jnp.where(match, carry.age + 1.0, 0.0)
    valid_out = (jnp.ones((n_seq,), jnp.float32) if reuse_on
                 else jnp.zeros((n_seq,), jnp.float32))
    sig = CondenseSignature(e0, age_out, valid_out)
    return CondensePlan(
        backend=backend, rep_idx=rep_idx, is_rep=is_rep, s_next=sims,
        rate=rate, measured_pairs=pairs, signature=sig,
        built=1.0 - mf, reused=mf)


# ---------------------------------------------------------------------------
# host-side stats (bucket selection / Fig. 5)
# ---------------------------------------------------------------------------

def similarity_quantiles(sim, expert_idx=None, same_expert_only: bool = True):
    """Decile values of the off-diagonal similarity distribution (host
    stats for bucket selection / Fig. 5).

    sim: [..., G, G] similarity; expert_idx: [..., G] primary expert ids,
    required when ``same_expert_only`` — only off-diagonal same-expert
    pairs (the pairs condensation can actually merge) enter the
    distribution, not the mostly-zero full matrix. Host-side numpy (the
    selection size is data-dependent, so this is not traceable); returns
    the 11 decile values ``pick_rate_bucket`` consumes.
    """
    import numpy as np
    s = np.asarray(sim, np.float64)
    G = s.shape[-1]
    s = s.reshape(-1, s.shape[-2], G)
    off_diag = ~np.eye(G, dtype=bool)
    if same_expert_only:
        if expert_idx is None:
            raise ValueError(
                "same_expert_only=True needs expert_idx to identify "
                "same-expert pairs (or pass same_expert_only=False)")
        e = np.asarray(expert_idx).reshape(-1, G)
        mask = (e[:, :, None] == e[:, None, :]) & off_diag[None]
    else:
        mask = np.broadcast_to(off_diag[None], s.shape)
    vals = s[mask]
    if vals.size == 0:
        vals = np.zeros((1,), np.float64)
    return np.quantile(vals, np.linspace(0.0, 1.0, 11))
