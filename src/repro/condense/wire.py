"""The deduplicated hierarchical wire format (DESIGN.md §10).

Since PR 1 the traffic ledger has *priced* a per-node-deduplicated
payload (``inter_bytes_dedup``: a token whose top-k experts land on the
same remote node crosses the expensive link once, not k times) while the
executed hier collectives still moved the dense buffers. This module
actually ships it, behind ``LuffyConfig.hier_dedup``:

**Dispatch.** Each source device packs one *unique* payload row per
(token, destination node) into a ``[N, C_u, d]`` buffer (``C_u`` =
:func:`dedup_capacity`) and a *re-expansion map* — the ordinary dense
``[E, C]`` dispatch layout carrying, per expert row, the unique-slot
pointer and the per-copy gate weight instead of the d-dim payload. The
unique buffer crosses nodes once per (token, node) pair (inter-node
all-to-all over the node axis), then fans out to the destination node's
devices on the cheap links (intra-node all-gather — exactly the
phase-2 redistribution ``repro.comm.ledger.dispatch_bytes(dedup=True)``
models). Row reconstruction through the map is exact, so expert inputs
are **bit-identical** to the dense wire.

**Combine.** Expert outputs destined to the same (source token, node)
are pre-reduced *on the expert node* — a deterministic scatter-add in
fixed row order, then an intra-node reduce-scatter — and one partial row
per (token, node) crosses back. The source adds the per-node partials in
ascending node order, so the whole reduction has a fixed, documented
association ("sum-order-stable"): outputs are deterministic run-to-run,
but associate differently than the flat wire's per-copy sum — dedup mode
matches flat within float tolerance, not bitwise (tested).

Scope: the vanilla (non-migrated) sync exchange — migrate-mode combine
re-addresses rows to new homes, where the (token, node) dedup map does
not apply; pipelined execution chunks the dense capacity. Both fall back
to the dense wire (``ExchangePlan.wire`` records the executed format).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.comm import CommContext, compat

Array = jnp.ndarray


def dedup_capacity(tokens: int, e_local: int, local: int,
                   capacity: int) -> int:
    """Static unique-row capacity per (source device, destination node).

    Bounded by both the token count (each token occupies at most one
    unique slot per node) and the node's dispatch slots (a unique row
    exists only if ≥1 of its copies took a slot on that node:
    ``e_local·L·C``), so the packing can never overflow — no drop path.
    """
    bound = min(tokens, e_local * local * capacity)
    return max(8, ((bound + 7) // 8) * 8)


def dedup_dispatch(xf, expert_idx, gate_w, valid, pos, *,
                   comm: CommContext, e_local: int, capacity: int
                   ) -> Tuple[Array, Array, Array, Dict]:
    """Ship the deduplicated dispatch payload; reconstruct dense rows.

    xf: [T, d] payload rows (compute dtype); expert_idx/gate_w/valid/
    pos: [T, k] routing (valid already excludes condensed/dropped rows).
    Returns ``(x_rows [E_local, M, C, d], gw [E_local, M, C],
    rvalid [E_local, M, C] bool, state)`` — ``x_rows`` bit-identical to
    the dense wire's payload slabs; ``state`` carries the maps
    :func:`dedup_combine` needs plus the shipped-bytes ledger count.
    """
    N = compat.axis_size(comm.node_axis)
    L = compat.axis_size(comm.local_axis)
    M = N * L
    T, k = expert_idx.shape
    d = xf.shape[1]
    C = capacity
    E = e_local * M
    cdt = xf.dtype
    my_node = comm.index() // L

    node_of = (expert_idx // e_local) // L                  # [T, k]
    # distinct destination nodes per token (the dedup map)
    hit = (node_of[..., None] == jnp.arange(N)[None, None, :]) \
        & valid[..., None]                                  # [T, k, N]
    headed = jnp.any(hit, axis=1)                           # [T, N]
    h_i = headed.astype(jnp.int32)
    urank = jnp.cumsum(h_i, axis=0) - h_i                   # [T, N]
    C_u = dedup_capacity(T, e_local, L, C)
    un_safe = jnp.where(headed, urank, 0)

    # unique payload buffer: one row per (token, dest node)
    n_grid = jnp.broadcast_to(jnp.arange(N)[None, :], (T, N))
    ubuf = jnp.zeros((N, C_u, d), cdt).at[n_grid, un_safe].add(
        xf[:, None, :] * headed[..., None].astype(cdt), mode="drop")

    # re-expansion map in the dense dispatch layout: (uslot+1, gate_w)
    u_copy = jnp.take_along_axis(urank, node_of, axis=1)    # [T, k]
    e_f = expert_idx.reshape(-1)
    p_f = pos.reshape(-1)
    v_f = valid.reshape(-1)
    e_safe = jnp.where(v_f, e_f, 0)
    p_safe = jnp.where(v_f, p_f, 0)
    mvals = jnp.stack([(u_copy + 1).astype(jnp.float32),
                       gate_w.astype(jnp.float32)], -1).reshape(-1, 2)
    mbuf = jnp.zeros((E, C, 2), jnp.float32).at[e_safe, p_safe].add(
        mvals * v_f[:, None].astype(jnp.float32), mode="drop")

    # wire: map via the ordinary dense exchange (2 scalars/row), unique
    # payload inter-node once per (token, node), then cheap-link fan-out
    mbuf = comm.all_to_all(mbuf)
    ub1 = comm.node_all_to_all(ubuf)                        # [N_src, C_u, d]
    ug = comm.local_all_gather(ub1)                         # [L*N, C_u, d]

    rmeta = mbuf.reshape(M, e_local, C, 2).transpose(1, 0, 2, 3)
    u = jnp.round(rmeta[..., 0]).astype(jnp.int32) - 1      # [E_l, M, C]
    rvalid = u >= 0
    u_safe = jnp.maximum(u, 0)
    gw = (rmeta[..., 1] * rvalid.astype(jnp.float32)).astype(cdt)
    m_ids = jnp.arange(M, dtype=jnp.int32)
    gi = (m_ids % L) * N + (m_ids // L)                     # source row in ug
    gi_b = jnp.broadcast_to(gi[None, :, None], u.shape)
    x_rows = ug[gi_b, u_safe] * rvalid[..., None].astype(cdt)

    occ = jnp.sum(h_i.astype(jnp.float32), axis=0)          # [N]
    state = {"headed": headed, "un_safe": un_safe, "u_safe": u_safe,
             "rvalid": rvalid, "N": N, "L": L, "M": M, "C_u": C_u,
             "shipped_rows": jnp.sum(occ) - occ[my_node]}
    return x_rows, gw, rvalid, state


def dedup_combine(out_rows, state, *, comm: CommContext) -> Array:
    """Return gate-weighted expert outputs to their source tokens with
    per-node pre-reduction.

    out_rows: [E_local, M, C, d] finished (gate-weighted) rows in the
    dense layout. Partial sums per (source token, node) accumulate in
    fixed (expert, source, slot) row order on the expert node, an
    intra-node reduce-scatter completes the node sum, one partial row
    per (token, node) crosses back, and the source adds node partials
    in ascending node index — a fully deterministic association.
    Returns delta [T, d].
    """
    N, L, M, C_u = state["N"], state["L"], state["M"], state["C_u"]
    rvalid, u_safe = state["rvalid"], state["u_safe"]
    headed, un_safe = state["headed"], state["un_safe"]
    d = out_rows.shape[-1]
    cdt = out_rows.dtype
    T = headed.shape[0]

    m_grid = jnp.broadcast_to(
        jnp.arange(M, dtype=jnp.int32)[None, :, None], u_safe.shape)
    comb = jnp.zeros((M, C_u, d), cdt).at[m_grid, u_safe].add(
        out_rows * rvalid[..., None].astype(cdt), mode="drop")
    # finish the node sum on the cheap links, keeping only my column's
    # source chunk (m = n_src * L + l_src)
    comb = comb.reshape(N, L, C_u, d).transpose(1, 0, 2, 3)
    part = comm.local_psum_scatter(comb)                    # [1, N, C_u, d]
    part = part.reshape(N, C_u, d)
    pback = comm.node_all_to_all(part)                      # [N, C_u, d]
    n_grid = jnp.broadcast_to(jnp.arange(N)[None, :], (T, N))
    g = pback[n_grid, un_safe] * headed[..., None].astype(cdt)
    return jnp.sum(g, axis=1)                               # node order
