"""The deduplicated hierarchical wire format (DESIGN.md §10).

Since PR 1 the traffic ledger has *priced* a per-node-deduplicated
payload (``inter_bytes_dedup``: a token whose top-k experts land on the
same remote node crosses the expensive link once, not k times) while the
executed hier collectives still moved the dense buffers. This module
actually ships it, behind ``LuffyConfig.hier_dedup``:

**Dispatch.** Each source device packs one *unique* payload row per
(token, destination node) into a ``[N, C_u, d]`` buffer (``C_u`` =
:func:`dedup_capacity`) and a *re-expansion map* — the ordinary dense
``[E, C]`` dispatch layout carrying, per expert row, the unique-slot
pointer and the per-copy gate weight instead of the d-dim payload. The
unique buffer crosses nodes once per (token, node) pair (inter-node
all-to-all over the node axis), then fans out to the destination node's
devices on the cheap links (intra-node all-gather — exactly the
phase-2 redistribution ``repro.comm.ledger.dispatch_bytes(dedup=True)``
models). Row reconstruction through the map is exact, so expert inputs
are **bit-identical** to the dense wire.

**Combine.** Expert outputs destined to the same (source token, node)
are pre-reduced *on the expert node* — a deterministic scatter-add in
fixed row order, then an intra-node reduce-scatter — and one partial row
per (token, node) crosses back. The source adds the per-node partials in
ascending node order, so the whole reduction has a fixed, documented
association ("sum-order-stable"): outputs are deterministic run-to-run,
but associate differently than the flat wire's per-copy sum — dedup mode
matches flat within float tolerance, not bitwise (tested).

Scope: **universal** (DESIGN.md §15). Dispatch is mode-independent —
experts never move, so the (token, node) unique packing is identical
under migration and pipelining. Migrate-mode combine re-addresses rows
to post-migration homes through a *dest-keyed* map: the re-expansion
map carries each row's destination position in the migrated frame
(``dest_gpos``), the expert node pre-reduces per (token, **dest**
device) and one partial row per (token, node) crosses straight to the
token's NEW home (:func:`dedup_combine_migrate`) — same
sum-order-stable schedule, no detour through the source. Pipelined
execution chunks the *unique-row* capacity
(``repro.sched.plan_unique_chunks``): each chunk's inter-node hop is
issued before the previous chunk's intra-node fan-out/dequantize is
consumed (the §6 depth-2 schedule), and chunks reassemble in the sync
layout before reconstruction — bit-identical to the sync dedup wire
(``ExchangePlan.wire`` records the executed format).

**Wire precision (DESIGN.md §14).** Both wires compose with
``LuffyConfig.wire_dtype``: activation rows are quantized
(:mod:`repro.comm.dtypes`) immediately before the node-crossing
collective and dequantized immediately after, so everything downstream
of the hop — fan-out, reconstruction, expert compute — runs at the
compute dtype on identical values to a quantize-then-exchange
reference (casts and per-row block scaling commute with permutation
collectives). The re-expansion map (``mbuf``) and the combine's int32
metadata never quantize: exact slot pointers are what make dedup
reconstruction bit-exact. ``wire_dtype="f32"`` is the identity wire —
byte-for-byte the historical graphs.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm import CommContext, compat
from repro.comm import dtypes as wdt
from repro.sched import ChunkPlan, run_pipeline

Array = jnp.ndarray


def _node_hop(q, sc, cdt, d: int, *, comm: CommContext,
              chunks: Optional[ChunkPlan] = None,
              fanout: bool = False) -> Array:
    """Cross the node axis with a quantized ``[N, R, .]`` payload and
    dequantize right after the hop (optionally following with the
    intra-node all-gather fan-out), software-pipelined over unique-row
    chunks when ``chunks`` is given.

    Chunking slices axis 1 (the unique-row axis): quantization is
    per-row, the collective is a permutation, and chunks reassemble by
    concatenation in slot order, so the chunked hop is **bit-identical**
    to the single-shot hop — the §6 depth-2 schedule just lets chunk
    k+1's expensive inter-node transfer fly while chunk k dequantizes
    and fans out on the cheap links.
    """
    def _land(qk, sck):
        x = wdt.dequantize_rows(qk, sck, cdt, d)
        return comm.local_all_gather(x) if fanout else x

    if chunks is None or chunks.n_chunks <= 1:
        q1 = comm.node_all_to_all(q)
        sc1 = None if sc is None else comm.node_all_to_all(sc)
        return _land(q1, sc1)

    def _disp(k):
        o, s = chunks.offsets[k], chunks.sizes[k]
        qk = comm.node_all_to_all(
            jax.lax.slice_in_dim(q, o, o + s, axis=1))
        sck = None if sc is None else comm.node_all_to_all(
            jax.lax.slice_in_dim(sc, o, o + s, axis=1))
        return qk, sck

    outs, _ = run_pipeline(chunks.n_chunks, dispatch=_disp,
                           compute=lambda k, p: _land(*p))
    return jnp.concatenate(outs, axis=1)


def ship_rows(comm_fn, buf: Array, d: int, wire_dtype: str) -> Array:
    """Move a ``[..., w >= d]`` buffer through a permutation collective
    with the activation columns (``[..., :d]``) at the wire dtype.

    The collective only permutes rows across devices, so
    quantize → ship → dequantize is bit-identical to
    quantize → dequantize → ship (the §14 reference-path law the tests
    pin). Trailing columns (gate weight / primary flag, 2 of ``w - d``)
    and the f8 scale sideband ship as separate arrays through the same
    collective at full precision. ``"f32"`` returns the single-buffer
    historical path untouched.
    """
    if wire_dtype == "f32":
        return comm_fn(buf)
    q, sc = wdt.quantize_rows(buf[..., :d], wire_dtype)
    q = comm_fn(q)
    if sc is not None:
        sc = comm_fn(sc)
    x = wdt.dequantize_rows(q, sc, buf.dtype, d)
    if buf.shape[-1] == d:
        return x
    tail = comm_fn(buf[..., d:])
    return jnp.concatenate([x, tail], axis=-1)


def dedup_capacity(tokens: int, e_local: int, local: int,
                   capacity: int) -> int:
    """Static unique-row capacity per (source device, destination node).

    Bounded by both the token count (each token occupies at most one
    unique slot per node) and the node's dispatch slots (a unique row
    exists only if ≥1 of its copies took a slot on that node:
    ``e_local·L·C``), so the packing can never overflow — no drop path.
    """
    bound = min(tokens, e_local * local * capacity)
    return max(8, ((bound + 7) // 8) * 8)


def dedup_dispatch(xf, expert_idx, gate_w, valid, pos, *,
                   comm: CommContext, e_local: int, capacity: int,
                   wire_dtype: str = "f32", use_kernel: bool = False,
                   dest_gpos: Optional[Array] = None,
                   prim: Optional[Array] = None,
                   chunks: Optional[ChunkPlan] = None,
                   ) -> Tuple[Array, Array, Array, Dict]:
    """Ship the deduplicated dispatch payload; reconstruct dense rows.

    xf: [T, d] payload rows (compute dtype); expert_idx/gate_w/valid/
    pos: [T, k] routing (valid already excludes condensed/dropped rows).
    Returns ``(x_rows [E_local, M, C, d], gw [E_local, M, C],
    rvalid [E_local, M, C] bool, state)`` — ``x_rows`` bit-identical to
    the dense wire's payload slabs (at the wire dtype's reconstruction
    when ``wire_dtype != "f32"``); ``state`` carries the maps
    :func:`dedup_combine` needs plus the shipped-bytes ledger count.

    Migrate mode (``dest_gpos``/``prim`` given): the re-expansion map
    grows two planes — each copy's destination global position
    ``dest_gpos [T]`` (``dest_device * T + dest_pos`` in the migrated
    frame) and its primary flag ``prim [T, k]`` — so the expert side
    can re-address the combine (:func:`dedup_combine_migrate`) without
    a second exchange. The payload wire itself is untouched:
    **dispatch is mode-independent** (experts never move), so
    ``x_rows`` stays bit-identical to the vanilla dedup dispatch.

    ``chunks`` pipelines the unique-row node hop (bit-identical
    reassembly, see :func:`_node_hop`).

    ``use_kernel`` routes the hot pre-dispatch path — gate-mask →
    dedup-pack → quantize — through the fused Pallas kernel
    (:func:`repro.kernels.ops.pack_quantize`) instead of the
    scatter-then-quantize pure-jnp composition; the two are bit-equal
    (each unique slot has exactly one contributing token, so gather
    and scatter-add-onto-zeros produce the same values and the codec
    formula is shared).
    """
    N = compat.axis_size(comm.node_axis)
    L = compat.axis_size(comm.local_axis)
    M = N * L
    T, k = expert_idx.shape
    d = xf.shape[1]
    C = capacity
    E = e_local * M
    cdt = xf.dtype
    my_node = comm.index() // L

    node_of = (expert_idx // e_local) // L                  # [T, k]
    # distinct destination nodes per token (the dedup map)
    hit = (node_of[..., None] == jnp.arange(N)[None, None, :]) \
        & valid[..., None]                                  # [T, k, N]
    headed = jnp.any(hit, axis=1)                           # [T, N]
    h_i = headed.astype(jnp.int32)
    urank = jnp.cumsum(h_i, axis=0) - h_i                   # [T, N]
    C_u = dedup_capacity(T, e_local, L, C)
    un_safe = jnp.where(headed, urank, 0)

    # unique payload buffer: one row per (token, dest node), quantized
    # for the wire. Exactly one token heads each occupied slot, so the
    # fused gather-form kernel and the scatter-add-onto-zeros build the
    # same values; empty slots are zero rows (the gate mask) either way.
    n_grid = jnp.broadcast_to(jnp.arange(N)[None, :], (T, N))
    if use_kernel:
        from repro.kernels import ops as kops
        tok_src = jnp.where(
            headed,
            jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None],
                             (T, N)), -1)
        # inverse map: slot -> contributing token (-1 = empty). At most
        # one token per slot, so scatter-max is deterministic.
        tok = jnp.full((N, C_u), -1, jnp.int32).at[n_grid, un_safe].max(
            tok_src, mode="drop")
        q, sc = kops.pack_quantize(xf, tok.reshape(-1),
                                   wire_dtype=wire_dtype)
        q = q.reshape(N, C_u, q.shape[-1])
        if sc is not None:
            sc = sc.reshape(N, C_u, sc.shape[-1])
    else:
        ubuf = jnp.zeros((N, C_u, d), cdt).at[n_grid, un_safe].add(
            xf[:, None, :] * headed[..., None].astype(cdt), mode="drop")
        q, sc = wdt.quantize_rows(ubuf, wire_dtype)

    # re-expansion map in the dense dispatch layout: (uslot+1, gate_w)
    # — plus, in migrate mode, (dest_gpos+1, prim). All planes ride the
    # exact f32 map exchange; dest_gpos < M*T stays far below 2^24, so
    # the f32 round-trip is lossless.
    u_copy = jnp.take_along_axis(urank, node_of, axis=1)    # [T, k]
    e_f = expert_idx.reshape(-1)
    p_f = pos.reshape(-1)
    v_f = valid.reshape(-1)
    e_safe = jnp.where(v_f, e_f, 0)
    p_safe = jnp.where(v_f, p_f, 0)
    cols = [(u_copy + 1).astype(jnp.float32),
            gate_w.astype(jnp.float32)]
    if dest_gpos is not None:
        cols.append(jnp.broadcast_to(
            dest_gpos.astype(jnp.float32)[:, None] + 1.0, (T, k)))
        cols.append(prim.astype(jnp.float32))
    w = len(cols)
    mvals = jnp.stack(cols, -1).reshape(-1, w)
    mbuf = jnp.zeros((E, C, w), jnp.float32).at[e_safe, p_safe].add(
        mvals * v_f[:, None].astype(jnp.float32), mode="drop")

    # wire: map via the ordinary dense exchange (2-4 scalars/row, exact
    # — it carries slot pointers), unique payload inter-node once per
    # (token, node) at the wire dtype (+ f8 scale sideband), dequantized
    # right after the node hop so the cheap-link fan-out and everything
    # downstream sees compute-dtype rows
    mbuf = comm.all_to_all(mbuf)
    ug = _node_hop(q, sc, cdt, d, comm=comm, chunks=chunks,
                   fanout=True)                             # [L*N, C_u, d]

    rmeta = mbuf.reshape(M, e_local, C, w).transpose(1, 0, 2, 3)
    u = jnp.round(rmeta[..., 0]).astype(jnp.int32) - 1      # [E_l, M, C]
    rvalid = u >= 0
    u_safe = jnp.maximum(u, 0)
    gw = (rmeta[..., 1] * rvalid.astype(jnp.float32)).astype(cdt)
    m_ids = jnp.arange(M, dtype=jnp.int32)
    gi = (m_ids % L) * N + (m_ids // L)                     # source row in ug
    gi_b = jnp.broadcast_to(gi[None, :, None], u.shape)
    x_rows = ug[gi_b, u_safe] * rvalid[..., None].astype(cdt)

    occ = jnp.sum(h_i.astype(jnp.float32), axis=0)          # [N]
    state = {"headed": headed, "un_safe": un_safe, "u_safe": u_safe,
             "rvalid": rvalid, "N": N, "L": L, "M": M, "C_u": C_u,
             "T": T, "shipped_rows": jnp.sum(occ) - occ[my_node]}
    if dest_gpos is not None:
        dg = jnp.round(rmeta[..., 2]).astype(jnp.int32) - 1
        state["dgpos"] = jnp.where(rvalid, dg, -1)          # [E_l, M, C]
        state["prim"] = (rmeta[..., 3]
                         * rvalid.astype(jnp.float32)).astype(cdt)
    return x_rows, gw, rvalid, state


def dedup_combine(out_rows, state, *, comm: CommContext,
                  wire_dtype: str = "f32",
                  chunks: Optional[ChunkPlan] = None) -> Array:
    """Return gate-weighted expert outputs to their source tokens with
    per-node pre-reduction.

    out_rows: [E_local, M, C, d] finished (gate-weighted) rows in the
    dense layout. Partial sums per (source token, node) accumulate in
    fixed (expert, source, slot) row order on the expert node, an
    intra-node reduce-scatter completes the node sum, one partial row
    per (token, node) crosses back, and the source adds node partials
    in ascending node index — a fully deterministic association.
    ``chunks`` pipelines the return hop over the unique-row axis
    (bit-identical, :func:`_node_hop`). Returns delta [T, d].
    """
    N, L, M, C_u = state["N"], state["L"], state["M"], state["C_u"]
    rvalid, u_safe = state["rvalid"], state["u_safe"]
    headed, un_safe = state["headed"], state["un_safe"]
    d = out_rows.shape[-1]
    cdt = out_rows.dtype
    T = headed.shape[0]

    m_grid = jnp.broadcast_to(
        jnp.arange(M, dtype=jnp.int32)[None, :, None], u_safe.shape)
    comb = jnp.zeros((M, C_u, d), cdt).at[m_grid, u_safe].add(
        out_rows * rvalid[..., None].astype(cdt), mode="drop")
    # finish the node sum on the cheap links, keeping only my column's
    # source chunk (m = n_src * L + l_src)
    comb = comb.reshape(N, L, C_u, d).transpose(1, 0, 2, 3)
    part = comm.local_psum_scatter(comb)                    # [1, N, C_u, d]
    part = part.reshape(N, C_u, d)
    # per-node partials cross back at the wire dtype; the intra-node
    # reduce-scatter above already ran at the compute dtype
    q, sc = wdt.quantize_rows(part, wire_dtype)
    pback = _node_hop(q, sc, cdt, d, comm=comm, chunks=chunks)
    n_grid = jnp.broadcast_to(jnp.arange(N)[None, :], (T, N))
    g = pback[n_grid, un_safe] * headed[..., None].astype(cdt)
    return jnp.sum(g, axis=1)                               # node order


def dedup_combine_migrate(out_rows, state, *, comm: CommContext,
                          wire_dtype: str = "f32",
                          chunks: Optional[ChunkPlan] = None) -> Array:
    """Dest-keyed combine for the migrated frame (DESIGN.md §15).

    out_rows: [E_local, M, C, d] finished rows — gate-weighted AND
    carrying the primary copy's residual (``y·gw + x·prim``), because
    migrate mode *materializes* the post-block hidden state at the
    token's NEW home rather than adding a delta at the source. Rows
    pre-reduce per (token, **destination** device) keyed by the
    ``dest_gpos`` plane of the re-expansion map: a deterministic
    scatter-add in fixed (expert, source, slot) row order into a
    ``[M, T, d]`` buffer, an intra-node reduce-scatter completing the
    node sum, one partial row per (token, node) crossing straight to
    the destination device — no detour through the source — and node
    partials added in ascending node index: the same sum-order-stable
    association as :func:`dedup_combine`, re-addressed. The migration
    permutation is a bijection on global slots, so each destination
    receives exactly T rows — no capacity bound, no drop path.
    ``chunks`` pipelines the return hop over the token axis
    (bit-identical). Returns y [T, d] in the migrated frame.
    """
    N, L, M, T = state["N"], state["L"], state["M"], state["T"]
    dgpos = state["dgpos"]
    d = out_rows.shape[-1]
    cdt = out_rows.dtype

    live = dgpos >= 0
    dd = jnp.where(live, dgpos // T, 0)                     # dest device
    dp = jnp.where(live, dgpos % T, 0)                      # dest position
    comb = jnp.zeros((M, T, d), cdt).at[dd, dp].add(
        out_rows * live[..., None].astype(cdt), mode="drop")
    # finish the node sum on the cheap links, keeping only my column's
    # destination chunk (dest device = n_dest * L + l_dest)
    comb = comb.reshape(N, L, T, d).transpose(1, 0, 2, 3)
    part = comm.local_psum_scatter(comb)                    # [1, N, T, d]
    part = part.reshape(N, T, d)
    q, sc = wdt.quantize_rows(part, wire_dtype)
    pback = _node_hop(q, sc, cdt, d, comm=comm, chunks=chunks)
    return jnp.sum(pback, axis=0)                           # node order
