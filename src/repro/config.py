"""Configuration system for the LUFFY-JAX framework.

Every architecture in ``repro.configs`` instantiates :class:`ModelConfig`;
the launcher composes it with :class:`MeshConfig`, :class:`ShapeConfig`
(the four assigned input shapes) and :class:`LuffyConfig` (the paper's
technique) into a :class:`RunConfig`.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttnConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    use_rope: bool = True
    # Sliding-window pattern, cycled over layers. ``None`` entries mean full
    # (global) attention for that layer; integers are window sizes.
    # e.g. gemma3's 5:1 local:global = (w, w, w, w, w, None).
    window_pattern: Tuple[Optional[int], ...] = (None,)
    # llama4-style: chunked local attention (block-diagonal) instead of
    # sliding window for the "local" layers.
    chunked_local: bool = False
    softmax_scale: Optional[float] = None
    logit_cap: Optional[float] = None

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def window_for_layer(self, layer: int) -> Optional[int]:
        return self.window_pattern[layer % len(self.window_pattern)]

    @property
    def subquadratic(self) -> bool:
        """True iff no layer does full quadratic attention."""
        return all(w is not None for w in self.window_pattern)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                      # hidden dim of EACH expert
    capacity_factor: float = 1.25
    num_shared_experts: int = 0    # llama4-style always-on shared expert(s)
    router_aux_coef: float = 0.01  # load-balance loss coefficient
    router_jitter: float = 0.0

    def capacity(self, tokens_per_device: int, num_devices: int) -> int:
        """Per-expert buffer capacity (tokens), before condensation."""
        total = tokens_per_device * num_devices
        cap = int(math.ceil(self.capacity_factor * total * self.top_k
                            / self.num_experts / num_devices)) * num_devices
        return max(cap, num_devices)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM (hymba) or RWKV6 token-mix."""
    kind: str = "mamba"            # "mamba" | "rwkv6"
    state_dim: int = 16            # N (mamba) — per-channel state size
    expand: int = 2                # d_inner = expand * d_model (mamba)
    conv_dim: int = 4              # depthwise conv width (mamba)
    head_dim: int = 64             # rwkv6 head size
    dt_rank: int = 0               # 0 => ceil(d_model/16)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                      # "decoder" | "encdec"
    family: str                    # "dense" | "moe" | "ssm" | "hybrid" | "audio" | "vlm"
    num_layers: int
    d_model: int
    d_ff: int                      # dense-FFN hidden dim (ignored if pure-MoE layers)
    vocab_size: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (hymba): run attention and SSM branches in parallel and mean-fuse.
    parallel_ssm: bool = False
    # layer_ffn_pattern: cycled; each entry "dense" or "moe".
    layer_ffn_pattern: Tuple[str, ...] = ("dense",)
    norm: str = "rms"              # "rms" | "ln"
    act: str = "silu"              # "silu" | "gelu"
    gated_mlp: bool = True
    causal: bool = True            # False for encoder-style (MoE-BERT)
    tie_embeddings: bool = False
    # enc-dec extras
    num_encoder_layers: int = 0
    # modality frontend stub: number of prefix embedding slots fed by the
    # (stubbed) vision/audio encoder, and their feature dim.
    prefix_slots: int = 0
    prefix_dim: int = 0
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    citation: str = ""

    # -- derived -----------------------------------------------------------
    def ffn_kind(self, layer: int) -> str:
        return self.layer_ffn_pattern[layer % len(self.layer_ffn_pattern)]

    @property
    def uses_moe(self) -> bool:
        return self.moe is not None and "moe" in self.layer_ffn_pattern

    @property
    def uses_attention(self) -> bool:
        return self.attn is not None

    @property
    def supports_long_decode(self) -> bool:
        """May run long_500k: SSM/hybrid archs, or attention archs whose
        layers are majority sliding-window/chunked (gemma3 5:1, llama4
        3:1, starcoder2 4k-window). Pure full-attention archs skip it
        (see DESIGN.md §Arch-applicability)."""
        if self.kind == "encdec":
            return False
        if self.ssm is not None and self.attn is None:
            return True            # pure SSM
        if self.parallel_ssm:
            return self.attn.subquadratic
        wp = self.attn.window_pattern
        windowed = sum(1 for w in wp if w is not None)
        return windowed * 2 >= len(wp) and windowed > 0 \
            or self.attn.subquadratic

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # unembed
        layers = []
        if self.kind == "encdec":
            layers += [("enc", i) for i in range(self.num_encoder_layers)]
            layers += [("dec", i) for i in range(self.num_layers)]
        else:
            layers += [("dec", i) for i in range(self.num_layers)]
        for which, i in layers:
            if self.attn is not None:
                a = self.attn
                n += d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d
                if which == "dec" and self.kind == "encdec":
                    n += d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d  # cross-attn
            if self.ssm is not None and (self.parallel_ssm or self.attn is None):
                s = self.ssm
                if s.kind == "mamba":
                    di = s.expand * d
                    n += 2 * d * di + di * d + di * (2 * s.state_dim) + di
                else:  # rwkv6
                    n += 6 * d * d
            kind = self.ffn_kind(i)
            mult = 3 if self.gated_mlp else 2
            if kind == "moe" and self.moe is not None:
                n += self.moe.num_experts * mult * d * self.moe.d_ff
                n += d * self.moe.num_experts          # router
                n += self.moe.num_shared_experts * mult * d * self.moe.d_ff
            else:
                n += mult * d * self.d_ff
            n += 2 * d                                  # norms
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k + shared experts)."""
        if not self.uses_moe:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        mult = 3 if self.gated_mlp else 2
        per_expert = mult * self.d_model * m.d_ff
        n_moe_layers = sum(
            1 for i in range(self.num_layers) if self.ffn_kind(i) == "moe")
        inactive = n_moe_layers * (m.num_experts - m.top_k) * per_expert
        return full - inactive


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# LUFFY technique config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LuffyConfig:
    """The paper's two techniques (§IV, §V)."""
    enable_condensation: bool = True
    enable_migration: bool = True
    # §V-A fast similarity measurement thresholds: previous-block
    # similarity > S1 => similar (skip calc), < S2 => dissimilar (skip).
    s1: float = 0.8
    s2: float = 0.2
    # §V-B adaptive threshold; if adaptive=False use static_threshold.
    adaptive_threshold: bool = True
    static_threshold: float = 0.5
    # Similarity-measurement backend (repro.condense.backends, DESIGN.md
    # §10): "exact" measures every §V-A uncertain pair (the historical
    # masked Gram path, bit-for-bit); "lsh" buckets tokens by lsh_bits
    # signed random projections and measures only intra-bucket pairs —
    # identical tokens always collide, random pairs with prob ~2^-bits,
    # so the measured-pair count drops for large groups.
    similarity_backend: str = "exact"
    lsh_bits: int = 8
    lsh_seed: int = 0
    # Condense-plan reuse across sublayers (repro.condense.plan): "off"
    # rebuilds the O(G²·d) similarity every MoE sublayer (historical);
    # "signature" reuses the carried rep map while the primary-expert
    # assignment matches what it was built on AND every sequence's age
    # is under condense_reuse_max_age (the §V-A freshness bound —
    # embeddings drift across layers, so a reused map trades freshness
    # for planning time); "always" skips the expert compare (age bound
    # still applies). The carry threads through the layer scan for every
    # mode ("off" pins the valid flag to 0) so compiled graphs stay
    # structurally identical across modes (DESIGN.md §9 graph parity).
    condense_reuse: str = "off"
    condense_reuse_max_age: int = 4
    # TPU adaptation: condensation-rate buckets. The adaptive threshold
    # picks a bucket each iteration; each bucket is a separately compiled
    # executable with capacity C' = ceil(C * (1 - rate)).
    rate_buckets: Tuple[float, ...] = (0.0, 0.25, 0.5)
    # §IV-A: top-q candidate devices per sequence.
    q: int = 3
    # Attention cost model speed term P (FLOP/s), profiled.
    gpu_speed: float = 1.0e13
    # Per-chunk pipeline issue cost (ms) for the overlap pricing. <= 0
    # means "use the built-in constant"
    # (repro.sched.cost.DEFAULT_CHUNK_OVERHEAD_MS); a measured value
    # comes from repro.obs.calibrate (Calibration.apply).
    chunk_overhead_ms: float = -1.0
    # TPU adaptation knobs: condensation group size (blocked similarity
    # tile; see DESIGN.md §3) and combine-buffer slack under migration.
    condense_group: int = 128
    combine_slack: float = 1.0
    # use the Pallas kernels for similarity / expert FFN
    use_kernels: bool = False
    # Expert-parallel collective strategy (DESIGN.md §5): "flat" = one
    # all-to-all over the whole model axis; "hier" = two-phase
    # intra-node/inter-node exchange over a ("node", "local") mesh pair,
    # bit-compatible with "flat" but with node-aggregated inter-node
    # messages and the per-node dedup ledger active.
    comm_mode: str = "flat"
    # Deduplicated hier wire format (repro.condense.wire, DESIGN.md
    # §10): "on" ships each token's payload across the inter-node links
    # once per (token, node) with a re-expansion map, and pre-reduces
    # combine rows per node with a sum-order-stable schedule — actually
    # moving the bytes the ledger's inter_bytes_dedup models (asserted
    # equal via the inter_bytes_shipped metric). Requires
    # comm_mode="hier"; universal across execution modes (DESIGN.md
    # §15): migrate-mode combine re-addresses the pre-reduce to each
    # row's *destination* node via a dest-keyed re-expansion map, and
    # pipelined execution chunks the unique-row capacity so the hop's
    # intra-node fan-out hides behind the next chunk's inter-node leg.
    # Dispatch reconstruction is exact, but the combine reduction
    # associates per-node, so outputs match "off" within float
    # tolerance, not bitwise.
    hier_dedup: str = "off"
    # Execution scheduling (DESIGN.md §6): "sync" runs gate → dispatch →
    # expert FFN → combine strictly in order; "pipeline" splits the
    # static dispatch capacity into `pipeline_chunks` 8-aligned chunks
    # and double-buffers chunk k's collectives against chunk k-1's
    # expert FFN (repro.sched). Forward outputs are bit-identical to
    # "sync" in both comm modes (weight grads accumulate per chunk, so
    # training may drift at the last ulp like remat); single-device
    # runs and the decode all-reduce path (no all-to-all to hide)
    # degenerate to sync. "decode_overlap" (DESIGN.md §13) targets that
    # decode all-reduce instead: the combine psum is issued concurrently
    # with the shared-expert FFN (moe_decode_allreduce), bit-identical
    # to sync; on the build/execute (train/prefill) path it behaves
    # exactly like "sync".
    exec_mode: str = "sync"
    # Capacity chunks for exec_mode="pipeline". 0 (or negative) requests
    # the objective-planned chunk count: build_exchange_plan reuses
    # estimate_exchange(chunks=None)'s 1..16 search instead of this
    # constant (an explicit positive value always overrides).
    pipeline_chunks: int = 4
    # Migration planner objective (DESIGN.md §7): "traffic" minimizes
    # link-cost-weighted combine bytes (the historical objective, exactly);
    # "overlap" minimizes modeled *exposed* — un-overlappable — time of
    # the pipelined exchange (repro.plan.objectives), preferring plans
    # that keep bytes off whichever link tier the pipeline cannot hide.
    # Registry-extensible: repro.plan.objectives.register_objective.
    plan_objective: str = "traffic"
    # Plan lifecycle (DESIGN.md §9): cross-layer migration-plan reuse
    # inside the layer scan. "off" replans every MoE sublayer (the
    # historical behavior); "signature" carries the plan through the
    # scan and re-runs the greedy only when the routing signature
    # (gathered per-slot expert counts + sequence lengths) drifts from
    # what the carried plan expects — on a match the emitted plan is
    # bit-identical to a full replan; "always" trusts the carried plan
    # without revalidation (outputs may then differ from "off").
    # Reuse requires plan_objective="traffic" (the "overlap" portfolio
    # may execute a plan the pure greedy would not re-derive); other
    # objectives replan every sublayer regardless of this setting.
    plan_reuse: str = "off"
    # Compressed exchange (DESIGN.md §14): precision activation rows
    # ship at when they cross the node boundary. "f32" is the identity
    # wire (rows ship at compute_dtype — the historical behavior,
    # byte-for-byte); "bf16" casts the d_model payload on the wire;
    # "f8e4m3" ships float8_e4m3fn with per-32-element f32 scales in a
    # sideband through the same collective (requires fp8 support in the
    # installed jax — validated at plan build). Decided at plan time
    # (frozen into ExchangePlan.wire_dtype, part of the plan cache
    # key), priced by plan/estimate.py, executed by plan/exchange.py +
    # condense/wire.py immediately around every node-crossing
    # collective that ships activation rows; integer route maps and
    # per-sequence metadata never quantize, and compute stays at
    # compute_dtype throughout.
    wire_dtype: str = "f32"
    # Error-feedback accumulation for the lossy wire (DESIGN.md §15):
    # each step the per-token quantization residual x - deq(quant(x))
    # is carried and added back into the NEXT step's dispatch payload
    # before quantization, so the time-averaged wire error is unbiased
    # instead of accumulating in one direction. No effect under the
    # exact "f32" wire; carried state threads through the same
    # cross-step bus as the condensation similarity carry.
    wire_error_feedback: bool = False


def resolve_pipeline_chunks(pipeline_chunks: Optional[int],
                            plan_objective: str) -> int:
    """Launcher default for ``--pipeline-chunks`` (None = unset): the
    objective-planned count (0, see ``LuffyConfig.pipeline_chunks``)
    under the "overlap" objective, the historical 4 otherwise. An
    explicit CLI value always wins."""
    if pipeline_chunks is not None:
        return pipeline_chunks
    return 0 if plan_objective == "overlap" else 4


# ---------------------------------------------------------------------------
# Mesh / run configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"


@dataclass(frozen=True)
class OptimConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    # ZeRO-1: shard optimizer moments over the data axis.
    zero1: bool = True


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = MeshConfig()
    luffy: LuffyConfig = LuffyConfig()
    optim: OptimConfig = OptimConfig()
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def reduced(model: ModelConfig, *, num_layers: int = 2, d_model: int = 256,
            max_experts: int = 4, seq_len_hint: int = 128) -> ModelConfig:
    """Smoke-test variant of the same family: <=2 layers, d_model<=512,
    <=4 experts, tiny vocab. Keeps the family/layer pattern intact."""
    d_model = min(d_model, 512)
    attn = model.attn
    if attn is not None:
        heads = max(2, min(4, attn.num_heads))
        kv = max(1, min(heads, attn.num_kv_heads))
        head_dim = max(8, d_model // heads)
        win = tuple((None if w is None else min(w, seq_len_hint // 2))
                    for w in attn.window_pattern)
        attn = dataclasses.replace(
            attn, num_heads=heads, num_kv_heads=kv, head_dim=head_dim,
            window_pattern=win)
    moe = model.moe
    if moe is not None:
        experts = min(max_experts, moe.num_experts)
        moe = dataclasses.replace(
            moe, num_experts=experts, top_k=min(moe.top_k, experts),
            d_ff=min(moe.d_ff, 2 * d_model),
            num_shared_experts=min(moe.num_shared_experts, 1))
    ssm = model.ssm
    # keep at least one full pattern period (gemma3's 5:1, llama4's 3:1)
    period = math.lcm(len(attn.window_pattern) if attn else 1,
                      len(model.layer_ffn_pattern))
    num_layers = max(num_layers, period)
    return dataclasses.replace(
        model,
        name=model.name + "-smoke",
        num_layers=num_layers,
        num_encoder_layers=min(model.num_encoder_layers, num_layers)
        if model.num_encoder_layers else 0,
        d_model=d_model,
        d_ff=min(model.d_ff, 2 * d_model),
        vocab_size=min(model.vocab_size, 1024),
        attn=attn, moe=moe, ssm=ssm,
        prefix_slots=min(model.prefix_slots, 8),
        prefix_dim=min(model.prefix_dim, d_model) if model.prefix_dim else 0,
        remat=False,
    )
