"""Architecture registry. Each module defines ``config()`` (and possibly
variants). Every entry cites its source in the ModelConfig.citation."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

ARCHS: List[str] = [
    "hymba_1p5b",
    "gemma3_12b",
    "rwkv6_3b",
    "seamless_m4t_large_v2",
    "llama4_maverick_400b_a17b",
    "yi_34b",
    "stablelm_12b",
    "starcoder2_15b",
    "internvl2_2b",
    "olmoe_1b_7b",
    # the paper's own models (LUFFY evaluation, Table II)
    "moe_transformerxl",
    "moe_bert_large",
    "moe_gpt2",
]

ALIASES = {
    "hymba-1.5b": "hymba_1p5b",
    "gemma3-12b": "gemma3_12b",
    "rwkv6-3b": "rwkv6_3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "yi-34b": "yi_34b",
    "stablelm-12b": "stablelm_12b",
    "starcoder2-15b": "starcoder2_15b",
    "internvl2-2b": "internvl2_2b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "moe-transformerxl": "moe_transformerxl",
    "moe-bert-large": "moe_bert_large",
    "moe-gpt2": "moe_gpt2",
}

ASSIGNED = ARCHS[:10]


def get_config(name: str, **overrides) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.config(**overrides)
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
