"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt scaled to the 12B spec]. 48L d_model=3840
16H (GQA kv=8, head_dim=256) d_ff=15360 vocab=262144."""
from repro.config import AttnConfig, ModelConfig


def config(**kw) -> ModelConfig:
    base = dict(
        name="gemma3-12b", kind="decoder", family="dense",
        num_layers=48, d_model=3840, d_ff=15360, vocab_size=262144,
        attn=AttnConfig(num_heads=16, num_kv_heads=8, head_dim=256,
                        rope_theta=1_000_000.0,
                        window_pattern=(1024, 1024, 1024, 1024, 1024, None)),
        layer_ffn_pattern=("dense",),
        act="gelu", tie_embeddings=True,
        param_dtype="bfloat16",
        citation="hf:google/gemma-3-1b-pt",
    )
    base.update(kw)
    return ModelConfig(**base)
