"""hymba-1.5b [hybrid] — parallel attention + Mamba heads, ssm_state=16.
[arXiv:2411.13676]. 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001.

All attention layers use a 1024 sliding window; the parallel Mamba branch
carries global context (the Hymba design rationale) — this keeps the
arch sub-quadratic for long_500k.
"""
from repro.config import AttnConfig, ModelConfig, SSMConfig


def config(**kw) -> ModelConfig:
    base = dict(
        name="hymba-1.5b", kind="decoder", family="hybrid",
        num_layers=32, d_model=1600, d_ff=5504, vocab_size=32001,
        attn=AttnConfig(num_heads=25, num_kv_heads=5, head_dim=64,
                        window_pattern=(1024,)),
        ssm=SSMConfig(kind="mamba", state_dim=16, expand=2),
        parallel_ssm=True,
        layer_ffn_pattern=("dense",),
        citation="arXiv:2411.13676",
    )
    base.update(kw)
    return ModelConfig(**base)
