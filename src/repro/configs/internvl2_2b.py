"""internvl2-2b [vlm] — InternViT + InternLM2 backbone. [arXiv:2404.16821].
LM: 24L d_model=2048 16H (GQA kv=8, head_dim=128) d_ff=8192 vocab=92553.
The ViT is a STUB: input_specs provides 256 patch embeddings (dim 1024)."""
from repro.config import AttnConfig, ModelConfig


def config(**kw) -> ModelConfig:
    base = dict(
        name="internvl2-2b", kind="decoder", family="vlm",
        num_layers=24, d_model=2048, d_ff=8192, vocab_size=92553,
        attn=AttnConfig(num_heads=16, num_kv_heads=8, head_dim=128),
        layer_ffn_pattern=("dense",),
        prefix_slots=256, prefix_dim=1024,
        citation="arXiv:2404.16821",
    )
    base.update(kw)
    return ModelConfig(**base)
