"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
iRoPE-style 3:1 chunked-local:global attention, early fusion (text path).
[hf:meta-llama/Llama-4-Scout-17B-16E scaled to the Maverick spec].
48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048."""
from repro.config import AttnConfig, ModelConfig, MoEConfig


def config(**kw) -> ModelConfig:
    base = dict(
        name="llama4-maverick-400b-a17b", kind="decoder", family="moe",
        num_layers=48, d_model=5120, d_ff=8192, vocab_size=202048,
        attn=AttnConfig(num_heads=40, num_kv_heads=8, head_dim=128,
                        rope_theta=500_000.0, chunked_local=True,
                        window_pattern=(8192, 8192, 8192, None)),
        moe=MoEConfig(num_experts=128, top_k=1, d_ff=8192,
                      capacity_factor=1.5, num_shared_experts=1),
        layer_ffn_pattern=("moe",),
        param_dtype="bfloat16",
        citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
    base.update(kw)
    return ModelConfig(**base)
