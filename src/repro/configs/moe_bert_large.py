"""MoE-BERT-Large (paper Table II): 24L, len 512, top-2, bidirectional.

NOTE: the paper's Table II prints d_model=768/d_hidden=3072 but its own
"Size" column (0.54/0.94/1.74/3.36 B) only reproduces with the real
BERT-Large dims d_model=1024 (16H) and expert d_ff=4096 — we follow the
sizes (validated in benchmarks/table2_models.py). [arXiv:1810.04805]."""
from repro.config import AttnConfig, ModelConfig, MoEConfig


def config(num_experts: int = 16, **kw) -> ModelConfig:
    base = dict(
        name=f"moe-bert-large-{num_experts}e", kind="decoder",
        family="moe",
        num_layers=24, d_model=1024, d_ff=4096, vocab_size=30522,
        attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=64,
                        use_rope=False),
        moe=MoEConfig(num_experts=num_experts, top_k=2, d_ff=4096,
                      capacity_factor=2.0),
        layer_ffn_pattern=("moe",),
        norm="ln", act="gelu", gated_mlp=False, causal=False,
        citation="paper Table II / arXiv:1810.04805",
    )
    base.update(kw)
    return ModelConfig(**base)
