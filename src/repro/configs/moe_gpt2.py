"""MoE-GPT2 (paper Table II): 12L d_model=768 d_hidden=3072, len 1024,
top-2. [paper Table II / GPT-2]."""
from repro.config import AttnConfig, ModelConfig, MoEConfig


def config(num_experts: int = 16, **kw) -> ModelConfig:
    base = dict(
        name=f"moe-gpt2-{num_experts}e", kind="decoder", family="moe",
        num_layers=12, d_model=768, d_ff=3072, vocab_size=50257,
        attn=AttnConfig(num_heads=12, num_kv_heads=12, head_dim=64,
                        use_rope=False),
        moe=MoEConfig(num_experts=num_experts, top_k=2, d_ff=3072,
                      capacity_factor=2.0),
        layer_ffn_pattern=("moe",),
        norm="ln", act="gelu", gated_mlp=False, tie_embeddings=True,
        citation="paper Table II / GPT-2",
    )
    base.update(kw)
    return ModelConfig(**base)
