"""MoE-TransformerXL (paper Table II): 18L d_model=1024 d_hidden=4096,
len 250, top-2 gate, experts in {2,4,8,16}. [arXiv:1901.02860 + paper]."""
from repro.config import AttnConfig, ModelConfig, MoEConfig


def config(num_experts: int = 16, **kw) -> ModelConfig:
    base = dict(
        name=f"moe-transformerxl-{num_experts}e", kind="decoder",
        family="moe",
        num_layers=18, d_model=1024, d_ff=4096, vocab_size=32000,
        attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=64),
        moe=MoEConfig(num_experts=num_experts, top_k=2, d_ff=4096,
                      capacity_factor=2.0),
        layer_ffn_pattern=("moe",),
        norm="ln", act="gelu", gated_mlp=False,
        citation="paper Table II / arXiv:1901.02860",
    )
    base.update(kw)
    return ModelConfig(**base)
