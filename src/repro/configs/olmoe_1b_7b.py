"""olmoe-1b-7b [moe] — 64 experts, top-8. [arXiv:2409.02060].
16L d_model=2048 16H (kv=16, head_dim=128) expert d_ff=1024 vocab=50304."""
from repro.config import AttnConfig, ModelConfig, MoEConfig


def config(**kw) -> ModelConfig:
    base = dict(
        name="olmoe-1b-7b", kind="decoder", family="moe",
        num_layers=16, d_model=2048, d_ff=1024, vocab_size=50304,
        attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=128),
        moe=MoEConfig(num_experts=64, top_k=8, d_ff=1024,
                      capacity_factor=1.25),
        layer_ffn_pattern=("moe",),
        citation="arXiv:2409.02060",
    )
    base.update(kw)
    return ModelConfig(**base)
