"""rwkv6-3b [ssm] — Finch, attention-free, data-dependent decay.
[arXiv:2404.05892]. 32L d_model=2560 d_ff=8960 vocab=65536 (head 64)."""
from repro.config import ModelConfig, SSMConfig


def config(**kw) -> ModelConfig:
    base = dict(
        name="rwkv6-3b", kind="decoder", family="ssm",
        num_layers=32, d_model=2560, d_ff=8960, vocab_size=65536,
        attn=None,
        ssm=SSMConfig(kind="rwkv6", head_dim=64),
        layer_ffn_pattern=("dense",),
        norm="ln", gated_mlp=False,
        citation="arXiv:2404.05892",
    )
    base.update(kw)
    return ModelConfig(**base)
