"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal backbone.
[arXiv:2308.11596]. 24L(+24 enc) d_model=1024 16H (kv=16) d_ff=8192
vocab=256206. The conformer/mel frontend is a STUB: input_specs provides
precomputed frame embeddings (prefix_dim=1024)."""
from repro.config import AttnConfig, ModelConfig


def config(**kw) -> ModelConfig:
    base = dict(
        name="seamless-m4t-large-v2", kind="encdec", family="audio",
        num_layers=24, num_encoder_layers=24,
        d_model=1024, d_ff=8192, vocab_size=256206,
        attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=64,
                        use_rope=False),
        layer_ffn_pattern=("dense",),
        norm="ln", act="gelu", gated_mlp=False,
        prefix_slots=1, prefix_dim=1024,
        citation="arXiv:2308.11596",
    )
    base.update(kw)
    return ModelConfig(**base)
