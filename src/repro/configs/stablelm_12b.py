"""stablelm-12b [dense]. [hf:stabilityai/stablelm-2-1_6b scaled to 12B].
40L d_model=5120 32H (GQA kv=8, head_dim=160) d_ff=13824 vocab=100352."""
from repro.config import AttnConfig, ModelConfig


def config(**kw) -> ModelConfig:
    base = dict(
        name="stablelm-12b", kind="decoder", family="dense",
        num_layers=40, d_model=5120, d_ff=13824, vocab_size=100352,
        attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=160),
        layer_ffn_pattern=("dense",),
        norm="ln",
        param_dtype="bfloat16",
        citation="hf:stabilityai/stablelm-2-1_6b",
    )
    base.update(kw)
    return ModelConfig(**base)
