"""starcoder2-15b [dense] — GQA, RoPE, 4k sliding window.
[arXiv:2402.19173]. 40L d_model=6144 48H (GQA kv=4, head_dim=128)
d_ff=24576 vocab=49152."""
from repro.config import AttnConfig, ModelConfig


def config(**kw) -> ModelConfig:
    base = dict(
        name="starcoder2-15b", kind="decoder", family="dense",
        num_layers=40, d_model=6144, d_ff=24576, vocab_size=49152,
        attn=AttnConfig(num_heads=48, num_kv_heads=4, head_dim=128,
                        rope_theta=100_000.0, window_pattern=(4096,)),
        layer_ffn_pattern=("dense",),
        norm="ln", act="gelu", gated_mlp=False,
        param_dtype="bfloat16",
        citation="arXiv:2402.19173",
    )
    base.update(kw)
    return ModelConfig(**base)
