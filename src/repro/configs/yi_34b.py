"""yi-34b [dense] — llama-arch GQA. [arXiv:2403.04652].
60L d_model=7168 56H (GQA kv=8, head_dim=128) d_ff=20480 vocab=64000."""
from repro.config import AttnConfig, ModelConfig


def config(**kw) -> ModelConfig:
    base = dict(
        name="yi-34b", kind="decoder", family="dense",
        num_layers=60, d_model=7168, d_ff=20480, vocab_size=64000,
        attn=AttnConfig(num_heads=56, num_kv_heads=8, head_dim=128,
                        rope_theta=5_000_000.0),
        layer_ffn_pattern=("dense",),
        param_dtype="bfloat16",
        citation="arXiv:2403.04652",
    )
    base.update(kw)
    return ModelConfig(**base)
