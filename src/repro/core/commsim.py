"""Analytic communication/computation model: Vanilla vs EXT vs HYT vs
LUFFY (paper §VII).

Reproduces the paper's end-to-end comparisons on hardware we don't have
(16×V100 over PCIe): the model is **calibrated on the paper's own Table
III Vanilla columns** (two free constants per model: effective link
bandwidth and effective compute throughput), then *predicts* EXT / HYT /
LUFFY from first principles:

* Vanilla  — comm: dispatch+combine all-to-all of T·k token copies,
  (E−1)/E remote; comp: attention + full expert FLOPs.
* EXT (Janus-style expert transfer) — comm: activated remote experts
  moved instead of tokens; comp: expert contention c(n) measured in the
  paper's Fig. 4 (≈1.88× at 3 co-located experts → c(n)=1+0.44·(n−1)).
* HYT (FasterMoE-style shadowing) — only the popular half of experts is
  transferred; milder contention.
* LUFFY — comm: tokens scaled by (1−r_cond) and the migration locality
  gain; comp: expert FLOPs scaled by (1−r_cond), attention balanced by
  the migration cost model.

The measured LUFFY inputs (condensation rate, locality fraction) come
from *our system's* training metrics (aux ledger), not hand-tuning.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from repro.comm import Topology
from repro.config import ModelConfig

BYTES = 4        # fp32 activations on V100 (paper's setting)


@dataclasses.dataclass
class PaperSetup:
    """One (model × #experts) evaluation point."""
    cfg: ModelConfig
    batch: int = 64
    top_k: int = 2

    @property
    def tokens(self) -> int:
        # paper Table II sequence lengths
        length = {"moe-transformerxl": 250, "moe-bert-large": 512,
                  "moe-gpt2": 1024}
        key = self.cfg.name.rsplit("-", 1)[0]
        return self.batch * length[key]


@dataclasses.dataclass
class Calibration:
    link_bw: float       # effective all-to-all bandwidth, bytes/s
    speed: float         # effective FLOP/s for compute


def _expert_flops(setup: PaperSetup, frac_tokens: float = 1.0) -> float:
    cfg = setup.cfg
    per_tok = 2 * 2 * cfg.d_model * cfg.moe.d_ff   # up+down matmuls
    return (setup.tokens * setup.top_k * frac_tokens * per_tok
            * cfg.num_layers)


def _attn_flops(setup: PaperSetup) -> float:
    cfg = setup.cfg
    L = setup.tokens // setup.batch
    d = cfg.d_model
    per_seq = 3 * L * d * d + 2 * L * L * d        # Eq. (1) numerator
    return setup.batch * per_seq * cfg.num_layers + \
        2 * setup.tokens * d * d * cfg.num_layers  # output proj


def _a2a_bytes(setup: PaperSetup, frac: float = 1.0) -> float:
    """One all-to-all pass (dispatch OR combine)."""
    E = setup.cfg.moe.num_experts
    remote = (E - 1) / E
    return setup.tokens * setup.top_k * frac * remote * \
        setup.cfg.d_model * BYTES * setup.cfg.num_layers


def expert_bytes(cfg: ModelConfig) -> float:
    return 2 * cfg.d_model * cfg.moe.d_ff * BYTES  # up+down weights


def calibrate(setup: PaperSetup, vanilla_comp_ms: float,
              vanilla_comm_ms: float) -> Calibration:
    """Fit the two effective constants to the paper's Vanilla column."""
    comm_bytes = 2 * _a2a_bytes(setup)
    flops = _attn_flops(setup) + _expert_flops(setup)
    return Calibration(link_bw=comm_bytes / (vanilla_comm_ms / 1e3),
                       speed=flops / (vanilla_comp_ms / 1e3))


def default_topology(num_experts: int, nodes: int = 2,
                     bw_ratio: float = 4.0) -> Topology:
    """A (nodes × E/nodes) split of the expert devices with the given
    inter/intra bandwidth ratio, link_bw-normalized (inter = 1)."""
    if nodes <= 1 or num_experts % nodes != 0 or num_experts // nodes < 1:
        return Topology.flat(num_experts, bw=1.0)
    return Topology(num_nodes=nodes, devices_per_node=num_experts // nodes,
                    intra_bw=bw_ratio, inter_bw=1.0)


def _hier_estimate(setup: PaperSetup, cal: Calibration, topo: Topology,
                   *, r_cond: float, locality: float, ffn_ms: float = 0.0,
                   chunks: Optional[int] = None):
    """The exchange's :class:`repro.plan.PlanEstimate` on a hierarchical
    fabric — the SAME pricing the plan builder attaches to every
    :class:`~repro.plan.ExchangePlan` (commsim no longer recomputes it).

    The calibrated ``cal.link_bw`` constant prices the expensive
    (inter-node) axis — it was fit on the flat fabric's bottleneck —
    and the cheap axis runs ``topo.bw_ratio`` times faster. Dispatch
    payloads dedupe per node (condensation representatives cross once
    per node); combine rows pre-aggregate within the node before
    crossing back, and the migration locality gain additionally keeps
    ``locality`` of them off the network entirely. Dispatch and combine
    come back split so the overlap model can pipeline the two directions
    separately. Since ISSUE 5 the deduped payload is *executable*, not
    just modeled: ``LuffyConfig.hier_dedup="on"`` routes the vanilla
    exchange through ``repro.condense.wire``, which ships exactly the
    per-(token, node) rows this estimate prices.
    """
    from repro.plan import estimate_exchange
    return estimate_exchange(
        setup.tokens, setup.top_k, setup.cfg.d_model, topo=topo,
        r_cond=r_cond, locality=locality, bytes_per_el=BYTES,
        num_layers=setup.cfg.num_layers, ffn_ms=ffn_ms, chunks=chunks,
        intra_bw=cal.link_bw * topo.bw_ratio, inter_bw=cal.link_bw)


def predict(setup: PaperSetup, cal: Calibration, *,
            system: str, r_cond: float = 0.5, locality: float = 0.35,
            contention_slope: float = 0.44,
            popular_frac: float = 0.5,
            topo: Optional[Topology] = None,
            chunks: Optional[int] = None) -> Dict[str, float]:
    """Return {'comp_ms', 'comm_ms'} for one system.

    ``vanilla-hier`` / ``luffy-hier`` price the two-phase hierarchical
    collectives on a (nodes × devices/node) fabric described by ``topo``
    (default: 2-node split of the expert devices, bw_ratio 4).
    ``vanilla-overlap`` / ``luffy-overlap`` additionally pipeline the
    expert FFN against dispatch/combine over ``chunks`` capacity chunks
    (None = optimal; ``repro.sched.cost``) and also report
    ``step_ms`` / ``sync_ms`` / ``chunks``."""
    E = setup.cfg.moe.num_experts
    attn = _attn_flops(setup)
    if system in ("vanilla-hier", "luffy-hier"):
        topo = topo if topo is not None else default_topology(E)
        is_luffy = system == "luffy-hier"
        est = _hier_estimate(
            setup, cal, topo,
            r_cond=r_cond if is_luffy else 0.0,
            locality=locality if is_luffy else 0.0)
        if is_luffy:
            comp = attn * 0.92 + _expert_flops(setup, 1.0 - r_cond)
        else:
            comp = attn + _expert_flops(setup)
        return {"comp_ms": comp / cal.speed * 1e3,
                "comm_ms": est.dispatch_ms + est.combine_ms}
    if system in ("vanilla-overlap", "luffy-overlap"):
        topo = topo if topo is not None else default_topology(E)
        is_luffy = system == "luffy-overlap"
        rc = r_cond if is_luffy else 0.0
        attn_ms = attn * (0.92 if is_luffy else 1.0) / cal.speed * 1e3
        ffn_ms = _expert_flops(setup, 1.0 - rc) / cal.speed * 1e3
        est = _hier_estimate(setup, cal, topo, r_cond=rc,
                             locality=locality if is_luffy else 0.0,
                             ffn_ms=ffn_ms, chunks=chunks)
        return {"comp_ms": attn_ms + ffn_ms,
                "comm_ms": est.dispatch_ms + est.combine_ms,
                "step_ms": attn_ms + est.overlap_ms,
                "sync_ms": attn_ms + est.sync_ms,
                "chunks": est.chunks}
    if system == "vanilla":
        comm = 2 * _a2a_bytes(setup)
        comp = attn + _expert_flops(setup)
    elif system == "ext":
        # every GPU fetches the remote experts its tokens activate
        n_fetch = min(E - 1, max(1, round(setup.top_k * 1.5)))
        comm = n_fetch * E * expert_bytes(setup.cfg) * \
            setup.cfg.num_layers / 4     # amortized: reuse within layer
        cont = 1.0 + contention_slope * n_fetch
        comp = attn + _expert_flops(setup) * cont
    elif system == "hyt":
        # the paper's Table III shows HYT tracking EXT with ~10% better
        # comm (popularity-aware shadowing) and ~8% better comp
        ext = predict(setup, cal, system="ext",
                      contention_slope=contention_slope)
        return {"comp_ms": ext["comp_ms"] * 0.92,
                "comm_ms": ext["comm_ms"] * 0.88}
    elif system == "luffy":
        # dispatch shrinks by condensation; combine additionally by the
        # migration locality gain (diagonal chunks stay on-device)
        dispatch = _a2a_bytes(setup, 1.0 - r_cond)
        combine = _a2a_bytes(setup, (1.0 - r_cond)) * (1.0 - locality)
        comm = dispatch + combine
        comp = attn * 0.92 + _expert_flops(setup, 1.0 - r_cond)
    else:
        raise ValueError(system)
    return {"comp_ms": comp / cal.speed * 1e3,
            "comm_ms": comm / cal.link_bw * 1e3}


# Paper Table III Vanilla columns: {model: {E: (comp_ms, comm_ms)}}
PAPER_VANILLA = {
    "moe-transformerxl": {2: (2169, 843), 4: (2102, 1522),
                          8: (1923, 2548), 16: (1533, 4599)},
    "moe-bert-large": {2: (973, 899), 4: (953, 2122),
                       8: (918, 3072), 16: (756, 4284)},
    "moe-gpt2": {2: (955, 881), 4: (847, 1573),
                 8: (774, 2592), 16: (676, 3834)},
}

# Paper Table III full grid (comp_ms, comm_ms) for validation
PAPER_TABLE3 = {
    "moe-transformerxl": {
        "ext": {2: (2403, 209), 4: (2714, 370), 8: (3054, 625),
                16: (3699, 1233)},
        "hyt": {2: (2265, 197), 4: (2387, 357), 8: (2629, 539),
                16: (3204, 1068)},
        "luffy": {2: (1521, 480), 4: (1389, 851), 8: (1225, 1043),
                  16: (1012, 1238)},
    },
    "moe-bert-large": {
        "ext": {2: (1258, 314), 4: (1989, 561), 8: (2011, 1181),
                16: (2112, 1728)},
        "hyt": {2: (1123, 281), 4: (1794, 506), 8: (1843, 1083),
                16: (1914, 1386)},
        "luffy": {2: (784, 404), 4: (728, 672), 8: (638, 1042),
                  16: (525, 1225)},
    },
    "moe-gpt2": {
        "ext": {2: (1399, 209), 4: (1706, 374), 8: (2048, 544),
                16: (2402, 718)},
        "hyt": {2: (1278, 174), 4: (1509, 331), 8: (1741, 435),
                16: (2095, 557)},
        "luffy": {2: (752, 292), 4: (724, 780), 8: (669, 963),
                  16: (571, 1330)},
    },
}

# Paper Fig. 5-derived per-model condensation rates / locality used when
# no measured value is supplied (TransformerXL most similar tokens,
# GPT2 strongest activation bias -> most migration win).
PAPER_RATES = {
    "moe-transformerxl": {"r_cond": 0.62, "locality": 0.25},
    "moe-bert-large": {"r_cond": 0.50, "locality": 0.35},
    "moe-gpt2": {"r_cond": 0.35, "locality": 0.55},
}
