"""Token condensation (paper §V) — compatibility shim.

The condensation machinery is a first-class subsystem now:
:mod:`repro.condense` (DESIGN.md §10) owns the similarity-backend
registry (``repro.condense.backends``), the :class:`CondensePlan`
lifecycle (``repro.condense.plan``) and the deduplicated hier wire
format (``repro.condense.wire``). This module re-exports the historical
names so existing imports (``repro.core.condensation``) keep working;
new code should import from :mod:`repro.condense`.
"""
from __future__ import annotations

from repro.condense.backends import (available_similarity_backends,
                                     expected_measured_pairs,
                                     fast_similarity, get_similarity_backend,
                                     lsh_codes, pairwise_cosine,
                                     register_similarity_backend)
from repro.condense.plan import (CondenseOutput, _components_and_reps,
                                 adaptive_threshold, condense_tokens,
                                 pick_rate_bucket, similarity_quantiles,
                                 uncondense)

__all__ = [
    "CondenseOutput", "_components_and_reps", "adaptive_threshold",
    "available_similarity_backends", "condense_tokens",
    "expected_measured_pairs", "fast_similarity",
    "get_similarity_backend", "lsh_codes", "pairwise_cosine",
    "pick_rate_bucket", "register_similarity_backend",
    "similarity_quantiles", "uncondense",
]
