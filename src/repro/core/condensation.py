"""Token condensation (paper §V), TPU-adapted.

The paper builds a DGL similarity graph over all tokens headed to the same
expert and keeps one representative per connected component. Dynamic
graphs don't exist on TPU, so we adapt (see DESIGN.md §3):

* tokens are processed in fixed *condensation groups* of ``G`` tokens
  (consecutive tokens of the local shard) — similarity is a blocked
  ``[G, G]`` problem that maps onto the MXU (Pallas kernel in
  ``repro.kernels.similarity``);
* §V-A's skip rules become masks: (1) different primary expert ⇒ 0;
  (2) previous-block similarity ``s_prev > S1`` ⇒ 1, ``< S2`` ⇒ 0;
  only the uncertain remainder is actually measured (and on TPU the
  measurement is a masked matmul — the *win* of the skip rules is the
  smaller uncertain-tile count, which the Pallas kernel exploits with
  tile-level early-out);
* connected components + highest-degree representative (§V-B) become
  ``ceil(log2(G))`` rounds of vectorized min-label propagation;
* the adaptive threshold (Eq. 2) is computed from the running loss and
  additionally quantized to a *rate bucket* that selects a compiled
  executable with capacity ``C' = ceil(C·(1−rate))`` — that is how the
  traffic reduction becomes real under XLA's static collectives.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class CondenseOutput(NamedTuple):
    rep_idx: jnp.ndarray      # [T] int32 — each token's representative (global)
    is_rep: jnp.ndarray       # [T] bool — True if token represents itself
    sim: jnp.ndarray          # [n_groups, G, G] f32 — similarity (for s_prev)
    rate: jnp.ndarray         # [] f32 — fraction of tokens condensed


def adaptive_threshold(l_ini, l_prev):
    """Paper Eq. (2): h_t = 1 / (1 + exp(l_norm))."""
    l_norm = (l_ini - l_prev) / jnp.maximum(l_ini, 1e-9)
    return 1.0 / (1.0 + jnp.exp(l_norm))


def pick_rate_bucket(threshold: float, sim_quantiles, buckets) -> int:
    """Host-side: choose the largest bucket whose condensable fraction
    (estimated from observed similarity quantiles) is supportable.

    sim_quantiles: callable q -> similarity value at quantile q, or an
    array of per-decile similarity values (len 11, deciles 0..100%).
    """
    import numpy as np
    q = np.asarray(sim_quantiles, dtype=np.float64)
    # fraction of pairs with similarity above threshold
    frac = float(np.mean(q >= threshold))
    best = 0
    for i, b in enumerate(buckets):
        if b <= frac + 1e-9:
            best = i
    return best


def pairwise_cosine(x, eps: float = 1e-8):
    """[G, d] -> [G, G] normalized cosine similarity in [0, 1]."""
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.sum(xf * xf, -1, keepdims=True) + eps)
    c = n @ n.T                      # [-1, 1]
    return (c + 1.0) * 0.5           # paper uses normalized cosine in [0,1]


def fast_similarity(x_group, expert_group, s_prev, s1: float, s2: float,
                    use_kernel: bool = False):
    """§V-A fast similarity for one group.

    x_group: [G, d]; expert_group: [G] primary expert ids;
    s_prev: [G, G] similarity from the previous block (or None).
    Returns (sim [G,G], measured_frac []).
    """
    G = x_group.shape[0]
    same_expert = expert_group[:, None] == expert_group[None, :]
    if s_prev is not None:
        known_hi = s_prev > s1
        known_lo = s_prev < s2
        uncertain = same_expert & ~known_hi & ~known_lo
    else:
        known_hi = jnp.zeros((G, G), bool)
        known_lo = jnp.zeros((G, G), bool)
        uncertain = same_expert
    if use_kernel:
        from repro.kernels import ops as kops
        cos = kops.masked_similarity(x_group, uncertain)
    else:
        cos = pairwise_cosine(x_group)
    sim = jnp.where(uncertain, cos, 0.0)
    sim = jnp.where(known_hi & same_expert, 1.0, sim)
    sim = jnp.where(~same_expert, 0.0, sim)
    measured = jnp.mean(uncertain.astype(jnp.float32))
    return sim, measured


def _components_and_reps(adj):
    """adj: [G, G] bool symmetric (no self loops needed). Returns rep [G]
    int32 — the index each node condenses to (highest-degree node of its
    connected component; §V-B).
    """
    G = adj.shape[0]
    idx = jnp.arange(G, dtype=jnp.int32)
    adj = adj | jnp.eye(G, dtype=bool)
    labels = idx
    # min-label propagation; diameter <= G but log2 rounds of
    # squaring-style propagation converge for the clustered graphs we see.
    n_iter = max(1, math.ceil(math.log2(G)) + 1)
    for _ in range(n_iter):
        neigh_min = jnp.min(jnp.where(adj, labels[None, :], G), axis=1)
        labels = jnp.minimum(labels, neigh_min.astype(jnp.int32))
        # propagate through current labels too (pointer jumping)
        labels = labels[labels]
    degree = jnp.sum(adj, axis=1).astype(jnp.int32)
    # highest degree in component, ties -> smallest index
    score = degree * G + (G - 1 - idx)               # larger is better
    same = labels[:, None] == labels[None, :]
    comp_scores = jnp.where(same, score[None, :], -1)
    rep = jnp.argmax(comp_scores, axis=1).astype(jnp.int32)
    return rep


def condense_tokens(x, primary_expert, threshold, *, group_size: int,
                    s_prev: Optional[jnp.ndarray] = None,
                    s1: float = 0.8, s2: float = 0.2,
                    use_kernel: bool = False) -> CondenseOutput:
    """Condense local tokens (paper §V).

    x: [T, d] token embeddings (router input); primary_expert: [T];
    threshold: scalar in [0,1] (runtime value — Eq. 2 or static);
    s_prev: [n_groups, G, G] similarity carried from the previous block.

    Returns global rep_idx over [T].
    """
    T, d = x.shape
    G = group_size
    assert T % G == 0, (T, G)
    n_groups = T // G
    xg = x.reshape(n_groups, G, d)
    eg = primary_expert.reshape(n_groups, G)

    def per_group(xb, ebb, spb):
        sim, measured = fast_similarity(xb, ebb, spb, s1, s2,
                                        use_kernel=use_kernel)
        adj = (sim >= threshold) & ~jnp.eye(G, dtype=bool)
        rep = _components_and_reps(adj)
        return sim, rep, measured

    if s_prev is None:
        sims, reps, measured = jax.vmap(
            lambda a, b: per_group(a, b, None))(xg, eg)
    else:
        sims, reps, measured = jax.vmap(per_group)(
            xg, eg, s_prev.astype(jnp.float32))

    offsets = (jnp.arange(n_groups, dtype=jnp.int32) * G)[:, None]
    rep_idx = (reps + offsets).reshape(T)
    is_rep = rep_idx == jnp.arange(T, dtype=jnp.int32)
    rate = 1.0 - jnp.mean(is_rep.astype(jnp.float32))
    return CondenseOutput(rep_idx, is_rep, sims, rate)


def uncondense(y, rep_idx):
    """y: [T, d] MoE outputs (garbage at condensed rows); copy each
    condensed token's value from its representative (token_to_token)."""
    return jnp.take(y, rep_idx, axis=0)


def similarity_quantiles(sim, expert_idx=None, same_expert_only: bool = True):
    """Decile values of the off-diagonal similarity distribution (host
    stats for bucket selection / Fig. 5).

    sim: [..., G, G] similarity; expert_idx: [..., G] primary expert ids,
    required when ``same_expert_only`` — only off-diagonal same-expert
    pairs (the pairs condensation can actually merge) enter the
    distribution, not the mostly-zero full matrix. Host-side numpy (the
    selection size is data-dependent, so this is not traceable); returns
    the 11 decile values ``pick_rate_bucket`` consumes.
    """
    import numpy as np
    s = np.asarray(sim, np.float64)
    G = s.shape[-1]
    s = s.reshape(-1, s.shape[-2], G)
    off_diag = ~np.eye(G, dtype=bool)
    if same_expert_only:
        if expert_idx is None:
            raise ValueError(
                "same_expert_only=True needs expert_idx to identify "
                "same-expert pairs (or pass same_expert_only=False)")
        e = np.asarray(expert_idx).reshape(-1, G)
        mask = (e[:, :, None] == e[:, None, :]) & off_diag[None]
    else:
        mask = np.broadcast_to(off_diag[None], s.shape)
    vals = s[mask]
    if vals.size == 0:
        vals = np.zeros((1,), np.float64)
    return np.quantile(vals, np.linspace(0.0, 1.0, 11))
