"""Single-device reference MoE (oracle for tests).

Computes the exact mathematical semantics of the expert-parallel layer —
``y_t = x_t + Σ_k g_k · FFN_{e_k}(norm(x_t))`` (+shared experts) — with
no capacity limit, no dispatch buffers, no collectives. The shard_map
implementation in ``moe_layer.py`` must match this bitwise-closely when
capacity is ample and LUFFY is off; with condensation on, the oracle
applies the paper's replacement semantics directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import LuffyConfig, ModelConfig
from repro.core import condensation as cond
from repro.core.gating import gate_apply
from repro.core.moe_layer import _rms
from repro.models import blocks as bk


def dense_moe_reference(params, x, cfg: ModelConfig, *,
                        rep_idx=None):
    """x: [T, d] tokens. Returns (y [T,d], aux_loss).

    If rep_idx is given (condensation), output rows are replaced by their
    representative's output (token_to_token semantics, paper §VI)."""
    m = cfg.moe
    cdt = bk._dtype(cfg.compute_dtype)
    act = bk._act(cfg.act)
    xn = _rms(x.reshape(-1, cfg.d_model), params["norm"]["scale"]).astype(cdt)
    gate = gate_apply(params["router"], xn, m.top_k)
    ew = params["experts"]

    def per_expert(e):
        up = xn @ ew["w_up"][e].astype(cdt)
        gt = xn @ ew["w_gate"][e].astype(cdt)
        return (act(gt) * up) @ ew["w_down"][e].astype(cdt)   # [T, d]

    all_out = jnp.stack([per_expert(e) for e in range(m.num_experts)])
    picked = all_out[gate.expert_idx.T, jnp.arange(x.shape[0])[None]]  # [k,T,d]
    delta = jnp.sum(picked * gate.gate_weights.T[..., None].astype(cdt),
                    axis=0)
    y = x + delta.astype(x.dtype)
    if rep_idx is not None:
        y = cond.uncondense(y, rep_idx)
    if "shared" in params:
        sh = params["shared"]
        # each token's shared-expert path uses its OWN x (vanilla semantics
        # in moe_core: shared output is added after un-condensation)
        xn2 = _rms(x, params["norm"]["scale"]).astype(cdt)
        up = xn2 @ sh["w_up"].astype(cdt)
        gt = xn2 @ sh["w_gate"].astype(cdt)
        y = y + ((act(gt) * up) @ sh["w_down"].astype(cdt)).astype(y.dtype)
    return y, gate.aux_loss
