"""Top-k gating for expert-parallel MoE (GShard-style, capacity-bounded).

The gate runs per device on the local token slice. Outputs feed the
dispatch logic in :mod:`repro.core.moe_layer`; the load-balance auxiliary
loss follows Switch/GShard.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class GateOutput(NamedTuple):
    expert_idx: jnp.ndarray   # [T, k] int32 — chosen experts per token
    gate_weights: jnp.ndarray  # [T, k] f32 — combine weights (softmaxed)
    aux_loss: jnp.ndarray     # [] f32 — load-balance loss
    router_probs: jnp.ndarray  # [T, E] f32 — full softmax (for stats)


def gate_init(key, d_model: int, num_experts: int, dtype=jnp.float32):
    return {"w_gate": (jax.random.normal(key, (d_model, num_experts))
                       * (1.0 / jnp.sqrt(d_model))).astype(dtype)}


def gate_apply(params, x, top_k: int, *, jitter: float = 0.0,
               rng=None) -> GateOutput:
    """x: [T, d] (normed token embeddings). Returns routing decisions."""
    logits = x.astype(jnp.float32) @ params["w_gate"].astype(jnp.float32)
    if jitter > 0.0 and rng is not None:
        logits += jax.random.uniform(rng, logits.shape, minval=-jitter,
                                     maxval=jitter)
    probs = jax.nn.softmax(logits, axis=-1)                       # [T,E]
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)           # [T,k]
    # renormalize the selected gates (standard top-k MoE)
    gate_weights = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    # Switch-style load balance: E * sum_e f_e * p_e
    num_experts = probs.shape[-1]
    top1 = expert_idx[:, 0]
    f = jnp.mean(jax.nn.one_hot(top1, num_experts, dtype=jnp.float32), axis=0)
    p = jnp.mean(probs, axis=0)
    aux = num_experts * jnp.sum(f * p)
    return GateOutput(expert_idx.astype(jnp.int32), gate_weights, aux, probs)


def dispatch_positions(expert_idx, keep_mask, num_experts: int):
    """Per-(token,k) position within its expert's buffer.

    expert_idx: [T,k]; keep_mask: [T,k] bool (False = condensed/invalid —
    takes no buffer slot). Returns positions [T,k] int32 (position among
    kept rows of the same expert, in (k-major, token-minor) priority order
    so primary copies pack first and survive capacity drops longest).
    """
    T, k = expert_idx.shape
    # priority order: all k=0 rows first (they carry the residual), then k=1…
    flat_e = expert_idx.T.reshape(-1)                 # [k*T] k-major
    flat_keep = keep_mask.T.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)
    onehot = onehot * flat_keep[:, None].astype(jnp.int32)
    pos_flat = jnp.cumsum(onehot, axis=0) - onehot    # position among same-e
    pos_flat = jnp.take_along_axis(pos_flat, flat_e[:, None], axis=1)[:, 0]
    return pos_flat.reshape(k, T).T.astype(jnp.int32)  # [T,k]


def expert_load(expert_idx, keep_mask, num_experts: int):
    """Tokens per expert (kept rows only). [E] int32."""
    onehot = jax.nn.one_hot(expert_idx, num_experts, dtype=jnp.int32)
    onehot = onehot * keep_mask[..., None].astype(jnp.int32)
    return jnp.sum(onehot, axis=(0, 1))
