"""Sequence migration (paper §IV): Algorithm 1 + the attention cost model.

The migration plan is a **bijection on global sequence slots**: slot
``i`` (one sequence) is re-homed to device ``assign[i]`` with a dest-local
slot number. The plan is executed inside the MoE combine all-to-all by
relabeling chunk destinations (see ``moe_layer.py`` and DESIGN.md §3) —
the collective's operand size is unchanged; what changes is how much of
it lands on the diagonal (stays off the network).

Topology awareness (DESIGN.md §5): both planners take an optional
``link_cost`` matrix (``repro.comm.Topology.link_cost()``) and minimize
*link-cost-weighted* traffic — a byte crossing nodes costs ``bw_ratio×``
a byte crossing NVLink/ICI, so the greedy prefers intra-node re-homes.
With no matrix (or a uniform one) both planners reproduce their
historical behavior exactly.

Two implementations, kept in lock-step by a property test:
  * :func:`plan_migration_np` — paper-faithful host-side Algorithm 1;
  * :func:`plan_migration_jax` — traceable device-side version used
    inside the compiled train step (the "controller" of §VI becomes a
    replicated on-device computation — no host round-trip).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Cost model (paper Eq. 1)
# ---------------------------------------------------------------------------

def t_att(B, L, d: int, speed: float):
    """Attention cost model: (3BLd^2 + 2BL^2d) / P   [seconds].

    Pure arithmetic on purpose: the host planner calls it with python /
    numpy scalars, the traced planner with jax arrays, and both must see
    the same float32-exact values — so no framework coercion here, just
    a float promotion that keeps int inputs from overflowing.
    """
    B = B * 1.0
    L = L * 1.0
    return (3.0 * B * L * d * d + 2.0 * B * L * L * d) / speed


class MigrationPlan(NamedTuple):
    assign: jnp.ndarray       # [n_slots] int32 — dest device per global slot
    dest_slot: jnp.ndarray    # [n_slots] int32 — slot index on dest device
    perm: jnp.ndarray         # [n_slots] int32 — new_global = perm[old_global]
    traffic_before: jnp.ndarray  # [] f32 — link-cost-weighted combine rows
    traffic_after: jnp.ndarray   # crossing devices without / with migration


def _uniform_cost(M: int, xp):
    return xp.ones((M, M), xp.float32 if xp is jnp else np.float64) \
        - xp.eye(M, dtype=xp.float32 if xp is jnp else np.float64)


def _weighted_traffic(counts, dest, cost, xp):
    """sum_i sum_m counts[i, m] * cost[m, dest[i]] (numpy/jnp agnostic)."""
    per_dev_cost = xp.take(cost, dest, axis=1).T          # [n_slots, M]
    return (counts * per_dev_cost).sum()


def _finalize_plan(assign, counts, n_per_dev, link_cost=None):
    """Common: dest-local slot numbers + traffic ledger; falls back to the
    identity placement when the greedy plan would move MORE (weighted)
    bytes than no migration at all (possible under adversarial capacity
    pressure — the identity is always feasible, so never do worse).
    numpy/jnp agnostic."""
    xp = jnp if isinstance(assign, jnp.ndarray) else np
    n_slots, M = counts.shape
    cost = _uniform_cost(M, xp) if link_cost is None else link_cost
    home = (xp.arange(n_slots) // n_per_dev).astype(assign.dtype)
    traffic_before = _weighted_traffic(counts, home, cost, xp)
    traffic_after = _weighted_traffic(counts, assign, cost, xp)
    if isinstance(assign, jnp.ndarray):
        worse = traffic_after > traffic_before
        assign = xp.where(worse, home, assign)
        traffic_after = xp.where(worse, traffic_before, traffic_after)
    elif float(traffic_after) > float(traffic_before):
        assign = home
        traffic_after = traffic_before
    # dest-local slot = rank among slots with same dest (stable by index)
    onehot = (assign[:, None] == xp.arange(M)[None, :]).astype(xp.int32)
    rank = onehot.cumsum(axis=0) - onehot
    dest_slot = rank[xp.arange(n_slots), assign]
    perm = assign * n_per_dev + dest_slot
    return (assign.astype(xp.int32), dest_slot.astype(xp.int32),
            perm.astype(xp.int32),
            traffic_before.astype(xp.float32),
            traffic_after.astype(xp.float32))


# ---------------------------------------------------------------------------
# Paper-faithful numpy Algorithm 1
# ---------------------------------------------------------------------------

def plan_migration_np(counts: np.ndarray, seq_lens: np.ndarray,
                      n_per_dev: int, *, q: int = 3, d_model: int = 1024,
                      speed: float = 1e13,
                      link_cost: Optional[np.ndarray] = None
                      ) -> MigrationPlan:
    """counts: [n_slots, M] tokens (expert copies) of slot i hosted on
    device j; seq_lens: [n_slots] true lengths. Every device ends with
    exactly ``n_per_dev`` slots (the SPMD capacity constraint).
    link_cost: optional [M, M] per-byte cost (Topology.link_cost())."""
    counts = np.asarray(counts)
    seq_lens = np.asarray(seq_lens)
    n_slots, M = counts.shape
    cost = _uniform_cost(M, np) if link_cost is None \
        else np.asarray(link_cost, np.float64)
    cap = np.full(M, n_per_dev, np.int64)
    dev_B = np.zeros(M, np.int64)        # sequences placed per device
    dev_L = np.zeros(M, np.int64)        # max length placed per device
    assign = np.full(n_slots, -1, np.int64)
    # migrate longer sequences first (they dominate T_att)
    order = np.argsort(-seq_lens, kind="stable")
    for i in order:
        # step 1: link-cost-weighted traffic f_{i,j} if homed at j
        f = counts[i] @ cost
        cand = [int(j) for j in np.argsort(f, kind="stable")[:q]
                if cap[j] > 0]                        # step 2: top-q min traffic
        if not cand:                                  # fallback: most free capacity
            cand = [int(np.argmax(cap))]
        # steps 3-6: min growth of the attention cost model.
        # Beyond-paper tie-break: Eq. 1 is linear in B, so clustering
        # same-length sequences is growth-neutral to the greedy — prefer
        # devices whose current max length already covers this sequence
        # (zero added padding), which actively groups similar lengths.
        best, best_growth = cand[0], None
        for j in cand:
            newL = max(dev_L[j], seq_lens[i])
            growth = (t_att(dev_B[j] + 1, newL, d_model, speed)
                      - t_att(dev_B[j], dev_L[j], d_model, speed))
            growth -= 1e-5 * abs(growth) * float(dev_L[j] >= seq_lens[i])
            if best_growth is None or growth < best_growth - 1e-30:
                best, best_growth = j, growth
        assign[i] = best
        cap[best] -= 1
        dev_B[best] += 1
        dev_L[best] = max(dev_L[best], seq_lens[i])
    return MigrationPlan(*_finalize_plan(assign, counts, n_per_dev,
                                         link_cost=None if link_cost is None
                                         else cost))


# ---------------------------------------------------------------------------
# Traceable device-side Algorithm 1
# ---------------------------------------------------------------------------

def plan_migration_jax(counts, seq_lens, n_per_dev: int, *, q: int = 3,
                       d_model: int = 1024, speed: float = 1e13,
                       link_cost=None) -> MigrationPlan:
    """Same algorithm, jax-traceable (runs replicated inside the step)."""
    counts = jnp.asarray(counts, jnp.float32)
    seq_lens = jnp.asarray(seq_lens, jnp.float32)
    n_slots, M = counts.shape
    cost = _uniform_cost(M, jnp) if link_cost is None \
        else jnp.asarray(link_cost, jnp.float32)
    order = jnp.argsort(-seq_lens, stable=True)

    def body(state, i):
        cap, dev_B, dev_L, assign = state
        slot = order[i]
        f = counts[slot] @ cost                        # [M] weighted traffic
        # top-q by min traffic
        _, cand = jax.lax.top_k(-f, q)                 # [q]
        cand_ok = cap[cand] > 0
        L_i = seq_lens[slot].astype(jnp.float32)
        newL = jnp.maximum(dev_L[cand], L_i)
        growth = (t_att(dev_B[cand] + 1, newL, d_model, speed)
                  - t_att(dev_B[cand], dev_L[cand], d_model, speed))
        # padding-free tie-break (see plan_migration_np)
        growth = growth - 1e-5 * jnp.abs(growth) * (dev_L[cand] >= L_i)
        growth = jnp.where(cand_ok, growth, jnp.inf)
        pick_c = jnp.argmin(growth)
        picked = cand[pick_c]
        # fallback: least-loaded device with capacity (if all cands full)
        any_ok = jnp.any(cand_ok)
        fb = jnp.argmax(cap)                            # max remaining capacity
        j = jnp.where(any_ok, picked, fb).astype(jnp.int32)
        cap = cap.at[j].add(-1)
        dev_B = dev_B.at[j].add(1.0)
        dev_L = dev_L.at[j].max(L_i)
        assign = assign.at[slot].set(j)
        return (cap, dev_B, dev_L, assign), None

    # zero-couple the carry init to `counts` so it picks up the same
    # varying-manual-axes type when traced inside shard_map (scan carries
    # must have uniform vma in/out).
    zf = jnp.sum(counts) * 0.0
    zi = zf.astype(jnp.int32)
    init = (jnp.full((M,), n_per_dev, jnp.int32) + zi,
            jnp.zeros((M,), jnp.float32) + zf,
            jnp.zeros((M,), jnp.float32) + zf,
            jnp.full((n_slots,), -1, jnp.int32) + zi)
    (cap, dev_B, dev_L, assign), _ = jax.lax.scan(
        body, init, jnp.arange(n_slots))
    return MigrationPlan(*_finalize_plan(assign, counts, n_per_dev,
                                         link_cost=None if link_cost is None
                                         else cost))


def identity_plan(n_slots: int, n_per_dev: int) -> MigrationPlan:
    idx = jnp.arange(n_slots, dtype=jnp.int32)
    return MigrationPlan(idx // n_per_dev, idx % n_per_dev, idx,
                         jnp.float32(0), jnp.float32(0))


def home_plan(counts, n_per_dev: int, link_cost=None) -> MigrationPlan:
    """The keep-everything-home plan WITH the traffic ledger.

    Runs ``_finalize_plan`` on the identity assignment, so the returned
    record is bit-for-bit what the greedy planners return whenever their
    assignment equals the current placement (``traffic_before ==
    traffic_after``, identity ``perm``). The plan-reuse fast path
    (``repro.plan.exchange``) emits this instead of re-running the
    greedy when the routing signature revalidates. numpy/jnp agnostic.
    """
    xp = jnp if isinstance(counts, jnp.ndarray) else np
    n_slots = counts.shape[0]
    home = (xp.arange(n_slots) // n_per_dev).astype(xp.int32)
    return MigrationPlan(*_finalize_plan(home, counts, n_per_dev,
                                         link_cost=link_cost))
