"""Expert-parallel MoE layer with LUFFY's two techniques (paper §III-§V).

Runs *inside* ``jax.shard_map`` over the full mesh: batch axes shard
sequences, the ``model`` axis shards experts. Per device this module sees

    x_local      [n_seq, S, d]     — this device's sequence slots
    experts      [E_local, ...]    — this device's expert shard

and performs: gate → (condense §V) → dispatch all-to-all → expert FFN →
(migrate §IV) combine all-to-all → un-condense.

Key TPU adaptations (DESIGN.md §3):

* **Condensation** shrinks the *static* expert capacity ``C`` by the rate
  bucket; non-representative tokens take no dispatch slot, so the
  all-to-all operand itself is smaller.
* **Migration** is a bijection on global sequence slots, planned from the
  router output *before* dispatch (device-side Algorithm 1, replicated
  within each model row). The dispatch payload carries the *pre-norm*
  residual ``x``; expert devices compute ``norm→FFN→gate·y (+ residual on
  the primary copy)`` and address combine rows to the token's **new**
  home. The combine collective has the same operand size as vanilla —
  the migration win is the larger diagonal (local) fraction, which never
  crosses ICI links. Reported via the locality ledger in ``aux``.
* Capacity overflow drops rows exactly like GShard; primary (residual-
  carrying) rows are packed first so they survive longest. Drop rates are
  reported in ``aux``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm import CommContext, compat
from repro.comm import ledger as comm_ledger
from repro.config import LuffyConfig, MoEConfig, ModelConfig
from repro.core import condensation as cond
from repro.core import migration as mig
from repro.core.gating import dispatch_positions, gate_apply, gate_init
from repro.sched import plan_chunks, run_pipeline

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig):
    """Global expert stack [E, ...] (sharded over 'model' outside)."""
    from repro.models.blocks import dense_init, _dtype
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff, m.num_experts
    pdt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    scale_down = 1.0 / math.sqrt(2 * cfg.num_layers)
    p = {
        "router": gate_init(ks[0], d, E),
        "experts": {
            "w_up": (jax.random.normal(ks[1], (E, d, f)) / math.sqrt(d)).astype(pdt),
            "w_gate": (jax.random.normal(ks[2], (E, d, f)) / math.sqrt(d)).astype(pdt),
            "w_down": (jax.random.normal(ks[3], (E, f, d)) * scale_down
                       / math.sqrt(f)).astype(pdt),
        },
        "norm": {"scale": jnp.ones((d,), pdt)},
    }
    if m.num_shared_experts > 0:
        fs = f * m.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_up": (jax.random.normal(k1, (d, fs)) / math.sqrt(d)).astype(pdt),
            "w_gate": (jax.random.normal(k2, (d, fs)) / math.sqrt(d)).astype(pdt),
            "w_down": (jax.random.normal(k3, (fs, d)) * scale_down
                       / math.sqrt(fs)).astype(pdt),
        }
    return p


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    v = jnp.mean(xf * xf, -1, keepdims=True)
    return (xf * jax.lax.rsqrt(v + eps) * scale.astype(jnp.float32))


def expert_ffn(ew, h, act, compute_dtype, use_kernel: bool = False):
    """h: [E_local, R, d] normed inputs -> [E_local, R, d]."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.expert_ffn(h, ew["w_up"], ew["w_gate"], ew["w_down"], act)
    cdt = compute_dtype
    hc = h.astype(cdt)
    up = jnp.einsum("erd,edf->erf", hc, ew["w_up"].astype(cdt))
    gt = jnp.einsum("erd,edf->erf", hc, ew["w_gate"].astype(cdt))
    hh = act(gt) * up
    return jnp.einsum("erf,efd->erd", hh, ew["w_down"].astype(cdt))


def capacity_for(moe: MoEConfig, tokens_local: int, num_experts: int,
                 rate: float = 0.0, slack: float = None) -> int:
    """Static per-(source, expert) capacity, condensation-bucket scaled."""
    cf = slack if slack is not None else moe.capacity_factor
    c = int(math.ceil(cf * tokens_local * moe.top_k * (1.0 - rate)
                      / num_experts))
    return max(8, ((c + 7) // 8) * 8)


class MoEAux(NamedTuple):
    aux_loss: Array
    dispatch_drop: Array      # fraction of kept rows dropped at dispatch
    combine_drop: Array       # fraction of rows dropped at combine regroup
    condense_rate: Array      # fraction of tokens condensed
    local_frac: Array         # fraction of combine rows staying on-device
    traffic_before: Array     # plan ledger (link-cost-weighted tokens
    traffic_after: Array      # crossing devices, without/with migration)
    inter_bytes_flat: Array   # dispatch bytes a flat a2a ships across nodes
    inter_bytes_dedup: Array  # modeled bytes after per-node dedup (hier
                              # mode; the executed wire is still dense)

N_AUX = len(MoEAux._fields)


def expert_ffn_2d(ew_local, h, act, cdt, fsdp_axes,
                  batch_sharded: bool = True):
    """Megatron-style expert FFN over the FSDP axes (decode path):

    weights are F-sharded (w_up/w_gate on dim 2, w_down on dim 1 — their
    stored layout, so NO weight resharding happens); the tiny decode
    activation rows are all-gathered, each rank computes its F-slice of
    the hidden, and the output partial-sums reduce-scatter back to each
    rank's own rows. Wire per layer ≈ 2×rows-size instead of the full
    expert weights (llama4 decode: ~20 MB vs ~2 GB; EXPERIMENTS.md §Perf).

    batch_sharded=False (long_500k: B=1 replicated over the fsdp axes):
    skip the gather/scatter — every rank holds the same rows, computes
    its F-slice partial, and a single psum yields the replicated output.
    """
    hc = h.astype(cdt)
    if batch_sharded:
        h_g = jax.lax.all_gather(hc, fsdp_axes, axis=1, tiled=True)
    else:
        h_g = hc
    up = jnp.einsum("erd,edf->erf", h_g, ew_local["w_up"].astype(cdt))
    gt = jnp.einsum("erd,edf->erf", h_g, ew_local["w_gate"].astype(cdt))
    hh = act(gt) * up                       # [E_l, R(_all), F_local]
    part = jnp.einsum("erf,efd->erd", hh,
                      ew_local["w_down"].astype(cdt))
    if batch_sharded:
        # reduce over F shards + scatter rows back to their owners
        return jax.lax.psum_scatter(part, fsdp_axes, scatter_dimension=1,
                                    tiled=True)
    return jax.lax.psum(part, fsdp_axes)


def moe_decode_allreduce(params, x, cfg: ModelConfig, *, capacity: int,
                         axis_name, use_kernel: bool = False,
                         fsdp_axes=None, batch_sharded: bool = True):
    """Decode-time expert parallelism via all-reduce (no all-to-all).

    At decode there is ONE token per sequence — the dispatch operand would
    be tiny and the token dim (S=1) cannot shard over the model axis. So
    tokens stay replicated across the model axis; each rank runs only its
    LOCAL experts on the tokens routed to them and the partial outputs are
    psum'd. Collective = one [B,1,d] all-reduce per layer.
    Returns (y, aux)."""
    from repro.models.blocks import _act, _dtype
    m = cfg.moe
    cdt = _dtype(cfg.compute_dtype)
    act = _act(cfg.act)
    n_seq, S, d = x.shape
    T = n_seq * S
    E = m.num_experts
    M = 1 if axis_name is None else compat.axis_size(axis_name)
    E_local = E // M
    my = 0 if axis_name is None else compat.axis_index(axis_name)
    C = capacity

    xf = x.reshape(T, d)
    xn = _rms(xf, params["norm"]["scale"]).astype(cdt)
    gate = gate_apply(params["router"], xn, m.top_k)
    lo = my * E_local
    local_e = gate.expert_idx - lo
    keep = (local_e >= 0) & (local_e < E_local)
    local_e = jnp.clip(local_e, 0, E_local - 1)
    pos = dispatch_positions(local_e, keep, E_local)
    valid = keep & (pos < C)
    e_safe = jnp.where(valid, local_e, 0).reshape(-1)
    p_safe = jnp.where(valid, pos, 0).reshape(-1)
    v_f = valid.reshape(-1)
    rows_in = jnp.zeros((E_local, C, d), cdt).at[e_safe, p_safe].add(
        jnp.tile(xn[:, None], (1, m.top_k, 1)).reshape(-1, d)
        * v_f[:, None].astype(cdt), mode="drop")
    if fsdp_axes:
        y_rows = expert_ffn_2d(params["experts"], rows_in, act, cdt,
                               fsdp_axes, batch_sharded=batch_sharded)
    else:
        y_rows = expert_ffn(params["experts"], rows_in, act, cdt,
                            use_kernel=use_kernel)
    vals = y_rows[e_safe, p_safe] * v_f[:, None].astype(cdt)
    vals = vals * gate.gate_weights.reshape(-1, 1).astype(cdt)
    delta = jnp.sum(vals.reshape(T, m.top_k, d), axis=1)
    if axis_name is not None:
        delta = jax.lax.psum(delta, axis_name)
    y = (xf + delta.astype(xf.dtype)).reshape(n_seq, S, d)
    if "shared" in params:
        from repro.models.blocks import ffn_apply
        sh = ffn_apply(params["shared"], cfg,
                       _rms(x, params["norm"]["scale"]).astype(cdt))
        y = y + sh.astype(y.dtype)
    kept = jnp.sum(keep.astype(jnp.float32))
    d_drop = 1.0 - jnp.sum(valid.astype(jnp.float32)) / jnp.maximum(kept, 1.0)
    aux = MoEAux(gate.aux_loss, d_drop, jnp.float32(0.0), jnp.float32(0.0),
                 jnp.float32(1.0 / max(M, 1)), jnp.float32(0.0),
                 jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0))
    return y, aux


# ---------------------------------------------------------------------------
# The per-device core
# ---------------------------------------------------------------------------

def moe_core(params, x, sideband: Dict[str, Array], cfg: ModelConfig,
             luffy: LuffyConfig, *, mode: str, capacity: int,
             axis_name=None, threshold=None,
             s_prev: Optional[Array] = None,
             group_size: int = 128, combine_slack: float = 1.0,
             use_kernel: bool = False,
             comm: Optional[CommContext] = None
             ) -> Tuple[Array, Dict[str, Array], Optional[Array], MoEAux]:
    """One MoE sublayer on this device's shard.

    x: [n_seq, S, d] pre-norm hidden. sideband: {"labels":[n_seq,S],
    "seq_len":[n_seq]} — travels with sequences under migration.
    mode: "vanilla" | "migrate". Condensation is on iff s_prev is not None
    or luffy.enable_condensation and mode != decode-style call.
    comm: collective strategy + topology (repro.comm); when None a flat
    context over ``axis_name`` is assumed (historical behavior).
    Returns (y, new_sideband, s_next, aux). In vanilla mode
    ``y = x + moe_delta``; in migrate mode ``y`` is the full post-block
    hidden materialized at *new* slots.
    """
    from repro.models.blocks import _act, _dtype
    m = cfg.moe
    cdt = _dtype(cfg.compute_dtype)
    act = _act(cfg.act)
    n_seq, S, d = x.shape
    T = n_seq * S
    E = m.num_experts
    if comm is None and axis_name is not None:
        comm = CommContext.build("flat", axis_name)
    M = 1 if comm is None else comm.size()
    assert E % M == 0, (E, M)
    E_local = E // M
    my = 0 if comm is None else comm.index()
    C = capacity

    xf = x.reshape(T, d)
    xn = _rms(xf, params["norm"]["scale"]).astype(cdt)
    gate = gate_apply(params["router"], xn, m.top_k)
    expert_idx, gate_w = gate.expert_idx, gate.gate_weights   # [T,k]

    # token validity (length padding)
    pos_in_seq = jnp.tile(jnp.arange(S, dtype=jnp.int32)[None], (n_seq, 1))
    token_valid = (pos_in_seq < sideband["seq_len"][:, None]).reshape(T)
    keep = jnp.tile(token_valid[:, None], (1, m.top_k))

    # ---- token condensation (§V) ----------------------------------------
    do_condense = luffy.enable_condensation and mode != "decode"
    if do_condense:
        co = cond.condense_tokens(
            xn, expert_idx[:, 0], threshold, group_size=group_size,
            s_prev=(None if s_prev is None
                    else s_prev.reshape(-1, group_size, group_size)),
            s1=luffy.s1, s2=luffy.s2, use_kernel=use_kernel)
        keep = keep & co.is_rep[:, None]
        rep_idx, s_next = co.rep_idx, co.sim
        c_rate = co.rate
    else:
        rep_idx = jnp.arange(T, dtype=jnp.int32)
        s_next, c_rate = None, jnp.float32(0.0)

    # ---- dispatch positions & drops --------------------------------------
    pos = dispatch_positions(expert_idx, keep, E)             # [T,k]
    valid = keep & (pos < C)
    kept = jnp.sum(keep.astype(jnp.float32))
    d_drop = 1.0 - jnp.sum(valid.astype(jnp.float32)) / jnp.maximum(kept, 1.0)

    # ---- inter-node traffic ledger (DESIGN.md §5) ------------------------
    topo = None if comm is None else comm.topology
    if topo is not None and topo.hierarchical and M > 1:
        row_bytes = float((d + 2) * jnp.dtype(cdt).itemsize)
        ib_flat, ib_dedup = comm_ledger.dispatch_node_ledger(
            expert_idx, valid, my, e_local=E_local, topo=topo,
            row_bytes=row_bytes)
        if comm.mode != "hier":
            ib_dedup = ib_flat      # the flat path ships every copy
    else:
        ib_flat = ib_dedup = jnp.float32(0.0)

    # ---- migration plan (§IV) — BEFORE dispatch so combine can be
    # re-addressed. Replicated within the model row. -----------------------
    migrate = (mode == "migrate") and luffy.enable_migration and M > 1
    if migrate:
        dev_of_e = expert_idx // E_local                      # [T,k]
        oh = jax.nn.one_hot(dev_of_e, M, dtype=jnp.float32) \
            * valid[..., None].astype(jnp.float32)
        counts_local = oh.reshape(n_seq, S, m.top_k, M).sum((1, 2))  # [n_seq,M]
        counts_g = jax.lax.all_gather(counts_local, comm.axis_name, axis=0,
                                      tiled=True)             # [M*n_seq, M]
        lens_g = jax.lax.all_gather(sideband["seq_len"], comm.axis_name,
                                    axis=0, tiled=True)       # [M*n_seq]
        plan = mig.plan_migration_jax(
            counts_g, lens_g.astype(jnp.float32), n_seq, q=luffy.q,
            d_model=d, speed=luffy.gpu_speed,
            link_cost=comm.link_cost())
        my_slots = my * n_seq + jnp.arange(n_seq, dtype=jnp.int32)
        dest_global = plan.perm[my_slots]                     # [n_seq]
        t_before, t_after = plan.traffic_before, plan.traffic_after
    else:
        dest_global = my * n_seq + jnp.arange(n_seq, dtype=jnp.int32)
        t_before = t_after = jnp.float32(0.0)

    # ---- build dispatch buffers ------------------------------------------
    # payload row: [x_raw(d), gate_w, is_primary]; meta: (dest_slot+1, pos)
    is_primary = (jnp.arange(m.top_k) == 0)[None, :]          # [1,k]
    tok_slot = jnp.tile((jnp.arange(T, dtype=jnp.int32) // S)[:, None],
                        (1, m.top_k))                         # local seq slot
    tok_pos = jnp.tile((jnp.arange(T, dtype=jnp.int32) % S)[:, None],
                       (1, m.top_k))
    dest_of_tok = dest_global[tok_slot]                       # [T,k]

    e_f = expert_idx.reshape(-1)
    p_f = pos.reshape(-1)
    v_f = valid.reshape(-1)
    payload = jnp.concatenate([
        jnp.tile(xf.astype(cdt)[:, None], (1, m.top_k, 1)),
        gate_w[..., None].astype(cdt),
        jnp.broadcast_to(is_primary, (T, m.top_k))[..., None].astype(cdt),
    ], axis=-1).reshape(-1, d + 2)                            # [T*k, d+2]
    meta = jnp.stack([dest_of_tok + 1, tok_pos], -1).reshape(-1, 2)

    buf = jnp.zeros((E, C, d + 2), cdt)
    mbuf = jnp.zeros((E, C, 2), jnp.int32)
    p_safe = jnp.where(v_f, p_f, 0)
    e_safe = jnp.where(v_f, e_f, 0)
    buf = buf.at[e_safe, p_safe].add(
        payload * v_f[:, None].astype(cdt), mode="drop")
    mbuf = mbuf.at[e_safe, p_safe].add(
        meta * v_f[:, None].astype(jnp.int32), mode="drop")

    # ---- dispatch → expert FFN → (vanilla) combine ------------------------
    # exec_mode="pipeline" chunks the static capacity dim and runs the
    # repro.sched software pipeline: chunk k's collective is issued before
    # chunk k-1's FFN result is consumed (DESIGN.md §6). Bit-identical to
    # "sync": capacity slicing commutes with the data-movement-only
    # collectives and the row-wise FFN, and chunk results are reassembled
    # in the sync layout before any order-sensitive step (the migrate-mode
    # regroup sorts across ALL rows, so it stays a post-pipeline barrier).
    def _ffn_rows(rows_k):
        """rows_k: [E_local, M, Ck, d+2] -> (out, prim) same leading dims."""
        xr = rows_k[..., :d]
        gw = rows_k[..., d:d + 1]
        prim_k = rows_k[..., d + 1:d + 2]
        ck = rows_k.shape[2]
        h = _rms(xr, params["norm"]["scale"]).astype(cdt)
        y = expert_ffn(params["experts"], h.reshape(E_local, M * ck, d),
                       act, cdt, use_kernel=use_kernel) \
            .reshape(E_local, M, ck, d)
        out_k = y * gw
        if migrate:
            out_k = out_k + xr * prim_k    # primary copy carries residual
        return out_k, prim_k

    assert luffy.exec_mode in ("sync", "pipeline"), luffy.exec_mode
    pipelined = luffy.exec_mode == "pipeline" and M > 1
    if pipelined:
        plan = plan_chunks(C, luffy.pipeline_chunks)

        def _disp(k):
            # vanilla needs no row metadata — exchanging it would put a
            # dead collective on the pipelined critical path (the barrier
            # keeps payloads live, so XLA could not DCE it there)
            o, s = plan.offsets[k], plan.sizes[k]
            bk = comm.all_to_all(jax.lax.slice_in_dim(buf, o, o + s,
                                                      axis=1))
            if not migrate:
                return bk
            return bk, comm.all_to_all(jax.lax.slice_in_dim(mbuf, o, o + s,
                                                            axis=1))

        def _compute(k, payload):
            bk, mk = payload if migrate else (payload, None)
            s = plan.sizes[k]
            rows_k = bk.reshape(M, E_local, s, d + 2).transpose(1, 0, 2, 3)
            if not migrate:
                return _ffn_rows(rows_k)
            meta_k = mk.reshape(M, E_local, s, 2).transpose(1, 0, 2, 3)
            return _ffn_rows(rows_k) + (meta_k,)

        if not migrate:
            def _comb(k, res):
                out_k = res[0]                 # [E_local, M, Ck, d]
                back_k = out_k.transpose(1, 0, 2, 3) \
                              .reshape(E, out_k.shape[2], d)
                return comm.combine(back_k)

            _, backs = run_pipeline(plan.n_chunks, dispatch=_disp,
                                    compute=_compute, combine=_comb)
            back = jnp.concatenate(backs, axis=1)            # [E, C, d]
        else:
            outs, _ = run_pipeline(plan.n_chunks, dispatch=_disp,
                                   compute=_compute)
            out_rows = jnp.concatenate([o for o, _, _ in outs], axis=2) \
                          .reshape(E_local, M * C, d)
            prim = jnp.concatenate([p for _, p, _ in outs], axis=2) \
                      .reshape(E_local, M * C, 1)
            rmeta = jnp.concatenate([m for _, _, m in outs], axis=2) \
                       .reshape(E_local, M * C, 2)
    else:
        if M > 1:
            buf = comm.all_to_all(buf)
            mbuf = comm.all_to_all(mbuf)
        # [M_src * E_local, C, .] -> [E_local, M_src, C, .]
        rows4 = buf.reshape(M, E_local, C, d + 2).transpose(1, 0, 2, 3)
        rmeta = mbuf.reshape(M, E_local, C, 2).transpose(1, 0, 2, 3) \
                    .reshape(E_local, M * C, 2)
        out4, prim4 = _ffn_rows(rows4)
        out_rows = out4.reshape(E_local, M * C, d)
        prim = prim4.reshape(E_local, M * C, 1)
        if not migrate:
            back = out_rows.reshape(E_local, M, C, d) \
                           .transpose(1, 0, 2, 3).reshape(E, C, d)
            if M > 1:
                back = comm.combine(back)

    # ---- combine ----------------------------------------------------------
    if not migrate:
        # vanilla: rows returned to their source in dispatch layout
        vals = back[e_safe, p_safe] * v_f[:, None].astype(cdt)  # [T*k, d]
        delta = jnp.sum(vals.reshape(T, m.top_k, d), axis=1)
        y_tok = xf + delta.astype(xf.dtype)
        c_drop = jnp.float32(0.0)
        local_frac = jnp.float32(1.0 / M)
        new_sideband = dict(sideband)
    else:
        # regroup rows by destination device (priority: residual rows first)
        R = E_local * M * C
        o_f = out_rows.reshape(R, d)
        dslot = rmeta[..., 0].reshape(R) - 1               # -1 = empty row
        rpos = rmeta[..., 1].reshape(R)
        rprim = prim.reshape(R) > 0.5
        rvalid = dslot >= 0
        ddev = jnp.where(rvalid, dslot // n_seq, M)        # M = dummy bin
        prio = (~rvalid).astype(jnp.int32) * 2 + (~rprim).astype(jnp.int32)
        order = jnp.argsort(prio, stable=True)
        o_f, dslot, rpos, ddev, rvalid = (a[order] for a in
                                          (o_f, dslot, rpos, ddev, rvalid))
        C_comb = max(8, int(math.ceil(combine_slack * E_local * C / 8)) * 8)
        oh = jax.nn.one_hot(ddev, M, dtype=jnp.int32)
        rank = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(R), jnp.where(
            rvalid, ddev, 0)]
        keep_c = rvalid & (rank < C_comb)
        n_rv = jnp.sum(rvalid.astype(jnp.float32))
        c_drop = 1.0 - jnp.sum(keep_c.astype(jnp.float32)) / jnp.maximum(
            n_rv, 1.0)
        local_frac = jnp.sum((keep_c & (ddev == my)).astype(jnp.float32)) \
            / jnp.maximum(n_rv, 1.0)
        dd_s = jnp.where(keep_c, ddev, 0)
        rk_s = jnp.where(keep_c, rank, 0)
        cbuf = jnp.zeros((M, C_comb, d), cdt).at[dd_s, rk_s].add(
            o_f * keep_c[:, None].astype(cdt), mode="drop")
        cmeta = jnp.zeros((M, C_comb, 2), jnp.int32).at[dd_s, rk_s].add(
            jnp.stack([jnp.where(keep_c, dslot % n_seq + 1, 0),
                       jnp.where(keep_c, rpos, 0)], -1), mode="drop")
        if M > 1:
            cbuf = comm.combine(cbuf)
            cmeta = comm.combine(cmeta)
        rs = cbuf.reshape(M * C_comb, d)
        rslot = cmeta[..., 0].reshape(-1) - 1
        rp = cmeta[..., 1].reshape(-1)
        ok = rslot >= 0
        y_grid = jnp.zeros((n_seq, S, d), cdt).at[
            jnp.where(ok, rslot, 0), jnp.where(ok, rp, 0)].add(
            rs * ok[:, None].astype(cdt), mode="drop")
        y_tok = y_grid.reshape(T, d).astype(xf.dtype)
        # sideband travels with sequences
        new_sideband = _exchange_sideband(
            sideband, dest_global, n_seq, M, comm)

    # ---- un-condense (token_to_token replacement, §VI) --------------------
    if do_condense:
        if not migrate:
            y_tok = cond.uncondense(y_tok, rep_idx)
        else:
            # rep map migrated as sideband: [n_seq, S] local rep position
            rep_local = (rep_idx % S).reshape(n_seq, S).astype(jnp.int32)
            rep_sb = _exchange_sideband({"rep": rep_local}, dest_global,
                                        n_seq, M, comm)["rep"]
            yg = y_tok.reshape(n_seq, S, d)
            y_tok = jnp.take_along_axis(yg, rep_sb[..., None], axis=1
                                        ).reshape(T, d)
        if s_next is not None and migrate:
            ng = S // group_size
            s_mig = s_next.reshape(n_seq, ng, group_size, group_size)
            s_next = _exchange_sideband(
                {"s": s_mig.astype(jnp.bfloat16)}, dest_global, n_seq, M,
                comm)["s"].astype(jnp.float32)
            s_next = s_next.reshape(-1, group_size, group_size)

    y_out = y_tok.reshape(n_seq, S, d)

    # ---- shared experts (always-on, llama4-style) -------------------------
    if "shared" in params:
        from repro.models.blocks import ffn_apply
        sh = ffn_apply({"w_up": params["shared"]["w_up"],
                        "w_gate": params["shared"]["w_gate"],
                        "w_down": params["shared"]["w_down"]},
                       cfg, _rms(y_out if migrate else x.reshape(n_seq, S, d),
                                 params["norm"]["scale"]).astype(cdt))
        y_out = y_out + sh.astype(y_out.dtype)

    aux = MoEAux(gate.aux_loss, d_drop, c_drop, c_rate, local_frac,
                 t_before, t_after, ib_flat, ib_dedup)
    return y_out, new_sideband, s_next, aux


def _exchange_sideband(sb: Dict[str, Array], dest_global: Array,
                       n_seq: int, M: int,
                       comm: Optional[CommContext]) -> Dict[str, Array]:
    """Move per-sequence side info to new homes (bijection on slots)."""
    if M == 1 or comm is None:
        # permutation within the single device
        out = {}
        inv = jnp.zeros((n_seq,), jnp.int32).at[dest_global % n_seq].set(
            jnp.arange(n_seq, dtype=jnp.int32))
        for k, v in sb.items():
            out[k] = v[inv]
        return out
    out = {}
    dd = dest_global // n_seq
    ds = dest_global % n_seq
    for k, v in sb.items():
        buf = jnp.zeros((M, n_seq) + v.shape[1:], v.dtype)
        buf = buf.at[dd, ds].add(v)
        buf = comm.combine(buf)
        out[k] = jnp.sum(buf, axis=0)      # exactly-one-writer per slot
    return out
