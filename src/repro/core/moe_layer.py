"""Expert-parallel MoE layer with LUFFY's two techniques (paper §III-§V).

Runs *inside* ``jax.shard_map`` over the full mesh: batch axes shard
sequences, the ``model`` axis shards experts. Per device this module sees

    x_local      [n_seq, S, d]     — this device's sequence slots
    experts      [E_local, ...]    — this device's expert shard

and performs: gate → (condense §V) → dispatch all-to-all → expert FFN →
(migrate §IV) combine all-to-all → un-condense. Since the plan/execute
split (DESIGN.md §7) the decisions live in a ``repro.plan.ExchangePlan``
and the data movement in ``repro.plan.execute_plan``; ``moe_core`` here
is the thin build + execute composition (plus the decode all-reduce
path, which has no all-to-all and bypasses the plan).

Key TPU adaptations (DESIGN.md §3):

* **Condensation** shrinks the *static* expert capacity ``C`` by the rate
  bucket; non-representative tokens take no dispatch slot, so the
  all-to-all operand itself is smaller.
* **Migration** is a bijection on global sequence slots, planned from the
  router output *before* dispatch (device-side Algorithm 1, replicated
  within each model row). The dispatch payload carries the *pre-norm*
  residual ``x``; expert devices compute ``norm→FFN→gate·y (+ residual on
  the primary copy)`` and address combine rows to the token's **new**
  home. The combine collective has the same operand size as vanilla —
  the migration win is the larger diagonal (local) fraction, which never
  crosses ICI links. Reported via the locality ledger in ``aux``.
* Capacity overflow drops rows exactly like GShard; primary (residual-
  carrying) rows are packed first so they survive longest. Drop rates are
  reported in ``aux``.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm import CommContext, compat
from repro.config import LuffyConfig, MoEConfig, ModelConfig
from repro.core.gating import dispatch_positions, gate_apply, gate_init
# The exchange decision/execution machinery lives in repro.plan
# (DESIGN.md §7); these re-exports keep the historical import surface.
from repro.plan.exchange import (MoEAux, N_AUX, _exchange_sideband, _rms,
                                 build_exchange_plan, execute_plan,
                                 expert_ffn)

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig):
    """Global expert stack [E, ...] (sharded over 'model' outside)."""
    from repro.models.blocks import dense_init, _dtype
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff, m.num_experts
    pdt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    scale_down = 1.0 / math.sqrt(2 * cfg.num_layers)
    p = {
        "router": gate_init(ks[0], d, E),
        "experts": {
            "w_up": (jax.random.normal(ks[1], (E, d, f)) / math.sqrt(d)).astype(pdt),
            "w_gate": (jax.random.normal(ks[2], (E, d, f)) / math.sqrt(d)).astype(pdt),
            "w_down": (jax.random.normal(ks[3], (E, f, d)) * scale_down
                       / math.sqrt(f)).astype(pdt),
        },
        "norm": {"scale": jnp.ones((d,), pdt)},
    }
    if m.num_shared_experts > 0:
        fs = f * m.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_up": (jax.random.normal(k1, (d, fs)) / math.sqrt(d)).astype(pdt),
            "w_gate": (jax.random.normal(k2, (d, fs)) / math.sqrt(d)).astype(pdt),
            "w_down": (jax.random.normal(k3, (fs, d)) * scale_down
                       / math.sqrt(fs)).astype(pdt),
        }
    return p


def capacity_for(moe: MoEConfig, tokens_local: int, num_experts: int,
                 rate: float = 0.0, slack: float = None) -> int:
    """Static per-(source, expert) capacity, condensation-bucket scaled."""
    cf = slack if slack is not None else moe.capacity_factor
    c = int(math.ceil(cf * tokens_local * moe.top_k * (1.0 - rate)
                      / num_experts))
    return max(8, ((c + 7) // 8) * 8)


def expert_ffn_2d(ew_local, h, act, cdt, fsdp_axes,
                  batch_sharded: bool = True):
    """Megatron-style expert FFN over the FSDP axes (decode path):

    weights are F-sharded (w_up/w_gate on dim 2, w_down on dim 1 — their
    stored layout, so NO weight resharding happens); the tiny decode
    activation rows are all-gathered, each rank computes its F-slice of
    the hidden, and the output partial-sums reduce-scatter back to each
    rank's own rows. Wire per layer ≈ 2×rows-size instead of the full
    expert weights (llama4 decode: ~20 MB vs ~2 GB; EXPERIMENTS.md §Perf).

    batch_sharded=False (long_500k: B=1 replicated over the fsdp axes):
    skip the gather/scatter — every rank holds the same rows, computes
    its F-slice partial, and a single psum yields the replicated output.
    """
    hc = h.astype(cdt)
    if batch_sharded:
        h_g = jax.lax.all_gather(hc, fsdp_axes, axis=1, tiled=True)
    else:
        h_g = hc
    up = jnp.einsum("erd,edf->erf", h_g, ew_local["w_up"].astype(cdt))
    gt = jnp.einsum("erd,edf->erf", h_g, ew_local["w_gate"].astype(cdt))
    hh = act(gt) * up                       # [E_l, R(_all), F_local]
    part = jnp.einsum("erf,efd->erd", hh,
                      ew_local["w_down"].astype(cdt))
    if batch_sharded:
        # reduce over F shards + scatter rows back to their owners
        return jax.lax.psum_scatter(part, fsdp_axes, scatter_dimension=1,
                                    tiled=True)
    return jax.lax.psum(part, fsdp_axes)


def moe_decode_allreduce(params, x, cfg: ModelConfig, *, capacity: int,
                         axis_name, use_kernel: bool = False,
                         fsdp_axes=None, batch_sharded: bool = True,
                         overlap: bool = False):
    """Decode-time expert parallelism via all-reduce (no all-to-all).

    At decode there is ONE token per sequence — the dispatch operand would
    be tiny and the token dim (S=1) cannot shard over the model axis. So
    tokens stay replicated across the model axis; each rank runs only its
    LOCAL experts on the tokens routed to them and the partial outputs are
    psum'd. Collective = one [B,1,d] all-reduce per layer.

    overlap (``LuffyConfig.exec_mode="decode_overlap"``, DESIGN.md §13):
    issue that combine psum CONCURRENTLY with the shared-expert FFN —
    the two are data-independent (the shared FFN reads the pre-expert
    hidden), so ``optimization_barrier`` pins the shared FFN between
    psum issue and psum consumption and XLA's async collectives hide
    the wire time behind the matmuls. The value graph is unchanged
    (same operands, same addition order), so overlap is bit-identical
    to sync; with no shared experts or no mesh it degrades to sync.
    Returns (y, aux)."""
    from repro.models.blocks import _act, _dtype
    m = cfg.moe
    cdt = _dtype(cfg.compute_dtype)
    act = _act(cfg.act)
    n_seq, S, d = x.shape
    T = n_seq * S
    E = m.num_experts
    M = 1 if axis_name is None else compat.axis_size(axis_name)
    E_local = E // M
    my = 0 if axis_name is None else compat.axis_index(axis_name)
    C = capacity

    xf = x.reshape(T, d)
    xn = _rms(xf, params["norm"]["scale"]).astype(cdt)
    gate = gate_apply(params["router"], xn, m.top_k)
    lo = my * E_local
    local_e = gate.expert_idx - lo
    keep = (local_e >= 0) & (local_e < E_local)
    local_e = jnp.clip(local_e, 0, E_local - 1)
    pos = dispatch_positions(local_e, keep, E_local)
    valid = keep & (pos < C)
    e_safe = jnp.where(valid, local_e, 0).reshape(-1)
    p_safe = jnp.where(valid, pos, 0).reshape(-1)
    v_f = valid.reshape(-1)
    rows_in = jnp.zeros((E_local, C, d), cdt).at[e_safe, p_safe].add(
        jnp.tile(xn[:, None], (1, m.top_k, 1)).reshape(-1, d)
        * v_f[:, None].astype(cdt), mode="drop")
    if fsdp_axes:
        y_rows = expert_ffn_2d(params["experts"], rows_in, act, cdt,
                               fsdp_axes, batch_sharded=batch_sharded)
    else:
        y_rows = expert_ffn(params["experts"], rows_in, act, cdt,
                            use_kernel=use_kernel)
    vals = y_rows[e_safe, p_safe] * v_f[:, None].astype(cdt)
    vals = vals * gate.gate_weights.reshape(-1, 1).astype(cdt)
    delta = jnp.sum(vals.reshape(T, m.top_k, d), axis=1)
    sh = None
    if overlap and axis_name is not None and "shared" in params:
        from repro.models.blocks import ffn_apply
        # barrier 1: the shared FFN may not be hoisted before the local
        # expert partials exist; barrier 2: the psum may not be awaited
        # before the shared FFN is done — together they bracket the
        # shared-expert matmuls inside the collective's in-flight window
        delta, x_b = compat.optimization_barrier((delta, x))
        sh = ffn_apply(params["shared"], cfg,
                       _rms(x_b, params["norm"]["scale"]).astype(cdt))
        delta = jax.lax.psum(delta, axis_name)
        delta, sh = compat.optimization_barrier((delta, sh))
    elif axis_name is not None:
        delta = jax.lax.psum(delta, axis_name)
    y = (xf + delta.astype(xf.dtype)).reshape(n_seq, S, d)
    if "shared" in params:
        if sh is None:
            from repro.models.blocks import ffn_apply
            sh = ffn_apply(params["shared"], cfg,
                           _rms(x, params["norm"]["scale"]).astype(cdt))
        y = y + sh.astype(y.dtype)
    kept = jnp.sum(keep.astype(jnp.float32))
    d_drop = 1.0 - jnp.sum(valid.astype(jnp.float32)) / jnp.maximum(kept, 1.0)
    z = jnp.float32(0.0)
    aux = MoEAux(gate.aux_loss, d_drop, z, z,
                 jnp.float32(1.0 / max(M, 1)),
                 *([z] * (N_AUX - 5)))
    return y, aux


# ---------------------------------------------------------------------------
# The per-device core
# ---------------------------------------------------------------------------

def moe_core_planned(params, x, sideband: Dict[str, Array],
                     cfg: ModelConfig, luffy: LuffyConfig, *, mode: str,
                     capacity: int, axis_name=None, threshold=None,
                     s_prev: Optional[Array] = None,
                     group_size: int = 128, combine_slack: float = 1.0,
                     use_kernel: bool = False,
                     comm: Optional[CommContext] = None,
                     reuse_from=None, condense_reuse_from=None,
                     plan_template=None, wire_ef: Optional[Array] = None):
    """``moe_core`` that also returns the :class:`ExchangePlan` it built
    — the plan-lifecycle entry point (DESIGN.md §9). ``reuse_from``
    threads a prior plan/signature into ``build_exchange_plan``'s
    revalidation fast path; ``condense_reuse_from`` (a
    :class:`repro.condense.CondenseCarry`) does the same for the
    condensation map (DESIGN.md §10); ``plan_template`` (a cached static
    template from :class:`repro.plan.cache.PlanCache`) switches the
    vanilla path to ``instantiate_plan``, skipping planning entirely;
    ``wire_ef`` threads the lossy-wire error-feedback residual
    (DESIGN.md §15) into the executor.
    Returns (y, new_sideband, s_next, aux, plan, cond_carry, wire_ef)."""
    from repro.models.blocks import _dtype
    from repro.plan.exchange import instantiate_decode_plan, instantiate_plan
    comm = CommContext.ensure(comm, axis_name)
    n_seq, S, d = x.shape
    xf = x.reshape(n_seq * S, d)
    xn = _rms(xf, params["norm"]["scale"]).astype(_dtype(cfg.compute_dtype))
    gate = gate_apply(params["router"], xn, cfg.moe.top_k)
    from repro.obs import trace as obs_trace
    with obs_trace.phase("plan_build") as _sp:
        if plan_template is not None:
            inst = (instantiate_decode_plan if plan_template.mode == "decode"
                    else instantiate_plan)
            plan = inst(
                plan_template, gate, xn, cfg, comm, capacity=capacity,
                sideband=sideband, use_kernel=use_kernel)
        else:
            plan = build_exchange_plan(
                gate, xn, cfg, luffy, comm, mode=mode, capacity=capacity,
                sideband=sideband, threshold=threshold, s_prev=s_prev,
                group_size=group_size, combine_slack=combine_slack,
                use_kernel=use_kernel, reuse_from=reuse_from,
                condense_reuse_from=condense_reuse_from)
        plan = _sp.fence(plan)
    with obs_trace.phase("exchange") as _sp:
        y, aux = execute_plan(params, x, sideband, plan, cfg,
                              wire_ef=wire_ef)
        y = _sp.fence(y)
    return (y, aux.sideband, aux.s_next, aux.moe, plan, aux.cond_carry,
            aux.wire_ef)


def moe_core(params, x, sideband: Dict[str, Array], cfg: ModelConfig,
             luffy: LuffyConfig, *, mode: str, capacity: int,
             axis_name=None, threshold=None,
             s_prev: Optional[Array] = None,
             group_size: int = 128, combine_slack: float = 1.0,
             use_kernel: bool = False,
             comm: Optional[CommContext] = None
             ) -> Tuple[Array, Dict[str, Array], Optional[Array], MoEAux]:
    """One MoE sublayer on this device's shard: build + execute.

    x: [n_seq, S, d] pre-norm hidden. sideband: {"labels":[n_seq,S],
    "seq_len":[n_seq]} — travels with sequences under migration.
    mode: "vanilla" | "migrate". Condensation is on iff s_prev is not None
    or luffy.enable_condensation and mode != decode-style call.
    comm: collective strategy + topology (repro.comm); the historical
    ``(comm=None, axis_name=...)`` spelling is normalized to a flat
    context here, at the call boundary — downstream the executor holds
    exactly one non-optional comm handle (``CommContext.ensure``).
    Returns (y, new_sideband, s_next, aux). In vanilla mode
    ``y = x + moe_delta``; in migrate mode ``y`` is the full post-block
    hidden materialized at *new* slots.

    This is nothing but the two-phase ``repro.plan`` API (DESIGN.md §7):
    every decision lives in the :class:`~repro.plan.ExchangePlan`, every
    byte moves in :func:`~repro.plan.execute_plan`. (The plan-lifecycle
    sibling ``moe_core_planned`` additionally returns the plan and takes
    ``reuse_from``/``plan_template``; this historical entry point keeps
    the 4-tuple contract.)
    """
    y, sb, s_next, aux, _, _, _ = moe_core_planned(
        params, x, sideband, cfg, luffy, mode=mode, capacity=capacity,
        axis_name=axis_name, threshold=threshold, s_prev=s_prev,
        group_size=group_size, combine_slack=combine_slack,
        use_kernel=use_kernel, comm=comm)
    return y, sb, s_next, aux
