"""Data pipeline: deterministic synthetic token streams + a file-backed
token dataset, with host sharding, length bucketing (the TPU analogue of
the paper's "gather sequences of similar lengths"), and modality stubs
for the audio/VLM architectures (precomputed frame/patch embeddings —
the one allowed carve-out, see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.config import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    seed: int = 0
    # synthetic stream: zipfian token distribution with markov structure,
    # which yields the *clustered* token embeddings the paper's
    # condensation exploits (similar contexts -> similar hidden states).
    zipf_a: float = 1.2
    markov_order: int = 1
    min_len_frac: float = 0.5      # sequences have len in [frac*S, S]
    length_buckets: int = 4


class SyntheticLM:
    """Deterministic synthetic language-model stream."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 data_cfg: DataConfig = DataConfig()):
        self.cfg, self.shape, self.dc = cfg, shape, data_cfg
        self.rng = np.random.default_rng(data_cfg.seed)
        V = cfg.vocab_size
        # zipf-ish unigram + low-rank bigram mixing for structure
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self.unigram = ranks ** (-data_cfg.zipf_a)
        self.unigram /= self.unigram.sum()

    def _sample_tokens(self, rng, n):
        return rng.choice(self.cfg.vocab_size, size=n, p=self.unigram
                          ).astype(np.int32)

    def batch(self, step: int, *, global_batch: Optional[int] = None,
              seq_len: Optional[int] = None) -> Dict[str, np.ndarray]:
        B = global_batch or self.shape.global_batch
        S = seq_len or self.shape.seq_len
        rng = np.random.default_rng((self.dc.seed, step))
        toks = self._sample_tokens(rng, B * (S + 1)).reshape(B, S + 1)
        # markov smoothing: repeat previous token sometimes (structure)
        rep = rng.random((B, S + 1)) < 0.3
        for t in range(1, S + 1):
            toks[:, t] = np.where(rep[:, t], toks[:, t - 1], toks[:, t])
        lens = rng.integers(max(2, int(self.dc.min_len_frac * S)), S + 1,
                            size=B).astype(np.int32)
        # length bucketing: sort into buckets so co-batched sequences have
        # similar lengths (reduces padding waste; §IV motivation)
        order = np.argsort(lens, kind="stable")
        toks, lens = toks[order], lens[order]
        tokens = toks[:, :S].copy()
        labels = toks[:, 1:S + 1].astype(np.int32).copy()
        pos = np.arange(S)[None, :]
        labels[pos >= lens[:, None]] = -1
        tokens[pos >= lens[:, None]] = 0
        batch = {"tokens": tokens, "labels": labels, "seq_len": lens}
        if self.cfg.prefix_slots > 0 and self.cfg.kind != "encdec":
            P = self.cfg.prefix_slots
            batch["prefix"] = rng.standard_normal(
                (B, P, self.cfg.prefix_dim or self.cfg.d_model)
            ).astype(np.float32)
            # prefix occupies the first P positions; tokens shrink
            batch["tokens"] = tokens[:, :S - P]
            lbl = labels.copy()
            lbl[:, :P] = -1
            batch["labels"] = lbl
        if self.cfg.kind == "encdec":
            batch["enc_input"] = rng.standard_normal(
                (B, S, self.cfg.prefix_dim or self.cfg.d_model)
            ).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class TokenFileDataset:
    """Memory-mapped flat token file (.npy int32), chunked into sequences,
    deterministically shuffled and sharded across hosts."""

    def __init__(self, path: str, cfg: ModelConfig, shape: ShapeConfig,
                 *, host_id: int = 0, num_hosts: int = 1, seed: int = 0):
        self.tokens = np.load(path, mmap_mode="r")
        self.cfg, self.shape = cfg, shape
        self.host_id, self.num_hosts, self.seed = host_id, num_hosts, seed
        S = shape.seq_len
        self.n_seqs = (len(self.tokens) - 1) // S
        rng = np.random.default_rng(seed)
        self.order = rng.permutation(self.n_seqs)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.shape.global_batch, self.shape.seq_len
        per_host = B // self.num_hosts
        idx0 = (step * B + self.host_id * per_host) % max(
            1, self.n_seqs - B)
        seqs = []
        for i in range(per_host):
            s = self.order[(idx0 + i) % self.n_seqs] * S
            seqs.append(np.asarray(self.tokens[s:s + S + 1], np.int32))
        arr = np.stack(seqs)
        return {"tokens": arr[:, :S],
                "labels": arr[:, 1:].astype(np.int32),
                "seq_len": np.full((per_host,), S, np.int32)}


def make_decode_batch(cfg: ModelConfig, shape: ShapeConfig, step: int = 0):
    rng = np.random.default_rng((17, step))
    B = shape.global_batch
    return {"tokens": rng.integers(0, cfg.vocab_size, (B, 1)
                                   ).astype(np.int32)}
