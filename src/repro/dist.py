"""Distribution context: how activations/params map onto the mesh.

One :class:`DistContext` per (arch × shape × mesh) combination. Dense
parts of the model are GSPMD-sharded via constraints; the MoE layer runs
in an explicit ``jax.shard_map`` (the paper's subject — we want manual
control of the dispatch/combine collectives).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class DistContext:
    mesh: Optional[Mesh] = None
    # axes sharding the batch dim of activations (may include 'model'
    # for train shapes — expert-parallel batch spreads over all axes)
    batch_axes: Tuple[str, ...] = ()
    # axis sharding the sequence dim (prefill / long-context), or None
    seq_axis: Optional[str] = None
    model_axis: str = "model"
    # axes over which (dense-arch / attention) params are fully sharded
    fsdp_axes: Tuple[str, ...] = ()

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    def axis_size(self, name) -> int:
        if self.mesh is None:
            return 1
        if isinstance(name, (tuple, list)):
            out = 1
            for n in name:
                out *= self.mesh.shape[n]
            return out
        return self.mesh.shape[name]

    @property
    def model_size(self) -> int:
        return self.axis_size(self.model_axis) if self.enabled else 1

    @property
    def batch_size_divisor(self) -> int:
        return self.axis_size(self.batch_axes) if self.enabled else 1

    # -- spec helpers -------------------------------------------------------
    def bspec(self, *rest) -> P:
        b = self.batch_axes if self.batch_axes else None
        return P(b, *rest)

    def act_spec(self, extra_dims: int = 1) -> P:
        """Spec for [B, S, ...] activations."""
        b = self.batch_axes if self.batch_axes else None
        return P(b, self.seq_axis, *([None] * extra_dims))

    def constrain(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def sharding(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)


def single_device() -> DistContext:
    return DistContext()


def make_dist(mesh: Mesh, shape_mode: str, global_batch: int,
              *, moe_arch: bool) -> DistContext:
    """Choose the sharding strategy for a given input shape (DESIGN.md §4).

    * train:   batch over ALL axes when divisible (expert-parallel rows
               live on 'model'); else batch over (pod,data) + seq on model.
    * prefill: batch over (pod,data), sequence over 'model'.
    * decode:  batch over (pod,data); KV sequence dim over 'model'
               (context-parallel decode). long_500k (B=1): KV over all axes.
    """
    names = tuple(mesh.axis_names)
    data_axes = tuple(a for a in names if a != "model")
    all_axes = tuple(a for a in names)
    n_all = 1
    for a in all_axes:
        n_all *= mesh.shape[a]
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]

    if shape_mode == "train":
        if global_batch % n_all == 0:
            return DistContext(mesh, batch_axes=all_axes, seq_axis=None,
                               fsdp_axes=data_axes)
        return DistContext(mesh, batch_axes=data_axes, seq_axis="model",
                           fsdp_axes=data_axes)
    if shape_mode == "prefill":
        if global_batch % n_all == 0 and not moe_arch:
            return DistContext(mesh, batch_axes=all_axes, seq_axis=None,
                               fsdp_axes=data_axes)
        return DistContext(mesh, batch_axes=data_axes, seq_axis="model",
                           fsdp_axes=data_axes)
    # decode: batch over data axes, KV-cache sequence dim over 'model'
    # (context-parallel decode). long_500k (B=1): KV over every axis.
    if global_batch == 1:
        return DistContext(mesh, batch_axes=(), seq_axis=all_axes,
                           fsdp_axes=data_axes)
    return DistContext(mesh, batch_axes=data_axes, seq_axis="model",
                       fsdp_axes=data_axes)
