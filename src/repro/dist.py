"""Distribution context: how activations/params map onto the mesh.

One :class:`DistContext` per (arch × shape × mesh) combination. Dense
parts of the model are GSPMD-sharded via constraints; the MoE layer runs
in an explicit ``jax.shard_map`` (the paper's subject — we want manual
control of the dispatch/combine collectives).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm import Topology, model_axes_of


@dataclass(frozen=True)
class DistContext:
    mesh: Optional[Mesh] = None
    # axes sharding the batch dim of activations (may include the model
    # axes for train shapes — expert-parallel batch spreads over all axes)
    batch_axes: Tuple[str, ...] = ()
    # axis (or axis tuple) sharding the sequence dim, or None
    seq_axis: Optional[Union[str, Tuple[str, ...]]] = None
    # expert-parallel axis: "model" on flat meshes, ("node", "local") on
    # hierarchical ones (DESIGN.md §5)
    model_axis: Union[str, Tuple[str, ...]] = "model"
    # axes over which (dense-arch / attention) params are fully sharded
    fsdp_axes: Tuple[str, ...] = ()
    # physical link hierarchy backing the mesh (None = uniform/unknown)
    topology: Optional[Topology] = None

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    def axis_size(self, name) -> int:
        if self.mesh is None:
            return 1
        if isinstance(name, (tuple, list)):
            out = 1
            for n in name:
                out *= self.mesh.shape[n]
            return out
        return self.mesh.shape[name]

    @property
    def model_size(self) -> int:
        return self.axis_size(self.model_axis) if self.enabled else 1

    @property
    def model_axes_tuple(self) -> Tuple[str, ...]:
        ma = self.model_axis
        return (ma,) if isinstance(ma, str) else tuple(ma)

    @property
    def batch_size_divisor(self) -> int:
        return self.axis_size(self.batch_axes) if self.enabled else 1

    # -- spec helpers -------------------------------------------------------
    def bspec(self, *rest) -> P:
        b = self.batch_axes if self.batch_axes else None
        return P(b, *rest)

    def act_spec(self, extra_dims: int = 1) -> P:
        """Spec for [B, S, ...] activations."""
        b = self.batch_axes if self.batch_axes else None
        return P(b, self.seq_axis, *([None] * extra_dims))

    def constrain(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def sharding(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)


def single_device() -> DistContext:
    return DistContext()


def make_dist(mesh: Mesh, shape_mode: str, global_batch: int,
              *, moe_arch: bool,
              topology: Optional[Topology] = None) -> DistContext:
    """Choose the sharding strategy for a given input shape (DESIGN.md §4).

    * train:   batch over ALL axes when divisible (expert-parallel rows
               live on the model axes); else batch over (pod,data) + seq
               over the model axes.
    * prefill: batch over (pod,data), sequence over the model axes.
    * decode:  batch over (pod,data); KV sequence dim over the model axes
               (context-parallel decode). long_500k (B=1): KV over all axes.

    The expert-parallel ("model") dimension is the ``model`` axis on flat
    meshes or the ``("node", "local")`` pair on hierarchical meshes
    (DESIGN.md §5); ``topology`` defaults to ``Topology.from_mesh``.
    """
    names = tuple(mesh.axis_names)
    model_ax = model_axes_of(names) or "model"
    m_axes = (model_ax,) if isinstance(model_ax, str) else model_ax
    data_axes = tuple(a for a in names if a not in m_axes)
    all_axes = tuple(a for a in names)
    if topology is None:
        topology = Topology.from_mesh(mesh)
    n_all = 1
    for a in all_axes:
        n_all *= mesh.shape[a]
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]

    common = dict(model_axis=model_ax, topology=topology)
    if shape_mode == "train":
        if global_batch % n_all == 0:
            return DistContext(mesh, batch_axes=all_axes, seq_axis=None,
                               fsdp_axes=data_axes, **common)
        return DistContext(mesh, batch_axes=data_axes, seq_axis=model_ax,
                           fsdp_axes=data_axes, **common)
    if shape_mode == "prefill":
        if global_batch % n_all == 0 and not moe_arch:
            return DistContext(mesh, batch_axes=all_axes, seq_axis=None,
                               fsdp_axes=data_axes, **common)
        return DistContext(mesh, batch_axes=data_axes, seq_axis=model_ax,
                           fsdp_axes=data_axes, **common)
    # decode: batch over data axes, KV-cache sequence dim over the model
    # axes (context-parallel decode). long_500k (B=1): KV over every axis.
    if global_batch == 1:
        return DistContext(mesh, batch_axes=(), seq_axis=all_axes,
                           fsdp_axes=data_axes, **common)
    return DistContext(mesh, batch_axes=data_axes, seq_axis=model_ax,
                       fsdp_axes=data_axes, **common)
