"""Condensation gather kernel: ``y[i] = y[rep_idx[i]]`` (token_to_token
replacement, paper §VI). A dynamic row-gather; on TPU this is a VMEM
gather per tile — the kernel exists so the un-condense step can fuse with
the combine scatter instead of round-tripping HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BT = 256


def _gather_kernel(idx_ref, src_ref, out_ref):
    """idx: [bt] int32 (global row ids); src: [T, d] (full residency);
    out: [bt, d]."""
    idx = idx_ref[...]
    out_ref[...] = src_ref[idx]


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def gather_rows(y, rep_idx, *, bt: int = DEFAULT_BT,
                interpret: bool = True):
    """y: [T, d]; rep_idx: [T] int32 -> y[rep_idx]."""
    T, d = y.shape
    bt_ = min(bt, T)
    assert T % bt_ == 0
    return pl.pallas_call(
        _gather_kernel,
        grid=(T // bt_,),
        in_specs=[
            pl.BlockSpec((bt_,), lambda i: (i,)),
            pl.BlockSpec((T, d), lambda i: (0, 0)),   # whole source table
        ],
        out_specs=pl.BlockSpec((bt_, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), y.dtype),
        interpret=interpret,
    )(rep_idx, y)
