"""Grouped expert-FFN Pallas kernel: the MoE compute hot-spot (§VII-C —
token condensation's computation saving materializes here, as fewer rows).

Computes ``out[e] = (act(h[e] @ w_gate[e]) * (h[e] @ w_up[e])) @ w_down[e]``
for every local expert. Grid: (E_local, R/br, F/bf); the f-dim is the
reduction for the second matmul, so each (e, r) accumulates over the f
grid axis into the output tile — BlockSpecs keep one [br, bf] activation
slab and one [bf, d] w_down slab in VMEM at a time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BR = 128     # rows per tile (tokens)
DEFAULT_BF = 512     # expert-hidden slab


def _ffn_kernel(h_ref, wu_ref, wg_ref, wd_ref, out_ref, *, act_name):
    """h: [br, d]; wu/wg: [d, bf]; wd: [bf, d]; out: [br, d] (accumulated
    over the f grid axis)."""
    f_idx = pl.program_id(2)
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act_name]
    h = h_ref[0].astype(jnp.float32)                       # [br, d]
    up = jax.lax.dot_general(h, wu_ref[0].astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    gt = jax.lax.dot_general(h, wg_ref[0].astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    part = jax.lax.dot_general(act(gt) * up,
                               wd_ref[0].astype(jnp.float32),
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    @pl.when(f_idx == 0)
    def init():
        out_ref[0] = part.astype(out_ref.dtype)

    @pl.when(f_idx > 0)
    def accum():
        out_ref[0] = (out_ref[0].astype(jnp.float32)
                      + part).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("act_name", "br", "bf", "interpret"))
def expert_ffn(h, w_up, w_gate, w_down, act_name: str = "silu", *,
               br: int = DEFAULT_BR, bf: int = DEFAULT_BF,
               interpret: bool = True):
    """h: [E, R, d]; w_up/w_gate: [E, d, F]; w_down: [E, F, d]."""
    E, R, d = h.shape
    F = w_up.shape[-1]
    br_ = min(br, R)
    bf_ = min(bf, F)
    assert R % br_ == 0 and F % bf_ == 0, (R, br_, F, bf_)
    grid = (E, R // br_, F // bf_)
    return pl.pallas_call(
        functools.partial(_ffn_kernel, act_name=act_name),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br_, d), lambda e, r, f: (e, r, 0)),
            pl.BlockSpec((1, d, bf_), lambda e, r, f: (e, 0, f)),
            pl.BlockSpec((1, d, bf_), lambda e, r, f: (e, 0, f)),
            pl.BlockSpec((1, bf_, d), lambda e, r, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, br_, d), lambda e, r, f: (e, r, 0)),
        out_shape=jax.ShapeDtypeStruct((E, R, d), h.dtype),
        interpret=interpret,
    )(h, w_up, w_gate, w_down)
