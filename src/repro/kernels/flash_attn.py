"""Flash-attention Pallas kernel (streaming softmax in VMEM).

The roofline (§Roofline) shows every training/prefill shape memory-bound,
with attention's [B,H,Sq,Sk] score tensor a top HBM consumer — exactly
the traffic FlashAttention (paper ref [29]) eliminates. This kernel keeps
one (bq × bk) score tile in VMEM with running (m, l, acc) statistics.

Grid: (B·H, Sq/bq, Sk/bk); the k axis is the reduction — (m, l, acc)
accumulate in the output ref across k steps (TPU grids iterate the
last axis innermost, sequentially per core).

Supports causal + sliding-window masks via position arithmetic; fully
masked tiles exit early (the same tile-level skip the similarity kernel
uses — and the band-slicing done at the jnp level in attend_chunked).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, bq, bk, nk):
    kk = pl.program_id(2)
    qq = pl.program_id(1)

    @pl.when(kk == 0)
    def init():
        m_ref[0] = jnp.full((bq,), NEG, jnp.float32)
        l_ref[0] = jnp.zeros((bq,), jnp.float32)
        acc_ref[0] = jnp.zeros_like(acc_ref[0])

    q0 = qq * bq
    k0 = kk * bk
    # tile-level early-out: causal tiles fully in the future, window
    # tiles fully in the past
    live = jnp.bool_(True)
    if causal:
        live = live & (k0 <= q0 + bq - 1)
    if window is not None:
        live = live & ((k0 + bk - 1) >= (q0 - window + 1))

    @pl.when(live)
    def compute():
        q = q_ref[0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= (qp - kp) < window
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        m_new = jnp.maximum(m_new, -0.5e30)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p, axis=1)
        acc_ref[0] = acc_ref[0] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(kk == nk - 1)
    def finalize():
        denom = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0] = (acc_ref[0] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    scale=None, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = True):
    """q: [B,S,H,hd]; k,v: [B,S,KV,hd] (KV heads pre-expanded or == H).
    Returns [B,S,H,hd]."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    assert KV == H, "expand GQA kv heads before the kernel"
    scale = scale or 1.0 / math.sqrt(hd)
    bq_, bk_ = min(bq, S), min(bk, S)
    assert S % bq_ == 0 and S % bk_ == 0
    nq, nk = S // bq_, S // bk_
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    # (m, l, acc) live in revisited output blocks (indexed by (b, i) only)
    # — the portable way to carry state across the k reduction axis.
    out, _, _, _ = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq_, bk=bk_, nk=nk),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq_), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq_), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
            jax.ShapeDtypeStruct((B * H, S), jnp.float32),
            jax.ShapeDtypeStruct((B * H, S), jnp.float32),
            jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
