"""Fused chunked Mamba-scan Pallas kernel.

EXPERIMENTS.md §Perf H4 showed that `lax.scan` unrolling does NOT fix the
SSM memory term: the [bd, N] state still round-trips HBM every token.
This kernel is the real fix — the Mamba-kernel insight on TPU:

* grid (B, d_inner/bd, S/bs), with the sequence axis innermost
  (sequential); the running state h [bd, N] lives in a revisited output
  block, so it touches HBM once per CHUNK instead of once per token;
* the per-step tensors da = exp(dt·A) and dbx = dt·x·B are fused in
  VMEM — the [B,S,di,N] intermediates of the jnp path (6.7 GB/seq at
  32k for hymba) are never materialized.

HBM traffic per chunk ≈ inputs (dt, x, B, C tiles) + y tile + state
once: ~(3·bs·bd + 2·bs·N + bd·N) floats vs the naive scan's
~3·bs·bd·N — a ×N/~16 reduction for hymba's N=16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BD = 256     # d_inner tile
DEFAULT_BS = 256     # sequence chunk


def _mamba_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, y_ref, h_ref, *,
                  bs, bd, n):
    # NOTE: refs are only ever indexed with slices ([...] / pl.dslice) —
    # integer ref indices break the state-discharge rules of older
    # pallas releases the compat story covers (DESIGN.md §5).
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def init():
        h_ref[...] = jnp.zeros((1, bd, n), jnp.float32)

    a = a_ref[...].astype(jnp.float32)                 # [bd, N]
    dt = dt_ref[...].astype(jnp.float32)               # [1, bs, bd] (VMEM)
    x = x_ref[...].astype(jnp.float32)
    bm = b_ref[...].astype(jnp.float32)                # [1, bs, N]
    cm = c_ref[...].astype(jnp.float32)

    def step(i, h):
        dt_i = dt[0, i]                                # [bd]
        x_i = x[0, i]                                  # [bd]
        b_i = bm[0, i]                                 # [N]
        c_i = cm[0, i]                                 # [N]
        da = jnp.exp(dt_i[:, None] * a)                # [bd, N]
        dbx = (dt_i * x_i)[:, None] * b_i[None, :]
        h = da * h + dbx
        y_i = jnp.sum(h * c_i[None, :], axis=1)        # [bd]
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(i, 1), slice(None)),
                 y_i[None, None].astype(y_ref.dtype))
        return h

    h_out = jax.lax.fori_loop(0, bs, step, h_ref[...][0])
    h_ref[...] = h_out[None]


@functools.partial(jax.jit, static_argnames=("bd", "bs", "interpret"))
def mamba_scan(dt, x, bmat, cmat, a, *, bd: int = DEFAULT_BD,
               bs: int = DEFAULT_BS, interpret: bool = True):
    """Fused selective-SSM scan.

    dt:   [B, S, di]  (post-softplus step sizes)
    x:    [B, S, di]  (post-conv, post-silu inputs)
    bmat: [B, S, N]   (input gate)
    cmat: [B, S, N]   (output gate)
    a:    [di, N]     (negative continuous-time decay, -exp(a_log))
    Returns y [B, S, di] = C_t · h_t with h_t = exp(dt·a)·h + dt·x·B_t.
    """
    B, S, di = dt.shape
    N = bmat.shape[-1]
    bd_, bs_ = min(bd, di), min(bs, S)
    assert di % bd_ == 0 and S % bs_ == 0
    grid = (B, di // bd_, S // bs_)
    y, _ = pl.pallas_call(
        functools.partial(_mamba_kernel, bs=bs_, bd=bd_, n=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs_, bd_), lambda b, d, s: (b, s, d)),  # dt
            pl.BlockSpec((1, bs_, bd_), lambda b, d, s: (b, s, d)),  # x
            pl.BlockSpec((1, bs_, N), lambda b, d, s: (b, s, 0)),    # B
            pl.BlockSpec((1, bs_, N), lambda b, d, s: (b, s, 0)),    # C
            pl.BlockSpec((bd_, N), lambda b, d, s: (d, 0)),          # a
        ],
        out_specs=(
            pl.BlockSpec((1, bs_, bd_), lambda b, d, s: (b, s, d)),  # y
            pl.BlockSpec((1, bd_, N), lambda b, d, s: (b, d, 0)),    # h
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, S, di), dt.dtype),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ),
        interpret=interpret,
    )(dt, x, bmat, cmat, a)
    return y
