"""Public jit'd wrappers for the Pallas kernels.

On CPU the kernels execute with ``interpret=True`` (the kernel body runs
in Python — correctness validation); on TPU ``interpret=False`` compiles
the real Mosaic kernels. ``repro.kernels.ref`` holds the pure-jnp oracles
used by the allclose tests.
"""
from __future__ import annotations

import jax

from repro.kernels import condense as _condense
from repro.kernels import expert_ffn as _expert_ffn
from repro.kernels import similarity as _similarity
from repro.kernels import ref  # noqa: F401 (re-export for convenience)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def masked_similarity(x, mask, **kw):
    # backend detection lives in the kernel itself (interpret=None)
    return _similarity.masked_similarity(x, mask, **kw)


def expert_ffn(h, w_up, w_gate, w_down, act="silu", **kw):
    act_name = act if isinstance(act, str) else \
        getattr(act, "__name__", "silu")
    kw.setdefault("interpret", _interpret())
    return _expert_ffn.expert_ffn(h, w_up, w_gate, w_down,
                                  act_name=act_name, **kw)


def gather_rows(y, rep_idx, **kw):
    kw.setdefault("interpret", _interpret())
    return _condense.gather_rows(y, rep_idx, **kw)


def pack_quantize(x, tok, **kw):
    from repro.kernels import pack as _pack
    kw.setdefault("interpret", _interpret())
    return _pack.pack_quantize(x, tok, **kw)


def flash_attention(q, k, v, **kw):
    from repro.kernels import flash_attn as _fa
    kw.setdefault("interpret", _interpret())
    return _fa.flash_attention(q, k, v, **kw)


def mamba_scan(dt, x, bmat, cmat, a, **kw):
    from repro.kernels import mamba_scan as _ms
    kw.setdefault("interpret", _interpret())
    return _ms.mamba_scan(dt, x, bmat, cmat, a, **kw)
