"""Fused gate-mask → dedup-pack → quantize kernel (DESIGN.md §14).

The dedup wire's hot pre-dispatch path used to be three separate HBM
round-trips: scatter the unique payload rows into the ``[N, C_u, d]``
wire buffer (the gate mask folded into the slot map), then a cast pass,
then (for f8) a block-scale pass. Given the inverse slot→token map
(``tok``, −1 = empty slot — cheap to build, it is an int scatter with no
``d``-wide payload), the whole thing is one gather-shaped pass: each
program packs a block of wire slots by gathering the full-residency
token table, masks empty slots to zero rows and writes the wire-dtype
payload — plus the per-``SCALE_BLOCK`` f32 scale sideband for f8e4m3 —
directly.

Bit-compatibility contract: the gather form equals the historical
scatter-add-onto-zeros build because every occupied slot has exactly one
contributing token, and the f8 codec formula (f32 accumulate → per-block
abs-max → guarded divide) is shared verbatim with
:func:`repro.comm.dtypes.quantize_rows` — the pure-jnp fallback and
:func:`repro.kernels.ref.pack_quantize_ref` are bit-for-bit targets,
not allclose targets.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.comm import dtypes as wdt

DEFAULT_BT = 256


def _pack_cast_kernel(idx_ref, src_ref, q_ref):
    """idx: [bt] int32 slot→token (−1 empty); src: [T, d] (full
    residency); q: [bt, d] at the wire dtype."""
    idx = idx_ref[...]
    rows = src_ref[jnp.maximum(idx, 0)]
    rows = jnp.where((idx >= 0)[:, None], rows, jnp.zeros_like(rows))
    q_ref[...] = rows.astype(q_ref.dtype)


def _pack_quant_kernel(idx_ref, src_ref, q_ref, sc_ref, *, block: int):
    """f8 variant: same gather+mask, then per-``block`` scales.
    src: [T, d_pad] (pre-padded); q: [bt, d_pad] f8; sc: [bt, d_pad/block]
    f32. Formula mirrors repro.comm.dtypes.quantize_rows exactly."""
    idx = idx_ref[...]
    rows = src_ref[jnp.maximum(idx, 0)].astype(jnp.float32)
    rows = jnp.where((idx >= 0)[:, None], rows, jnp.zeros_like(rows))
    bt, dp = rows.shape
    blocks = rows.reshape(bt, dp // block, block)
    amax = jnp.max(jnp.abs(blocks), axis=-1)
    # reciprocal multiply, like dtypes.quantize_rows (bitwise contract)
    scale = jnp.where(amax > 0, amax * (1.0 / wdt.F8_MAX), 1.0) \
        .astype(jnp.float32)
    q_ref[...] = (blocks / scale[..., None]).reshape(bt, dp) \
        .astype(q_ref.dtype)
    sc_ref[...] = scale


def _block_rows(R: int, bt: int) -> int:
    bt_ = min(bt, R)
    if R % bt_:
        bt_ = math.gcd(R, bt_)
    return bt_


@functools.partial(jax.jit, static_argnames=("wire_dtype", "bt",
                                             "interpret"))
def pack_quantize(x, tok, *, wire_dtype: str = "f32",
                  bt: int = DEFAULT_BT, interpret: bool = True):
    """x: [T, d] source rows; tok: [R] int32 slot→token map (−1 empty).
    Returns ``(q, scales)``: ``q`` [R, d] at the wire dtype (``[R,
    d_pad]`` for f8, padded to whole scale blocks), ``scales`` [R,
    d_pad/32] f32 for f8 else None — exactly
    :func:`repro.comm.dtypes.quantize_rows` of the packed rows."""
    T, d = x.shape
    R = tok.shape[0]
    bt_ = _block_rows(R, bt)
    if wire_dtype == "f8e4m3":
        d_pad = wdt.pad_to_block(d)
        if d_pad != d:
            x = jnp.pad(x, ((0, 0), (0, d_pad - d)))
        nb = d_pad // wdt.SCALE_BLOCK
        return pl.pallas_call(
            functools.partial(_pack_quant_kernel, block=wdt.SCALE_BLOCK),
            grid=(R // bt_,),
            in_specs=[
                pl.BlockSpec((bt_,), lambda i: (i,)),
                pl.BlockSpec((T, d_pad), lambda i: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((bt_, d_pad), lambda i: (i, 0)),
                pl.BlockSpec((bt_, nb), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((R, d_pad), wdt._f8_dtype()),
                jax.ShapeDtypeStruct((R, nb), jnp.float32),
            ],
            interpret=interpret,
        )(tok, x)
    out_dt = x.dtype if wire_dtype == "f32" else jnp.bfloat16
    q = pl.pallas_call(
        _pack_cast_kernel,
        grid=(R // bt_,),
        in_specs=[
            pl.BlockSpec((bt_,), lambda i: (i,)),
            pl.BlockSpec((T, d), lambda i: (0, 0)),   # whole source table
        ],
        out_specs=pl.BlockSpec((bt_, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), out_dt),
        interpret=interpret,
    )(tok, x)
    return q, None
