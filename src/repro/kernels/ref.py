"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_similarity_ref(x, mask, eps: float = 1e-8):
    """x: [G, d]; mask: [G, G] bool (True = must measure).
    Returns normalized cosine similarity in [0, 1], zero where masked out.
    """
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.sum(xf * xf, -1, keepdims=True) + eps)
    c = (n @ n.T + 1.0) * 0.5
    return jnp.where(mask, c, 0.0)


def expert_ffn_ref(h, w_up, w_gate, w_down, act_name: str = "silu"):
    """h: [E, R, d]; w_up/w_gate: [E, d, f]; w_down: [E, f, d]."""
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act_name]
    hf = h.astype(jnp.float32)
    up = jnp.einsum("erd,edf->erf", hf, w_up.astype(jnp.float32))
    gt = jnp.einsum("erd,edf->erf", hf, w_gate.astype(jnp.float32))
    out = jnp.einsum("erf,efd->erd", act(gt) * up,
                     w_down.astype(jnp.float32))
    return out.astype(h.dtype)


def gather_rows_ref(y, rep_idx):
    """y: [T, d]; rep_idx: [T] int32 -> y[rep_idx] (un-condensation)."""
    return jnp.take(y, rep_idx, axis=0)


def pack_quantize_ref(x, tok, wire_dtype: str = "f32"):
    """Oracle for the fused gate-mask → dedup-pack → quantize kernel:
    gather rows by the slot→token map (−1 = empty → zero row), then the
    shared wire codec. x: [T, d]; tok: [R] int32. Returns (q, scales)
    exactly like :func:`repro.kernels.pack.pack_quantize` — a
    bit-for-bit target, not an allclose one."""
    from repro.comm import dtypes as wdt
    rows = jnp.take(x, jnp.maximum(tok, 0), axis=0)
    rows = jnp.where((tok >= 0)[:, None], rows, jnp.zeros_like(rows))
    return wdt.quantize_rows(rows, wire_dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """Oracle for the flash kernel: plain masked softmax attention.
    q,k,v: [B,S,H,hd] (kv pre-expanded)."""
    import math
    hd = q.shape[-1]
    scale = scale or 1.0 / math.sqrt(hd)
    S = q.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    qp, kp = pos[:, None], pos[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= (qp - kp) < window
    lg = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    lg = jnp.where(mask[None, None], lg, -1e30)
    w = jax.nn.softmax(lg, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def mamba_scan_ref(dt, x, bmat, cmat, a):
    """Oracle for the fused Mamba scan: naive per-step recurrence.
    dt/x: [B,S,di]; bmat/cmat: [B,S,N]; a: [di,N]. Returns y [B,S,di]."""
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)        # [B,S,di,N]
    dbx = (dt * x).astype(jnp.float32)[..., None] \
        * bmat.astype(jnp.float32)[..., None, :]

    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t * h + dbx_t
        return h, jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))

    B, S, di = dt.shape
    h0 = jnp.zeros((B, di, a.shape[-1]), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(da, 1, 0),
                                    jnp.moveaxis(dbx, 1, 0),
                                    jnp.moveaxis(cmat, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(dt.dtype)
