"""Blocked masked pairwise-similarity Pallas kernel (§V-A, TPU-adapted).

Pairwise cosine similarity over one condensation group is a rank-``d``
Gram matmul — exactly MXU work. The fast-measurement skip rules (same
expert / historical similarity) arrive as a boolean mask; whole output
tiles with no uncertain pair are skipped (tile-level early-out), which is
the TPU analogue of the paper's per-edge skipping (per-element control
flow is poison on a systolic array; tile granularity is free).

Grid: (G/bg, G/bg); each program computes one [bg, bg] tile of the Gram
matrix by streaming d in [bd]-sized VMEM slabs.

The mask arrives from a similarity *backend* (DESIGN.md §10,
``repro.condense.backends``): the "exact" backend passes the §V-A
uncertain mask; the "lsh" backend additionally restricts it to LSH
bucket collisions, which empties whole tiles and lets the early-out skip
them — :func:`mask_tile_fraction` reports exactly that win.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BG = 128      # output tile edge (MXU-aligned)
DEFAULT_BD = 512      # feature-dim slab


def _sim_kernel(mask_any_ref, x_ref, y_ref, mask_ref, out_ref, *, bd, d):
    """One [bg,bg] output tile. x_ref/y_ref: [bg, d] row/col slabs in VMEM;
    mask_ref: [bg,bg] bool; mask_any_ref: [1,1] tile-level early-out flag
    (scalar prefetch)."""
    bg = out_ref.shape[0]

    @pl.when(mask_any_ref[0, 0] > 0)
    def compute():
        acc = jnp.zeros((bg, bg), jnp.float32)
        xx = jnp.zeros((bg,), jnp.float32)
        yy = jnp.zeros((bg,), jnp.float32)
        n_slabs = d // bd
        for s in range(n_slabs):
            xs = x_ref[:, s * bd:(s + 1) * bd].astype(jnp.float32)
            ys = y_ref[:, s * bd:(s + 1) * bd].astype(jnp.float32)
            acc += jax.lax.dot_general(
                xs, ys, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            xx += jnp.sum(xs * xs, axis=1)
            yy += jnp.sum(ys * ys, axis=1)
        inv = jax.lax.rsqrt(xx[:, None] * yy[None, :] + 1e-8)
        sim = (acc * inv + 1.0) * 0.5
        out_ref[...] = jnp.where(mask_ref[...], sim, 0.0)

    @pl.when(mask_any_ref[0, 0] == 0)
    def skip():
        out_ref[...] = jnp.zeros_like(out_ref)


def mask_tile_fraction(mask, bg: int = DEFAULT_BG) -> float:
    """Host-side: fraction of [bg, bg] output tiles with ≥1 True entry —
    the tiles the kernel must actually compute (everything else hits the
    early-out). The condensation-backend benchmark reports this per
    backend to show the LSH bucketing win at tile granularity."""
    import numpy as np
    m = np.asarray(mask)
    G = m.shape[-1]
    b = min(bg, G)
    if G % b:
        pad = b - G % b
        m = np.pad(m, [(0, 0)] * (m.ndim - 2) + [(0, pad), (0, pad)])
        G = m.shape[-1]
    nt = G // b
    tiles = m.reshape(m.shape[:-2] + (nt, b, nt, b)).any(axis=(-3, -1))
    return float(tiles.mean())


@functools.partial(jax.jit, static_argnames=("bg", "bd", "interpret"))
def masked_similarity(x, mask, *, bg: int = DEFAULT_BG,
                      bd: int = DEFAULT_BD,
                      interpret: Optional[bool] = None):
    """x: [G, d]; mask: [G, G] bool. Returns [G, G] f32 similarity in
    [0,1], zeroed where mask is False; fully-masked tiles are skipped.

    ``interpret=None`` (default) resolves by backend like the other
    kernels: the compiled Mosaic kernel on TPU, interpreter mode
    elsewhere. Pass an explicit bool to override (tests force True)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    G, d = x.shape
    bg = min(bg, G)
    bd = min(bd, d)
    assert G % bg == 0
    if d % bd != 0:                      # pad features (zero rows are
        pad = bd - d % bd                # harmless for dot & norms)
        x = jnp.pad(x, ((0, 0), (0, pad)))
        d = x.shape[1]
    nt = G // bg
    # tile-level early-out flags, computed on the host side of the kernel
    mask_tiles = mask.reshape(nt, bg, nt, bg).any(axis=(1, 3))
    mask_any = mask_tiles.astype(jnp.int32)

    grid = (nt, nt)
    return pl.pallas_call(
        functools.partial(_sim_kernel, bd=bd, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),          # mask_any
            pl.BlockSpec((bg, d), lambda i, j: (i, 0)),          # rows
            pl.BlockSpec((bg, d), lambda i, j: (j, 0)),          # cols
            pl.BlockSpec((bg, bg), lambda i, j: (i, j)),         # mask
        ],
        out_specs=pl.BlockSpec((bg, bg), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((G, G), jnp.float32),
        interpret=interpret,
    )(mask_any, x, x, mask)
