import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination with ShapeDtypeStruct stand-ins (no allocation), record
memory_analysis / cost_analysis / per-collective bytes for the roofline.

Usage:
    python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k
    python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
    python -m repro.launch.dryrun --all [--jobs 6]   # orchestrate subprocesses
"""

import argparse
import json
import re
import subprocess
import sys
import time
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# shared with hlo_analysis (ISSUE 9) — the two copies used to drift
from repro.comm.dtypes import DTYPE_BYTES as _DTYPE_BYTES

_COLLECTIVES = ("all-to-all", "all-gather", "all-reduce", "reduce-scatter",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string like 'bf16[128,48,514]{2,1,0}' or a
    tuple '(f32[2,3], s32[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 0


def parse_collectives(hlo_text: str):
    """Sum result-operand bytes of every collective op in optimized HLO."""
    out = {c: {"bytes": 0, "count": 0, "ops": []} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        lhs, rhs = ls.split(" = ", 1)
        for c in _COLLECTIVES:
            # match the op name at the start of the rhs expression,
            # e.g. "bf16[...] all-to-all(" — not fused-computation refs
            m = re.match(r"^((?:\([^)]*\))|(?:[\w\[\]{},: ]+?))\s+"
                         + re.escape(c) + r"(-start|-done)?\(", rhs)
            if m:
                if m.group(2) == "-done":
                    continue       # counted at -start
                b = _shape_bytes(m.group(1))
                g = _group_size(ls)
                out[c]["bytes"] += b
                out[c]["count"] += 1
                if len(out[c]["ops"]) < 40:
                    out[c]["ops"].append({"bytes": b, "groups": g})
                break
    return out


def comm_traffic_ledger(cfg, shape, mesh, *, nodes: int = 0,
                        exec_chunks: int = 0, plan_reuse: str = "off",
                        similarity_backend: str = "exact",
                        lsh_bits: int = 8, condense_reuse: str = "off",
                        hier_dedup: str = "off",
                        wire_dtype: str = "f32",
                        condense_group: int = 128,
                        calibration=None,
                        autotune_applied: bool = False):
    """Analytic per-step dispatch traffic split by link tier (DESIGN.md §5)
    plus the modeled compute/communication overlap (§6).

    One :func:`repro.plan.estimate_exchange` call per condensation rate
    bucket — the SAME per-phase estimate the plan builder attaches to
    every :class:`~repro.plan.ExchangePlan` (the ledger reports plan
    numbers, it does not recompute them): bytes a flat all-to-all ships
    across nodes vs. the hierarchical path after per-node dedup, and the
    pipelined MoE-sublayer time — at exactly ``exec_chunks`` chunks when
    the run executed a pipeline, else at the 1..16 planning optimum
    (dispatch and combine priced on the hier bytes, expert FFN on the
    peak-FLOP roofline). On a flat mesh the ledger prices a hypothetical
    ``nodes``-way split of the model axis (default 4) — the planning
    number for moving to a hierarchical deployment.

    ``calibration`` (a ``repro.obs.calibrate.Calibration``) swaps every
    hand-set pricing constant for the measured fit: link bandwidths and
    latencies (via ``Calibration.topology``), the per-chunk pipeline
    overhead, the FFN roofline, and the planning/similarity step costs.
    The returned JSON carries ``schema_version`` (see
    ``repro.obs.metrics.COMM_LEDGER_SCHEMA_VERSION``); the golden-schema
    test pins its key sets."""
    from repro import comm as rcomm
    from repro.core.moe_layer import capacity_for
    from repro.launch.mesh import (DCN_BW, ICI_BW, PEAK_FLOPS_BF16,
                                   topology_for_mesh)
    from repro.plan import estimate_exchange
    from repro.sched import plan_chunks
    names = tuple(mesh.axis_names)
    if "node" in names:
        topo = topology_for_mesh(mesh)
    else:
        M = dict(zip(names, mesh.devices.shape)).get("model", 1)
        nodes = nodes or min(4, M)
        if M % nodes or M // nodes < 1:
            return None
        topo = rcomm.Topology(nodes, M // nodes,
                              intra_bw=ICI_BW, inter_bw=DCN_BW)
    if not topo.hierarchical or not cfg.uses_moe:
        return None
    from repro.obs.metrics import COMM_LEDGER_SCHEMA_VERSION
    if calibration is not None:
        topo = calibration.topology(topo)
    peak_flops = (calibration.ffn_speed if calibration is not None
                  else PEAK_FLOPS_BF16)
    est_kw = (calibration.estimate_kwargs() if calibration is not None
              else {})
    tokens = shape.global_batch * shape.seq_len
    k = cfg.moe.top_k
    out = {"schema_version": COMM_LEDGER_SCHEMA_VERSION,
           "calibration": (calibration.key if calibration is not None
                           else None),
           "topology": {"nodes": topo.num_nodes,
                        "devices_per_node": topo.devices_per_node,
                        "bw_ratio": topo.bw_ratio},
           "dedup_factor": rcomm.expected_dedup_factor(k, topo),
           "buckets": {}}
    for r in (0.0, 0.25, 0.5):
        # dispatch ≈ combine on the hier bytes; expert FFN at the bf16
        # roofline (or the measured fit) spread over the expert shards
        ffn_flops = (tokens * (1.0 - r) * k * 4 * cfg.d_model
                     * cfg.moe.d_ff * cfg.num_layers)
        ffn_ms = ffn_flops / (peak_flops * topo.num_devices) * 1e3
        if exec_chunks > 0:      # report the executed configuration,
            # with the executor's own capacity clipping (plan_chunks
            # caps the chunk count at this bucket's capacity / 8)
            cap = capacity_for(cfg.moe, tokens // mesh.devices.size,
                               cfg.moe.num_experts, rate=r)
            chunks = plan_chunks(cap, exec_chunks).n_chunks
        else:                    # planning search
            chunks = None
        est = estimate_exchange(tokens, k, cfg.d_model, topo=topo,
                                r_cond=r, num_layers=cfg.num_layers,
                                ffn_ms=ffn_ms, chunks=chunks,
                                wire_dtype=wire_dtype, **est_kw)
        out["buckets"][str(r)] = {
            "flat": {"intra_bytes": est.flat_intra_dispatch_bytes,
                     "inter_bytes": est.flat_inter_dispatch_bytes,
                     "time_s": est.flat_dispatch_ms / 1e3},
            "hier": {"intra_bytes": est.intra_dispatch_bytes,
                     "inter_bytes": est.inter_dispatch_bytes,
                     "time_s": est.dispatch_ms / 1e3},
            "overlap": {"ffn_ms": est.ffn_ms, "sync_ms": est.sync_ms,
                        "pipelined_ms": est.overlap_ms,
                        "chunks": est.chunks,
                        "speedup": est.speedup},
        }

    # ---- wire precision ledger (DESIGN.md §14) ---------------------------
    # The bucket byte/time fields above are already priced at this wire
    # dtype (estimate_exchange scales bytes_per_el by 1/wire_precision);
    # this section records the dtype and the exact per-row arithmetic so
    # a reader can undo or cross-check the scaling. bytes_per_el 4
    # matches estimate_exchange's default compute itemsize.
    from repro.comm import dtypes as wire_dtypes
    # Per-execution-mode shipped inter-node bytes (schema v6): the dedup
    # wire is universal (DESIGN.md §15), so vanilla / migrate / pipelined
    # all ship the per-node-deduplicated payload when it is on — the
    # three fields are equal by construction and exist so a reader (and
    # the golden-schema test) can see the mode scope is closed, not
    # implied. Dispatch bytes are mode-independent (experts never move),
    # which is why one number covers all three.
    b0w = out["buckets"]["0.0"]
    shipped = (b0w["hier"]["inter_bytes"] if hier_dedup == "on"
               else b0w["flat"]["inter_bytes"])
    out["wire"] = {
        "dtype": wire_dtype,
        "precision": wire_dtypes.wire_precision(cfg.d_model, wire_dtype, 4),
        "row_bytes": wire_dtypes.wire_row_bytes(cfg.d_model, wire_dtype, 4),
        "row_bytes_f32": (cfg.d_model + 2) * 4,
        "scale_block": wire_dtypes.SCALE_BLOCK,
        "shipped_vanilla_bytes": shipped,
        "shipped_migrate_bytes": shipped,
        "shipped_pipelined_bytes": shipped,
    }

    # ---- plan-reuse ledger (DESIGN.md §9) --------------------------------
    # Modeled under stable routing (the regime reuse exists for): with
    # plan_reuse on, one full replan per forward seeds the carried plan
    # and every later MoE sublayer revalidates instead of replanning.
    from repro.plan import estimate_planning_ms, estimate_revalidate_ms
    n_moe = sum(1 for i in range(cfg.num_layers)
                if cfg.ffn_kind(i) == "moe")
    M = topo.num_devices
    # migrate-mode training shards the batch over ALL mesh axes (the
    # planner only runs when seq_axis is None; see dist.make_dist), so
    # per-device n_seq is global_batch / mesh size and the planner sees
    # M * n_seq global slots
    n_seq_local = max(1, shape.global_batch // mesh.devices.size)
    n_slots = M * n_seq_local
    built = n_moe if plan_reuse == "off" else min(1, n_moe)
    reused = n_moe - built
    plan_ms = (estimate_planning_ms(n_slots, M,
                                    step_us=calibration.plan_step_us)
               if calibration is not None
               else estimate_planning_ms(n_slots, M))
    reval_ms = estimate_revalidate_ms(n_slots, M)
    # "always" trusts the carry without the signature compare, so it
    # pays no revalidation cost; "signature" checks every reused layer
    checks = reused if plan_reuse == "signature" else 0
    out["plan_reuse"] = {
        "mode": plan_reuse,
        "moe_sublayers": n_moe,
        "n_slots": n_slots,
        "plans_built_per_step": built,
        "plans_reused_per_step": reused,
        "revalidation_mismatches": 0,      # stable-routing model
        "planning_ms_per_plan": plan_ms,
        "revalidate_ms_per_check": reval_ms,
        "planning_ms_saved_per_step": reused * plan_ms
        - checks * reval_ms,
    }

    # ---- condensation ledger (DESIGN.md §10) -----------------------------
    # Per-backend measured-pair model (uniform first-block routing), the
    # dedup-wire bytes (modeled inter_bytes_dedup == shipped when the
    # wire is on — the executor asserts the traced equality), and the
    # condense-plan build/reuse counters under the same stable-routing
    # model as plan_reuse above.
    from repro.condense import expected_measured_pairs
    from repro.plan import estimate_similarity_ms
    G = min(condense_group, shape.seq_len)
    tokens_l = max(1, tokens // mesh.devices.size)   # per-device groups
    pairs = {b: expected_measured_pairs(
        tokens_l, G, cfg.moe.num_experts, backend=b, lsh_bits=lsh_bits)
        * mesh.devices.size
        for b in ("exact", "lsh")}
    # one build runs per device in parallel: price the per-device share
    sim_kw = ({"speed": calibration.sim_speed}
              if calibration is not None else {})
    sim_ms = {b: estimate_similarity_ms(p / mesh.devices.size,
                                        cfg.d_model, **sim_kw)
              for b, p in pairs.items()}
    b0 = out["buckets"]["0.0"]
    c_built = n_moe if condense_reuse == "off" else min(1, n_moe)
    c_reused = n_moe - c_built
    out["condensation"] = {
        "backend": similarity_backend,
        "group_size": G,
        "lsh_bits": lsh_bits,
        "measured_pairs_per_step": pairs,
        "similarity_ms_per_build": sim_ms,
        "dedup_wire": {
            "enabled": hier_dedup == "on",
            "modeled_inter_bytes": b0["hier"]["inter_bytes"],
            "flat_inter_bytes": b0["flat"]["inter_bytes"],
            "shipped_inter_bytes": (b0["hier"]["inter_bytes"]
                                    if hier_dedup == "on" else
                                    b0["flat"]["inter_bytes"]),
        },
        "condense_plan": {
            "mode": condense_reuse,
            "built_per_step": c_built,
            "reused_per_step": c_reused,
            "similarity_ms_saved_per_step":
                c_reused * sim_ms[similarity_backend],
        },
    }

    # ---- decode ledger (DESIGN.md §13) -----------------------------------
    # The serving decode step on this fabric: one [B, d_model] combine
    # all-reduce per MoE sublayer (moe_decode_allreduce — no all-to-all
    # at decode) plus the shared-expert FFN, and what the
    # "decode_overlap" exec mode saves by issuing the psum concurrently
    # with those matmuls. Modeled with the SAME sched.cost functions the
    # autotune grid prices the decode_ms term with; archs without shared
    # experts (shared_ffn_ms 0) show speedup 1.0 — there is nothing to
    # hide the wire behind.
    from repro.sched.cost import decode_combine_ms, decode_step_ms
    dec_tokens = shape.global_batch          # one live token per sequence
    dec_combine = decode_combine_ms(dec_tokens, cfg.d_model, topo)
    dec_shared = (dec_tokens * 4.0 * cfg.d_model * cfg.moe.d_ff
                  * cfg.moe.num_shared_experts / peak_flops * 1e3)
    dec_sync = decode_step_ms(combine_ms=dec_combine,
                              shared_ffn_ms=dec_shared,
                              overlap=False) * n_moe
    dec_ovl = decode_step_ms(combine_ms=dec_combine,
                             shared_ffn_ms=dec_shared,
                             overlap=True) * n_moe
    out["decode"] = {
        "tokens": dec_tokens,
        "combine_ms": dec_combine,
        "shared_ffn_ms": dec_shared,
        "sync_ms": dec_sync,
        "overlap_ms": dec_ovl,
        "modeled_speedup": dec_sync / max(dec_ovl, 1e-12),
    }

    # ---- autotune ledger (DESIGN.md §12) ---------------------------------
    # The calibration-driven knob search over THIS ledger's topology and
    # pricing constants: chosen config + modeled step time vs the repo
    # defaults. `applied` records whether the run actually resolved a
    # TunedConfig into its compiled LuffyConfig (--autotune) — the
    # section itself is always modeled, so every dryrun artifact reports
    # what tuning WOULD buy on its fabric. Defaults are always in the
    # grid, so modeled_step_ms <= default_step_ms by construction
    # (swept by benchmarks/fig_autotune.py).
    from repro.obs.autotune import autotune_config
    tuned = autotune_config(
        topo=topo, tokens=tokens, top_k=k, d_model=cfg.d_model,
        d_ff=cfg.moe.d_ff, num_layers=cfg.num_layers,
        n_moe=max(1, n_moe), n_slots=n_slots,
        num_experts=cfg.moe.num_experts,
        mesh_devices=mesh.devices.size, group_size=G,
        plan_reuse=plan_reuse, condense_reuse=condense_reuse,
        calib=calibration, ffn_speed=peak_flops)
    out["autotune"] = {
        "applied": bool(autotune_applied),
        "key": tuned.key,
        "knobs": dict(tuned.knobs),
        "modeled_step_ms": tuned.modeled_step_ms,
        "default_step_ms": tuned.default_step_ms,
        "modeled_savings_ms": tuned.modeled_savings_ms,
        "candidates": tuned.candidates,
    }
    return out


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             out_path: Path, *, luffy_on: bool = True,
             bucket: int = 0, variant: str = "baseline",
             nodes: int = 0, exec_mode: str = None,
             pipeline_chunks: int = None, plan_objective: str = None,
             plan_reuse: str = "off", similarity_backend: str = None,
             lsh_bits: int = None, condense_reuse: str = "off",
             hier_dedup: str = None, wire_dtype: str = None,
             calibration_path: str = "",
             autotune_dir: str = "", autotune_force: bool = False):
    import jax
    import jax.numpy as jnp
    from repro import optim, serve_lib, train_lib
    from repro.config import (SHAPES, LuffyConfig, OptimConfig,
                              resolve_pipeline_chunks)
    from repro.configs import get_config
    from repro.dist import make_dist
    from repro.launch.mesh import (PEAK_FLOPS_BF16, make_production_mesh,
                                   topology_for_mesh)
    from repro.obs import autotune as obs_at

    t0 = time.time()
    cfg = get_config(arch)
    calibration = None
    if calibration_path:
        from repro.obs.calibrate import Calibration
        calibration = Calibration.from_json(
            Path(calibration_path).read_text())
        if calibration is None:
            raise ValueError(
                f"unreadable calibration artifact: {calibration_path} "
                "(wrong magic, schema drift, or malformed)")
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod, nodes=nodes)

    # knob resolution (DESIGN.md §12): explicit args > tuned artifact
    # (--autotune) > historical defaults. comm_mode stays structural —
    # it is pinned by the mesh axes the --nodes split built.
    cli = {"exec_mode": exec_mode, "pipeline_chunks": pipeline_chunks,
           "plan_objective": plan_objective,
           "similarity_backend": similarity_backend,
           "lsh_bits": lsh_bits, "hier_dedup": hier_dedup,
           "wire_dtype": wire_dtype}
    explicit = {k for k, v in cli.items() if v is not None}
    comm_mode = "hier" if nodes > 1 else "flat"
    tuned = None
    if autotune_dir and cfg.uses_moe:
        at_topo = topology_for_mesh(mesh)
        n_moe_l = sum(1 for i in range(cfg.num_layers)
                      if cfg.ffn_kind(i) == "moe")
        n_seq_l = max(1, shape.global_batch // mesh.devices.size)
        tuned = obs_at.run_autotune(
            topo=at_topo, out_dir=autotune_dir, force=autotune_force,
            tokens=shape.global_batch * shape.seq_len,
            top_k=cfg.moe.top_k, d_model=cfg.d_model,
            d_ff=cfg.moe.d_ff, num_layers=cfg.num_layers,
            n_moe=max(1, n_moe_l),
            n_slots=at_topo.num_devices * n_seq_l,
            num_experts=cfg.moe.num_experts,
            mesh_devices=mesh.devices.size,
            group_size=min(128, shape.seq_len), plan_reuse=plan_reuse,
            condense_reuse=condense_reuse, calib=calibration,
            ffn_speed=PEAK_FLOPS_BF16)
        print(f"autotune {tuned.key}: {tuned.knobs} modeled "
              f"{tuned.modeled_step_ms:.3f}ms vs default "
              f"{tuned.default_step_ms:.3f}ms")
    knobs = dict(obs_at.DEFAULT_KNOBS)
    knobs["pipeline_chunks"] = None    # sentinel: resolve by objective
    if tuned is not None:
        knobs.update({k: v for k, v in tuned.knobs.items()
                      if k not in explicit and k != "comm_mode"})
    knobs.update({k: v for k, v in cli.items() if v is not None})
    if "hier_dedup" not in explicit and knobs["hier_dedup"] == "on" \
            and comm_mode != "hier":
        knobs["hier_dedup"] = "off"   # dedup wire needs hier comm; it
                                      # is otherwise universal (§15)
    if knobs["pipeline_chunks"] is None:
        knobs["pipeline_chunks"] = resolve_pipeline_chunks(
            None, knobs["plan_objective"])
    exec_mode = knobs["exec_mode"]
    pipeline_chunks = knobs["pipeline_chunks"]
    plan_objective = knobs["plan_objective"]
    similarity_backend = knobs["similarity_backend"]
    lsh_bits = knobs["lsh_bits"]
    hier_dedup = knobs["hier_dedup"]
    wire_dtype = knobs["wire_dtype"]

    from repro.models.model import build_model
    mesh_tag = "x".join(str(d) for d in mesh.devices.shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "variant": variant, "exec_mode": exec_mode,
           "plan_objective": plan_objective, "plan_reuse": plan_reuse,
           "autotuned": tuned is not None,
           "status": "unknown"}

    if shape_name == "long_500k" and not cfg.supports_long_decode:
        rec["status"] = "skipped"
        rec["reason"] = "full-attention arch; long_500k skipped (DESIGN.md)"
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"SKIP {arch} {shape_name}")
        return rec

    dist = make_dist(mesh, shape.mode, shape.global_batch,
                     moe_arch=cfg.uses_moe)
    model = build_model(cfg)
    pstruct = model.init_struct()
    pspecs = model.param_pspecs(dist, pstruct)

    def with_sharding(struct, specs):
        return jax.tree.map(
            lambda s, p: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=dist.sharding(p)),
            struct, specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    params_in = with_sharding(pstruct, pspecs)
    luffy = LuffyConfig(
        enable_condensation=luffy_on and cfg.uses_moe,
        enable_migration=luffy_on and cfg.uses_moe,
        comm_mode=comm_mode,
        exec_mode=exec_mode, pipeline_chunks=pipeline_chunks,
        plan_objective=plan_objective, plan_reuse=plan_reuse,
        similarity_backend=similarity_backend, lsh_bits=lsh_bits,
        condense_reuse=condense_reuse, hier_dedup=hier_dedup,
        wire_dtype=wire_dtype)

    if shape.mode == "train":
        # 100B+ models: full f32 Adam moments cannot fit 16GB/chip even at
        # maximal sharding — use Adafactor (production choice; DESIGN.md)
        ocfg = OptimConfig(name="adafactor"
                           if cfg.param_count() > 1e11 else "adamw")
        rec["optimizer"] = ocfg.name
        ostruct = jax.eval_shape(
            lambda p: optim.init_opt_state(p, ocfg), pstruct)
        from jax.sharding import PartitionSpec as P
        mu_specs, nu_specs = model.opt_moment_pspecs(dist, ocfg, pstruct)
        opt_in = optim.OptState(
            jax.ShapeDtypeStruct((), jnp.int32,
                                 sharding=dist.sharding(P())),
            with_sharding(ostruct.mu, mu_specs),
            with_sharding(ostruct.nu, nu_specs))
        lstate_in = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=dist.sharding(P())),
            jax.eval_shape(train_lib.init_luffy_state))
        batch_in = model.input_specs(shape, dist)
        if cfg.uses_moe:
            cap = train_lib.capacity_for_bucket(cfg, shape, dist, luffy,
                                                bucket)
        else:
            cap = 8
        step = train_lib.make_train_step(cfg, luffy, ocfg, dist, cap,
                                         param_pspecs=pspecs)
        fn = jax.jit(step, donate_argnums=(0, 1))
        lowered = fn.lower(params_in, opt_in, lstate_in, batch_in)
    elif shape.mode == "prefill":
        batch_in = model.input_specs(shape, dist)

        def pf(params, batch):
            return model.prefill(
                params, batch["tokens"], shape.seq_len, luffy=luffy,
                dist=dist, prefix=batch.get("prefix"),
                enc_input=batch.get("enc_input"))[0]

        lowered = jax.jit(pf).lower(params_in, batch_in)
    else:  # decode
        cache_in, _ = model.cache_specs(shape, dist)
        batch_in = model.input_specs(shape, dist)

        def dec(params, cache, batch):
            return model.decode_step(params, cache, batch["tokens"],
                                     luffy=luffy, dist=dist)

        lowered = jax.jit(dec, donate_argnums=(1,)).lower(
            params_in, cache_in, batch_in)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # old jax: list of per-program dicts
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    # loop-corrected analysis: cost_analysis counts while (scan) bodies
    # once; our models scan over layer groups (see hlo_analysis.py)
    from repro.launch import hlo_analysis
    corrected = hlo_analysis.analyze(hlo)

    # Analytic per-device static memory (exact, backend-independent):
    # NOTE the CPU backend emulates bf16 dots by materializing f32 operand
    # copies, inflating temp_bytes for bf16 archs vs real TPU (DESIGN.md).
    def sharded_bytes(struct, specs):
        import numpy as _np
        from jax.sharding import PartitionSpec as _P
        ax_size = dict(zip(mesh.axis_names, mesh.devices.shape))
        leaves = jax.tree.leaves(struct, is_leaf=lambda x: isinstance(
            x, jax.ShapeDtypeStruct))
        sl = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, _P))
        out = 0
        for leaf, spec in zip(leaves, sl):
            factor = 1
            for entry in (spec or ()):
                if entry is None:
                    continue
                for ax in (entry if isinstance(entry, tuple) else (entry,)):
                    factor *= ax_size[ax]
            out += int(_np.prod(leaf.shape)) * leaf.dtype.itemsize // factor
        return out

    analytic = {"param_bytes_per_device": sharded_bytes(pstruct, pspecs)}
    if shape.mode == "train":
        analytic["opt_moment_bytes_per_device"] = (
            sharded_bytes(ostruct.mu, mu_specs)
            + sharded_bytes(ostruct.nu, nu_specs))
    if shape.mode == "decode":
        analytic["cache_bytes_per_device"] = sharded_bytes(
            cache_in, model.cache_specs(shape, dist)[1])

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "num_devices": mesh.devices.size,
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        },
        "cost": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": {k: {"bytes": v["bytes"], "count": v["count"],
                            "ops": v["ops"]}
                        for k, v in coll.items()},
        "corrected": {
            "flops": corrected["flops"],
            "bytes_touched": corrected["bytes_touched"],
            "collectives": {k: {"bytes": v["bytes"], "count": v["count"],
                                "wire_bytes": v["wire_bytes"],
                                "wire_bytes_f32": v["wire_bytes_f32"]}
                            for k, v in corrected["collectives"].items()},
            "loop_multipliers": corrected["loop_multipliers"],
        },
        "model": {
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
        },
        "analytic": analytic,
        "comm_ledger": (comm_traffic_ledger(
            cfg, shape, mesh, nodes=nodes,
            exec_chunks=(pipeline_chunks if exec_mode == "pipeline"
                         else 0), plan_reuse=plan_reuse,
            similarity_backend=similarity_backend, lsh_bits=lsh_bits,
            condense_reuse=condense_reuse, hier_dedup=hier_dedup,
            wire_dtype=wire_dtype,
            condense_group=luffy.condense_group,
            calibration=calibration,
            autotune_applied=tuned is not None)
                        if shape.mode == "train" else None),
    })
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    tot_coll = sum(v["bytes"] for v in coll.values())
    print(f"OK {arch} {shape_name} {rec['mesh']} [{variant}] "
          f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
          f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
          f"flops={ca.get('flops', 0):.3g} coll={tot_coll/2**20:.1f}MiB")
    return rec


def pair_list():
    from repro.config import SHAPES
    from repro.configs import ARCHS, get_config
    pairs = []
    for arch in ARCHS[:10]:                 # the 10 assigned archs
        for shape in SHAPES:
            pairs.append((arch, shape))
    # the paper's own models, at their evaluation context (training)
    for arch in ARCHS[10:]:
        pairs.append((arch, "train_4k"))
    return pairs


def orchestrate(jobs: int, multi_pod_also: bool = True,
                only_missing: bool = True):
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    work = []
    for arch, shape in pair_list():
        for mp in ([False, True] if multi_pod_also else [False]):
            mesh_tag = "2x16x16" if mp else "16x16"
            out = ARTIFACTS / f"{arch}__{shape}__{mesh_tag}.json"
            if only_missing and out.exists():
                try:
                    if json.loads(out.read_text()).get("status") in (
                            "ok", "skipped"):
                        continue
                except Exception:
                    pass
            work.append((arch, shape, mp, out))
    print(f"{len(work)} dry-run jobs, {jobs} parallel")
    procs = []
    while work or procs:
        while work and len(procs) < jobs:
            arch, shape, mp, out = work.pop(0)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", str(out)]
            if mp:
                cmd.append("--multi-pod")
            logf = open(str(out) + ".log", "w")
            procs.append((subprocess.Popen(
                cmd, stdout=logf, stderr=subprocess.STDOUT,
                env={**os.environ, "PYTHONPATH": "src"},
                cwd=str(ARTIFACTS.parents[1])), arch, shape, mp, out, logf,
                time.time()))
        still = []
        for p, arch, shape, mp, out, logf, t0 in procs:
            if p.poll() is None:
                if time.time() - t0 > 3600:
                    p.kill()
                    print(f"TIMEOUT {arch} {shape} mp={mp}")
                else:
                    still.append((p, arch, shape, mp, out, logf, t0))
            else:
                logf.close()
                tag = "2x16x16" if mp else "16x16"
                ok = out.exists()
                print(f"[{time.strftime('%H:%M:%S')}] done {arch} {shape} "
                      f"{tag} rc={p.returncode} artifact={ok}")
        procs = still
        time.sleep(3)
    print("orchestration complete")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--bucket", type=int, default=0)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--no-luffy", action="store_true")
    ap.add_argument("--nodes", type=int, default=0,
                    help="hierarchical mesh: split the model axis into "
                         "this many nodes (comm_mode=hier)")
    ap.add_argument("--exec-mode",
                    choices=["sync", "pipeline", "decode_overlap"],
                    default=None,
                    help="MoE execution schedule: strict order, chunked "
                         "pipeline with overlap (DESIGN.md §6), or the "
                         "decode combine/shared-FFN overlap (DESIGN.md "
                         "§13 — prices like sync on the train path; "
                         "default sync)")
    ap.add_argument("--pipeline-chunks", type=int, default=None,
                    help="capacity chunks for --exec-mode pipeline "
                         "(default 4; under --plan-objective overlap "
                         "the estimate search picks the count)")
    ap.add_argument("--plan-objective", default=None,
                    choices=["traffic", "overlap", "replicate"],
                    help="migration planner objective (DESIGN.md §7; "
                         "\"replicate\" adds intra-node hot-expert "
                         "replicas, DESIGN.md §15; default traffic)")
    ap.add_argument("--plan-reuse", default="off",
                    choices=["off", "signature", "always"],
                    help="cross-layer plan reuse; also selects the "
                         "comm_ledger plan_reuse section's modeled "
                         "mode (DESIGN.md §9)")
    ap.add_argument("--similarity-backend", default=None,
                    choices=["exact", "lsh"],
                    help="condensation similarity backend "
                         "(repro.condense.backends, DESIGN.md §10; "
                         "default exact)")
    ap.add_argument("--lsh-bits", type=int, default=None,
                    help="projections per LSH bucket code (default 8)")
    ap.add_argument("--condense-reuse", default="off",
                    choices=["off", "signature", "always"],
                    help="cross-layer condense-plan reuse; also selects "
                         "the comm_ledger condensation section's "
                         "modeled mode (DESIGN.md §10)")
    ap.add_argument("--hier-dedup", default=None, choices=["off", "on"],
                    help="deduplicated hier wire format "
                         "(repro.condense.wire; needs --nodes > 1; "
                         "default off)")
    ap.add_argument("--wire-dtype", default=None,
                    choices=["f32", "bf16", "f8e4m3"],
                    help="precision activation rows ship at on node-"
                         "crossing exchange hops (DESIGN.md §14); the "
                         "comm_ledger's wire section and bucket bytes "
                         "are priced at it (default f32)")
    ap.add_argument("--autotune", default="",
                    help="TunedConfig artifact dir (repro.obs.autotune): "
                         "fill every knob the CLI left unset from the "
                         "tuned artifact for this mesh's topology "
                         "(explicit flags always override; DESIGN.md "
                         "§12)")
    ap.add_argument("--autotune-force", action="store_true",
                    help="re-run the autotune search even when a valid "
                         "artifact exists")
    ap.add_argument("--calibration", default="",
                    help="path to a repro.obs.calibrate artifact "
                         "(*.calib.json): price the comm_ledger with "
                         "the measured fit instead of the hand-set "
                         "constants")
    ap.add_argument("--metrics-json", default="",
                    help="also append the flattened comm_ledger as one "
                         "unified metrics record (repro.obs.metrics "
                         "JSONL) to this path")
    args = ap.parse_args()
    from repro.config import resolve_pipeline_chunks
    if args.all:
        orchestrate(args.jobs)
        return
    # knob resolution happens in run_pair (None = "not set", so
    # --autotune can fill it); the artifact tag reflects only what the
    # CLI pinned explicitly
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    if args.nodes > 1:
        mesh_tag += f"__hier{args.nodes}"
    if args.exec_mode == "pipeline":
        chunks = (args.pipeline_chunks if args.pipeline_chunks is not None
                  else resolve_pipeline_chunks(
                      None, args.plan_objective or "traffic"))
        mesh_tag += f"__pipe{chunks}"
    if args.plan_objective not in (None, "traffic"):
        mesh_tag += f"__{args.plan_objective}"
    if args.plan_reuse != "off":
        mesh_tag += f"__reuse-{args.plan_reuse}"
    if args.similarity_backend not in (None, "exact"):
        mesh_tag += f"__{args.similarity_backend}"
    if args.condense_reuse != "off":
        mesh_tag += f"__creuse-{args.condense_reuse}"
    if args.hier_dedup == "on":
        mesh_tag += "__dedup"
    if args.wire_dtype not in (None, "f32"):
        mesh_tag += f"__wd-{args.wire_dtype}"
    if args.autotune:
        mesh_tag += "__autotuned"
    out = Path(args.out) if args.out else \
        ARTIFACTS / f"{args.arch}__{args.shape}__{mesh_tag}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    try:
        rec = run_pair(args.arch, args.shape, args.multi_pod, out,
                       luffy_on=not args.no_luffy, bucket=args.bucket,
                       variant=args.variant, nodes=args.nodes,
                       exec_mode=args.exec_mode,
                       pipeline_chunks=args.pipeline_chunks,
                       plan_objective=args.plan_objective,
                       plan_reuse=args.plan_reuse,
                       similarity_backend=args.similarity_backend,
                       lsh_bits=args.lsh_bits,
                       condense_reuse=args.condense_reuse,
                       hier_dedup=args.hier_dedup,
                       wire_dtype=args.wire_dtype,
                       calibration_path=args.calibration,
                       autotune_dir=args.autotune,
                       autotune_force=args.autotune_force)
        if args.metrics_json and rec.get("comm_ledger"):
            from repro.obs import metrics as obs_metrics
            flat = obs_metrics.flatten("comm_ledger", rec["comm_ledger"])
            record = {"schema_version":
                      obs_metrics.METRICS_SCHEMA_VERSION,
                      "arch": args.arch, "shape": args.shape,
                      "mesh": rec["mesh"], "metrics": flat}
            obs_metrics.write_jsonl(args.metrics_json, record)
    except Exception as e:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": mesh_tag,
               "variant": args.variant, "status": "error",
               "error": f"{type(e).__name__}: {e}"}
        out.write_text(json.dumps(rec, indent=1))
        raise


if __name__ == "__main__":
    main()
