"""Loop-aware analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` (and any naive op-counting over the
HLO) counts a ``while`` body ONCE — but our models scan over layer
groups, so per-layer FLOPs/bytes/collectives execute ``trip_count``
times. This module parses the optimized HLO:

  * builds the computation call graph (ENTRY → while bodies → …),
  * reads each while's trip count from its ``backend_config``
    ``known_trip_count`` (falling back to the constant in the condition),
  * multiplies every op's cost by the product of enclosing trip counts,

returning loop-corrected totals: dot FLOPs, bytes touched (≈2×result
size per op — a traffic proxy), and per-collective operand/on-wire bytes.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

# One dtype-size table for every HLO byte accounter (ISSUE 9): dryrun
# and this module used to keep drifting private copies.
from repro.comm.dtypes import DTYPE_BYTES as _DTYPE_BYTES

_COLLECTIVES = ("all-to-all", "all-gather", "all-reduce",
                "reduce-scatter", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_TRIP_BC = re.compile(r'known_trip_count[":{]+n["\s:]+"?(\d+)')
_TRIP_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DOT_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> Tuple[int, int]:
    """(elements, bytes) summed over every shape literal in the string."""
    elems = total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def parse_hlo(text: str):
    comps: Dict[str, List[Tuple[str, str]]] = {}
    entry = None
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):
            m = _COMP_HDR.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if line.strip() == "}":
            continue
        if cur is not None:
            m = _OP_RE.match(line)
            if m:
                comps[cur].append((m.group(1), m.group(2)))
    return comps, entry


def computation_multipliers(comps, entry) -> Dict[str, float]:
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps or m <= 0:
            return
        mult[name] = max(mult.get(name, 0.0), m)
        for _, rhs in comps[name]:
            if re.search(r"\bwhile\(", rhs):
                trip = 1
                bc = _TRIP_BC.search(rhs)
                mc = re.search(r"condition=%?([\w.\-]+)", rhs)
                if bc:
                    trip = int(bc.group(1))
                elif mc and mc.group(1) in comps:
                    consts = [int(x.group(1)) for _, r2 in comps[mc.group(1)]
                              for x in _TRIP_CONST.finditer(r2)]
                    trip = max(consts) if consts else 1
                mb = re.search(r"body=%?([\w.\-]+)", rhs)
                if mb:
                    visit(mb.group(1), m * trip)
                continue
            for mm in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)", rhs):
                visit(mm.group(1), m)
            mm = re.search(r"branch_computations=\{([^}]*)\}", rhs)
            if mm:
                for b in mm.group(1).split(","):
                    visit(b.strip().lstrip("%"), m)
    visit(entry, 1.0)
    return mult


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


def _wire_bytes(kind: str, result_bytes: float, g: int) -> float:
    g = max(g, 1)
    if kind in ("all-gather", "all-to-all"):
        return result_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    return result_bytes  # collective-permute


def analyze(text: str):
    comps, entry = parse_hlo(text)
    mult = computation_multipliers(comps, entry)
    flops = 0.0
    bytes_touched = 0.0
    coll = {c: {"bytes": 0.0, "count": 0.0, "wire_bytes": 0.0,
                "wire_bytes_f32": 0.0, "ops": []}
            for c in _COLLECTIVES}
    for cname, ops in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        shape_of = {}
        for lhs, rhs in ops:
            if rhs.startswith("("):
                # tuple result type: span to the matching close paren
                depth, end = 0, len(rhs)
                for i, ch in enumerate(rhs):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i + 1
                            break
                head = rhs[:end]
            else:
                head = rhs.split("(")[0] if "(" in rhs else rhs
            elems, b = _shape_bytes(head)
            shape_of[lhs] = head
            bytes_touched += 2.0 * b * m
            if re.search(r"\bdot\(", rhs):
                k = 1
                mc = _DOT_CONTRACT.search(rhs)
                ma = re.search(r"dot\(([^)]*)\)", rhs)
                if mc and ma:
                    arg0 = ma.group(1).split(",")[0].strip().lstrip("%")
                    lh = shape_of.get(arg0)
                    if lh is None:
                        for l2, r2 in ops:
                            if l2 == arg0:
                                lh = r2.split("(")[0]
                                break
                    if lh is not None:
                        sm = _SHAPE_RE.search(lh)
                        if sm:
                            dims = [int(d) for d in sm.group(2).split(",")
                                    if d]
                            for c in (int(x) for x in
                                      mc.group(1).split(",") if x):
                                if c < len(dims):
                                    k *= dims[c]
                flops += 2.0 * elems * k * m
                continue
            for c in _COLLECTIVES:
                if re.search(rf"\b{re.escape(c)}(-start)?\(", rhs):
                    if f"{c}-done" in rhs:
                        break
                    g = _group_size(rhs)
                    wire = _wire_bytes(c, b, g) * m
                    coll[c]["bytes"] += b * m
                    coll[c]["count"] += m
                    coll[c]["wire_bytes"] += wire
                    # f32 payloads are usually CPU bf16-dot emulation
                    # artifacts (converts commuted before the collective);
                    # track them so the roofline can report a TPU-native
                    # bf16 estimate (f32 share halves).
                    if head.lstrip("( ").startswith("f32"):
                        coll[c]["wire_bytes_f32"] += wire
                    if len(coll[c]["ops"]) < 24:
                        coll[c]["ops"].append(
                            {"bytes": b, "groups": g, "mult": m,
                             "dtype": head.lstrip("( ").split("[")[0]})
                    break
    return {"flops": flops, "bytes_touched": bytes_touched,
            "collectives": coll,
            "loop_multipliers": {k: v for k, v in mult.items() if v > 1}}
