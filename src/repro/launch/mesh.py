"""Production meshes + the physical topologies that back them.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices.

Flat meshes carry a ``model`` expert-parallel axis; hierarchical meshes
(``nodes > 1``) split it into ``("node", "local")`` so the comm
subsystem can run two-phase collectives over the bandwidth hierarchy
(DESIGN.md §5). Device order is node-major, matching
``repro.comm.Topology``.

Target hardware: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI intra-node and ~12 GB/s DCN across nodes (constants
used by the roofline and the topology-aware traffic model).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.comm import Topology, make_mesh
from repro.comm.topology import DEFAULT_INTER_BW, DEFAULT_INTRA_BW


def make_production_mesh(*, multi_pod: bool = False, nodes: int = 0):
    """16×16 pod (or 2×16×16 multi-pod). ``nodes > 1`` splits the model
    axis into a (node, local) hierarchy of that many nodes."""
    if nodes > 1:
        model = 16
        assert model % nodes == 0, (model, nodes)
        shape = (2, 16, nodes, model // nodes) if multi_pod \
            else (16, nodes, model // nodes)
        axes = ("pod", "data", "node", "local") if multi_pod \
            else ("data", "node", "local")
        return make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 4, nodes: int = 0):
    """Small mesh over whatever devices exist (CPU testing). ``nodes > 1``
    builds the hierarchical ("data", "node", "local") layout."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    if nodes > 1:
        assert model % nodes == 0, (model, nodes)
        return make_mesh((data, nodes, model // nodes),
                         ("data", "node", "local"))
    return make_mesh((data, model), ("data", "model"))


def topology_for_mesh(mesh, *, intra_bw: Optional[float] = None,
                      inter_bw: Optional[float] = None) -> Topology:
    """The hardware topology backing a mesh, priced with the constants
    below unless overridden."""
    return Topology.from_mesh(mesh, intra_bw=intra_bw or ICI_BW,
                              inter_bw=inter_bw or DCN_BW)


# Hardware constants for the roofline / topology pricing (per chip).
# Link bandwidths live in repro.comm.topology (the pricing source of
# truth); these aliases keep the roofline's historical import path.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = DEFAULT_INTRA_BW     # B/s per link (~50 GB/s, intra-node)
DCN_BW = DEFAULT_INTER_BW     # B/s per link (~12 GB/s, cross-node DCN)
