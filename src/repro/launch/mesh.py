"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices.

Target hardware: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (constants used by the roofline, see benchmarks/).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 4):
    """Small mesh over whatever devices exist (CPU testing)."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# Hardware constants for the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 4.9e10               # B/s per link (~50 GB/s)
