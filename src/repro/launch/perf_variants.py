"""§Perf hillclimb variant runner.

Runs the three chosen (arch × shape) pairs under before/after variants
(env flags + condensation buckets), writing variant-tagged artifacts to
artifacts/perf/. EXPERIMENTS.md §Perf is written from these.

    PYTHONPATH=src python -m repro.launch.perf_variants
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3]
OUT = ROOT / "artifacts" / "perf"

# (arch, shape, variant_name, env, extra_args)
VARIANTS = [
    # H1 — gemma3 prefill_32k: windowed-band chunk skipping
    ("gemma3-12b", "prefill_32k", "band_off",
     {"REPRO_ATTN_BAND": "0"}, []),
    ("gemma3-12b", "prefill_32k", "band_on",
     {"REPRO_ATTN_BAND": "1"}, []),
    # H2 — llama4 decode_32k: Megatron-style 2D expert decode
    ("llama4-maverick-400b-a17b", "decode_32k", "decode2d_off",
     {"REPRO_MOE_DECODE_2D": "0"}, []),
    ("llama4-maverick-400b-a17b", "decode_32k", "decode2d_on",
     {"REPRO_MOE_DECODE_2D": "1"}, []),
    # H3 — olmoe train_4k: condensation capacity buckets (the paper's
    # technique becoming real wire savings) + LUFFY fully off
    ("olmoe-1b-7b", "train_4k", "noluffy", {}, ["--no-luffy"]),
    ("olmoe-1b-7b", "train_4k", "bucket0", {}, ["--bucket", "0"]),
    ("olmoe-1b-7b", "train_4k", "bucket1", {}, ["--bucket", "1"]),
    ("olmoe-1b-7b", "train_4k", "bucket2", {}, ["--bucket", "2"]),
    # H1b — hymba prefill_32k: SSM scan unroll (chunked-scan insight)
    ("hymba-1.5b", "prefill_32k", "unroll1",
     {"REPRO_SSM_UNROLL": "1"}, []),
    ("hymba-1.5b", "prefill_32k", "unroll8",
     {"REPRO_SSM_UNROLL": "8"}, []),
]


def main(jobs: int = 4):
    OUT.mkdir(parents=True, exist_ok=True)
    work = []
    for arch, shape, var, env, extra in VARIANTS:
        out = OUT / f"{arch}__{shape}__{var}.json"
        if out.exists():
            try:
                if json.loads(out.read_text()).get("status") == "ok":
                    continue
            except Exception:
                pass
        work.append((arch, shape, var, env, extra, out))
    print(f"{len(work)} perf-variant jobs")
    procs = []
    while work or procs:
        while work and len(procs) < jobs:
            arch, shape, var, env, extra, out = work.pop(0)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", str(out),
                   "--variant", var] + extra
            full_env = {**os.environ, "PYTHONPATH": "src", **env}
            logf = open(str(out) + ".log", "w")
            procs.append((subprocess.Popen(
                cmd, stdout=logf, stderr=subprocess.STDOUT, env=full_env,
                cwd=str(ROOT)), var, out, logf, time.time()))
            print("launched", arch, shape, var)
        still = []
        for pr, var, out, logf, t0 in procs:
            if pr.poll() is None:
                if time.time() - t0 > 3600:
                    pr.kill()
                else:
                    still.append((pr, var, out, logf, t0))
            else:
                logf.close()
                print(f"done {var} rc={pr.returncode}")
        procs = still
        time.sleep(3)
    print("perf variants complete")


if __name__ == "__main__":
    main()
