"""Batched serving driver: prefill a prompt batch, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b \
        --reduced --batch 4 --prompt-len 32 --gen 16

The MoE sublayers run through the same ``repro.plan`` build/execute core
as training (DESIGN.md §7), so the execution-schedule knobs apply here
too: ``--exec-mode pipeline`` chunks the prefill dispatch capacity and
overlaps the expert collectives with compute, ``--prefill batch`` runs
one whole-prompt ``serve_lib.prefill`` pass through that executor (and
times it) before the cache-building decode loop.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moe-gpt2")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-axis", type=int, default=4)
    ap.add_argument("--prefill", choices=["step", "batch"], default="step",
                    help="step: feed the prompt token-by-token (cache-"
                         "correct for every arch family); batch: also run "
                         "one whole-prompt prefill through the shared "
                         "build/execute MoE core (times the pipelined "
                         "serving forward)")
    ap.add_argument("--exec-mode", choices=["sync", "pipeline"],
                    default=None,
                    help="MoE execution schedule for prefill/decode "
                         "sublayers: strict order or chunked software "
                         "pipeline with compute/comm overlap "
                         "(bit-identical; DESIGN.md §6; default sync)")
    ap.add_argument("--pipeline-chunks", type=int, default=None,
                    help="capacity chunks for --exec-mode pipeline "
                         "(default 4; under --plan-objective overlap "
                         "the estimate search picks the count)")
    ap.add_argument("--plan-cache", default="",
                    help="directory for the serialized ExchangePlan "
                         "cache (DESIGN.md §9): prefill looks up "
                         "precomputed static plans by batch-shape key "
                         "and executes them without planning")
    ap.add_argument("--precompute-plans", action="store_true",
                    help="warm --plan-cache with this run's prefill "
                         "shape before serving (ahead-of-time planning)")
    ap.add_argument("--hier-dedup", default=None, choices=["off", "on"],
                    help="deduplicated hier wire format on the batched "
                         "prefill exchange (repro.condense.wire, "
                         "DESIGN.md §10): each prompt token's payload "
                         "crosses the inter-node links once per (token, "
                         "node) — serving never condenses, but the "
                         "top-k copy dedup still applies. Needs a "
                         "hierarchical mesh; the flat host mesh keeps "
                         "the dense wire")
    ap.add_argument("--plan-objective", default=None,
                    choices=["traffic", "overlap"],
                    help="migration planner objective (DESIGN.md §7; "
                         "default traffic). RESERVED for a future "
                         "serving migration mode: today serving forces "
                         "migration off (prompts are never re-homed), "
                         "so both choices build identical vanilla plans "
                         "— the flag only threads the config through "
                         "for parity with train/dryrun")
    ap.add_argument("--autotune", default="",
                    help="TunedConfig artifact dir (repro.obs.autotune): "
                         "fill the execution knobs the CLI left unset "
                         "from the tuned artifact for this mesh's "
                         "topology (explicit flags always override; "
                         "DESIGN.md §12)")
    ap.add_argument("--autotune-force", action="store_true",
                    help="re-run the autotune search even when a valid "
                         "artifact exists")
    ap.add_argument("--trace", action="store_true",
                    help="step tracing (repro.obs.trace): fenced spans "
                         "around batched prefill, the step-wise prompt "
                         "feed and every decode step; writes "
                         "Chrome-trace JSON (see --trace-out)")
    ap.add_argument("--trace-out", default="",
                    help="trace JSON path (implies --trace; default "
                         "trace.json)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import serve_lib
    from repro.config import LuffyConfig, reduced
    from repro.configs import get_config
    from repro.dist import DistContext, make_dist, single_device
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if len(jax.devices()) > 1:
        mesh = make_host_mesh(model=args.model_axis)
        dist = make_dist(mesh, "decode", args.batch, moe_arch=cfg.uses_moe)
    else:
        dist = single_device()
    # knob resolution (DESIGN.md §12): explicit flags > tuned artifact
    # (--autotune) > defaults. Serving never migrates or condenses, so
    # only the execution knobs are taken from the artifact.
    from repro.config import resolve_pipeline_chunks
    from repro.obs import autotune as obs_at
    serve_knobs = ("exec_mode", "pipeline_chunks", "plan_objective",
                   "hier_dedup")
    explicit = {k for k in serve_knobs
                if getattr(args, k) is not None}
    tuned = None
    if args.autotune and cfg.uses_moe:
        from repro.comm.topology import Topology
        at_topo = (Topology.from_mesh(mesh) if len(jax.devices()) > 1
                   else Topology.flat(1))
        tuned = obs_at.run_autotune(
            topo=at_topo, out_dir=args.autotune,
            force=args.autotune_force,
            tokens=args.batch * args.prompt_len,
            top_k=cfg.moe.top_k, d_model=cfg.d_model,
            d_ff=cfg.moe.d_ff, num_layers=cfg.num_layers,
            n_slots=args.batch, num_experts=cfg.moe.num_experts,
            group_size=min(128, args.prompt_len))
        print(f"autotune {tuned.key}: {tuned.knobs} modeled "
              f"{tuned.modeled_step_ms:.3f}ms vs default "
              f"{tuned.default_step_ms:.3f}ms")
    knobs = dict(obs_at.DEFAULT_KNOBS)
    knobs["pipeline_chunks"] = None    # sentinel: resolve by objective
    if tuned is not None:
        knobs.update({k: v for k, v in tuned.knobs.items()
                      if k in serve_knobs and k not in explicit})
    for k in explicit:
        knobs[k] = getattr(args, k)
    if "hier_dedup" not in explicit and knobs["hier_dedup"] == "on" \
            and knobs["exec_mode"] != "sync":
        knobs["hier_dedup"] = "off"   # dedup wire is sync scope
    if knobs["pipeline_chunks"] is None:
        knobs["pipeline_chunks"] = resolve_pipeline_chunks(
            None, knobs["plan_objective"])
    pipeline_chunks = knobs["pipeline_chunks"]
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False,
                        exec_mode=knobs["exec_mode"],
                        pipeline_chunks=pipeline_chunks,
                        plan_objective=knobs["plan_objective"],
                        hier_dedup=knobs["hier_dedup"])
    print(f"exec_mode={luffy.exec_mode} chunks={pipeline_chunks} "
          f"plan_objective={luffy.plan_objective} "
          f"plan_cache={args.plan_cache or 'off'}")

    from repro.obs import trace as obs_trace
    trace_out = args.trace_out or ("trace.json" if args.trace else "")
    tracer = None
    if trace_out:
        tracer = obs_trace.Tracer(fence=True)
        obs_trace.activate(tracer)

    r = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(r.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    s_max = S + args.gen
    plan_cache = None
    if args.plan_cache:
        from repro.plan.cache import PlanCache
        plan_cache = PlanCache(args.plan_cache)
        if args.prefill != "batch":
            print("WARNING: --plan-cache only engages on the batched "
                  "prefill path; pass --prefill batch (the step-wise "
                  "prompt feed never builds exchange plans)")
    if args.prefill == "batch":
        # whole-prompt forward through the shared build/execute MoE core
        # (the pipelined serving path inherited from repro.plan)
        if len(jax.devices()) > 1:
            pdist = make_dist(mesh, "prefill", B, moe_arch=cfg.uses_moe)
        else:
            pdist = single_device()
        if plan_cache is not None and args.precompute_plans \
                and cfg.uses_moe:
            import dataclasses as _dc
            from repro.plan.cache import precompute_prefill_plans
            nl = _dc.replace(luffy, enable_condensation=False,
                             enable_migration=False)
            key = precompute_prefill_plans(cfg, nl, pdist, B, S,
                                           plan_cache)
            print(f"precomputed prefill plan: {key}")
        pf = jax.jit(lambda p, t: model.prefill(
            p, t, s_max, luffy=luffy, dist=pdist,
            plan_cache=plan_cache)[0])
        logits_pf = pf(params, prompts)
        jax.block_until_ready(logits_pf)
        t0 = time.time()
        with obs_trace.phase("prefill_batch", cat="step") as _sp:
            logits_pf = jax.block_until_ready(pf(params, prompts))
        dt = time.time() - t0
        print(f"batched prefill({B}x{S} tokens): {dt:.3f}s "
              f"({B * S / max(dt, 1e-9):.0f} tok/s)")
        if plan_cache is not None:
            print(f"plan cache: {plan_cache.stats()}")
    t0 = time.time()
    cache = serve_lib.cache_struct(cfg, B, s_max, as_struct=False)
    dec = jax.jit(lambda p, c, t: serve_lib.decode_step(
        p, cfg, luffy, dist, c, t))
    # feed the prompt token by token (cache-correct for every arch family)
    logits = None
    with obs_trace.phase("prefill_step", cat="step", tokens=S) as _sp:
        for t in range(S):
            logits, cache = dec(params, cache, prompts[:, t:t + 1])
        logits = _sp.fence(logits)
    print(f"prefill({S} tokens): {time.time()-t0:.2f}s")
    out = []
    t0 = time.time()
    for i in range(args.gen):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(np.asarray(nxt[:, 0]))
        with obs_trace.phase("decode", cat="step", step=i) as _sp:
            logits, cache = dec(params, cache, nxt)
            logits = _sp.fence(logits)
    dt = time.time() - t0
    toks = int(np.asarray(out).size)
    print(f"decode: {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s batch={B})")
    print("sample token ids:", [int(x) for x in np.asarray(out)[:, 0][:10]])
    if tracer is not None:
        obs_trace.deactivate()
        tracer.write(trace_out)
        print(f"trace: {len(tracer.events)} events -> {trace_out}")


if __name__ == "__main__":
    main()
