"""Batched serving driver: prefill a prompt batch, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b \
        --reduced --batch 4 --prompt-len 32 --gen 16

The MoE sublayers run through the same ``repro.plan`` build/execute core
as training (DESIGN.md §7), so the execution-schedule knobs apply here
too: ``--exec-mode pipeline`` chunks the prefill dispatch capacity and
overlaps the expert collectives with compute, ``--prefill batch`` runs
one whole-prompt ``serve_lib.prefill`` pass through that executor (and
times it) before the cache-building decode loop.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moe-gpt2")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-axis", type=int, default=4)
    ap.add_argument("--prefill", choices=["step", "batch"], default="step",
                    help="step: feed the prompt token-by-token (cache-"
                         "correct for every arch family); batch: also run "
                         "one whole-prompt prefill through the shared "
                         "build/execute MoE core (times the pipelined "
                         "serving forward)")
    ap.add_argument("--exec-mode", choices=["sync", "pipeline"],
                    default="sync",
                    help="MoE execution schedule for prefill/decode "
                         "sublayers: strict order or chunked software "
                         "pipeline with compute/comm overlap "
                         "(bit-identical; DESIGN.md §6)")
    ap.add_argument("--pipeline-chunks", type=int, default=None,
                    help="capacity chunks for --exec-mode pipeline "
                         "(default 4; under --plan-objective overlap "
                         "the estimate search picks the count)")
    ap.add_argument("--plan-cache", default="",
                    help="directory for the serialized ExchangePlan "
                         "cache (DESIGN.md §9): prefill looks up "
                         "precomputed static plans by batch-shape key "
                         "and executes them without planning")
    ap.add_argument("--precompute-plans", action="store_true",
                    help="warm --plan-cache with this run's prefill "
                         "shape before serving (ahead-of-time planning)")
    ap.add_argument("--hier-dedup", default="off", choices=["off", "on"],
                    help="deduplicated hier wire format on the batched "
                         "prefill exchange (repro.condense.wire, "
                         "DESIGN.md §10): each prompt token's payload "
                         "crosses the inter-node links once per (token, "
                         "node) — serving never condenses, but the "
                         "top-k copy dedup still applies. Needs a "
                         "hierarchical mesh; the flat host mesh keeps "
                         "the dense wire")
    ap.add_argument("--plan-objective", default="traffic",
                    choices=["traffic", "overlap"],
                    help="migration planner objective (DESIGN.md §7). "
                         "RESERVED for a future serving migration mode: "
                         "today serving forces migration off (prompts "
                         "are never re-homed), so both choices build "
                         "identical vanilla plans — the flag only "
                         "threads the config through for parity with "
                         "train/dryrun")
    ap.add_argument("--trace", action="store_true",
                    help="step tracing (repro.obs.trace): fenced spans "
                         "around batched prefill, the step-wise prompt "
                         "feed and every decode step; writes "
                         "Chrome-trace JSON (see --trace-out)")
    ap.add_argument("--trace-out", default="",
                    help="trace JSON path (implies --trace; default "
                         "trace.json)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import serve_lib
    from repro.config import LuffyConfig, reduced
    from repro.configs import get_config
    from repro.dist import DistContext, make_dist, single_device
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if len(jax.devices()) > 1:
        mesh = make_host_mesh(model=args.model_axis)
        dist = make_dist(mesh, "decode", args.batch, moe_arch=cfg.uses_moe)
    else:
        dist = single_device()
    from repro.config import resolve_pipeline_chunks
    pipeline_chunks = resolve_pipeline_chunks(args.pipeline_chunks,
                                              args.plan_objective)
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False,
                        exec_mode=args.exec_mode,
                        pipeline_chunks=pipeline_chunks,
                        plan_objective=args.plan_objective,
                        hier_dedup=args.hier_dedup)
    print(f"exec_mode={args.exec_mode} chunks={pipeline_chunks} "
          f"plan_objective={args.plan_objective} "
          f"plan_cache={args.plan_cache or 'off'}")

    from repro.obs import trace as obs_trace
    trace_out = args.trace_out or ("trace.json" if args.trace else "")
    tracer = None
    if trace_out:
        tracer = obs_trace.Tracer(fence=True)
        obs_trace.activate(tracer)

    r = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(r.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    s_max = S + args.gen
    plan_cache = None
    if args.plan_cache:
        from repro.plan.cache import PlanCache
        plan_cache = PlanCache(args.plan_cache)
        if args.prefill != "batch":
            print("WARNING: --plan-cache only engages on the batched "
                  "prefill path; pass --prefill batch (the step-wise "
                  "prompt feed never builds exchange plans)")
    if args.prefill == "batch":
        # whole-prompt forward through the shared build/execute MoE core
        # (the pipelined serving path inherited from repro.plan)
        if len(jax.devices()) > 1:
            pdist = make_dist(mesh, "prefill", B, moe_arch=cfg.uses_moe)
        else:
            pdist = single_device()
        if plan_cache is not None and args.precompute_plans \
                and cfg.uses_moe:
            import dataclasses as _dc
            from repro.plan.cache import precompute_prefill_plans
            nl = _dc.replace(luffy, enable_condensation=False,
                             enable_migration=False)
            key = precompute_prefill_plans(cfg, nl, pdist, B, S,
                                           plan_cache)
            print(f"precomputed prefill plan: {key}")
        pf = jax.jit(lambda p, t: model.prefill(
            p, t, s_max, luffy=luffy, dist=pdist,
            plan_cache=plan_cache)[0])
        logits_pf = pf(params, prompts)
        jax.block_until_ready(logits_pf)
        t0 = time.time()
        with obs_trace.phase("prefill_batch", cat="step") as _sp:
            logits_pf = jax.block_until_ready(pf(params, prompts))
        dt = time.time() - t0
        print(f"batched prefill({B}x{S} tokens): {dt:.3f}s "
              f"({B * S / max(dt, 1e-9):.0f} tok/s)")
        if plan_cache is not None:
            print(f"plan cache: {plan_cache.stats()}")
    t0 = time.time()
    cache = serve_lib.cache_struct(cfg, B, s_max, as_struct=False)
    dec = jax.jit(lambda p, c, t: serve_lib.decode_step(
        p, cfg, luffy, dist, c, t))
    # feed the prompt token by token (cache-correct for every arch family)
    logits = None
    with obs_trace.phase("prefill_step", cat="step", tokens=S) as _sp:
        for t in range(S):
            logits, cache = dec(params, cache, prompts[:, t:t + 1])
        logits = _sp.fence(logits)
    print(f"prefill({S} tokens): {time.time()-t0:.2f}s")
    out = []
    t0 = time.time()
    for i in range(args.gen):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(np.asarray(nxt[:, 0]))
        with obs_trace.phase("decode", cat="step", step=i) as _sp:
            logits, cache = dec(params, cache, nxt)
            logits = _sp.fence(logits)
    dt = time.time() - t0
    toks = int(np.asarray(out).size)
    print(f"decode: {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s batch={B})")
    print("sample token ids:", [int(x) for x in np.asarray(out)[:, 0][:10]])
    if tracer is not None:
        obs_trace.deactivate()
        tracer.write(trace_out)
        print(f"trace: {len(tracer.events)} events -> {trace_out}")


if __name__ == "__main__":
    main()
