"""Serving driver: fixed batches or continuous batching (DESIGN.md §13).

    PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b \
        --reduced --batch 4 --prompt-len 32 --gen 16

The MoE sublayers run through the same ``repro.plan`` build/execute core
as training (DESIGN.md §7), so the execution-schedule knobs apply here
too: ``--exec-mode pipeline`` chunks the prefill dispatch capacity and
overlaps the expert collectives with compute, ``--exec-mode
decode_overlap`` issues the decode combine psum concurrently with the
shared-expert FFN, ``--prefill batch`` runs one whole-prompt
``serve.prefill`` pass through that executor (and times it) before the
cache-building decode loop.

``--continuous`` switches the unit of work from a step to a *request*
(repro.serve.scheduler): a synthetic bursty-arrivals workload is
admitted into free cache slots between decode steps, finished sequences
are evicted so their slots recycle mid-stream, and per-request SLOs
(queue/TTFT/per-token latency) flow through the ``repro.obs`` metrics
registry (``--metrics-json``). With ``--plan-cache --precompute-plans``
the decode template is warmed ahead of time, so the steady-state loop
makes zero ``build_exchange_plan`` calls.
"""
from __future__ import annotations

import argparse
import time


def _serve_continuous(args, cfg, luffy, dist, params, plan_cache,
                      registry):
    """The continuous-batching request loop (one decode step per
    iteration; admissions and evictions happen between steps)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.obs import trace as obs_trace
    from repro.serve import engine
    from repro.serve.scheduler import ContinuousScheduler

    B, S = args.batch, args.prompt_len
    # per-slot relative frames: one occupant never holds more than
    # prompt + gen positions, no matter how long the run is
    s_max = S + args.gen
    r = np.random.default_rng(0)
    prompts = r.integers(1, cfg.vocab_size,
                         (args.requests, S)).astype(np.int32)
    # synthetic bursty arrivals: bursts of --burst requests land
    # together every --arrival-every decode steps
    arrival_step = [(i // max(1, args.burst)) * max(1, args.arrival_every)
                    for i in range(args.requests)]

    if plan_cache is not None and args.precompute_plans and cfg.uses_moe:
        from repro.plan.cache import precompute_decode_plans
        key = precompute_decode_plans(cfg, luffy, dist, B, plan_cache)
        print(f"precomputed decode plan: {key}")

    cache = engine.cache_struct(cfg, B, s_max, as_struct=False)
    dec = jax.jit(lambda p, c, t: engine.decode_step(
        p, cfg, luffy, dist, c, t, plan_cache=plan_cache))
    sched = ContinuousScheduler(B)
    step = 0
    submitted = 0
    t0 = time.time()
    while step < args.max_steps:
        now = time.time()
        while submitted < args.requests \
                and arrival_step[submitted] <= step:
            sched.submit(prompts[submitted], args.gen, now=now)
            submitted += 1
        if sched.all_done():
            if submitted >= args.requests:
                break
            step += 1          # idle until the next burst lands
            continue
        for slot, _req in sched.admit(now=now):
            cache = engine.admit_slot(cache, slot, int(cache["pos"]))
        toks = sched.next_feed()
        with obs_trace.phase("decode", cat="step", step=step,
                             active=sched.active_slots) as _sp:
            logits, cache = dec(params, cache, jnp.asarray(toks))
            logits = _sp.fence(logits)
        sched.observe(np.asarray(logits), now=time.time())
        if registry is not None:
            from repro.obs.metrics import write_jsonl
            write_jsonl(args.metrics_json,
                        registry.observe(step, sched.step_metrics()))
        step += 1
    dt = time.time() - t0
    done = sched.done
    tok = sched.generated_tokens
    print(f"continuous: {len(done)}/{args.requests} requests, "
          f"{tok} tokens in {dt:.2f}s ({tok / max(dt, 1e-9):.1f} tok/s), "
          f"{step} steps, slot_churn={sched.slot_churn}")
    if done:
        def _mean(name):
            vals = [getattr(q, name) for q in done]
            vals = [v for v in vals if v is not None]
            return float(np.mean(vals)) if vals else float("nan")
        print(f"SLO: queue {_mean('queue_ms'):.1f}ms "
              f"ttft {_mean('ttft_ms'):.1f}ms "
              f"tpot {_mean('tpot_ms'):.1f}ms")
    if plan_cache is not None:
        print(f"plan cache: {plan_cache.stats()}")
    if sched.queue or sched.active_slots:
        print(f"WARNING: --max-steps hit with {len(sched.queue)} queued "
              f"and {sched.active_slots} active requests")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moe-gpt2")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-axis", type=int, default=4)
    ap.add_argument("--prefill", choices=["step", "batch"], default="step",
                    help="step: feed the prompt token-by-token (cache-"
                         "correct for every arch family); batch: also run "
                         "one whole-prompt prefill through the shared "
                         "build/execute MoE core (times the pipelined "
                         "serving forward)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching (repro.serve.scheduler, "
                         "DESIGN.md §13): a synthetic bursty-arrivals "
                         "workload of --requests prompts is admitted "
                         "into free cache slots between decode steps "
                         "and evicted on finish — slot reuse instead of "
                         "fixed batches; per-request SLOs go to "
                         "--metrics-json")
    ap.add_argument("--requests", type=int, default=8,
                    help="total synthetic requests for --continuous")
    ap.add_argument("--burst", type=int, default=3,
                    help="requests arriving together per burst "
                         "(--continuous)")
    ap.add_argument("--arrival-every", type=int, default=4,
                    help="decode steps between bursts (--continuous)")
    ap.add_argument("--max-steps", type=int, default=512,
                    help="hard step budget for --continuous (guards "
                         "against an undrainable queue)")
    ap.add_argument("--exec-mode",
                    choices=["sync", "pipeline", "decode_overlap"],
                    default=None,
                    help="MoE execution schedule: strict order, chunked "
                         "software pipeline with compute/comm overlap "
                         "on prefill (bit-identical; DESIGN.md §6), or "
                         "the decode combine psum issued concurrently "
                         "with the shared-expert FFN (bit-identical; "
                         "DESIGN.md §13; default sync)")
    ap.add_argument("--pipeline-chunks", type=int, default=None,
                    help="capacity chunks for --exec-mode pipeline "
                         "(default 4; under --plan-objective overlap "
                         "the estimate search picks the count)")
    ap.add_argument("--plan-cache", default="",
                    help="directory for the serialized ExchangePlan "
                         "cache (DESIGN.md §9): prefill AND decode look "
                         "up precomputed static plans by batch-shape "
                         "key and execute them without planning")
    ap.add_argument("--precompute-plans", action="store_true",
                    help="warm --plan-cache with this run's prefill "
                         "and decode shapes before serving "
                         "(ahead-of-time planning)")
    ap.add_argument("--hier-dedup", default=None, choices=["off", "on"],
                    help="deduplicated hier wire format on the batched "
                         "prefill exchange (repro.condense.wire, "
                         "DESIGN.md §10): each prompt token's payload "
                         "crosses the inter-node links once per (token, "
                         "node) — serving never condenses, but the "
                         "top-k copy dedup still applies. Needs a "
                         "hierarchical mesh; the flat host mesh keeps "
                         "the dense wire")
    ap.add_argument("--plan-objective", default=None,
                    choices=["traffic", "overlap"],
                    help="migration planner objective (DESIGN.md §7; "
                         "default traffic). RESERVED for a future "
                         "serving migration mode: today serving forces "
                         "migration off (prompts are never re-homed), "
                         "so both choices build identical vanilla plans "
                         "— the flag only threads the config through "
                         "for parity with train/dryrun")
    ap.add_argument("--similarity-backend", default=None,
                    choices=["exact", "lsh"],
                    help="condensation similarity backend (DESIGN.md "
                         "§10; default exact). Serving never condenses "
                         "— the flag threads the config through for "
                         "parity with train/dryrun and, with the "
                         "PR-7 precedence, overrides a TunedConfig's "
                         "backend choice explicitly")
    ap.add_argument("--lsh-bits", type=int, default=None,
                    help="signed random projections per LSH bucket code "
                         "(default 8; parity flag, see "
                         "--similarity-backend)")
    ap.add_argument("--wire-dtype", default=None,
                    choices=["f32", "bf16", "f8e4m3"],
                    help="precision activation rows ship at on node-"
                         "crossing exchange hops (DESIGN.md §14): "
                         "identity wire, bf16 cast, or f8e4m3 with "
                         "per-32-element f32 scales; part of the plan "
                         "cache key (default f32)")
    ap.add_argument("--condense-reuse", default="off",
                    choices=["off", "signature", "always"],
                    help="cross-layer condense-plan reuse (DESIGN.md "
                         "§10; parity flag — serving forces "
                         "condensation off, so this only threads the "
                         "config through like train/dryrun)")
    ap.add_argument("--autotune", default="",
                    help="TunedConfig artifact dir (repro.obs.autotune): "
                         "fill the execution knobs the CLI left unset "
                         "from the tuned artifact for this mesh's "
                         "topology (explicit flags always override; "
                         "DESIGN.md §12)")
    ap.add_argument("--autotune-force", action="store_true",
                    help="re-run the autotune search even when a valid "
                         "artifact exists")
    ap.add_argument("--metrics-json", default="",
                    help="append unified metrics records (repro.obs."
                         "metrics JSONL): one batched-prefill row plus "
                         "one row per decode step (serve/* SLO and "
                         "occupancy keys under --continuous)")
    ap.add_argument("--trace", action="store_true",
                    help="step tracing (repro.obs.trace): fenced spans "
                         "around batched prefill, the step-wise prompt "
                         "feed and every decode step; writes "
                         "Chrome-trace JSON (see --trace-out)")
    ap.add_argument("--trace-out", default="",
                    help="trace JSON path (implies --trace; default "
                         "trace.json)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import serve_lib
    from repro.config import LuffyConfig, reduced
    from repro.configs import get_config
    from repro.dist import DistContext, make_dist, single_device
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if len(jax.devices()) > 1:
        mesh = make_host_mesh(model=args.model_axis)
        dist = make_dist(mesh, "decode", args.batch, moe_arch=cfg.uses_moe)
    else:
        dist = single_device()
    # knob resolution (DESIGN.md §12): explicit flags > tuned artifact
    # (--autotune) > defaults. Serving never migrates or condenses, so
    # the execution knobs plus the similarity pair (parity with train —
    # an explicit --similarity-backend beats the artifact's choice) are
    # taken from the artifact.
    from repro.config import resolve_pipeline_chunks
    from repro.obs import autotune as obs_at
    serve_knobs = ("exec_mode", "pipeline_chunks", "plan_objective",
                   "hier_dedup", "similarity_backend", "lsh_bits",
                   "wire_dtype")
    explicit = {k for k in serve_knobs
                if getattr(args, k) is not None}
    tuned = None
    if args.autotune and cfg.uses_moe:
        from repro.comm.topology import Topology
        at_topo = (Topology.from_mesh(mesh) if len(jax.devices()) > 1
                   else Topology.flat(1))
        tuned = obs_at.run_autotune(
            topo=at_topo, out_dir=args.autotune,
            force=args.autotune_force,
            tokens=args.batch * args.prompt_len,
            top_k=cfg.moe.top_k, d_model=cfg.d_model,
            d_ff=cfg.moe.d_ff, num_layers=cfg.num_layers,
            n_slots=args.batch, num_experts=cfg.moe.num_experts,
            group_size=min(128, args.prompt_len),
            # decode workload term (DESIGN.md §13): lets the grid see
            # what decode_overlap buys on this arch and fabric
            decode_tokens=args.batch,
            d_ff_shared=cfg.moe.d_ff * cfg.moe.num_shared_experts)
        print(f"autotune {tuned.key}: {tuned.knobs} modeled "
              f"{tuned.modeled_step_ms:.3f}ms vs default "
              f"{tuned.default_step_ms:.3f}ms")
    knobs = dict(obs_at.DEFAULT_KNOBS)
    knobs["pipeline_chunks"] = None    # sentinel: resolve by objective
    if tuned is not None:
        knobs.update({k: v for k, v in tuned.knobs.items()
                      if k in serve_knobs and k not in explicit})
    for k in explicit:
        knobs[k] = getattr(args, k)
    if "hier_dedup" not in explicit and knobs["hier_dedup"] == "on":
        knobs["hier_dedup"] = "off"   # serving runs comm_mode="flat";
                                      # the dedup wire needs hier comm
    if knobs["pipeline_chunks"] is None:
        knobs["pipeline_chunks"] = resolve_pipeline_chunks(
            None, knobs["plan_objective"])
    pipeline_chunks = knobs["pipeline_chunks"]
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False,
                        exec_mode=knobs["exec_mode"],
                        pipeline_chunks=pipeline_chunks,
                        plan_objective=knobs["plan_objective"],
                        similarity_backend=knobs["similarity_backend"],
                        lsh_bits=knobs["lsh_bits"],
                        condense_reuse=args.condense_reuse,
                        hier_dedup=knobs["hier_dedup"],
                        wire_dtype=knobs["wire_dtype"])
    print(f"exec_mode={luffy.exec_mode} chunks={pipeline_chunks} "
          f"plan_objective={luffy.plan_objective} "
          f"similarity_backend={luffy.similarity_backend} "
          f"plan_cache={args.plan_cache or 'off'}")

    from repro.obs import trace as obs_trace
    trace_out = args.trace_out or ("trace.json" if args.trace else "")
    tracer = None
    if trace_out:
        tracer = obs_trace.Tracer(fence=True)
        obs_trace.activate(tracer)
    registry = None
    if args.metrics_json:
        from repro.obs.metrics import MetricsRegistry
        registry = MetricsRegistry(luffy=luffy, run_info={
            "launcher": "serve", "arch": args.arch,
            "continuous": bool(args.continuous), "batch": args.batch,
            "prompt_len": args.prompt_len, "gen": args.gen})

    plan_cache = None
    if args.plan_cache:
        from repro.plan.cache import PlanCache
        plan_cache = PlanCache(args.plan_cache)

    if args.continuous:
        _serve_continuous(args, cfg, luffy, dist, params, plan_cache,
                          registry)
        if tracer is not None:
            obs_trace.deactivate()
            tracer.write(trace_out)
            print(f"trace: {len(tracer.events)} events -> {trace_out}")
        return

    r = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(r.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    s_max = S + args.gen
    if plan_cache is not None and args.prefill != "batch":
        print("NOTE: --plan-cache on the fixed-batch driver engages the "
              "batched prefill (--prefill batch) and the decode "
              "template; the step-wise prompt feed reuses the decode "
              "template too")
    if args.prefill == "batch":
        # whole-prompt forward through the shared build/execute MoE core
        # (the pipelined serving path inherited from repro.plan)
        if len(jax.devices()) > 1:
            pdist = make_dist(mesh, "prefill", B, moe_arch=cfg.uses_moe)
        else:
            pdist = single_device()
        if plan_cache is not None and args.precompute_plans \
                and cfg.uses_moe:
            import dataclasses as _dc
            from repro.plan.cache import precompute_prefill_plans
            nl = _dc.replace(luffy, enable_condensation=False,
                             enable_migration=False)
            key = precompute_prefill_plans(cfg, nl, pdist, B, S,
                                           plan_cache)
            print(f"precomputed prefill plan: {key}")
        pf = jax.jit(lambda p, t: model.prefill(
            p, t, s_max, luffy=luffy, dist=pdist,
            plan_cache=plan_cache)[0])
        logits_pf = pf(params, prompts)
        jax.block_until_ready(logits_pf)
        t0 = time.time()
        with obs_trace.phase("prefill_batch", cat="step") as _sp:
            logits_pf = jax.block_until_ready(pf(params, prompts))
        dt = time.time() - t0
        print(f"batched prefill({B}x{S} tokens): {dt:.3f}s "
              f"({B * S / max(dt, 1e-9):.0f} tok/s)")
        if registry is not None:
            from repro.obs.metrics import write_jsonl
            write_jsonl(args.metrics_json, registry.observe(
                0, {"time_s": dt}, phase="prefill_batch",
                prefill_tokens=B * S))
        if plan_cache is not None:
            print(f"plan cache: {plan_cache.stats()}")
    if plan_cache is not None and args.precompute_plans and cfg.uses_moe:
        from repro.plan.cache import precompute_decode_plans
        key = precompute_decode_plans(cfg, luffy, dist, B, plan_cache)
        print(f"precomputed decode plan: {key}")
    t0 = time.time()
    cache = serve_lib.cache_struct(cfg, B, s_max, as_struct=False)
    dec = jax.jit(lambda p, c, t: serve_lib.decode_step(
        p, cfg, luffy, dist, c, t, plan_cache=plan_cache))
    # feed the prompt token by token (cache-correct for every arch family)
    logits = None
    with obs_trace.phase("prefill_step", cat="step", tokens=S) as _sp:
        for t in range(S):
            logits, cache = dec(params, cache, prompts[:, t:t + 1])
        logits = _sp.fence(logits)
    print(f"prefill({S} tokens): {time.time()-t0:.2f}s")
    out = []
    t0 = time.time()
    for i in range(args.gen):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(np.asarray(nxt[:, 0]))
        ts = time.time()
        with obs_trace.phase("decode", cat="step", step=i) as _sp:
            logits, cache = dec(params, cache, nxt)
            logits = _sp.fence(logits)
        if registry is not None:
            from repro.obs.metrics import write_jsonl
            write_jsonl(args.metrics_json, registry.observe(
                i + 1, {"time_s": time.time() - ts,
                        "generated_tokens": B}))
    dt = time.time() - t0
    toks = int(np.asarray(out).size)
    print(f"decode: {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s batch={B})")
    print("sample token ids:", [int(x) for x in np.asarray(out)[:, 0][:10]])
    if plan_cache is not None:
        print(f"plan cache: {plan_cache.stats()}")
    if tracer is not None:
        obs_trace.deactivate()
        tracer.write(trace_out)
        print(f"trace: {len(tracer.events)} events -> {trace_out}")


if __name__ == "__main__":
    main()
