"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
        --steps 200 --reduced --mesh host --model-axis 4

Runs the full production stack: mesh + sharded params, LUFFY (adaptive
condensation threshold with host-side rate-bucket switching — one
compiled executable per bucket, cached), AdamW/Adafactor, checkpointing,
metrics logging. ``--mesh host`` builds a mesh over the visible devices
(CPU testing); ``--mesh production`` targets the 16×16 pod (dry-run
hardware only).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moe-gpt2")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the arch (CPU)")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--experts", type=int, default=0,
                    help="override expert count (reduced mode)")
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--mesh", choices=["host", "production", "none"],
                    default="host")
    ap.add_argument("--model-axis", type=int, default=4)
    ap.add_argument("--comm-mode", choices=["flat", "hier"], default="flat",
                    help="expert-parallel collectives: one flat all-to-all "
                         "or hierarchical two-phase (DESIGN.md §5)")
    ap.add_argument("--nodes", type=int, default=0,
                    help="split the model axis into this many nodes "
                         "(builds a (node, local) mesh; required for "
                         "--comm-mode hier)")
    ap.add_argument("--inter-bw", type=float, default=0.0,
                    help="override cross-node bandwidth (bytes/s) for the "
                         "topology ledger / migration link costs")
    ap.add_argument("--exec-mode", choices=["sync", "pipeline"],
                    default="sync",
                    help="MoE execution schedule: strict dispatch→FFN→"
                         "combine order, or chunked software pipeline "
                         "overlapping collectives with expert compute "
                         "(bit-identical; DESIGN.md §6)")
    ap.add_argument("--pipeline-chunks", type=int, default=None,
                    help="capacity chunks for --exec-mode pipeline "
                         "(clipped to capacity/8). Default: 4, except "
                         "under --plan-objective overlap where the "
                         "estimate search picks the count (0 = force "
                         "the planned count; DESIGN.md §9)")
    ap.add_argument("--plan-objective", default="traffic",
                    choices=["traffic", "overlap"],
                    help="migration planner objective (DESIGN.md §7): "
                         "link-cost-weighted bytes, or modeled exposed "
                         "(un-overlappable) time under the pipeline")
    ap.add_argument("--plan-reuse", default="off",
                    choices=["off", "signature", "always"],
                    help="cross-layer migration-plan reuse (DESIGN.md "
                         "§9): replan every MoE sublayer, revalidate a "
                         "carried plan by routing signature, or trust "
                         "it unconditionally")
    ap.add_argument("--similarity-backend", default="exact",
                    choices=["exact", "lsh"],
                    help="condensation similarity backend (DESIGN.md "
                         "§10): measure every §V-A uncertain pair, or "
                         "only LSH-bucket collisions (fewer measured "
                         "pairs for large groups)")
    ap.add_argument("--lsh-bits", type=int, default=8,
                    help="signed random projections per LSH bucket code")
    ap.add_argument("--condense-reuse", default="off",
                    choices=["off", "signature", "always"],
                    help="cross-layer condense-plan reuse (DESIGN.md "
                         "§10): rebuild similarity every MoE sublayer, "
                         "revalidate the carried rep map by primary-"
                         "expert signature, or trust it up to the age "
                         "bound")
    ap.add_argument("--condense-max-age", type=int, default=4,
                    help="staleness bound (sublayers) on a reused "
                         "condense plan (§V-A freshness)")
    ap.add_argument("--hier-dedup", default="off", choices=["off", "on"],
                    help="ship the per-node-deduplicated hier payload "
                         "(repro.condense.wire; needs --comm-mode hier, "
                         "vanilla sync exchange)")
    ap.add_argument("--no-condensation", action="store_true")
    ap.add_argument("--no-migration", action="store_true")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-file", default="")
    ap.add_argument("--metrics-json", default="",
                    help="append one unified per-step metrics record "
                         "(repro.obs.metrics JSONL) per step to this "
                         "path")
    ap.add_argument("--trace", action="store_true",
                    help="step tracing (repro.obs.trace): fenced spans "
                         "around every jitted step plus one eager "
                         "exchange probe for the per-phase breakdown; "
                         "writes Chrome-trace JSON (see --trace-out)")
    ap.add_argument("--trace-out", default="",
                    help="trace JSON path (implies --trace; default "
                         "trace.json)")
    ap.add_argument("--calibrate", default="",
                    help="calibration artifact dir (repro.obs.calibrate)"
                         ": load the fit for this topology+backend or "
                         "measure and persist one, then price links, "
                         "chunk overhead and the FFN roofline with it")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro import checkpoint, optim, train_lib
    from repro.config import (LuffyConfig, OptimConfig, ShapeConfig,
                              reduced)
    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.dist import DistContext, make_dist, single_device
    from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                                   topology_for_mesh)
    from repro.models.model import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, num_layers=args.layers, d_model=args.d_model,
                      max_experts=args.experts or 4,
                      seq_len_hint=args.seq_len)
    gb = args.global_batch or (8 if args.reduced else 256)
    shape = ShapeConfig("train", args.seq_len, gb, "train")

    nodes = args.nodes
    if args.comm_mode == "hier" and nodes <= 1:
        nodes = 2                     # hier needs a (node, local) split
    mesh = topo = None
    if not (args.mesh == "none" or len(jax.devices()) == 1):
        mesh = (make_production_mesh(nodes=nodes)
                if args.mesh == "production"
                else make_host_mesh(model=args.model_axis, nodes=nodes))
        topo = topology_for_mesh(
            mesh, inter_bw=args.inter_bw or None)

    # measured cost-model fit (DESIGN.md §11): load or measure BEFORE the
    # dist context so migration link costs / the overlap model / the
    # ledger all price calibrated links
    calib = None
    if args.calibrate:
        from repro.obs import calibrate as obs_cal
        calib = obs_cal.run_calibration(mesh, topo, out_dir=args.calibrate)
        if topo is not None:
            topo = calib.topology(topo)
        print(f"calibration {calib.key}: "
              f"intra_bw={calib.intra_bw:.3g}B/s "
              f"inter_bw={calib.inter_bw:.3g}B/s "
              f"chunk_overhead={calib.chunk_overhead_ms:.3g}ms "
              f"ffn_speed={calib.ffn_speed:.3g}FLOP/s")

    if mesh is None:
        dist = single_device()
    else:
        dist = make_dist(mesh, "train", gb, moe_arch=cfg.uses_moe,
                         topology=topo)
        print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"topology {topo.num_nodes}x{topo.devices_per_node} "
              f"bw_ratio={topo.bw_ratio:.1f} comm_mode={args.comm_mode} "
              f"exec_mode={args.exec_mode} "
              f"plan_objective={args.plan_objective} "
              f"plan_reuse={args.plan_reuse}")

    # objective-aware chunk count (DESIGN.md §9): under the "overlap"
    # objective the estimate search picks n_chunks unless the CLI pins it
    from repro.config import resolve_pipeline_chunks
    pipeline_chunks = resolve_pipeline_chunks(args.pipeline_chunks,
                                              args.plan_objective)
    luffy = LuffyConfig(
        enable_condensation=not args.no_condensation and cfg.uses_moe,
        enable_migration=not args.no_migration and cfg.uses_moe,
        condense_group=min(128, args.seq_len),
        combine_slack=2.0,
        comm_mode=args.comm_mode,
        exec_mode=args.exec_mode,
        pipeline_chunks=pipeline_chunks,
        plan_objective=args.plan_objective,
        plan_reuse=args.plan_reuse,
        similarity_backend=args.similarity_backend,
        lsh_bits=args.lsh_bits,
        condense_reuse=args.condense_reuse,
        condense_reuse_max_age=args.condense_max_age,
        hier_dedup=args.hier_dedup)
    if calib is not None:
        luffy = calib.apply(luffy)
    ocfg = OptimConfig(name=args.optimizer, lr=args.lr,
                       total_steps=args.steps,
                       warmup_steps=max(2, args.steps // 20))

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pspecs = model.param_pspecs(dist)
    if dist.enabled:
        params = jax.device_put(
            params, jax.tree.map(lambda s: dist.sharding(s), pspecs))
    opt_state = optim.init_opt_state(params, ocfg)
    lstate = train_lib.init_luffy_state()
    data = SyntheticLM(cfg, shape)

    # one executable per condensation rate bucket, compiled on demand
    steps_by_bucket = {}

    def get_step(bucket: int):
        if bucket not in steps_by_bucket:
            cap = (train_lib.capacity_for_bucket(cfg, shape, dist, luffy,
                                                 bucket)
                   if cfg.uses_moe else 8)
            fn = train_lib.make_train_step(cfg, luffy, ocfg, dist, cap,
                                           param_pspecs=pspecs)
            steps_by_bucket[bucket] = jax.jit(fn)
        return steps_by_bucket[bucket]

    # step tracing (DESIGN.md §11): fenced spans around the jitted step;
    # phase spans inside the step are structural no-ops (lax.scan traces
    # the forward), so --trace adds one eager probe_exchange at the end
    # for the plan_build/dispatch/expert_ffn/combine breakdown
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    trace_out = args.trace_out or ("trace.json" if args.trace else "")
    tracer = None
    if trace_out:
        tracer = obs_trace.Tracer(fence=True)
        obs_trace.activate(tracer)
    registry = obs_metrics.MetricsRegistry(
        luffy=luffy, run_info={"arch": args.arch, "steps": args.steps,
                               "comm_mode": args.comm_mode,
                               "exec_mode": args.exec_mode,
                               "calibrated": calib is not None})

    bucket = 0
    log = []
    t_start = time.time()
    observed_rate = 0.0
    for i in range(args.steps):
        with obs_trace.phase("data", cat="step"):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        t0 = time.time()
        with obs_trace.phase("step", cat="step", step=i) as _sp:
            out = get_step(bucket)(params, opt_state, lstate, batch)
            params, opt_state, lstate, m = _sp.fence(out)
        dt = time.time() - t0
        m = train_lib.finalize_metrics(m, luffy)
        observed_rate = 0.8 * observed_rate + 0.2 * m["condense_rate"]
        if cfg.uses_moe and luffy.enable_condensation and i >= 3:
            bucket = train_lib.pick_bucket_host(luffy, 0.0, observed_rate)
        rec = registry.observe(i, m, time_s=round(dt, 3), bucket=bucket)
        log.append(rec)
        if args.metrics_json:
            obs_metrics.write_jsonl(args.metrics_json, rec)
        if i % max(1, args.steps // 20) == 0 or i == args.steps - 1:
            inter = ""
            if (m.get("inter_bytes_flat") or 0.0) > 0:
                inter = (f" inter={m['inter_bytes_dedup']:.0f}B"
                         f"/{m['inter_bytes_flat']:.0f}B")
            print(f"step {i:5d} loss={m['loss']:.4f} "
                  f"cond={m['condense_rate']:.2f} bucket={bucket} "
                  f"local={m['local_frac']:.2f} "
                  f"drop=({m['dispatch_drop']:.3f},{m['combine_drop']:.3f})"
                  f"{inter} {dt:.2f}s", flush=True)
        if args.ckpt and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt, params, pspecs=pspecs, step=i + 1)
    print(f"done: {args.steps} steps in {time.time()-t_start:.1f}s; "
          f"final loss {log[-1]['metrics']['train/loss']:.4f}")
    if args.ckpt:
        checkpoint.save(args.ckpt, params, pspecs=pspecs, step=args.steps)
    if args.log_file:
        Path(args.log_file).write_text(json.dumps(log, indent=1))
    if tracer is not None:
        if cfg.uses_moe:
            from repro.obs.calibrate import probe_exchange
            with obs_trace.phase("probe", cat="probe"):
                probe_exchange(cfg, luffy,
                               seq_len=min(args.seq_len, 64))
        obs_trace.deactivate()
        tracer.write(trace_out)
        summary = tracer.summary()
        steps = summary.get("step", {})
        print(f"trace: {len(tracer.events)} events -> {trace_out} "
              f"(step total {steps.get('total_us', 0.0)/1e3:.1f}ms over "
              f"{steps.get('count', 0)} spans)")


if __name__ == "__main__":
    main()
