"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
        --steps 200 --reduced --mesh host --model-axis 4

Runs the full production stack: mesh + sharded params, LUFFY (adaptive
condensation threshold with host-side rate-bucket switching — one
compiled executable per bucket, cached), AdamW/Adafactor, checkpointing,
metrics logging. ``--mesh host`` builds a mesh over the visible devices
(CPU testing); ``--mesh production`` targets the 16×16 pod (dry-run
hardware only).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moe-gpt2")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the arch (CPU)")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--experts", type=int, default=0,
                    help="override expert count (reduced mode)")
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--mesh", choices=["host", "production", "none"],
                    default="host")
    ap.add_argument("--model-axis", type=int, default=4)
    # Tunable knobs default to None ("not set"): --autotune may fill
    # them, and anything the user typed explicitly always wins
    # (DESIGN.md §12). Unset knobs without --autotune fall back to the
    # historical defaults (flat/sync/traffic/exact/8/off).
    ap.add_argument("--comm-mode", choices=["flat", "hier"], default=None,
                    help="expert-parallel collectives: one flat all-to-all "
                         "or hierarchical two-phase (DESIGN.md §5; "
                         "default flat)")
    ap.add_argument("--nodes", type=int, default=0,
                    help="split the model axis into this many nodes "
                         "(builds a (node, local) mesh; required for "
                         "--comm-mode hier)")
    ap.add_argument("--inter-bw", type=float, default=0.0,
                    help="override cross-node bandwidth (bytes/s) for the "
                         "topology ledger / migration link costs")
    ap.add_argument("--exec-mode", choices=["sync", "pipeline"],
                    default=None,
                    help="MoE execution schedule: strict dispatch→FFN→"
                         "combine order, or chunked software pipeline "
                         "overlapping collectives with expert compute "
                         "(bit-identical; DESIGN.md §6; default sync)")
    ap.add_argument("--pipeline-chunks", type=int, default=None,
                    help="capacity chunks for --exec-mode pipeline "
                         "(clipped to capacity/8). Default: 4, except "
                         "under --plan-objective overlap where the "
                         "estimate search picks the count (0 = force "
                         "the planned count; DESIGN.md §9)")
    ap.add_argument("--plan-objective", default=None,
                    choices=["traffic", "overlap", "replicate"],
                    help="migration planner objective (DESIGN.md §7): "
                         "link-cost-weighted bytes, modeled exposed "
                         "(un-overlappable) time under the pipeline, or "
                         "traffic + intra-node hot-expert replication "
                         "(DESIGN.md §15; default traffic)")
    ap.add_argument("--plan-reuse", default="off",
                    choices=["off", "signature", "always"],
                    help="cross-layer migration-plan reuse (DESIGN.md "
                         "§9): replan every MoE sublayer, revalidate a "
                         "carried plan by routing signature, or trust "
                         "it unconditionally")
    ap.add_argument("--similarity-backend", default=None,
                    choices=["exact", "lsh"],
                    help="condensation similarity backend (DESIGN.md "
                         "§10): measure every §V-A uncertain pair, or "
                         "only LSH-bucket collisions (fewer measured "
                         "pairs for large groups; default exact)")
    ap.add_argument("--lsh-bits", type=int, default=None,
                    help="signed random projections per LSH bucket code "
                         "(default 8)")
    ap.add_argument("--condense-reuse", default="off",
                    choices=["off", "signature", "always"],
                    help="cross-layer condense-plan reuse (DESIGN.md "
                         "§10): rebuild similarity every MoE sublayer, "
                         "revalidate the carried rep map by primary-"
                         "expert signature, or trust it up to the age "
                         "bound")
    ap.add_argument("--condense-max-age", type=int, default=4,
                    help="staleness bound (sublayers) on a reused "
                         "condense plan (§V-A freshness)")
    ap.add_argument("--hier-dedup", default=None, choices=["off", "on"],
                    help="ship the per-node-deduplicated hier payload "
                         "(repro.condense.wire; needs --comm-mode hier, "
                         "works under every exec mode incl. migrate + "
                         "pipelined, DESIGN.md §15; default off)")
    ap.add_argument("--wire-dtype", default=None,
                    choices=["f32", "bf16", "f8e4m3"],
                    help="precision activation rows ship at when they "
                         "cross a node boundary (DESIGN.md §14): "
                         "identity wire, bf16 cast, or f8e4m3 with "
                         "per-32-element f32 scales. Frozen into the "
                         "exchange plan; compute stays at the compute "
                         "dtype (default f32)")
    ap.add_argument("--wire-error-feedback", action="store_true",
                    help="carry each token's wire quantization residual "
                         "into the next step's shipped payload "
                         "(DESIGN.md §15); no effect under --wire-dtype "
                         "f32")
    ap.add_argument("--no-condensation", action="store_true")
    ap.add_argument("--no-migration", action="store_true")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-file", default="")
    ap.add_argument("--metrics-json", default="",
                    help="append one unified per-step metrics record "
                         "(repro.obs.metrics JSONL) per step to this "
                         "path")
    ap.add_argument("--trace", action="store_true",
                    help="step tracing (repro.obs.trace): fenced spans "
                         "around every jitted step plus one eager "
                         "exchange probe for the per-phase breakdown; "
                         "writes Chrome-trace JSON (see --trace-out)")
    ap.add_argument("--trace-out", default="",
                    help="trace JSON path (implies --trace; default "
                         "trace.json)")
    ap.add_argument("--calibrate", default="",
                    help="calibration artifact dir (repro.obs.calibrate)"
                         ": load the fit for this topology+backend or "
                         "measure and persist one, then price links, "
                         "chunk overhead and the FFN roofline with it")
    ap.add_argument("--autotune", default="",
                    help="TunedConfig artifact dir (repro.obs.autotune): "
                         "load the tuned knob set for this topology+"
                         "backend or search and persist one, then fill "
                         "every knob the CLI left unset (explicit flags "
                         "always override; DESIGN.md §12)")
    ap.add_argument("--autotune-force", action="store_true",
                    help="re-run the autotune search even when a valid "
                         "artifact exists (overwrites it)")
    ap.add_argument("--autotune-refine", type=int, default=0,
                    help="after this many measured warmup steps, re-rank "
                         "the tuned top candidates under the measured/"
                         "modeled step-time ratio (online refinement; "
                         "0 = off)")
    ap.add_argument("--recalibrate-on-drift", action="store_true",
                    help="when the step-time drift detector fires "
                         "(repro.obs.monitor), re-measure the "
                         "calibration in place (force=True; needs "
                         "--calibrate; at most once per run)")
    ap.add_argument("--drift-tolerance", type=float, default=1.5,
                    help="drift detector tolerance: EWMA of measured/"
                         "expected step time outside [1/t, t] counts as "
                         "out-of-tolerance")
    ap.add_argument("--drift-k", type=int, default=5,
                    help="consecutive out-of-tolerance steps before the "
                         "drift detector fires")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro import checkpoint, optim, train_lib
    from repro.config import (LuffyConfig, OptimConfig, ShapeConfig,
                              reduced)
    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.dist import DistContext, make_dist, single_device
    from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                                   topology_for_mesh)
    from repro.models.model import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, num_layers=args.layers, d_model=args.d_model,
                      max_experts=args.experts or 4,
                      seq_len_hint=args.seq_len)
    gb = args.global_batch or (8 if args.reduced else 256)
    shape = ShapeConfig("train", args.seq_len, gb, "train")

    nodes = args.nodes
    if args.comm_mode == "hier" and nodes <= 1:
        nodes = 2                     # hier needs a (node, local) split
    mesh = topo = None
    if not (args.mesh == "none" or len(jax.devices()) == 1):
        mesh = (make_production_mesh(nodes=nodes)
                if args.mesh == "production"
                else make_host_mesh(model=args.model_axis, nodes=nodes))
        topo = topology_for_mesh(
            mesh, inter_bw=args.inter_bw or None)

    # measured cost-model fit (DESIGN.md §11): load or measure BEFORE the
    # dist context so migration link costs / the overlap model / the
    # ledger all price calibrated links
    calib = None
    if args.calibrate:
        from repro.obs import calibrate as obs_cal
        calib = obs_cal.run_calibration(mesh, topo, out_dir=args.calibrate)
        if topo is not None:
            topo = calib.topology(topo)
        print(f"calibration {calib.key}: "
              f"intra_bw={calib.intra_bw:.3g}B/s "
              f"inter_bw={calib.inter_bw:.3g}B/s "
              f"chunk_overhead={calib.chunk_overhead_ms:.3g}ms "
              f"ffn_speed={calib.ffn_speed:.3g}FLOP/s")

    # knob resolution (DESIGN.md §12): explicit CLI flags > tuned
    # artifact (--autotune) > historical defaults
    from repro.comm.topology import Topology
    from repro.obs import autotune as obs_at
    explicit = {k for k in obs_at.TUNABLE_KNOBS
                if getattr(args, k) is not None}
    n_moe = (sum(1 for i in range(cfg.num_layers)
                 if cfg.ffn_kind(i) == "moe") if cfg.uses_moe else 0)
    at_topo = topo if topo is not None else Topology.flat(1)
    tuned = None
    if args.autotune and cfg.uses_moe:
        tuned = obs_at.run_autotune(
            topo=at_topo, out_dir=args.autotune,
            force=args.autotune_force,
            tokens=gb * args.seq_len, top_k=cfg.moe.top_k,
            d_model=cfg.d_model, d_ff=cfg.moe.d_ff,
            num_layers=max(1, n_moe), n_moe=max(1, n_moe),
            n_slots=gb, num_experts=cfg.moe.num_experts,
            mesh_devices=mesh.devices.size if mesh is not None else 1,
            group_size=min(128, args.seq_len),
            plan_reuse=args.plan_reuse,
            condense_reuse=args.condense_reuse, calib=calib)
        print(f"autotune {tuned.key}: {tuned.knobs} "
              f"modeled {tuned.modeled_step_ms:.3f}ms vs default "
              f"{tuned.default_step_ms:.3f}ms "
              f"({tuned.candidates} candidates, "
              f"calibrated={tuned.calibrated})")
    knobs = dict(obs_at.DEFAULT_KNOBS)
    knobs["pipeline_chunks"] = None    # sentinel: resolve by objective
    if tuned is not None:
        knobs.update({k: v for k, v in tuned.knobs.items()
                      if k not in explicit})
    for k in explicit:
        knobs[k] = getattr(args, k)
    if "hier_dedup" not in explicit and knobs["hier_dedup"] == "on" \
            and knobs["comm_mode"] != "hier":
        knobs["hier_dedup"] = "off"   # dedup wire needs hier comm; it
                                      # is otherwise universal (§15)
    from repro.config import resolve_pipeline_chunks
    if knobs["pipeline_chunks"] is None:
        # objective-aware chunk count (DESIGN.md §9): under the
        # "overlap" objective the estimate search picks n_chunks
        knobs["pipeline_chunks"] = resolve_pipeline_chunks(
            None, knobs["plan_objective"])

    if mesh is None:
        dist = single_device()
    else:
        dist = make_dist(mesh, "train", gb, moe_arch=cfg.uses_moe,
                         topology=topo)
        print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"topology {topo.num_nodes}x{topo.devices_per_node} "
              f"bw_ratio={topo.bw_ratio:.1f} "
              f"comm_mode={knobs['comm_mode']} "
              f"exec_mode={knobs['exec_mode']} "
              f"plan_objective={knobs['plan_objective']} "
              f"plan_reuse={args.plan_reuse}")

    luffy = LuffyConfig(
        enable_condensation=not args.no_condensation and cfg.uses_moe,
        enable_migration=not args.no_migration and cfg.uses_moe,
        condense_group=min(128, args.seq_len),
        combine_slack=2.0,
        comm_mode=knobs["comm_mode"],
        exec_mode=knobs["exec_mode"],
        pipeline_chunks=knobs["pipeline_chunks"],
        plan_objective=knobs["plan_objective"],
        plan_reuse=args.plan_reuse,
        similarity_backend=knobs["similarity_backend"],
        lsh_bits=knobs["lsh_bits"],
        condense_reuse=args.condense_reuse,
        condense_reuse_max_age=args.condense_max_age,
        hier_dedup=knobs["hier_dedup"],
        wire_dtype=knobs["wire_dtype"],
        wire_error_feedback=args.wire_error_feedback)
    if calib is not None:
        luffy = calib.apply(luffy)
    ocfg = OptimConfig(name=args.optimizer, lr=args.lr,
                       total_steps=args.steps,
                       warmup_steps=max(2, args.steps // 20))

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pspecs = model.param_pspecs(dist)
    if dist.enabled:
        params = jax.device_put(
            params, jax.tree.map(lambda s: dist.sharding(s), pspecs))
    opt_state = optim.init_opt_state(params, ocfg)
    # cross-step wire error feedback (DESIGN.md §15): allocate the
    # residual buffer only when a lossy wire can produce one
    from repro.models import transformer as tf_mod
    use_ef = (luffy.wire_error_feedback and luffy.wire_dtype != "f32"
              and cfg.uses_moe)
    lstate = train_lib.init_luffy_state(
        tf_mod.wire_ef_shape(cfg, gb, args.seq_len) if use_ef else None)
    data = SyntheticLM(cfg, shape)

    # one executable per condensation rate bucket, compiled on demand
    steps_by_bucket = {}

    def get_step(bucket: int):
        if bucket not in steps_by_bucket:
            cap = (train_lib.capacity_for_bucket(cfg, shape, dist, luffy,
                                                 bucket)
                   if cfg.uses_moe else 8)
            fn = train_lib.make_train_step(cfg, luffy, ocfg, dist, cap,
                                           param_pspecs=pspecs)
            steps_by_bucket[bucket] = jax.jit(fn)
        return steps_by_bucket[bucket]

    # step tracing (DESIGN.md §11): fenced spans around the jitted step;
    # phase spans inside the step are structural no-ops (lax.scan traces
    # the forward), so --trace adds one eager probe_exchange at the end
    # for the plan_build/dispatch/expert_ffn/combine breakdown
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    trace_out = args.trace_out or ("trace.json" if args.trace else "")
    tracer = None
    if trace_out:
        tracer = obs_trace.Tracer(fence=True)
        obs_trace.activate(tracer)
    registry = obs_metrics.MetricsRegistry(
        luffy=luffy, run_info={"arch": args.arch, "steps": args.steps,
                               "comm_mode": luffy.comm_mode,
                               "exec_mode": luffy.exec_mode,
                               "calibrated": calib is not None,
                               "autotuned": tuned is not None})

    # residual stream (DESIGN.md §12): the expected step time under the
    # current calibration is anchored on a short measured warmup (the
    # modeled exchange is only part of a full fwd+bwd+opt step); the
    # EWMA detector then flags sustained departures from it
    from repro.obs import monitor as obs_monitor
    monitor = obs_monitor.ResidualMonitor(tolerance=args.drift_tolerance,
                                          k=args.drift_k)
    warmup_ms = []
    expected_step_ms = None
    recalibrated = False

    bucket = 0
    log = []
    t_start = time.time()
    observed_rate = 0.0
    for i in range(args.steps):
        with obs_trace.phase("data", cat="step"):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        t0 = time.time()
        with obs_trace.phase("step", cat="step", step=i) as _sp:
            out = get_step(bucket)(params, opt_state, lstate, batch)
            params, opt_state, lstate, m = _sp.fence(out)
        dt = time.time() - t0
        m = train_lib.finalize_metrics(m, luffy)
        observed_rate = 0.8 * observed_rate + 0.2 * m["condense_rate"]
        if cfg.uses_moe and luffy.enable_condensation and i >= 3:
            bucket = train_lib.pick_bucket_host(luffy, 0.0, observed_rate)
        extra = {}
        step_ms = dt * 1e3
        if expected_step_ms is None:
            if i >= 1:                 # step 0 is compile time
                warmup_ms.append(step_ms)
            if len(warmup_ms) >= 3:
                expected_step_ms = sum(warmup_ms) / len(warmup_ms)
                if tuned is not None and args.autotune_refine > 0 \
                        and not tuned.refined:
                    # online refinement: re-rank the top candidates
                    # under the measured/modeled step-time ratio
                    ratio = expected_step_ms / max(
                        tuned.modeled_step_ms, 1e-9)
                    refined = obs_at.rerank(
                        tuned, {"step": ratio}, topo=at_topo,
                        chunk_overhead_ms=luffy.chunk_overhead_ms)
                    changed = {k: v for k, v in refined.knobs.items()
                               if k not in explicit
                               and v != tuned.knobs.get(k)}
                    tuned = refined
                    if changed:
                        luffy = dataclasses.replace(luffy, **changed)
                        registry.luffy = luffy
                        steps_by_bucket.clear()
                        expected_step_ms = None
                        warmup_ms.clear()
                        print(f"autotune refine @ step {i}: {changed} "
                              f"(ratio {ratio:.2f})")
        else:
            extra.update(monitor.observe(
                i, {"step": expected_step_ms}, {"step": step_ms}))
            if args.recalibrate_on_drift and args.calibrate \
                    and monitor.drifted and not recalibrated:
                recalibrated = True
                from repro.obs import calibrate as obs_cal
                print(f"drift @ step {i} "
                      f"(phases {monitor.drifted_phases()}): "
                      f"recalibrating", flush=True)
                calib = obs_cal.run_calibration(
                    mesh, topo, out_dir=args.calibrate, force=True)
                luffy = calib.apply(luffy)
                steps_by_bucket.clear()
                monitor.reset()
                expected_step_ms = None
                warmup_ms.clear()
        rec = registry.observe(i, m, time_s=round(dt, 3), bucket=bucket,
                               **extra)
        log.append(rec)
        if args.metrics_json:
            obs_metrics.write_jsonl(args.metrics_json, rec)
        if i % max(1, args.steps // 20) == 0 or i == args.steps - 1:
            inter = ""
            if (m.get("inter_bytes_flat") or 0.0) > 0:
                inter = (f" inter={m['inter_bytes_dedup']:.0f}B"
                         f"/{m['inter_bytes_flat']:.0f}B")
            print(f"step {i:5d} loss={m['loss']:.4f} "
                  f"cond={m['condense_rate']:.2f} bucket={bucket} "
                  f"local={m['local_frac']:.2f} "
                  f"drop=({m['dispatch_drop']:.3f},{m['combine_drop']:.3f})"
                  f"{inter} {dt:.2f}s", flush=True)
        if args.ckpt and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt, params, pspecs=pspecs, step=i + 1)
    print(f"done: {args.steps} steps in {time.time()-t_start:.1f}s; "
          f"final loss {log[-1]['metrics']['train/loss']:.4f}")
    if args.ckpt:
        checkpoint.save(args.ckpt, params, pspecs=pspecs, step=args.steps)
    if args.log_file:
        Path(args.log_file).write_text(json.dumps(log, indent=1))
    if tracer is not None:
        if cfg.uses_moe:
            from repro.obs.calibrate import probe_exchange_per_device
            S = min(args.seq_len, 64)
            with obs_trace.phase("probe", cat="probe"):
                per_dev = probe_exchange_per_device(cfg, luffy,
                                                    seq_len=S)
            # probe residuals: join the phases the cost model prices
            # against the fenced probe spans (expert_ffn is the only
            # phase the single-device probe predicts meaningfully —
            # its residual is a direct ffn_speed-staleness check)
            rows = S * cfg.moe.top_k
            pred = {"expert_ffn": rows * 4.0 * cfg.d_model
                    * cfg.moe.d_ff / luffy.gpu_speed * 1e3}
            meas = obs_monitor.measured_phase_ms(tracer)
            res = obs_monitor.ResidualMonitor().observe(
                args.steps, pred, meas, per_device_ms=per_dev)
            rec = registry.observe(args.steps, {}, **res)
            if args.metrics_json:
                obs_metrics.write_jsonl(args.metrics_json, rec)
            disp = res.get("residual_device_dispersion", 1.0)
            print(f"probe: {len(per_dev)} devices, "
                  f"dispersion {disp:.2f}x")
        obs_trace.deactivate()
        tracer.write(trace_out)
        summary = tracer.summary()
        steps = summary.get("step", {})
        print(f"trace: {len(tracer.events)} events -> {trace_out} "
              f"(step total {steps.get('total_us', 0.0)/1e3:.1f}ms over "
              f"{steps.get('count', 0)} spans)")


if __name__ == "__main__":
    main()
