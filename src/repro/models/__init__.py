from repro.models.model import build_model, Model  # noqa: F401
