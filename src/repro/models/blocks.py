"""Core transformer building blocks (pure JAX, pytree-dict params).

Conventions:
  * params are nested dicts of jnp arrays;
  * every ``init_*`` returns params, every ``apply``-style fn is pure;
  * compute runs in ``cfg.compute_dtype``; norm/softmax statistics in f32;
  * decode attention returns flash-style partials (o*, m, l) so the
    distributed layer can merge partials across context-parallel shards.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import AttnConfig, ModelConfig

NEG_INF = -1e30


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str, dtype):
    if kind == "rms":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_apply(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rms":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                              # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, *, cross: bool = False):
    a = cfg.attn
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, a.q_dim, dt),
        "wk": dense_init(ks[1], cfg.d_model, a.kv_dim, dt),
        "wv": dense_init(ks[2], cfg.d_model, a.kv_dim, dt),
        "wo": dense_init(ks[3], a.q_dim, cfg.d_model, dt,
                         scale=1.0 / math.sqrt(2 * cfg.num_layers)),
    }


def _split_heads(x, n_heads, head_dim):
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=-2)


def make_attn_mask(q_pos, k_pos, *, causal: bool, window: Optional[int],
                   chunked: bool = False):
    """Boolean [.., Sq, Sk] mask; True = attend.

    window: sliding window size (attend to keys within `window` before the
    query). chunked=True uses llama4-style block-diagonal chunks of size
    `window` instead of a sliding window.
    """
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    mask = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        mask &= k <= q
    if window is not None:
        if chunked:
            mask &= (q // window) == (k // window)
        else:
            mask &= (q - k) < window
    return mask


def attend(q, k, v, mask, scale, logit_cap=None):
    """q:[B,Sq,H,hd] k,v:[B,Sk,Hkv,hd]; mask broadcastable to [B,1,Sq,Sk]."""
    n_rep = q.shape[-2] // k.shape[-2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if logit_cap is not None:
        logits = logit_cap * jnp.tanh(logits / logit_cap)
    if mask.ndim == 2:            # [Sq,Sk] -> [1,1,Sq,Sk]
        mask = mask[None, None]
    elif mask.ndim == 3:          # [B,Sq,Sk] -> [B,1,Sq,Sk]
        mask = mask[:, None]
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


# FlashAttention-style two-level chunked attention in pure JAX: never
# materializes the [B,H,Sq,Sk] logits. The paper cites FlashAttention
# [29] for exactly this cost structure; on TPU the same streaming
# formulation keeps the working set in VMEM-sized tiles.
ATTN_CHUNK_Q = 512
ATTN_CHUNK_K = 1024
ATTN_DIRECT_MAX = 2048            # below this, use the direct path


def attend_chunked(q, k, v, q_pos, k_pos, scale, *, causal, window,
                   chunked_window, logit_cap=None, kv_valid=None,
                   chunk_q=ATTN_CHUNK_Q, chunk_k=ATTN_CHUNK_K):
    """Streaming-softmax attention.

    q: [B,Sq,H,hd]; k,v: [B,Sk,Hkv,hd]; q_pos/k_pos: [Sq]/[Sk] int32
    (position vectors, shared across batch); kv_valid: [B,Sk] bool or None.
    Returns [B,Sq,H,hd].
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    n_rep = H // k.shape[2]
    cq = min(chunk_q, Sq)
    ck = min(chunk_k, Sk)
    nq, nk = Sq // cq, Sk // ck
    assert Sq % cq == 0 and Sk % ck == 0, (Sq, cq, Sk, ck)

    kc = k.reshape(B, nk, ck, k.shape[2], hd)
    vc = v.reshape(B, nk, ck, v.shape[2], hd)
    kpc = k_pos.reshape(nk, ck)
    kvc = (None if kv_valid is None
           else kv_valid.reshape(B, nk, ck))

    # windowed layers touch only ~window/ck k-chunks per q-chunk: slice
    # that band out instead of sweeping (and masking) all nk chunks.
    # 16x fewer attention FLOPs for gemma3/starcoder2 local layers.
    # (REPRO_ATTN_BAND=0 restores the naive sweep — the §Perf baseline.)
    import os as _os
    band_ok = _os.environ.get("REPRO_ATTN_BAND", "1") == "1"
    n_need = nk
    # only causal windows look strictly backward — a non-causal window
    # (BERT-style local) also attends forward, so the band doesn't apply
    if band_ok and window is not None and Sq == Sk and causal:
        if chunked_window:
            n_need = min(nk, (window + ck - 1) // ck + (cq + ck - 1) // ck)
        else:
            n_need = min(nk, (window + cq + ck - 1) // ck + 1)

    def q_block(qb, qp):
        # qb: [B,cq,H,hd]; qp: [cq]
        def k_step(carry, inp):
            m, l, acc = carry
            kb, vb, kp, kvb = inp
            kk = _repeat_kv(kb, n_rep)
            vv = _repeat_kv(vb, n_rep)
            lg = jnp.einsum("bqhd,bkhd->bhqk", qb, kk,
                            preferred_element_type=jnp.float32) * scale
            if logit_cap is not None:
                lg = logit_cap * jnp.tanh(lg / logit_cap)
            msk = jnp.ones((qp.shape[0], kp.shape[0]), bool)
            qpp, kpp = qp[:, None], kp[None, :]
            if causal:
                msk &= kpp <= qpp
            if window is not None:
                if chunked_window:
                    msk &= (qpp // window) == (kpp // window)
                else:
                    msk &= (qpp - kpp) < window
            msk4 = msk[None, None]
            if kvb is not None:
                msk4 = msk4 & kvb[:, None, None, :]
            lg = jnp.where(msk4, lg, NEG_INF)
            m2 = jnp.maximum(m, jnp.max(lg, axis=-1))
            m2 = jnp.maximum(m2, -0.5e30)
            a = jnp.exp(m - m2)
            p = jnp.exp(lg - m2[..., None])
            p = jnp.where(msk4, p, 0.0)
            l2 = l * a + jnp.sum(p, axis=-1)
            acc2 = acc * a[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vv.dtype), vv
            ).astype(jnp.float32)
            return (m2, l2, acc2), None

        # zero-couple carry inits to qb so they inherit its varying-
        # manual-axes type when this runs inside shard_map (scan carries
        # must have uniform vma in/out)
        zq = jnp.sum(qb).astype(jnp.float32) * 0.0
        m0 = jnp.full((B, H, qb.shape[1]), -1e30, jnp.float32) + zq
        l0 = jnp.zeros((B, H, qb.shape[1]), jnp.float32) + zq
        a0 = jnp.zeros((B, H, qb.shape[1], hd), jnp.float32) + zq
        if n_need < nk:
            # dynamic band of k-chunks covering [q_start - window, q_end]
            q0 = qp[0]
            if chunked_window:
                lo = (q0 // window) * (window // ck) if window >= ck \
                    else q0 // ck
            else:
                lo = jnp.maximum(q0 - window + 1, 0) // ck
            lo = jnp.clip(lo, 0, nk - n_need).astype(jnp.int32)
            kc_u = jax.lax.dynamic_slice_in_dim(kc, lo, n_need, axis=1)
            vc_u = jax.lax.dynamic_slice_in_dim(vc, lo, n_need, axis=1)
            kpc_u = jax.lax.dynamic_slice_in_dim(kpc, lo, n_need, axis=0)
            kvc_u = (None if kvc is None else
                     jax.lax.dynamic_slice_in_dim(kvc, lo, n_need, axis=1))
        else:
            kc_u, vc_u, kpc_u, kvc_u = kc, vc, kpc, kvc
        xs = (jnp.moveaxis(kc_u, 1, 0), jnp.moveaxis(vc_u, 1, 0), kpc_u)
        if kvc_u is not None:
            xs = xs + (jnp.moveaxis(kvc_u, 1, 0),)

            def body(c, i):
                return k_step(c, (i[0], i[1], i[2], i[3]))
        else:
            def body(c, i):
                return k_step(c, (i[0], i[1], i[2], None))
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)   # [B,cq,H,hd]

    qcs = jnp.moveaxis(q.reshape(B, nq, cq, H, hd), 1, 0)   # [nq,B,cq,H,hd]
    qps = q_pos.reshape(nq, cq)
    outs = jax.lax.map(lambda inp: q_block(inp[0], inp[1]), (qcs, qps))
    # outs: [nq, B, cq, H, hd] -> [B, Sq, H, hd]
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)


def attn_apply(p, cfg: ModelConfig, x, positions, *, layer: int,
               kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
               kv_positions=None, causal: bool = True, kv_valid=None):
    """Full-sequence attention (train / prefill / encoder / cross).

    x: [B,S,d]. If ``kv`` given (cross-attention), keys/values come from it.
    kv_valid: [B,Sk] bool — key validity (needed for non-causal archs
    with padded sequences). Returns (out [B,S,d], (k,v) cache entries).
    """
    a = cfg.attn
    cdt = _dtype(cfg.compute_dtype)
    xq = x.astype(cdt)
    q = _split_heads(xq @ p["wq"].astype(cdt), a.num_heads, a.head_dim)
    if kv is None:
        k = _split_heads(xq @ p["wk"].astype(cdt), a.num_kv_heads, a.head_dim)
        v = _split_heads(xq @ p["wv"].astype(cdt), a.num_kv_heads, a.head_dim)
        kv_positions = positions
    else:
        src, src_pos = kv
        srcc = src.astype(cdt)
        k = _split_heads(srcc @ p["wk"].astype(cdt), a.num_kv_heads, a.head_dim)
        v = _split_heads(srcc @ p["wv"].astype(cdt), a.num_kv_heads, a.head_dim)
        kv_positions = src_pos
    window = a.window_for_layer(layer) if kv is None else None
    if a.use_rope:
        q = apply_rope(q, positions, a.rope_theta)
        if kv is None:
            k = apply_rope(k, kv_positions, a.rope_theta)
    scale = a.softmax_scale or 1.0 / math.sqrt(a.head_dim)
    is_causal = causal and kv is None
    Sq, Sk = q.shape[1], k.shape[1]
    if max(Sq, Sk) > ATTN_DIRECT_MAX:
        # flash-style streaming path: positions are shared across batch
        out = attend_chunked(q, k, v, positions[0] if positions.ndim == 2
                             else positions,
                             kv_positions[0] if kv_positions.ndim == 2
                             else kv_positions,
                             scale, causal=is_causal, window=window,
                             chunked_window=a.chunked_local,
                             logit_cap=a.logit_cap, kv_valid=kv_valid)
    else:
        mask = make_attn_mask(positions, kv_positions, causal=is_causal,
                              window=window, chunked=a.chunked_local)
        if kv_valid is not None:
            mask = mask & kv_valid[:, None, :]
        out = attend(q, k, v, mask, scale, a.logit_cap)
    out = out.reshape(out.shape[:-2] + (a.q_dim,))
    return (out @ p["wo"].astype(cdt)).astype(x.dtype), (k, v)


def attn_decode_partial(p, cfg: ModelConfig, x, pos, k_cache, v_cache,
                        cache_positions, *, layer: int):
    """One-token decode against a (possibly sharded) KV cache chunk.

    x: [B,1,d]; k_cache/v_cache: [B,Sc,Hkv,hd]; cache_positions: [B,Sc]
    (absolute positions; entries < 0 are invalid/padding).
    Returns flash-style partials (o_weighted [B,1,H,hd], m [B,H,1], l [B,H,1])
    so context-parallel shards can be merged with :func:`merge_partials`,
    plus the new (k,v) for cache insertion.
    """
    a = cfg.attn
    cdt = _dtype(cfg.compute_dtype)
    xq = x.astype(cdt)
    q = _split_heads(xq @ p["wq"].astype(cdt), a.num_heads, a.head_dim)
    k_new = _split_heads(xq @ p["wk"].astype(cdt), a.num_kv_heads, a.head_dim)
    v_new = _split_heads(xq @ p["wv"].astype(cdt), a.num_kv_heads, a.head_dim)
    if a.use_rope:
        q = apply_rope(q, pos, a.rope_theta)
        k_new = apply_rope(k_new, pos, a.rope_theta)
    window = a.window_for_layer(layer)
    scale = a.softmax_scale or 1.0 / math.sqrt(a.head_dim)

    n_rep = a.num_heads // a.num_kv_heads
    k = _repeat_kv(k_cache.astype(cdt), n_rep)       # [B,Sc,H,hd]
    v = _repeat_kv(v_cache.astype(cdt), n_rep)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale  # [B,H,1,Sc]
    qp = pos[:, None, :, None]                        # pos [B,1] -> [B,1,1q,1]
    kp = cache_positions[:, None, None, :]            # [B,1,1,Sc]
    valid = (kp >= 0) & (kp <= qp)
    if window is not None:
        if a.chunked_local:
            valid &= (qp // window) == (kp // window)
        else:
            valid &= (qp - kp) < window
    if a.logit_cap is not None:
        logits = a.logit_cap * jnp.tanh(logits / a.logit_cap)
    logits = jnp.where(valid, logits, NEG_INF)
    m = jnp.max(logits, axis=-1)                      # [B,H,1]
    # guard fully-masked shards
    m_safe = jnp.maximum(m, -0.5e30)
    w = jnp.exp(logits - m_safe[..., None])
    w = jnp.where(valid, w, 0.0)
    l = jnp.sum(w, axis=-1)                           # [B,H,1]
    o = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)  # [B,1,H,hd]
    return (o, m_safe, l), (k_new, v_new)


def merge_partials(partials):
    """Merge flash partials [(o, m, l)] across KV chunks -> [B,1,H,hd]."""
    o, m, l = partials[0]
    for o2, m2, l2 in partials[1:]:
        m_new = jnp.maximum(m, m2)
        a1 = jnp.exp(m - m_new)[..., None].swapaxes(1, 2)   # [B,1,H,1]
        a2 = jnp.exp(m2 - m_new)[..., None].swapaxes(1, 2)
        o = o * a1.astype(o.dtype) + o2 * a2.astype(o.dtype)
        l = l * jnp.exp(m - m_new) + l2 * jnp.exp(m2 - m_new)
        m = m_new
    return o, m, l


def finalize_partial(p, cfg: ModelConfig, x_dtype, o, m, l):
    a = cfg.attn
    cdt = _dtype(cfg.compute_dtype)
    denom = jnp.maximum(l, 1e-30)[..., None].swapaxes(1, 2)  # [B,1,H,1]
    out = (o / denom.astype(o.dtype)).reshape(o.shape[0], o.shape[1], a.q_dim)
    return (out @ p["wo"].astype(cdt)).astype(x_dtype)


# ---------------------------------------------------------------------------
# Dense FFN (gated MLP)
# ---------------------------------------------------------------------------

def ffn_init(key, d_model: int, d_ff: int, cfg: ModelConfig):
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dt),
         "w_down": dense_init(ks[1], d_ff, d_model, dt,
                              scale=1.0 / math.sqrt(2 * cfg.num_layers))}
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dt)
    return p


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def ffn_apply(p, cfg: ModelConfig, x):
    cdt = _dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    h = xc @ p["w_up"].astype(cdt)
    if cfg.gated_mlp:
        h = _act(cfg.act)(xc @ p["w_gate"].astype(cdt)) * h
    else:
        h = _act(cfg.act)(h)
    return (h @ p["w_down"].astype(cdt)).astype(x.dtype)
