"""Public model API: build_model(cfg) → Model.

Bundles init / train-loss / prefill / decode with the sharding rules and
``input_specs`` (ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, no device allocation) used by the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import serve_lib
from repro.config import LuffyConfig, ModelConfig, ShapeConfig
from repro.dist import DistContext
from repro.models import blocks as bk
from repro.models import transformer as tf


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- params -----------------------------------------------------------
    def init(self, key):
        return tf.init_params(key, self.cfg)

    def init_struct(self):
        """Parameter ShapeDtypeStructs without allocation (for dry-run)."""
        return jax.eval_shape(lambda: tf.init_params(
            jax.random.PRNGKey(0), self.cfg))

    # ---- forward fns -------------------------------------------------------
    def train_loss(self, params, batch, threshold, *, luffy: LuffyConfig,
                   dist: DistContext, capacity: int, wire_ef=None):
        return tf.forward_train(params, self.cfg, luffy, dist, batch,
                                threshold, capacity, wire_ef=wire_ef)

    def decode_step(self, params, cache, tokens, *, luffy: LuffyConfig,
                    dist: DistContext, plan_cache=None):
        return serve_lib.decode_step(params, self.cfg, luffy, dist, cache,
                                     tokens, plan_cache=plan_cache)

    def prefill(self, params, tokens, s_max, *, luffy: LuffyConfig,
                dist: DistContext, prefix=None, enc_input=None,
                plan_cache=None):
        return serve_lib.prefill(params, self.cfg, luffy, dist, tokens,
                                 s_max, prefix=prefix, enc_input=enc_input,
                                 plan_cache=plan_cache)

    # ---- sharding rules ----------------------------------------------------
    def param_pspecs(self, dist: DistContext, params_struct=None):
        cfg = self.cfg
        if params_struct is None:
            params_struct = self.init_struct()
        model_ax = dist.model_axis if dist.enabled else None
        fsdp = tuple(dist.fsdp_axes) if dist.enabled else ()

        def ax_size(name):
            return dist.axis_size(name) if dist.enabled else 1

        def rule(path, leaf):
            keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            shape = leaf.shape
            if not dist.enabled or leaf.ndim == 0:
                return P()
            stacked = keys.startswith("layers") or "encoder/layers" in keys
            off = 1 if (stacked and leaf.ndim >= 2) else 0
            dims = shape[off:]
            spec = [None] * leaf.ndim

            if "experts" in keys and len(dims) == 3:
                # experts over model; FSDP over the F dim (w_up/w_gate
                # [E,d,F] on dim 2, w_down [E,F,d] on dim 1) — the layout
                # the Megatron-style decode path consumes in place.
                spec[off] = model_ax
                fdim = off + (1 if "w_down" in keys else 2)
                if fsdp and shape[fdim] % ax_size(fsdp) == 0:
                    spec[fdim] = fsdp
                return P(*spec)
            if "embed/table" in keys:
                # shard the d dim only: the token gather stays fully local
                # (vocab sharding would turn every lookup into a masked
                # gather + batch-replicated all-reduce)
                if shape[1] % ax_size(model_ax) == 0:
                    spec[1] = model_ax
                return P(*spec)
            if "unembed" in keys:
                # vocab dim over model: logits stay vocab-sharded through
                # the chunked cross-entropy (logsumexp psums over model)
                vdim = leaf.ndim - 1
                if shape[vdim] % ax_size(model_ax) == 0:
                    spec[vdim] = model_ax
                if fsdp and shape[0] % ax_size(fsdp) == 0:
                    spec[0] = fsdp
                return P(*spec)
            if len(dims) >= 2:
                # generic 2-D weights: FSDP the largest dim
                big = max(range(len(dims)), key=lambda i: dims[i])
                if fsdp and dims[big] % ax_size(fsdp) == 0:
                    spec[off + big] = fsdp
                return P(*spec)
            return P()

        return jax.tree_util.tree_map_with_path(rule, params_struct)

    def opt_pspecs(self, dist: DistContext, params_struct=None):
        """Adam moments: same layout as params (already FSDP-sharded for
        the big tensors — ZeRO-1 falls out of the FSDP rules)."""
        return self.param_pspecs(dist, params_struct)

    def opt_moment_pspecs(self, dist: DistContext, ocfg, params_struct=None):
        """(mu_specs, nu_specs) for the given optimizer. Adafactor's
        factored nu gets the param spec with the reduced dim dropped."""
        from repro.optim import _factored
        if params_struct is None:
            params_struct = self.init_struct()
        pspecs = self.param_pspecs(dist, params_struct)
        if ocfg.name != "adafactor":
            return pspecs, pspecs

        def nu_spec(leaf, ps):
            if _factored(leaf):
                t = tuple(ps) + (None,) * (leaf.ndim - len(tuple(ps)))
                return {"r": P(*t[:-1]), "c": P(*(t[:-2] + t[-1:]))}
            return ps

        nu = jax.tree.map(nu_spec, params_struct, pspecs,
                          is_leaf=lambda x: isinstance(
                              x, jax.ShapeDtypeStruct))
        return pspecs, nu

    # ---- input specs (dry-run stand-ins) -----------------------------------
    def input_specs(self, shape: ShapeConfig, dist: DistContext
                    ) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        ba = dist.batch_axes if (dist.enabled and dist.batch_axes) else None
        sax = dist.seq_axis if dist.enabled else None

        def sds(shp, dt, spec):
            sh = dist.sharding(spec) if dist.enabled else None
            return jax.ShapeDtypeStruct(shp, dt, sharding=sh)

        if shape.mode == "train":
            # prefix slots displace decoder tokens only for decoder-only
            # multimodal archs; enc-dec prefixes feed the encoder instead
            S_tok = S - (cfg.prefix_slots if cfg.kind != "encdec" else 0)
            out = {
                "tokens": sds((B, S_tok), jnp.int32, P(ba, sax)),
                "labels": sds((B, S), jnp.int32, P(ba, sax)),
                "seq_len": sds((B,), jnp.int32, P(ba)),
            }
            if cfg.prefix_slots > 0 and cfg.kind != "encdec":
                out["prefix"] = sds(
                    (B, cfg.prefix_slots, cfg.prefix_dim or cfg.d_model),
                    jnp.float32, P(ba, None, None))
            if cfg.kind == "encdec":
                out["enc_input"] = sds(
                    (B, S, cfg.prefix_dim or cfg.d_model), jnp.float32,
                    P(ba, sax, None))
            return out
        if shape.mode == "prefill":
            S_tok = S - (cfg.prefix_slots if cfg.kind != "encdec" else 0)
            out = {"tokens": sds((B, S_tok), jnp.int32, P(ba, sax))}
            if cfg.prefix_slots > 0 and cfg.kind != "encdec":
                out["prefix"] = sds(
                    (B, cfg.prefix_slots, cfg.prefix_dim or cfg.d_model),
                    jnp.float32, P(ba, None, None))
            if cfg.kind == "encdec":
                out["enc_input"] = sds(
                    (B, S, cfg.prefix_dim or cfg.d_model), jnp.float32,
                    P(ba, sax, None))
            return out
        # decode
        return {"tokens": sds((B, 1), jnp.int32, P(ba, None))}

    def cache_specs(self, shape: ShapeConfig, dist: DistContext):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        enc_len = S if cfg.kind == "encdec" else 0
        struct = serve_lib.cache_struct(cfg, B, S, enc_len=enc_len,
                                        as_struct=True)
        pspecs = serve_lib.cache_pspecs(cfg, dist, S)
        if not dist.enabled:
            return struct, pspecs

        def attach(s, p):
            return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                        sharding=dist.sharding(p))

        return jax.tree.map(attach, struct, pspecs,
                            is_leaf=lambda x: isinstance(
                                x, jax.ShapeDtypeStruct)), pspecs


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
