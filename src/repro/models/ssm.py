"""State-space / linear-attention token mixers.

* ``mamba_*`` — selective SSM (used by hymba's parallel SSM heads)
  [arXiv:2411.13676 uses Mamba heads with state 16].
* ``rwkv6_*`` — RWKV-6 "Finch" time-mix with data-dependent decay
  [arXiv:2404.05892].

Both expose a full-sequence form (``lax.scan`` over time — the recurrence
IS the paper-faithful semantics; a chunked/associative formulation is a
perf option handled at the kernel layer) and a single-step decode form
carrying explicit recurrent state, which is what makes these archs legal
for the ``long_500k`` shape.
"""
from __future__ import annotations

import math
from typing import Tuple

import os

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def _scan_unroll() -> int:
    """lax.scan unroll factor for the SSM time scans (REPRO_SSM_UNROLL).
    Unrolling k steps keeps k states in registers/VMEM per loop iteration
    instead of round-tripping the loop-carried state through HBM every
    token — the chunked-scan insight of the Mamba kernel, expressible in
    pure XLA. Default 1 (paper-faithful naive scan = the §Perf baseline).
    """
    return int(os.environ.get("REPRO_SSM_UNROLL", "1"))
from repro.models.blocks import dense_init, _dtype


# ---------------------------------------------------------------------------
# Mamba-style selective SSM
# ---------------------------------------------------------------------------

def mamba_init(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dt_rank = s.dt_rank or max(1, math.ceil(d / 16))
    pdt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    a_init = jnp.tile(jnp.arange(1, s.state_dim + 1, dtype=jnp.float32)[None],
                      (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, pdt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_dim, di)) * 0.1).astype(pdt),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * s.state_dim, pdt),
        "dt_proj": dense_init(ks[3], dt_rank, di, pdt),
        "dt_bias": jnp.zeros((di,), pdt),
        "a_log": jnp.log(a_init).astype(jnp.float32),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, pdt,
                               scale=1.0 / math.sqrt(2 * cfg.num_layers)),
    }


def _mamba_inner(p, cfg, x_conv, z, h0):
    """x_conv: [B,S,di] post-conv pre-activation; returns (y [B,S,di], hT)."""
    s = cfg.ssm
    dt_rank = p["dt_proj"].shape[0]
    cdt = _dtype(cfg.compute_dtype)
    xc = jax.nn.silu(x_conv).astype(cdt)
    proj = (xc @ p["x_proj"].astype(cdt)).astype(jnp.float32)
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + s.state_dim], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # [B,S,di]
    a = -jnp.exp(p["a_log"])                                      # [di,N]
    da = jnp.exp(dt[..., None] * a)                               # [B,S,di,N]
    dbx = (dt * xc.astype(jnp.float32))[..., None] * bmat[..., None, :]

    S, di = xc.shape[1], dt.shape[-1]
    use_kernel = (os.environ.get("REPRO_MAMBA_KERNEL", "0") == "1"
                  and S % 32 == 0 and di % 32 == 0)
    if use_kernel and S > 1:
        # fused chunked-scan Pallas kernel (EXPERIMENTS.md §Perf H4):
        # never materializes the [B,S,di,N] da/dbx intermediates and the
        # state touches HBM once per chunk. NOTE: assumes zero initial
        # state (training/prefill); decode keeps the step path below.
        from repro.kernels import ops as kops
        y = kops.mamba_scan(dt, xc.astype(jnp.float32),
                            bmat, cmat, a,
                            bd=min(256, di), bs=min(256, S))
        hT = h0  # final state not produced by the fused path
    else:
        def step(h, inp):
            da_t, dbx_t, c_t = inp
            h = da_t * h + dbx_t                                  # [B,di,N]
            y = jnp.einsum("bdn,bn->bd", h, c_t)
            return h, y

        da_s = jnp.moveaxis(da, 1, 0)
        dbx_s = jnp.moveaxis(dbx, 1, 0)
        c_s = jnp.moveaxis(cmat, 1, 0)
        hT, ys = jax.lax.scan(step, h0, (da_s, dbx_s, c_s),
                              unroll=_scan_unroll())
        y = jnp.moveaxis(ys, 0, 1)                                # [B,S,di]
    y = y + p["d_skip"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(cdt), hT


def mamba_apply(p, cfg: ModelConfig, x):
    """Full-sequence Mamba. x: [B,S,d] -> [B,S,d]."""
    s = cfg.ssm
    cdt = _dtype(cfg.compute_dtype)
    di = p["dt_bias"].shape[0]
    xz = x.astype(cdt) @ p["in_proj"].astype(cdt)
    xin, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv
    w = p["conv_w"].astype(cdt)                                   # [K,di]
    pad = jnp.pad(xin, ((0, 0), (s.conv_dim - 1, 0), (0, 0)))
    xconv = sum(pad[:, i:i + xin.shape[1]] * w[i] for i in range(s.conv_dim))
    h0 = jnp.zeros((x.shape[0], di, s.state_dim), jnp.float32)
    y, _ = _mamba_inner(p, cfg, xconv, z, h0)
    return (y @ p["out_proj"].astype(cdt)).astype(x.dtype)


def mamba_init_state(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {"h": jnp.zeros((batch, di, s.state_dim), jnp.float32),
            "conv": jnp.zeros((batch, s.conv_dim - 1, di), jnp.float32)}


def mamba_step(p, cfg: ModelConfig, x, state):
    """Single-token decode. x: [B,1,d]; state carries h and conv tail."""
    s = cfg.ssm
    cdt = _dtype(cfg.compute_dtype)
    xz = x.astype(cdt) @ p["in_proj"].astype(cdt)
    xin, z = jnp.split(xz, 2, axis=-1)                            # [B,1,di]
    hist = jnp.concatenate([state["conv"].astype(cdt), xin], axis=1)  # [B,K,di]
    w = p["conv_w"].astype(cdt)
    xconv = jnp.einsum("bkd,kd->bd", hist, w)[:, None]
    y, hT = _mamba_inner(p, cfg, xconv, z, state["h"])
    new_state = {"h": hT, "conv": hist[:, 1:].astype(jnp.float32)}
    return (y @ p["out_proj"].astype(cdt)).astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# RWKV-6 (Finch) time-mix with data-dependent decay
# ---------------------------------------------------------------------------

def rwkv6_init(key, cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    n_heads = d // hd
    pdt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    lora = max(32, d // 32)
    return {
        "mix_r": jnp.full((d,), 0.5, pdt),
        "mix_k": jnp.full((d,), 0.5, pdt),
        "mix_v": jnp.full((d,), 0.5, pdt),
        "mix_w": jnp.full((d,), 0.5, pdt),
        "wr": dense_init(ks[0], d, d, pdt),
        "wk": dense_init(ks[1], d, d, pdt),
        "wv": dense_init(ks[2], d, d, pdt),
        "wg": dense_init(ks[3], d, d, pdt),
        "wo": dense_init(ks[4], d, d, pdt,
                         scale=1.0 / math.sqrt(2 * cfg.num_layers)),
        # data-dependent decay LoRA (the Finch contribution)
        "w_lora_a": dense_init(ks[5], d, lora, pdt),
        "w_lora_b": dense_init(ks[6], lora, d, pdt, scale=0.1),
        "w_bias": jnp.full((d,), -6.0, jnp.float32),
        "u_bonus": (jax.random.normal(ks[7], (n_heads, hd)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.ones((d,), pdt),
    }


def _rwkv6_core(p, cfg, r, k, v, w, state):
    """Recurrent WKV6. r,k,v: [B,S,H,hd]; w decay in (0,1): [B,S,H,hd].

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    """
    u = p["u_bonus"]                                              # [H,hd]

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                                  # [B,H,hd]
        kv = k_t[..., :, None] * v_t[..., None, :]                # [B,H,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    rs, ks_, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    ST, ys = jax.lax.scan(step, state, (rs, ks_, vs, ws),
                          unroll=_scan_unroll())
    return jnp.moveaxis(ys, 0, 1), ST                             # [B,S,H,hd]


def _rwkv6_project(p, cfg, x, x_prev):
    """Token-shift mixes + projections. x,x_prev: [B,S,d] (x_prev = shifted)."""
    cdt = _dtype(cfg.compute_dtype)
    hd = cfg.ssm.head_dim
    d = x.shape[-1]
    n_heads = d // hd
    xc, xp = x.astype(cdt), x_prev.astype(cdt)

    def mix(m):
        mm = p[m].astype(cdt)
        return xc * mm + xp * (1 - mm)

    B, S = x.shape[0], x.shape[1]
    r = (mix("mix_r") @ p["wr"].astype(cdt)).reshape(B, S, n_heads, hd)
    k = (mix("mix_k") @ p["wk"].astype(cdt)).reshape(B, S, n_heads, hd)
    v = (mix("mix_v") @ p["wv"].astype(cdt)).reshape(B, S, n_heads, hd)
    g = jax.nn.silu(xc @ p["wg"].astype(cdt))
    ww = mix("mix_w").astype(jnp.float32)
    ww = (ww @ p["w_lora_a"].astype(jnp.float32)) @ p["w_lora_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww + p["w_bias"]))                       # (0,1)
    w = w.reshape(B, S, n_heads, hd)
    return (r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w, g)


def _rwkv6_out(p, cfg, y, g, out_dtype):
    cdt = _dtype(cfg.compute_dtype)
    B, S = y.shape[0], y.shape[1]
    d = p["wo"].shape[0]
    yf = y.reshape(B, S, d)
    # per-head group norm approximation: RMS over head dim
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
    yf = yf * p["ln_scale"].astype(jnp.float32)
    out = (yf.astype(cdt) * g) @ p["wo"].astype(cdt)
    return out.astype(out_dtype)


def rwkv6_apply(p, cfg: ModelConfig, x):
    """Full-sequence RWKV6 time-mix. x: [B,S,d]."""
    hd = cfg.ssm.head_dim
    n_heads = x.shape[-1] // hd
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, w, g = _rwkv6_project(p, cfg, x, x_prev)
    S0 = jnp.zeros((x.shape[0], n_heads, hd, hd), jnp.float32)
    y, _ = _rwkv6_core(p, cfg, r, k, v, w, S0)
    return _rwkv6_out(p, cfg, y, g, x.dtype)


def rwkv6_init_state(cfg: ModelConfig, batch: int):
    hd = cfg.ssm.head_dim
    n_heads = cfg.d_model // hd
    return {"S": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
            "x_prev": jnp.zeros((batch, 1, cfg.d_model), jnp.float32)}


def rwkv6_step(p, cfg: ModelConfig, x, state):
    """Single-token decode. x: [B,1,d]."""
    r, k, v, w, g = _rwkv6_project(p, cfg, x, state["x_prev"].astype(x.dtype))
    y, ST = _rwkv6_core(p, cfg, r, k, v, w, state["S"])
    new_state = {"S": ST, "x_prev": x.astype(jnp.float32)}
    return _rwkv6_out(p, cfg, y, g, x.dtype), new_state


# ---------------------------------------------------------------------------
# RWKV channel-mix (the FFN of rwkv archs)
# ---------------------------------------------------------------------------

def rwkv_cmix_init(key, cfg: ModelConfig):
    d, dff = cfg.d_model, cfg.d_ff
    pdt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {"mix_k": jnp.full((d,), 0.5, pdt),
            "wk": dense_init(ks[0], d, dff, pdt),
            "wv": dense_init(ks[1], dff, d, pdt,
                             scale=1.0 / math.sqrt(2 * cfg.num_layers)),
            "wr": dense_init(ks[2], d, d, pdt)}


def rwkv_cmix_apply(p, cfg: ModelConfig, x, x_prev=None):
    cdt = _dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    if x_prev is None:
        xp = jnp.pad(xc, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xp = x_prev.astype(cdt)
    m = p["mix_k"].astype(cdt)
    xk = xc * m + xp * (1 - m)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(cdt)))
    r = jax.nn.sigmoid(xc @ p["wr"].astype(cdt))
    return (r * (k @ p["wv"].astype(cdt))).astype(x.dtype)
