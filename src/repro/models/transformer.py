"""Model stacks: decoder-only / encoder-decoder, dense / MoE / SSM / hybrid.

Layers are grouped by the architecture's *pattern period* (lcm of the
window pattern and the FFN pattern) and scanned with ``lax.scan`` over
groups — one group's HLO regardless of depth, which keeps 48-layer
dry-run compiles cheap. Params for pattern position ``j`` are stacked
``[n_groups, ...]``.

Train/prefill use full-sequence attention; decode uses per-layer KV ring
buffers (window layers) or full caches (global layers), written as plain
sharded-array code so GSPMD inserts the context-parallel collectives.
The MoE sublayer is the exception: it runs in an explicit shard_map
(see ``repro.core.moe_layer``).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import comm as rcomm
from repro.config import LuffyConfig, ModelConfig
from repro.core import moe_layer as moe
from repro.dist import DistContext
from repro.models import blocks as bk
from repro.models import ssm as ssm_mod

Array = jnp.ndarray


def pattern_period(cfg: ModelConfig) -> int:
    a = len(cfg.attn.window_pattern) if cfg.attn is not None else 1
    b = len(cfg.layer_ffn_pattern)
    return math.lcm(a, b)


def _uses_ssm(cfg: ModelConfig) -> bool:
    return cfg.ssm is not None


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, j: int, *, decoder_of_encdec: bool):
    ks = jax.random.split(key, 8)
    pdt = bk._dtype(cfg.param_dtype)
    p: Dict[str, Any] = {}
    if cfg.attn is not None:
        p["attn_norm"] = bk.norm_init(cfg.d_model, cfg.norm, pdt)
        p["attn"] = bk.attn_init(ks[0], cfg)
    if cfg.ssm is not None:
        if cfg.ssm.kind == "mamba":
            p["ssm"] = ssm_mod.mamba_init(ks[1], cfg)
        else:
            p["ssm"] = ssm_mod.rwkv6_init(ks[1], cfg)
        if cfg.attn is None or not cfg.parallel_ssm:
            p["ssm_norm"] = bk.norm_init(cfg.d_model, cfg.norm, pdt)
    if decoder_of_encdec:
        p["cross_norm"] = bk.norm_init(cfg.d_model, cfg.norm, pdt)
        p["cross_attn"] = bk.attn_init(ks[2], cfg, cross=True)
    kind = cfg.ffn_kind(j)
    if kind == "moe":
        p["moe"] = moe.moe_init(ks[3], cfg)
    else:
        p["ffn_norm"] = bk.norm_init(cfg.d_model, cfg.norm, pdt)
        if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
            p["ffn"] = ssm_mod.rwkv_cmix_init(ks[4], cfg)
        else:
            p["ffn"] = bk.ffn_init(ks[4], cfg.d_model, cfg.d_ff, cfg)
    return p


def init_params(key, cfg: ModelConfig):
    period = pattern_period(cfg)
    assert cfg.num_layers % period == 0, (cfg.name, cfg.num_layers, period)
    n_groups = cfg.num_layers // period
    pdt = bk._dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": {"table": bk.embed_init(keys[0], cfg.vocab_size,
                                         cfg.d_model, pdt)},
        "final_norm": bk.norm_init(cfg.d_model, cfg.norm, pdt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = {"w": bk.dense_init(keys[1], cfg.d_model,
                                                cfg.vocab_size, pdt)}
    if cfg.prefix_slots > 0:
        params["prefix_proj"] = {"w": bk.dense_init(
            keys[2], cfg.prefix_dim or cfg.d_model, cfg.d_model, pdt)}

    def stack_layers(base_key, n, j, decoder_of_encdec):
        lkeys = jax.random.split(base_key, n)
        return jax.vmap(lambda k: _init_layer(
            k, cfg, j, decoder_of_encdec=decoder_of_encdec))(lkeys)

    params["layers"] = [stack_layers(jax.random.fold_in(keys[3], j),
                                     n_groups, j,
                                     decoder_of_encdec=(cfg.kind == "encdec"))
                        for j in range(period)]
    if cfg.kind == "encdec":
        enc_groups = cfg.num_encoder_layers // period
        assert enc_groups * period == cfg.num_encoder_layers
        params["encoder"] = {
            "layers": [stack_layers(jax.random.fold_in(keys[4], 100 + j),
                                    enc_groups, j, decoder_of_encdec=False)
                       for j in range(period)],
            "final_norm": bk.norm_init(cfg.d_model, cfg.norm, pdt),
        }
    return params


# ---------------------------------------------------------------------------
# full-sequence layer (train / prefill / encoder)
# ---------------------------------------------------------------------------

def _attn_seqpar(p, cfg, xn, positions, layer_idx, *, causal, dist,
                 kv_valid=None, kv_src=None, kv_src_pos=None):
    """Sequence-parallel attention: S is sharded over dist.seq_axis, so
    each device attends its LOCAL query chunk against all-gathered K/V
    (one bf16 gather per layer). Without this, the chunked-attention
    lax.map serializes the q-chunk axis and GSPMD replicates the whole
    attention on every model rank (observed: prefill memory terms blowing
    up by the axis size)."""
    a = cfg.attn
    mesh = dist.mesh
    sax = dist.seq_axis
    bax = dist.batch_axes if dist.batch_axes else None
    import math as _math
    cdt = bk._dtype(cfg.compute_dtype)

    has_kvv = kv_valid is not None
    has_src = kv_src is not None

    def inner(p_l, x_l, pos_l, kvv_l, src_l, spos_l):
        kvv_l = kvv_l if has_kvv else None
        src_l = src_l if has_src else None
        spos_l = spos_l if has_src else None
        xq = x_l.astype(cdt)
        q = bk._split_heads(xq @ p_l["wq"].astype(cdt), a.num_heads,
                            a.head_dim)
        src = xq if src_l is None else src_l.astype(cdt)
        k = bk._split_heads(src @ p_l["wk"].astype(cdt), a.num_kv_heads,
                            a.head_dim)
        v = bk._split_heads(src @ p_l["wv"].astype(cdt), a.num_kv_heads,
                            a.head_dim)
        kpos_l = pos_l if src_l is None else spos_l
        if a.use_rope:
            q = bk.apply_rope(q, pos_l, a.rope_theta)
            if src_l is None:
                k = bk.apply_rope(k, kpos_l, a.rope_theta)
        # gather keys/values (+positions/validity) across the seq shards.
        # The optimization barrier pins the gathered buffers: without it
        # XLA sinks the (loop-invariant) gather INTO the q-chunk loop and
        # re-gathers K/V per chunk — observed 512 gathers instead of 48
        # on gemma3 prefill (EXPERIMENTS.md §Perf H1).
        k_g = jax.lax.all_gather(k, sax, axis=1, tiled=True)
        v_g = jax.lax.all_gather(v, sax, axis=1, tiled=True)
        kp_g = jax.lax.all_gather(kpos_l, sax, axis=1, tiled=True)
        k_g, v_g, kp_g = jax.lax.optimization_barrier((k_g, v_g, kp_g))
        kv_g = (None if kvv_l is None
                else jax.lax.all_gather(kvv_l, sax, axis=1, tiled=True))
        scale = a.softmax_scale or 1.0 / _math.sqrt(a.head_dim)
        window = a.window_for_layer(layer_idx) if src_l is None else None
        is_causal = causal and src_l is None
        if max(q.shape[1], k_g.shape[1]) > bk.ATTN_DIRECT_MAX:
            out = bk.attend_chunked(
                q, k_g, v_g, pos_l[0], kp_g[0], scale, causal=is_causal,
                window=window, chunked_window=a.chunked_local,
                logit_cap=a.logit_cap, kv_valid=kv_g)
        else:
            mask = bk.make_attn_mask(pos_l, kp_g, causal=is_causal,
                                     window=window,
                                     chunked=a.chunked_local)
            if kv_g is not None:
                mask = mask & kv_g[:, None, :]
            out = bk.attend(q, k_g, v_g, mask, scale, a.logit_cap)
        out = out.reshape(out.shape[:-2] + (a.q_dim,))
        return (out @ p_l["wo"].astype(cdt)).astype(x_l.dtype)

    x_spec = P(bax, sax, None)
    pos_spec = P(bax, sax)
    p_specs = jax.tree.map(lambda _: P(), p)
    kvv = kv_valid
    fn = rcomm.shard_map(
        inner, mesh=mesh,
        in_specs=(p_specs, x_spec, pos_spec,
                  pos_spec if kvv is not None else P(),
                  x_spec if kv_src is not None else P(),
                  pos_spec if kv_src is not None else P()),
        out_specs=x_spec)
    return fn(p, xn, positions,
              kvv if kvv is not None else jnp.zeros((), jnp.int32),
              kv_src if kv_src is not None else jnp.zeros((), jnp.int32),
              kv_src_pos if kv_src_pos is not None
              else jnp.zeros((), jnp.int32))


def _token_mixer_full(p, cfg, x, positions, layer_idx, *, causal, enc_out,
                      enc_pos, dist: DistContext, kv_valid=None):
    """Attention and/or SSM sublayer (+cross-attn), full sequence."""
    out_kv = None
    seqpar = (dist.enabled and dist.seq_axis is not None
              and cfg.attn is not None)

    def self_attn(xn):
        if seqpar:
            return _attn_seqpar(p["attn"], cfg, xn, positions, layer_idx,
                                causal=causal, dist=dist,
                                kv_valid=kv_valid), None
        return bk.attn_apply(p["attn"], cfg, xn, positions,
                             layer=layer_idx, causal=causal,
                             kv_valid=kv_valid)

    if cfg.attn is not None and cfg.ssm is not None and cfg.parallel_ssm:
        xn = bk.norm_apply(p["attn_norm"], x, cfg.norm)
        att, out_kv = self_attn(xn)
        sso = ssm_mod.mamba_apply(p["ssm"], cfg, xn)
        x = x + 0.5 * (att + sso)
    elif cfg.attn is not None:
        xn = bk.norm_apply(p["attn_norm"], x, cfg.norm)
        att, out_kv = self_attn(xn)
        x = x + att
    else:  # pure SSM (rwkv6)
        xn = bk.norm_apply(p["ssm_norm"], x, cfg.norm)
        if cfg.ssm.kind == "mamba":
            x = x + ssm_mod.mamba_apply(p["ssm"], cfg, xn)
        else:
            x = x + ssm_mod.rwkv6_apply(p["ssm"], cfg, xn)
    if enc_out is not None:
        xn = bk.norm_apply(p["cross_norm"], x, cfg.norm)
        if seqpar:
            ca = _attn_seqpar(p["cross_attn"], cfg, xn, positions,
                              layer_idx, causal=False, dist=dist,
                              kv_src=enc_out, kv_src_pos=enc_pos)
        else:
            ca, _ = bk.attn_apply(p["cross_attn"], cfg, xn, positions,
                                  layer=layer_idx, kv=(enc_out, enc_pos),
                                  causal=False)
        x = x + ca
    return x, out_kv


def _pmean_all(v, axes):
    """pmean over all mesh axes regardless of the value's varying state
    (replicated-over-model decode aux scalars otherwise fail the vma
    check on new jax; see repro.comm.compat.pmean_all)."""
    return rcomm.pmean_all(v, axes)


def _moe_apply_dist(p_moe, x, sideband, s_prev, threshold, cfg, luffy,
                    dist: DistContext, mode: str, capacity: int,
                    plan_carry=None, cond_carry=None, plan_template=None,
                    wire_ef=None):
    """Wrap moe_core in shard_map when a mesh is present.

    plan_carry (DESIGN.md §9): the cross-sublayer plan-reuse state —
    ``{"counts", "lens", "valid"}`` global arrays threaded through the
    layer scan; None disables threading (the return slot is then None).
    cond_carry (DESIGN.md §10): the condense-reuse state — ``{"rep"
    [B,S], "cexp" [B,S], "age" [B], "valid" [B]}`` — threaded the same
    way whenever condensation is on (every ``condense_reuse`` mode, for
    graph parity).
    plan_template: a cached static :class:`ExchangePlan` template (the
    serving path) routed to ``instantiate_plan`` instead of a build.
    wire_ef (DESIGN.md §15): the per-layer lossy-wire error-feedback
    residual [B, S, d] (sharded like x); None disables threading.
    Returns (y, sideband, s_next, aux, plan_carry_out, cond_carry_out,
    wire_ef_out)."""
    from repro.condense.plan import CondenseCarry
    from repro.plan.exchange import PlanSignature
    if mode == "decode" and dist.enabled and dist.model_size > 1:
        # decode: tokens replicated over the model axis; all-reduce MoE
        # (see moe_decode_allreduce — the S=1 token dim cannot shard)
        mesh = dist.mesh
        all_axes = tuple(mesh.axis_names)
        bax = dist.batch_axes if dist.batch_axes else None
        # 2D expert sharding for decode (REPRO_MOE_DECODE_2D=0 restores
        # the weight-gather baseline — the §Perf "before" variant): the
        # FSDP'd expert weights stay sharded; activations psum instead.
        import os as _os
        fsdp = tuple(a for a in dist.fsdp_axes if a in all_axes)
        n_fsdp = dist.axis_size(fsdp) if fsdp else 1
        use_2d = (_os.environ.get("REPRO_MOE_DECODE_2D", "1") == "1"
                  and fsdp
                  and cfg.moe.d_ff % n_fsdp == 0)
        ma = dist.model_axis          # "model" or ("node", "local")
        moe_specs = jax.tree.map(lambda _: P(), p_moe)
        if use_2d:
            moe_specs["experts"] = {
                k: (P(ma, fsdp, None) if k == "w_down"
                    else P(ma, None, fsdp))
                for k in p_moe["experts"]}
        else:
            moe_specs["experts"] = jax.tree.map(
                lambda _: P(ma, None, None), p_moe["experts"])

        batch_sharded = bool(dist.batch_axes)

        def inner_dec(p_moe_l, x_l):
            y, aux = moe.moe_decode_allreduce(
                p_moe_l, x_l, cfg, capacity=capacity,
                axis_name=dist.model_axis, use_kernel=luffy.use_kernels,
                fsdp_axes=fsdp if use_2d else None,
                batch_sharded=batch_sharded,
                overlap=luffy.exec_mode == "decode_overlap")
            aux = jax.tree.map(lambda a: _pmean_all(a, all_axes), aux)
            return y, aux

        fn = rcomm.shard_map(
            inner_dec, mesh=mesh,
            in_specs=(moe_specs, P(bax, None, None)),
            out_specs=(P(bax, None, None),
                       jax.tree.map(lambda _: P(),
                                    moe.MoEAux(*([0.0] * moe.N_AUX)))))
        y, aux = fn(p_moe, x)
        return y, dict(sideband), None, aux, plan_carry, cond_carry, \
            wire_ef
    if not dist.enabled or dist.model_size == 1:
        sb = dict(sideband)
        reuse = None
        if plan_carry is not None:
            reuse = PlanSignature(plan_carry["counts"], plan_carry["lens"],
                                  plan_carry["valid"])
        creuse = None
        if cond_carry is not None:
            creuse = CondenseCarry(cond_carry["rep"].reshape(-1),
                                   cond_carry["cexp"].reshape(-1),
                                   cond_carry["age"], cond_carry["valid"])
        y, sb2, s_next, aux, plan, cc, ef2 = moe.moe_core_planned(
            p_moe, x, sb, cfg, luffy, mode=mode, capacity=capacity,
            axis_name=None, threshold=threshold, s_prev=s_prev,
            group_size=luffy.condense_group,
            combine_slack=luffy.combine_slack, use_kernel=luffy.use_kernels,
            reuse_from=reuse, condense_reuse_from=creuse,
            plan_template=plan_template, wire_ef=wire_ef)
        if s_next is not None:
            G = luffy.condense_group
            s_next = s_next.reshape(x.shape[0], x.shape[1] // G, G, G)
        carry_out = None
        if plan_carry is not None:
            sig = plan.signature
            carry_out = {"counts": sig.counts, "lens": sig.lens,
                         "valid": sig.valid}
        cond_out = None
        if cond_carry is not None:
            cond_out = cond_carry if cc is None else cc
        return y, sb2, s_next, aux, carry_out, cond_out, \
            (wire_ef if ef2 is None else ef2)

    mesh = dist.mesh
    all_axes = tuple(mesh.axis_names)
    bax = dist.batch_axes if dist.batch_axes else None
    sax = dist.seq_axis
    x_spec = P(bax, sax, None)
    lbl_spec = P(bax, sax)
    len_spec = P(bax)
    sp_spec = P(bax, None, None, None)
    has_sp = s_prev is not None

    fsdp = tuple(a for a in dist.fsdp_axes if a in all_axes)
    comm_ctx = rcomm.CommContext.build(luffy.comm_mode, dist.model_axis,
                                       dist.topology)
    has_pc = plan_carry is not None
    has_cc = cond_carry is not None
    has_ef = wire_ef is not None

    def inner(p_moe_l, x_l, lbl, slen, sp, thr, pcc, pcl, pcv,
              ccr, cce, cca, ccv, efp):
        if fsdp:
            # explicit bf16 FSDP all-gather of the expert F-dim shards;
            # leaving this to GSPMD hoists an f32 convert before the
            # gather on backends that emulate bf16 dots (2x bytes).
            p_moe_l = dict(p_moe_l)
            p_moe_l["experts"] = {
                k: jax.lax.all_gather(
                    w, fsdp, axis=(1 if k == "w_down" else 2), tiled=True)
                for k, w in p_moe_l["experts"].items()}
        sb = {"labels": lbl, "seq_len": slen}
        reuse = PlanSignature(pcc, pcl, pcv) if has_pc else None
        creuse = (CondenseCarry(ccr.reshape(-1), cce.reshape(-1), cca, ccv)
                  if has_cc else None)
        y, sb2, s_next, aux, plan, cc, ef2 = moe.moe_core_planned(
            p_moe_l, x_l, sb, cfg, luffy, mode=mode, capacity=capacity,
            comm=comm_ctx, threshold=thr,
            s_prev=(sp if has_sp else None),
            group_size=luffy.condense_group,
            combine_slack=luffy.combine_slack, use_kernel=luffy.use_kernels,
            reuse_from=reuse, condense_reuse_from=creuse,
            plan_template=plan_template,
            wire_ef=(efp if has_ef else None))
        if has_ef and ef2 is not None:
            efp = ef2
        aux = jax.tree.map(lambda a: _pmean_all(a, all_axes), aux)
        if s_next is None:
            s_next = jnp.zeros((1,), jnp.float32)    # placeholder
        else:
            ng = x_l.shape[1] // luffy.condense_group
            s_next = s_next.reshape(x_l.shape[0], ng, luffy.condense_group,
                                    luffy.condense_group)
        if has_pc:
            # carried signature: replicated within a model row by
            # construction (all-gathered planner inputs), but specced
            # per-device varying to stay version-robust — mark it so
            sig = plan.signature
            pcc = rcomm.pvary_all(sig.counts, all_axes)
            pcl = rcomm.pvary_all(sig.lens, all_axes)
            pcv = sig.valid
        if has_cc and cc is not None:
            ccr, cce = cc["rep"], cc["cexp"]
            cca, ccv = cc["age"], cc["valid"]
        return (y, sb2["labels"], sb2["seq_len"], s_next, aux,
                pcc, pcl, pcv, ccr, cce, cca, ccv, efp)

    ma = dist.model_axis              # "model" or ("node", "local")
    moe_specs = jax.tree.map(lambda _: P(), p_moe)
    moe_specs["experts"] = {
        k: (P(ma, fsdp if fsdp else None, None) if k == "w_down"
            else P(ma, None, fsdp if fsdp else None))
        for k in p_moe["experts"]}
    sp_in = sp_spec if has_sp else P()
    sp_arg = s_prev if has_sp else jnp.zeros((1,), jnp.float32)
    s_out_spec = sp_spec if (luffy.enable_condensation and mode != "decode") \
        else P()
    zp = jnp.zeros((1,), jnp.float32)
    zpi = jnp.zeros((1,), jnp.int32)
    pc_counts_spec = P(bax, None) if has_pc else P()
    pc_lens_spec = P(bax) if has_pc else P()
    pc_args = ((plan_carry["counts"], plan_carry["lens"],
                plan_carry["valid"]) if has_pc else (zp, zp, zp))
    cc_map_spec = P(bax, None) if has_cc else P()
    cc_seq_spec = P(bax) if has_cc else P()
    cc_args = ((cond_carry["rep"], cond_carry["cexp"], cond_carry["age"],
                cond_carry["valid"]) if has_cc else (zpi, zpi, zp, zp))
    ef_spec = x_spec if has_ef else P()
    ef_arg = wire_ef if has_ef else jnp.zeros((1, 1, 1), jnp.float32)
    fn = rcomm.shard_map(
        inner, mesh=mesh,
        in_specs=(moe_specs, x_spec, lbl_spec, len_spec, sp_in, P(),
                  pc_counts_spec, pc_lens_spec, P(),
                  cc_map_spec, cc_map_spec, cc_seq_spec, cc_seq_spec,
                  ef_spec),
        out_specs=(x_spec, lbl_spec, len_spec, s_out_spec,
                   jax.tree.map(lambda _: P(),
                                moe.MoEAux(*([0.0] * moe.N_AUX))),
                   pc_counts_spec, pc_lens_spec, P(),
                   cc_map_spec, cc_map_spec, cc_seq_spec, cc_seq_spec,
                   ef_spec))
    (y, lbl2, slen2, s_next, aux, pcc2, pcl2, pcv2,
     ccr2, cce2, cca2, ccv2, ef2) = fn(
        p_moe, x, sideband["labels"], sideband["seq_len"], sp_arg,
        threshold, *pc_args, *cc_args, ef_arg)
    if not (luffy.enable_condensation and mode != "decode"):
        s_next = None
    carry_out = ({"counts": pcc2, "lens": pcl2, "valid": pcv2}
                 if has_pc else None)
    cond_out = ({"rep": ccr2, "cexp": cce2, "age": cca2, "valid": ccv2}
                if has_cc else None)
    return (y, {"labels": lbl2, "seq_len": slen2}, s_next, aux, carry_out,
            cond_out, (ef2 if has_ef else None))


def _layer_full(p, cfg, luffy, dist, x, sideband, s_prev, threshold,
                j, *, causal, enc_out, enc_pos, moe_mode, capacity,
                plan_carry=None, cond_carry=None, wire_ef=None):
    # NOTE: the window pattern repeats with the scan period, so the static
    # pattern position ``j`` fully determines this layer's window — no
    # traced layer index may reach ``window_for_layer``.
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
    kv_valid = None
    if not causal:
        # non-causal archs (MoE-BERT): padded keys must not be attended
        kv_valid = positions < sideband["seq_len"][:, None]
    x, _ = _token_mixer_full(p, cfg, x, positions, j, causal=causal,
                             enc_out=enc_out, enc_pos=enc_pos, dist=dist,
                             kv_valid=kv_valid)
    x = dist.constrain(x, dist.act_spec())
    kind = cfg.ffn_kind(j)
    if kind == "moe":
        (x, sideband, s_prev, aux, plan_carry, cond_carry,
         wire_ef) = _moe_apply_dist(
            p["moe"], x, sideband, s_prev, threshold, cfg, luffy, dist,
            moe_mode, capacity, plan_carry=plan_carry,
            cond_carry=cond_carry, wire_ef=wire_ef)
        x = dist.constrain(x, dist.act_spec())
    else:
        xn = bk.norm_apply(p["ffn_norm"], x, cfg.norm)
        if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
            x = x + ssm_mod.rwkv_cmix_apply(p["ffn"], cfg, xn)
        else:
            x = x + bk.ffn_apply(p["ffn"], cfg, xn)
        aux = moe.MoEAux(*([jnp.float32(0.0)] * moe.N_AUX))
    return x, sideband, s_prev, aux, plan_carry, cond_carry, wire_ef


# ---------------------------------------------------------------------------
# embedding / logits / loss
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens, prefix=None,
                 dist: Optional[DistContext] = None):
    """Token embedding. The table is d-sharded over 'model'; when the
    batch is also sharded over 'model' (expert-parallel train shapes) the
    gather can't keep both, so we stage it: batch over the data axes only
    -> local gather (d over model) -> reshard to the activation spec.
    Without staging, GSPMD replicates the batch (observed: 1.25 GiB
    [256,4096,320] buffers dominating the llama4 memory profile)."""
    cdt = bk._dtype(cfg.compute_dtype)
    table = params["embed"]["table"]
    m_axes = () if dist is None else dist.model_axes_tuple
    staged = (dist is not None and dist.enabled
              and any(a in (dist.batch_axes or ()) for a in m_axes))
    if staged:
        from jax.sharding import PartitionSpec as P
        dax = tuple(a for a in dist.batch_axes if a not in m_axes)
        tokens = dist.constrain(tokens, P(dax or None, dist.seq_axis))
    x = jnp.take(table, tokens, axis=0).astype(cdt)
    if staged:
        x = dist.constrain(x, P(dax or None, dist.seq_axis,
                                dist.model_axis))
        x = dist.constrain(x, dist.act_spec())
    x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(cdt)
    if prefix is not None:
        px = (prefix.astype(cdt) @ params["prefix_proj"]["w"].astype(cdt))
        x = jnp.concatenate([px, x], axis=1)
    return x


def logits_fn(params, cfg: ModelConfig, x):
    cdt = bk._dtype(cfg.compute_dtype)
    h = bk.norm_apply(params["final_norm"], x, cfg.norm).astype(cdt)
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(cdt).T
    else:
        w = params["unembed"]["w"].astype(cdt)
    return h @ w


def chunked_xent(params, cfg, x, labels, *, chunk: int = 512):
    """Cross-entropy over S in chunks to bound logits memory.

    labels < 0 are ignored. Returns (sum_loss, count)."""
    B, S, _ = x.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def one(xc, lc):
        lg = logits_fn(params, cfg, xc).astype(jnp.float32)
        valid = lc >= 0
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(
            lg, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        tok_loss = (lse - gold) * valid.astype(jnp.float32)
        return jnp.sum(tok_loss), jnp.sum(valid.astype(jnp.float32))

    if n > 0:
        xs = x[:, :n * chunk].reshape(B, n, chunk, -1).swapaxes(0, 1)
        ls = labels[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)

        def body(c, inp):
            dl, dc = one(inp[0], inp[1])
            return (c[0] + dl, c[1] + dc), None

        (sl, sc), _ = jax.lax.scan(
            body, (jnp.float32(0), jnp.float32(0)), (xs, ls))
    else:
        sl = sc = jnp.float32(0)
    if rem:
        l2, c2 = one(x[:, n * chunk:], labels[:, n * chunk:])
        sl, sc = sl + l2, sc + c2
    return sl, sc


# ---------------------------------------------------------------------------
# the train forward
# ---------------------------------------------------------------------------

def wire_ef_shape(cfg: ModelConfig, batch: int, seq_len: int):
    """Shape of the cross-step wire error-feedback buffer (DESIGN.md
    §15): one per-token residual slot per layer, grouped the way the
    layer scan consumes it — ``(n_groups, period, B, S, d_model)``."""
    period = pattern_period(cfg)
    return (cfg.num_layers // period, period, batch, seq_len, cfg.d_model)


def forward_train(params, cfg: ModelConfig, luffy: LuffyConfig,
                  dist: DistContext, batch: Dict[str, Array], threshold,
                  capacity: int, wire_ef=None):
    """batch: tokens [B,S_tok], labels [B,S], seq_len [B],
    (prefix [B,P,pd] for vlm/audio). Returns (loss, metrics).

    ``wire_ef`` (optional, :func:`wire_ef_shape`): previous step's
    per-layer wire quantization residuals. When given, each MoE layer
    adds its slot to the shipped payload and the refreshed residuals
    come back under ``metrics["_wire_ef"]`` for the caller to carry
    into the next step (LuffyConfig.wire_error_feedback)."""
    period = pattern_period(cfg)
    prefix = batch.get("prefix")
    x = embed_tokens(params, cfg, batch["tokens"], prefix, dist=dist)
    x = dist.constrain(x, dist.act_spec())
    S = x.shape[1]
    sideband = {"labels": batch["labels"],
                "seq_len": batch["seq_len"].astype(jnp.int32)}

    enc_out = enc_pos = None
    if cfg.kind == "encdec":
        enc_x = (batch["enc_input"].astype(x.dtype)
                 @ params["prefix_proj"]["w"].astype(x.dtype))
        enc_x = dist.constrain(enc_x, dist.act_spec())
        enc_out = _run_encoder(params["encoder"], cfg, luffy, dist, enc_x)
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None],
            enc_out.shape[:2])

    use_cond = (luffy.enable_condensation and cfg.uses_moe
                and dist.seq_axis is None)
    G = luffy.condense_group
    if use_cond and S % G == 0:
        # init at 0.5 = "uncertain": block 1 measures everything (§V-A has
        # no history yet); 0.0 would wrongly mark every pair dissimilar.
        s_prev0 = jnp.full((x.shape[0], S // G, G, G), 0.5, jnp.float32)
    else:
        s_prev0 = None
        use_cond = False
    moe_mode = ("migrate" if (luffy.enable_migration and cfg.uses_moe
                              and dist.seq_axis is None) else "vanilla")
    eff_luffy = luffy if use_cond else \
        dataclasses.replace(luffy, enable_condensation=False)

    # Plan-lifecycle carry (DESIGN.md §9): the migration plan's routing
    # signature threads through the layer scan so stable-routing stacks
    # plan once and execute N times. The carry (and the revalidation
    # cond) is threaded for EVERY plan_reuse mode including "off" —
    # "off" pins the carried valid flag to 0 so it always replans — so
    # the compiled graphs of "off" and "signature" are structurally
    # identical and their forwards bit-comparable (the greedy planner
    # has float near-ties; two different compilations may legitimately
    # pick different equally-good plans). Global layout: per-batch-
    # device slot rows stacked data-major — [M·B, M] counts, [M·B] lens
    # (tiny; specced per-device varying for jax-version robustness).
    use_reuse = moe_mode == "migrate" and cfg.uses_moe
    B = x.shape[0]
    if use_reuse:
        M_model = dist.model_size if dist.enabled else 1
        pc0 = {"counts": jnp.zeros((M_model * B, M_model), jnp.float32),
               "lens": jnp.zeros((M_model * B,), jnp.float32),
               "valid": jnp.float32(0.0)}
    else:
        pc0 = {"counts": jnp.zeros((1,), jnp.float32),
               "lens": jnp.zeros((1,), jnp.float32),
               "valid": jnp.float32(0.0)}
    # Condense-reuse carry (DESIGN.md §10): the carried rep map +
    # signature threads through the scan whenever condensation is on —
    # for EVERY condense_reuse mode ("off" pins the valid flag to 0), so
    # the compiled graphs stay structurally identical across modes (the
    # same graph-parity discipline as the migration carry above).
    use_creuse = use_cond
    if use_creuse:
        cc0 = {"rep": jnp.zeros((B, S), jnp.int32),
               "cexp": jnp.zeros((B, S), jnp.int32),
               "age": jnp.zeros((B,), jnp.float32),
               "valid": jnp.zeros((B,), jnp.float32)}
    else:
        cc0 = {"rep": jnp.zeros((1,), jnp.int32),
               "cexp": jnp.zeros((1,), jnp.int32),
               "age": jnp.zeros((1,), jnp.float32),
               "valid": jnp.zeros((1,), jnp.float32)}

    use_ef = wire_ef is not None

    def group_body(carry, p_group, efg=None):
        x, sb, sp, pc, cc, aux_sum = carry
        ef_outs = []
        for j in range(period):

            def apply_j(x, sb, sp, pc, cc, ef, pj=p_group[j], jj=j):
                return _layer_full(
                    pj, cfg, eff_luffy, dist, x, sb, sp, threshold,
                    jj, causal=cfg.causal, enc_out=enc_out,
                    enc_pos=enc_pos, moe_mode=moe_mode, capacity=capacity,
                    plan_carry=pc, cond_carry=cc, wire_ef=ef)

            if cfg.remat:
                apply_j = jax.checkpoint(apply_j)
            efj = efg[j] if efg is not None else None
            x, sb, sp, aux, pc, cc, efo = apply_j(x, sb, sp, pc, cc, efj)
            ef_outs.append(efo)
            aux_sum = jax.tree.map(lambda a, b: a + b, aux_sum, aux)
        ef_stack = jnp.stack(ef_outs) if efg is not None else None
        return (x, sb, sp, pc, cc, aux_sum), ef_stack

    aux0 = moe.MoEAux(*([jnp.float32(0.0)] * moe.N_AUX))
    n_groups = cfg.num_layers // period
    # stack the per-position param lists into a tuple pytree for scan
    stacked = tuple(params["layers"])
    if s_prev0 is None:
        s_prev0 = jnp.zeros((1,), jnp.float32)  # dummy carried value

    # error-feedback xs: the real buffer when enabled, else a structural
    # dummy sliced and discarded (keeps the scan signature uniform)
    ef_xs = wire_ef if use_ef else jnp.zeros((n_groups,), jnp.float32)

    def scan_body(carry, xs):
        p_group, efg = xs
        (x, sb, sp, pc, cc, aux_sum) = carry
        sp_real = sp if use_cond else None
        pc_real = pc if use_reuse else None
        cc_real = cc if use_creuse else None
        (x, sb, sp_new, pc_new, cc_new, aux_sum), ef_y = group_body(
            (x, sb, sp_real, pc_real, cc_real, aux_sum), p_group,
            efg if use_ef else None)
        if not use_cond:
            sp_new = sp
        if not use_reuse:
            pc_new = pc
        if not use_creuse:
            cc_new = cc
        return (x, sb, sp_new, pc_new, cc_new, aux_sum), ef_y

    (x, sideband, s_prev, _pc, _cc, aux_sum), ef_ys = jax.lax.scan(
        scan_body, (x, sideband, s_prev0, pc0, cc0, aux0),
        (stacked, ef_xs))

    sl, sc = chunked_xent(params, cfg, x, sideband["labels"])
    if dist.enabled:
        # global mean over devices happens automatically: sl/sc are global
        pass
    loss = sl / jnp.maximum(sc, 1.0)
    n_moe = sum(1 for i in range(cfg.num_layers) if cfg.ffn_kind(i) == "moe")
    n_moe = max(n_moe, 1)
    aux_mean = jax.tree.map(lambda a: a / n_moe, aux_sum)
    total = loss
    if cfg.uses_moe and cfg.moe is not None:
        total = loss + cfg.moe.router_aux_coef * aux_mean.aux_loss
    metrics = {
        "loss": loss, "aux_loss": aux_mean.aux_loss,
        "dispatch_drop": aux_mean.dispatch_drop,
        "combine_drop": aux_mean.combine_drop,
        "condense_rate": aux_mean.condense_rate,
        "local_frac": aux_mean.local_frac,
        "traffic_before": aux_mean.traffic_before,
        "traffic_after": aux_mean.traffic_after,
        "inter_bytes_flat": aux_mean.inter_bytes_flat,
        "inter_bytes_dedup": aux_mean.inter_bytes_dedup,
        "inter_bytes_shipped": aux_mean.inter_bytes_shipped,
        # plan-reuse ledger (DESIGN.md §9): per-forward COUNTS (sums over
        # MoE sublayers, device-mean), not per-sublayer means — so
        # "plans_built == 1.0" reads as "one full replan this forward"
        "plans_built": aux_sum.plans_built,
        "plans_reused": aux_sum.plans_reused,
        "plan_reuse_mismatch": aux_sum.reuse_mismatch,
        # condensation ledger (DESIGN.md §10): similarity builds per
        # forward + pairs the backend actually measured (sums)
        "measured_pairs": aux_sum.measured_pairs,
        "condense_built": aux_sum.condense_built,
        "condense_reused": aux_sum.condense_reused,
    }
    if use_ef:
        # refreshed residual buffer for the caller to thread into the
        # next step's forward (underscore: stripped before logging)
        metrics["_wire_ef"] = ef_ys
    return total, metrics


def _run_encoder(enc_params, cfg, luffy, dist, enc_x):
    period = pattern_period(cfg)

    def group_body(x, p_group):
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
        for j in range(period):
            p = p_group[j]
            x, _ = _token_mixer_full(p, cfg, x, positions, j, causal=False,
                                     enc_out=None, enc_pos=None, dist=dist)
            xn = bk.norm_apply(p["ffn_norm"], x, cfg.norm)
            x = x + bk.ffn_apply(p["ffn"], cfg, xn)
        return x, None

    x, _ = jax.lax.scan(group_body, enc_x, tuple(enc_params["layers"]))
    return bk.norm_apply(enc_params["final_norm"], x, cfg.norm)
