"""repro.obs — observability (DESIGN.md §11–§12).

Five layers:

* :mod:`repro.obs.trace` — host-timed spans with ``block_until_ready``
  fencing and Chrome-trace/Perfetto JSON export (``--trace`` /
  ``--trace-out`` on the launchers);
* :mod:`repro.obs.metrics` — one typed registry unifying the
  ``MoEAux``/optimizer/ledger counter names, per-step + cumulative
  views, crash-safe JSONL emission (``--metrics-json``);
* :mod:`repro.obs.calibrate` — measured cost-model constants (link
  bandwidths, chunk overhead, planning/similarity/FFN speeds) persisted
  as a versioned artifact keyed by topology fingerprint + backend
  (``--calibrate``);
* :mod:`repro.obs.monitor` — the per-step residual stream joining each
  plan's ``PlanEstimate`` against traced phase timings, with EWMA drift
  detection (``--recalibrate-on-drift``);
* :mod:`repro.obs.autotune` — calibration-driven configuration search
  emitting a versioned ``TunedConfig`` artifact resolved into
  ``LuffyConfig`` by ``--autotune`` (explicit flags always win).
"""
from repro.obs.autotune import (DEFAULT_KNOBS, TUNABLE_KNOBS,
                                TUNED_SCHEMA_VERSION, TunedConfig,
                                autotune_config, candidate_grid,
                                load_tuned, modeled_step_components,
                                rerank, run_autotune, save_tuned,
                                tuned_key)
from repro.obs.calibrate import (CALIBRATION_SCHEMA_VERSION, Calibration,
                                 calibration_key, load_calibration,
                                 probe_exchange,
                                 probe_exchange_per_device,
                                 run_calibration, save_calibration)
from repro.obs.metrics import (COMM_LEDGER_SCHEMA_VERSION,
                               METRICS_SCHEMA_VERSION, MetricsRegistry,
                               MetricSpec, SCHEMA, canonical_name,
                               flatten, mask_inapplicable, read_jsonl,
                               write_jsonl)
from repro.obs.monitor import (RESIDUAL_PHASES, DriftDetector,
                               ResidualMonitor, device_dispersion,
                               measured_phase_ms, predicted_phase_ms)
from repro.obs.trace import (DEVICE_TID_BASE, NULL_SPAN, Tracer,
                             activate, active, deactivate, phase)

__all__ = [
    "CALIBRATION_SCHEMA_VERSION", "Calibration", "calibration_key",
    "load_calibration", "probe_exchange", "probe_exchange_per_device",
    "run_calibration", "save_calibration", "COMM_LEDGER_SCHEMA_VERSION",
    "METRICS_SCHEMA_VERSION", "MetricsRegistry", "MetricSpec", "SCHEMA",
    "canonical_name", "flatten", "mask_inapplicable", "read_jsonl",
    "write_jsonl", "DEVICE_TID_BASE", "NULL_SPAN", "Tracer", "activate",
    "active", "deactivate", "phase", "RESIDUAL_PHASES", "DriftDetector",
    "ResidualMonitor", "device_dispersion", "measured_phase_ms",
    "predicted_phase_ms", "DEFAULT_KNOBS", "TUNABLE_KNOBS",
    "TUNED_SCHEMA_VERSION", "TunedConfig", "autotune_config",
    "candidate_grid", "load_tuned", "modeled_step_components", "rerank",
    "run_autotune", "save_tuned", "tuned_key",
]
