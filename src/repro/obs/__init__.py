"""repro.obs — observability (DESIGN.md §11).

Three layers:

* :mod:`repro.obs.trace` — host-timed spans with ``block_until_ready``
  fencing and Chrome-trace/Perfetto JSON export (``--trace`` /
  ``--trace-out`` on the launchers);
* :mod:`repro.obs.metrics` — one typed registry unifying the
  ``MoEAux``/optimizer/ledger counter names, per-step + cumulative
  views, JSONL emission (``--metrics-json``);
* :mod:`repro.obs.calibrate` — measured cost-model constants (link
  bandwidths, chunk overhead, planning/similarity/FFN speeds) persisted
  as a versioned artifact keyed by topology fingerprint + backend
  (``--calibrate``).
"""
from repro.obs.calibrate import (CALIBRATION_SCHEMA_VERSION, Calibration,
                                 calibration_key, load_calibration,
                                 probe_exchange, run_calibration,
                                 save_calibration)
from repro.obs.metrics import (COMM_LEDGER_SCHEMA_VERSION,
                               METRICS_SCHEMA_VERSION, MetricsRegistry,
                               MetricSpec, SCHEMA, canonical_name,
                               flatten, mask_inapplicable, write_jsonl)
from repro.obs.trace import (NULL_SPAN, Tracer, activate, active,
                             deactivate, phase)

__all__ = [
    "CALIBRATION_SCHEMA_VERSION", "Calibration", "calibration_key",
    "load_calibration", "probe_exchange", "run_calibration",
    "save_calibration", "COMM_LEDGER_SCHEMA_VERSION",
    "METRICS_SCHEMA_VERSION", "MetricsRegistry", "MetricSpec", "SCHEMA",
    "canonical_name", "flatten", "mask_inapplicable", "write_jsonl",
    "NULL_SPAN", "Tracer", "activate", "active", "deactivate", "phase",
]
