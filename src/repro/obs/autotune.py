"""Calibration-driven configuration search (DESIGN.md §12).

The repo exposes a handful of execution knobs — ``exec_mode`` /
``pipeline_chunks``, ``plan_objective``, ``comm_mode`` /
``hier_dedup``, ``similarity_backend`` / ``lsh_bits`` — and, since
PR 6, a *measured* fit of every constant the cost model prices them
with. This module closes the loop: enumerate a small candidate grid
over those knobs, price each candidate's modeled step time with the
same estimators everything else uses (``estimate_exchange`` +
``repro.sched.cost`` for the exchange, ``estimate_planning_ms`` for
the migration greedy, ``estimate_similarity_ms`` × per-backend
``expected_measured_pairs`` for condensation), and return the argmin
as a versioned :class:`TunedConfig` artifact.

Artifact discipline is :mod:`repro.obs.calibrate`'s exactly: keyed
``topology_fingerprint + "__" + backend`` (:func:`tuned_key` ==
``calibration_key``), ``magic`` + ``schema_version`` + key checked on
load, any mismatch a MISS. ``--autotune DIR`` on train/dryrun/serve
resolves the artifact into :class:`~repro.config.LuffyConfig` via
:meth:`TunedConfig.apply`; **explicit CLI flags always win** (the
launcher passes the set of flags the user actually typed).

Pricing conventions (shared with the dryrun ``comm_ledger``):

* the dedup wire (``comm_mode="hier"`` + ``hier_dedup="on"``,
  universal across execution modes since DESIGN.md §15) ships the
  per-node-deduplicated bytes; every other wire mode ships the flat
  payload; pipelined dedup candidates price the chunked hop's
  inter/intra phase overlap (``sched_cost.dedup_overlap_ms``);
* ``exec_mode="sync"`` prices ``sched_cost.sync_ms``; a fixed positive
  chunk count prices ``overlap_ms`` at that count; ``pipeline_chunks
  <= 0`` (the "overlap"-objective planned search) prices
  ``optimal_chunks``;
* the similarity term is the only knob-dependent planning cost — the
  grid search therefore models *time*, not condensation quality (the
  LSH backend's recall trade-off is DESIGN.md §10's concern).

Determinism: the grid is enumerated in a fixed preference order with
the repo defaults FIRST, and a candidate wins only by strict
improvement — equal-cost candidates resolve to the simpler (earlier)
config, so the tuner is reproducible and never leaves the defaults for
a tie. Because the defaults are always in the grid, the tuned modeled
step time is ≤ the default modeled step time *by construction* (the
invariant ``benchmarks/fig_autotune.py`` sweeps).

:func:`rerank` is the online refinement hook: scale the stored
per-candidate phase components by measured warmup residual ratios
(``repro.obs.monitor``) and re-pick among the top candidates — the
train launcher's ``--autotune-refine``.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.comm import dtypes as wire_dtypes
from repro.comm.topology import Topology
from repro.obs.calibrate import Calibration, calibration_key
from repro.sched import cost as sched_cost

TUNED_MAGIC = "repro-tuned-config"
# v2 (ISSUE 9): the knob set gained "wire_dtype" (the compressed
# exchange, DESIGN.md §14). v1 artifacts miss (schema drift) and the
# search reruns — the standard Calibration miss discipline.
TUNED_SCHEMA_VERSION = 2

# The LuffyConfig fields the tuner may set (and the launchers guard
# with explicit-flag precedence).
TUNABLE_KNOBS = ("comm_mode", "hier_dedup", "exec_mode",
                 "pipeline_chunks", "plan_objective",
                 "similarity_backend", "lsh_bits", "wire_dtype")

# The repo defaults, in one place: always the FIRST grid candidate, so
# ties resolve to them and `default_step_ms` is always priced.
DEFAULT_KNOBS: Dict[str, Any] = {
    "comm_mode": "flat", "hier_dedup": "off", "exec_mode": "sync",
    "pipeline_chunks": 4, "plan_objective": "traffic",
    "similarity_backend": "exact", "lsh_bits": 8, "wire_dtype": "f32",
}

# TPU v5e-class bf16 peak (launch.mesh.PEAK_FLOPS_BF16); the default
# FFN roofline when no calibration supplies a measured speed.
DEFAULT_FFN_SPEED = 197e12


def tuned_key(topo: Optional[Topology], M: int,
              backend: Optional[str] = None) -> str:
    """Same key form as the calibration artifact: topology fingerprint
    + the jax backend the model constants describe."""
    return calibration_key(topo, M, backend=backend)


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One search result, bound to (topology fingerprint, backend).

    ``knobs`` is the chosen knob dict (exactly :data:`TUNABLE_KNOBS`);
    ``top`` keeps the best few candidates WITH their modeled phase
    components so :func:`rerank` can refine the choice online;
    ``workload`` records the shape the search priced (an artifact tuned
    for one workload is keyed only by fabric+backend — the launcher
    prints the workload so a cross-shape reuse is visible, and a fresh
    search is one ``--autotune-force`` away).
    """
    key: str
    knobs: Dict[str, Any]
    modeled_step_ms: float
    default_step_ms: float
    candidates: int
    calibrated: bool
    workload: Dict[str, Any] = dataclasses.field(default_factory=dict)
    top: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    refined: bool = False
    schema_version: int = TUNED_SCHEMA_VERSION

    @property
    def modeled_savings_ms(self) -> float:
        return self.default_step_ms - self.modeled_step_ms

    def apply(self, luffy, explicit: Sequence[str] = ()) -> Any:
        """``luffy`` with every tuned knob the user did NOT set
        explicitly (``explicit``: LuffyConfig field names pinned by CLI
        flags — those always win)."""
        skip = set(explicit)
        updates = {k: v for k, v in self.knobs.items()
                   if k in TUNABLE_KNOBS and k not in skip}
        return dataclasses.replace(luffy, **updates)

    # -- serialization (the Calibration miss discipline) --------------------
    def to_json(self) -> str:
        payload = {"magic": TUNED_MAGIC, **dataclasses.asdict(self)}
        return json.dumps(payload, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str, expect_key: Optional[str] = None
                  ) -> Optional["TunedConfig"]:
        """Parse an artifact; None (a miss) on wrong magic, schema
        drift, or — with ``expect_key`` — a stale fingerprint/backend."""
        try:
            payload = json.loads(text)
        except (ValueError, TypeError):
            return None
        if not isinstance(payload, dict):
            return None
        if payload.pop("magic", None) != TUNED_MAGIC:
            return None
        if payload.get("schema_version") != TUNED_SCHEMA_VERSION:
            return None
        if expect_key is not None and payload.get("key") != expect_key:
            return None
        fields = {f.name for f in dataclasses.fields(cls)}
        if not fields.issubset(payload):
            return None
        try:
            return cls(**{k: payload[k] for k in fields})
        except (TypeError, ValueError):
            return None


def _artifact_path(out_dir, key: str) -> Path:
    return Path(out_dir) / f"{key}.tuned.json"


def save_tuned(out_dir, tuned: TunedConfig) -> Path:
    path = _artifact_path(out_dir, tuned.key)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(tuned.to_json())
    return path


def load_tuned(out_dir, key: str) -> Optional[TunedConfig]:
    path = _artifact_path(out_dir, key)
    if not path.exists():
        return None
    try:
        text = path.read_text()
    except OSError:
        return None
    return TunedConfig.from_json(text, expect_key=key)


# ---------------------------------------------------------------------------
# candidate grid
# ---------------------------------------------------------------------------

def candidate_grid(topo: Topology, *,
                   fixed_chunks: Sequence[int] = (2, 4, 8),
                   lsh_bits_options: Sequence[int] = (4, 8, 16)
                   ) -> List[Dict[str, Any]]:
    """Every knob combination the fabric supports, defaults first.

    Structural constraints mirror the executors: ``comm_mode="hier"``
    needs a hierarchical topology; ``hier_dedup="on"`` needs hier and
    pairs with every TRAIN exec_mode (the dedup wire is universal
    across sync/migrate/pipelined execution since DESIGN.md §15) but
    never with ``decode_overlap`` — serving forces the wire off
    (single-token decode has nothing to dedup and runs flat comm, see
    ``launch/serve.py``); ``pipeline_chunks <= 0``
    (the planned search) is tied to ``plan_objective="overlap"``
    exactly as ``resolve_pipeline_chunks`` ties them for the launchers.
    """
    wire = [("flat", "off")]
    if topo.hierarchical:
        wire += [("hier", "off"), ("hier", "on")]
    execs: List[Tuple[str, str, int]] = [("sync", "traffic", 4)]
    execs += [("pipeline", "traffic", int(n)) for n in fixed_chunks
              if int(n) > 0]
    execs += [("pipeline", "overlap", 0)]          # planned chunk search
    # decode combine/shared-FFN overlap (DESIGN.md §13): prices like
    # sync on the build/execute path, wins only through the decode_ms
    # term — so it is only ever picked for decode workloads
    # (decode_tokens > 0 with shared experts)
    execs += [("decode_overlap", "traffic", 4)]
    sims = [("exact", 8)] + [("lsh", int(b)) for b in lsh_bits_options]
    # wire precision (DESIGN.md §14): f32 first so ties resolve to the
    # identity wire; f8 only offered on stacks that expose the dtype
    wds = ["f32", "bf16"]
    if wire_dtypes.have_f8():
        wds.append("f8e4m3")
    out: List[Dict[str, Any]] = []
    for cm, hd in wire:
        for em, obj, nc in execs:
            if hd == "on" and em == "decode_overlap":
                continue        # serving runs flat comm — no dedup wire
            for wd in wds:
                for sb, bits in sims:
                    out.append({"comm_mode": cm, "hier_dedup": hd,
                                "exec_mode": em, "plan_objective": obj,
                                "pipeline_chunks": nc,
                                "similarity_backend": sb, "lsh_bits": bits,
                                "wire_dtype": wd})
    assert out[0] == DEFAULT_KNOBS
    return out


# ---------------------------------------------------------------------------
# the modeled step
# ---------------------------------------------------------------------------

def modeled_step_components(knobs: Mapping[str, Any], *,
                            topo: Topology, tokens: int, top_k: int,
                            d_model: int, d_ff: int, num_layers: int,
                            n_moe: int, n_slots: int,
                            num_experts: Optional[int] = None,
                            mesh_devices: Optional[int] = None,
                            group_size: int = 128, r_cond: float = 0.0,
                            plan_reuse: str = "off",
                            condense_reuse: str = "off",
                            calib: Optional[Calibration] = None,
                            ffn_speed: float = DEFAULT_FFN_SPEED,
                            decode_tokens: int = 0,
                            d_ff_shared: int = 0) -> Dict[str, float]:
    """Price one candidate: the per-phase components and their total.

    Returns ``{"dispatch_ms", "combine_ms", "ffn_ms", "exchange_ms",
    "chunks", "planning_ms", "similarity_ms", "decode_ms",
    "total_ms"}`` — all host-side floats under the calibrated constants
    when ``calib`` is given. ``mesh_devices`` is the full mesh size
    (data × model) the per-device similarity work divides over;
    defaults to the expert devices ``topo.num_devices``.

    ``decode_tokens`` > 0 adds the decode-step term (DESIGN.md §13):
    per MoE sublayer, one [decode_tokens, d_model] combine all-reduce
    plus the shared-expert FFN (``d_ff_shared`` = total shared hidden
    width), overlapped into ``max`` of the two when the candidate's
    ``exec_mode`` is ``"decode_overlap"`` and summed otherwise. Train
    workloads leave it 0, so the term vanishes and the grid behaves
    exactly as before (ties still resolve to the defaults).
    """
    from repro.condense import expected_measured_pairs
    from repro.plan.estimate import (PLAN_STEP_US, estimate_exchange,
                                     estimate_planning_ms,
                                     estimate_similarity_ms)
    M = topo.num_devices
    devices = mesh_devices or M
    speed = calib.ffn_speed if calib is not None else ffn_speed
    est_kw = calib.estimate_kwargs() if calib is not None else {}
    overhead = sched_cost.resolve_chunk_overhead_ms(
        est_kw.pop("chunk_overhead_ms", None))
    ffn_ms = (tokens * (1.0 - r_cond) * top_k * 4.0 * d_model * d_ff
              * num_layers / (speed * M) * 1e3)
    est = estimate_exchange(tokens, top_k, d_model, topo=topo,
                            r_cond=r_cond, num_layers=num_layers,
                            ffn_ms=ffn_ms, chunks=1,
                            chunk_overhead_ms=overhead,
                            wire_dtype=knobs.get("wire_dtype", "f32"),
                            **est_kw)
    dedup_wire = (knobs["comm_mode"] == "hier"
                  and knobs["hier_dedup"] == "on")
    d_ms = est.dispatch_ms if dedup_wire else est.flat_dispatch_ms
    c_ms = d_ms                        # locality 0: combine == dispatch
    kw = dict(dispatch_ms=d_ms, ffn_ms=ffn_ms, combine_ms=c_ms,
              chunk_overhead_ms=overhead)
    if knobs["exec_mode"] in ("sync", "decode_overlap"):
        # decode_overlap chunks/prices the build/execute exchange like
        # sync — it only reschedules the decode combine (decode_ms)
        chunks, exchange_ms = 1, sched_cost.sync_ms(topo, **kw)
    elif dedup_wire:
        # pipelined dedup wire (DESIGN.md §15): chunking the unique-row
        # capacity lets the hop's intra-node fan-out / pre-reduce hide
        # behind the next chunk's inter-node leg — price it with the
        # same estimator the plan builder freezes (dedup_overlap_ms)
        nc = int(knobs["pipeline_chunks"])
        est_p = estimate_exchange(tokens, top_k, d_model, topo=topo,
                                  r_cond=r_cond, num_layers=num_layers,
                                  ffn_ms=ffn_ms,
                                  chunks=nc if nc > 0 else None,
                                  chunk_overhead_ms=overhead,
                                  wire_dtype=knobs.get("wire_dtype",
                                                       "f32"),
                                  **est_kw)
        chunks, exchange_ms = est_p.chunks, est_p.dedup_overlap_ms
    elif int(knobs["pipeline_chunks"]) > 0:
        chunks = int(knobs["pipeline_chunks"])
        exchange_ms = sched_cost.overlap_ms(topo, chunks, **kw)
    else:                              # planned search (overlap objective)
        chunks, exchange_ms = sched_cost.optimal_chunks(topo, **kw)

    step_us = calib.plan_step_us if calib is not None else PLAN_STEP_US
    built = n_moe if plan_reuse == "off" else min(1, n_moe)
    planning_ms = built * estimate_planning_ms(n_slots, M,
                                               step_us=step_us)
    sim_kw = ({"speed": calib.sim_speed} if calib is not None else {})
    G = max(1, min(group_size, tokens))
    E = num_experts if num_experts else M   # one-expert-per-device default
    pairs_local = expected_measured_pairs(
        max(1, tokens // devices), G, num_experts=max(1, E),
        backend=knobs["similarity_backend"],
        lsh_bits=int(knobs["lsh_bits"]))
    c_built = n_moe if condense_reuse == "off" else min(1, n_moe)
    similarity_ms = c_built * estimate_similarity_ms(
        pairs_local, d_model, **sim_kw)
    decode_ms = 0.0
    if decode_tokens > 0:
        dec_combine = sched_cost.decode_combine_ms(decode_tokens, d_model,
                                                   topo)
        shared_ffn = (decode_tokens * 4.0 * d_model * d_ff_shared
                      / speed * 1e3)
        decode_ms = sched_cost.decode_step_ms(
            combine_ms=dec_combine, shared_ffn_ms=shared_ffn,
            overlap=knobs["exec_mode"] == "decode_overlap") * n_moe
    total = exchange_ms + planning_ms + similarity_ms + decode_ms
    return {"dispatch_ms": d_ms, "combine_ms": c_ms, "ffn_ms": ffn_ms,
            "exchange_ms": exchange_ms, "chunks": float(chunks),
            "planning_ms": planning_ms, "similarity_ms": similarity_ms,
            "decode_ms": decode_ms, "total_ms": total}


def _exchange_ms_for(knobs: Mapping[str, Any], topo: Topology, *,
                     dispatch_ms: float, ffn_ms: float,
                     combine_ms: float, chunk_overhead_ms: float
                     ) -> float:
    """Re-price one candidate's exchange from (possibly rescaled) phase
    components — the :func:`rerank` kernel."""
    kw = dict(dispatch_ms=dispatch_ms, ffn_ms=ffn_ms,
              combine_ms=combine_ms,
              chunk_overhead_ms=chunk_overhead_ms)
    if knobs["exec_mode"] in ("sync", "decode_overlap"):
        return sched_cost.sync_ms(topo, **kw)
    if int(knobs["pipeline_chunks"]) > 0:
        return sched_cost.overlap_ms(topo, int(knobs["pipeline_chunks"]),
                                     **kw)
    return sched_cost.optimal_chunks(topo, **kw)[1]


# ---------------------------------------------------------------------------
# the search
# ---------------------------------------------------------------------------

def autotune_config(*, topo: Topology, tokens: int, top_k: int,
                    d_model: int, d_ff: int, num_layers: int,
                    n_moe: Optional[int] = None,
                    n_slots: Optional[int] = None,
                    num_experts: Optional[int] = None,
                    mesh_devices: Optional[int] = None,
                    group_size: int = 128, r_cond: float = 0.0,
                    plan_reuse: str = "off",
                    condense_reuse: str = "off",
                    calib: Optional[Calibration] = None,
                    ffn_speed: float = DEFAULT_FFN_SPEED,
                    decode_tokens: int = 0, d_ff_shared: int = 0,
                    key: Optional[str] = None,
                    backend: Optional[str] = None,
                    grid: Optional[List[Dict[str, Any]]] = None,
                    top_n: int = 5) -> TunedConfig:
    """Brute-force argmin of the modeled step over the candidate grid.

    Strict-improvement selection in grid order (defaults first) makes
    the result deterministic and tie-stable; ``tests/test_autotune.py``
    asserts it equals an exhaustive re-evaluation of the grid."""
    n_moe = num_layers if n_moe is None else n_moe
    n_slots = topo.num_devices if n_slots is None else n_slots
    if key is None:
        key = tuned_key(topo, topo.num_devices, backend=backend)
    if grid is None:
        grid = candidate_grid(topo)
    model_kw = dict(topo=topo, tokens=tokens, top_k=top_k,
                    d_model=d_model, d_ff=d_ff, num_layers=num_layers,
                    n_moe=n_moe, n_slots=n_slots,
                    num_experts=num_experts,
                    mesh_devices=mesh_devices, group_size=group_size,
                    r_cond=r_cond, plan_reuse=plan_reuse,
                    condense_reuse=condense_reuse, calib=calib,
                    ffn_speed=ffn_speed, decode_tokens=decode_tokens,
                    d_ff_shared=d_ff_shared)
    scored: List[Dict[str, Any]] = []
    for knobs in grid:
        comp = modeled_step_components(knobs, **model_kw)
        scored.append({"knobs": dict(knobs), "components": comp,
                       "modeled_ms": comp["total_ms"]})
    default_ms = scored[0]["modeled_ms"]    # defaults lead the grid
    best = scored[0]
    for cand in scored[1:]:
        if cand["modeled_ms"] < best["modeled_ms"] - 1e-12:
            best = cand
    top = sorted(scored, key=lambda c: c["modeled_ms"])[:max(1, top_n)]
    workload = {"tokens": tokens, "top_k": top_k, "d_model": d_model,
                "d_ff": d_ff, "num_layers": num_layers, "n_moe": n_moe,
                "n_slots": n_slots, "num_experts": num_experts,
                "group_size": group_size, "r_cond": r_cond,
                "decode_tokens": decode_tokens,
                "d_ff_shared": d_ff_shared}
    return TunedConfig(
        key=key, knobs=dict(best["knobs"]),
        modeled_step_ms=best["modeled_ms"],
        default_step_ms=default_ms, candidates=len(scored),
        calibrated=calib is not None,
        # canonicalize so the in-memory result equals its round trip
        workload=json.loads(json.dumps(workload)),
        top=json.loads(json.dumps(top)))


def run_autotune(*, topo: Topology, out_dir=None, force: bool = False,
                 backend: Optional[str] = None,
                 **search_kw) -> TunedConfig:
    """Load-before-search: return the persisted artifact for this
    fabric+backend when one validates, else search and persist (the
    PlanCache / run_calibration discipline). ``force`` re-searches and
    overwrites."""
    key = tuned_key(topo, topo.num_devices, backend=backend)
    if out_dir is not None and not force:
        cached = load_tuned(out_dir, key)
        if cached is not None:
            return cached
    tuned = autotune_config(topo=topo, key=key, **search_kw)
    if out_dir is not None:
        save_tuned(out_dir, tuned)
    return tuned


# ---------------------------------------------------------------------------
# online refinement
# ---------------------------------------------------------------------------

def rerank(tuned: TunedConfig, ratios: Mapping[str, float], *,
           topo: Topology,
           chunk_overhead_ms: float = -1.0) -> TunedConfig:
    """Re-rank the stored top candidates under measured residuals.

    ``ratios`` maps residual phases (``repro.obs.monitor``) to measured
    / predicted factors: ``dispatch`` / ``combine`` / ``expert_ffn``
    scale that component; a ``step`` ratio scales all three (the
    per-step signal the train warmup loop has). Planning and similarity
    terms are host-side and keep their modeled values. Returns a new
    ``TunedConfig`` (``refined=True``) whose knobs are the re-ranked
    winner — possibly unchanged."""
    if not tuned.top:
        return tuned
    overhead = sched_cost.resolve_chunk_overhead_ms(chunk_overhead_ms)
    common = float(ratios.get("step", 1.0))
    r_d = float(ratios.get("dispatch", 1.0)) * common
    r_f = float(ratios.get("expert_ffn", 1.0)) * common
    r_c = float(ratios.get("combine", 1.0)) * common
    best = None
    best_ms = None
    for cand in tuned.top:
        comp = cand["components"]
        ex = _exchange_ms_for(cand["knobs"], topo,
                              dispatch_ms=comp["dispatch_ms"] * r_d,
                              ffn_ms=comp["ffn_ms"] * r_f,
                              combine_ms=comp["combine_ms"] * r_c,
                              chunk_overhead_ms=overhead)
        # decode_ms keeps its modeled value (host-side; absent on
        # artifacts persisted before the decode term existed)
        total = (ex + comp["planning_ms"] + comp["similarity_ms"]
                 + comp.get("decode_ms", 0.0))
        if best_ms is None or total < best_ms - 1e-12:
            best, best_ms = cand, total
    return dataclasses.replace(
        tuned, knobs=dict(best["knobs"]), modeled_step_ms=best_ms,
        refined=True)
