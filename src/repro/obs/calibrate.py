"""Measured cost-model calibration (DESIGN.md §11, ROADMAP item 5).

Every planning decision in this repo prices against analytic models
with hand-set constants: link bandwidths/latencies
(:class:`repro.comm.Topology`), the per-chunk pipeline overhead
(``repro.sched.cost.DEFAULT_CHUNK_OVERHEAD_MS``), the planning-cost
slope (``repro.plan.estimate.PLAN_STEP_US``), the similarity and FFN
compute speeds (``estimate_similarity_ms``, ``LuffyConfig.gpu_speed``).
This module *measures* each of those on the running backend:

* **collectives** — flat/hier all-to-all and psum timed at several
  payload sizes; a linear fit ``t = lat + bytes / bw`` per link tier
  recovers effective bandwidth and message latency;
* **per-chunk overhead** — ``k`` dependency-chained collectives on the
  same payload vs one, the residual beyond the fitted message latency;
* **pipeline stages** — the expert-FFN einsum chain and the
  condensation Gram matmul, timed and converted to effective FLOP/s
  under the same flop conventions the estimators use (so the fitted
  speeds are drop-in replacements for ``gpu_speed`` / ``speed``);
* **planning** — the host migration greedy
  (``plan_migration_with_objective``) timed over several slot counts,
  slope converted to a per-slot ``step_us``.

The fit persists as a **versioned artifact** keyed exactly like
:class:`repro.plan.cache.PlanCache` entries — topology fingerprint +
backend (:func:`calibration_key`) — so a stale fingerprint, foreign
backend, or schema bump is a *miss* (remeasure), never a misread.
:meth:`Calibration.topology` / :meth:`Calibration.apply` /
:meth:`Calibration.estimate_kwargs` feed the fit into
``Topology``/``LuffyConfig``/``estimate_exchange`` so the ``overlap``
objective, planned chunk counts and the dryrun ledger run on measured
numbers. ``benchmarks/fig_calibration.py`` asserts held-out
predicted-vs-measured agreement.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.comm.topology import Topology

CALIBRATION_MAGIC = "repro-calibration"
CALIBRATION_SCHEMA_VERSION = 1

# Clamp rails for degenerate fits (two near-equal timing points on a
# noisy host can produce a negative slope): bandwidths in bytes/s,
# latencies in seconds, speeds in FLOP/s.
_MIN_BW, _MAX_BW = 1e6, 1e13
_MIN_LAT, _MAX_LAT = 0.0, 1.0
_MIN_SPEED, _MAX_SPEED = 1e6, 1e16


def calibration_key(topo: Optional[Topology], M: int,
                    backend: Optional[str] = None) -> str:
    """Artifact key: the PlanCache topology fingerprint extended with the
    jax backend the numbers were measured on (a CPU fit must never price
    a TPU run)."""
    from repro.plan.cache import topology_fingerprint
    if backend is None:
        import jax
        backend = jax.default_backend()
    return f"{topology_fingerprint(topo, M)}__{backend}"


@dataclasses.dataclass(frozen=True)
class Calibration:
    """One measured fit, bound to (topology fingerprint, backend).

    Bandwidths bytes/s, latencies seconds, speeds FLOP/s under the
    estimator conventions (``4·d·d_ff`` per FFN row, ``4·d`` per
    measured similarity pair). ``samples`` keeps the raw (bytes,
    seconds) measurements for audit/plotting; it is persisted but never
    read back into pricing.
    """
    key: str
    intra_bw: float
    inter_bw: float
    intra_lat: float
    inter_lat: float
    chunk_overhead_ms: float
    plan_step_us: float
    sim_speed: float
    ffn_speed: float
    samples: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schema_version: int = CALIBRATION_SCHEMA_VERSION

    # -- pricing hand-off ----------------------------------------------------
    def topology(self, base: Topology) -> Topology:
        """``base`` with measured link speeds/latencies — what the
        launchers hand to ``make_dist`` so the migration link-cost
        matrix, ledger and overlap model all price measured links."""
        return base.with_links(
            intra_bw=self.intra_bw, inter_bw=self.inter_bw,
            intra_lat=self.intra_lat, inter_lat=self.inter_lat)

    def apply(self, luffy):
        """``luffy`` with the measured compute speed and chunk overhead
        (``LuffyConfig.chunk_overhead_ms``; ≤0 means the built-in
        default, see ``repro.sched.cost``)."""
        return dataclasses.replace(
            luffy, gpu_speed=self.ffn_speed,
            chunk_overhead_ms=self.chunk_overhead_ms)

    def estimate_kwargs(self) -> Dict[str, float]:
        """Overrides for :func:`repro.plan.estimate.estimate_exchange`."""
        return {"intra_bw": self.intra_bw, "inter_bw": self.inter_bw,
                "chunk_overhead_ms": self.chunk_overhead_ms}

    # -- serialization -------------------------------------------------------
    def to_json(self) -> str:
        payload = {"magic": CALIBRATION_MAGIC, **dataclasses.asdict(self)}
        return json.dumps(payload, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str,
                  expect_key: Optional[str] = None
                  ) -> Optional["Calibration"]:
        """Parse an artifact; None (a miss) on any mismatch: wrong
        magic, schema drift, or — when ``expect_key`` is given — a stale
        topology fingerprint / backend."""
        try:
            payload = json.loads(text)
        except (ValueError, TypeError):
            return None
        if not isinstance(payload, dict):
            return None
        if payload.pop("magic", None) != CALIBRATION_MAGIC:
            return None
        if payload.get("schema_version") != CALIBRATION_SCHEMA_VERSION:
            return None
        if expect_key is not None and payload.get("key") != expect_key:
            return None
        fields = {f.name for f in dataclasses.fields(cls)}
        if not fields.issubset(payload):
            return None
        try:
            return cls(**{k: payload[k] for k in fields})
        except (TypeError, ValueError):
            return None


def _artifact_path(out_dir, key: str) -> Path:
    return Path(out_dir) / f"{key}.calib.json"


def save_calibration(out_dir, calib: Calibration) -> Path:
    path = _artifact_path(out_dir, calib.key)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(calib.to_json())
    return path


def load_calibration(out_dir, key: str) -> Optional[Calibration]:
    """Artifact for ``key``, or None (miss: absent, corrupt, version
    drift, or written for another fingerprint/backend)."""
    path = _artifact_path(out_dir, key)
    if not path.exists():
        return None
    try:
        text = path.read_text()
    except OSError:
        return None
    return Calibration.from_json(text, expect_key=key)


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def _timeit(fn, *args, repeats: int = 3) -> float:
    """Best-of-``repeats`` wall seconds of ``fn(*args)``, blocking on the
    result (one untimed warmup absorbs compilation)."""
    import jax
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _fit_bw_lat(samples: Sequence[Tuple[float, float]]
                ) -> Tuple[float, float]:
    """Least-squares ``t = lat + bytes/bw`` over (bytes, seconds)
    samples, clamped to physical rails."""
    xs = np.array([s[0] for s in samples], np.float64)
    ys = np.array([s[1] for s in samples], np.float64)
    if len(xs) < 2 or float(np.ptp(xs)) == 0.0:
        bw = float(xs.mean() / max(ys.mean(), 1e-12)) if len(xs) else _MIN_BW
        return float(np.clip(bw, _MIN_BW, _MAX_BW)), 0.0
    slope, intercept = np.polyfit(xs, ys, 1)
    bw = 1.0 / max(float(slope), 1e-14)
    lat = max(float(intercept), 0.0)
    return (float(np.clip(bw, _MIN_BW, _MAX_BW)),
            float(np.clip(lat, _MIN_LAT, _MAX_LAT)))


def _a2a_fn(mesh, axis: str, chain: int = 1):
    """jitted shard_map'd chain of ``chain`` dependent tiled all_to_alls
    over ``axis``."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.comm import compat

    def f(x):
        for _ in range(chain):
            x = jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                   tiled=True)
        return x
    return jax.jit(compat.shard_map(f, mesh=mesh, in_specs=P(axis),
                                    out_specs=P(axis)))


def _psum_fn(mesh, axis: str):
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.comm import compat

    def f(x):
        return jax.lax.psum(x, axis)
    return jax.jit(compat.shard_map(f, mesh=mesh, in_specs=P(axis),
                                    out_specs=P()))


def _payload(mesh, axis: str, rows: int, d: int):
    """[size(axis)·rows, d] f32 sharded over ``axis`` on dim 0 (so each
    device holds ``rows`` rows split into size(axis) exchange chunks)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    x = jnp.ones((size * rows, d), jnp.float32)
    return jax.device_put(x, NamedSharding(mesh, P(axis)))


def measure_all_to_all(mesh, axis: str, rows_list: Sequence[int],
                       d: int = 256) -> List[Tuple[float, float]]:
    """(off-device bytes per device, seconds) of one tiled all_to_all
    over ``axis`` at each payload size."""
    size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    fn = _a2a_fn(mesh, axis)
    out = []
    for rows in rows_list:
        x = _payload(mesh, axis, rows, d)
        t = _timeit(fn, x)
        off_bytes = (size - 1) / size * rows * d * 4.0
        out.append((off_bytes, t))
    return out


def measure_psum(mesh, axis: str, rows_list: Sequence[int],
                 d: int = 256) -> List[Tuple[float, float]]:
    """(payload bytes per device, seconds) of one psum over ``axis``."""
    import jax
    import jax.numpy as jnp
    fn = _psum_fn(mesh, axis)
    out = []
    for rows in rows_list:
        x = jnp.ones((rows, d), jnp.float32)
        t = _timeit(fn, x)
        out.append((rows * d * 4.0, t))
    return out


def measure_chunk_overhead_ms(mesh, axis: str, topo: Topology, *,
                              rows: int = 512, d: int = 256,
                              chain: int = 4,
                              intra_lat: float = 0.0,
                              inter_lat: float = 0.0) -> float:
    """Per-chunk issue cost beyond message latency: ``chain`` dependent
    all_to_alls vs one, residual per extra collective minus the fitted
    per-message latencies (the quantity ``sched.cost.overlap_ms`` adds
    on top of ``chunk_latency_s``)."""
    from repro.comm.ledger import phase_messages
    x = _payload(mesh, axis, rows, d)
    t1 = _timeit(_a2a_fn(mesh, axis, 1), x)
    tk = _timeit(_a2a_fn(mesh, axis, chain), x)
    per_extra_s = max(0.0, (tk - t1) / max(1, chain - 1) - t1)
    mi, me = phase_messages(topo)
    lat_s = mi * intra_lat + me * inter_lat
    return float(np.clip((per_extra_s - lat_s) * 1e3, 1e-4, 1e3))


def measure_plan_step_us(M: int, *, q: int = 3,
                         slot_counts: Sequence[int] = (16, 32, 64)
                         ) -> Tuple[float, List[Tuple[float, float]]]:
    """Fitted per-slot cost (µs) of one migration replan, from timing
    the host greedy at several slot counts (the best available proxy for
    ``estimate_planning_ms``'s scan-latency slope on this backend)."""
    from repro.plan.estimate import PLAN_DEVICE_US
    from repro.plan.objectives import plan_migration_with_objective
    rng = np.random.default_rng(0)
    samples = []
    for n_slots in slot_counts:
        counts = np.floor(rng.random((n_slots, M)) ** 3 * 16.0)
        lens = rng.permutation(np.arange(8, 8 + n_slots)).astype(np.float64)
        n_per_dev = max(1, n_slots // M)

        def run():
            return plan_migration_with_objective(counts, lens, n_per_dev,
                                                 q=q)
        run()                                    # warmup
        t0 = time.perf_counter()
        run()
        samples.append((float(n_slots), time.perf_counter() - t0))
    xs = np.array([s[0] for s in samples])
    ys = np.array([s[1] for s in samples])
    slope_us = float(np.polyfit(xs, ys, 1)[0]) * 1e6 if len(xs) > 1 \
        else float(ys[0] / xs[0]) * 1e6
    step_us = max(slope_us - PLAN_DEVICE_US * M * max(1, q), 0.01)
    return step_us, samples


def measure_sim_speed(*, group: int = 64, d: int = 256
                      ) -> Tuple[float, float]:
    """(effective FLOP/s, seconds) of one condensation Gram build, under
    the ``pairs · 4 · d`` convention of ``estimate_similarity_ms``."""
    import jax
    import jax.numpy as jnp
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (group, d)), jnp.float32)
    fn = jax.jit(lambda a: a @ a.T)
    t = _timeit(fn, x)
    pairs = group * (group - 1) / 2.0
    speed = pairs * 4.0 * d / max(t, 1e-9)
    return float(np.clip(speed, _MIN_SPEED, _MAX_SPEED)), t


def measure_ffn_speed(*, rows: int = 512, d: int = 256, d_ff: int = 1024
                      ) -> Tuple[float, float]:
    """(effective FLOP/s, seconds) of the gated expert-FFN einsum chain,
    under the ``rows · 4 · d · d_ff`` convention the exchange planner
    prices ``ffn_ms`` with (a fitted *effective* speed: the real chain
    has three matmuls, the convention two — calibration absorbs that)."""
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((rows, d)), jnp.float32)
    wu = jnp.asarray(rng.standard_normal((d, d_ff)) / np.sqrt(d),
                     jnp.float32)
    wg = jnp.asarray(rng.standard_normal((d, d_ff)) / np.sqrt(d),
                     jnp.float32)
    wd = jnp.asarray(rng.standard_normal((d_ff, d)) / np.sqrt(d_ff),
                     jnp.float32)

    def f(x):
        h = jax.nn.silu(x @ wg) * (x @ wu)
        return h @ wd
    t = _timeit(jax.jit(f), x)
    speed = rows * 4.0 * d * d_ff / max(t, 1e-9)
    return float(np.clip(speed, _MIN_SPEED, _MAX_SPEED)), t


# ---------------------------------------------------------------------------
# the full run
# ---------------------------------------------------------------------------

def run_calibration(mesh, topo: Optional[Topology], *,
                    out_dir=None, quick: bool = True,
                    force: bool = False) -> Calibration:
    """Measure everything on ``mesh``'s backend and return the fit
    (loading a previously-persisted artifact for the same key from
    ``out_dir`` instead of re-measuring, and persisting fresh fits
    there — the PlanCache load-before-build discipline).

    ``mesh=None`` (or a mesh with no expert axis) skips the collective
    fits and keeps the topology's built-in link constants; compute and
    planning fits always run. ``force=True`` skips the cached-artifact
    load and overwrites it with a fresh fit — the drift detector's
    recalibration path (``--recalibrate-on-drift``): a fit that no
    longer matches reality must not satisfy its own cache key.
    """
    from repro.comm.topology import model_axes_of
    M = topo.num_devices if topo is not None else 1
    axes = model_axes_of(tuple(mesh.axis_names)) if mesh is not None \
        else None
    key = calibration_key(topo, M)
    if out_dir is not None and not force:
        cached = load_calibration(out_dir, key)
        if cached is not None:
            return cached

    rows_list = (64, 256, 1024) if quick else (64, 256, 1024, 4096)
    samples: Dict[str, Any] = {"rows_list": list(rows_list)}
    intra_bw = topo.intra_bw if topo is not None else _MAX_BW
    inter_bw = topo.inter_bw if topo is not None else _MAX_BW
    intra_lat = topo.intra_lat if topo is not None else 0.0
    inter_lat = topo.inter_lat if topo is not None else 0.0
    chunk_overhead_ms = -1.0

    if mesh is not None and axes is not None and topo is not None:
        if isinstance(axes, tuple):               # ("node", "local")
            node_ax, local_ax = axes
            intra_samples = measure_all_to_all(mesh, local_ax, rows_list)
            inter_samples = measure_all_to_all(mesh, node_ax, rows_list)
            intra_bw, intra_lat = _fit_bw_lat(intra_samples)
            inter_bw, inter_lat = _fit_bw_lat(inter_samples)
            samples["a2a_intra"] = intra_samples
            samples["a2a_inter"] = inter_samples
            samples["psum"] = measure_psum(mesh, local_ax, rows_list[:2])
            overhead_ax = local_ax
        else:                                     # flat "model"
            flat_samples = measure_all_to_all(mesh, axes, rows_list)
            intra_bw, intra_lat = _fit_bw_lat(flat_samples)
            inter_bw, inter_lat = intra_bw, intra_lat
            samples["a2a_intra"] = flat_samples
            samples["psum"] = measure_psum(mesh, axes, rows_list[:2])
            overhead_ax = axes
        chunk_overhead_ms = measure_chunk_overhead_ms(
            mesh, overhead_ax, topo, intra_lat=intra_lat,
            inter_lat=inter_lat)
    if chunk_overhead_ms <= 0.0:
        from repro.sched.cost import DEFAULT_CHUNK_OVERHEAD_MS
        chunk_overhead_ms = DEFAULT_CHUNK_OVERHEAD_MS

    plan_step_us, plan_samples = measure_plan_step_us(max(M, 2))
    samples["planning"] = plan_samples
    sim_speed, sim_t = measure_sim_speed()
    samples["similarity_s"] = sim_t
    ffn_speed, ffn_t = measure_ffn_speed()
    samples["ffn_s"] = ffn_t

    calib = Calibration(
        key=key, intra_bw=intra_bw, inter_bw=inter_bw,
        intra_lat=intra_lat, inter_lat=inter_lat,
        chunk_overhead_ms=chunk_overhead_ms, plan_step_us=plan_step_us,
        sim_speed=sim_speed, ffn_speed=ffn_speed,
        # canonicalize (tuples -> lists) so the in-memory fit equals its
        # serialized round trip
        samples=json.loads(json.dumps(samples)))
    if out_dir is not None:
        save_calibration(out_dir, calib)
    return calib


# ---------------------------------------------------------------------------
# trace-mode phase probe
# ---------------------------------------------------------------------------

def probe_exchange(cfg, luffy, *, n_seq: int = 2,
                   seq_len: Optional[int] = None, seed: int = 0):
    """Drive ONE representative gate → plan-build → execute exchange
    *eagerly* on this device, so an active tracer records real fenced
    plan_build / condense / dispatch / expert_ffn / combine phase spans.

    The jitted train step hides those phases structurally: the
    transformer forward scans over layer groups and ``lax.scan`` traces
    its body even outside ``jit``, so the library ``phase()`` hooks can
    never fire through ``forward_train``. The probe is the ``--trace``
    mode's source of per-phase timings — same code path
    (``build_exchange_plan``/``execute_plan``), representative shapes,
    single-device collectives. Returns (y, aux).
    """
    import jax
    import jax.numpy as jnp
    from repro.comm import CommContext
    from repro.core import moe_layer
    S = seq_len if seq_len is not None else 64
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    params = moe_layer.moe_init(k1, cfg)
    x = jax.random.normal(k2, (n_seq, S, cfg.d_model), jnp.float32)
    sideband = {"labels": jnp.zeros((n_seq, S), jnp.int32),
                "seq_len": jnp.full((n_seq,), S, jnp.float32)}
    capacity = moe_layer.capacity_for(cfg.moe, n_seq * S,
                                      cfg.moe.num_experts)
    y, _sb, _sn, aux = moe_layer.moe_core(
        params, x, sideband, cfg, luffy, mode="vanilla",
        capacity=capacity, threshold=jnp.float32(0.95),
        group_size=min(luffy.condense_group, S),
        combine_slack=luffy.combine_slack, comm=CommContext.local())
    jax.block_until_ready(y)
    return y, aux


def probe_exchange_per_device(cfg, luffy, *, n_seq: int = 1,
                              seq_len: Optional[int] = None,
                              seed: int = 0,
                              max_devices: int = 8) -> Dict[int, float]:
    """Run :func:`probe_exchange` once pinned to each local device and
    return ``{device_index: wall_ms}`` — the straggler probe.

    Each repetition runs under a ``probe_exchange`` span tagged
    ``device=i``, which ``Tracer.to_chrome`` maps onto its own Perfetto
    row; the returned dict feeds
    :func:`repro.obs.monitor.device_dispersion`. On a single-device
    backend this degenerates to one entry (dispersion 1.0) — cheap and
    harmless."""
    import time

    import jax

    from repro.obs import trace as obs_trace
    out: Dict[int, float] = {}
    for i, dev in enumerate(jax.local_devices()[:max_devices]):
        with jax.default_device(dev):
            with obs_trace.phase("probe_exchange", cat="probe",
                                 device=i):
                t0 = time.perf_counter()
                probe_exchange(cfg, luffy, n_seq=n_seq, seq_len=seq_len,
                               seed=seed)
                out[i] = (time.perf_counter() - t0) * 1e3
    return out
