"""Unified metrics registry (DESIGN.md §11).

Before this module the same quantities lived under three ad-hoc naming
schemes: the ``MoEAux``-derived dict ``forward_train`` returns
(``plans_built``, ``inter_bytes_shipped``, …), the optimizer metrics
(``grad_norm``, ``lr``), and the dryrun ``comm_ledger`` sections. The
registry maps every known legacy key onto one canonical
``group/name`` scheme, distinguishes **gauges** (per-step values) from
**counters** (per-step increments that also accumulate into a
cumulative view), and emits one JSONL record per step that benchmarks
and CI consume directly.

Applicability masking: some legacy keys are only *populated* under a
specific config — ``inter_bytes_shipped`` is computed only when
``hier_dedup="on"``; in every other mode the aux slot is numerically
``0.0``, which a dashboard would read as "zero bytes shipped" rather
than "dense wire, nothing measured". :func:`mask_inapplicable` (and
:meth:`MetricsRegistry.observe`, which applies it) reports such keys as
``None`` (JSON ``null``) when their requirement is not met.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, NamedTuple, Optional, Tuple

# Version of the per-step metrics JSONL record (bump on renames or
# structural changes).
METRICS_SCHEMA_VERSION = 1

# Version of the dryrun comm-traffic ledger JSON (repro.launch.dryrun
# imports this; the golden-schema test pins both the value and the key
# sets). v1 was the unversioned pre-obs ledger; v2 adds the
# ``schema_version`` field itself; v3 adds the ``autotune`` section
# (chosen config + modeled savings vs defaults); v4 adds the ``decode``
# section (combine/shared-FFN pricing + the decode_overlap speedup).
# v5 adds the ``wire`` section (wire_dtype precision arithmetic,
# DESIGN.md §14) and prices the bucket bytes at the run's wire dtype.
# v6 extends ``wire`` with per-execution-mode shipped inter-node bytes
# (``shipped_vanilla_bytes`` / ``shipped_migrate_bytes`` /
# ``shipped_pipelined_bytes`` — equal by construction now the dedup
# wire is universal, DESIGN.md §15).
COMM_LEDGER_SCHEMA_VERSION = 6


class MetricSpec(NamedTuple):
    """One canonical metric: its unified name, kind, the legacy keys it
    absorbs, and an optional config requirement gating applicability."""
    name: str                      # canonical "group/name"
    kind: str                      # "gauge" | "counter"
    legacy: Tuple[str, ...]        # raw dict keys mapped onto this
    unit: str = ""
    requires: Optional[str] = None  # key into _REQUIREMENTS, or None


# Config predicates for MetricSpec.requires. A metric whose predicate
# fails is *inapplicable*: reported as None, never accumulated.
_REQUIREMENTS = {
    "hier": lambda luffy: luffy is not None and luffy.comm_mode == "hier",
    "hier_dedup": lambda luffy: (luffy is not None
                                 and luffy.hier_dedup == "on"),
}


_SPECS = (
    MetricSpec("train/loss", "gauge", ("loss",)),
    MetricSpec("train/total_loss", "gauge", ("total_loss",)),
    MetricSpec("train/aux_loss", "gauge", ("aux_loss",)),
    MetricSpec("train/grad_norm", "gauge", ("grad_norm",)),
    MetricSpec("train/lr", "gauge", ("lr",)),
    MetricSpec("moe/dispatch_drop", "gauge", ("dispatch_drop",), "frac"),
    MetricSpec("moe/combine_drop", "gauge", ("combine_drop",), "frac"),
    MetricSpec("condense/rate", "gauge", ("condense_rate",), "frac"),
    MetricSpec("migrate/local_frac", "gauge", ("local_frac",), "frac"),
    MetricSpec("migrate/traffic_before", "gauge", ("traffic_before",),
               "rows"),
    MetricSpec("migrate/traffic_after", "gauge", ("traffic_after",),
               "rows"),
    MetricSpec("comm/inter_bytes_flat", "counter", ("inter_bytes_flat",),
               "bytes", "hier"),
    MetricSpec("comm/inter_bytes_dedup", "counter", ("inter_bytes_dedup",),
               "bytes", "hier"),
    MetricSpec("comm/inter_bytes_shipped", "counter",
               ("inter_bytes_shipped",), "bytes", "hier_dedup"),
    MetricSpec("plan/built", "counter", ("plans_built",)),
    MetricSpec("plan/reused", "counter", ("plans_reused",)),
    MetricSpec("plan/reuse_mismatch", "counter", ("plan_reuse_mismatch",
                                                  "reuse_mismatch")),
    MetricSpec("condense/measured_pairs", "counter", ("measured_pairs",),
               "pairs"),
    MetricSpec("condense/built", "counter", ("condense_built",)),
    MetricSpec("condense/reused", "counter", ("condense_reused",)),
    MetricSpec("step/time_s", "gauge", ("time_s", "step_time_s"), "s"),
    MetricSpec("step/bucket", "gauge", ("bucket",)),
) + tuple(
    # Residual-stream gauges (repro.obs.monitor): one
    # predicted/measured/ratio triple per instrumented phase.
    MetricSpec(f"residual/{phase}/{field}", "gauge",
               (f"residual_{phase}_{field}",), unit)
    for phase in ("plan_build", "dispatch", "expert_ffn", "combine",
                  "step")
    for field, unit in (("predicted_ms", "ms"), ("measured_ms", "ms"),
                        ("ratio", "x"))
) + (
    MetricSpec("residual/drift", "gauge", ("residual_drift",)),
    MetricSpec("residual/device_dispersion", "gauge",
               ("residual_device_dispersion",), "x"),
) + (
    # Serving SLOs + scheduler occupancy (repro.serve, DESIGN.md §13):
    # per-step rows from launch/serve.py --continuous. The SLO gauges
    # are means over the requests that FINISHED that step (absent keys
    # stay inapplicable-None under the masking rule).
    MetricSpec("serve/queue_ms", "gauge", ("queue_ms",), "ms"),
    MetricSpec("serve/ttft_ms", "gauge", ("ttft_ms",), "ms"),
    MetricSpec("serve/tpot_ms", "gauge", ("tpot_ms",), "ms"),
    MetricSpec("serve/active_slots", "gauge", ("active_slots",)),
    MetricSpec("serve/queued", "gauge", ("queued_requests",)),
    MetricSpec("serve/admitted", "counter", ("admitted",)),
    MetricSpec("serve/finished", "counter", ("finished",)),
    MetricSpec("serve/generated_tokens", "counter", ("generated_tokens",),
               "tokens"),
    MetricSpec("serve/slot_churn", "counter", ("slot_churn",)),
)

SCHEMA: Dict[str, MetricSpec] = {s.name: s for s in _SPECS}
_LEGACY: Dict[str, MetricSpec] = {
    legacy: s for s in _SPECS for legacy in s.legacy}


def canonical_name(legacy_key: str) -> str:
    """The unified name for a legacy metrics-dict key (unknown keys map
    to themselves — they pass through records verbatim)."""
    spec = _LEGACY.get(legacy_key)
    return spec.name if spec is not None else legacy_key


def applicable(spec: MetricSpec, luffy) -> bool:
    if spec.requires is None:
        return True
    return _REQUIREMENTS[spec.requires](luffy)


def mask_inapplicable(raw: Dict[str, Any], luffy) -> Dict[str, Any]:
    """Replace values of config-gated legacy keys with ``None`` when the
    gating config is off (the ``inter_bytes_shipped`` fix: a dense-wire
    run reports null, not 0 bytes). Operates on *legacy* names so the
    launchers can apply it before or instead of full canonicalization."""
    out = dict(raw)
    for key, value in raw.items():
        spec = _LEGACY.get(key)
        if spec is not None and not applicable(spec, luffy):
            out[key] = None
    return out


def _to_float(v):
    if v is None or isinstance(v, (bool, str)):
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return v


class MetricsRegistry:
    """Per-step metric canonicalizer + counter accumulator.

    ``observe(step, raw)`` maps a raw legacy metrics dict to one JSONL
    record: values under canonical names (inapplicable ones ``None``),
    plus a ``cumulative`` view of every counter observed so far.
    """

    def __init__(self, *, luffy=None, run_info: Optional[Dict[str, Any]]
                 = None):
        self.luffy = luffy
        self.run_info = dict(run_info or {})
        self.cumulative: Dict[str, float] = {}
        self.steps_observed = 0

    def observe(self, step: int, raw: Dict[str, Any],
                **extra) -> Dict[str, Any]:
        metrics: Dict[str, Any] = {}
        for key, value in {**raw, **extra}.items():
            spec = _LEGACY.get(key)
            if spec is None:
                metrics[key] = _to_float(value)
                continue
            if not applicable(spec, self.luffy):
                metrics[spec.name] = None
                continue
            value = _to_float(value)
            metrics[spec.name] = value
            if spec.kind == "counter" and isinstance(value, float):
                self.cumulative[spec.name] = (
                    self.cumulative.get(spec.name, 0.0) + value)
        self.steps_observed += 1
        record = {"schema_version": METRICS_SCHEMA_VERSION,
                  "step": int(step), "metrics": metrics,
                  "cumulative": dict(self.cumulative)}
        if self.run_info and self.steps_observed == 1:
            record["run"] = dict(self.run_info)
        return record


def write_jsonl(path, record: Dict[str, Any]) -> None:
    """Append one record as a JSON line (creating parent dirs).

    The whole line goes out in a single ``os.write`` on an
    ``O_APPEND`` descriptor: a run killed mid-stream leaves a valid
    JSONL *prefix* plus at most one torn final line, which
    :func:`read_jsonl` skips — no record is ever half-applied across
    two lines."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    line = (json.dumps(record) + "\n").encode("utf-8")
    fd = os.open(str(p), os.O_APPEND | os.O_CREAT | os.O_WRONLY, 0o644)
    try:
        os.write(fd, line)
    finally:
        os.close(fd)


def read_jsonl(path) -> list:
    """Every complete record of a (possibly truncated) JSONL file.

    Parses record-by-record and stops at the first undecodable line —
    the torn tail a killed writer leaves — so crash artifacts are
    readable up to the last whole record."""
    out = []
    try:
        data = Path(path).read_bytes()
    except OSError:
        return out
    for raw in data.split(b"\n"):
        if not raw.strip():
            continue
        try:
            out.append(json.loads(raw.decode("utf-8")))
        except (ValueError, UnicodeDecodeError):
            break
    return out


def flatten(prefix: str, nested: Dict[str, Any]) -> Dict[str, Any]:
    """Flatten a nested dict (e.g. the dryrun ledger) into
    ``prefix/key/subkey`` scalars for a metrics record."""
    out: Dict[str, Any] = {}
    for key, value in nested.items():
        name = f"{prefix}/{key}" if prefix else str(key)
        if isinstance(value, dict):
            out.update(flatten(name, value))
        else:
            out[name] = value
    return out
