"""Predicted-vs-measured residual monitoring + drift detection
(DESIGN.md §12).

PR 6 left the three observability primitives disconnected: every
executed plan carries a :class:`repro.plan.estimate.PlanEstimate`
(what the cost model *predicted*), the tracer records fenced phase
spans (what actually *happened*), and the metrics registry publishes
both — but nothing joined them. This module closes that gap:

* :func:`predicted_phase_ms` maps a ``PlanEstimate`` onto the traced
  phase names (``dispatch`` / ``expert_ffn`` / ``combine`` and the
  whole-sublayer ``step``), so predictions and measurements share one
  key space;
* :func:`measured_phase_ms` aggregates a tracer's completed spans into
  mean per-phase milliseconds under the same names;
* :class:`ResidualMonitor` joins the two streams per step, publishes
  the canonical ``residual/<phase>/{predicted_ms,measured_ms,ratio}``
  gauges (plus ``residual/device_dispersion`` — max/median of
  per-device probe times, the straggler signal) through the metrics
  registry's legacy-key mapping, and runs one EWMA
  :class:`DriftDetector` per phase.

Drift semantics: each step updates an EWMA of ``log(measured /
predicted)``; a step is *out of tolerance* when ``|ewma| >
log(tolerance)``, and the detector **fires** after ``k`` consecutive
out-of-tolerance steps — a single straggler step never flags a stale
calibration, a sustained 2× bandwidth degradation does within a few
steps of the EWMA crossing (the property ``tests/test_monitor.py``
pins). ``--recalibrate-on-drift`` on the train launcher re-runs
``run_calibration(force=True)`` when the step detector fires.

Everything here is host-side float arithmetic: the monitor never
touches device values and adds nothing to the jitted step.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Iterable, Mapping, Optional

# The phase names shared between PlanEstimate fields and the tracer's
# instrumented spans ("step" is the whole exchange: sync or pipelined).
RESIDUAL_PHASES = ("plan_build", "dispatch", "expert_ffn", "combine",
                   "step")

_EPS_MS = 1e-9


def predicted_phase_ms(est, *, pipelined: bool = False
                       ) -> Dict[str, float]:
    """A :class:`~repro.plan.estimate.PlanEstimate` keyed by the traced
    phase names — the join key of the residual stream. ``step`` is the
    modeled whole-sublayer time under the executed schedule
    (``overlap_ms`` when pipelined, ``sync_ms`` otherwise)."""
    return {
        "dispatch": float(est.dispatch_ms),
        "expert_ffn": float(est.ffn_ms),
        "combine": float(est.combine_ms),
        "step": float(est.overlap_ms if pipelined else est.sync_ms),
    }


def measured_phase_ms(tracer, phases: Iterable[str] = RESIDUAL_PHASES
                      ) -> Dict[str, float]:
    """Mean inclusive milliseconds per phase name from a tracer's
    completed spans (fenced spans: real device time). Phases that never
    fired are absent, not zero."""
    summary = tracer.summary()
    out: Dict[str, float] = {}
    for name in phases:
        s = summary.get(name)
        if s and s["count"] > 0:
            out[name] = s["total_us"] / s["count"] / 1e3
    return out


class DriftDetector:
    """EWMA drift detector on the log residual ratio of ONE phase.

    ``update(ratio)`` folds ``log(ratio)`` into an exponentially
    weighted mean (initialized at the first sample, so the EWMA is
    always a convex combination of observed log-ratios: samples that
    all stay within tolerance can NEVER push it out — the
    no-false-positive property). Returns True — *fired* — once
    ``consecutive`` out-of-tolerance steps reach ``k``; ``fired``
    latches until :meth:`reset`.
    """

    def __init__(self, *, tolerance: float = 1.5,
                 ewma_alpha: float = 0.5, k: int = 5):
        assert tolerance > 1.0 and 0.0 < ewma_alpha <= 1.0 and k >= 1
        self.tolerance = float(tolerance)
        self.log_tol = math.log(tolerance)
        self.alpha = float(ewma_alpha)
        self.k = int(k)
        self.reset()

    def reset(self) -> None:
        self.ewma = 0.0
        self.samples = 0
        self.consecutive = 0
        self.fired = False

    @property
    def ewma_ratio(self) -> float:
        return math.exp(self.ewma)

    @property
    def out_of_tolerance(self) -> bool:
        return self.samples > 0 and abs(self.ewma) > self.log_tol

    def update(self, ratio: float) -> bool:
        x = math.log(max(float(ratio), 1e-9))
        self.samples += 1
        self.ewma = x if self.samples == 1 else (
            (1.0 - self.alpha) * self.ewma + self.alpha * x)
        if self.out_of_tolerance:
            self.consecutive += 1
        else:
            self.consecutive = 0
        if self.consecutive >= self.k:
            self.fired = True
        return self.fired


class ResidualMonitor:
    """Per-step join of predicted vs measured phase times.

    ``observe(step, predicted_ms, measured_ms)`` emits one flat dict of
    *legacy* residual keys (``residual_<phase>_predicted_ms`` /
    ``_measured_ms`` / ``_ratio`` plus ``residual_drift`` /
    ``residual_device_dispersion``) — exactly what
    ``MetricsRegistry.observe(step, raw, **extra)`` canonicalizes into
    the ``residual/...`` schema — and feeds each phase's ratio into its
    drift detector. Only phases present in BOTH streams produce
    residuals; prediction without measurement (or vice versa) is
    silence, not zero.
    """

    def __init__(self, *, tolerance: float = 1.5,
                 ewma_alpha: float = 0.5, k: int = 5,
                 phases: Iterable[str] = RESIDUAL_PHASES):
        self.phases = tuple(phases)
        self.detectors: Dict[str, DriftDetector] = {
            p: DriftDetector(tolerance=tolerance, ewma_alpha=ewma_alpha,
                             k=k) for p in self.phases}

    def reset(self) -> None:
        for d in self.detectors.values():
            d.reset()

    @property
    def drifted(self) -> bool:
        return any(d.fired for d in self.detectors.values())

    def drifted_phases(self) -> tuple:
        return tuple(p for p, d in self.detectors.items() if d.fired)

    def observe(self, step: int, predicted_ms: Mapping[str, float],
                measured_ms: Mapping[str, float],
                per_device_ms: Optional[Mapping[Any, float]] = None
                ) -> Dict[str, Any]:
        del step                       # kept for call-site symmetry
        out: Dict[str, Any] = {}
        for phase in self.phases:
            pred = predicted_ms.get(phase)
            meas = measured_ms.get(phase)
            if pred is None or meas is None:
                continue
            ratio = float(meas) / max(float(pred), _EPS_MS)
            out[f"residual_{phase}_predicted_ms"] = float(pred)
            out[f"residual_{phase}_measured_ms"] = float(meas)
            out[f"residual_{phase}_ratio"] = ratio
            self.detectors[phase].update(ratio)
        if per_device_ms:
            out["residual_device_dispersion"] = device_dispersion(
                per_device_ms)
        out["residual_drift"] = 1.0 if self.drifted else 0.0
        return out


def device_dispersion(per_device_ms: Mapping[Any, float]) -> float:
    """Straggler signal: max over median of per-device phase times. 1.0
    means perfectly balanced devices; 2.0 means the slowest device took
    twice the median — the Perfetto per-device rows (`Tracer.to_chrome`)
    show *which* one."""
    vals = sorted(float(v) for v in per_device_ms.values())
    if not vals:
        return 1.0
    mid = vals[len(vals) // 2] if len(vals) % 2 else (
        0.5 * (vals[len(vals) // 2 - 1] + vals[len(vals) // 2]))
    return vals[-1] / max(mid, _EPS_MS)
