"""Step tracing: low-overhead host-side spans + Chrome-trace export
(DESIGN.md §11).

A :class:`Tracer` records **host-timed spans** — begin/end wall-clock
pairs with nesting — as structured events, and exports them in the
Chrome trace-event JSON format (``chrome://tracing`` / Perfetto:
``{"traceEvents": [{"ph": "X", "ts", "dur", "name", ...}]}``).

Two ways to open a span:

* ``tracer.span("step", step=i)`` — explicit, used by the launchers
  around the jitted train/serve step (the caller holds the tracer);
* ``phase("dispatch")`` — the module-level hook the instrumented hot
  path (``repro.plan.exchange``) calls. It is a **no-op** unless a
  tracer has been :func:`activate`\\ d *and* the caller is running
  outside a jax trace (inside ``jit``/``scan``/``shard_map`` bodies the
  Python code runs at trace time, so a host timestamp there would be
  compile-time garbage — those spans are dropped, not recorded).

Fencing: jax dispatch is asynchronous, so a host timestamp right after
an op returns measures *launch*, not completion. With
``Tracer(fence=True)`` the ``--trace`` mode of the launchers,
``span.fence(value)`` calls ``jax.block_until_ready`` on the value at
the phase boundary, making the span's duration the real device time of
the phase (single-process backends; the fence is skipped for abstract
tracers). Untraced runs pay only a module-global ``None`` check per
``phase()`` call — the <5% overhead budget ``benchmarks/
fig_calibration.py`` asserts.

Exclusive time: every completed span records ``self_us`` (duration
minus the duration of its direct children), so a parent's inclusive
time is always ≥ the sum of its children's exclusive times — the
invariant the 8-device trace test asserts.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional


# Synthetic Chrome-trace thread ids for device-tagged spans: host tids
# are masked to 16 bits, so rows at 0x10000+ can never collide.
DEVICE_TID_BASE = 0x10000


def _now_us() -> float:
    return time.perf_counter_ns() / 1e3


def _trace_state_clean() -> bool:
    """True when NOT inside a jax trace (jit/scan/shard_map body) — the
    only place a host-side timestamp means anything. Falls back to True
    when the introspection API is unavailable (or jax is not imported
    at all: pure host spans are always fine)."""
    import sys
    jax = sys.modules.get("jax")
    if jax is None:
        return True
    try:
        return jax.core.trace_state_clean()
    except Exception:
        return True


def _block(value):
    """``jax.block_until_ready`` that tolerates non-array / abstract
    leaves (fencing must never change program behavior)."""
    import sys
    jax = sys.modules.get("jax")
    if jax is None:
        return value
    try:
        leaves = jax.tree.leaves(value)
        for leaf in leaves:
            if isinstance(leaf, jax.core.Tracer):
                continue
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
    except Exception:
        pass
    return value


class _Span:
    """One open span. Context manager; records an ``"X"`` (complete)
    event on exit."""
    __slots__ = ("tracer", "name", "cat", "args", "t0", "child_us",
                 "parent")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = 0.0
        self.child_us = 0.0
        self.parent: Optional["_Span"] = None

    def set(self, **kw) -> "_Span":
        self.args.update(kw)
        return self

    def fence(self, value):
        """Block on ``value`` (when fencing is active) so the span's end
        timestamp covers the device work that produced it. Returns the
        value unchanged either way."""
        if self.tracer.fence:
            value = _block(value)
        return value

    def __enter__(self) -> "_Span":
        stack = self.tracer._stack()
        self.parent = stack[-1] if stack else None
        stack.append(self)
        self.t0 = _now_us()
        return self

    def __exit__(self, *exc) -> bool:
        dur = _now_us() - self.t0
        stack = self.tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        if self.parent is not None:
            self.parent.child_us += dur
        self.tracer._record({
            "name": self.name, "cat": self.cat, "ph": "X",
            "ts": self.t0, "dur": dur, "pid": self.tracer.pid,
            "tid": threading.get_ident() & 0xFFFF,
            "args": {**self.args,
                     "self_us": max(0.0, dur - self.child_us)},
        })
        return False


class _NullSpan:
    """Inert span returned when no tracer is active (or the caller is
    inside a jax trace). One shared instance; every method is a no-op."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **_kw) -> "_NullSpan":
        return self

    def fence(self, value):
        return value


NULL_SPAN = _NullSpan()


class Tracer:
    """Host-side span recorder with Chrome-trace export.

    ``fence=True`` makes ``span.fence(x)`` block on device values at
    phase boundaries (the ``--trace`` launcher mode); with ``fence=False``
    spans are pure host intervals (async launch times).
    """

    def __init__(self, *, fence: bool = False):
        self.fence = fence
        self.pid = os.getpid()
        self.events: List[Dict[str, Any]] = []
        self._tls = threading.local()
        self._lock = threading.Lock()

    # -- recording -----------------------------------------------------------
    def _stack(self) -> List[_Span]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = []
            self._tls.stack = stack
        return stack

    def _record(self, event: Dict[str, Any]) -> None:
        with self._lock:
            self.events.append(event)

    def span(self, name: str, cat: str = "phase", **args) -> _Span:
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "mark", **args) -> None:
        self._record({"name": name, "cat": cat, "ph": "i",
                      "ts": _now_us(), "pid": self.pid,
                      "tid": threading.get_ident() & 0xFFFF, "s": "t",
                      "args": args})

    def counter(self, name: str, **series: float) -> None:
        self._record({"name": name, "cat": "metric", "ph": "C",
                      "ts": _now_us(), "pid": self.pid, "tid": 0,
                      "args": dict(series)})

    # -- views ---------------------------------------------------------------
    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        """Completed ``"X"`` events (optionally filtered by name), in
        completion order."""
        return [e for e in self.events
                if e["ph"] == "X" and (name is None or e["name"] == name)]

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-name aggregate: count, inclusive total, exclusive total
        (µs). Exclusive = duration minus direct children — sums to wall
        time without double counting."""
        out: Dict[str, Dict[str, float]] = {}
        for e in self.spans():
            s = out.setdefault(e["name"],
                               {"count": 0, "total_us": 0.0,
                                "self_us": 0.0})
            s["count"] += 1
            s["total_us"] += e["dur"]
            s["self_us"] += e["args"].get("self_us", e["dur"])
        return out

    # -- export --------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """The Chrome trace-event JSON object (``traceEvents`` array of
        events each carrying the required ``ph``/``ts``/``name`` — and
        ``dur`` for complete events).

        Spans tagged with a ``device`` arg (the eager per-device
        exchange probe) are remapped onto synthetic per-device ``tid``
        rows with ``thread_name`` metadata, so Perfetto shows the
        devices side-by-side instead of flattening them onto the host
        thread — stragglers become visible as the one long row."""
        events: List[Dict[str, Any]] = []
        device_rows: Dict[int, int] = {}   # device index -> (pid, tid)
        for e in self.events:
            dev = e.get("args", {}).get("device")
            if e["ph"] == "X" and isinstance(dev, int):
                e = dict(e)
                e["tid"] = DEVICE_TID_BASE + dev
                device_rows[dev] = e["pid"]
            events.append(e)
        for dev in sorted(device_rows):
            events.append({"name": "thread_name", "ph": "M", "ts": 0.0,
                           "pid": device_rows[dev],
                           "tid": DEVICE_TID_BASE + dev,
                           "args": {"name": f"device {dev}"}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path) -> None:
        from pathlib import Path
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_chrome(), indent=1))


# ---------------------------------------------------------------------------
# module-level hook (the instrumented hot path calls this)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[Tracer] = None


def activate(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process-wide :func:`phase` sink."""
    global _ACTIVE
    _ACTIVE = tracer
    return tracer


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[Tracer]:
    return _ACTIVE


def phase(name: str, cat: str = "phase", **args):
    """Span hook for instrumented library code (``repro.plan.exchange``
    phases: plan_build / condense / dispatch / expert_ffn / combine).

    Returns :data:`NULL_SPAN` (free) unless a tracer is active AND the
    caller runs outside a jax trace — so production steps pay one
    module-global comparison, and jitted/scanned bodies never record
    compile-time timestamps."""
    tracer = _ACTIVE
    if tracer is None:
        return NULL_SPAN
    if not _trace_state_clean():
        return NULL_SPAN
    return tracer.span(name, cat, **args)
