"""Optimizers in pure JAX: AdamW (with ZeRO-1-friendly moment sharding),
SGD+momentum, global-norm clipping, warmup-cosine schedule.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimConfig


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moments  (pytree like params, f32)
    nu: Any          # second moments (pytree like params, f32)


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 128 and p.shape[-2] >= 128


def init_opt_state(params, cfg: OptimConfig) -> OptState:
    """AdamW: f32 mu/nu. Adafactor: bf16 mu + factored f32 nu (row/col
    second-moment estimates) — the memory-viable choice for 100B+ MoE
    (full f32 Adam moments for llama4-400b are 24 GB/device at maximal
    sharding on a 256-chip pod; factored states are ~params/4096)."""
    if cfg.name == "adafactor":
        mu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)

        def nu_init(p):
            if _factored(p):
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                       jnp.float32)}
            return jnp.zeros(p.shape, jnp.float32)

        return OptState(jnp.zeros((), jnp.int32), mu,
                        jax.tree.map(nu_init, params))
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: OptimConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def _decay_mask(path) -> bool:
    """No weight decay for norms / biases / 1-d params."""
    keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    flat = "/".join(str(k) for k in keys)
    return not any(s in flat for s in ("norm", "scale", "bias", "mix_",
                                       "dt_bias", "a_log", "d_skip",
                                       "w_bias", "u_bonus"))


def adamw_update(params, grads, state: OptState, cfg: OptimConfig
                 ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state.mu, state.nu)
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step, new_mu, new_nu), \
        {"grad_norm": gnorm, "lr": lr}


def adafactor_update(params, grads, state: OptState, cfg: OptimConfig):
    """Adafactor with momentum (bf16 mu, factored f32 nu) + weight decay."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b2 = cfg.b2

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + 1e-30
        if isinstance(v, dict):
            r = b2 * v["r"] + (1 - b2) * jnp.mean(g2, axis=-1)
            c = b2 * v["c"] + (1 - b2) * jnp.mean(g2, axis=-2)
            rc = r[..., None] * c[..., None, :]
            denom = rc / jnp.maximum(
                jnp.mean(r, axis=-1)[..., None, None], 1e-30)
            v2 = {"r": r, "c": c}
        else:
            denom = b2 * v + (1 - b2) * g2
            v2 = denom
        u = gf / (jnp.sqrt(denom) + cfg.eps)
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * u
        delta = m2
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(m.dtype), v2

    # NB: trees 2..4 are flattened up-to params' structure, so a factored
    # nu arrives at `upd` as its whole {"r","c"} dict.
    out = jax.tree_util.tree_map_with_path(
        upd, params, grads, state.mu, state.nu)

    def is3(t):
        return isinstance(t, tuple) and len(t) == 3
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return new_params, OptState(step, new_mu, new_nu), \
        {"grad_norm": gnorm, "lr": lr}


def sgd_update(params, grads, state: OptState, cfg: OptimConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    mu = jax.tree.map(lambda m, g: 0.9 * m + g.astype(jnp.float32),
                      state.mu, grads)
    params = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
        params, mu)
    return params, OptState(step, mu, state.nu), \
        {"grad_norm": gnorm, "lr": lr}


def update(params, grads, state, cfg: OptimConfig):
    if cfg.name == "sgd":
        return sgd_update(params, grads, state, cfg)
    if cfg.name == "adafactor":
        return adafactor_update(params, grads, state, cfg)
    return adamw_update(params, grads, state, cfg)
