"""Exchange planning subsystem (DESIGN.md §7).

Where :mod:`repro.comm` decides *where bytes go and what they cost* and
:mod:`repro.sched` decides *when the collectives run*, ``repro.plan``
materializes the whole decision as data: :func:`build_exchange_plan`
turns one router output into a frozen :class:`ExchangePlan` (routing,
condensation map, migration assignment, chunk schedule, per-phase
estimates) and :func:`execute_plan` is the thin executor every consumer
— train forward, serving prefill, future paths — shares. Planning
policy is pluggable through :mod:`repro.plan.objectives`
(``LuffyConfig.plan_objective``: ``"traffic"`` reproduces the historical
link-cost-weighted planner exactly, ``"overlap"`` minimizes modeled
exposed time); :mod:`repro.plan.estimate` is the single analytic pricing
source the dry-run ledger and ``commsim`` report from.
"""
from repro.plan.estimate import PlanEstimate, estimate_exchange
from repro.plan.exchange import (ExchangeAux, ExchangePlan, MoEAux, N_AUX,
                                 build_exchange_plan, execute_plan)
from repro.plan.objectives import (ObjectiveContext, available_objectives,
                                   get_objective,
                                   plan_migration_with_objective,
                                   register_objective)

__all__ = [
    "ExchangeAux", "ExchangePlan", "MoEAux", "N_AUX", "ObjectiveContext",
    "PlanEstimate", "available_objectives", "build_exchange_plan",
    "estimate_exchange", "execute_plan", "get_objective",
    "plan_migration_with_objective", "register_objective",
]
