"""Exchange planning subsystem (DESIGN.md §7).

Where :mod:`repro.comm` decides *where bytes go and what they cost* and
:mod:`repro.sched` decides *when the collectives run*, ``repro.plan``
materializes the whole decision as data: :func:`build_exchange_plan`
turns one router output into a frozen :class:`ExchangePlan` (routing,
condensation map, migration assignment, chunk schedule, per-phase
estimates) and :func:`execute_plan` is the thin executor every consumer
— train forward, serving prefill, future paths — shares. Planning
policy is pluggable through :mod:`repro.plan.objectives`
(``LuffyConfig.plan_objective``: ``"traffic"`` reproduces the historical
link-cost-weighted planner exactly, ``"overlap"`` minimizes modeled
exposed time); :mod:`repro.plan.estimate` is the single analytic pricing
source the dry-run ledger and ``commsim`` report from.
"""
from repro.plan.cache import (PlanCache, build_decode_template,
                              build_plan_template, decode_plan_key,
                              plan_key, precompute_decode_plans,
                              precompute_prefill_plans, prefill_plan_key,
                              topology_fingerprint)
from repro.plan.estimate import (PlanEstimate, estimate_exchange,
                                 estimate_planning_ms,
                                 estimate_revalidate_ms,
                                 estimate_similarity_ms)
from repro.plan.exchange import (ExchangeAux, ExchangePlan, MoEAux, N_AUX,
                                 PlanSignature, build_exchange_plan,
                                 execute_plan, instantiate_decode_plan,
                                 instantiate_plan,
                                 invalid_signature, next_signature,
                                 plan_static_schedule,
                                 routing_signature_matches)
from repro.plan.objectives import (ObjectiveContext, available_objectives,
                                   get_objective,
                                   plan_migration_with_objective,
                                   register_objective)
from repro.plan.serial import (FORMAT_VERSION, PlanFormatError, from_bytes,
                               to_bytes)

__all__ = [
    "ExchangeAux", "ExchangePlan", "FORMAT_VERSION", "MoEAux", "N_AUX",
    "ObjectiveContext", "PlanCache", "PlanEstimate", "PlanFormatError",
    "PlanSignature", "available_objectives", "build_decode_template",
    "build_exchange_plan",
    "build_plan_template", "decode_plan_key", "estimate_exchange",
    "estimate_planning_ms",
    "estimate_revalidate_ms", "estimate_similarity_ms", "execute_plan",
    "from_bytes",
    "get_objective", "instantiate_decode_plan", "instantiate_plan",
    "invalid_signature",
    "next_signature", "plan_key", "plan_migration_with_objective",
    "plan_static_schedule", "precompute_decode_plans",
    "precompute_prefill_plans",
    "prefill_plan_key", "register_objective", "routing_signature_matches",
    "to_bytes", "topology_fingerprint",
]
