"""Keyed :class:`PlanCache` with disk spill + ahead-of-time templates.

The serving half of the plan lifecycle (DESIGN.md §9): for *known* batch
shapes every static decision of an exchange — capacity partition, chunk
schedule, pipelined flag, analytic estimate — is a pure function of the
shape key, so it can be decided once, serialized
(:mod:`repro.plan.serial`) and looked up on the request path.
``serve_lib.prefill`` resolves a template at trace time and routes the
MoE sublayer through :func:`repro.plan.exchange.instantiate_plan`, which
binds fresh routing onto the template without calling
``build_exchange_plan`` at all (the zero-planning request path;
``launch/serve.py --plan-cache DIR --precompute-plans``).

Keys are filesystem-safe slugs over batch shape × seq len × planner
objective × topology fingerprint (plus the execution knobs that select
the schedule), so a cache directory can be shared across processes and
restarts; entries whose serialized format version drifts are treated as
misses and rebuilt, never misread.
"""
from __future__ import annotations

from collections import OrderedDict
from pathlib import Path
from typing import Optional, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro.comm import CommContext
from repro.comm.topology import Topology
from repro.config import LuffyConfig, ModelConfig
from repro.plan import serial
from repro.plan.exchange import ExchangePlan, plan_static_schedule


def topology_fingerprint(topo: Optional[Topology], M: int) -> str:
    """Short stable id of the fabric a plan was priced on. Absolute
    per-tier bandwidths and latencies are part of the id (not just the
    ratio): with a planned chunk count (``pipeline_chunks <= 0``) the
    estimate search depends on them, and two fabrics with equal shape
    but different link speeds must not share a cached schedule."""
    if topo is None:
        return f"flat{M}"
    return (f"{topo.num_nodes}x{topo.devices_per_node}"
            f"i{topo.intra_bw:.4g}e{topo.inter_bw:.4g}"
            f"l{topo.intra_lat:.3g}-{topo.inter_lat:.3g}")


def plan_key(*, n_seq: int, seq_len: int, d_model: int, capacity: int,
             top_k: int, num_experts: int, mode: str, objective: str,
             exec_mode: str, pipeline_chunks: int, comm_mode: str,
             topo: Optional[Topology], M: int,
             compute_dtype: str = "bfloat16",
             gpu_speed: float = 1.0e13, d_ff: int = 0,
             hier_dedup: str = "off",
             params_version: str = "0",
             chunk_overhead_ms: float = -1.0,
             wire_dtype: str = "f32") -> str:
    """The cache key: batch shape × seq len × objective × topology
    fingerprint, plus every knob that selects the static schedule
    (``gpu_speed``/``d_ff`` price the FFN stage the chunk search
    overlaps against) and the wire format (``hier_dedup`` selects the
    executed exchange, DESIGN.md §10). ``n_seq``/``seq_len`` are the
    PER-DEVICE sequence slots and (possibly sequence-sharded) token
    count the MoE sublayer sees.

    ``params_version`` is a router/optimizer-step fingerprint (ISSUE 5
    satellite): vanilla serving plans hold no routing and use the
    default "0", but a migrate-mode plan cached across training steps
    bakes the router's decisions in — keying (and the serialized
    header, ``repro.plan.serial``) on the fingerprint guarantees a
    stale assignment is never trusted after an optimizer step."""
    # A calibrated per-chunk overhead changes the planned chunk count /
    # estimate, so it is part of the key; the unset default (<= 0) adds
    # nothing, keeping historical keys (and spilled caches) valid.
    o_part = f"_o{chunk_overhead_ms:.3g}" if chunk_overhead_ms > 0 else ""
    # The wire precision is frozen into the plan (estimate + executed
    # quantization, DESIGN.md §14) — a dtype change must be a cache
    # MISS. The f32 default adds nothing so historical keys stay valid.
    wd_part = f"_wd{wire_dtype}" if wire_dtype != "f32" else ""
    # The "replicate" objective freezes a replica placement into
    # migrate-mode plans (DESIGN.md §15) — those must not share entries
    # with replica-free plans. Empty otherwise, so historical keys
    # (every objective shipped before replication) stay valid.
    rep_part = ("_rep1" if (objective == "replicate" and mode == "migrate")
                else "")
    return (f"b{n_seq}_s{seq_len}_d{d_model}_f{d_ff}_c{capacity}"
            f"_k{top_k}_e{num_experts}_{mode}_{objective}"
            f"_{exec_mode}{pipeline_chunks}_p{gpu_speed:.4g}"
            f"_{comm_mode}_{topology_fingerprint(topo, M)}"
            f"_{compute_dtype}_w{hier_dedup}_pv{params_version}"
            f"{o_part}{wd_part}{rep_part}")


class PlanCache:
    """In-memory LRU of ExchangePlans keyed by :func:`plan_key`, with
    optional disk spill (one ``<key>.plan`` file per entry, the
    :mod:`repro.plan.serial` byte format).

    ``get`` falls back to disk on a memory miss; unreadable or
    version-mismatched files count as misses (and are rebuilt by the
    caller) — a stale cache can cost a replan, never a wrong plan.
    """

    def __init__(self, path: Optional[Union[str, Path]] = None,
                 mem_capacity: int = 64, params_version: str = "0"):
        self.path = None if path is None else Path(path)
        if self.path is not None:
            self.path.mkdir(parents=True, exist_ok=True)
        self.mem_capacity = int(mem_capacity)
        # router/optimizer-step fingerprint stamped into every spilled
        # plan and demanded back on load: a blob written at another
        # params_version is a miss, never a trusted stale plan
        self.params_version = str(params_version)
        self._mem: "OrderedDict[str, ExchangePlan]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.disk_loads = 0
        self.puts = 0

    def __len__(self) -> int:
        return len(self._mem)

    def _file(self, key: str) -> Optional[Path]:
        return None if self.path is None else self.path / f"{key}.plan"

    def get(self, key: str) -> Optional[ExchangePlan]:
        plan = self._mem.get(key)
        if plan is not None:
            self._mem.move_to_end(key)
            self.hits += 1
            return plan
        f = self._file(key)
        if f is not None and f.exists():
            try:
                plan = serial.from_bytes(
                    f.read_bytes(),
                    expect_params_version=self.params_version)
            except Exception:        # stale/corrupt/foreign file: a
                plan = None          # miss (and a replan), never a
                                     # crash or a wrong plan
            if plan is not None:
                self._insert(key, plan)
                self.hits += 1
                self.disk_loads += 1
                return plan
        self.misses += 1
        return None

    def put(self, key: str, plan: ExchangePlan, *,
            spill: bool = True) -> None:
        self._insert(key, plan)
        self.puts += 1
        f = self._file(key)
        if spill and f is not None:
            f.write_bytes(serial.to_bytes(
                plan, params_version=self.params_version))

    def _insert(self, key: str, plan: ExchangePlan) -> None:
        self._mem[key] = plan
        self._mem.move_to_end(key)
        while len(self._mem) > self.mem_capacity:
            self._mem.popitem(last=False)   # evict LRU (disk copy stays)

    def stats(self) -> dict:
        return {"entries": len(self._mem), "hits": self.hits,
                "misses": self.misses, "disk_loads": self.disk_loads,
                "puts": self.puts}


# ---------------------------------------------------------------------------
# ahead-of-time templates
# ---------------------------------------------------------------------------

def build_plan_template(cfg: ModelConfig, luffy: LuffyConfig, *,
                        n_seq: int, seq_len: int, capacity: int,
                        comm_mode: str = "local",
                        axes: Tuple[str, ...] = (),
                        topo: Optional[Topology] = None,
                        M: int = 1) -> ExchangePlan:
    """Decide every static part of a vanilla exchange for one shape key
    — host-side, no tracing, no routing. The schedule comes from the
    SAME :func:`plan_static_schedule` the live builder uses, so a
    template's chunk plan / pipelined flag / estimate are identical to
    what ``build_exchange_plan`` would decide; the traced fields are
    zero placeholders that ``instantiate_plan`` replaces per request.
    """
    m = cfg.moe
    d = cfg.d_model
    T = n_seq * seq_len
    from repro.condense.plan import CondensePlan
    from repro.models.blocks import _dtype
    from repro.comm import dtypes as wire_dtypes
    bytes_per_el = jnp.dtype(_dtype(cfg.compute_dtype)).itemsize
    wire_dtype = wire_dtypes.validate_wire_dtype(luffy.wire_dtype)
    pipelined, chunks, est = plan_static_schedule(
        cfg, luffy, topo, M, T, d, capacity, bytes_per_el=bytes_per_el,
        wire_dtype=wire_dtype)
    # wire decision — same rule as build_exchange_plan (DESIGN.md §15:
    # the dedup wire is universal, pipelined exchanges included)
    wire = ("dedup" if (luffy.hier_dedup == "on" and comm_mode == "hier"
                        and M > 1) else "dense")
    z = np.float32(0.0)
    zi = np.zeros((0,), np.int32)
    return ExchangePlan(
        mode="vanilla", migrate=False, condense=False,
        pipelined=pipelined, capacity=capacity, chunks=chunks,
        comm=CommContext(comm_mode, tuple(axes), topo),
        objective=luffy.plan_objective, group_size=luffy.condense_group,
        combine_slack=luffy.combine_slack, use_kernel=luffy.use_kernels,
        wire=wire, wire_dtype=wire_dtype, estimate=est,
        # placeholder routing — instantiate_plan never reads these
        expert_idx=zi.reshape(0, 1), gate_weights=zi.astype(np.float32)
        .reshape(0, 1), positions=zi.reshape(0, 1),
        valid=zi.reshape(0, 1).astype(bool), aux_loss=z,
        dispatch_drop=z,
        condense_plan=CondensePlan(
            backend=luffy.similarity_backend, rep_idx=zi,
            is_rep=zi.astype(bool), s_next=None, rate=z,
            measured_pairs=z),
        dest_global=zi, traffic_before=z, traffic_after=z,
        inter_bytes_flat=z, inter_bytes_dedup=z, signature=None,
        plans_built=z, plans_reused=z, reuse_mismatch=z)


def _prefill_locals(dist, batch: int, seq_len: int):
    """Per-device (n_seq, seq_len, M, topo) split of one prefill shape —
    exactly what the prefill shard_map sees."""
    M = dist.model_size if dist.enabled else 1
    div = dist.batch_size_divisor if dist.enabled else 1
    n_seq_l = max(1, batch // max(1, div))
    s_l = seq_len
    if dist.enabled and dist.seq_axis is not None:
        s_l = seq_len // dist.axis_size(dist.seq_axis)
    topo = dist.topology if dist.enabled else None
    return n_seq_l, s_l, M, topo


def prefill_plan_key(cfg: ModelConfig, luffy: LuffyConfig, dist,
                     batch: int, seq_len: int,
                     capacity: Optional[int] = None) -> str:
    """The key ``serve_lib.prefill`` and ``precompute_prefill_plans``
    agree on; ``capacity`` defaults to the shared
    ``serve_lib.prefill_capacity`` derivation."""
    if capacity is None:
        from repro.serve_lib import prefill_capacity
        capacity = prefill_capacity(cfg, dist, batch, seq_len)
    n_seq_l, s_l, M, topo = _prefill_locals(dist, batch, seq_len)
    return plan_key(
        n_seq=n_seq_l, seq_len=s_l, d_model=cfg.d_model,
        capacity=capacity, top_k=cfg.moe.top_k,
        num_experts=cfg.moe.num_experts, mode="vanilla",
        objective=luffy.plan_objective, exec_mode=luffy.exec_mode,
        pipeline_chunks=luffy.pipeline_chunks,
        comm_mode=luffy.comm_mode if M > 1 else "local",
        topo=topo if M > 1 else None, M=M,
        compute_dtype=cfg.compute_dtype, gpu_speed=luffy.gpu_speed,
        d_ff=cfg.moe.d_ff, hier_dedup=luffy.hier_dedup,
        chunk_overhead_ms=luffy.chunk_overhead_ms,
        wire_dtype=luffy.wire_dtype)


def precompute_prefill_plans(cfg: ModelConfig, luffy: LuffyConfig, dist,
                             batch: int, seq_len: int,
                             cache: PlanCache,
                             capacity: Optional[int] = None) -> str:
    """Warm ``cache`` with the template for one (batch, seq_len) prefill
    shape; returns the key. ``launch/serve.py --precompute-plans`` calls
    this for the shapes it is about to serve."""
    if capacity is None:
        from repro.serve_lib import prefill_capacity
        capacity = prefill_capacity(cfg, dist, batch, seq_len)
    n_seq_l, s_l, M, topo = _prefill_locals(dist, batch, seq_len)
    if M > 1:
        ma = dist.model_axis
        axes = (ma,) if isinstance(ma, str) else tuple(ma)
        comm_mode = luffy.comm_mode
    else:
        axes, comm_mode, topo = (), "local", None
    key = prefill_plan_key(cfg, luffy, dist, batch, seq_len, capacity)
    tmpl = build_plan_template(
        cfg, luffy, n_seq=n_seq_l, seq_len=s_l, capacity=capacity,
        comm_mode=comm_mode, axes=axes, topo=topo, M=M)
    cache.put(key, tmpl)
    return key


# ---------------------------------------------------------------------------
# decode templates (DESIGN.md §13)
# ---------------------------------------------------------------------------

def _decode_locals(dist, batch: int):
    """Per-device (n_seq, M, topo) split of one decode step — exactly
    what ``serve.engine.decode_step`` sees (seq_len is always 1)."""
    M = dist.model_size if dist.enabled else 1
    div = dist.batch_size_divisor if dist.enabled else 1
    n_seq_l = max(1, batch // max(1, div))
    topo = dist.topology if dist.enabled else None
    return n_seq_l, M, topo


def decode_plan_key(cfg: ModelConfig, luffy: LuffyConfig, dist,
                    batch: int, capacity: Optional[int] = None) -> str:
    """The key ``serve.engine.decode_step`` and
    ``precompute_decode_plans`` agree on; ``capacity`` defaults to the
    shared ``serve.engine.decode_capacity`` derivation. The decode
    exchange is shape-static per batch slot, so this key is constant
    across a serving run — one template serves every steady-state step."""
    if capacity is None:
        from repro.serve.engine import decode_capacity
        capacity = decode_capacity(cfg, dist, batch)
    n_seq_l, M, topo = _decode_locals(dist, batch)
    return plan_key(
        n_seq=n_seq_l, seq_len=1, d_model=cfg.d_model,
        capacity=capacity, top_k=cfg.moe.top_k,
        num_experts=cfg.moe.num_experts, mode="decode",
        objective=luffy.plan_objective, exec_mode=luffy.exec_mode,
        pipeline_chunks=luffy.pipeline_chunks,
        comm_mode=luffy.comm_mode if M > 1 else "local",
        topo=topo if M > 1 else None, M=M,
        compute_dtype=cfg.compute_dtype, gpu_speed=luffy.gpu_speed,
        d_ff=cfg.moe.d_ff, hier_dedup=luffy.hier_dedup,
        chunk_overhead_ms=luffy.chunk_overhead_ms,
        wire_dtype=luffy.wire_dtype)


def build_decode_template(cfg: ModelConfig, luffy: LuffyConfig, *,
                          n_seq: int, capacity: int,
                          comm_mode: str = "local",
                          axes: Tuple[str, ...] = (),
                          topo: Optional[Topology] = None,
                          M: int = 1) -> ExchangePlan:
    """The decode twin of :func:`build_plan_template`: one static
    template for the shape-invariant single-token exchange (seq_len 1,
    one live token per batch slot). Decode never migrates, never
    condenses and never pipelines (``plan_static_schedule`` keeps
    ``pipelined`` False under both ``sync`` and ``decode_overlap``), so
    the template is the vanilla schedule stamped ``mode="decode"`` —
    ``instantiate_decode_plan`` asserts on that stamp so a prefill
    template can never be bound to a decode shape."""
    tmpl = build_plan_template(cfg, luffy, n_seq=n_seq, seq_len=1,
                               capacity=capacity, comm_mode=comm_mode,
                               axes=axes, topo=topo, M=M)
    assert not tmpl.pipelined     # decode has no capacity to chunk
    return tmpl._replace(mode="decode")


def precompute_decode_plans(cfg: ModelConfig, luffy: LuffyConfig, dist,
                            batch: int, cache: PlanCache,
                            capacity: Optional[int] = None) -> str:
    """Warm ``cache`` with the decode template for one batch shape;
    returns the key. ``launch/serve.py --precompute-plans`` calls this
    next to the prefill warmup so steady-state decode makes zero
    ``build_exchange_plan`` calls."""
    if capacity is None:
        from repro.serve.engine import decode_capacity
        capacity = decode_capacity(cfg, dist, batch)
    n_seq_l, M, topo = _decode_locals(dist, batch)
    if M > 1:
        ma = dist.model_axis
        axes = (ma,) if isinstance(ma, str) else tuple(ma)
        comm_mode = luffy.comm_mode
    else:
        axes, comm_mode, topo = (), "local", None
    key = decode_plan_key(cfg, luffy, dist, batch, capacity)
    tmpl = build_decode_template(
        cfg, luffy, n_seq=n_seq_l, capacity=capacity,
        comm_mode=comm_mode, axes=axes, topo=topo, M=M)
    cache.put(key, tmpl)
    return key
