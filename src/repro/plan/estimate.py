"""Analytic per-phase estimates attached to an :class:`ExchangePlan`.

One exchange = dispatch all-to-all → expert FFN → combine all-to-all.
:func:`estimate_exchange` prices each phase on a :class:`~repro.comm`
``Topology`` — per-tier bytes (flat wire vs per-node-deduplicated),
bandwidth-latency phase times, and the pipelined/sync sublayer times of
the ``repro.sched.cost`` overlap model — in ONE place, so the plan
builder, ``core/commsim.py`` and the dry-run ``comm_ledger`` all report
the same numbers instead of each recomputing them (DESIGN.md §7).

Everything here is host-side float arithmetic on static shapes: an
estimate is metadata riding on the plan pytree, never traced.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

from repro.comm import dtypes as wire
from repro.comm import ledger as comm_ledger
from repro.comm.topology import Topology
from repro.sched import cost as sched_cost


class PlanEstimate(NamedTuple):
    """Per-phase byte/latency model of one exchange (all static floats).

    Byte fields are split by link tier (intra-node vs inter-node) and by
    wire format: ``flat_*`` is what a flat all-to-all ships, the unprefixed
    fields are the per-node-deduplicated hierarchical payload (equal on
    flat topologies). Times come from the same bandwidth-latency and
    3-stage overlap models the rest of the repo prices with.
    """
    intra_dispatch_bytes: float
    inter_dispatch_bytes: float
    flat_intra_dispatch_bytes: float
    flat_inter_dispatch_bytes: float
    intra_combine_bytes: float
    inter_combine_bytes: float
    dispatch_ms: float
    combine_ms: float
    flat_dispatch_ms: float
    ffn_ms: float
    sync_ms: float
    overlap_ms: float
    chunks: int
    # pipelined *dedup* wire (DESIGN.md §15): the unique-row chunks let
    # the hop's inter-node and intra-node phases overlap depth-2 within
    # the dispatch/combine stages — strictly ≤ overlap_ms on
    # hierarchical topologies. Defaulted so pre-§15 call sites and
    # serialized estimates keep their shape.
    dedup_overlap_ms: float = 0.0

    @property
    def speedup(self) -> float:
        return self.sync_ms / max(self.overlap_ms, 1e-12)


def estimate_exchange(tokens: int, top_k: int, d_model: int, *,
                      topo: Topology, r_cond: float = 0.0,
                      locality: float = 0.0, bytes_per_el: int = 4,
                      num_layers: int = 1, ffn_ms: float = 0.0,
                      chunks: Optional[int] = None, max_chunks: int = 16,
                      intra_bw: Optional[float] = None,
                      inter_bw: Optional[float] = None,
                      chunk_overhead_ms: float =
                      sched_cost.DEFAULT_CHUNK_OVERHEAD_MS,
                      wire_dtype: str = "f32") -> PlanEstimate:
    """Price one exchange of ``tokens`` × ``top_k`` dispatch rows.

    ``r_cond`` removes condensed tokens before dispatch; ``locality``
    scales the combine payload by the migration locality gain (rows whose
    new home is their expert device never cross the wire). ``ffn_ms`` is
    the modeled expert-FFN stage the pipeline overlaps against; with
    ``chunks=None`` the 1..``max_chunks`` planning optimum is searched,
    otherwise the given (executor-clipped) chunk count is priced.
    ``intra_bw``/``inter_bw`` override the topology's link bandwidths —
    commsim passes its *calibrated* effective bandwidth here.
    ``wire_dtype`` prices the compressed wire (DESIGN.md §14) by
    scaling the effective bytes-per-element by the exact per-row
    compression factor :func:`repro.comm.dtypes.wire_precision`, so
    every byte field here — and everything downstream that reads them
    (dryrun ledger, commsim, objectives, autotune) — shrinks by
    exactly ``1/precision`` without any second pricing source.
    """
    wire_bpe = bytes_per_el / wire.wire_precision(d_model, wire_dtype,
                                                  bytes_per_el)
    fi, fe = comm_ledger.dispatch_bytes(
        tokens, top_k, d_model, topo=topo, r_cond=r_cond,
        bytes_per_el=wire_bpe, num_layers=num_layers, dedup=False)
    hi, he = comm_ledger.dispatch_bytes(
        tokens, top_k, d_model, topo=topo, r_cond=r_cond,
        bytes_per_el=wire_bpe, num_layers=num_layers, dedup=True)
    ci, ce = hi * (1.0 - locality), he * (1.0 - locality)
    bw_i = intra_bw if intra_bw is not None else topo.intra_bw
    bw_e = inter_bw if inter_bw is not None else topo.inter_bw

    def phase_ms(intra_bytes: float, inter_bytes: float) -> float:
        mi, me = comm_ledger.phase_messages(topo)
        return (intra_bytes / bw_i + inter_bytes / bw_e
                + mi * topo.intra_lat + me * topo.inter_lat) * 1e3

    d_ms = phase_ms(hi, he)
    c_ms = phase_ms(ci, ce)
    kw = dict(dispatch_ms=d_ms, ffn_ms=ffn_ms, combine_ms=c_ms,
              chunk_overhead_ms=chunk_overhead_ms)
    if chunks is None:
        n, t_pipe = sched_cost.optimal_chunks(topo, max_chunks=max_chunks,
                                              **kw)
    else:
        n = max(1, int(chunks))
        t_pipe = sched_cost.overlap_ms(topo, n, **kw)
    # pipelined dedup wire: price the hop phases separately so the
    # intra-node fan-out / pre-reduce can hide behind the next chunk's
    # inter-node hop (sched_cost.dedup_overlap_ms, DESIGN.md §15)
    mi, me = comm_ledger.phase_messages(topo)
    t_dedup = sched_cost.dedup_overlap_ms(
        topo, n,
        dispatch_inter_ms=(he / bw_e + me * topo.inter_lat) * 1e3,
        dispatch_intra_ms=(hi / bw_i + mi * topo.intra_lat) * 1e3,
        ffn_ms=ffn_ms,
        combine_inter_ms=(ce / bw_e + me * topo.inter_lat) * 1e3,
        combine_intra_ms=(ci / bw_i + mi * topo.intra_lat) * 1e3,
        chunk_overhead_ms=chunk_overhead_ms)
    return PlanEstimate(
        intra_dispatch_bytes=hi, inter_dispatch_bytes=he,
        flat_intra_dispatch_bytes=fi, flat_inter_dispatch_bytes=fe,
        intra_combine_bytes=ci, inter_combine_bytes=ce,
        dispatch_ms=d_ms, combine_ms=c_ms,
        flat_dispatch_ms=phase_ms(fi, fe),
        ffn_ms=ffn_ms, sync_ms=sched_cost.sync_ms(topo, **kw),
        overlap_ms=t_pipe, chunks=n, dedup_overlap_ms=t_dedup)


# ---------------------------------------------------------------------------
# planning-cost model (plan lifecycle, DESIGN.md §9)
# ---------------------------------------------------------------------------

# Modeled per-slot latency of one migration-greedy iteration. The greedy
# (core/migration.py Algorithm 1) is a SEQUENTIAL lax.scan over global
# sequence slots — on accelerators its cost is dominated by the
# serialized scan-step latency, not flops, so the model is linear in
# n_slots with a small per-candidate-device term.
PLAN_STEP_US = 2.0
PLAN_DEVICE_US = 0.02
# Modeled cost of one signature revalidation: an elementwise compare of
# the [n_slots, M] counts (+ lens) against the carried expectation.
REVALIDATE_US = 1.0
REVALIDATE_PER_EL_US = 1e-3


def estimate_planning_ms(n_slots: int, M: int, *, q: int = 3,
                         step_us: float = PLAN_STEP_US) -> float:
    """Modeled wall time (ms) of ONE full migration replan on
    ``n_slots`` global sequence slots over ``M`` devices — what the
    plan-reuse fast path saves per revalidated sublayer. Host-side
    model; the dryrun ``comm_ledger.plan_reuse`` section and
    ``benchmarks/fig_plan_reuse.py`` both report from it."""
    return n_slots * (step_us + PLAN_DEVICE_US * M * max(1, q)) * 1e-3


def estimate_revalidate_ms(n_slots: int, M: int) -> float:
    """Modeled wall time (ms) of one routing-signature compare (the
    price of reuse; orders of magnitude under a replan)."""
    return (REVALIDATE_US + REVALIDATE_PER_EL_US * n_slots * (M + 1)) \
        * 1e-3


def replica_consistency_ms(n_replicas: int, d_model: int, d_ff: int, *,
                           topo: Topology,
                           bytes_per_el: int = 4) -> float:
    """Per-step price of keeping ``n_replicas`` intra-node expert
    replicas consistent (HierMoE-style replication, DESIGN.md §15).

    Each replica costs, per step, the forward weight fan-in (the host
    reads the owner's 3 FFN matrices over the intra-node links) plus
    the gradient psum between replica and owner (2× the weight bytes
    for the reduce+broadcast ring) — replicas are *always* intra-node,
    so only the cheap links are priced. This is the cost side the
    "replicate" planner objective weighs against the modeled hot-expert
    serialization relief (``repro.plan.objectives.plan_expert_replicas``).
    """
    if topo is None or n_replicas <= 0:
        return 0.0
    w_bytes = 3.0 * float(d_model) * float(d_ff) * bytes_per_el
    return n_replicas * 3.0 * w_bytes / topo.intra_bw * 1e3


def estimate_similarity_ms(measured_pairs: float, d_model: int, *,
                           speed: float = 1e13) -> float:
    """Modeled wall time (ms) of one condensation similarity build: the
    masked Gram matmul costs ``2·d`` MACs per measured pair (DESIGN.md
    §10) — the quantity a similarity backend (``repro.condense``) or a
    reused condense plan saves. Pair counts come from the backend's
    analytic model (``expected_measured_pairs``) or the traced
    ``measured_pairs`` ledger."""
    return measured_pairs * 4.0 * d_model / speed * 1e3
