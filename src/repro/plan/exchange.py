"""Plan/execute split for the MoE exchange (DESIGN.md §7).

Every decision about one expert-parallel exchange — routing, the
condensation map (§V), the migration assignment (§IV), the pipeline
chunk schedule (§6) and the per-phase cost estimates — is materialized
as ONE frozen record, :class:`ExchangePlan`, by
:func:`build_exchange_plan`; :func:`execute_plan` is a thin executor
that moves the bytes the plan prescribes. ``core/moe_layer.moe_core``
is build + execute and nothing else, so the train forward, the serving
prefill path and any future consumer share the same decisions and the
same executor, and planning policy (``LuffyConfig.plan_objective``,
:mod:`repro.plan.objectives`) is swappable without touching execution.

Both halves run *inside* the same ``shard_map`` trace: the plan's array
fields are per-device traced values (replicated where they must agree,
e.g. the migration permutation), its static fields (mode, capacity,
chunk schedule, comm context, estimates) are fixed at trace time.
Splitting a pure computation into two functions does not change any
value's defining subgraph, so build + execute is bit-identical to the
fused pre-split ``moe_core`` (tested: ``tests/test_plan.py``).

Plan lifecycle (DESIGN.md §9): plans are also *reused*. Inside a layer
scan, :func:`build_exchange_plan` takes ``reuse_from`` (a prior plan or
its :class:`PlanSignature`) and, under ``LuffyConfig.plan_reuse``,
revalidates the carried decision with a cheap routing-signature compare
instead of re-running the migration greedy; on the serving path,
:func:`instantiate_plan` binds fresh routing onto a cached static
template (``repro.plan.cache``) without any planning at all.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.comm import CommContext, compat
from repro.comm import dtypes as wdt
from repro.comm import ledger as comm_ledger
from repro.condense import plan as cplan
from repro.condense import wire as cwire
from repro.condense.plan import (CondenseCarry, CondensePlan,
                                 identity_condense_plan, uncondense)
from repro.config import LuffyConfig, ModelConfig
from repro.core import migration as mig
from repro.core.gating import GateOutput, dispatch_positions
from repro.obs import trace as obs_trace
from repro.plan import objectives
from repro.plan.estimate import PlanEstimate, estimate_exchange
from repro.sched import (ChunkPlan, plan_chunks, plan_unique_chunks,
                         run_pipeline)
from repro.sched.cost import resolve_chunk_overhead_ms

Array = jnp.ndarray

# Fallback chunk count when the objective-planned search has no topology
# to price against (mirrors the historical --pipeline-chunks default).
DEFAULT_PIPELINE_CHUNKS = 4

# Trace-time planning-call counter: incremented once per
# build_exchange_plan call. The serving cache's zero-planning guarantee
# is asserted against it (a warm PlanCache prefill must not move it).
BUILD_CALLS = 0


class MoEAux(NamedTuple):
    aux_loss: Array
    dispatch_drop: Array      # fraction of kept rows dropped at dispatch
    combine_drop: Array       # fraction of rows dropped at combine regroup
    condense_rate: Array      # fraction of tokens condensed
    local_frac: Array         # fraction of combine rows staying on-device
    traffic_before: Array     # plan ledger (link-cost-weighted tokens
    traffic_after: Array      # crossing devices, without/with migration)
    inter_bytes_flat: Array   # dispatch bytes a flat a2a ships across nodes
    inter_bytes_dedup: Array  # modeled bytes after per-node dedup (what
                              # the hier dedup wire ships, in every mode)
    plans_built: Array        # plan-reuse ledger (DESIGN.md §9): 1 when
    plans_reused: Array       # the full migration planner ran / when a
    reuse_mismatch: Array     # carried plan revalidated / when a carried
                              # plan FAILED revalidation (and was rebuilt)
    measured_pairs: Array     # condensation ledger (DESIGN.md §10): pairs
                              # the similarity backend actually measured
    condense_built: Array     # 1 when the similarity build ran / when a
    condense_reused: Array    # carried condense plan was reused instead
    inter_bytes_shipped: Array  # bytes the dedup wire ACTUALLY shipped
                                # across nodes (0 on the dense wire);
                                # equals inter_bytes_dedup when active

N_AUX = len(MoEAux._fields)


class PlanSignature(NamedTuple):
    """Routing signature a carried plan revalidates against.

    ``counts``/``lens`` are the migration planner's inputs *expected at
    the next exchange* — the gathered per-(global slot, device) expert
    counts and sequence lengths, rows permuted into the post-migration
    slot layout (``next_signature``). The greedy is deterministic in
    these inputs, so observed == expected implies the planner would keep
    every sequence at its current home and the greedy can be skipped
    (``repro.core.migration.home_plan``). ``valid`` > 0.5 marks that a
    plan was actually built (the first MoE sublayer seeds it).
    """
    counts: Array             # [n_slots, M] f32 expected planner counts
    lens: Array               # [n_slots] f32 expected sequence lengths
    valid: Array              # [] f32 — 1.0 once a plan has been built


def routing_signature_matches(sig: PlanSignature, counts, lens):
    """Cheap revalidation: observed planner inputs == expected. numpy in
    -> host bool, jnp in -> traced bool (both backends share this exact
    predicate; ``benchmarks/fig_plan_reuse.py`` drives the host side)."""
    if (tuple(sig.counts.shape) != tuple(counts.shape)
            or tuple(sig.lens.shape) != tuple(lens.shape)):
        return (jnp.bool_(False) if isinstance(counts, jnp.ndarray)
                else False)
    xp = jnp if isinstance(counts, jnp.ndarray) else np
    same = xp.all(sig.counts == counts) & xp.all(sig.lens == lens)
    return (sig.valid > 0.5) & same


def next_signature(counts, lens, perm) -> PlanSignature:
    """Expected planner inputs after executing a plan with ``perm``:
    the slot at ``perm[i]`` next holds the sequence whose counts/lens
    sit in row ``i`` today. numpy/jnp agnostic."""
    xp = jnp if isinstance(counts, jnp.ndarray) else np
    n = counts.shape[0]
    ar = xp.arange(n, dtype=xp.int32)
    if xp is jnp:
        inv = jnp.zeros((n,), jnp.int32).at[perm].set(ar)
    else:
        inv = np.zeros(n, np.int32)
        inv[np.asarray(perm)] = ar
    one = jnp.float32(1.0) if xp is jnp else np.float32(1.0)
    return PlanSignature(counts[inv], lens[inv], one)


def invalid_signature(n_slots: int, M: int) -> PlanSignature:
    """Fixed-shape 'no carried plan' signature (scan carries need a
    uniform pytree even on sublayers that plan nothing)."""
    return PlanSignature(jnp.zeros((n_slots, M), jnp.float32),
                         jnp.zeros((n_slots,), jnp.float32),
                         jnp.float32(0.0))


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    v = jnp.mean(xf * xf, -1, keepdims=True)
    return (xf * jax.lax.rsqrt(v + eps) * scale.astype(jnp.float32))


def expert_ffn(ew, h, act, compute_dtype, use_kernel: bool = False):
    """h: [E_local, R, d] normed inputs -> [E_local, R, d]."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.expert_ffn(h, ew["w_up"], ew["w_gate"], ew["w_down"], act)
    cdt = compute_dtype
    hc = h.astype(cdt)
    up = jnp.einsum("erd,edf->erf", hc, ew["w_up"].astype(cdt))
    gt = jnp.einsum("erd,edf->erf", hc, ew["w_gate"].astype(cdt))
    hh = act(gt) * up
    return jnp.einsum("erf,efd->erd", hh, ew["w_down"].astype(cdt))


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

class ExchangePlan(NamedTuple):
    """Every decision about one exchange, as data.

    Static fields (python values, fixed at trace time) describe *how* to
    execute; traced fields describe *what* the router/condenser/planner
    decided for this step's tokens. ``estimate`` carries the analytic
    per-phase byte/latency model (None on single-device / unknown
    topologies) — dry-run ledgers and commsim report off it.
    """
    # -- static decisions ---------------------------------------------------
    mode: str                     # "vanilla" | "migrate"
    migrate: bool                 # mode == "migrate" and active (M > 1)
    condense: bool                # condensation active this call
    pipelined: bool               # chunked software pipeline vs sync
    capacity: int                 # per-(source, expert) dispatch capacity
    chunks: ChunkPlan             # capacity partition (1 chunk = sync)
    comm: CommContext             # collective strategy (never None)
    objective: str                # planner objective that produced this
    group_size: int               # condensation group G
    combine_slack: float          # migrate-mode combine buffer slack
    use_kernel: bool
    wire: str                     # "dense" | "dedup" (repro.condense.wire)
    wire_dtype: str               # "f32" | "bf16" | "f8e4m3" — precision
                                  # rows ship at across nodes (DESIGN §14)
    estimate: Optional[PlanEstimate]
    # -- routing (traced) ---------------------------------------------------
    expert_idx: Array             # [T, k] global expert ids
    gate_weights: Array           # [T, k] combine weights
    positions: Array              # [T, k] dispatch buffer positions
    valid: Array                  # [T, k] row takes a dispatch slot
    aux_loss: Array               # [] router load-balance loss
    dispatch_drop: Array          # [] fraction of kept rows dropped
    # -- condensation (repro.condense, DESIGN.md §10) -----------------------
    condense_plan: CondensePlan   # rep map, sim history, reuse signature
    # -- migration assignment ----------------------------------------------
    dest_global: Array            # [n_seq] new global slot per local slot
    traffic_before: Array         # [] weighted combine rows, identity plan
    traffic_after: Array          # [] weighted combine rows, this plan
    # -- traced wire ledger -------------------------------------------------
    inter_bytes_flat: Array
    inter_bytes_dedup: Array
    # -- plan lifecycle (DESIGN.md §9) --------------------------------------
    # signature: expected NEXT-exchange planner inputs (None when reuse
    # is off / nothing was planned); counters feed the MoEAux ledger.
    signature: Optional[PlanSignature] = None
    plans_built: Optional[Array] = None
    plans_reused: Optional[Array] = None
    reuse_mismatch: Optional[Array] = None
    # -- expert replication (objective "replicate", DESIGN.md §15) ----------
    # Frozen placement-cardinality decision: each device owns one extra
    # dispatch lane that can serve a replica of an intra-node peer's hot
    # expert. None (the default, and every other objective) = no lanes —
    # the executor's dense layout is unchanged.
    replica_src: Optional[Array] = None    # [M] int32 global expert id the
                                           # device's replica lane serves
                                           # (-1 = idle lane)
    replica_valid: Optional[Array] = None  # [T, k] bool — overflow copies
                                           # redirected to their expert's
                                           # replica lane

    # historical accessors — the condensation map now lives in the
    # nested CondensePlan (kept so call sites and tests read naturally)
    @property
    def rep_idx(self) -> Array:
        return self.condense_plan.rep_idx

    @property
    def s_next(self) -> Optional[Array]:
        return self.condense_plan.s_next

    @property
    def condense_rate(self) -> Array:
        return self.condense_plan.rate


class ExchangeAux(NamedTuple):
    """Executor outputs riding alongside ``y``."""
    sideband: Dict[str, Array]    # per-sequence state at its (new) home
    s_next: Optional[Array]       # similarity history (migrated if needed)
    moe: MoEAux
    cond_carry: Optional[Dict[str, Array]] = None
    # condense-reuse state for the next sublayer (DESIGN.md §10):
    # {"rep" [n_seq,S], "cexp" [n_seq,S], "age" [n_seq], "valid" [n_seq]}
    # — migrated to the sequences' new homes alongside the sideband
    wire_ef: Optional[Array] = None
    # lossy-wire error-feedback residual for the next step (§15):
    # [n_seq, S, d] f32, keyed by (slot, position), stop-gradded


# ---------------------------------------------------------------------------
# static schedule (shared by build_exchange_plan and the plan cache)
# ---------------------------------------------------------------------------

def plan_static_schedule(cfg: ModelConfig, luffy: LuffyConfig, topo, M: int,
                         T: int, d: int, capacity: int, bytes_per_el: int,
                         wire_dtype: str = "f32"
                         ) -> Tuple[bool, ChunkPlan, Optional[PlanEstimate]]:
    """All shape-keyed (token-independent) schedule decisions of one
    exchange: pipelined?, the :class:`ChunkPlan`, and the analytic
    :class:`PlanEstimate`. Host-side pure — ``repro.plan.cache`` builds
    ahead-of-time templates from exactly this function, so a cached
    template's schedule is identical to what ``build_exchange_plan``
    would decide for the same static key.

    ``luffy.pipeline_chunks <= 0`` requests the objective-planned chunk
    count (ROADMAP item): ``estimate_exchange(chunks=None)``'s existing
    1..16 search picks ``ChunkPlan.n_chunks`` instead of the CLI
    constant (an explicit positive CLI value still overrides).
    """
    m = cfg.moe
    pipelined = luffy.exec_mode == "pipeline" and M > 1
    # "decode_overlap" only reschedules the decode combine psum
    # (DESIGN.md §13); on the build/execute path it prices and chunks
    # exactly like sync.
    assert luffy.exec_mode in ("sync", "pipeline", "decode_overlap"), \
        luffy.exec_mode
    priced = topo is not None and M > 1
    ffn_ms = 0.0
    if priced:
        ffn_rows = m.num_experts * capacity   # static rows (M*C*E_local)
        # 4·d·d_ff flops/row (up+down matmuls) — the repo-wide pricing
        # convention (commsim._expert_flops, dryrun ledger, objective
        # sweep); gate matmuls are deliberately excluded everywhere so
        # objective decisions stay consistent with the calibrated model
        ffn_ms = ffn_rows * 4.0 * d * m.d_ff / luffy.gpu_speed * 1e3
    # per-chunk overhead: the measured fit when calibration set one
    # (repro.obs.calibrate via LuffyConfig), the constant otherwise
    o_ms = resolve_chunk_overhead_ms(luffy.chunk_overhead_ms)
    req = luffy.pipeline_chunks if pipelined else 1
    if pipelined and req <= 0:
        if priced:
            req = estimate_exchange(T, m.top_k, d, topo=topo,
                                    bytes_per_el=bytes_per_el,
                                    ffn_ms=ffn_ms, chunks=None,
                                    chunk_overhead_ms=o_ms,
                                    wire_dtype=wire_dtype).chunks
        else:
            req = DEFAULT_PIPELINE_CHUNKS   # nothing to price against
    chunks = plan_chunks(capacity, req)
    est = None
    if priced:
        est = estimate_exchange(T, m.top_k, d, topo=topo,
                                bytes_per_el=bytes_per_el, ffn_ms=ffn_ms,
                                chunks=chunks.n_chunks,
                                chunk_overhead_ms=o_ms,
                                wire_dtype=wire_dtype)
    return pipelined, chunks, est


# ---------------------------------------------------------------------------
# build
# ---------------------------------------------------------------------------

def build_exchange_plan(gate: GateOutput, xn: Array, cfg: ModelConfig,
                        luffy: LuffyConfig, comm: CommContext, *,
                        mode: str, capacity: int,
                        sideband: Dict[str, Array],
                        threshold=None, s_prev: Optional[Array] = None,
                        group_size: int = 128, combine_slack: float = 1.0,
                        use_kernel: bool = False,
                        reuse_from: Optional[Union["ExchangePlan",
                                                   PlanSignature]] = None,
                        condense_reuse_from: Optional[CondenseCarry] = None
                        ) -> ExchangePlan:
    """Decide one exchange: condensation map, dispatch slots/drops, the
    migration assignment (via the ``luffy.plan_objective`` registry
    entry), the chunk schedule, and the analytic phase estimates.

    gate: router output over ``xn`` [T, d] (normed tokens, T = n_seq*S);
    sideband must hold ``seq_len`` [n_seq]. Pure function of the routing
    — no payload bytes move here.

    reuse_from (DESIGN.md §9): a prior :class:`ExchangePlan` (or its
    :class:`PlanSignature`) from an earlier sublayer of the same
    forward. Under ``luffy.plan_reuse="signature"`` the carried decision
    is revalidated with the routing-signature compare and, on a match,
    the migration greedy is skipped — the sequences already sit where a
    replan would put them, so the emitted plan (``home_plan``) is
    bit-identical to what the full planner would return. On a mismatch
    the stale plan is discarded and a full replan runs (counted in
    ``reuse_mismatch``). ``"always"`` skips revalidation entirely
    (trusted reuse; forward outputs may then differ from ``"off"``).
    """
    global BUILD_CALLS
    BUILD_CALLS += 1
    m = cfg.moe
    T, d = xn.shape
    n_seq = sideband["seq_len"].shape[0]
    S = T // n_seq
    E = m.num_experts
    M = comm.size()
    assert E % M == 0, (E, M)
    E_local = E // M
    my = comm.index()
    C = capacity
    expert_idx, gate_w = gate.expert_idx, gate.gate_weights   # [T,k]

    # token validity (length padding)
    pos_in_seq = jnp.tile(jnp.arange(S, dtype=jnp.int32)[None], (n_seq, 1))
    token_valid = (pos_in_seq < sideband["seq_len"][:, None]).reshape(T)
    keep = jnp.tile(token_valid[:, None], (1, m.top_k))

    # ---- token condensation (§V, repro.condense) -------------------------
    do_condense = luffy.enable_condensation and mode != "decode"
    if do_condense:
        with obs_trace.phase("condense") as _sp:
            cp = cplan.build_condense_plan(
                xn, expert_idx[:, 0], threshold, group_size=group_size,
                s_prev=(None if s_prev is None
                        else s_prev.reshape(-1, group_size, group_size)),
                s1=luffy.s1, s2=luffy.s2, use_kernel=use_kernel,
                backend=luffy.similarity_backend, lsh_bits=luffy.lsh_bits,
                lsh_seed=luffy.lsh_seed, carry=condense_reuse_from,
                reuse_mode=luffy.condense_reuse,
                max_age=luffy.condense_reuse_max_age)
            cp = _sp.fence(cp)
        keep = keep & cp.is_rep[:, None]
    else:
        cp = identity_condense_plan(T, backend=luffy.similarity_backend)

    # ---- dispatch positions & drops --------------------------------------
    pos = dispatch_positions(expert_idx, keep, E)             # [T,k]
    valid = keep & (pos < C)
    kept = jnp.sum(keep.astype(jnp.float32))
    d_drop = 1.0 - jnp.sum(valid.astype(jnp.float32)) / jnp.maximum(kept, 1.0)

    # ---- execution schedule + phase estimates ----------------------------
    from repro.models.blocks import _dtype
    cdt = _dtype(cfg.compute_dtype)
    topo = comm.topology
    wire_dtype = wdt.validate_wire_dtype(luffy.wire_dtype)
    pipelined, chunks, est = plan_static_schedule(
        cfg, luffy, topo, M, T, d, C,
        bytes_per_el=jnp.dtype(cdt).itemsize, wire_dtype=wire_dtype)

    # ---- wire format (DESIGN.md §10, §15) --------------------------------
    # universal: the dedup wire now applies in EVERY mode — migrate-mode
    # combine re-addresses through the dest-keyed map and pipelined
    # execution chunks the unique-row capacity (§15), so only the comm
    # strategy gates it
    wire = ("dedup" if (luffy.hier_dedup == "on" and comm.mode == "hier"
                        and M > 1) else "dense")

    # ---- hot-expert replication (objective "replicate", DESIGN.md §15) ---
    # HierMoE-style placement cardinality: replicate each node's hottest
    # expert onto an intra-node peer's spare dispatch lane when the
    # modeled serialization relief beats the replica-consistency psum.
    # The dedup wire takes precedence (its unique-row packing already
    # removes the duplicate bytes the replica would shortcut); the
    # migration half of the objective still runs below.
    replica_src = replica_valid = None
    lane = (luffy.plan_objective == "replicate" and mode == "migrate"
            and luffy.enable_migration and M > 1 and wire == "dense"
            and topo is not None and topo.hierarchical
            and topo.devices_per_node > 1)
    if lane:
        ohe = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32) \
            * keep[..., None].astype(jnp.float32)
        # demand per expert (pre-drop: replication exists to relieve the
        # overflow the capacity bound is about to drop), psum-replicated
        # so every device freezes the SAME placement
        load_e = jax.lax.psum(ohe.sum((0, 1)), comm.axis_name)    # [E]
        replica_src = objectives.plan_expert_replicas(
            load_e, e_local=E_local, topo=topo,
            ffn_ms=(0.0 if est is None else est.ffn_ms),
            d_model=d, d_ff=m.d_ff,
            bytes_per_el=jnp.dtype(cdt).itemsize)
        host_of = jnp.full((E,), -1, jnp.int32).at[
            jnp.where(replica_src >= 0, replica_src, 0)].max(
            jnp.where(replica_src >= 0,
                      jnp.arange(M, dtype=jnp.int32), -1), mode="drop")
        # redirect rule: first-overflow copies (C <= pos < 2C) of a
        # replicated expert take slot pos - C on the host's replica lane
        # — strictly fewer drops; rows with pos < C are untouched, so
        # the lane-less layout is bit-identical where it was valid
        replica_valid = keep & (pos >= C) & (pos < 2 * C) \
            & (host_of[expert_idx] >= 0)
        d_drop = 1.0 - (jnp.sum(valid.astype(jnp.float32))
                        + jnp.sum(replica_valid.astype(jnp.float32))) \
            / jnp.maximum(kept, 1.0)

    # ---- inter-node traffic ledger (DESIGN.md §5) ------------------------
    # redirected replica rows count too: the host sits on the owner's
    # node, so expert_idx still keys the destination node correctly
    v_ledger = valid if replica_valid is None else (valid | replica_valid)
    if topo is not None and topo.hierarchical and M > 1:
        row_bytes = float((d + 2) * jnp.dtype(cdt).itemsize)
        ib_flat, ib_dedup = comm_ledger.dispatch_node_ledger(
            expert_idx, v_ledger, my, e_local=E_local, topo=topo,
            row_bytes=row_bytes)
        if comm.mode != "hier":
            ib_dedup = ib_flat      # the flat path ships every copy
    else:
        ib_flat = ib_dedup = jnp.float32(0.0)

    # ---- migration plan (§IV) — BEFORE dispatch so combine can be
    # re-addressed. Replicated within the model row. -----------------------
    migrate = (mode == "migrate") and luffy.enable_migration and M > 1
    reuse_mode = luffy.plan_reuse
    reuse_enabled = reuse_mode != "off"
    z = jnp.float32(0.0)
    built = reused = mismatch = z
    sig_out: Optional[PlanSignature] = None
    if migrate:
        n_slots = M * n_seq
        dev_of_e = expert_idx // E_local                      # [T,k]
        oh = jax.nn.one_hot(dev_of_e, M, dtype=jnp.float32) \
            * valid[..., None].astype(jnp.float32)
        counts_local = oh.reshape(n_seq, S, m.top_k, M).sum((1, 2))  # [n_seq,M]
        counts_g = jax.lax.all_gather(counts_local, comm.axis_name, axis=0,
                                      tiled=True)             # [M*n_seq, M]
        lens_g = jax.lax.all_gather(sideband["seq_len"], comm.axis_name,
                                    axis=0, tiled=True)       # [M*n_seq]
        lens_f = lens_g.astype(jnp.float32)
        o_ms = resolve_chunk_overhead_ms(luffy.chunk_overhead_ms)
        octx = objectives.ObjectiveContext(topo=topo,
                                           chunk_overhead_ms=o_ms)
        if est is not None:
            octx = objectives.ObjectiveContext(
                topo=topo, ffn_ms=est.ffn_ms,
                dispatch_intra_ms=est.intra_dispatch_bytes
                / topo.intra_bw * 1e3,
                dispatch_inter_ms=est.inter_dispatch_bytes
                / topo.inter_bw * 1e3,
                chunks=chunks.n_chunks,
                row_bytes=float(d * jnp.dtype(cdt).itemsize),
                chunk_overhead_ms=o_ms)

        def _replan(cg, lf):
            return tuple(objectives.plan_migration_with_objective(
                cg, lf, n_seq, objective=luffy.plan_objective, ctx=octx,
                q=luffy.q, d_model=d, speed=luffy.gpu_speed))

        sig_in = None
        if reuse_from is not None:
            sig_in = (reuse_from.signature
                      if isinstance(reuse_from, ExchangePlan)
                      else reuse_from)
        # Reuse is sound only under the "traffic" objective: its greedy
        # re-derives the executed placement from a matching signature.
        # The "overlap" portfolio may execute the exposure candidate,
        # which the next frame's greedy would NOT re-derive — so other
        # objectives emit carries that never validate (below), and the
        # cond machinery is still built for them, keeping the compiled
        # graph identical across objectives and plan_reuse modes.
        reuse_capable = luffy.plan_objective == "traffic"
        if sig_in is not None:
            # The cond machinery is built whenever a carry is threaded —
            # for plan_reuse="off" too, with the carried ``valid`` pinned
            # to 0.0 so revalidation never fires at runtime. Rationale:
            # the greedy has float near-ties, so two *structurally
            # different* compiled graphs may pick different (equally
            # valid) plans; keeping "off" and "signature" graphs
            # identical makes their forwards bit-comparable, which is
            # the reuse correctness guarantee the tests assert.
            have = sig_in.valid > 0.5
            if reuse_mode == "always":
                match = have
            else:                                   # "off" | "signature"
                same = routing_signature_matches(sig_in, counts_g, lens_f)
                match = have & same
                mismatch = (have & ~same).astype(jnp.float32)
            lc_np = objectives.traffic_link_cost(topo)
            lc = None if lc_np is None else jnp.asarray(lc_np, jnp.float32)

            def _reuse(cg, lf):
                # signature matched: the (deterministic) greedy would
                # re-derive the current placement, so skip it and emit
                # the home plan with the exact same traffic ledger
                return tuple(mig.home_plan(cg, n_seq, link_cost=lc))

            mplan = mig.MigrationPlan(*jax.lax.cond(
                match, _reuse, _replan, counts_g, lens_f))
            mf = match.astype(jnp.float32)
            built, reused = 1.0 - mf, mf
        else:
            mplan = mig.MigrationPlan(*_replan(counts_g, lens_f))
            built = jnp.float32(1.0)
        my_slots = my * n_seq + jnp.arange(n_seq, dtype=jnp.int32)
        dest_global = mplan.perm[my_slots]                    # [n_seq]
        t_before, t_after = mplan.traffic_before, mplan.traffic_after
        if reuse_enabled or sig_in is not None:
            sig_out = next_signature(counts_g, lens_f, mplan.perm)
            if not (reuse_enabled and reuse_capable):
                # "off", or an objective that cannot soundly reuse:
                # the carry never revalidates (always replans)
                sig_out = sig_out._replace(valid=jnp.float32(0.0))
    else:
        dest_global = my * n_seq + jnp.arange(n_seq, dtype=jnp.int32)
        t_before = t_after = jnp.float32(0.0)
    if sig_out is None and (reuse_enabled or reuse_from is not None):
        # fixed-shape carry even when nothing was planned (vanilla mode,
        # single device): an invalid signature that never revalidates
        sig_out = invalid_signature(M * n_seq, M)

    return ExchangePlan(
        mode=mode, migrate=migrate, condense=do_condense,
        pipelined=pipelined, capacity=C, chunks=chunks, comm=comm,
        objective=luffy.plan_objective, group_size=group_size,
        combine_slack=combine_slack, use_kernel=use_kernel, wire=wire,
        wire_dtype=wire_dtype, estimate=est,
        expert_idx=expert_idx, gate_weights=gate_w, positions=pos,
        valid=valid, aux_loss=gate.aux_loss, dispatch_drop=d_drop,
        condense_plan=cp,
        dest_global=dest_global, traffic_before=t_before,
        traffic_after=t_after, inter_bytes_flat=ib_flat,
        inter_bytes_dedup=ib_dedup, signature=sig_out,
        plans_built=built, plans_reused=reused, reuse_mismatch=mismatch,
        replica_src=replica_src, replica_valid=replica_valid)


# ---------------------------------------------------------------------------
# execute
# ---------------------------------------------------------------------------

def execute_plan(params, x: Array, sideband: Dict[str, Array],
                 plan: ExchangePlan, cfg: ModelConfig, *,
                 wire_ef: Optional[Array] = None
                 ) -> Tuple[Array, ExchangeAux]:
    """Move the bytes the plan prescribes: pack dispatch buffers, run the
    (optionally pipelined) dispatch → expert FFN → combine exchange,
    regroup/un-condense, apply shared experts. No decisions are made
    here — the plan is the single source of truth, so the train forward
    and the serving prefill execute identically.

    x: [n_seq, S, d] pre-norm hidden. Returns ``(y, ExchangeAux)``; in
    vanilla mode ``y = x + moe_delta``, in migrate mode ``y`` is the full
    post-block hidden materialized at *new* slots.

    ``wire_ef`` (DESIGN.md §15): the carried positional error-feedback
    residual for a lossy wire, [n_seq, S, d] f32. It is added to the
    *shipped payload only* — the residual connection, the router and
    the aux ledger all keep the exact hidden — and the new residual
    ``payload - dequant(quant(payload))`` is returned on
    ``ExchangeAux.wire_ef`` for the caller to carry into the NEXT
    step's payload at the same (slot, position). Quantization is
    per-row, so the token-major residual computed here equals the
    residual of every shipped copy of that row.
    """
    from repro.models.blocks import _act, _dtype
    m = cfg.moe
    cdt = _dtype(cfg.compute_dtype)
    act = _act(cfg.act)
    n_seq, S, d = x.shape
    T = n_seq * S
    E = m.num_experts
    comm = plan.comm
    M = comm.size()
    E_local = E // M
    my = comm.index()
    C = plan.capacity
    migrate = plan.migrate
    use_kernel = plan.use_kernel
    group_size = plan.group_size
    expert_idx, gate_w = plan.expert_idx, plan.gate_weights
    pos, valid = plan.positions, plan.valid
    rep_idx, s_next = plan.rep_idx, plan.s_next
    dest_global = plan.dest_global

    xf = x.reshape(T, d)

    # ---- lossy-wire error feedback (DESIGN.md §15) -----------------------
    # x_pay is what the dispatch buffers carry; xf stays exact for the
    # residual connection. The new residual is stop-gradded state, not a
    # differentiable path.
    x_pay = xf
    ef_next = None
    if wire_ef is not None:
        x_pay = xf + wire_ef.reshape(T, d).astype(xf.dtype)
        if plan.wire_dtype != "f32" and M > 1:
            pc = x_pay.astype(cdt)
            q_ef, sc_ef = wdt.quantize_rows(pc, plan.wire_dtype)
            deq_ef = wdt.dequantize_rows(q_ef, sc_ef, cdt, d)
            ef_next = jax.lax.stop_gradient(
                (pc - deq_ef).astype(jnp.float32).reshape(n_seq, S, d))
        else:       # exact wire (or nothing crosses it): residual dies
            ef_next = jnp.zeros((n_seq, S, d), jnp.float32)

    def _finish(y_tok, new_sideband, s_next, c_drop, local_frac, shipped):
        """Shared executor tail: un-condense (token_to_token, §VI), the
        condense-reuse carry (migrated with sequences), shared experts
        and the aux ledger."""
        cpn = plan.condense_plan
        carry_sig = cpn.signature if plan.condense else None
        cexp_sb = age_sb = valid_sb = None
        rep_carry = None
        if carry_sig is not None:
            cexp_sb = carry_sig.expert.reshape(n_seq, S).astype(jnp.int32)
            age_sb, valid_sb = carry_sig.age, carry_sig.valid
        if plan.condense:
            if not migrate:
                y_tok = uncondense(y_tok, rep_idx)
                rep_carry = (rep_idx % group_size).reshape(n_seq, S)
            else:
                # rep map (and the condense-reuse signature) migrated as
                # sideband: everything per-sequence rides with its owner
                ex = {"rep": (rep_idx % S).reshape(n_seq, S)
                      .astype(jnp.int32)}
                if carry_sig is not None:
                    ex.update(cexp=cexp_sb, cage=age_sb, cvalid=valid_sb)
                mig_sb = _exchange_sideband(ex, dest_global, n_seq, M, comm)
                rep_sb = mig_sb["rep"]
                if carry_sig is not None:
                    cexp_sb, age_sb, valid_sb = (
                        mig_sb["cexp"], mig_sb["cage"], mig_sb["cvalid"])
                yg = y_tok.reshape(n_seq, S, d)
                y_tok = jnp.take_along_axis(yg, rep_sb[..., None], axis=1
                                            ).reshape(T, d)
                # within-group position survives the within-seq one
                rep_carry = rep_sb % group_size
            if s_next is not None and migrate:
                ng = S // group_size
                s_mig = s_next.reshape(n_seq, ng, group_size, group_size)
                s_next = _exchange_sideband(
                    {"s": s_mig.astype(jnp.bfloat16)}, dest_global, n_seq,
                    M, comm)["s"].astype(jnp.float32)
                s_next = s_next.reshape(-1, group_size, group_size)

        y_out = y_tok.reshape(n_seq, S, d)

        # ---- shared experts (always-on, llama4-style) ---------------------
        if "shared" in params:
            from repro.models.blocks import ffn_apply
            sh = ffn_apply({"w_up": params["shared"]["w_up"],
                            "w_gate": params["shared"]["w_gate"],
                            "w_down": params["shared"]["w_down"]},
                           cfg, _rms(y_out if migrate
                                     else x.reshape(n_seq, S, d),
                                     params["norm"]["scale"]).astype(cdt))
            y_out = y_out + sh.astype(y_out.dtype)

        zc = jnp.float32(0.0)
        aux = MoEAux(
            plan.aux_loss, plan.dispatch_drop, c_drop, plan.condense_rate,
            local_frac, plan.traffic_before, plan.traffic_after,
            plan.inter_bytes_flat, plan.inter_bytes_dedup,
            zc if plan.plans_built is None else plan.plans_built,
            zc if plan.plans_reused is None else plan.plans_reused,
            zc if plan.reuse_mismatch is None else plan.reuse_mismatch,
            cpn.measured_pairs,
            zc if cpn.built is None else cpn.built,
            zc if cpn.reused is None else cpn.reused,
            shipped)
        cond_carry = None
        if carry_sig is not None:
            cond_carry = {"rep": rep_carry.astype(jnp.int32),
                          "cexp": cexp_sb, "age": age_sb,
                          "valid": valid_sb}
        return y_out, ExchangeAux(sideband=new_sideband, s_next=s_next,
                                  moe=aux, cond_carry=cond_carry,
                                  wire_ef=ef_next)

    # ---- deduplicated hier wire (DESIGN.md §10, §14, §15) ----------------
    # universal: vanilla, migrate (dest-keyed combine) and pipelined
    # (unique-row chunking) all run the dedup wire now
    if plan.wire == "dedup":
        assert plan.replica_src is None, plan.objective
        dchunks = None
        if plan.pipelined:
            L_loc = compat.axis_size(comm.local_axis)
            dchunks = plan_unique_chunks(
                cwire.dedup_capacity(T, E_local, L_loc, C),
                plan.chunks.n_chunks)
        dest_gpos = prim_tk = None
        if migrate:
            # each copy's destination global position in the migrated
            # frame: dest device × T + position within it — the plane
            # dedup_combine_migrate re-addresses the combine through
            tok_ids = jnp.arange(T, dtype=jnp.int32)
            dslot_g = dest_global[tok_ids // S]
            dest_gpos = ((dslot_g // n_seq) * T
                         + (dslot_g % n_seq) * S + (tok_ids % S))
            prim_tk = jnp.broadcast_to(
                (jnp.arange(m.top_k) == 0)[None, :], (T, m.top_k))
        with obs_trace.phase("dispatch") as _sp:
            x_rows, gw_rows, rvalid, wstate = cwire.dedup_dispatch(
                x_pay.astype(cdt), expert_idx, gate_w, valid, pos,
                comm=comm, e_local=E_local, capacity=C,
                wire_dtype=plan.wire_dtype, use_kernel=use_kernel,
                dest_gpos=dest_gpos, prim=prim_tk, chunks=dchunks)
            x_rows = _sp.fence(x_rows)
        with obs_trace.phase("expert_ffn") as _sp:
            h = _rms(x_rows, params["norm"]["scale"]).astype(cdt)
            y_rows = expert_ffn(params["experts"],
                                h.reshape(E_local, M * C, d), act,
                                cdt, use_kernel=use_kernel
                                ).reshape(E_local, M, C, d)
            y_rows = _sp.fence(y_rows)
        with obs_trace.phase("combine") as _sp:
            if not migrate:
                delta = cwire.dedup_combine(y_rows * gw_rows[..., None],
                                            wstate, comm=comm,
                                            wire_dtype=plan.wire_dtype,
                                            chunks=dchunks)
                y_tok = xf + delta.astype(xf.dtype)
                c_drop = jnp.float32(0.0)
                local_frac = jnp.float32(1.0 / M)
                new_sideband = dict(sideband)
            else:
                # gate-weighted + the primary copy's residual: the
                # dest-keyed combine materializes the post-block hidden
                # at NEW slots (no drop path — the migration perm is a
                # bijection, every destination receives exactly T rows)
                out_rows = (y_rows * gw_rows[..., None]
                            + x_rows * wstate["prim"][..., None])
                mchunks = (plan_unique_chunks(T, plan.chunks.n_chunks)
                           if plan.pipelined else None)
                y_mig = cwire.dedup_combine_migrate(
                    out_rows, wstate, comm=comm,
                    wire_dtype=plan.wire_dtype, chunks=mchunks)
                y_tok = y_mig.astype(xf.dtype)
                c_drop = jnp.float32(0.0)
                dd_rows = jnp.where(wstate["dgpos"] >= 0,
                                    wstate["dgpos"] // T, -1)
                local_frac = (jnp.sum((dd_rows == my).astype(jnp.float32))
                              / jnp.maximum(
                                  jnp.sum(rvalid.astype(jnp.float32)),
                                  1.0))
                new_sideband = _exchange_sideband(
                    sideband, dest_global, n_seq, M, comm)
            y_tok = _sp.fence(y_tok)
        # executed wire accounting: unique rows × the wire row bytes —
        # the same wire_row_bytes the estimate divides by, so
        # shipped == inter_bytes_dedup / precision == flat / (dedup ×
        # precision) exactly (the §14 ledger contract; dispatch is
        # mode-independent, so the law holds in all three modes)
        row_bytes = wdt.wire_row_bytes(d, plan.wire_dtype,
                                       jnp.dtype(cdt).itemsize)
        return _finish(y_tok, new_sideband, s_next,
                       c_drop, local_frac,
                       wstate["shipped_rows"] * jnp.float32(row_bytes))

    # ---- build dispatch buffers ------------------------------------------
    # payload row: [x_raw(d), gate_w, is_primary]; meta: (dest_slot+1, pos)
    # Replica lanes (objective "replicate", §15): each device's buffer
    # grows one lane (row index [M, n_lanes] flattened); first-overflow
    # copies of a replicated expert redirect to the HOST device's lane
    # at slot pos - C. n_lanes == E_local (no lanes) leaves row == e_f,
    # the historical layout, bit-for-bit.
    has_lane = plan.replica_src is not None
    n_lanes = E_local + (1 if has_lane else 0)
    R_rows = M * n_lanes
    is_primary = (jnp.arange(m.top_k) == 0)[None, :]          # [1,k]
    tok_slot = jnp.tile((jnp.arange(T, dtype=jnp.int32) // S)[:, None],
                        (1, m.top_k))                         # local seq slot
    tok_pos = jnp.tile((jnp.arange(T, dtype=jnp.int32) % S)[:, None],
                       (1, m.top_k))
    dest_of_tok = dest_global[tok_slot]                       # [T,k]

    e_f = expert_idx.reshape(-1)
    p_f = pos.reshape(-1)
    v_f = valid.reshape(-1)
    row_f = (e_f // E_local) * n_lanes + (e_f % E_local)
    if has_lane:
        rep_src = plan.replica_src
        host_of = jnp.full((E,), -1, jnp.int32).at[
            jnp.where(rep_src >= 0, rep_src, 0)].max(
            jnp.where(rep_src >= 0, jnp.arange(M, dtype=jnp.int32), -1),
            mode="drop")
        rv_f = plan.replica_valid.reshape(-1)
        host_row = host_of[jnp.where(rv_f, e_f, 0)] * n_lanes + E_local
        row_f = jnp.where(rv_f, host_row, row_f)
        p_f = jnp.where(rv_f, p_f - C, p_f)
        v_f = v_f | rv_f
    payload = jnp.concatenate([
        jnp.tile(x_pay.astype(cdt)[:, None], (1, m.top_k, 1)),
        gate_w[..., None].astype(cdt),
        jnp.broadcast_to(is_primary, (T, m.top_k))[..., None].astype(cdt),
    ], axis=-1).reshape(-1, d + 2)                            # [T*k, d+2]
    meta = jnp.stack([dest_of_tok + 1, tok_pos], -1).reshape(-1, 2)

    with obs_trace.phase("dispatch_pack") as _sp:
        buf = jnp.zeros((R_rows, C, d + 2), cdt)
        mbuf = jnp.zeros((R_rows, C, 2), jnp.int32)
        p_safe = jnp.where(v_f, p_f, 0)
        r_safe = jnp.where(v_f, row_f, 0)
        buf = buf.at[r_safe, p_safe].add(
            payload * v_f[:, None].astype(cdt), mode="drop")
        mbuf = mbuf.at[r_safe, p_safe].add(
            meta * v_f[:, None].astype(jnp.int32), mode="drop")
        buf = _sp.fence(buf)

    # replica-lane expert weights: the lane serves replica_src[my],
    # fetched from its intra-node owner over the cheap links (the
    # forward fan-in replica_consistency_ms prices); an idle lane gets
    # zero weights, so its (empty) rows produce exact zeros
    ew = params["experts"]
    if has_lane:
        L_loc = compat.axis_size(comm.local_axis)
        src = plan.replica_src[my]
        src_safe = jnp.maximum(src, 0)
        owner_row = (src_safe // E_local) % L_loc * E_local \
            + src_safe % E_local
        live = (src >= 0).astype(cdt)

        def _lane_w(wk):
            return comm.local_all_gather(wk)[owner_row] * live

        ew = {k: jnp.concatenate([ew[k], _lane_w(ew[k])[None]], axis=0)
              for k in ("w_up", "w_gate", "w_down")}

    # ---- dispatch → expert FFN → (vanilla) combine ------------------------
    # plan.pipelined chunks the static capacity dim and runs the
    # repro.sched software pipeline: chunk k's collective is issued before
    # chunk k-1's FFN result is consumed (DESIGN.md §6). Bit-identical to
    # sync: capacity slicing commutes with the data-movement-only
    # collectives and the row-wise FFN, and chunk results are reassembled
    # in the sync layout before any order-sensitive step (the migrate-mode
    # regroup sorts across ALL rows, so it stays a post-pipeline barrier).
    def _ffn_rows(rows_k):
        """rows_k: [n_lanes, M, Ck, d+2] -> (out, prim) same leading dims
        (lane n_lanes-1, when present, runs the replica's weights)."""
        xr = rows_k[..., :d]
        gw = rows_k[..., d:d + 1]
        prim_k = rows_k[..., d + 1:d + 2]
        ck = rows_k.shape[2]
        h = _rms(xr, params["norm"]["scale"]).astype(cdt)
        y = expert_ffn(ew, h.reshape(n_lanes, M * ck, d),
                       act, cdt, use_kernel=use_kernel) \
            .reshape(n_lanes, M, ck, d)
        out_k = y * gw
        if migrate:
            out_k = out_k + xr * prim_k    # primary copy carries residual
        return out_k, prim_k

    if plan.pipelined:
        cplan = plan.chunks

        def _disp(k):
            # vanilla needs no row metadata — exchanging it would put a
            # dead collective on the pipelined critical path (the barrier
            # keeps payloads live, so XLA could not DCE it there)
            o, s = cplan.offsets[k], cplan.sizes[k]
            bk = cwire.ship_rows(comm.all_to_all,
                                 jax.lax.slice_in_dim(buf, o, o + s, axis=1),
                                 d, plan.wire_dtype)
            if not migrate:
                return bk
            return bk, comm.all_to_all(jax.lax.slice_in_dim(mbuf, o, o + s,
                                                            axis=1))

        def _compute(k, payload):
            bk, mk = payload if migrate else (payload, None)
            s = cplan.sizes[k]
            rows_k = bk.reshape(M, n_lanes, s, d + 2).transpose(1, 0, 2, 3)
            if not migrate:
                return _ffn_rows(rows_k)
            meta_k = mk.reshape(M, n_lanes, s, 2).transpose(1, 0, 2, 3)
            return _ffn_rows(rows_k) + (meta_k,)

        with obs_trace.phase("pipeline_exchange") as _psp:
            if not migrate:
                def _comb(k, res):
                    out_k = res[0]             # [n_lanes, M, Ck, d]
                    back_k = out_k.transpose(1, 0, 2, 3) \
                                  .reshape(R_rows, out_k.shape[2], d)
                    return cwire.ship_rows(comm.combine, back_k, d,
                                           plan.wire_dtype)

                _, backs = run_pipeline(cplan.n_chunks, dispatch=_disp,
                                        compute=_compute, combine=_comb)
                back = jnp.concatenate(backs, axis=1)        # [R_rows, C, d]
                back = _psp.fence(back)
            else:
                outs, _ = run_pipeline(cplan.n_chunks, dispatch=_disp,
                                       compute=_compute)
                out_rows = jnp.concatenate([o for o, _, _ in outs],
                                           axis=2) \
                              .reshape(n_lanes, M * C, d)
                prim = jnp.concatenate([p for _, p, _ in outs], axis=2) \
                          .reshape(n_lanes, M * C, 1)
                rmeta = jnp.concatenate([m for _, _, m in outs], axis=2) \
                           .reshape(n_lanes, M * C, 2)
                out_rows = _psp.fence(out_rows)
    else:
        with obs_trace.phase("dispatch") as _sp:
            if M > 1:
                # activation columns ship at the wire dtype; the int32
                # meta buffer (slot map) never quantizes (DESIGN.md §14)
                buf = cwire.ship_rows(comm.all_to_all, buf, d,
                                      plan.wire_dtype)
                mbuf = comm.all_to_all(mbuf)
            # [M_src * n_lanes, C, .] -> [n_lanes, M_src, C, .]
            rows4 = buf.reshape(M, n_lanes, C, d + 2).transpose(1, 0, 2, 3)
            rmeta = mbuf.reshape(M, n_lanes, C, 2).transpose(1, 0, 2, 3) \
                        .reshape(n_lanes, M * C, 2)
            rows4 = _sp.fence(rows4)
        with obs_trace.phase("expert_ffn") as _sp:
            out4, prim4 = _ffn_rows(rows4)
            out4 = _sp.fence(out4)
        out_rows = out4.reshape(n_lanes, M * C, d)
        prim = prim4.reshape(n_lanes, M * C, 1)
        if not migrate:
            with obs_trace.phase("combine") as _sp:
                back = out_rows.reshape(n_lanes, M, C, d) \
                               .transpose(1, 0, 2, 3).reshape(R_rows, C, d)
                if M > 1:
                    back = cwire.ship_rows(comm.combine, back, d,
                                           plan.wire_dtype)
                back = _sp.fence(back)

    # ---- combine ----------------------------------------------------------
    if not migrate:
        # vanilla: rows returned to their source in dispatch layout —
        # replica copies merge in the same fixed per-copy k-order sum
        # as owner copies (the deterministic replica-merge order)
        vals = back[r_safe, p_safe] * v_f[:, None].astype(cdt)  # [T*k, d]
        delta = jnp.sum(vals.reshape(T, m.top_k, d), axis=1)
        y_tok = xf + delta.astype(xf.dtype)
        c_drop = jnp.float32(0.0)
        local_frac = jnp.float32(1.0 / M)
        new_sideband = dict(sideband)
    else:
        # regroup rows by destination device (priority: residual rows first)
        R = n_lanes * M * C
        o_f = out_rows.reshape(R, d)
        dslot = rmeta[..., 0].reshape(R) - 1               # -1 = empty row
        rpos = rmeta[..., 1].reshape(R)
        rprim = prim.reshape(R) > 0.5
        rvalid = dslot >= 0
        ddev = jnp.where(rvalid, dslot // n_seq, M)        # M = dummy bin
        prio = (~rvalid).astype(jnp.int32) * 2 + (~rprim).astype(jnp.int32)
        order = jnp.argsort(prio, stable=True)
        o_f, dslot, rpos, ddev, rvalid = (a[order] for a in
                                          (o_f, dslot, rpos, ddev, rvalid))
        C_comb = max(8, int(math.ceil(
            plan.combine_slack * n_lanes * C / 8)) * 8)
        oh = jax.nn.one_hot(ddev, M, dtype=jnp.int32)
        rank = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(R), jnp.where(
            rvalid, ddev, 0)]
        keep_c = rvalid & (rank < C_comb)
        n_rv = jnp.sum(rvalid.astype(jnp.float32))
        c_drop = 1.0 - jnp.sum(keep_c.astype(jnp.float32)) / jnp.maximum(
            n_rv, 1.0)
        local_frac = jnp.sum((keep_c & (ddev == my)).astype(jnp.float32)) \
            / jnp.maximum(n_rv, 1.0)
        dd_s = jnp.where(keep_c, ddev, 0)
        rk_s = jnp.where(keep_c, rank, 0)
        cbuf = jnp.zeros((M, C_comb, d), cdt).at[dd_s, rk_s].add(
            o_f * keep_c[:, None].astype(cdt), mode="drop")
        cmeta = jnp.zeros((M, C_comb, 2), jnp.int32).at[dd_s, rk_s].add(
            jnp.stack([jnp.where(keep_c, dslot % n_seq + 1, 0),
                       jnp.where(keep_c, rpos, 0)], -1), mode="drop")
        if M > 1:
            cbuf = cwire.ship_rows(comm.combine, cbuf, d, plan.wire_dtype)
            cmeta = comm.combine(cmeta)
        rs = cbuf.reshape(M * C_comb, d)
        rslot = cmeta[..., 0].reshape(-1) - 1
        rp = cmeta[..., 1].reshape(-1)
        ok = rslot >= 0
        y_grid = jnp.zeros((n_seq, S, d), cdt).at[
            jnp.where(ok, rslot, 0), jnp.where(ok, rp, 0)].add(
            rs * ok[:, None].astype(cdt), mode="drop")
        y_tok = y_grid.reshape(T, d).astype(xf.dtype)
        # sideband travels with sequences
        new_sideband = _exchange_sideband(
            sideband, dest_global, n_seq, M, comm)

    return _finish(y_tok, new_sideband, s_next, c_drop, local_frac,
                   jnp.float32(0.0))


def instantiate_plan(template: ExchangePlan, gate: GateOutput, xn: Array,
                     cfg: ModelConfig, comm: CommContext, *,
                     capacity: int, sideband: Dict[str, Array],
                     use_kernel: bool = False) -> ExchangePlan:
    """Bind fresh routing onto a cached static plan template — the
    zero-planning serving path (DESIGN.md §9).

    ``template`` is a shape-keyed :class:`ExchangePlan` from a
    :class:`~repro.plan.cache.PlanCache` (built ahead of time by
    ``build_plan_template`` — its traced fields are placeholders). This
    reuses every *static* decision (chunk schedule, pipelined flag,
    estimate) and fills only the per-request routing, exactly the traced
    arithmetic ``build_exchange_plan`` performs in vanilla mode — so the
    executed forward is bit-identical to the uncached path while no
    planning (chunk search, pricing, objectives) runs per request.
    Templates are vanilla- or decode-mode only: serving prompts are
    never re-homed and never condensed (and ``build_exchange_plan``
    forces condensation off for ``mode="decode"``, so a decode template
    binds routing through the identical arithmetic).
    """
    m = cfg.moe
    T, d = xn.shape
    n_seq = sideband["seq_len"].shape[0]
    S = T // n_seq
    E = m.num_experts
    M = comm.size()
    E_local = E // M
    my = comm.index()
    C = capacity
    assert template.mode in ("vanilla", "decode") and not template.migrate \
        and not template.condense, (template.mode, template.migrate,
                                    template.condense)
    assert template.capacity == C and template.chunks.capacity == C, \
        (template.capacity, template.chunks, C)
    expert_idx, gate_w = gate.expert_idx, gate.gate_weights

    pos_in_seq = jnp.tile(jnp.arange(S, dtype=jnp.int32)[None], (n_seq, 1))
    token_valid = (pos_in_seq < sideband["seq_len"][:, None]).reshape(T)
    keep = jnp.tile(token_valid[:, None], (1, m.top_k))
    pos = dispatch_positions(expert_idx, keep, E)
    valid = keep & (pos < C)
    kept = jnp.sum(keep.astype(jnp.float32))
    d_drop = 1.0 - jnp.sum(valid.astype(jnp.float32)) / jnp.maximum(kept, 1.0)

    from repro.models.blocks import _dtype
    cdt = _dtype(cfg.compute_dtype)
    topo = comm.topology
    if topo is not None and topo.hierarchical and M > 1:
        row_bytes = float((d + 2) * jnp.dtype(cdt).itemsize)
        ib_flat, ib_dedup = comm_ledger.dispatch_node_ledger(
            expert_idx, valid, my, e_local=E_local, topo=topo,
            row_bytes=row_bytes)
        if comm.mode != "hier":
            ib_dedup = ib_flat
    else:
        ib_flat = ib_dedup = jnp.float32(0.0)

    z = jnp.float32(0.0)
    return ExchangePlan(
        mode=template.mode, migrate=False, condense=False,
        pipelined=template.pipelined, capacity=C, chunks=template.chunks,
        comm=comm, objective=template.objective,
        group_size=template.group_size,
        combine_slack=template.combine_slack, use_kernel=use_kernel,
        wire=template.wire, wire_dtype=template.wire_dtype,
        estimate=template.estimate,
        expert_idx=expert_idx, gate_weights=gate_w, positions=pos,
        valid=valid, aux_loss=gate.aux_loss, dispatch_drop=d_drop,
        condense_plan=identity_condense_plan(
            T, backend=template.condense_plan.backend),
        dest_global=my * n_seq + jnp.arange(n_seq, dtype=jnp.int32),
        traffic_before=z, traffic_after=z, inter_bytes_flat=ib_flat,
        inter_bytes_dedup=ib_dedup, signature=None, plans_built=z,
        plans_reused=jnp.float32(1.0), reuse_mismatch=z)


def instantiate_decode_plan(template: ExchangePlan, gate: GateOutput,
                            xn: Array, cfg: ModelConfig,
                            comm: CommContext, *, capacity: int,
                            sideband: Dict[str, Array],
                            use_kernel: bool = False) -> ExchangePlan:
    """Bind fresh routing onto a cached *decode* template (DESIGN.md
    §13) — the zero-planning steady-state decode path. The decode
    exchange is shape-static per batch slot (T = batch, S = 1), so one
    template covers every decode step of a serving run; this wrapper
    just asserts the template really is the decode one (a prefill
    template bound to a decode shape would be a silent cache-key bug)."""
    assert template.mode == "decode", template.mode
    return instantiate_plan(template, gate, xn, cfg, comm,
                            capacity=capacity, sideband=sideband,
                            use_kernel=use_kernel)


def _exchange_sideband(sb: Dict[str, Array], dest_global: Array,
                       n_seq: int, M: int,
                       comm: CommContext) -> Dict[str, Array]:
    """Move per-sequence side info to new homes (bijection on slots)."""
    if M == 1:
        # permutation within the single device
        out = {}
        inv = jnp.zeros((n_seq,), jnp.int32).at[dest_global % n_seq].set(
            jnp.arange(n_seq, dtype=jnp.int32))
        for k, v in sb.items():
            out[k] = v[inv]
        return out
    out = {}
    dd = dest_global // n_seq
    ds = dest_global % n_seq
    for k, v in sb.items():
        buf = jnp.zeros((M, n_seq) + v.shape[1:], v.dtype)
        buf = buf.at[dd, ds].add(v)
        buf = comm.combine(buf)
        out[k] = jnp.sum(buf, axis=0)      # exactly-one-writer per slot
    return out
