"""Pluggable migration-planner objectives (DESIGN.md §7).

The migration greedy (``core/migration.py`` Algorithm 1) ranks candidate
destinations by a ``[M, M]`` per-byte link-cost matrix. An *objective*
decides what that matrix prices and, when the greedy cannot optimize the
true goal exactly, how to select among candidate plans:

* ``"traffic"`` — the historical objective, exactly: minimize
  link-cost-weighted combine bytes (``Topology.link_cost()``; uniform
  ``1 − I`` on flat fabrics). Plans are bit-identical to the pre-registry
  code path.
* ``"overlap"`` — minimize modeled **exposed** (un-overlappable) time of
  the pipelined exchange. With the ``repro.sched`` pipeline hiding
  collectives under expert compute, a byte only costs wall-clock when its
  link tier is the pipeline bottleneck: intra-node bytes are hidden
  ``chunks``-fold deeper than bottleneck inter-node bytes, so the
  greedy's effective inter/intra cost ratio grows from ``bw_ratio`` to
  ``≈ chunks · bw_ratio``. Because that matrix is a surrogate, the
  objective evaluates BOTH its own plan and the traffic plan under the
  phase-decomposed exposed-time model and keeps the better one — an
  ``"overlap"`` plan is never worse in modeled exposed ms than the
  ``"traffic"`` plan on the same instance.

New objectives register with :func:`register_objective` and are selected
by ``LuffyConfig.plan_objective`` (CLI ``--plan-objective``).
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional

import numpy as np

import jax.numpy as jnp

from repro.comm.topology import Topology
from repro.core import migration as mig
from repro.sched.cost import DEFAULT_CHUNK_OVERHEAD_MS


class ObjectiveContext(NamedTuple):
    """Static facts an objective prices a migration against.

    Phase times model ONE device's share of one exchange (the same units
    as the evaluator's combine times): ``dispatch_*_ms`` are
    plan-invariant (routing fixes them before migration re-homes
    anything); ``ffn_ms`` is the expert-FFN stage the pipeline hides
    collectives under; ``chunks`` is the executed/planned pipeline depth
    (1 = sync); ``row_bytes`` converts the planner's token counts to
    combine-payload bytes. ``chunk_overhead_ms`` (and the topology's
    link speeds) default to hand-set constants; a measured fit from
    ``repro.obs.calibrate`` replaces both, so the exposed-time model
    prices real links (``build_exchange_plan`` threads
    ``LuffyConfig.chunk_overhead_ms`` through here).
    """
    topo: Optional[Topology]
    ffn_ms: float = 0.0
    dispatch_intra_ms: float = 0.0
    dispatch_inter_ms: float = 0.0
    chunks: int = 1
    row_bytes: float = 4.0
    chunk_overhead_ms: float = DEFAULT_CHUNK_OVERHEAD_MS

    @property
    def hierarchical(self) -> bool:
        return self.topo is not None and self.topo.hierarchical


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

# objective(counts, seq_lens, n_per_dev, *, ctx, q, d_model, speed)
#   -> MigrationPlan   (numpy in -> host plan, jax in -> traceable plan)
Objective = Callable[..., mig.MigrationPlan]

OBJECTIVES: Dict[str, Objective] = {}


def register_objective(name: str):
    """Decorator: register a planner objective under ``name``."""
    def deco(fn: Objective) -> Objective:
        OBJECTIVES[name] = fn
        return fn
    return deco


def available_objectives():
    return sorted(OBJECTIVES)


def get_objective(name: str) -> Objective:
    try:
        return OBJECTIVES[name]
    except KeyError:
        raise ValueError(
            f"unknown plan_objective {name!r}; registered objectives: "
            f"{available_objectives()}") from None


def plan_migration_with_objective(counts, seq_lens, n_per_dev: int, *,
                                  objective: str = "traffic",
                                  ctx: Optional[ObjectiveContext] = None,
                                  q: int = 3, d_model: int = 1024,
                                  speed: float = 1e13) -> mig.MigrationPlan:
    """Run Algorithm 1 under the named objective. Array types select the
    backend: numpy inputs use the host planner, jax inputs the traceable
    one (both stay in lock-step; see ``core/migration.py``)."""
    fn = get_objective(objective)
    if ctx is None:
        ctx = ObjectiveContext(topo=None)
    return fn(counts, seq_lens, n_per_dev, ctx=ctx, q=q, d_model=d_model,
              speed=speed)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _is_traced(x) -> bool:
    return isinstance(x, jnp.ndarray)


def _planner(counts):
    return mig.plan_migration_jax if _is_traced(counts) \
        else mig.plan_migration_np


def _as_cost(matrix: Optional[np.ndarray], counts):
    if matrix is None:
        return None
    if _is_traced(counts):
        return jnp.asarray(matrix, jnp.float32)
    return np.asarray(matrix, np.float64)


def traffic_link_cost(topo: Optional[Topology]) -> Optional[np.ndarray]:
    """The historical matrix: ``Topology.link_cost()`` when hierarchical,
    None (planners fall back to ``1 − I``) otherwise — exactly
    ``CommContext.link_cost()`` semantics."""
    if topo is None or not topo.hierarchical:
        return None
    return topo.link_cost()


def exposed_link_cost(ctx: ObjectiveContext) -> np.ndarray:
    """[M, M] per-byte *exposed-time* cost under the chunked pipeline.

    Phase-decomposed pipeline model: per-chunk stage times are
    ``{dispatch_intra, dispatch_inter, ffn, combine_intra,
    combine_inter}`` and the steady state runs at their max. A combine
    byte on tier ``t`` always pays its bandwidth time in the boundary
    chunk (weight ``1/n``) and additionally in every steady-state chunk
    iff tier ``t``'s stage is the bottleneck (weight ``(n-1)/n``). The
    bottleneck test uses the plan-invariant baseline (combine ≈ dispatch
    bytes — the identity plan). Normalized so an intra-node byte costs 1;
    at ``chunks=1`` (sync) this degenerates to ``link_cost()`` exactly.
    """
    topo = ctx.topo
    assert topo is not None and topo.hierarchical, topo
    n = max(1, int(ctx.chunks))
    f = ctx.ffn_ms / n
    per_byte = {"intra": 1e3 / topo.intra_bw, "inter": 1e3 / topo.inter_bw}
    stage0 = {"intra": ctx.dispatch_intra_ms / n,
              "inter": ctx.dispatch_inter_ms / n}
    peak = max(f, *stage0.values())
    alpha = {t: (1.0 if stage0[t] >= peak - 1e-12 else 1.0 / n)
             for t in stage0}
    w_intra = alpha["intra"] * per_byte["intra"]
    w_inter = alpha["inter"] * per_byte["inter"]
    ratio = w_inter / max(w_intra, 1e-30)
    M = topo.num_devices
    dev = np.arange(M)
    same_node = topo.node_of(dev)[:, None] == topo.node_of(dev)[None, :]
    cost = np.where(same_node, 1.0, ratio)
    np.fill_diagonal(cost, 0.0)
    return cost.astype(np.float64)


def combine_tier_ms(counts, assign, topo: Topology, row_bytes: float):
    """(intra_ms, inter_ms) of the combine phase for a migration plan:
    ``counts[i, m]`` rows travel device ``m`` → ``assign[i]``; diagonal
    rows never touch the wire. numpy/jnp agnostic (traceable)."""
    xp = jnp if _is_traced(counts) or _is_traced(assign) else np
    M = counts.shape[1]
    L = topo.devices_per_node
    src = xp.arange(M)
    dst = xp.asarray(assign)
    same_dev = src[None, :] == dst[:, None]               # [n_slots, M]
    same_node = (src[None, :] // L) == (dst[:, None] // L)
    c = counts * row_bytes
    intra = xp.sum(xp.where(same_node & ~same_dev, c, 0.0))
    inter = xp.sum(xp.where(~same_node, c, 0.0))
    return intra / topo.intra_bw * 1e3, inter / topo.inter_bw * 1e3


def exposed_ms(ctx: ObjectiveContext, combine_intra_ms, combine_inter_ms):
    """Modeled exposed sublayer time (ms) of the 5-stage chunked
    pipeline: warm-up + cool-down of every stage plus ``(n-1)`` chunks at
    the bottleneck stage's rate. The phase-refined sibling of
    ``repro.sched.cost.overlap_ms`` (which folds each direction's two
    phases into one stage); traceable when the combine times are."""
    xp = jnp if (_is_traced(combine_intra_ms)
                 or _is_traced(combine_inter_ms)) else np
    n = max(1, int(ctx.chunks))
    o = ctx.chunk_overhead_ms / 2.0
    stages = (ctx.dispatch_intra_ms / n + o,
              ctx.dispatch_inter_ms / n + o,
              ctx.ffn_ms / n,
              combine_intra_ms / n + o,
              combine_inter_ms / n + o)
    peak = stages[0]
    for s in stages[1:]:
        peak = xp.maximum(peak, s)
    return sum(stages) + (n - 1) * peak


def plan_exposed_ms(counts, assign, ctx: ObjectiveContext):
    """Exposed time of a migration plan's exchange (traceable)."""
    ci, ce = combine_tier_ms(counts, assign, ctx.topo, ctx.row_bytes)
    return exposed_ms(ctx, ci, ce)


def _select_plan(take_a, a: mig.MigrationPlan,
                 b: mig.MigrationPlan) -> mig.MigrationPlan:
    if not _is_traced(a.assign) and not _is_traced(b.assign):
        return a if bool(take_a) else b
    return mig.MigrationPlan(*(jnp.where(take_a, x, y)
                               for x, y in zip(a, b)))


# ---------------------------------------------------------------------------
# the objectives
# ---------------------------------------------------------------------------

@register_objective("traffic")
def traffic_objective(counts, seq_lens, n_per_dev: int, *,
                      ctx: ObjectiveContext, q: int = 3,
                      d_model: int = 1024,
                      speed: float = 1e13) -> mig.MigrationPlan:
    """Historical objective: link-cost-weighted combine bytes."""
    cost = _as_cost(traffic_link_cost(ctx.topo), counts)
    return _planner(counts)(counts, seq_lens, n_per_dev, q=q,
                            d_model=d_model, speed=speed, link_cost=cost)


@register_objective("overlap")
def overlap_objective(counts, seq_lens, n_per_dev: int, *,
                      ctx: ObjectiveContext, q: int = 3,
                      d_model: int = 1024,
                      speed: float = 1e13) -> mig.MigrationPlan:
    """Exposed-time objective (ROADMAP item 1): greedy on the
    exposure-weighted matrix, then keep whichever of {exposed-plan,
    traffic-plan} models less un-overlappable time — never worse than
    ``"traffic"`` by construction."""
    base = traffic_objective(counts, seq_lens, n_per_dev, ctx=ctx, q=q,
                             d_model=d_model, speed=speed)
    if not ctx.hierarchical or ctx.chunks <= 1:
        return base          # nothing to hide behind — exposed == traffic
    cost = _as_cost(exposed_link_cost(ctx), counts)
    cand = _planner(counts)(counts, seq_lens, n_per_dev, q=q,
                            d_model=d_model, speed=speed, link_cost=cost)
    t_cand = plan_exposed_ms(counts, cand.assign, ctx)
    t_base = plan_exposed_ms(counts, base.assign, ctx)
    return _select_plan(t_cand < t_base, cand, base)


# ---------------------------------------------------------------------------
# expert replication (objective "replicate", DESIGN.md §15)
# ---------------------------------------------------------------------------

# Minimum hot-expert demand, as a multiple of the mean per-expert
# demand, before a replica is even considered: below 2× the migration
# planner can hide the skew by re-homing sequences, and a replica would
# pay its consistency psum for noise.
REPLICATE_SKEW_MIN = 2.0


@register_objective("replicate")
def replicate_objective(counts, seq_lens, n_per_dev: int, *,
                        ctx: ObjectiveContext, q: int = 3,
                        d_model: int = 1024,
                        speed: float = 1e13) -> mig.MigrationPlan:
    """HierMoE-style expert replication (DESIGN.md §15).

    The *migration* half is ``"traffic"`` verbatim — sequence
    re-homing under this objective is bit-identical to the historical
    planner. What the objective adds is **placement cardinality**:
    :func:`plan_expert_replicas` (called by ``build_exchange_plan``
    after migration planning) replicates each node's hottest expert
    onto an intra-node peer's spare dispatch lane when the modeled
    hot-expert serialization relief exceeds the replica-consistency
    psum (``repro.plan.estimate.replica_consistency_ms``). Replicas are
    strictly gated on modeled gain and the migration plan is traffic's
    own, so under the modeled exposed-time cost a "replicate" plan is
    never worse than "traffic". When the dedup wire is active the
    builder skips replica planning (the unique-row packing already
    removes the duplicate bytes) and this objective degrades to exactly
    "traffic".
    """
    return traffic_objective(counts, seq_lens, n_per_dev, ctx=ctx, q=q,
                             d_model=d_model, speed=speed)


def plan_expert_replicas(load_e, *, e_local: int, topo: Topology,
                         ffn_ms: float, d_model: int, d_ff: int,
                         bytes_per_el: int = 4):
    """Freeze the replica placement: ``[M] int32`` — the global expert
    id each device's replica lane serves, -1 for an idle lane.

    Per node: find the hottest locally-owned expert; replicate it onto
    the owner's next intra-node peer (``(owner + 1) mod L`` within the
    node — deterministic, vectorized, no host sync) iff BOTH

    * its demand is ≥ ``REPLICATE_SKEW_MIN ×`` the mean per-expert
      demand (skew migration alone can't hide — re-homing sequences
      moves *whole rows of demand*, it cannot split one expert's), and
    * the modeled serialization relief — halving the hot expert's share
      of the FFN stage, ``ffn_ms · (load / total) / 2`` — exceeds the
      per-step replica-consistency cost
      (:func:`repro.plan.estimate.replica_consistency_ms`).

    ``load_e`` is the psum-replicated per-expert demand, so every
    device freezes the same placement. Traceable.
    """
    from repro.plan.estimate import replica_consistency_ms
    E = load_e.shape[0]
    M = E // e_local
    L = topo.devices_per_node
    N = topo.num_nodes
    assert M == N * L, (M, N, L)
    per_node = load_e.reshape(N, L * e_local)
    hot_rel = jnp.argmax(per_node, axis=1).astype(jnp.int32)    # [N]
    hot_load = jnp.max(per_node, axis=1)                        # [N]
    hot_e = jnp.arange(N, dtype=jnp.int32) * (L * e_local) + hot_rel
    total = jnp.maximum(jnp.sum(load_e), 1.0)
    mean = total / E
    relief_ms = ffn_ms * (hot_load / total) / 2.0
    cost_ms = replica_consistency_ms(1, d_model, d_ff, topo=topo,
                                     bytes_per_el=bytes_per_el)
    take = (hot_load >= REPLICATE_SKEW_MIN * mean) \
        & (relief_ms > cost_ms)                                 # [N]
    owner = hot_e // e_local
    node_base = jnp.arange(N, dtype=jnp.int32) * L
    host = node_base + (owner - node_base + 1) % L              # [N]
    return jnp.full((M,), -1, jnp.int32).at[host].set(
        jnp.where(take, hot_e, -1))
