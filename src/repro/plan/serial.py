"""Versioned byte format for :class:`~repro.plan.ExchangePlan` (§9).

``to_bytes`` / ``from_bytes`` give a plan a stable, shape-keyed wire
format so serving can precompute plans for known batch shapes and spill
them to disk (:mod:`repro.plan.cache`). Design constraints:

* **No pickle.** The container is ``MAGIC | version | header | payload``:
  a JSON header describing every static field plus a manifest of the
  array fields (dtype name, shape, byte offset), followed by the raw
  little-endian array bytes. Nothing executable is ever deserialized.
* **numpy-backed.** Arrays round-trip through contiguous buffers
  (``ml_dtypes``-backed dtypes like bfloat16 included — dtype names are
  resolved via ``jnp.dtype``). Traced arrays cannot be serialized; plans
  must be concrete (templates, or plans captured outside a trace).
* **Versioned.** ``FORMAT_VERSION`` gates the whole layout; a mismatch
  raises :class:`PlanFormatError` instead of guessing — stale disk
  caches are rebuilt, never misread.
"""
from __future__ import annotations

import json
import struct
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.comm import CommContext
from repro.comm import dtypes as wire_dtypes
from repro.comm.topology import Topology
from repro.condense.plan import CondensePlan, CondenseSignature
from repro.plan.estimate import PlanEstimate
from repro.plan.exchange import ExchangePlan, PlanSignature
from repro.sched import ChunkPlan

MAGIC = b"LFPL"
# v2 (ISSUE 5): the condensation map moved into a nested CondensePlan
# ("condense.*" array fields), the header gained "wire",
# "condense_backend" and "params_version" (router/optimizer-step
# fingerprint — a cached migrate-mode plan is never trusted across a
# router update). v1 blobs raise PlanFormatError and are rebuilt.
# v3 (ISSUE 9): the header gained "wire_dtype" (the compressed-exchange
# precision frozen into the plan, DESIGN.md §14) and "wire_scale_block"
# (the f8 sideband's elements-per-scale — a reader must not guess the
# block size the scales were computed at). v2 blobs raise
# PlanFormatError and are rebuilt.
# v4 (ISSUE 10): the array manifest gained the optional "replica_src"
# / "replica_valid" fields (hot-expert replica placement frozen into
# the plan by the "replicate" objective, DESIGN.md §15) and the header
# "estimate" gained "dedup_overlap_ms". v3 blobs raise PlanFormatError
# and are rebuilt.
FORMAT_VERSION = 4

# ExchangePlan array fields in serialization order. Optional array
# fields (may be None on a given plan) are marked in the header.
_ARRAY_FIELDS = (
    "expert_idx", "gate_weights", "positions", "valid", "aux_loss",
    "dispatch_drop", "dest_global",
    "traffic_before", "traffic_after", "inter_bytes_flat",
    "inter_bytes_dedup", "plans_built", "plans_reused", "reuse_mismatch",
    "replica_src", "replica_valid",
)
_SIG_FIELDS = ("counts", "lens", "valid")
# nested CondensePlan arrays ("condense.<field>"); optionals marked in
# none_fields like everything else
_COND_FIELDS = ("rep_idx", "is_rep", "s_next", "rate", "measured_pairs",
                "built", "reused")
_CSIG_FIELDS = ("expert", "age", "valid")


class PlanFormatError(ValueError):
    """Raised when bytes are not a compatible serialized ExchangePlan."""


def _np(a) -> np.ndarray:
    if isinstance(a, jax.core.Tracer):
        raise TypeError(
            "cannot serialize a traced ExchangePlan — plans must hold "
            "concrete arrays (build them outside jit, or serialize a "
            "cache template)")
    return np.ascontiguousarray(np.asarray(a))


def _estimate_to_dict(est: Optional[PlanEstimate]) -> Optional[Dict]:
    if est is None:
        return None
    d = est._asdict()
    return {k: (int(v) if k == "chunks" else float(v))
            for k, v in d.items()}


def _comm_to_dict(comm: CommContext) -> Dict[str, Any]:
    topo = comm.topology
    return {
        "mode": comm.mode,
        "axes": list(comm.axes),
        "topology": None if topo is None else {
            "num_nodes": topo.num_nodes,
            "devices_per_node": topo.devices_per_node,
            "intra_bw": topo.intra_bw, "inter_bw": topo.inter_bw,
            "intra_lat": topo.intra_lat, "inter_lat": topo.inter_lat,
        },
    }


def _comm_from_dict(d: Dict[str, Any]) -> CommContext:
    t = d.get("topology")
    topo = None if t is None else Topology(**t)
    return CommContext(d["mode"], tuple(d["axes"]), topo)


def to_bytes(plan: ExchangePlan, *, params_version: str = "0") -> bytes:
    """Serialize a concrete plan: MAGIC, u16 version, u32 header length,
    JSON header, raw array payload. ``params_version`` is the router/
    optimizer-step fingerprint the plan was built against ("0" for
    routing-free vanilla templates); readers may demand a match."""
    payloads: list[bytes] = []
    manifest = []
    offset = 0

    def add(name: str, a) -> None:
        nonlocal offset
        na = _np(a)
        raw = na.tobytes()
        manifest.append({"field": name, "dtype": na.dtype.name,
                         "shape": list(na.shape), "offset": offset,
                         "nbytes": len(raw)})
        payloads.append(raw)
        offset += len(raw)

    none_fields = []
    for f in _ARRAY_FIELDS:
        v = getattr(plan, f)
        if v is None:
            none_fields.append(f)
        else:
            add(f, v)
    sig = plan.signature
    if sig is None:
        none_fields.append("signature")
    else:
        for f in _SIG_FIELDS:
            add(f"signature.{f}", getattr(sig, f))
    cp = plan.condense_plan
    for f in _COND_FIELDS:
        v = getattr(cp, f)
        if v is None:
            none_fields.append(f"condense.{f}")
        else:
            add(f"condense.{f}", v)
    if cp.signature is None:
        none_fields.append("condense.signature")
    else:
        for f in _CSIG_FIELDS:
            add(f"condense.signature.{f}", getattr(cp.signature, f))

    header = {
        "mode": plan.mode, "migrate": bool(plan.migrate),
        "condense": bool(plan.condense), "pipelined": bool(plan.pipelined),
        "capacity": int(plan.capacity),
        "chunks": {"capacity": int(plan.chunks.capacity),
                   "sizes": [int(s) for s in plan.chunks.sizes]},
        "comm": _comm_to_dict(plan.comm),
        "objective": plan.objective,
        "group_size": int(plan.group_size),
        "combine_slack": float(plan.combine_slack),
        "use_kernel": bool(plan.use_kernel),
        "wire": plan.wire,
        "wire_dtype": plan.wire_dtype,
        "wire_scale_block": wire_dtypes.SCALE_BLOCK,
        "condense_backend": cp.backend,
        "params_version": str(params_version),
        "estimate": _estimate_to_dict(plan.estimate),
        "arrays": manifest,
        "none_fields": none_fields,
    }
    hj = json.dumps(header, sort_keys=True).encode("utf-8")
    return b"".join([MAGIC, struct.pack("<HI", FORMAT_VERSION, len(hj)),
                     hj] + payloads)


def from_bytes(data: bytes, *,
               expect_params_version: Optional[str] = None) -> ExchangePlan:
    """Parse :func:`to_bytes` output back into an ExchangePlan (arrays as
    jnp values). Rejects foreign magic and any other format version;
    with ``expect_params_version`` set, also rejects plans serialized
    against a different router/optimizer fingerprint (a stale
    migrate-mode plan must never be trusted after a router update)."""
    if len(data) < 10 or data[:4] != MAGIC:
        raise PlanFormatError("not a serialized ExchangePlan (bad magic)")
    version, hlen = struct.unpack("<HI", data[4:10])
    if version != FORMAT_VERSION:
        raise PlanFormatError(
            f"plan format version {version} != supported "
            f"{FORMAT_VERSION}; rebuild the cache")
    try:
        header = json.loads(data[10:10 + hlen].decode("utf-8"))
    except Exception as e:
        raise PlanFormatError(f"corrupt plan header: {e}") from None
    if expect_params_version is not None \
            and header.get("params_version") != str(expect_params_version):
        raise PlanFormatError(
            f"plan params_version {header.get('params_version')!r} != "
            f"expected {expect_params_version!r}; rebuild the cache")
    if header["wire_scale_block"] != wire_dtypes.SCALE_BLOCK:
        raise PlanFormatError(
            f"plan f8 scale block {header['wire_scale_block']} != "
            f"supported {wire_dtypes.SCALE_BLOCK}; rebuild the cache")
    payload = data[10 + hlen:]

    vals: Dict[str, Any] = {}
    for rec in header["arrays"]:
        dt = jnp.dtype(rec["dtype"])
        raw = payload[rec["offset"]:rec["offset"] + rec["nbytes"]]
        if len(raw) != rec["nbytes"]:
            raise PlanFormatError("truncated plan payload")
        na = np.frombuffer(raw, dtype=dt).reshape(rec["shape"])
        vals[rec["field"]] = jnp.asarray(na)

    none = set(header["none_fields"])
    arr = {f: (None if f in none else vals[f]) for f in _ARRAY_FIELDS}
    sig = None
    if "signature" not in none:
        sig = PlanSignature(*(vals[f"signature.{f}"] for f in _SIG_FIELDS))
    csig = None
    if "condense.signature" not in none:
        csig = CondenseSignature(*(vals[f"condense.signature.{f}"]
                                   for f in _CSIG_FIELDS))
    cond = CondensePlan(
        backend=header["condense_backend"], signature=csig,
        **{f: (None if f"condense.{f}" in none else vals[f"condense.{f}"])
           for f in _COND_FIELDS})
    est = None
    if header["estimate"] is not None:
        est = PlanEstimate(**header["estimate"])
    return ExchangePlan(
        mode=header["mode"], migrate=header["migrate"],
        condense=header["condense"], pipelined=header["pipelined"],
        capacity=header["capacity"],
        chunks=ChunkPlan(header["chunks"]["capacity"],
                         tuple(header["chunks"]["sizes"])),
        comm=_comm_from_dict(header["comm"]),
        objective=header["objective"], group_size=header["group_size"],
        combine_slack=header["combine_slack"],
        use_kernel=header["use_kernel"], wire=header["wire"],
        wire_dtype=header["wire_dtype"],
        estimate=est, condense_plan=cond, signature=sig, **arr)
