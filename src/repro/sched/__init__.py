"""Execution scheduling subsystem (DESIGN.md §6).

Where :mod:`repro.comm` decides *where bytes go and what they cost*,
``repro.sched`` decides *when the collectives that carry them run*. It
splits the MoE hot path's static dispatch capacity into 8-aligned chunks
(:mod:`repro.sched.plan`), executes dispatch → expert FFN → combine as a
double-buffered software pipeline so chunk ``k``'s collective is in
flight while chunk ``k-1`` computes (:mod:`repro.sched.pipeline`), and
prices the resulting compute/communication overlap analytically for
``commsim`` and the dry-run ledger (:mod:`repro.sched.cost`).

The pipelined executor is a pure re-ordering of the sync path — chunking
the capacity dimension of the dispatch buffers commutes with the
(data-movement-only) collectives and with the row-wise expert FFN, so
``LuffyConfig.exec_mode="pipeline"`` is bit-identical to ``"sync"``
(tested per {migration, condensation} × {flat, hier} combination).
"""
from repro.sched.cost import (dedup_overlap_ms, optimal_chunks, overlap_ms,
                              sync_ms)
from repro.sched.pipeline import (format_schedule, pipeline_schedule,
                                  run_pipeline)
from repro.sched.plan import ChunkPlan, plan_chunks, plan_unique_chunks

__all__ = [
    "ChunkPlan", "dedup_overlap_ms", "format_schedule", "optimal_chunks",
    "overlap_ms", "pipeline_schedule", "plan_chunks", "plan_unique_chunks",
    "run_pipeline", "sync_ms",
]
