"""Analytic overlap pricing for the chunked pipeline (DESIGN.md §6).

Models one MoE sublayer executed as the ``repro.sched.pipeline``
schedule: dispatch / expert-FFN / combine stage totals ``D``, ``F``,
``Cm`` split into ``n`` chunks run as a 3-stage linear pipeline, so

    T(n) = d + f + c + (n - 1) * max(d, f, c)

with per-chunk stage times ``d = D/n + o``, ``f = F/n``,
``c = Cm/n + o`` — ``o`` the per-chunk collective overhead (message
latencies from the :class:`~repro.comm.Topology` plus a fixed issue
cost). ``n = 1`` degenerates to the sync path ``D + F + Cm + 2o``;
large ``n`` approaches ``max(D, F, Cm)`` (perfect overlap) until the
``(n-1)·o`` term wins. This is the number ``commsim``'s
``vanilla-overlap``/``luffy-overlap`` systems and the dry-run
``comm_ledger`` report, and what ``benchmarks/fig_overlap_sweep.py``
sweeps against chunk count and bandwidth ratio.

The ``dispatch_ms`` / ``combine_ms`` inputs arrive already wire-priced:
:func:`repro.plan.estimate.estimate_exchange` scales its effective
bytes-per-element by ``1 / wire_precision(d_model, wire_dtype, ...)``
(DESIGN.md §14) before pricing the links, so nothing here needs to know
the wire dtype — a compressed wire simply shows up as smaller ``D`` and
``Cm`` stage totals.
"""
from __future__ import annotations

from typing import Tuple

from repro.comm.ledger import chunk_latency_s
from repro.comm.topology import Topology

# Fixed per-chunk collective issue cost (ms): launch + fusion-boundary
# overhead of one extra start/done pair. Swamped by bandwidth terms at
# production payload sizes; keeps the optimal chunk count finite.
DEFAULT_CHUNK_OVERHEAD_MS = 0.05


def resolve_chunk_overhead_ms(value: float = None) -> float:
    """Normalize a configured per-chunk overhead: None or <= 0 means the
    built-in constant; a positive value (typically a measured fit from
    ``repro.obs.calibrate``, via ``LuffyConfig.chunk_overhead_ms``)
    wins."""
    if value is None or value <= 0.0:
        return DEFAULT_CHUNK_OVERHEAD_MS
    return float(value)


def overlap_ms(topo: Topology, chunks: int, *, dispatch_ms: float,
               ffn_ms: float, combine_ms: float = 0.0,
               chunk_overhead_ms: float = DEFAULT_CHUNK_OVERHEAD_MS
               ) -> float:
    """Modeled MoE-sublayer time (ms) pipelined over ``chunks`` chunks."""
    n = max(1, int(chunks))
    o = chunk_overhead_ms + chunk_latency_s(topo) * 1e3
    d = dispatch_ms / n + o
    f = ffn_ms / n
    c = combine_ms / n + (o if combine_ms > 0.0 else 0.0)
    return d + f + c + (n - 1) * max(d, f, c)


def dedup_overlap_ms(topo: Topology, chunks: int, *,
                     dispatch_inter_ms: float, dispatch_intra_ms: float,
                     ffn_ms: float, combine_inter_ms: float = 0.0,
                     combine_intra_ms: float = 0.0,
                     chunk_overhead_ms: float = DEFAULT_CHUNK_OVERHEAD_MS
                     ) -> float:
    """Modeled MoE-sublayer time (ms) for the *pipelined dedup wire*
    (DESIGN.md §15).

    The dedup wire's dispatch and combine each have two phases — the
    expensive inter-node unique-row hop and the cheap intra-node
    fan-out / pre-reduce — and chunking the unique-row capacity lets
    those phases overlap depth-2 *within* the stage: chunk k's
    fan-out runs on the cheap links while chunk k+1's node hop flies.
    The dense wire cannot express this — its single all-to-all has no
    phase boundary to split. Steady-state per-chunk stage cost is
    therefore ``max(inter, intra)/n + o`` instead of their sum; the
    minor phase of each hop is paid once at pipeline fill. ``n = 1``
    degenerates exactly to :func:`sync_ms` with the phase sums.
    """
    n = max(1, int(chunks))
    o = chunk_overhead_ms + chunk_latency_s(topo) * 1e3
    d = max(dispatch_inter_ms, dispatch_intra_ms) / n + o
    has_c = (combine_inter_ms + combine_intra_ms) > 0.0
    c = (max(combine_inter_ms, combine_intra_ms) / n + o) if has_c else 0.0
    f = ffn_ms / n
    fill = (min(dispatch_inter_ms, dispatch_intra_ms)
            + min(combine_inter_ms, combine_intra_ms)) / n
    return d + f + c + fill + (n - 1) * max(d, f, c)


def sync_ms(topo: Topology, *, dispatch_ms: float, ffn_ms: float,
            combine_ms: float = 0.0,
            chunk_overhead_ms: float = DEFAULT_CHUNK_OVERHEAD_MS) -> float:
    """The unpipelined baseline — ``overlap_ms`` at one chunk."""
    return overlap_ms(topo, 1, dispatch_ms=dispatch_ms, ffn_ms=ffn_ms,
                      combine_ms=combine_ms,
                      chunk_overhead_ms=chunk_overhead_ms)


def optimal_chunks(topo: Topology, *, dispatch_ms: float, ffn_ms: float,
                   combine_ms: float = 0.0, max_chunks: int = 16,
                   chunk_overhead_ms: float = DEFAULT_CHUNK_OVERHEAD_MS
                   ) -> Tuple[int, float]:
    """(argmin chunk count, modeled ms) over ``1..max_chunks``; ties go
    to the smaller chunk count (fewer collectives, same time)."""
    best_n, best_t = 1, None
    for n in range(1, max(1, max_chunks) + 1):
        t = overlap_ms(topo, n, dispatch_ms=dispatch_ms, ffn_ms=ffn_ms,
                       combine_ms=combine_ms,
                       chunk_overhead_ms=chunk_overhead_ms)
        if best_t is None or t < best_t - 1e-12:
            best_n, best_t = n, t
    return best_n, best_t


# ---------------------------------------------------------------------------
# decode-step pricing (DESIGN.md §13)
# ---------------------------------------------------------------------------

def decode_combine_ms(tokens: int, d_model: int, topo: Topology, *,
                      bytes_per_el: int = 2) -> float:
    """Modeled decode MoE combine: one [tokens, d_model] all-reduce over
    the model axis per MoE sublayer (``moe_decode_allreduce`` — decode
    has no all-to-all to chunk). Ring all-reduce over the topology's
    slowest link class: ``2(M−1)`` steps each moving ``payload/M`` bytes
    plus per-step latency."""
    M = topo.num_devices
    if M <= 1 or tokens <= 0:
        return 0.0
    payload = float(tokens) * d_model * bytes_per_el
    hier = topo.num_nodes > 1
    bw = topo.inter_bw if hier else topo.intra_bw
    lat = topo.inter_lat if hier else topo.intra_lat
    steps = 2 * (M - 1)
    return (steps / M * payload / bw + steps * lat) * 1e3


def decode_step_ms(*, combine_ms: float, shared_ffn_ms: float,
                   overlap: bool) -> float:
    """One decode MoE sublayer's exposed time: the combine psum and the
    shared-expert FFN are data-independent, so ``decode_overlap``
    (``LuffyConfig.exec_mode``) exposes only the longer of the two while
    sync pays their sum. Degenerate cases fall out: no shared experts or
    a flat single-device mesh give overlap == sync."""
    if overlap:
        return max(combine_ms, shared_ffn_ms)
    return combine_ms + shared_ffn_ms
