"""Double-buffered software pipeline over capacity chunks (DESIGN.md §6).

The schedule is the classic two-slot DMA pipeline (warm up the first
transfer, then issue chunk ``k+1``'s transfer *before* consuming chunk
``k``), lifted from the kernel level to the XLA collective level:

    dispatch[0]
    dispatch[1] ; compute[0] ; combine[0]
    dispatch[2] ; compute[1] ; combine[1]
    ...
                  compute[n-1] ; combine[n-1]

At most two dispatch payloads are live at any point — the one being
consumed and the one in flight — so peak buffer memory is ``2/n`` of the
sync path's. XLA lowers the collectives to async start/done pairs; the
program-order interleaving above gives the latency-hiding scheduler a
compute region to sink each ``done`` past. An optimization barrier
(``repro.comm.compat.optimization_barrier`` — differentiable shim)
ties each issued next-chunk payload to the current chunk's payload so the
scheduler cannot "helpfully" defer the next collective until after the
current compute (the same reason the attention path barriers its K/V
gathers; see ``models/transformer.py``).

:func:`pipeline_schedule` returns that issue order as data — the explicit
unrolled variant — so tests and humans can inspect exactly what the
executor traces (:func:`format_schedule` pretty-prints it).
"""
from __future__ import annotations

from typing import Callable, List, NamedTuple, Optional, Tuple

from repro.comm import compat


class Stage(NamedTuple):
    name: str                     # "dispatch" | "compute" | "combine"
    chunk: int


def pipeline_schedule(n_chunks: int, *, with_combine: bool = True
                      ) -> Tuple[Stage, ...]:
    """Issue order of the depth-2 software pipeline over ``n_chunks``.

    Invariants (asserted by tests): every chunk's dispatch precedes its
    compute, which precedes its combine; chunk ``k+1``'s dispatch is
    issued before chunk ``k``'s compute; at most two dispatched payloads
    are outstanding at any point.
    """
    assert n_chunks >= 1, n_chunks
    out: List[Stage] = [Stage("dispatch", 0)]
    for k in range(n_chunks):
        if k + 1 < n_chunks:
            out.append(Stage("dispatch", k + 1))
        out.append(Stage("compute", k))
        if with_combine:
            out.append(Stage("combine", k))
    return tuple(out)


def format_schedule(n_chunks: int, *, with_combine: bool = True) -> str:
    """Human-readable pipeline diagram of :func:`pipeline_schedule`."""
    sched = pipeline_schedule(n_chunks, with_combine=with_combine)
    lines, row = [], []
    for st in sched:
        if st.name == "dispatch" and row:
            lines.append(" ; ".join(row))
            row = []
        row.append(f"{st.name}[{st.chunk}]")
    if row:
        lines.append(" ; ".join(row))
    return "\n".join(f"t{i}: {ln}" for i, ln in enumerate(lines))


def run_pipeline(n_chunks: int, *,
                 dispatch: Callable[[int], object],
                 compute: Callable[[int, object], object],
                 combine: Optional[Callable[[int, object], object]] = None,
                 barrier: bool = True):
    """Trace the pipelined execution of ``n_chunks`` chunks.

    ``dispatch(k)`` issues chunk ``k``'s collective and returns its
    payload (any pytree); ``compute(k, payload)`` consumes it;
    ``combine(k, out)`` optionally runs the return-direction collective.
    Returns ``(computed, combined)`` lists in chunk order (``combined``
    is None when no combine stage is given).

    ``barrier=True`` ties (next payload, current payload) with
    ``optimization_barrier`` right after the next dispatch is issued,
    pinning the double-buffered issue order against XLA reordering. The
    executor follows :func:`pipeline_schedule` exactly — the schedule is
    the spec, this is the interpreter.
    """
    payloads = {}
    computed: List[object] = [None] * n_chunks
    combined: Optional[List[object]] = \
        [None] * n_chunks if combine is not None else None
    for st in pipeline_schedule(n_chunks, with_combine=combine is not None):
        if st.name == "dispatch":
            payloads[st.chunk] = dispatch(st.chunk)
            prev = st.chunk - 1
            if barrier and prev in payloads:
                payloads[st.chunk], payloads[prev] = \
                    compat.optimization_barrier(
                        (payloads[st.chunk], payloads[prev]))
        elif st.name == "compute":
            computed[st.chunk] = compute(st.chunk, payloads.pop(st.chunk))
        else:
            combined[st.chunk] = combine(st.chunk, computed[st.chunk])
        assert len(payloads) <= 2, "double-buffer invariant violated"
    return computed, combined
