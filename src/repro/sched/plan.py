"""Chunk planning over the dispatch-capacity dimension (DESIGN.md §6).

The MoE dispatch buffer is ``[E, C, row]`` with a *static* per-(source,
expert) capacity ``C`` (always 8-aligned; see ``moe_layer.capacity_for``).
A :class:`ChunkPlan` partitions ``C`` into contiguous 8-aligned
sub-capacities. Because gating, dispatch positions and drop decisions are
computed *before* the buffers are sliced, per-chunk semantics are exactly
the sync path's — a row lands in chunk ``j`` iff its dispatch position
falls inside chunk ``j``'s capacity window, and capacity overflow still
drops exactly the rows with ``pos >= C``.

8-alignment matters twice: it keeps every chunk's trailing dims on TPU
lane boundaries (so the sliced collectives lay out like the full one),
and it guarantees a chunk is never empty (``C >= 8``).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

ALIGN = 8


class ChunkPlan(NamedTuple):
    """Contiguous partition of the capacity dimension."""
    capacity: int                 # total per-(source, expert) capacity
    sizes: Tuple[int, ...]        # per-chunk sub-capacities (8-aligned)

    @property
    def n_chunks(self) -> int:
        return len(self.sizes)

    @property
    def offsets(self) -> Tuple[int, ...]:
        out, off = [], 0
        for s in self.sizes:
            out.append(off)
            off += s
        return tuple(out)

    def slices(self) -> Tuple[Tuple[int, int], ...]:
        """(offset, size) pairs, in capacity order."""
        return tuple(zip(self.offsets, self.sizes))


def plan_chunks(capacity: int, n_chunks: int, *, align: int = ALIGN
                ) -> ChunkPlan:
    """Split ``capacity`` into at most ``n_chunks`` aligned sub-capacities.

    ``capacity`` must itself be a multiple of ``align`` (the capacity
    helpers guarantee this). The request is clipped so every chunk gets at
    least one alignment unit; units are distributed as evenly as possible
    with the remainder on the leading chunks, so chunk sizes differ by at
    most ``align``.
    """
    assert capacity >= align and capacity % align == 0, capacity
    units = capacity // align
    n = max(1, min(int(n_chunks), units))
    base, rem = divmod(units, n)
    sizes = tuple((base + (1 if i < rem else 0)) * align for i in range(n))
    return ChunkPlan(capacity, sizes)


def plan_unique_chunks(unique_capacity: int, n_chunks: int) -> ChunkPlan:
    """:class:`ChunkPlan` over the dedup wire's *unique-row* capacity
    (DESIGN.md §15).

    The pipelined dedup wire chunks the ``[N, C_u, d]`` unique-row
    buffer (``C_u`` = ``repro.condense.wire.dedup_capacity``, 8-aligned
    and ≥ 8 by construction) instead of the dense ``[E, C]`` layout —
    the same aligned partition applies, just along the axis the bytes
    actually travel on. Token-axis return hops (migrate-mode combine)
    may pass an unaligned total; fall back to a single chunk rather
    than force alignment there.
    """
    if unique_capacity < ALIGN or unique_capacity % ALIGN != 0:
        return ChunkPlan(unique_capacity, (unique_capacity,))
    return plan_chunks(unique_capacity, n_chunks)
