"""Decode-path serving subsystem (DESIGN.md §13).

Where training's unit of work is a *step*, ``repro.serve``'s is a
*request*:

* :mod:`repro.serve.engine` — prefill + single-token decode with the
  per-layer cache layout. The cache carries a per-slot ``offset`` frame
  origin so a recycled slot restarts at relative position 0 with no
  recompile and no attention-cache reset (the slot-recycling invariant
  makes stale ring entries mask identically to a fresh cache's −1
  entries — bitwise). Decode MoE sublayers take a precomputed plan
  template from the :class:`~repro.plan.cache.PlanCache` so steady-state
  decode makes zero ``build_exchange_plan`` calls, and the
  ``decode_overlap`` exec mode issues the decode combine psum
  concurrently with the shared-expert FFN
  (``core/moe_layer.py::moe_decode_allreduce``).
* :mod:`repro.serve.scheduler` — the continuous-batching request
  scheduler: FIFO admission into free cache slots between decode steps,
  evict-on-finish slot recycling, per-request SLO accounting
  (queue/TTFT/per-token latency) published through the ``repro.obs``
  metrics registry by ``launch/serve.py --continuous``.

The historical top-level ``repro.serve_lib`` module remains as a
re-export shim (mirroring ``core/condensation.py`` → ``repro.condense``).
"""
from repro.serve.engine import (admit_slot, attn_decode, cache_pspecs,
                                cache_struct, cross_attn_decode,
                                decode_capacity, decode_step, prefill,
                                prefill_capacity)
from repro.serve.scheduler import (ContinuousScheduler, Request,
                                   DECODE, DONE, IDLE_TOKEN, PREFILL,
                                   QUEUED)

__all__ = [
    "ContinuousScheduler", "DECODE", "DONE", "IDLE_TOKEN", "PREFILL",
    "QUEUED", "Request", "admit_slot", "attn_decode", "cache_pspecs",
    "cache_struct", "cross_attn_decode", "decode_capacity", "decode_step",
    "prefill", "prefill_capacity",
]
