"""Serving engine: prefill + single-token decode with per-layer caches.

Promoted from the historical top-level ``repro.serve_lib`` module when
serving became a first-class subsystem (DESIGN.md §13); ``serve_lib``
remains as a re-export shim, mirroring ``core/condensation.py`` →
``repro.condense``.

Cache layout (per pattern position ``j``, stacked over scan groups):

* attention:  ``{"k","v": [n_groups, B, W_j, kv, hd],
  "cpos": [n_groups, B, W_j]}`` where ``W_j = min(window_j, S_max)`` —
  window layers keep a ring buffer (slot = rpos % W), global layers a
  full buffer. ``cpos`` holds per-slot RELATIVE positions (−1 = empty).
* mamba:      ``{"h": [n_groups, B, d_inner, N], "conv": [n_groups, B, K−1, d_inner]}``
* rwkv6:      ``{"S": [n_groups, B, H, hd, hd], "x_prev": [n_groups, B, 1, d]}``
* cross-attn: ``{"ck","cv": [n_groups, B, S_enc, kv, hd]}`` (static after prefill)
* ``offset [B] int32``: per-slot start of the occupant's coordinate
  frame. A slot's relative position is ``rpos = pos − offset[b]`` — the
  continuous-batching scheduler (``serve/scheduler.py``) admits a new
  request into a recycled slot by setting ``offset[b] = pos`` (see
  :func:`admit_slot`), which restarts that slot at rpos 0 without
  touching any other slot or recompiling (``offset`` is a traced input).

Slot-recycling invariant (why admission needs NO attention-cache reset):
every ``cpos`` entry at ring index ``i`` is either −1 or a value
``v ≥ i`` with ``v ≡ i (mod W)`` (writes store ``rpos`` at index
``rpos % W``). For a fresh occupant at ``rpos_new``, every stale index
``i > rpos_new`` therefore holds ``v ≥ i > rpos_new`` or −1 — masked by
``kp <= rpos`` exactly where a fresh batch's −1 entries would be, and
the NEG_INF logits underflow to exactly-0.0 softmax weights, so
``0.0 × stale_v = 0.0`` bitwise. SSM/RWKV recurrent state DOES carry
across tokens unmasked, so :func:`admit_slot` zeroes those rows.

Decode attention is written as plain masked softmax over the (possibly
context-parallel-sharded) cache — GSPMD inserts the partial-softmax
collectives when the cache's sequence dim is sharded.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import LuffyConfig, ModelConfig
from repro.core import moe_layer as moe
from repro.dist import DistContext
from repro.models import blocks as bk
from repro.models import ssm as ssm_mod
from repro.models.transformer import (_moe_apply_dist, embed_tokens,
                                      logits_fn, pattern_period,
                                      _run_encoder)

Array = jnp.ndarray
NEG_INF = -1e30


def _win(cfg: ModelConfig, j: int, s_max: int) -> int:
    w = cfg.attn.window_for_layer(j) if cfg.attn is not None else None
    return s_max if w is None else min(w, s_max)


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def cache_struct(cfg: ModelConfig, batch: int, s_max: int, *,
                 enc_len: int = 0, as_struct: bool = True):
    """Pytree of ShapeDtypeStruct (as_struct) or zero arrays."""
    period = pattern_period(cfg)
    n_groups = cfg.num_layers // period
    cdt = bk._dtype(cfg.compute_dtype)

    def mk(shape, dtype):
        if as_struct:
            return jax.ShapeDtypeStruct(shape, dtype)
        if dtype == jnp.int32:
            return jnp.full(shape, -1, dtype)
        return jnp.zeros(shape, dtype)

    groups = []
    for j in range(period):
        g: Dict[str, Any] = {}
        if cfg.attn is not None:
            a = cfg.attn
            W = _win(cfg, j, s_max)
            g["k"] = mk((n_groups, batch, W, a.num_kv_heads, a.head_dim), cdt)
            g["v"] = mk((n_groups, batch, W, a.num_kv_heads, a.head_dim), cdt)
            g["cpos"] = mk((n_groups, batch, W), jnp.int32)
        if cfg.ssm is not None:
            s = cfg.ssm
            if s.kind == "mamba":
                di = s.expand * cfg.d_model
                g["ssm_h"] = mk((n_groups, batch, di, s.state_dim),
                                jnp.float32)
                g["ssm_conv"] = mk((n_groups, batch, s.conv_dim - 1, di),
                                   jnp.float32)
            else:
                hd = s.head_dim
                nh = cfg.d_model // hd
                g["ssm_S"] = mk((n_groups, batch, nh, hd, hd), jnp.float32)
                g["ssm_xprev"] = mk((n_groups, batch, 1, cfg.d_model),
                                    jnp.float32)
                # channel-mix has its own token-shift state (the cmix
                # input is normed by a DIFFERENT norm than time-mix)
                g["cmix_xprev"] = mk((n_groups, batch, 1, cfg.d_model),
                                     jnp.float32)
        if cfg.kind == "encdec":
            a = cfg.attn
            g["ck"] = mk((n_groups, batch, enc_len, a.num_kv_heads,
                          a.head_dim), cdt)
            g["cv"] = mk((n_groups, batch, enc_len, a.num_kv_heads,
                          a.head_dim), cdt)
        groups.append(g)
    # per-slot coordinate-frame origin: 0 everywhere at boot (NOT the
    # int32 −1 fill — a zero offset makes the relative frame coincide
    # with the absolute one, i.e. the pre-continuous-batching layout)
    off = (jax.ShapeDtypeStruct((batch,), jnp.int32) if as_struct
           else jnp.zeros((batch,), jnp.int32))
    cache = {"groups": groups, "offset": off,
             "pos": mk((), jnp.int32) if as_struct else jnp.int32(0)}
    return cache


def cache_pspecs(cfg: ModelConfig, dist: DistContext, s_max: int):
    """PartitionSpecs matching cache_struct. KV sequence dim sharded over
    dist.seq_axis (context-parallel decode)."""
    period = pattern_period(cfg)
    ba = dist.batch_axes if dist.batch_axes else None
    sax = dist.seq_axis
    groups = []
    for j in range(period):
        g: Dict[str, Any] = {}
        if cfg.attn is not None:
            W = _win(cfg, j, s_max)
            kv_seq = sax if (sax is not None and _div(W, dist, sax)) else None
            g["k"] = P(None, ba, kv_seq, None, None)
            g["v"] = P(None, ba, kv_seq, None, None)
            g["cpos"] = P(None, ba, kv_seq)
        if cfg.ssm is not None:
            if cfg.ssm.kind == "mamba":
                g["ssm_h"] = P(None, ba, None, None)
                g["ssm_conv"] = P(None, ba, None, None)
            else:
                g["ssm_S"] = P(None, ba, None, None, None)
                g["ssm_xprev"] = P(None, ba, None, None)
                g["cmix_xprev"] = P(None, ba, None, None)
        if cfg.kind == "encdec":
            # encoder KV can be long (32k frames) — shard its seq dim too
            g["ck"] = P(None, ba, sax, None, None)
            g["cv"] = P(None, ba, sax, None, None)
        groups.append(g)
    return {"groups": groups, "offset": P(ba), "pos": P()}


def _div(n: int, dist: DistContext, axes) -> bool:
    return n % dist.axis_size(axes) == 0


# ---------------------------------------------------------------------------
# slot admission (continuous batching)
# ---------------------------------------------------------------------------

def admit_slot(cache, slot: int, position) -> dict:
    """Recycle cache slot ``slot`` for a new request whose first token
    will be fed at absolute decode position ``position`` (normally the
    current ``cache["pos"]``).

    Only two things change: ``offset[slot]`` (restarting the slot's
    relative coordinate frame at 0) and the recurrent SSM/RWKV state
    rows (which carry across tokens unmasked). The attention k/v/cpos
    ring entries are deliberately NOT cleared — the slot-recycling
    invariant in the module docstring guarantees every stale entry is
    masked exactly where a fresh cache's −1 entries would be, so the
    recycled slot is bitwise-identical to a fresh one
    (``tests/test_serve_consistency.py``)."""
    new = dict(cache)
    new["offset"] = cache["offset"].at[slot].set(jnp.int32(position))
    groups = []
    for g in cache["groups"]:
        g = dict(g)
        for k in ("ssm_h", "ssm_conv", "ssm_S", "ssm_xprev", "cmix_xprev"):
            if k in g:
                g[k] = g[k].at[:, slot].set(0.0)
        groups.append(g)
    new["groups"] = groups
    return new


# ---------------------------------------------------------------------------
# decode attention (plain masked softmax; GSPMD shards the cache)
# ---------------------------------------------------------------------------

def attn_decode(p, cfg: ModelConfig, x, pos, offset, ck, cv, cpos, *,
                layer: int, window: Optional[int]):
    """x: [B,1,d]; ck/cv: [B,W,kv,hd]; cpos: [B,W]; pos: scalar int32;
    offset: [B] int32 (per-slot frame origin). Inserts the new token's
    KV at its slot's relative ring index then attends. Returns
    (out, ck, cv, cpos)."""
    a = cfg.attn
    cdt = bk._dtype(cfg.compute_dtype)
    xq = x.astype(cdt)
    q = xq @ p["wq"].astype(cdt)
    k_new = xq @ p["wk"].astype(cdt)
    v_new = xq @ p["wv"].astype(cdt)
    B = x.shape[0]
    q = q.reshape(B, 1, a.num_heads, a.head_dim)
    k_new = k_new.reshape(B, 1, a.num_kv_heads, a.head_dim)
    v_new = v_new.reshape(B, 1, a.num_kv_heads, a.head_dim)
    rpos = pos - offset                     # [B] per-slot relative position
    posb = rpos[:, None]                    # [B,1]
    if a.use_rope:
        q = bk.apply_rope(q, posb, a.rope_theta)
        k_new = bk.apply_rope(k_new, posb, a.rope_theta)
    W = ck.shape[1]
    rslot = rpos % W        # ring buffer; full caches have W = S_max >= rpos
    b_idx = jnp.arange(B)
    ck = ck.at[b_idx, rslot].set(k_new[:, 0])
    cv = cv.at[b_idx, rslot].set(v_new[:, 0])
    cpos = cpos.at[b_idx, rslot].set(rpos)

    n_rep = a.num_heads // a.num_kv_heads
    kk = bk._repeat_kv(ck, n_rep)
    vv = bk._repeat_kv(cv, n_rep)
    scale = a.softmax_scale or 1.0 / math.sqrt(a.head_dim)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                        preferred_element_type=jnp.float32) * scale
    kp = cpos[:, None, None, :]             # [B,1,1,W] relative positions
    rq = rpos[:, None, None, None]
    valid = (kp >= 0) & (kp <= rq)
    if window is not None:
        if a.chunked_local:
            valid = valid & ((rq // window) == (kp // window))
        else:
            valid = valid & ((rq - kp) < window)
    if a.logit_cap is not None:
        logits = a.logit_cap * jnp.tanh(logits / a.logit_cap)
    logits = jnp.where(valid, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(vv.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, vv)
    o = o.reshape(B, 1, a.q_dim)
    return (o @ p["wo"].astype(cdt)).astype(x.dtype), ck, cv, cpos


def cross_attn_decode(p, cfg, x, ck, cv):
    a = cfg.attn
    cdt = bk._dtype(cfg.compute_dtype)
    B = x.shape[0]
    q = (x.astype(cdt) @ p["wq"].astype(cdt)).reshape(
        B, 1, a.num_heads, a.head_dim)
    n_rep = a.num_heads // a.num_kv_heads
    kk = bk._repeat_kv(ck, n_rep)
    vv = bk._repeat_kv(cv, n_rep)
    scale = a.softmax_scale or 1.0 / math.sqrt(a.head_dim)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                        preferred_element_type=jnp.float32) * scale
    w = jax.nn.softmax(logits, axis=-1).astype(vv.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, vv).reshape(B, 1, a.q_dim)
    return (o @ p["wo"].astype(cdt)).astype(x.dtype)


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def decode_capacity(cfg: ModelConfig, dist: DistContext, batch: int) -> int:
    """The MoE dispatch capacity one decode step uses for ``batch``
    sequences — the single derivation shared by :func:`decode_step`, the
    decode plan-template key (``plan/cache.py::decode_plan_key``) and
    the launcher's ``--precompute-plans`` (drift here would silently
    miss the cache)."""
    return moe.capacity_for(
        cfg.moe, max(1, batch // max(1, dist.batch_size_divisor)),
        cfg.moe.num_experts, slack=2.0)


def decode_step(params, cfg: ModelConfig, luffy: LuffyConfig,
                dist: DistContext, cache, tokens, *, plan_cache=None):
    """One decode step for the whole batch. tokens: [B,1] int32.
    Returns (logits [B,V], new cache).

    plan_cache (DESIGN.md §13): a :class:`repro.plan.cache.PlanCache`.
    The decode exchange is shape-static per batch slot, so when the
    (batch × capacity × topology) key hits — e.g. after the launcher's
    ``--precompute-plans`` — every MoE sublayer runs through
    ``instantiate_decode_plan`` on the cached template instead of
    ``build_exchange_plan``: zero planning calls in steady-state decode
    (counter-tested), bit-identical logits to the unplanned path. Only
    the single-device / model_size==1 route builds plans at decode; the
    multi-device route is the plan-free all-reduce
    (``moe_decode_allreduce``), so the template is not consulted there.
    """
    period = pattern_period(cfg)
    pos = cache["pos"]
    offset = cache["offset"]
    x = embed_tokens(params, cfg, tokens, dist=dist)
    B = x.shape[0]
    x = dist.constrain(x, P(dist.batch_axes or None, None, None))
    dummy_sb = {"labels": jnp.zeros((B, 1), jnp.int32),
                "seq_len": jnp.full((B,), 1, jnp.int32)}
    cap = decode_capacity(cfg, dist, B) if cfg.uses_moe else 0
    tmpl = None
    if (plan_cache is not None and cfg.uses_moe
            and (not dist.enabled or dist.model_size == 1)):
        from repro.plan.cache import decode_plan_key
        tmpl = plan_cache.get(decode_plan_key(cfg, luffy, dist, B,
                                              capacity=cap))

    def group_body(x, xs):
        p_group, cgroup = xs
        new_groups = []
        for j in range(period):
            p = p_group[j]
            g = dict(cgroup[j])
            window = (cfg.attn.window_for_layer(j)
                      if cfg.attn is not None else None)
            if cfg.attn is not None and cfg.ssm is not None \
                    and cfg.parallel_ssm:
                xn = bk.norm_apply(p["attn_norm"], x, cfg.norm)
                att, g["k"], g["v"], g["cpos"] = attn_decode(
                    p["attn"], cfg, xn, pos, offset, g["k"], g["v"],
                    g["cpos"], layer=j, window=window)
                sso, st = ssm_mod.mamba_step(
                    p["ssm"], cfg, xn,
                    {"h": g["ssm_h"], "conv": g["ssm_conv"]})
                g["ssm_h"], g["ssm_conv"] = st["h"], st["conv"]
                x = x + 0.5 * (att + sso)
            elif cfg.attn is not None:
                xn = bk.norm_apply(p["attn_norm"], x, cfg.norm)
                att, g["k"], g["v"], g["cpos"] = attn_decode(
                    p["attn"], cfg, xn, pos, offset, g["k"], g["v"],
                    g["cpos"], layer=j, window=window)
                x = x + att
            else:
                xn = bk.norm_apply(p["ssm_norm"], x, cfg.norm)
                if cfg.ssm.kind == "mamba":
                    y, st = ssm_mod.mamba_step(
                        p["ssm"], cfg, xn,
                        {"h": g["ssm_h"], "conv": g["ssm_conv"]})
                    g["ssm_h"], g["ssm_conv"] = st["h"], st["conv"]
                else:
                    y, st = ssm_mod.rwkv6_step(
                        p["ssm"], cfg, xn,
                        {"S": g["ssm_S"], "x_prev": g["ssm_xprev"]})
                    g["ssm_S"], g["ssm_xprev"] = st["S"], st["x_prev"]
                x = x + y
            if cfg.kind == "encdec":
                xn = bk.norm_apply(p["cross_norm"], x, cfg.norm)
                x = x + cross_attn_decode(p["cross_attn"], cfg, xn,
                                          g["ck"], g["cv"])
            kind = cfg.ffn_kind(j)
            if kind == "moe":
                y, _, _, _, _, _, _ = _moe_apply_dist(
                    p["moe"], x, dummy_sb, None, jnp.float32(1.0),
                    cfg, luffy, dist, "decode", cap, plan_template=tmpl)
                x = y
            else:
                xn = bk.norm_apply(p["ffn_norm"], x, cfg.norm)
                if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
                    x = x + ssm_mod.rwkv_cmix_apply(
                        p["ffn"], cfg, xn, x_prev=g["cmix_xprev"])
                    g["cmix_xprev"] = xn.astype(jnp.float32)
                else:
                    x = x + bk.ffn_apply(p["ffn"], cfg, xn)
            new_groups.append(g)
        return x, tuple(new_groups)

    stacked = tuple(params["layers"])
    cstacked = tuple(cache["groups"])
    x, new_cgroups = jax.lax.scan(group_body, x, (stacked, cstacked))
    logits = logits_fn(params, cfg, x)[:, 0]
    new_cache = {"groups": list(new_cgroups), "offset": offset,
                 "pos": pos + 1}
    return logits.astype(jnp.float32), new_cache


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill_capacity(cfg: ModelConfig, dist: DistContext, batch: int,
                     seq_len: int) -> int:
    """The MoE dispatch capacity prefill uses for one (batch, seq_len)
    shape — the single derivation shared by :func:`prefill`, the plan
    cache key, and ``launch/serve.py --precompute-plans`` (drift here
    would silently miss the cache)."""
    div = dist.batch_size_divisor
    if dist.seq_axis is not None:
        div *= dist.axis_size(dist.seq_axis)
    return moe.capacity_for(cfg.moe, max(1, batch * seq_len // div),
                            cfg.moe.num_experts)


def prefill(params, cfg: ModelConfig, luffy: LuffyConfig, dist: DistContext,
            tokens, s_max: int, *, prefix=None, enc_input=None,
            plan_cache=None):
    """Full forward over the prompt; builds the decode cache.
    Returns (last-token logits [B,V], cache).

    MoE sublayers run through the shared ``repro.plan`` build/execute
    core (DESIGN.md §7), so ``luffy.exec_mode="pipeline"`` chunks the
    prefill dispatch capacity exactly like the train forward (migration/
    condensation are forced off — serving prompts are not re-homed).

    plan_cache (DESIGN.md §9): a :class:`repro.plan.cache.PlanCache`.
    When the (batch shape × seq len × objective × topology) key hits —
    e.g. after ``--precompute-plans`` — every MoE sublayer runs through
    ``instantiate_plan`` on the cached static template instead of
    ``build_exchange_plan``: zero planning on the request path, with
    the executed forward bit-identical to the uncached one (the
    template's schedule comes from the same ``plan_static_schedule``)."""
    import dataclasses as _dc
    period = pattern_period(cfg)
    x = embed_tokens(params, cfg, tokens, prefix, dist=dist)
    x = dist.constrain(x, dist.act_spec())
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    sb = {"labels": jnp.zeros((B, S), jnp.int32),
          "seq_len": jnp.full((B,), S, jnp.int32)}
    nl = _dc.replace(luffy, enable_condensation=False,
                     enable_migration=False)
    enc_out = None
    if cfg.kind == "encdec":
        enc_x = (enc_input.astype(x.dtype)
                 @ params["prefix_proj"]["w"].astype(x.dtype))
        enc_out = _run_encoder(params["encoder"], cfg, nl, dist, enc_x)
    enc_pos = None if enc_out is None else jnp.broadcast_to(
        jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None],
        enc_out.shape[:2])

    def group_body(x, p_group):
        kvs = []
        for j in range(period):
            p = p_group[j]
            if cfg.attn is not None and cfg.ssm is not None \
                    and cfg.parallel_ssm:
                xn = bk.norm_apply(p["attn_norm"], x, cfg.norm)
                att, kv = bk.attn_apply(p["attn"], cfg, xn, positions,
                                        layer=j, causal=True)
                sso = ssm_mod.mamba_apply(p["ssm"], cfg, xn)
                x = x + 0.5 * (att + sso)
            elif cfg.attn is not None:
                xn = bk.norm_apply(p["attn_norm"], x, cfg.norm)
                att, kv = bk.attn_apply(p["attn"], cfg, xn, positions,
                                        layer=j, causal=True)
                x = x + att
            else:
                xn = bk.norm_apply(p["ssm_norm"], x, cfg.norm)
                if cfg.ssm.kind == "mamba":
                    x = x + ssm_mod.mamba_apply(p["ssm"], cfg, xn)
                else:
                    x = x + ssm_mod.rwkv6_apply(p["ssm"], cfg, xn)
                kv = None
            if cfg.kind == "encdec":
                xn = bk.norm_apply(p["cross_norm"], x, cfg.norm)
                ca, ckv = bk.attn_apply(p["cross_attn"], cfg, xn, positions,
                                        layer=j, kv=(enc_out, enc_pos),
                                        causal=False)
                x = x + ca
            else:
                ckv = None
            kind = cfg.ffn_kind(j)
            if kind == "moe":
                cap = prefill_capacity(cfg, dist, B, S)
                tmpl = None
                if plan_cache is not None:
                    from repro.plan.cache import prefill_plan_key
                    tmpl = plan_cache.get(
                        prefill_plan_key(cfg, nl, dist, B, S, cap))
                y, _, _, _, _, _, _ = _moe_apply_dist(
                    p["moe"], x, sb, None, jnp.float32(1.0), cfg, nl,
                    dist, "vanilla", cap, plan_template=tmpl)
                x = y
            else:
                xn = bk.norm_apply(p["ffn_norm"], x, cfg.norm)
                if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
                    x = x + ssm_mod.rwkv_cmix_apply(p["ffn"], cfg, xn)
                else:
                    x = x + bk.ffn_apply(p["ffn"], cfg, xn)
            kvs.append((kv, ckv))
        return x, tuple(kvs)

    x, kvs = jax.lax.scan(group_body, x, tuple(params["layers"]))
    # NOTE: prefill returns KV for cache building; SSM final states are not
    # captured here (serve driver for SSM archs decodes from scratch or via
    # chunked prefill). For the dry-run shapes, decode_step is what lowers.
    logits = logits_fn(params, cfg, x[:, -1:])[:, 0]
    return logits.astype(jnp.float32), kvs
