"""Continuous-batching request scheduler (DESIGN.md §13).

The unit of work here is a *request*, not a step: sequences are admitted
into free decode cache slots between steps, decoded until their budget
is spent, then evicted so the slot can be recycled for the next queued
request — the batch never drains to refill. The scheduler is pure host
state (no jax); the launcher owns the cache and applies
:func:`repro.serve.engine.admit_slot` for every admission the scheduler
reports, so the decision logic stays unit-testable with a virtual clock.

State machine per request::

    QUEUED --admit--> PREFILL --last prompt token--> DECODE --budget--> DONE
                      (prompt fed token-by-token;     (greedy argmax
                       logits discarded)               feeds itself)

Step protocol (one decode step = one model call over all B slots)::

    sched.submit(prompt, max_new, now=t)        # any time
    for slot, req in sched.admit(now=t):        # fill free slots, FIFO
        cache = engine.admit_slot(cache, slot, int(cache["pos"]))
    toks  = sched.next_feed()                   # [B,1] int32
    logits, cache = dec(params, cache, toks)
    sched.observe(np.asarray(logits), now=t2)   # records generated tokens,
                                                # finishes + evicts requests

SLO accounting (per request, published through the ``repro.obs``
metrics registry by the launcher): ``queue_ms`` (arrival → admission),
``ttft_ms`` (arrival → first generated token), ``tpot_ms`` (mean
inter-token latency after the first). All timestamps are caller-passed,
so tests and the throughput benchmark can drive a virtual clock.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

QUEUED = "queued"
PREFILL = "prefill"
DECODE = "decode"
DONE = "done"

# token fed to idle slots (their logits are discarded; any in-vocab id
# works — the slot's stale cache entries are masked per the recycling
# invariant, and admit_slot restarts the frame before real use)
IDLE_TOKEN = 0


@dataclasses.dataclass
class Request:
    """One serving request and its lifecycle timestamps (seconds; the
    caller picks the clock — wall for serving, virtual for tests)."""
    rid: int
    prompt: np.ndarray                   # [S] int32
    max_new: int
    arrival: float
    state: str = QUEUED
    slot: int = -1
    fed: int = 0                         # prompt tokens fed so far
    generated: List[int] = dataclasses.field(default_factory=list)
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def queue_ms(self) -> Optional[float]:
        if self.admit_time is None:
            return None
        return (self.admit_time - self.arrival) * 1e3

    @property
    def ttft_ms(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return (self.first_token_time - self.arrival) * 1e3

    @property
    def tpot_ms(self) -> Optional[float]:
        """Mean per-output-token latency after the first token."""
        if self.finish_time is None or self.first_token_time is None \
                or len(self.generated) < 2:
            return None
        return ((self.finish_time - self.first_token_time)
                / (len(self.generated) - 1)) * 1e3


class ContinuousScheduler:
    """FIFO admission into ``n_slots`` decode cache slots."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.done: List[Request] = []
        self._next_rid = 0
        self._slot_used = [False] * n_slots   # ever occupied → churn
        # cumulative counters (step_metrics reports per-step deltas)
        self.admitted = 0
        self.finished = 0
        self.generated_tokens = 0
        self.slot_churn = 0                   # admissions into a used slot
        self._last_counts: Dict[str, int] = {}
        self._finished_this_step: List[Request] = []

    # ---- submission / admission -------------------------------------------

    def submit(self, prompt, max_new: int, *, now: float,
               rid: Optional[int] = None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size >= 1 and max_new >= 1
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        req = Request(rid=rid, prompt=prompt, max_new=max_new, arrival=now)
        self.queue.append(req)
        return req

    def admit(self, *, now: float) -> List[Tuple[int, Request]]:
        """Move queued requests into free slots (FIFO). Returns the
        (slot, request) admissions; the caller must apply
        ``engine.admit_slot(cache, slot, pos)`` for each."""
        out: List[Tuple[int, Request]] = []
        for slot in range(self.n_slots):
            if not self.queue:
                break
            if self.slots[slot] is not None:
                continue
            req = self.queue.popleft()
            req.state = PREFILL
            req.slot = slot
            req.admit_time = now
            self.slots[slot] = req
            self.admitted += 1
            if self._slot_used[slot]:
                self.slot_churn += 1
            self._slot_used[slot] = True
            out.append((slot, req))
        return out

    # ---- per-step feed / observe ------------------------------------------

    def next_feed(self) -> np.ndarray:
        """The [B,1] int32 token vector to feed this step."""
        toks = np.full((self.n_slots, 1), IDLE_TOKEN, np.int32)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            if req.fed < len(req.prompt):
                toks[slot, 0] = req.prompt[req.fed]
                req.fed += 1
            else:
                toks[slot, 0] = req.generated[-1]
        return toks

    def observe(self, logits: np.ndarray, *, now: float) -> None:
        """Consume the step's logits [B,V]: greedy-pick generated tokens,
        transition PREFILL→DECODE after the final prompt token, finish +
        evict requests whose budget is spent."""
        self._finished_this_step = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            if req.state == PREFILL:
                if req.fed < len(req.prompt):
                    continue              # mid-prompt logits are discarded
                req.state = DECODE        # these logits predict token 1
            nxt = int(np.argmax(logits[slot]))
            req.generated.append(nxt)
            self.generated_tokens += 1
            if req.first_token_time is None:
                req.first_token_time = now
            if len(req.generated) >= req.max_new:
                req.state = DONE
                req.finish_time = now
                self.finished += 1
                self.done.append(req)
                self._finished_this_step.append(req)
                self.slots[slot] = None   # evict → slot is recyclable
        return None

    # ---- status / metrics --------------------------------------------------

    @property
    def active_slots(self) -> int:
        return sum(1 for r in self.slots if r is not None)

    def all_done(self) -> bool:
        return not self.queue and self.active_slots == 0

    def step_metrics(self) -> Dict[str, float]:
        """Raw metric dict for ``MetricsRegistry.observe`` — counters as
        per-step increments, gauges as current values, SLO gauges as the
        mean over the requests that finished THIS step (omitted when
        none did, so the registry's applicability masking applies)."""
        cur = {"admitted": self.admitted, "finished": self.finished,
               "generated_tokens": self.generated_tokens,
               "slot_churn": self.slot_churn}
        out: Dict[str, float] = {
            k: float(v - self._last_counts.get(k, 0))
            for k, v in cur.items()}
        self._last_counts = cur
        out["active_slots"] = float(self.active_slots)
        out["queued_requests"] = float(len(self.queue))
        fin = self._finished_this_step
        self._finished_this_step = []     # each finish reported once
        for name in ("queue_ms", "ttft_ms", "tpot_ms"):
            vals = [getattr(r, name) for r in fin]
            vals = [v for v in vals if v is not None]
            if vals:
                out[name] = float(np.mean(vals))
        return out
