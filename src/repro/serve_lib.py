"""Serving (prefill + decode) — compatibility shim.

The serving path is a first-class subsystem now: :mod:`repro.serve`
(DESIGN.md §13) owns the engine (``repro.serve.engine`` — cache layout,
decode plan templates, the ``decode_overlap`` schedule) and the
continuous-batching request scheduler (``repro.serve.scheduler``). This
module re-exports the historical names so existing imports
(``repro.serve_lib``) keep working; new code should import from
:mod:`repro.serve`.
"""
from __future__ import annotations

__all__ = [
    "NEG_INF", "admit_slot", "attn_decode", "cache_pspecs", "cache_struct",
    "cross_attn_decode", "decode_capacity", "decode_step", "prefill",
    "prefill_capacity",
]


def __getattr__(name):
    # Lazy delegation instead of eager ``from repro.serve.engine import
    # ...``: the engine pulls in repro.models, whose __init__ imports
    # model.py, which imports THIS module — an eager named import here
    # would read the engine mid-initialization. Deferring to attribute
    # access time breaks the cycle while keeping ``from repro.serve_lib
    # import prefill`` working.
    from repro.serve import engine
    return getattr(engine, name)


def __dir__():
    return sorted(__all__)
