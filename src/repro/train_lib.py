"""Train-step builders: loss + grad + AdamW update, LUFFY state threading.

The adaptive condensation threshold (paper Eq. 2) is a *runtime scalar*
computed in-step from the running loss; the condensation *rate bucket*
(which fixes the static dispatch capacity) is chosen host-side between
steps — one compiled executable per bucket, cached (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import optim
from repro.config import LuffyConfig, ModelConfig, OptimConfig, ShapeConfig
from repro.core import moe_layer
from repro.core.condensation import adaptive_threshold
from repro.dist import DistContext
from repro.models import transformer as tf


class LuffyState(NamedTuple):
    l_ini: jnp.ndarray     # loss at iteration 1 (Eq. 2)
    l_prev: jnp.ndarray    # loss at t-1
    step: jnp.ndarray
    # Cross-step wire error-feedback buffer (DESIGN.md §15): previous
    # step's per-layer payload quantization residuals, shape
    # tf.wire_ef_shape(cfg, B, S). None unless
    # LuffyConfig.wire_error_feedback is on under a lossy wire_dtype.
    wire_ef: Optional[jnp.ndarray] = None


def init_luffy_state(wire_ef_shape: Optional[Tuple[int, ...]] = None
                     ) -> LuffyState:
    ef = (jnp.zeros(wire_ef_shape, jnp.float32)
          if wire_ef_shape is not None else None)
    return LuffyState(jnp.float32(-1.0), jnp.float32(-1.0), jnp.int32(0),
                      ef)


def tokens_per_device(cfg: ModelConfig, shape: ShapeConfig,
                      dist: DistContext) -> int:
    div = dist.batch_size_divisor
    if dist.seq_axis is not None:
        div *= dist.axis_size(dist.seq_axis)
    return max(1, shape.global_batch * shape.seq_len // max(1, div))


def capacity_for_bucket(cfg: ModelConfig, shape: ShapeConfig,
                        dist: DistContext, luffy: LuffyConfig,
                        bucket: int) -> int:
    rate = luffy.rate_buckets[bucket] if luffy.enable_condensation else 0.0
    return moe_layer.capacity_for(
        cfg.moe, tokens_per_device(cfg, shape, dist),
        cfg.moe.num_experts, rate=rate)


def loss_and_metrics(params, batch, lstate: LuffyState, cfg, luffy, dist,
                     capacity):
    if luffy.adaptive_threshold:
        have = lstate.l_ini > 0
        thr = jnp.where(have, adaptive_threshold(lstate.l_ini,
                                                 lstate.l_prev),
                        jnp.float32(0.999))
    else:
        thr = jnp.float32(luffy.static_threshold)
    return tf.forward_train(params, cfg, luffy, dist, batch, thr, capacity,
                            wire_ef=lstate.wire_ef)


def make_train_step(cfg: ModelConfig, luffy: LuffyConfig,
                    ocfg: OptimConfig, dist: DistContext, capacity: int,
                    param_pspecs=None):
    """Returns step(params, opt_state, lstate, batch) ->
    (params, opt_state, lstate, metrics). Not yet jitted (callers attach
    shardings / donation).

    param_pspecs: if given, gradients are sharding-constrained back to the
    parameter layout right after value_and_grad — without this, grads of
    shard_map inputs (spec P('model',…)) stay data-axis-replicated and the
    transient f32 grad tree blows past HBM (ZeRO grad resharding)."""

    def step(params, opt_state, lstate, batch):
        def lf(p):
            loss, metrics = loss_and_metrics(p, batch, lstate, cfg, luffy,
                                             dist, capacity)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        if param_pspecs is not None and dist.enabled:
            grads = jax.tree.map(
                lambda g, sp: dist.constrain(g, sp), grads, param_pspecs)
        params, opt_state, ometrics = optim.update(params, grads, opt_state,
                                                   ocfg)
        metrics = dict(metrics)
        ef_next = metrics.pop("_wire_ef", None)
        metrics.update(ometrics)
        metrics["total_loss"] = loss
        new_l = metrics["loss"]
        lstate2 = LuffyState(
            jnp.where(lstate.l_ini > 0, lstate.l_ini, new_l),
            new_l, lstate.step + 1,
            ef_next if ef_next is not None else lstate.wire_ef)
        return params, opt_state, lstate2, metrics

    return step


def make_eval_step(cfg: ModelConfig, luffy: LuffyConfig, dist: DistContext,
                   capacity: int):
    no_luffy = dataclasses.replace(luffy, enable_condensation=False,
                                   enable_migration=False)

    def step(params, batch):
        loss, metrics = tf.forward_train(params, cfg, no_luffy, dist, batch,
                                         jnp.float32(1.0), capacity)
        return metrics

    return step


def finalize_metrics(metrics, luffy: LuffyConfig):
    """Host-side view of one step's metrics dict: device scalars pulled
    to python floats, config-inapplicable keys masked to ``None`` (an
    ``inter_bytes_shipped`` of 0.0 from a dense-wire run means "nothing
    measured", not "zero bytes"; see ``repro.obs.metrics``)."""
    from repro.obs import metrics as obs_metrics
    out = {}
    for k, v in metrics.items():
        try:
            out[k] = float(v)
        except (TypeError, ValueError):
            out[k] = v
    return obs_metrics.mask_inapplicable(out, luffy)


def pick_bucket_host(luffy: LuffyConfig, threshold: float,
                     observed_rate: float) -> int:
    """Host-side bucket selection: the largest capacity-reduction bucket
    that the *observed* condensation rate supports (hysteresis of one
    bucket to avoid recompile thrash)."""
    best = 0
    for i, r in enumerate(luffy.rate_buckets):
        if r <= max(0.0, observed_rate - 0.05):
            best = i
    return best
