"""Optional-dependency shim for hypothesis (see requirements-dev note in
requirements.txt and DESIGN.md §7).

``hypothesis`` is a dev-only dependency: the property tests use it when
installed; without it they must *skip* — not kill collection of the
whole module (the seed repo hard-imported it and the tier-1 suite died
at collection). Import ``given/settings/st`` from here instead of from
hypothesis directly: when the package is absent the decorators degrade
to ``pytest.mark.skip`` and the strategy objects to inert stubs, so
every non-property test in the same file still runs.
"""
import pytest

try:
    from hypothesis import assume, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dev dependency
    HAVE_HYPOTHESIS = False

    def assume(*_a, **_k):
        return True

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (optional dev dep)")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
