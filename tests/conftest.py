"""Shared fixtures. NOTE: no XLA_FLAGS set here — unit/smoke tests run
against whatever the environment provides (1 real CPU device locally;
CI forces 8 fake host devices, which they must also tolerate).
Multi-device tests spawn subprocesses with their own
--xla_force_host_platform_device_count regardless (see
test_multidevice.py / test_comm.py).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
