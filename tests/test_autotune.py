"""Calibration-driven autotuning (repro.obs.autotune, DESIGN.md §12).

Pins the ISSUE-7 acceptance property — ``autotune_config`` returns the
brute-force argmin of the calibrated model over the grid — plus the
artifact miss discipline (the ``Calibration`` rules exactly), the
explicit-flag precedence of :meth:`TunedConfig.apply`, the structural
constraints of :func:`candidate_grid`, and :func:`rerank`.
"""
import dataclasses
import json

import pytest

from repro.comm.topology import Topology
from repro.config import LuffyConfig
from repro.obs import autotune as at
from repro.obs.calibrate import Calibration, calibration_key

HIER = Topology(4, 2)
WORK = dict(tokens=4096, top_k=2, d_model=512, d_ff=2048, num_layers=4,
            n_moe=2, n_slots=64, num_experts=16, mesh_devices=8,
            group_size=128)


def _tune(topo=HIER, **kw):
    return at.autotune_config(topo=topo, **{**WORK, **kw})


# ------------------------------------------------------------------ grid

def test_grid_defaults_first_and_structural_constraints():
    grid = at.candidate_grid(HIER)
    assert grid[0] == at.DEFAULT_KNOBS
    assert len(grid) == len({json.dumps(g, sort_keys=True) for g in grid})
    for g in grid:
        assert set(g) == set(at.TUNABLE_KNOBS)
        if g["hier_dedup"] == "on":      # dedup wire is universal (§15):
            assert g["comm_mode"] == "hier"   # needs hier comm only
        if g["comm_mode"] == "hier":
            assert HIER.hierarchical
        # planned chunk search <=> overlap objective (launcher coupling)
        assert (g["pipeline_chunks"] <= 0) == \
            (g["plan_objective"] == "overlap")
    # the universal wire pairs with BOTH exec modes in the grid
    assert any(g["hier_dedup"] == "on" and g["exec_mode"] == "pipeline"
               for g in grid)
    flat_grid = at.candidate_grid(Topology.flat(8))
    assert all(g["comm_mode"] == "flat" for g in flat_grid)
    assert len(flat_grid) < len(grid)


# ---------------------------------------------------------------- argmin

def test_autotune_is_bruteforce_argmin_of_model():
    grid = at.candidate_grid(HIER)
    tuned = _tune(grid=grid)
    costs = [at.modeled_step_components(g, topo=HIER, **WORK)["total_ms"]
             for g in grid]
    best = min(range(len(grid)), key=lambda i: costs[i])
    assert tuned.modeled_step_ms == pytest.approx(costs[best])
    assert tuned.knobs == grid[best] or \
        costs[grid.index(tuned.knobs)] == pytest.approx(costs[best])
    assert tuned.default_step_ms == pytest.approx(costs[0])
    assert tuned.candidates == len(grid)
    assert tuned.modeled_step_ms <= tuned.default_step_ms
    assert tuned.modeled_savings_ms == pytest.approx(
        tuned.default_step_ms - tuned.modeled_step_ms)


def test_tie_resolves_to_earliest_candidate():
    """Strict-improvement selection: a grid of identical candidates
    returns the first one (the defaults)."""
    grid = [dict(at.DEFAULT_KNOBS) for _ in range(4)]
    tuned = _tune(grid=grid)
    assert tuned.knobs == at.DEFAULT_KNOBS
    assert tuned.modeled_savings_ms == pytest.approx(0.0)


def test_calibration_changes_the_pricing():
    calib = Calibration(
        key=calibration_key(HIER, HIER.num_devices, backend="cpu"),
        intra_bw=1e9, inter_bw=1e8, intra_lat=1e-5, inter_lat=1e-4,
        chunk_overhead_ms=0.5, plan_step_us=50.0, sim_speed=1e10,
        ffn_speed=1e12)
    tuned = _tune(calib=calib)
    base = _tune()
    assert tuned.calibrated and not base.calibrated
    # slower measured constants: every modeled time strictly grows
    assert tuned.default_step_ms > base.default_step_ms
    assert tuned.modeled_step_ms > base.modeled_step_ms


# -------------------------------------------------- artifact discipline

def test_artifact_roundtrip_identity(tmp_path):
    tuned = _tune()
    path = at.save_tuned(tmp_path, tuned)
    assert path.name == f"{tuned.key}.tuned.json"
    loaded = at.load_tuned(tmp_path, tuned.key)
    assert loaded == tuned


def test_artifact_miss_on_magic_schema_key(tmp_path):
    tuned = _tune()
    good = tuned.to_json()
    assert at.TunedConfig.from_json(good, expect_key=tuned.key) == tuned
    # wrong magic
    bad = json.loads(good)
    bad["magic"] = "not-a-tuned-config"
    assert at.TunedConfig.from_json(json.dumps(bad)) is None
    # schema drift
    bad = json.loads(good)
    bad["schema_version"] = at.TUNED_SCHEMA_VERSION + 1
    assert at.TunedConfig.from_json(json.dumps(bad)) is None
    # stale fingerprint/backend
    assert at.TunedConfig.from_json(good, expect_key="other__cpu") is None
    # missing field
    bad = json.loads(good)
    del bad["knobs"]
    assert at.TunedConfig.from_json(json.dumps(bad)) is None
    # garbage
    assert at.TunedConfig.from_json("{not json") is None
    assert at.TunedConfig.from_json("[1,2]") is None
    # load_tuned enforces the expected key for the directory lookup
    at.save_tuned(tmp_path, tuned)
    assert at.load_tuned(tmp_path, "wrong__key") is None


def test_run_autotune_load_before_search(tmp_path):
    t1 = at.run_autotune(topo=HIER, out_dir=tmp_path, **WORK)
    # second run hits the artifact even under a different workload
    t2 = at.run_autotune(topo=HIER, out_dir=tmp_path,
                         **{**WORK, "tokens": 8 * WORK["tokens"]})
    assert t2 == t1
    # force re-searches under the new workload
    t3 = at.run_autotune(topo=HIER, out_dir=tmp_path, force=True,
                         **{**WORK, "tokens": 8 * WORK["tokens"]})
    assert t3.workload["tokens"] == 8 * WORK["tokens"]
    assert at.load_tuned(tmp_path, t1.key) == t3


# ------------------------------------------------------------- apply

def test_apply_sets_knobs_and_respects_explicit_flags():
    tuned = _tune()
    luffy = LuffyConfig()
    applied = tuned.apply(luffy)
    for k in at.TUNABLE_KNOBS:
        assert getattr(applied, k) == tuned.knobs[k]
    # explicit CLI flags always win
    pinned = dataclasses.replace(LuffyConfig(), exec_mode="sync",
                                 pipeline_chunks=7)
    applied = tuned.apply(pinned, explicit=("exec_mode",
                                            "pipeline_chunks"))
    assert applied.exec_mode == "sync"
    assert applied.pipeline_chunks == 7
    for k in at.TUNABLE_KNOBS:
        if k not in ("exec_mode", "pipeline_chunks"):
            assert getattr(applied, k) == tuned.knobs[k]


# ------------------------------------------------------------- rerank

def test_rerank_prefers_sync_when_measured_ffn_vanishes():
    """A measured expert_ffn far below the model removes the pipelining
    win (nothing to overlap), so refinement must not pick a pipelined
    candidate over sync if sync re-prices cheaper."""
    tuned = _tune(top_n=len(at.candidate_grid(HIER)))
    refined = at.rerank(tuned, {"expert_ffn": 1e-6}, topo=HIER)
    assert refined.refined
    # recompute the re-priced cost of every stored candidate by hand
    def cost(cand):
        c = cand["components"]
        ex = at._exchange_ms_for(
            cand["knobs"], HIER, dispatch_ms=c["dispatch_ms"],
            ffn_ms=c["ffn_ms"] * 1e-6, combine_ms=c["combine_ms"],
            chunk_overhead_ms=at.sched_cost.DEFAULT_CHUNK_OVERHEAD_MS)
        return ex + c["planning_ms"] + c["similarity_ms"]
    best = min(tuned.top, key=cost)
    assert refined.modeled_step_ms == pytest.approx(cost(best))
    assert refined.knobs == best["knobs"]


def test_rerank_step_ratio_scales_all_components():
    tuned = _tune()
    r1 = at.rerank(tuned, {"step": 2.0}, topo=HIER)
    r2 = at.rerank(tuned, {"dispatch": 2.0, "expert_ffn": 2.0,
                           "combine": 2.0}, topo=HIER)
    assert r1.modeled_step_ms == pytest.approx(r2.modeled_step_ms)
    assert r1.knobs == r2.knobs


def test_rerank_without_top_is_identity():
    tuned = dataclasses.replace(_tune(), top=[])
    assert at.rerank(tuned, {"step": 3.0}, topo=HIER) == tuned
