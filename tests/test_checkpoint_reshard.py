"""Checkpoint resharding: save from one mesh layout, restore onto a
different one (the production restart-on-different-topology path)."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_checkpoint_reshards_across_meshes(tmp_path):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro import checkpoint
        from repro.config import reduced
        from repro.configs import get_config
        from repro.dist import DistContext
        from repro.models.model import build_model

        cfg = reduced(get_config("olmoe-1b-7b"))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))

        from repro.comm import make_mesh
        mesh_a = make_mesh((2, 4), ("data", "model"))
        dist_a = DistContext(mesh_a, batch_axes=("data", "model"),
                             fsdp_axes=("data",))
        specs_a = model.param_pspecs(dist_a)
        p_a = jax.device_put(params, jax.tree.map(
            lambda s: dist_a.sharding(s), specs_a))
        checkpoint.save("{tmp_path}/ck", p_a, pspecs=specs_a, step=3)

        mesh_b = make_mesh((4, 2), ("data", "model"))
        dist_b = DistContext(mesh_b, batch_axes=("data", "model"),
                             fsdp_axes=("data",))
        specs_b = model.param_pspecs(dist_b)
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                            params)
        shardings = jax.tree.map(lambda s: dist_b.sharding(s), specs_b)
        p_b, step = checkpoint.restore("{tmp_path}/ck", like,
                                       shardings=shardings)
        assert step == 3
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored leaves actually live on mesh B
        leaf = jax.tree.leaves(p_b)[0]
        assert leaf.sharding.mesh.shape == {{"data": 4, "model": 2}}
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
