"""repro.comm: topology descriptor, link-cost-weighted migration,
hierarchical two-phase collectives (subprocess, 8 host devices), and the
inter-node dedup traffic ledger (DESIGN.md §5)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.comm import (Topology, dispatch_bytes, expected_dedup_factor,
                        simulate_dispatch_rows)
from repro.core import migration as mig

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------

def test_topology_link_cost_matrix():
    t = Topology(num_nodes=2, devices_per_node=2, intra_bw=4e10,
                 inter_bw=1e10)
    c = t.link_cost()
    assert c.shape == (4, 4)
    assert np.allclose(np.diag(c), 0.0)
    assert c[0, 1] == 1.0 and c[2, 3] == 1.0          # intra-node
    assert c[0, 2] == 4.0 and c[1, 3] == 4.0          # bw_ratio across
    assert np.array_equal(c, c.T)
    assert t.bw_ratio == 4.0
    assert np.array_equal(np.asarray(t.node_of(np.arange(4))), [0, 0, 1, 1])


def test_flat_topology_degenerates_to_uniform():
    t = Topology.flat(4)
    assert not t.hierarchical
    c = t.link_cost()
    assert np.array_equal(c, np.ones((4, 4)) - np.eye(4))


# ---------------------------------------------------------------------------
# t_att host/device parity (cost-model normalization)
# ---------------------------------------------------------------------------

def test_t_att_parity_host_vs_traced():
    import jax.numpy as jnp
    want = (3.0 * 2 * 128 * 64 * 64 + 2.0 * 2 * 128 * 128 * 64) / 1e9
    host_scalar = mig.t_att(2, 128, 64, 1e9)
    host_np = mig.t_att(np.int64(2), np.int64(128), 64, 1e9)
    traced = mig.t_att(jnp.float32(2), jnp.float32(128), 64, 1e9)
    assert isinstance(host_scalar, float)             # no device round-trip
    assert isinstance(host_np, np.floating)
    assert abs(host_scalar - want) < 1e-9
    assert abs(float(host_np) - want) < 1e-9
    assert abs(float(traced) - want) / want < 1e-6    # f32 vs f64


# ---------------------------------------------------------------------------
# link-cost-weighted migration planning
# ---------------------------------------------------------------------------

def _instance(seed, n_slots, M):
    r = np.random.default_rng(seed)
    counts = (r.random((n_slots, M)) ** 3)
    counts = (counts / counts.sum(1, keepdims=True) * 100).astype(np.int64)
    counts = counts + r.random(counts.shape) * 1e-3   # break ties
    lens = r.integers(10, 100, n_slots).astype(np.int64)
    return counts.astype(np.float64), lens


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_plan_np_uniform_link_cost_matches_none(seed):
    """An explicit uniform matrix must reproduce the no-matrix plan."""
    counts, lens = _instance(seed, 8, 4)
    base = mig.plan_migration_np(counts, lens, 2, q=2)
    uni = mig.plan_migration_np(counts, lens, 2, q=2,
                                link_cost=np.ones((4, 4)) - np.eye(4))
    np.testing.assert_array_equal(np.asarray(base.assign),
                                  np.asarray(uni.assign))
    assert float(base.traffic_after) == float(uni.traffic_after)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_plan_np_jax_linkcost_parity(seed):
    """np and jax planners stay in lock-step under a hierarchical cost
    matrix, produce valid bijections, and never worsen weighted traffic."""
    topo = Topology(num_nodes=2, devices_per_node=2)
    cost = topo.link_cost()
    counts, lens = _instance(seed, 8, 4)
    p_np = mig.plan_migration_np(counts, lens, 2, q=2, link_cost=cost)
    p_jx = mig.plan_migration_jax(
        np.asarray(counts, np.float32), np.asarray(lens, np.float32), 2,
        q=2, link_cost=cost)
    np.testing.assert_array_equal(np.asarray(p_np.assign),
                                  np.asarray(p_jx.assign))
    np.testing.assert_array_equal(np.asarray(p_np.perm),
                                  np.asarray(p_jx.perm))
    perm = np.asarray(p_np.perm)
    assert sorted(perm.tolist()) == list(range(8))
    assert float(p_np.traffic_after) <= float(p_np.traffic_before) + 1e-6
    assert abs(float(p_np.traffic_after) - float(p_jx.traffic_after)) \
        < 1e-2 * max(1.0, float(p_np.traffic_after))


def test_plan_weighted_prefers_intra_node():
    """A slot pulled equally by an intra-node and an inter-node device
    must be homed on the cheap link."""
    topo = Topology(num_nodes=2, devices_per_node=2, intra_bw=8e10,
                    inter_bw=1e10)
    # slot 0 lives on device 0; devices 1 (same node) and 2 (other node)
    # each host 50 of its token copies.
    counts = np.zeros((4, 4)) + 1e-3
    counts[0, 1] = 50.0
    counts[0, 2] = 50.0
    lens = np.array([40, 30, 20, 10])
    plan = mig.plan_migration_np(counts, lens, 1, q=4,
                                 link_cost=topo.link_cost())
    # homed at 0 or 1 the copies on device 1 travel cheap links and only
    # device 2's cross nodes; homed at 2 or 3 the device-1 copies cross
    # too. With bw_ratio 8 the weighted greedy must stay on node 0 (the
    # unweighted objective is indifferent between devices 1 and 2).
    assert int(plan.assign[0]) in (0, 1)


# ---------------------------------------------------------------------------
# analytic dedup ledger
# ---------------------------------------------------------------------------

def test_expected_dedup_factor_bounds():
    topo = Topology(num_nodes=4, devices_per_node=4)
    assert expected_dedup_factor(1, topo) == 1.0
    f2 = expected_dedup_factor(2, topo)
    f4 = expected_dedup_factor(4, topo)
    assert 0.0 < f4 < f2 < 1.0
    flat = Topology.flat(16)
    assert expected_dedup_factor(4, flat) == 1.0


def test_dispatch_bytes_dedup_and_condensation_shrink_inter():
    topo = Topology(num_nodes=2, devices_per_node=4)
    _, inter_flat = dispatch_bytes(1024, 2, 64, topo=topo)
    _, inter_hier = dispatch_bytes(1024, 2, 64, topo=topo, dedup=True)
    _, inter_cond = dispatch_bytes(1024, 2, 64, topo=topo, dedup=True,
                                   r_cond=0.5)
    assert inter_hier < inter_flat
    assert inter_cond < inter_hier
    mc = np.random.default_rng(0)
    flat_r, dedup_r, _ = simulate_dispatch_rows(mc, 2048, 2, topo)
    # monte-carlo (distinct top-k draws) tracks the independent-draw
    # closed form to within a few percent
    assert abs(dedup_r / flat_r
               - expected_dedup_factor(2, topo)) < 0.06


def test_commsim_hier_variants():
    from repro.core import commsim
    from repro.configs import get_config
    cfg = get_config("moe-gpt2", num_experts=8)
    setup = commsim.PaperSetup(cfg=cfg)
    comp, comm = commsim.PAPER_VANILLA["moe-gpt2"][8]
    cal = commsim.calibrate(setup, comp, comm)
    van = commsim.predict(setup, cal, system="vanilla")
    vh = commsim.predict(setup, cal, system="vanilla-hier",
                         topo=commsim.default_topology(8, nodes=2,
                                                       bw_ratio=4.0))
    lh = commsim.predict(setup, cal, system="luffy-hier",
                         topo=commsim.default_topology(8, nodes=2,
                                                       bw_ratio=4.0))
    # hierarchical vanilla beats flat vanilla (dedup + cheap intra links)
    assert vh["comm_ms"] < van["comm_ms"]
    assert lh["comm_ms"] < vh["comm_ms"]              # + condensation
    assert vh["comp_ms"] == pytest.approx(van["comp_ms"])


# ---------------------------------------------------------------------------
# multi-device: hierarchical collectives + end-to-end comm_mode parity
# (subprocesses with 8 forced host devices, like test_multidevice.py)
# ---------------------------------------------------------------------------

def _run(script_body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import (CommContext, Topology, hier_all_to_all,
                                make_mesh, shard_map)
    """) + textwrap.dedent(script_body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_hier_all_to_all_matches_flat_collective():
    out = _run("""
        N, L, R = 2, 4, 5
        M = N * L
        mesh = make_mesh((N, L), ("node", "local"))
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            (M * M, R)), jnp.float32)

        flat = shard_map(
            lambda b: jax.lax.all_to_all(b, ("node", "local"), split_axis=0,
                                         concat_axis=0, tiled=True),
            mesh=mesh, in_specs=P(("node", "local"), None),
            out_specs=P(("node", "local"), None))(x)
        hier = shard_map(
            lambda b: hier_all_to_all(b, "node", "local"),
            mesh=mesh, in_specs=P(("node", "local"), None),
            out_specs=P(("node", "local"), None))(x)
        assert np.array_equal(np.asarray(flat), np.asarray(hier))
        # involution: routing back restores the input exactly
        back = shard_map(
            lambda b: hier_all_to_all(b, "node", "local"),
            mesh=mesh, in_specs=P(("node", "local"), None),
            out_specs=P(("node", "local"), None))(hier)
        assert np.array_equal(np.asarray(back), np.asarray(x))
        print("OK")
    """)
    assert "OK" in out


def test_comm_mode_hier_bit_identical_and_dedups_inter_bytes():
    out = _run("""
        from repro.configs import get_config
        from repro.config import reduced, LuffyConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.dist import DistContext
        from repro.data import SyntheticLM
        from repro.core.moe_layer import capacity_for

        cfg = reduced(get_config("moe-gpt2"), num_layers=2)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        shape = ShapeConfig("t", 128, 8, "train")
        data = SyntheticLM(cfg, shape)
        b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

        mesh = make_mesh((2, 2, 2), ("data", "node", "local"))
        topo = Topology(num_nodes=2, devices_per_node=2)
        dist = DistContext(mesh, batch_axes=("data", "node", "local"),
                           seq_axis=None, fsdp_axes=("data",),
                           model_axis=("node", "local"), topology=topo)
        cap = capacity_for(cfg.moe, 128, cfg.moe.num_experts, slack=8.0)
        flat = LuffyConfig(enable_condensation=True, enable_migration=True,
                           combine_slack=4.0, condense_group=64,
                           comm_mode="flat")
        hier = dataclasses.replace(flat, comm_mode="hier")
        lf, mf = jax.jit(lambda p, bb: model.train_loss(
            p, bb, jnp.float32(0.4), luffy=flat, dist=dist,
            capacity=cap))(params, b)
        lh, mh = jax.jit(lambda p, bb: model.train_loss(
            p, bb, jnp.float32(0.4), luffy=hier, dist=dist,
            capacity=cap))(params, b)
        # bit-identical layer outputs -> bit-identical loss
        assert float(lf) == float(lh), (float(lf), float(lh))
        assert float(mh["condense_rate"]) > 0.0
        # the hier path ships strictly fewer inter-node dispatch bytes
        assert float(mh["inter_bytes_flat"]) > 0.0
        assert float(mh["inter_bytes_dedup"]) < float(mh["inter_bytes_flat"])
        # the flat path's ledger shows no dedup (ships every copy)
        assert float(mf["inter_bytes_dedup"]) == float(mf["inter_bytes_flat"])
        print("OK", float(lf),
              float(mh["inter_bytes_dedup"]) / float(mh["inter_bytes_flat"]))
    """)
    assert "OK" in out


def test_hier_mesh_vanilla_matches_single_device():
    """The hierarchical mesh + two-phase collectives reproduce the
    single-device forward (sanity against relabeling bugs)."""
    out = _run("""
        from repro.configs import get_config
        from repro.config import reduced, LuffyConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.dist import DistContext, single_device
        from repro.data import SyntheticLM
        from repro.core.moe_layer import capacity_for

        cfg = reduced(get_config("moe-gpt2"), num_layers=2)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        shape = ShapeConfig("t", 128, 8, "train")
        data = SyntheticLM(cfg, shape)
        b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        off = LuffyConfig(enable_condensation=False, enable_migration=False,
                          comm_mode="hier")
        cap1 = capacity_for(cfg.moe, 8 * 128, cfg.moe.num_experts, slack=8.0)
        cap8 = capacity_for(cfg.moe, 128, cfg.moe.num_experts, slack=8.0)
        l1, _ = model.train_loss(params, b, jnp.float32(1.0), luffy=off,
                                 dist=single_device(), capacity=cap1)
        mesh = make_mesh((2, 2, 2), ("data", "node", "local"))
        dist = DistContext(mesh, batch_axes=("data", "node", "local"),
                           seq_axis=None, fsdp_axes=("data",),
                           model_axis=("node", "local"),
                           topology=Topology(2, 2))
        l2, m2 = jax.jit(lambda p, bb: model.train_loss(
            p, bb, jnp.float32(1.0), luffy=off, dist=dist,
            capacity=cap8))(params, b)
        assert abs(float(l1) - float(l2)) < 5e-3, (float(l1), float(l2))
        assert float(m2["dispatch_drop"]) == 0.0
        print("OK", float(l1), float(l2))
    """)
    assert "OK" in out
