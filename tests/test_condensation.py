"""Unit + property tests for token condensation (paper §V)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st   # optional dep; skips when absent

from repro.core import condensation as cond


def test_adaptive_threshold_monotone():
    """Eq. 2: threshold starts ~0.5 at zero loss decrease and FALLS as the
    loss drops (condense more later in training)."""
    l_ini = 10.0
    prev = np.linspace(10.0, 1.0, 20)
    th = [float(cond.adaptive_threshold(l_ini, p)) for p in prev]
    assert abs(th[0] - 0.5) < 1e-6
    assert all(a >= b for a, b in zip(th, th[1:]))
    assert th[-1] < 0.3


def test_pairwise_cosine_range(rng):
    x = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    c = cond.pairwise_cosine(x)
    assert float(jnp.min(c)) >= -1e-6 and float(jnp.max(c)) <= 1 + 1e-6
    np.testing.assert_allclose(np.diag(np.asarray(c)), 1.0, atol=1e-5)


def test_fast_similarity_skip_rules(rng):
    """§V-A: cross-expert pairs are 0; s_prev>s1 pairs forced to 1;
    s_prev<s2 pairs 0; only the uncertain remainder measured."""
    G, d = 32, 16
    x = jnp.asarray(rng.standard_normal((G, d)), jnp.float32)
    e = jnp.asarray(rng.integers(0, 2, G))
    s_prev = jnp.asarray(rng.random((G, G)), jnp.float32)
    sim, measured = cond.fast_similarity(x, e, s_prev, 0.8, 0.2)
    same = np.asarray(e)[:, None] == np.asarray(e)[None, :]
    sp = np.asarray(s_prev)
    s = np.asarray(sim)
    assert (s[~same] == 0).all()
    assert (s[same & (sp > 0.8)] == 1.0).all()
    assert (s[same & (sp < 0.2)] == 0.0).all()
    assert float(measured) < 1.0


def test_condense_identical_tokens():
    """Identical tokens routed to the same expert collapse to one rep."""
    G = 16
    x = jnp.ones((G, 8), jnp.float32)
    e = jnp.zeros((G,), jnp.int32)
    out = cond.condense_tokens(x, e, 0.9, group_size=G)
    assert int(out.is_rep.sum()) == 1
    assert float(out.rate) == 1.0 - 1.0 / G
    # all tokens point at the same representative
    assert len(np.unique(np.asarray(out.rep_idx))) == 1


def test_condense_distinct_tokens(rng):
    """Orthogonal tokens condense nothing at a high threshold."""
    G = 8
    x = jnp.eye(G, 32, dtype=jnp.float32)
    e = jnp.zeros((G,), jnp.int32)
    out = cond.condense_tokens(x, e, 0.95, group_size=G)
    assert bool(jnp.all(out.is_rep))
    assert float(out.rate) == 0.0
    np.testing.assert_array_equal(np.asarray(out.rep_idx), np.arange(G))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16, 32]),
       st.integers(1, 4), st.floats(0.3, 0.95))
def test_condense_properties(seed, G, n_experts, threshold):
    """Properties: rep_idx is a valid projection (rep of a rep is itself),
    reps never point across expert boundaries or groups, rate matches."""
    r = np.random.default_rng(seed)
    T = 2 * G
    x = jnp.asarray(r.standard_normal((T, 12)), jnp.float32)
    e = jnp.asarray(r.integers(0, n_experts, T), jnp.int32)
    out = cond.condense_tokens(x, e, threshold, group_size=G)
    rep = np.asarray(out.rep_idx)
    # projection: rep[rep[i]] == rep[i]
    np.testing.assert_array_equal(rep[rep], rep)
    # same expert + same group
    ee = np.asarray(e)
    assert (ee[rep] == ee).all()
    assert (rep // G == np.arange(T) // G).all()
    # rate consistency
    np.testing.assert_allclose(
        float(out.rate), 1.0 - np.mean(rep == np.arange(T)), atol=1e-6)


def test_uncondense_semantics(rng):
    y = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    rep = jnp.asarray([0, 0, 2, 2, 4, 4, 6, 6], jnp.int32)
    out = np.asarray(cond.uncondense(y, rep))
    np.testing.assert_array_equal(out[1], np.asarray(y)[0])
    np.testing.assert_array_equal(out[3], np.asarray(y)[2])


def test_kernel_path_matches_jnp(rng):
    """condense_tokens(use_kernel=True) == use_kernel=False."""
    G, d = 128, 64
    x = jnp.asarray(rng.standard_normal((G, d)), jnp.float32)
    e = jnp.asarray(rng.integers(0, 4, G), jnp.int32)
    a = cond.condense_tokens(x, e, 0.7, group_size=G, use_kernel=False)
    b = cond.condense_tokens(x, e, 0.7, group_size=G, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(a.rep_idx),
                                  np.asarray(b.rep_idx))


def test_similarity_quantiles_same_expert_masking(rng):
    """Quantiles must cover only off-diagonal same-expert pairs — the
    pairs condensation can merge — not the mostly-zero full matrix."""
    G = 8
    e = np.array([0, 0, 0, 0, 1, 1, 1, 1])
    sim = np.zeros((G, G))
    same = e[:, None] == e[None, :]
    sim[same] = 0.9                          # condensable pairs: high
    np.fill_diagonal(sim, 1.0)
    q = cond.similarity_quantiles(sim, expert_idx=e)
    assert q.shape == (11,)
    # every masked value is 0.9: all deciles equal it (no zeros, no diag)
    np.testing.assert_allclose(q, 0.9)
    # unmasked: the cross-expert zeros dominate the low deciles (the
    # diagonal stays excluded in both modes)
    q_all = cond.similarity_quantiles(sim, same_expert_only=False)
    assert q_all[0] == 0.0 and q_all[-1] == 0.9
    with pytest.raises(ValueError):
        cond.similarity_quantiles(sim)       # mask needs expert ids
    # batched [n_groups, G, G] input, as produced by condense_tokens
    q_b = cond.similarity_quantiles(
        np.stack([sim, sim]), expert_idx=np.stack([e, e]))
    np.testing.assert_allclose(q_b, 0.9)
