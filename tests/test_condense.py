"""repro.condense (DESIGN.md §10): the similarity-backend registry
("exact" == legacy bit-for-bit, "lsh" measures strictly fewer pairs with
full recall on identical tokens), condense-plan reuse (signature
revalidation + staleness bound, builds drop to 1 per forward), the
deduplicated hier wire (dispatch reconstruction bit-identical, combine
within tolerance, shipped == modeled bytes) and the serial-format /
PlanCache params_version bump."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st   # optional dep; skips when absent

from repro.comm import CommContext
from repro.condense import (CondenseCarry, available_similarity_backends,
                            condense_tokens, expected_measured_pairs,
                            fast_similarity, get_similarity_backend,
                            lsh_codes)
from repro.condense import backends as cbk
from repro.config import LuffyConfig, ModelConfig, MoEConfig
from repro.core import moe_layer as ml
from repro.core.gating import gate_apply
from repro.plan import (PlanCache, PlanFormatError, from_bytes,
                        build_exchange_plan, execute_plan, plan_key,
                        to_bytes)

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

def test_registry_lookup_and_error():
    assert set(available_similarity_backends()) >= {"exact", "lsh"}
    assert get_similarity_backend("exact") is cbk.exact_backend
    with pytest.raises(ValueError, match="exact"):
        get_similarity_backend("nope")


def test_registry_extensible():
    @cbk.register_similarity_backend("_test_none")
    def none_backend(x, uncertain, *, use_kernel=False, lsh_bits=8,
                     lsh_seed=0):
        G = x.shape[0]
        eye = jnp.eye(G, dtype=bool)
        return jnp.where(eye, 1.0, 0.0), eye

    try:
        sim, measured = fast_similarity(
            jnp.ones((8, 4), jnp.float32), jnp.zeros((8,), jnp.int32),
            None, 0.8, 0.2, backend="_test_none")
        # only the diagonal was measured
        assert float(measured) == pytest.approx(1.0 / 8)
    finally:
        cbk.SIMILARITY_BACKENDS.pop("_test_none")


def test_exact_backend_reproduces_legacy_skip_rules(rng):
    """The registry's "exact" entry is the historical §V-A path: the
    masked values equal pairwise_cosine under the skip-rule masks."""
    G, d = 32, 16
    x = jnp.asarray(rng.standard_normal((G, d)), jnp.float32)
    e = jnp.asarray(rng.integers(0, 2, G))
    s_prev = jnp.asarray(rng.random((G, G)), jnp.float32)
    sim, measured = fast_similarity(x, e, s_prev, 0.8, 0.2,
                                    backend="exact")
    same = np.asarray(e)[:, None] == np.asarray(e)[None, :]
    sp = np.asarray(s_prev)
    s = np.asarray(sim)
    cos = np.asarray(cbk.pairwise_cosine(x))
    uncertain = same & ~(sp > 0.8) & ~(sp < 0.2)
    np.testing.assert_array_equal(s[uncertain], cos[uncertain])
    assert (s[~same] == 0).all()
    assert (s[same & (sp > 0.8)] == 1.0).all()
    assert float(measured) == pytest.approx(uncertain.mean())


def test_lsh_measures_strictly_fewer_pairs_on_random_tokens(rng):
    G, d = 256, 64
    x = jnp.asarray(rng.standard_normal((G, d)), jnp.float32)
    e = jnp.asarray(rng.integers(0, 4, G), jnp.int32)
    a = condense_tokens(x, e, 0.9, group_size=G, backend="exact")
    b = condense_tokens(x, e, 0.9, group_size=G, backend="lsh")
    assert float(b.measured_pairs) < float(a.measured_pairs)
    # codes are deterministic (fixed host-side projections)
    np.testing.assert_array_equal(np.asarray(lsh_codes(x)),
                                  np.asarray(lsh_codes(x)))


def test_lsh_identical_tokens_condense_like_exact():
    """Duplicate-heavy groups: identical tokens always share a bucket,
    so the LSH backend condenses them at exactly the exact rate."""
    G, d = 32, 16
    uniq = np.eye(G // 4, d, dtype=np.float32)        # orthogonal uniques
    x = jnp.asarray(np.repeat(uniq, 4, axis=0))       # 4 clones each
    e = jnp.asarray(np.repeat(np.arange(G // 4) % 2, 4), jnp.int32)
    a = condense_tokens(x, e, 0.9, group_size=G, backend="exact")
    b = condense_tokens(x, e, 0.9, group_size=G, backend="lsh",
                        lsh_bits=8)
    np.testing.assert_array_equal(np.asarray(a.rep_idx),
                                  np.asarray(b.rep_idx))
    assert float(a.rate) == float(b.rate) == 0.75


def test_expected_measured_pairs_model():
    ex = expected_measured_pairs(1024, 128, 8, backend="exact")
    ls = expected_measured_pairs(1024, 128, 8, backend="lsh", lsh_bits=8)
    assert 0 < ls < ex
    with pytest.raises(ValueError):
        expected_measured_pairs(1024, 128, 8, backend="nope")


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 32]),
       st.sampled_from([4, 8]))
def test_lsh_identical_token_recall_property(seed, G, bits):
    """Property: on groups built from orthogonal uniques + exact clones,
    LSH reps == exact reps for any seed/bits (identical tokens collide
    with probability 1)."""
    r = np.random.default_rng(seed)
    n_uniq = G // 4
    uniq = np.eye(n_uniq, 24, dtype=np.float32) * (1 + r.random(1))
    x = jnp.asarray(np.repeat(uniq, 4, axis=0))
    e = jnp.asarray(np.repeat(r.integers(0, 3, n_uniq), 4), jnp.int32)
    a = condense_tokens(x, e, 0.9, group_size=G, backend="exact")
    b = condense_tokens(x, e, 0.9, group_size=G, backend="lsh",
                        lsh_bits=bits, lsh_seed=seed % 7)
    np.testing.assert_array_equal(np.asarray(a.rep_idx),
                                  np.asarray(b.rep_idx))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 6, 8]))
def test_lsh_perturbed_clone_recall_property(seed, bits):
    """Property: small perturbations rarely flip projection signs — the
    fraction of (token, clone) pairs the LSH backend still measures
    stays above the recall floor."""
    r = np.random.default_rng(seed)
    G, d = 64, 32
    base = r.standard_normal((G // 2, d)).astype(np.float32)
    clones = base + 0.01 * r.standard_normal((G // 2, d)).astype(
        np.float32) * np.abs(base).mean()
    x = jnp.asarray(np.concatenate([base, clones], 0))
    codes = np.asarray(lsh_codes(x, bits=bits, seed=0))
    recall = float(np.mean(codes[:G // 2] == codes[G // 2:]))
    assert recall >= 0.6, (seed, bits, recall)


# ---------------------------------------------------------------------------
# condense-plan reuse (single device; the 8-dev golden test is below)
# ---------------------------------------------------------------------------

def _mk(num_experts=4, top_k=2):
    return ModelConfig(
        name="t", kind="decoder", family="moe", num_layers=2,
        d_model=32, d_ff=64, vocab_size=128,
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, d_ff=64),
        layer_ffn_pattern=("moe",), compute_dtype="float32",
        param_dtype="float32")


def _plan_with_carry(luffy, carry, s_prev, threshold=0.7, seed=1):
    from repro.models.blocks import _dtype
    cfg = _mk()
    p = ml.moe_init(jax.random.PRNGKey(0), cfg)
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    sb = {"labels": jnp.zeros((2, 16), jnp.int32),
          "seq_len": jnp.full((2,), 16, jnp.int32)}
    xn = ml._rms(x.reshape(-1, cfg.d_model),
                 p["norm"]["scale"]).astype(_dtype(cfg.compute_dtype))
    gate = gate_apply(p["router"], xn, cfg.moe.top_k)
    plan = build_exchange_plan(
        gate, xn, cfg, luffy, CommContext.local(), mode="vanilla",
        capacity=256, sideband=sb, threshold=jnp.float32(threshold),
        group_size=16, s_prev=s_prev, condense_reuse_from=carry)
    return cfg, p, x, sb, plan


def _zero_carry(T=32, n_seq=2):
    return CondenseCarry(jnp.zeros((T,), jnp.int32),
                         jnp.zeros((T,), jnp.int32),
                         jnp.zeros((n_seq,), jnp.float32),
                         jnp.zeros((n_seq,), jnp.float32))


def test_condense_reuse_matches_rebuild_on_stable_frame():
    """The reuse guarantee at the API level: revalidating against the
    exact frame the plan was built on emits a rep map bit-identical to
    a full rebuild (same deterministic inputs), with the similarity
    build skipped (measured_pairs == 0, reused counter set)."""
    luffy = LuffyConfig(enable_condensation=True, enable_migration=False,
                        condense_group=16, condense_reuse="signature")
    s_prev = jnp.full((2, 16, 16), 0.5, jnp.float32)
    cfg, p, x, sb, p1 = _plan_with_carry(luffy, _zero_carry(), s_prev)
    assert float(p1.condense_plan.built) == 1.0      # seed layer builds
    _, aux1 = execute_plan(p, x, dict(sb), p1, cfg)
    cc = aux1.cond_carry
    assert cc is not None
    carry = CondenseCarry(cc["rep"].reshape(-1), cc["cexp"].reshape(-1),
                          cc["age"], cc["valid"])
    _, _, _, _, p2 = _plan_with_carry(luffy, carry, p1.s_next)
    cp = p2.condense_plan
    assert float(cp.reused) == 1.0 and float(cp.built) == 0.0
    assert float(cp.measured_pairs) == 0.0
    nl = dataclasses.replace(luffy, condense_reuse="off")
    _, _, _, _, p2f = _plan_with_carry(nl, None, p1.s_next)
    np.testing.assert_array_equal(np.asarray(p2.rep_idx),
                                  np.asarray(p2f.rep_idx))


def test_condense_reuse_staleness_and_expert_drift():
    luffy = LuffyConfig(enable_condensation=True, enable_migration=False,
                        condense_group=16, condense_reuse="signature",
                        condense_reuse_max_age=1)
    s_prev = jnp.full((2, 16, 16), 0.5, jnp.float32)
    cfg, p, x, sb, p1 = _plan_with_carry(luffy, _zero_carry(), s_prev)
    _, aux1 = execute_plan(p, x, dict(sb), p1, cfg)
    cc = aux1.cond_carry
    carry = CondenseCarry(cc["rep"].reshape(-1), cc["cexp"].reshape(-1),
                          cc["age"], cc["valid"])
    # age at the bound: the carried plan is stale, a rebuild runs
    old = carry._replace(age=jnp.full((2,), 1.0, jnp.float32))
    _, _, _, _, p2 = _plan_with_carry(luffy, old, p1.s_next)
    assert float(p2.condense_plan.built) == 1.0
    # expert drift: merged tokens no longer share an expert -> rebuild
    drift = carry._replace(expert=carry.expert + 1)
    _, _, _, _, p3 = _plan_with_carry(luffy, drift, p1.s_next)
    assert float(p3.condense_plan.built) == 1.0
    # "off" pins the EMITTED valid flag (like migration plan_reuse, the
    # pin is at emission): within an "off" stack the carry never
    # revalidates, so every sublayer rebuilds with the same graph
    off = LuffyConfig(enable_condensation=True, enable_migration=False,
                      condense_group=16, condense_reuse="off")
    _, _, _, _, p4 = _plan_with_carry(off, _zero_carry(), s_prev)
    assert float(p4.condense_plan.built) == 1.0
    assert float(jnp.max(p4.condense_plan.signature.valid)) == 0.0
    sig4 = p4.condense_plan.signature
    off_carry = CondenseCarry(p4.condense_plan.rep_idx % 16, sig4.expert,
                              sig4.age, sig4.valid)
    _, _, _, _, p4b = _plan_with_carry(off, off_carry, p4.s_next)
    assert float(p4b.condense_plan.built) == 1.0
    # "always" skips the expert compare (age bound still applies)
    alw = LuffyConfig(enable_condensation=True, enable_migration=False,
                      condense_group=16, condense_reuse="always")
    _, _, _, _, p5 = _plan_with_carry(alw, drift, p1.s_next)
    assert float(p5.condense_plan.reused) == 1.0


# ---------------------------------------------------------------------------
# serial format v2 + PlanCache params_version (ISSUE 5 satellite)
# ---------------------------------------------------------------------------

def _vanilla_plan():
    luffy = LuffyConfig(enable_condensation=True, enable_migration=False,
                        condense_group=16)
    return _plan_with_carry(luffy, None, None)


def test_serial_rejects_v1_blobs():
    """Old-format blobs (pre-CondensePlan layout) are rejected with
    PlanFormatError, never misread."""
    import struct
    _, _, _, _, plan = _vanilla_plan()
    data = bytearray(to_bytes(plan))
    v1 = bytes(data[:4]) + struct.pack("<H", 1) + bytes(data[6:])
    with pytest.raises(PlanFormatError, match="version 1"):
        from_bytes(v1)


def test_serial_condense_plan_roundtrip():
    luffy = LuffyConfig(enable_condensation=True, enable_migration=False,
                        condense_group=16, condense_reuse="signature")
    s_prev = jnp.full((2, 16, 16), 0.5, jnp.float32)
    cfg, p, x, sb, plan = _plan_with_carry(luffy, _zero_carry(), s_prev)
    plan2 = from_bytes(to_bytes(plan))
    cp, cp2 = plan.condense_plan, plan2.condense_plan
    assert cp2.backend == cp.backend
    for f in ("rep_idx", "is_rep", "s_next", "rate", "measured_pairs",
              "built", "reused"):
        np.testing.assert_array_equal(np.asarray(getattr(cp, f)),
                                      np.asarray(getattr(cp2, f)))
    for f in ("expert", "age", "valid"):
        np.testing.assert_array_equal(
            np.asarray(getattr(cp.signature, f)),
            np.asarray(getattr(cp2.signature, f)))
    assert plan2.wire == plan.wire
    y1, _ = execute_plan(p, x, dict(sb), plan, cfg)
    y2, _ = execute_plan(p, x, dict(sb), plan2, cfg)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_serial_params_version_gate():
    _, _, _, _, plan = _vanilla_plan()
    data = to_bytes(plan, params_version="step42")
    assert from_bytes(data, expect_params_version="step42") is not None
    from_bytes(data)                         # no expectation: accepted
    with pytest.raises(PlanFormatError, match="params_version"):
        from_bytes(data, expect_params_version="step43")


def test_plan_cache_params_version_never_trusts_stale(tmp_path):
    """A cache at a newer router fingerprint treats blobs written at an
    older one as misses (rebuilt, never trusted)."""
    from repro.plan import build_plan_template
    cfg = _mk()
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False)
    tmpl = build_plan_template(cfg, luffy, n_seq=2, seq_len=16,
                               capacity=64)
    key = "shared_key"
    old = PlanCache(tmp_path, params_version="step1")
    old.put(key, tmpl)
    assert PlanCache(tmp_path, params_version="step1").get(key) is not None
    assert PlanCache(tmp_path, params_version="step2").get(key) is None
    # and the key itself separates versions/wire formats
    base = dict(n_seq=2, seq_len=16, d_model=32, capacity=64, top_k=2,
                num_experts=4, mode="migrate", objective="traffic",
                exec_mode="sync", pipeline_chunks=1, comm_mode="hier",
                topo=None, M=8)
    k1 = plan_key(**base, params_version="step1")
    k2 = plan_key(**base, params_version="step2")
    k3 = plan_key(**base, params_version="step1", hier_dedup="on")
    assert len({k1, k2, k3}) == 3


# ---------------------------------------------------------------------------
# dedup wire: 8-device round-trips + golden grid (subprocess, like
# test_sideband / test_plan)
# ---------------------------------------------------------------------------

def _run(script_body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CommContext, Topology, make_mesh, shard_map
        from repro.configs import get_config
        from repro.config import reduced, LuffyConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.dist import DistContext, make_dist
        from repro.data import SyntheticLM
        from repro.core.moe_layer import capacity_for
    """) + textwrap.dedent(script_body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_dedup_wire_roundtrip_8dev():
    """Bijection of the dedup wire: the reconstructed dispatch rows are
    bit-identical to the dense wire's, the combine round trip matches
    the dense per-token sums within float tolerance, and the shipped
    inter-node row count equals the ledger's distinct-(token, node)
    model exactly."""
    out = _run("""
        from repro.comm import ledger as comm_ledger
        from repro.condense.wire import dedup_combine, dedup_dispatch
        from repro.core.gating import dispatch_positions

        N, L = 2, 4
        M = N * L
        mesh = make_mesh((N, L), ("node", "local"))
        topo = Topology(N, L)
        comm = CommContext.build("hier", ("node", "local"), topo)
        T, k, d, E_local, C = 48, 2, 16, 2, 24
        E = E_local * M
        r = np.random.default_rng(0)
        xf = r.standard_normal((M, T, d)).astype(np.float32)
        expert_idx = r.integers(0, E, (M, T, k)).astype(np.int32)
        gate_w = r.random((M, T, k)).astype(np.float32)

        def inner(xf_l, e_l, g_l):
            xf_l, e_l, g_l = xf_l[0], e_l[0], g_l[0]   # drop shard dim
            keep = jnp.ones((T, k), bool)
            pos = dispatch_positions(e_l, keep, E)
            valid = keep & (pos < C)
            my = comm.index()
            # dense reference: payload [x, gw] through the dense wire
            pay = jnp.concatenate([
                jnp.tile(xf_l[:, None], (1, k, 1)),
                g_l[..., None]], -1).reshape(-1, d + 1)
            v_f = valid.reshape(-1)
            e_s = jnp.where(v_f, e_l.reshape(-1), 0)
            p_s = jnp.where(v_f, pos.reshape(-1), 0)
            buf = jnp.zeros((E, C, d + 1), jnp.float32).at[e_s, p_s].add(
                pay * v_f[:, None], mode="drop")
            buf = comm.all_to_all(buf)
            rows = buf.reshape(M, E_local, C, d + 1).transpose(1, 0, 2, 3)
            x_rows, gw_rows, rvalid, state = dedup_dispatch(
                xf_l, e_l, g_l, valid, pos, comm=comm,
                e_local=E_local, capacity=C)
            # combine: fake per-row expert output = 3*x, gate-weighted
            out_rows = 3.0 * x_rows * gw_rows[..., None]
            delta = dedup_combine(out_rows, state, comm=comm)
            # dense combine reference
            dr = 3.0 * rows[..., :d] * rows[..., d:]
            back = dr.reshape(E_local, M, C, d).transpose(1, 0, 2, 3) \
                     .reshape(E, C, d)
            back = comm.combine(back)
            vals = back[e_s, p_s] * v_f[:, None]
            dense_delta = jnp.sum(vals.reshape(T, k, d), axis=1)
            _, dedup_model = comm_ledger.dispatch_node_ledger(
                e_l, valid, my, e_local=E_local, topo=topo, row_bytes=1.0)
            return tuple(jnp.asarray(a)[None] for a in (
                x_rows, rows[..., :d], gw_rows, rows[..., d],
                delta, dense_delta, state["shipped_rows"], dedup_model))

        fn = shard_map(inner, mesh=mesh,
                       in_specs=(P(("node", "local")),) * 3,
                       out_specs=(P(("node", "local")),) * 8)
        (xr, xd, gr, gd, delta, dense, shipped, model) = fn(
            jnp.asarray(xf), jnp.asarray(expert_idx), jnp.asarray(gate_w))
        assert np.array_equal(np.asarray(xr), np.asarray(xd)), "x rows"
        assert np.array_equal(np.asarray(gr), np.asarray(gd)), "gate rows"
        np.testing.assert_allclose(np.asarray(delta), np.asarray(dense),
                                   rtol=0, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(shipped),
                                      np.asarray(model))
        assert float(np.asarray(shipped).sum()) > 0
        print("OK")
    """)
    assert "OK" in out


def test_dedup_migrate_roundtrip_8dev():
    """Migrate-frame bijection roundtrip (ISSUE 10): the dest-keyed
    re-expansion map survives the wire exactly — the ``dgpos``/``prim``
    planes reconstruct bit-identically to a dense map exchange — and
    :func:`dedup_combine_migrate` lands every token's materialized row
    (``y·gw + x·prim``) at its post-migration home within float
    tolerance of a host-side dense reference. The migration permutation
    is a bijection on global slots, so every destination receives
    exactly T rows."""
    out = _run("""
        from repro.condense.wire import (dedup_combine_migrate,
                                         dedup_dispatch)
        from repro.core.gating import dispatch_positions

        N, L = 2, 4
        M = N * L
        mesh = make_mesh((N, L), ("node", "local"))
        topo = Topology(N, L)
        comm = CommContext.build("hier", ("node", "local"), topo)
        T, k, d, E_local, C = 48, 2, 16, 2, 24
        E = E_local * M
        r = np.random.default_rng(1)
        xf = r.standard_normal((M, T, d)).astype(np.float32)
        expert_idx = r.integers(0, E, (M, T, k)).astype(np.int32)
        gate_w = r.random((M, T, k)).astype(np.float32)
        SHIFT = 3        # cyclic device shift: a slot bijection

        def inner(xf_l, e_l, g_l):
            xf_l, e_l, g_l = xf_l[0], e_l[0], g_l[0]
            keep = jnp.ones((T, k), bool)
            pos = dispatch_positions(e_l, keep, E)
            valid = keep & (pos < C)
            my = comm.index()
            dest_dev = (my + SHIFT) % M        # position-preserving
            dest_gpos = dest_dev * T + jnp.arange(T, dtype=jnp.int32)
            prim = jnp.broadcast_to(
                (jnp.arange(k) == 0)[None, :], (T, k)) \
                .astype(jnp.float32)
            x_rows, gw_rows, rvalid, state = dedup_dispatch(
                xf_l, e_l, g_l, valid, pos, comm=comm,
                e_local=E_local, capacity=C,
                dest_gpos=dest_gpos, prim=prim)
            # fake expert: 3*x, gate-weighted + primary-copy residual
            out_rows = (3.0 * x_rows * gw_rows[..., None]
                        + x_rows * state["prim"][..., None])
            y = dedup_combine_migrate(out_rows, state, comm=comm)
            # dense map reference: the (dgpos+1, prim) planes through
            # the ordinary dense exchange
            pay = jnp.concatenate([
                jnp.broadcast_to(
                    dest_gpos.astype(jnp.float32)[:, None, None] + 1.0,
                    (T, k, 1)),
                prim[..., None]], -1).reshape(-1, 2)
            v_f = valid.reshape(-1)
            e_s = jnp.where(v_f, e_l.reshape(-1), 0)
            p_s = jnp.where(v_f, pos.reshape(-1), 0)
            buf = jnp.zeros((E, C, 2), jnp.float32).at[e_s, p_s].add(
                pay * v_f[:, None], mode="drop")
            buf = comm.all_to_all(buf)
            rmeta = buf.reshape(M, E_local, C, 2).transpose(1, 0, 2, 3)
            dg_want = jnp.round(rmeta[..., 0]).astype(jnp.int32) - 1
            return tuple(jnp.asarray(a)[None] for a in (
                y, state["dgpos"], dg_want, state["prim"],
                rmeta[..., 1], valid))

        fn = shard_map(inner, mesh=mesh,
                       in_specs=(P(("node", "local")),) * 3,
                       out_specs=(P(("node", "local")),) * 6)
        y, dg, dg_want, pr, pr_want, valid = fn(
            jnp.asarray(xf), jnp.asarray(expert_idx),
            jnp.asarray(gate_w))
        # exact map roundtrip: bit-identical to the dense exchange
        np.testing.assert_array_equal(np.asarray(dg), np.asarray(dg_want))
        np.testing.assert_array_equal(np.asarray(pr), np.asarray(pr_want))
        # host-side dense migrate reference, permuted by the bijection
        v = np.asarray(valid)                     # [M, T, k]
        y_ref = np.zeros((M, T, d), np.float32)
        for m in range(M):
            contrib = (3.0 * xf[m][:, None, :] * gate_w[m][..., None]
                       * v[m][..., None]).sum(1)
            y_ref[(m + SHIFT) % M] = contrib + v[m][:, 0:1] * xf[m]
        np.testing.assert_allclose(np.asarray(y), y_ref,
                                   rtol=0, atol=1e-5)
        assert np.abs(y_ref).sum() > 0
        print("OK")
    """)
    assert "OK" in out


def test_condense_golden_grid_8dev():
    """Acceptance (ISSUE 5): on the 8-device hier mesh, (a) the "lsh"
    backend trains to a finite loss with measured_pairs strictly below
    "exact"; (b) hier_dedup="on" matches the flat wire within the
    documented tolerance with inter_bytes_shipped == inter_bytes_dedup
    and < inter_bytes_flat, and gradients flow; (c) condense-plan reuse
    under stable routing drops similarity builds to 1 per forward,
    bitwise-equal to condense_reuse="off" when the rebuild would emit
    the same rep map."""
    out = _run("""
        cfg = reduced(get_config("moe-gpt2"), num_layers=3, d_model=128)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        shape = ShapeConfig("t", 64, 16, "train")
        data = SyntheticLM(cfg, shape)
        b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        cap = capacity_for(cfg.moe, 64, cfg.moe.num_experts, slack=8.0)
        mesh = make_mesh((2, 2, 2), ("data", "node", "local"))
        dist = DistContext(mesh, batch_axes=("data", "node", "local"),
                           seq_axis=None, fsdp_axes=("data",),
                           model_axis=("node", "local"),
                           topology=Topology(2, 2))

        def loss(params, luffy, thr=0.4):
            l, m = jax.jit(lambda p, bb: model.train_loss(
                p, bb, jnp.float32(thr), luffy=luffy, dist=dist,
                capacity=cap))(params, b)
            return float(l), {k: float(v) for k, v in m.items()}

        base = LuffyConfig(enable_condensation=True,
                           enable_migration=False, combine_slack=4.0,
                           condense_group=32, comm_mode="hier")

        # (a) LSH backend: finite loss, strictly fewer measured pairs
        le, me = loss(params, base)
        ll, ml = loss(params,
                      dataclasses.replace(base,
                                          similarity_backend="lsh"))
        assert np.isfinite(ll), ll
        assert 0 < ml["measured_pairs"] < me["measured_pairs"], (
            ml["measured_pairs"], me["measured_pairs"])

        # (b) dedup wire vs flat, with gradients
        flat = dataclasses.replace(base, comm_mode="flat")
        ded = dataclasses.replace(base, hier_dedup="on")
        lf, mf = loss(params, flat)
        ld, md = loss(params, ded)
        assert abs(lf - ld) < 2e-5, (lf, ld)
        assert md["inter_bytes_shipped"] == md["inter_bytes_dedup"]
        assert md["inter_bytes_shipped"] < md["inter_bytes_flat"]
        assert mf["inter_bytes_shipped"] == 0.0
        g = jax.jit(jax.grad(lambda p, bb: model.train_loss(
            p, bb, jnp.float32(0.4), luffy=ded, dist=dist,
            capacity=cap)[0]))(params, b)
        gn = float(sum(jnp.sum(jnp.abs(x))
                       for x in jax.tree.leaves(g)))
        assert np.isfinite(gn) and gn > 0, gn

        # (c) condense reuse. Stable routing = zeroed routers; at a
        # threshold above 1 the rebuild provably emits the identity rep
        # map every sublayer, so reuse is bitwise-equal to "off" while
        # the build counter drops 3 -> 1.
        stable = dict(params)
        stable["layers"] = [dict(params["layers"][0])]
        stable["layers"][0]["moe"] = dict(params["layers"][0]["moe"])
        stable["layers"][0]["moe"]["router"] = {
            "w_gate": jnp.zeros_like(
                params["layers"][0]["moe"]["router"]["w_gate"])}
        COUNTERS = ("condense_built", "condense_reused", "measured_pairs")
        off = dataclasses.replace(base, comm_mode="flat")
        sig = dataclasses.replace(off, condense_reuse="signature")
        l0, m0 = loss(stable, off, thr=1.5)
        l1, m1 = loss(stable, sig, thr=1.5)
        assert l0 == l1, (l0, l1)
        for k in m0:
            if k not in COUNTERS:
                assert m0[k] == m1[k], (k, m0[k], m1[k])
        assert m0["condense_built"] == 3.0
        assert m1["condense_built"] == 1.0, m1
        assert m1["condense_reused"] == 2.0
        assert m1["measured_pairs"] < m0["measured_pairs"]

        # realistic threshold: builds still drop to 1 per forward
        l2, m2 = loss(stable, sig, thr=0.4)
        assert np.isfinite(l2)
        assert m2["condense_built"] == 1.0, m2
        # drifting routing (per-layer routers): reuse never fires, and
        # signature mode stays bitwise-equal to off by graph parity
        l3, m3 = loss(params, off)
        l4, m4 = loss(params, sig)
        assert l3 == l4, (l3, l4)
        assert m4["condense_built"] == 3.0 and m4["condense_reused"] == 0.0
        # migrate + condense reuse: carries migrate with sequences
        mig = dataclasses.replace(sig, enable_migration=True)
        l5, m5 = loss(stable, mig, thr=1.5)
        assert np.isfinite(l5)
        assert m5["condense_built"] == 1.0, m5
        print("OK")
    """)
    assert "OK" in out
