"""Property tests on the gating/dispatch substrate (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import assume, given, settings, st   # optional dep; skips when absent

from repro.core.gating import (dispatch_positions, expert_load, gate_apply,
                               gate_init)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]),
       st.sampled_from([1, 2, 4]))
def test_gate_invariants(seed, E, k):
    assume(k <= E)
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((32, 16)), jnp.float32)
    p = gate_init(jax.random.PRNGKey(seed % 7), 16, E)
    out = gate_apply(p, x, k)
    idx = np.asarray(out.expert_idx)
    w = np.asarray(out.gate_weights)
    # choices are valid expert ids and distinct per token
    assert idx.min() >= 0 and idx.max() < E
    for row in idx:
        assert len(set(row.tolist())) == k
    # combine weights are a distribution over the k choices
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-5)
    assert (w >= 0).all()
    # aux loss ~ E * sum f_e p_e: >= ~1 up to finite-sample f vs p skew
    assert float(out.aux_loss) >= 0.9


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4]),
       st.sampled_from([1, 2]))
def test_dispatch_positions_are_unique_slots(seed, E, k):
    assume(k <= E)
    """(expert, position) pairs must be unique among kept rows, positions
    dense from 0, and primary (k=0) copies occupy the earliest slots."""
    r = np.random.default_rng(seed)
    T = 24
    idx = jnp.asarray(r.integers(0, E, (T, k)), jnp.int32)
    keep = jnp.asarray(r.random((T, k)) < 0.8)
    pos = np.asarray(dispatch_positions(idx, keep, E))
    e = np.asarray(idx)
    kp = np.asarray(keep)
    seen = set()
    per_expert_counts = np.zeros(E, int)
    for t in range(T):
        for j in range(k):
            if kp[t, j]:
                key = (e[t, j], pos[t, j])
                assert key not in seen, key
                seen.add(key)
                per_expert_counts[e[t, j]] += 1
    # positions are dense 0..count-1 per expert
    for ex in range(E):
        ps = sorted(pos[(e == ex) & kp])
        assert ps == list(range(per_expert_counts[ex]))
    # priority: every kept primary row has a position smaller than any
    # kept secondary row of the same expert
    if k > 1:
        for ex in range(E):
            m_p = (e[:, 0] == ex) & kp[:, 0]
            m_s = (e[:, 1:] == ex) & kp[:, 1:]
            prim = pos[:, 0][m_p]
            sec = pos[:, 1:][m_s]
            if len(prim) and len(sec):
                assert prim.max() < sec.min()
    # load accounting matches
    load = np.asarray(expert_load(idx, keep, E))
    np.testing.assert_array_equal(load, per_expert_counts)
