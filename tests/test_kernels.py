"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("G,d", [(128, 256), (256, 768), (128, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_similarity(rng, G, d, dtype):
    x = jnp.asarray(rng.standard_normal((G, d)), dtype)
    e = jnp.asarray(rng.integers(0, 4, G))
    mask = e[:, None] == e[None, :]
    got = ops.masked_similarity(x, mask, interpret=True)
    want = ref.masked_similarity_ref(x, mask)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


def test_masked_similarity_backend_detected_default(rng):
    """interpret defaults by backend (None → interpreter off-TPU), both
    through ops and when calling the kernel module directly; an explicit
    bool still overrides."""
    from repro.kernels import similarity as sim_mod
    G, d = 128, 256
    x = jnp.asarray(rng.standard_normal((G, d)), jnp.float32)
    e = jnp.asarray(rng.integers(0, 4, G))
    mask = e[:, None] == e[None, :]
    want = ref.masked_similarity_ref(x, mask)
    if jax.default_backend() == "tpu":       # auto-compiles there instead
        pytest.skip("default resolves to the compiled Mosaic kernel")
    for got in (ops.masked_similarity(x, mask),
                sim_mod.masked_similarity(x, mask),
                sim_mod.masked_similarity(x, mask, interpret=True)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


def test_similarity_tile_earlyout(rng):
    """Fully-masked tiles must be exactly zero (skipped)."""
    G, d = 256, 128
    x = jnp.asarray(rng.standard_normal((G, d)), jnp.float32)
    mask = jnp.zeros((G, G), bool).at[:128, :128].set(True)
    got = ops.masked_similarity(x, mask, bg=128, interpret=True)
    assert float(jnp.max(jnp.abs(got[128:, :]))) == 0.0
    assert float(jnp.max(jnp.abs(got[:, 128:]))) == 0.0
    want = ref.masked_similarity_ref(x, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("E,R,d,F", [(2, 128, 128, 256), (4, 256, 256, 512),
                                     (1, 128, 512, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_expert_ffn(rng, E, R, d, F, dtype, act):
    h = jnp.asarray(rng.standard_normal((E, R, d)), dtype)
    wu = jnp.asarray(rng.standard_normal((E, d, F)) * 0.05, dtype)
    wg = jnp.asarray(rng.standard_normal((E, d, F)) * 0.05, dtype)
    wd = jnp.asarray(rng.standard_normal((E, F, d)) * 0.05, dtype)
    got = ops.expert_ffn(h, wu, wg, wd, act, interpret=True)
    want = ref.expert_ffn_ref(h, wu, wg, wd, act)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("T,d", [(256, 64), (512, 128), (1024, 32)])
def test_gather_rows(rng, T, d):
    y = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, T, T), jnp.int32)
    got = ops.gather_rows(y, idx, interpret=True)
    want = ref.gather_rows_ref(y, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("S,hd", [(128, 32), (256, 64)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(rng, S, hd, causal, window, dtype):
    B, H = 2, 2
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,di,N", [(1, 32, 32, 8), (2, 64, 64, 16),
                                      (2, 128, 32, 16)])
def test_mamba_scan(rng, B, S, di, N):
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, di))) * 0.1,
                     jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, S, di)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    a = -jnp.exp(jnp.asarray(rng.standard_normal((di, N)), jnp.float32))
    got = ops.mamba_scan(dt, x, bm, cm, a, bd=32, bs=32, interpret=True)
    want = ref.mamba_scan_ref(dt, x, bm, cm, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("T,d,R", [(128, 64, 128), (256, 96, 192),
                                   (64, 33, 96)])
def test_pack_quantize_bitwise_vs_ref(rng, T, d, R):
    """The fused gate-mask→pack→quantize kernel (DESIGN.md §14) is a
    bit-for-bit target, not allclose: uint8 views of payload AND scale
    sideband must match the pure-jnp reference exactly, for every wire
    dtype the stack supports (non-multiple-of-32 d exercises the f8
    zero-padding)."""
    from repro.comm import dtypes as wdt
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    tok = jnp.asarray(rng.integers(-1, T, R), jnp.int32)  # ~1/T empty
    tok = tok.at[::7].set(-1)                             # force empties
    wds = ["f32", "bf16"] + (["f8e4m3"] if wdt.have_f8() else [])
    for wd in wds:
        got_q, got_sc = ops.pack_quantize(x, tok, wire_dtype=wd,
                                          interpret=True)
        want_q, want_sc = ref.pack_quantize_ref(x, tok, wire_dtype=wd)
        assert got_q.dtype == want_q.dtype
        assert got_q.shape == want_q.shape
        np.testing.assert_array_equal(
            np.asarray(got_q).view(np.uint8),
            np.asarray(want_q).view(np.uint8), err_msg=f"payload {wd}")
        assert (got_sc is None) == (want_sc is None)
        if got_sc is not None:
            np.testing.assert_array_equal(
                np.asarray(got_sc).view(np.uint8),
                np.asarray(want_sc).view(np.uint8),
                err_msg=f"scales {wd}")


def test_mamba_kernel_path_in_model(rng, monkeypatch):
    """hymba forward with REPRO_MAMBA_KERNEL=1 == the lax.scan path."""
    import os
    from repro.config import reduced
    from repro.configs import get_config
    from repro.models import ssm as ssm_mod
    cfg = reduced(get_config("hymba-1.5b"))
    import dataclasses
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    p = ssm_mod.mamba_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 64, cfg.d_model)), jnp.float32)
    monkeypatch.setenv("REPRO_MAMBA_KERNEL", "0")
    y0 = ssm_mod.mamba_apply(p, cfg, x)
    monkeypatch.setenv("REPRO_MAMBA_KERNEL", "1")
    y1 = ssm_mod.mamba_apply(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=2e-4, rtol=2e-4)
