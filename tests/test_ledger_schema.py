"""Golden schema of the dryrun comm-traffic ledger (DESIGN.md §11).

The ledger JSON is a consumed artifact (benchmarks, CI uploads, the
--metrics-json flattening), so its shape is versioned: this test pins
``schema_version`` and the exact key sets of every section. Renaming or
adding a key MUST bump ``repro.obs.metrics.COMM_LEDGER_SCHEMA_VERSION``
and update the goldens here."""
import os
import types

import numpy as np
import pytest

from repro.config import SHAPES
from repro.configs import get_config

# importing the dryrun launcher sets XLA_FLAGS for its own 512-device
# use; restore the suite's environment so later jax inits (in-process
# or in subprocess tests) keep their device count
_SAVED_XLA_FLAGS = os.environ.get("XLA_FLAGS")
from repro.launch.dryrun import comm_traffic_ledger  # noqa: E402
if _SAVED_XLA_FLAGS is None:
    os.environ.pop("XLA_FLAGS", None)
else:
    os.environ["XLA_FLAGS"] = _SAVED_XLA_FLAGS
from repro.obs.calibrate import Calibration, calibration_key
from repro.obs.metrics import COMM_LEDGER_SCHEMA_VERSION

TOP_KEYS = {"schema_version", "calibration", "topology", "dedup_factor",
            "buckets", "wire", "plan_reuse", "condensation", "decode",
            "autotune"}
TOPOLOGY_KEYS = {"nodes", "devices_per_node", "bw_ratio"}
BUCKET_KEYS = {"flat", "hier", "overlap"}
TIER_KEYS = {"intra_bytes", "inter_bytes", "time_s"}
OVERLAP_KEYS = {"ffn_ms", "sync_ms", "pipelined_ms", "chunks", "speedup"}
PLAN_REUSE_KEYS = {"mode", "moe_sublayers", "n_slots",
                   "plans_built_per_step", "plans_reused_per_step",
                   "revalidation_mismatches", "planning_ms_per_plan",
                   "revalidate_ms_per_check",
                   "planning_ms_saved_per_step"}
CONDENSATION_KEYS = {"backend", "group_size", "lsh_bits",
                     "measured_pairs_per_step",
                     "similarity_ms_per_build", "dedup_wire",
                     "condense_plan"}
DEDUP_WIRE_KEYS = {"enabled", "modeled_inter_bytes", "flat_inter_bytes",
                   "shipped_inter_bytes"}
CONDENSE_PLAN_KEYS = {"mode", "built_per_step", "reused_per_step",
                      "similarity_ms_saved_per_step"}
DECODE_KEYS = {"tokens", "combine_ms", "shared_ffn_ms", "sync_ms",
               "overlap_ms", "modeled_speedup"}
AUTOTUNE_KEYS = {"applied", "key", "knobs", "modeled_step_ms",
                 "default_step_ms", "modeled_savings_ms", "candidates"}
KNOB_KEYS = {"comm_mode", "hier_dedup", "exec_mode", "pipeline_chunks",
             "plan_objective", "similarity_backend", "lsh_bits",
             "wire_dtype"}
WIRE_KEYS = {"dtype", "precision", "row_bytes", "row_bytes_f32",
             "scale_block", "shipped_vanilla_bytes",
             "shipped_migrate_bytes", "shipped_pipelined_bytes"}


def _fake_mesh(shape_by_axis):
    return types.SimpleNamespace(
        axis_names=tuple(shape_by_axis),
        devices=np.zeros(tuple(shape_by_axis.values())))


def _ledger(**kw):
    cfg = get_config("moe-gpt2")
    return comm_traffic_ledger(cfg, SHAPES["train_4k"],
                               _fake_mesh({"data": 16, "model": 16}),
                               nodes=4, **kw)


def test_ledger_schema_version_and_key_sets():
    led = _ledger()
    assert led["schema_version"] == COMM_LEDGER_SCHEMA_VERSION == 6
    assert set(led) == TOP_KEYS
    assert set(led["topology"]) == TOPOLOGY_KEYS
    assert set(led["buckets"]) == {"0.0", "0.25", "0.5"}
    for b in led["buckets"].values():
        assert set(b) == BUCKET_KEYS
        assert set(b["flat"]) == set(b["hier"]) == TIER_KEYS
        assert set(b["overlap"]) == OVERLAP_KEYS
    assert set(led["wire"]) == WIRE_KEYS
    # default run: identity wire — precision exactly 1, bytes unscaled
    assert led["wire"]["dtype"] == "f32"
    assert led["wire"]["precision"] == 1.0
    assert led["wire"]["row_bytes"] == led["wire"]["row_bytes_f32"]
    # v6: per-execution-mode shipped bytes — equal by construction
    # (dispatch dedup is mode-independent; the keys exist to record
    # that the wire's mode scope is closed, DESIGN.md §15)
    w = led["wire"]
    assert (w["shipped_vanilla_bytes"] == w["shipped_migrate_bytes"]
            == w["shipped_pipelined_bytes"])
    assert set(led["plan_reuse"]) == PLAN_REUSE_KEYS
    assert set(led["condensation"]) == CONDENSATION_KEYS
    assert set(led["condensation"]["dedup_wire"]) == DEDUP_WIRE_KEYS
    assert set(led["condensation"]["condense_plan"]) == \
        CONDENSE_PLAN_KEYS
    assert set(led["decode"]) == DECODE_KEYS
    # decode step cost: overlap hides the shorter leg behind the longer
    dec = led["decode"]
    assert dec["overlap_ms"] <= dec["sync_ms"]
    assert dec["modeled_speedup"] >= 1.0
    assert set(led["autotune"]) == AUTOTUNE_KEYS
    assert set(led["autotune"]["knobs"]) == KNOB_KEYS
    assert led["autotune"]["applied"] is False   # modeled, not resolved
    # defaults are always in the grid: tuned can never model worse
    assert led["autotune"]["modeled_step_ms"] <= \
        led["autotune"]["default_step_ms"]
    assert led["autotune"]["modeled_savings_ms"] == pytest.approx(
        led["autotune"]["default_step_ms"]
        - led["autotune"]["modeled_step_ms"])
    assert led["calibration"] is None          # uncalibrated pricing


def test_ledger_wire_dtype_scales_bucket_bytes():
    """The compressed wire (DESIGN.md §14) shows up in the ledger as an
    exact 1/precision scaling of every modeled byte field."""
    base = _ledger()
    led = _ledger(wire_dtype="bf16")
    assert set(led) == TOP_KEYS
    prec = led["wire"]["precision"]
    assert prec > 1.0
    b, c = led["buckets"]["0.0"], base["buckets"]["0.0"]
    for tier in ("flat", "hier"):
        assert b[tier]["inter_bytes"] == pytest.approx(
            c[tier]["inter_bytes"] / prec)
        assert b[tier]["intra_bytes"] == pytest.approx(
            c[tier]["intra_bytes"] / prec)


def test_ledger_non_hier_and_non_moe_return_none():
    cfg = get_config("moe-gpt2")
    led = comm_traffic_ledger(cfg, SHAPES["train_4k"],
                              _fake_mesh({"data": 16, "model": 3}),
                              nodes=2)        # 3 % 2 != 0: no hier split
    assert led is None


def test_ledger_calibrated_pricing_same_schema():
    """Calibration swaps constants, never shape: same key sets, the
    artifact key recorded, and the measured numbers actually flow into
    the priced sections."""
    from repro.comm.topology import Topology
    base = _ledger()
    topo = Topology(4, 4)
    calib = Calibration(
        key=calibration_key(topo, 16, backend="cpu"),
        intra_bw=1e9, inter_bw=1e8, intra_lat=1e-5, inter_lat=1e-4,
        chunk_overhead_ms=0.5, plan_step_us=50.0, sim_speed=1e10,
        ffn_speed=1e12)
    led = _ledger(calibration=calib)
    assert set(led) == TOP_KEYS
    assert led["schema_version"] == COMM_LEDGER_SCHEMA_VERSION
    assert led["calibration"] == calib.key
    b0, c0 = led["buckets"]["0.0"], base["buckets"]["0.0"]
    # slower measured FFN roofline and slower links: times move
    assert b0["overlap"]["ffn_ms"] > c0["overlap"]["ffn_ms"]
    assert b0["hier"]["time_s"] > c0["hier"]["time_s"]
    assert led["plan_reuse"]["planning_ms_per_plan"] > \
        base["plan_reuse"]["planning_ms_per_plan"]
    sims = led["condensation"]["similarity_ms_per_build"]
    assert sims["exact"] > \
        base["condensation"]["similarity_ms_per_build"]["exact"]


def test_ledger_flattens_into_metrics_record():
    from repro.obs.metrics import flatten
    led = _ledger()
    flat = flatten("comm_ledger", led)
    assert flat["comm_ledger/schema_version"] == 6
    assert "comm_ledger/decode/modeled_speedup" in flat
    assert "comm_ledger/buckets/0.0/hier/inter_bytes" in flat
    assert "comm_ledger/plan_reuse/planning_ms_per_plan" in flat
    assert all(not isinstance(v, dict) for v in flat.values())
