"""Sequence migration (paper §IV): Algorithm 1 numpy reference vs the
traceable device version, plan validity, traffic/cost behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st   # optional dep; skips when absent

from repro.core import migration as mig


def _random_instance(r, n_slots, M, bias=3.0):
    counts = r.random((n_slots, M)) ** bias
    counts = (counts / counts.sum(1, keepdims=True) * 100).astype(np.int64)
    lens = r.integers(10, 100, n_slots)
    return counts.astype(np.float64), lens.astype(np.int64)


def test_t_att_cost_model():
    """Eq. 1: (3BLd^2 + 2BL^2d)/P."""
    got = float(mig.t_att(2, 128, 64, 1e9))
    want = (3 * 2 * 128 * 64**2 + 2 * 2 * 128**2 * 64) / 1e9
    assert abs(got - want) < 1e-9


def test_identity_plan():
    p = mig.identity_plan(8, 2)
    np.testing.assert_array_equal(np.asarray(p.perm), np.arange(8))
    np.testing.assert_array_equal(np.asarray(p.assign),
                                  np.arange(8) // 2)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]),
       st.sampled_from([1, 2, 4]))
def test_plan_np_properties(seed, M, n_per_dev):
    """The plan is a bijection respecting per-device capacity, and never
    increases combine traffic vs no migration."""
    r = np.random.default_rng(seed)
    n_slots = M * n_per_dev
    counts, lens = _random_instance(r, n_slots, M)
    plan = mig.plan_migration_np(counts, lens, n_per_dev, q=2)
    perm = np.asarray(plan.perm)
    assert sorted(perm.tolist()) == list(range(n_slots))       # bijection
    assign = np.asarray(plan.assign)
    assert (np.bincount(assign, minlength=M) == n_per_dev).all()
    assert float(plan.traffic_after) <= float(plan.traffic_before) + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4]),
       st.sampled_from([1, 2]))
def test_plan_jax_matches_np(seed, M, n_per_dev):
    """Device-side Algorithm 1 == host-side Algorithm 1 (same greedy)."""
    r = np.random.default_rng(seed)
    n_slots = M * n_per_dev
    counts, lens = _random_instance(r, n_slots, M)
    # perturb to avoid ties (tie-breaking order may differ)
    counts = counts + r.random(counts.shape) * 1e-3
    lens = lens + np.arange(n_slots) * 0  # keep ints distinct enough
    p_np = mig.plan_migration_np(counts, lens, n_per_dev, q=2)
    p_jx = mig.plan_migration_jax(jnp.asarray(counts),
                                  jnp.asarray(lens, jnp.float32),
                                  n_per_dev, q=2)
    np.testing.assert_array_equal(np.asarray(p_jx.assign),
                                  np.asarray(p_np.assign))
    np.testing.assert_array_equal(np.asarray(p_jx.perm),
                                  np.asarray(p_np.perm))
    # traffic values are token counts; near-zero instances differ only by
    # f32-vs-f64 rounding — atol covers them
    np.testing.assert_allclose(float(p_jx.traffic_after),
                               float(p_np.traffic_after), rtol=1e-4,
                               atol=1e-2)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
def test_plan_np_jax_parity_nonuniform_link_cost(seed):
    """Host and device planners agree under genuinely non-uniform cost
    matrices: the 2×4 hierarchical topology AND a random symmetric
    per-link matrix (not expressible as any topology) — the greedy's
    step-1 traffic ranking must weight links identically in both."""
    from repro.comm import Topology
    r = np.random.default_rng(seed)
    M, n_per = 8, 2
    n_slots = M * n_per
    counts = (r.random((n_slots, M)) ** 3)
    counts = counts / counts.sum(1, keepdims=True) * 100
    counts = counts + r.random(counts.shape) * 1e-3     # break ties
    lens = r.integers(10, 100, n_slots).astype(np.float64)
    rand = r.random((M, M)) * 4.0 + 0.5
    rand = (rand + rand.T) / 2.0
    np.fill_diagonal(rand, 0.0)
    for cost in (Topology(2, 4).link_cost(), rand):
        p_np = mig.plan_migration_np(counts, lens, n_per, q=3,
                                     link_cost=cost)
        p_jx = mig.plan_migration_jax(jnp.asarray(counts, jnp.float32),
                                      jnp.asarray(lens, jnp.float32),
                                      n_per, q=3,
                                      link_cost=jnp.asarray(cost,
                                                            jnp.float32))
        np.testing.assert_array_equal(np.asarray(p_jx.assign),
                                      np.asarray(p_np.assign))
        np.testing.assert_array_equal(np.asarray(p_jx.perm),
                                      np.asarray(p_np.perm))
        perm = np.asarray(p_np.perm)
        assert sorted(perm.tolist()) == list(range(n_slots))
        assert float(p_np.traffic_after) <= float(p_np.traffic_before) + 1e-6
        np.testing.assert_allclose(float(p_jx.traffic_after),
                                   float(p_np.traffic_after), rtol=1e-4,
                                   atol=1e-2)


def test_migration_prefers_majority_device():
    """A sequence with 90% of its tokens on device 2 should be homed
    there (q covers it, capacity allows)."""
    M, n_per = 4, 1
    counts = np.full((4, 4), 5.0)
    counts[0] = [1, 1, 1, 90]
    counts[3] = [90, 1, 1, 1]
    lens = np.array([50, 10, 10, 50])
    plan = mig.plan_migration_np(counts, lens, n_per, q=2)
    assign = np.asarray(plan.assign)
    assert assign[0] == 3
    assert assign[3] == 0
