"""moe_core vs the dense per-token oracle (single device)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import LuffyConfig, ModelConfig, MoEConfig
from repro.core import moe_layer as ml
from repro.core.dense_moe import dense_moe_reference
from repro.core import condensation as cond
from repro.core.moe_layer import _rms


def _mk(num_experts=4, top_k=2, shared=0):
    return ModelConfig(
        name="t", kind="decoder", family="moe", num_layers=2,
        d_model=32, d_ff=64, vocab_size=128,
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, d_ff=64,
                      num_shared_experts=shared),
        layer_ffn_pattern=("moe",), compute_dtype="float32",
        param_dtype="float32")


def _params(cfg, seed=0):
    return ml.moe_init(jax.random.PRNGKey(seed), cfg)


def _x(cfg, rng, n_seq=2, S=16):
    return jnp.asarray(rng.standard_normal((n_seq, S, cfg.d_model)),
                       jnp.float32)


@pytest.mark.parametrize("top_k", [1, 2])
@pytest.mark.parametrize("shared", [0, 1])
def test_vanilla_matches_oracle(rng, top_k, shared):
    cfg = _mk(top_k=top_k, shared=shared)
    p = _params(cfg)
    x = _x(cfg, rng)
    sb = {"labels": jnp.zeros((2, 16), jnp.int32),
          "seq_len": jnp.full((2,), 16, jnp.int32)}
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False)
    y, _, _, aux = ml.moe_core(p, x, sb, cfg, luffy, mode="vanilla",
                               capacity=256, axis_name=None,
                               threshold=jnp.float32(1.0))
    want, aux_want = dense_moe_reference(p, x.reshape(-1, cfg.d_model), cfg)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, cfg.d_model),
                               np.asarray(want), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux.aux_loss), float(aux_want),
                               rtol=1e-6)
    assert float(aux.dispatch_drop) == 0.0


def test_condensation_zero_rate_is_vanilla(rng):
    """threshold > 1 condenses nothing -> bitwise-vanilla output."""
    cfg = _mk()
    p = _params(cfg)
    x = _x(cfg, rng, n_seq=2, S=16)
    sb = {"labels": jnp.zeros((2, 16), jnp.int32),
          "seq_len": jnp.full((2,), 16, jnp.int32)}
    off = LuffyConfig(enable_condensation=False, enable_migration=False)
    on = LuffyConfig(enable_condensation=True, enable_migration=False,
                     condense_group=16)
    y0, *_ = ml.moe_core(p, x, sb, cfg, off, mode="vanilla", capacity=256,
                         axis_name=None, threshold=jnp.float32(2.0))
    y1, _, _, aux1 = ml.moe_core(p, x, sb, cfg, on, mode="vanilla",
                                 capacity=256, axis_name=None,
                                 threshold=jnp.float32(2.0),
                                 group_size=16)
    assert float(aux1.condense_rate) == 0.0
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=1e-6)


def test_condensation_replacement_semantics(rng):
    """Condensed tokens take their representative's output exactly
    (token_to_token, paper §VI) — check against the oracle given the same
    rep assignment."""
    cfg = _mk()
    p = _params(cfg)
    n_seq, S, G = 1, 32, 32
    x = _x(cfg, rng, n_seq=n_seq, S=S)
    # duplicate some tokens so condensation actually fires
    xr = np.array(x)               # writable copy
    xr[0, 1] = xr[0, 0]
    xr[0, 9] = xr[0, 8]
    x = jnp.asarray(xr)
    sb = {"labels": jnp.zeros((n_seq, S), jnp.int32),
          "seq_len": jnp.full((n_seq,), S, jnp.int32)}
    on = LuffyConfig(enable_condensation=True, enable_migration=False,
                     condense_group=G)
    thr = jnp.float32(0.9999)
    y, _, s_next, aux = ml.moe_core(p, x, sb, cfg, on, mode="vanilla",
                                    capacity=256, axis_name=None,
                                    threshold=thr, group_size=G)
    assert float(aux.condense_rate) > 0.0
    # recompute the rep assignment the layer used
    xn = _rms(x.reshape(-1, cfg.d_model),
              p["norm"]["scale"]).astype(jnp.float32)
    from repro.core.gating import gate_apply
    gate = gate_apply(p["router"], xn, cfg.moe.top_k)
    co = cond.condense_tokens(xn, gate.expert_idx[:, 0], thr, group_size=G)
    want, _ = dense_moe_reference(p, x.reshape(-1, cfg.d_model), cfg,
                                  rep_idx=co.rep_idx)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, cfg.d_model),
                               np.asarray(want), atol=1e-5, rtol=1e-5)
    # duplicated tokens got identical outputs
    yy = np.asarray(y)[0]
    np.testing.assert_array_equal(yy[0], yy[1])


def test_capacity_drops_reported(rng):
    cfg = _mk(num_experts=2, top_k=1)
    p = _params(cfg)
    x = _x(cfg, rng, n_seq=1, S=32)
    sb = {"labels": jnp.zeros((1, 32), jnp.int32),
          "seq_len": jnp.full((1,), 32, jnp.int32)}
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False)
    y, _, _, aux = ml.moe_core(p, x, sb, cfg, luffy, mode="vanilla",
                               capacity=8, axis_name=None,
                               threshold=jnp.float32(1.0))
    # 32 tokens over 2 experts with capacity 8 -> at least half dropped
    assert float(aux.dispatch_drop) >= 0.4


def test_decode_allreduce_single_device_matches_oracle(rng):
    cfg = _mk(shared=1)
    p = _params(cfg)
    x = _x(cfg, rng, n_seq=4, S=1)
    y, aux = ml.moe_decode_allreduce(p, x, cfg, capacity=64,
                                     axis_name=None)
    want, _ = dense_moe_reference(p, x.reshape(-1, cfg.d_model), cfg)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, cfg.d_model),
                               np.asarray(want), atol=1e-5, rtol=1e-5)
