"""Residual monitoring and drift detection (repro.obs.monitor,
DESIGN.md §12).

The two drift properties ISSUE 7 pins:

* **no false positives** — stationary noise whose every per-step ratio
  stays strictly within tolerance NEVER fires.  This is deterministic:
  the EWMA is initialized at the first sample, so it is always a convex
  combination of observed log-ratios and cannot leave an interval the
  samples never leave.
* **true positive latency** — an injected sustained 2× degradation
  fires within a few steps of onset (the EWMA crossing plus the k-run
  confirmation), and never before ``k`` post-onset steps.
"""
import math

import pytest

from repro.obs import monitor as obs_mon
from repro.obs.monitor import (DriftDetector, ResidualMonitor,
                               device_dispersion, measured_phase_ms,
                               predicted_phase_ms)
from tests._hyp import given, settings, st

TOL = 1.5
K = 5


# ---------------------------------------------------------------- drift

@settings(max_examples=200, deadline=None)
@given(st.lists(st.floats(min_value=1.0 / TOL * 1.001,
                          max_value=TOL * 0.999),
                min_size=1, max_size=100))
def test_stationary_noise_within_tolerance_never_fires(ratios):
    det = DriftDetector(tolerance=TOL, k=K)
    for r in ratios:
        assert det.update(r) is False
    assert not det.fired and not det.out_of_tolerance


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=0, max_value=20),
       st.floats(min_value=2.0, max_value=4.0))
def test_injected_degradation_fires_within_k_plus_margin(warmup, degrade):
    """Healthy ratio=1.0 for ``warmup`` steps, then a sustained
    ``degrade``x slowdown: with alpha=0.5 the EWMA of log(degrade>=2)
    crosses log(1.5) within 2 steps of onset, so the detector must fire
    within k+2 post-onset steps — and never before k of them."""
    det = DriftDetector(tolerance=TOL, ewma_alpha=0.5, k=K)
    for _ in range(warmup):
        assert det.update(1.0) is False
    fired_at = None
    for i in range(1, K + 8):
        if det.update(degrade):
            fired_at = i
            break
    assert fired_at is not None, "sustained degradation never fired"
    assert fired_at >= K          # needs k consecutive bad steps
    assert fired_at <= K + 2      # EWMA crossing latency under alpha=0.5
    # fired latches until reset, even if the ratio recovers
    assert det.update(1.0) is True
    det.reset()
    assert not det.fired and det.samples == 0


def test_stationary_noise_deterministic_never_fires():
    """Hypothesis-free pin of the no-false-positive property: a fixed
    oscillating within-tolerance ratio stream (worst case: alternating
    near both edges) never fires."""
    det = DriftDetector(tolerance=TOL, k=K)
    ratios = [1.49, 0.68, 1.4, 0.7, 1.0, 1.45, 0.69, 1.3, 0.75, 1.2] * 10
    for r in ratios:
        assert det.update(r) is False
    assert not det.fired


def test_injected_degradation_deterministic_fires_within_k():
    """Hypothesis-free pin of the true-positive property: healthy steps
    then a sustained 2x slowdown fires in exactly k+1 post-onset steps
    (one EWMA-crossing step under alpha=0.5, then the k-run)."""
    det = DriftDetector(tolerance=TOL, ewma_alpha=0.5, k=K)
    for _ in range(10):
        assert det.update(1.0) is False
    fires = [det.update(2.0) for _ in range(K + 2)]
    assert fires == [False] * K + [True, True]


def test_single_straggler_step_does_not_fire():
    det = DriftDetector(tolerance=TOL, k=K)
    det.update(1.0)
    det.update(10.0)              # one bad step
    for _ in range(20):
        assert det.update(1.0) is False or det.fired is False
    assert not det.fired


def test_detector_is_symmetric_in_log_space():
    """2x too slow and 2x too fast are both drift (|ewma| test)."""
    for ratio in (2.0, 0.5):
        det = DriftDetector(tolerance=TOL, ewma_alpha=1.0, k=3)
        fires = [det.update(ratio) for _ in range(5)]
        assert fires == [False, False, True, True, True]


def test_detector_ewma_ratio_tracks_geometric_mean():
    det = DriftDetector(tolerance=10.0, ewma_alpha=1.0, k=1)
    det.update(4.0)
    assert det.ewma_ratio == pytest.approx(4.0)
    det2 = DriftDetector(tolerance=10.0, ewma_alpha=0.5, k=1)
    det2.update(4.0)
    det2.update(1.0)
    assert det2.ewma_ratio == pytest.approx(2.0)   # sqrt(4*1)


# ------------------------------------------------------ ResidualMonitor

def test_monitor_emits_legacy_keys_for_joined_phases_only():
    mon = ResidualMonitor(tolerance=TOL, k=K)
    rec = mon.observe(0,
                      {"dispatch": 2.0, "expert_ffn": 4.0},
                      {"dispatch": 3.0, "step": 9.0})
    # only the intersection produces residuals
    assert rec["residual_dispatch_predicted_ms"] == 2.0
    assert rec["residual_dispatch_measured_ms"] == 3.0
    assert rec["residual_dispatch_ratio"] == pytest.approx(1.5)
    assert "residual_expert_ffn_ratio" not in rec
    assert "residual_step_ratio" not in rec
    assert rec["residual_drift"] == 0.0


def test_monitor_drift_flag_and_reset():
    mon = ResidualMonitor(tolerance=TOL, ewma_alpha=1.0, k=2)
    for i in range(2):
        rec = mon.observe(i, {"step": 1.0}, {"step": 5.0})
    assert rec["residual_drift"] == 1.0
    assert mon.drifted and mon.drifted_phases() == ("step",)
    mon.reset()
    assert not mon.drifted
    rec = mon.observe(9, {"step": 1.0}, {"step": 1.0})
    assert rec["residual_drift"] == 0.0


def test_monitor_device_dispersion_passthrough():
    mon = ResidualMonitor()
    rec = mon.observe(0, {}, {}, per_device_ms={0: 1.0, 1: 1.0, 2: 2.0})
    assert rec["residual_device_dispersion"] == pytest.approx(2.0)


def test_device_dispersion_edge_cases():
    assert device_dispersion({}) == 1.0
    assert device_dispersion({0: 3.0}) == pytest.approx(1.0)
    assert device_dispersion({0: 1.0, 1: 3.0}) == pytest.approx(1.5)


# ----------------------------------------- phase-name join helpers

def test_predicted_phase_ms_from_estimate():
    from repro.comm.topology import Topology
    from repro.plan.estimate import estimate_exchange
    est = estimate_exchange(4096, 2, 512, topo=Topology(2, 4),
                            r_cond=0.0, num_layers=1, ffn_ms=1.0)
    pred = predicted_phase_ms(est)
    assert set(pred) == {"dispatch", "expert_ffn", "combine", "step"}
    assert pred["dispatch"] == pytest.approx(est.dispatch_ms)
    assert pred["step"] == pytest.approx(est.sync_ms)
    piped = predicted_phase_ms(est, pipelined=True)
    assert piped["step"] == pytest.approx(est.overlap_ms)
    assert piped["step"] <= pred["step"]


def test_measured_phase_ms_from_tracer():
    from repro.obs import trace as obs_trace
    tr = obs_trace.Tracer(fence=False)
    obs_trace.activate(tr)
    try:
        for us in (1000, 3000):
            with obs_trace.phase("dispatch", cat="exchange"):
                pass
            tr.events[-1]["dur"] = float(us)    # pin span duration
        with obs_trace.phase("not_a_residual_phase", cat="x"):
            pass
    finally:
        obs_trace.deactivate()
    meas = measured_phase_ms(tr)
    assert set(meas) == {"dispatch"}
    assert meas["dispatch"] == pytest.approx(2.0)   # mean of 1ms, 3ms


def test_residual_phases_cover_canonical_metric_specs():
    """Every residual phase has canonical specs in the registry (the
    monitor's legacy keys all map to residual/... gauges)."""
    from repro.obs.metrics import SCHEMA
    names = set(SCHEMA)
    for phase in obs_mon.RESIDUAL_PHASES:
        for field in ("predicted_ms", "measured_ms", "ratio"):
            assert f"residual/{phase}/{field}" in names
    assert "residual/drift" in names
    assert "residual/device_dispersion" in names
