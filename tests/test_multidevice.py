"""Multi-device correctness, run in subprocesses with 8 host devices
(XLA_FLAGS must be set before jax initializes, hence not in-process —
and conftest deliberately leaves the main process at 1 device).

Covers: sharded-vanilla == single-device, migration loss-invariance +
traffic ledger, condensation+migration training convergence, decode
all-reduce MoE == oracle.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import dataclasses
        import numpy as np
        from repro.configs import get_config
        from repro.config import reduced, LuffyConfig, ShapeConfig, OptimConfig
        from repro.models.model import build_model
        from repro.dist import DistContext, single_device
        from repro.data import SyntheticLM
        from repro.core.moe_layer import capacity_for

        cfg = reduced(get_config("moe-gpt2"), num_layers=2)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        shape = ShapeConfig("t", 128, 8, "train")
        data = SyntheticLM(cfg, shape)
        b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        from repro.comm import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        dist = DistContext(mesh, batch_axes=("data", "model"),
                           seq_axis=None, fsdp_axes=("data",))
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_vanilla_matches_single_device():
    out = _run("""
        off = LuffyConfig(enable_condensation=False, enable_migration=False)
        cap1 = capacity_for(cfg.moe, 8*128, cfg.moe.num_experts, slack=8.0)
        cap8 = capacity_for(cfg.moe, 128, cfg.moe.num_experts, slack=8.0)
        l1, m1 = model.train_loss(params, b, jnp.float32(1.0), luffy=off,
                                  dist=single_device(), capacity=cap1)
        l2, m2 = jax.jit(lambda p, bb: model.train_loss(
            p, bb, jnp.float32(1.0), luffy=off, dist=dist,
            capacity=cap8))(params, b)
        assert abs(float(l1) - float(l2)) < 5e-3, (float(l1), float(l2))
        assert float(m2["dispatch_drop"]) == 0.0
        print("OK", float(l1), float(l2))
    """)
    assert "OK" in out


def test_migration_is_loss_invariant_and_reduces_traffic():
    out = _run("""
        off = LuffyConfig(enable_condensation=False, enable_migration=False)
        mig = LuffyConfig(enable_condensation=False, enable_migration=True,
                          combine_slack=4.0)
        cap8 = capacity_for(cfg.moe, 128, cfg.moe.num_experts, slack=8.0)
        l0, m0 = jax.jit(lambda p, bb: model.train_loss(
            p, bb, jnp.float32(1.0), luffy=off, dist=dist,
            capacity=cap8))(params, b)
        l1, m1 = jax.jit(lambda p, bb: model.train_loss(
            p, bb, jnp.float32(1.0), luffy=mig, dist=dist,
            capacity=cap8))(params, b)
        assert abs(float(l0) - float(l1)) < 5e-3, (float(l0), float(l1))
        assert float(m1["combine_drop"]) == 0.0
        assert float(m1["traffic_after"]) <= float(m1["traffic_before"])
        assert float(m1["local_frac"]) >= 1.0 / 4 - 1e-6
        print("OK", float(m1["traffic_before"]), float(m1["traffic_after"]),
              float(m1["local_frac"]))
    """)
    assert "OK" in out


def test_full_luffy_training_converges_sharded():
    out = _run("""
        from repro import optim, train_lib
        luffy = LuffyConfig(condense_group=64, combine_slack=2.0)
        cap8 = capacity_for(cfg.moe, 128, cfg.moe.num_experts)
        ocfg = OptimConfig(total_steps=20, warmup_steps=2)
        pspecs = model.param_pspecs(dist)
        step = jax.jit(train_lib.make_train_step(
            cfg, luffy, ocfg, dist, cap8, param_pspecs=pspecs))
        p = jax.device_put(params, jax.tree.map(
            lambda s: dist.sharding(s), pspecs))
        ost = optim.init_opt_state(p, ocfg)
        lst = train_lib.init_luffy_state()
        losses = []
        for i in range(12):
            bb = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            p, ost, lst, m = step(p, ost, lst, bb)
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0] - 0.2, losses
        assert float(m["condense_rate"]) > 0.0
        print("OK", losses[0], losses[-1])
    """)
    assert "OK" in out


def test_decode_moe_allreduce_matches_dense_path():
    out = _run("""
        from repro import serve_lib
        from repro.data import make_decode_batch
        luffy = LuffyConfig()
        B = 8
        cache1 = serve_lib.cache_struct(cfg, B, 64, as_struct=False)
        cache2 = serve_lib.cache_struct(cfg, B, 64, as_struct=False)
        tok = jnp.asarray(np.random.default_rng(0).integers(
            1, cfg.vocab_size, (B, 1)), jnp.int32)
        lg1, _ = serve_lib.decode_step(params, cfg, luffy, single_device(),
                                       cache1, tok)
        ddist = DistContext(mesh, batch_axes=("data",), seq_axis="model",
                            fsdp_axes=("data",))
        lg2, _ = jax.jit(lambda p, c, t: serve_lib.decode_step(
            p, cfg, luffy, ddist, c, t))(params, cache2, tok)
        d = float(jnp.max(jnp.abs(lg1 - lg2)))
        assert d < 1e-3, d
        print("OK", d)
    """)
    assert "OK" in out
