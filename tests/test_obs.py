"""repro.obs (DESIGN.md §11): span nesting + exclusive-time invariants
(property test), Chrome-trace export validity, the phase() hook's no-op
guarantees (no tracer / inside a jax trace), the unified metrics
registry (canonical names, counter accumulation, applicability
masking — the inter_bytes_shipped null fix), calibration artifact
round-trip + stale-fingerprint/version-drift miss semantics, the
plan_key chunk-overhead extension's backward compatibility, and the
8-device traced-exchange invariant (subprocess)."""
import json
import os
import subprocess
import sys
import textwrap
import time

import pytest

from _hyp import given, settings, st   # optional dep; skips when absent

from repro.config import LuffyConfig
from repro.obs import calibrate as obs_cal
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.calibrate import Calibration, calibration_key
from repro.obs.metrics import (COMM_LEDGER_SCHEMA_VERSION,
                               METRICS_SCHEMA_VERSION, MetricsRegistry,
                               canonical_name, flatten, mask_inapplicable)
from repro.obs.trace import NULL_SPAN, Tracer

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# trace: spans
# ---------------------------------------------------------------------------

def test_span_nesting_and_exclusive_time():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("a"):
            time.sleep(0.002)
        with tr.span("b"):
            time.sleep(0.002)
    ev = {e["name"]: e for e in tr.spans()}
    assert set(ev) == {"outer", "a", "b"}
    # children complete (and record) before the parent
    names = [e["name"] for e in tr.spans()]
    assert names.index("outer") > names.index("a")
    assert names.index("outer") > names.index("b")
    # inclusive parent time covers both children; exclusive excludes them
    child_dur = ev["a"]["dur"] + ev["b"]["dur"]
    assert ev["outer"]["dur"] >= child_dur
    assert ev["outer"]["args"]["self_us"] == pytest.approx(
        ev["outer"]["dur"] - child_dur, abs=1e-3)
    for e in tr.spans():
        assert 0.0 <= e["args"]["self_us"] <= e["dur"] + 1e-9


def _tree_strategy():
    return st.recursive(st.just([]),
                        lambda kids: st.lists(kids, max_size=3),
                        max_leaves=12)


@settings(max_examples=25, deadline=None)
@given(tree=_tree_strategy())
def test_span_tree_property(tree):
    """For ANY nesting structure: one event per span, post-order
    completion, child intervals contained in the parent's, and parent
    inclusive duration >= sum of direct-child durations."""
    tr = Tracer()
    parent_of = {}
    counter = [0]

    def walk(kids, parent_name):
        name = f"n{counter[0]}"
        counter[0] += 1
        parent_of[name] = parent_name
        with tr.span(name):
            for k in kids:
                walk(k, name)

    walk(tree, None)
    events = {e["name"]: e for e in tr.spans()}
    assert len(events) == len(parent_of)
    order = [e["name"] for e in tr.spans()]
    for name, parent in parent_of.items():
        if parent is None:
            continue
        c, p = events[name], events[parent]
        assert order.index(name) < order.index(parent)   # post-order
        assert c["ts"] >= p["ts"] - 1e-6
        assert c["ts"] + c["dur"] <= p["ts"] + p["dur"] + 1e-6
    for parent in parent_of.values():
        if parent is None:
            continue
        kids = [events[n] for n, p in parent_of.items() if p == parent]
        assert events[parent]["dur"] >= \
            sum(k["dur"] for k in kids) - 1e-6
        assert events[parent]["args"]["self_us"] == pytest.approx(
            events[parent]["dur"] - sum(k["dur"] for k in kids),
            abs=1e-3)


def test_chrome_trace_export(tmp_path):
    tr = Tracer()
    with tr.span("step", cat="step", step=0):
        pass
    tr.instant("mark")
    tr.counter("tokens", condensed=3.0)
    path = tmp_path / "sub" / "trace.json"
    tr.write(path)
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    for e in doc["traceEvents"]:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
        if e["ph"] == "X":                       # complete events
            assert "dur" in e and e["dur"] >= 0.0
    steps = [e for e in doc["traceEvents"] if e["name"] == "step"]
    assert steps[0]["args"]["step"] == 0


def test_chrome_trace_per_device_rows(tmp_path):
    """Device-tagged spans (the per-device exchange probe) get their own
    synthetic tid row plus a thread_name metadata event, so Perfetto
    shows devices side-by-side instead of flattening them onto the host
    thread."""
    from repro.obs.trace import DEVICE_TID_BASE
    tr = Tracer()
    for dev in range(3):
        with tr.span("probe_exchange", cat="probe", device=dev):
            pass
    with tr.span("step", cat="step"):          # untagged: host row
        pass
    doc = tr.to_chrome()
    probes = [e for e in doc["traceEvents"]
              if e["ph"] == "X" and e["name"] == "probe_exchange"]
    assert sorted(e["tid"] for e in probes) == [
        DEVICE_TID_BASE, DEVICE_TID_BASE + 1, DEVICE_TID_BASE + 2]
    (step,) = [e for e in doc["traceEvents"]
               if e["ph"] == "X" and e["name"] == "step"]
    assert step["tid"] < DEVICE_TID_BASE       # host tids are 16-bit
    names = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {e["args"]["name"] for e in names} == \
        {"device 0", "device 1", "device 2"}
    # metadata events still satisfy the validity invariant above
    for e in names:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
    # the in-memory recorder is untouched: summary() still aggregates
    assert tr.summary()["probe_exchange"]["count"] == 3


def test_phase_hook_noop_without_tracer():
    obs_trace.deactivate()
    assert obs_trace.phase("dispatch") is NULL_SPAN
    sentinel = object()
    with obs_trace.phase("dispatch") as sp:
        assert sp.fence(sentinel) is sentinel
    tr = obs_trace.activate(Tracer())
    try:
        with obs_trace.phase("dispatch", cat="phase", layer=3):
            pass
    finally:
        obs_trace.deactivate()
    (e,) = tr.spans("dispatch")
    assert e["args"]["layer"] == 3


def test_phase_hook_noop_inside_jax_trace():
    """Inside a scan/jit body host timestamps are compile-time garbage:
    phase() must drop the span, not record it."""
    import jax
    import jax.numpy as jnp
    tr = obs_trace.activate(Tracer())
    try:
        def body(c, x):
            with obs_trace.phase("inner"):
                c = c + x
            return c, c
        jax.lax.scan(body, jnp.float32(0.0), jnp.arange(4, dtype=jnp.float32))
        jax.jit(lambda x: obs_trace.phase("jitted").__enter__() and x)(
            jnp.float32(1.0))
    finally:
        obs_trace.deactivate()
    assert tr.spans("inner") == []
    assert tr.spans("jitted") == []


def test_tracer_summary():
    tr = Tracer()
    for _ in range(3):
        with tr.span("step"):
            with tr.span("io"):
                pass
    s = tr.summary()
    assert s["step"]["count"] == 3 and s["io"]["count"] == 3
    assert s["step"]["self_us"] <= s["step"]["total_us"]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_canonical_names():
    assert canonical_name("loss") == "train/loss"
    assert canonical_name("plans_built") == "plan/built"
    assert canonical_name("inter_bytes_shipped") == \
        "comm/inter_bytes_shipped"
    assert canonical_name("reuse_mismatch") == "plan/reuse_mismatch"
    assert canonical_name("not_a_known_key") == "not_a_known_key"


def test_registry_counters_accumulate_gauges_dont():
    luffy = LuffyConfig(comm_mode="hier", hier_dedup="on")
    reg = MetricsRegistry(luffy=luffy, run_info={"arch": "x"})
    r0 = reg.observe(0, {"loss": 2.0, "plans_built": 2,
                         "inter_bytes_shipped": 100.0})
    r1 = reg.observe(1, {"loss": 1.0, "plans_built": 1,
                         "inter_bytes_shipped": 50.0})
    assert r0["schema_version"] == METRICS_SCHEMA_VERSION
    assert "run" in r0 and "run" not in r1          # stamped once
    assert r1["metrics"]["train/loss"] == 1.0
    assert r1["cumulative"]["plan/built"] == 3.0
    assert r1["cumulative"]["comm/inter_bytes_shipped"] == 150.0
    assert "train/loss" not in r1["cumulative"]     # gauges don't sum


def test_applicability_masking():
    raw = {"inter_bytes_flat": 10.0, "inter_bytes_dedup": 8.0,
           "inter_bytes_shipped": 0.0, "loss": 1.0}
    flat = mask_inapplicable(raw, LuffyConfig(comm_mode="flat"))
    assert flat["inter_bytes_flat"] is None
    assert flat["inter_bytes_shipped"] is None
    assert flat["loss"] == 1.0
    hier = mask_inapplicable(raw, LuffyConfig(comm_mode="hier"))
    assert hier["inter_bytes_flat"] == 10.0
    assert hier["inter_bytes_shipped"] is None      # dense wire: null
    dedup = mask_inapplicable(
        raw, LuffyConfig(comm_mode="hier", hier_dedup="on"))
    assert dedup["inter_bytes_shipped"] == 0.0
    # the registry reports the same nulls under canonical names and
    # never accumulates an inapplicable counter
    reg = MetricsRegistry(luffy=LuffyConfig(comm_mode="flat"))
    rec = reg.observe(0, raw)
    assert rec["metrics"]["comm/inter_bytes_flat"] is None
    assert "comm/inter_bytes_flat" not in rec["cumulative"]


def test_write_jsonl_appends(tmp_path):
    path = tmp_path / "deep" / "m.jsonl"
    obs_metrics.write_jsonl(path, {"step": 0})
    obs_metrics.write_jsonl(path, {"step": 1})
    recs = [json.loads(x) for x in path.read_text().splitlines()]
    assert [r["step"] for r in recs] == [0, 1]


def test_read_jsonl_tolerates_truncation(tmp_path):
    """A killed run leaves a valid JSONL prefix: every whole line (one
    atomic write each) parses, and a torn final line is skipped instead
    of poisoning the whole file."""
    path = tmp_path / "m.jsonl"
    for i in range(5):
        obs_metrics.write_jsonl(path, {"step": i, "metrics": {"x": i}})
    data = path.read_bytes()
    assert len(obs_metrics.read_jsonl(path)) == 5
    # chop the file mid-way through the last record (simulated kill)
    path.write_bytes(data[:-7])
    recs = obs_metrics.read_jsonl(path)
    assert [r["step"] for r in recs] == [0, 1, 2, 3]
    # record-by-record: every complete prefix parses at every cut point
    for cut in range(len(data)):
        path.write_bytes(data[:cut])
        recs = obs_metrics.read_jsonl(path)
        assert [r["step"] for r in recs] == list(range(len(recs)))
        assert len(recs) >= data[:cut].count(b"\n") - 1
    assert obs_metrics.read_jsonl(tmp_path / "absent.jsonl") == []


def test_flatten_nested():
    flat = flatten("comm_ledger", {"buckets": {"0.0": {"flat": 1}},
                                   "dedup_factor": 2.0})
    assert flat == {"comm_ledger/buckets/0.0/flat": 1,
                    "comm_ledger/dedup_factor": 2.0}


# ---------------------------------------------------------------------------
# calibration artifact
# ---------------------------------------------------------------------------

def _calib(key: str) -> Calibration:
    return Calibration(key=key, intra_bw=2e10, inter_bw=5e9,
                       intra_lat=1e-5, inter_lat=4e-5,
                       chunk_overhead_ms=0.07, plan_step_us=3.0,
                       sim_speed=1e11, ffn_speed=2e12,
                       samples={"rows_list": [64]})


def test_calibration_roundtrip():
    c = _calib("2x2i1e+10e2e+09l0-0__cpu")
    back = Calibration.from_json(c.to_json(), expect_key=c.key)
    assert back == c


def test_calibration_miss_semantics():
    c = _calib("2x2i1e+10e2e+09l0-0__cpu")
    text = c.to_json()
    # stale fingerprint (different topology/backend) is a MISS, not an
    # error and never a silent hit
    assert Calibration.from_json(text, expect_key="4x2i1e+10e2e+09l0-0"
                                 "__cpu") is None
    assert Calibration.from_json("{not json", expect_key=c.key) is None
    assert Calibration.from_json(json.dumps({"a": 1})) is None
    bumped = json.loads(text)
    bumped["schema_version"] = obs_cal.CALIBRATION_SCHEMA_VERSION + 1
    assert Calibration.from_json(json.dumps(bumped),
                                 expect_key=c.key) is None
    wrong_magic = json.loads(text)
    wrong_magic["magic"] = "something-else"
    assert Calibration.from_json(json.dumps(wrong_magic)) is None


def test_calibration_save_load_dir(tmp_path):
    c = _calib("flat4__cpu")
    path = obs_cal.save_calibration(tmp_path, c)
    assert path.name == "flat4__cpu.calib.json"
    assert obs_cal.load_calibration(tmp_path, c.key) == c
    assert obs_cal.load_calibration(tmp_path, "flat8__cpu") is None
    # a corrupted artifact is a miss too
    path.write_text(path.read_text().replace(obs_cal.CALIBRATION_MAGIC,
                                             "nope"))
    assert obs_cal.load_calibration(tmp_path, c.key) is None


def test_calibration_key_binds_backend_and_topology():
    from repro.comm.topology import Topology
    topo = Topology(2, 2, intra_bw=1e10, inter_bw=2e9)
    k_cpu = calibration_key(topo, 4, backend="cpu")
    k_tpu = calibration_key(topo, 4, backend="tpu")
    assert k_cpu.endswith("__cpu") and k_tpu.endswith("__tpu")
    assert k_cpu.split("__")[0] == k_tpu.split("__")[0]
    assert calibration_key(None, 4, backend="cpu") == "flat4__cpu"


def test_calibration_pricing_handoff():
    from repro.comm.topology import Topology
    c = _calib("2x2i1e+10e2e+09l0-0__cpu")
    topo = c.topology(Topology(2, 2, intra_bw=1e10, inter_bw=2e9))
    assert topo.intra_bw == c.intra_bw and topo.inter_bw == c.inter_bw
    assert topo.num_nodes == 2 and topo.devices_per_node == 2
    luffy = c.apply(LuffyConfig())
    assert luffy.gpu_speed == c.ffn_speed
    assert luffy.chunk_overhead_ms == c.chunk_overhead_ms
    kw = c.estimate_kwargs()
    assert set(kw) == {"intra_bw", "inter_bw", "chunk_overhead_ms"}


# ---------------------------------------------------------------------------
# plan-key / cost-constant integration
# ---------------------------------------------------------------------------

def test_plan_key_chunk_overhead_backward_compatible():
    from repro.plan import plan_key
    kw = dict(n_seq=2, seq_len=64, d_model=128, capacity=32, top_k=2,
              num_experts=4, mode="vanilla", objective="traffic",
              exec_mode="sync", pipeline_chunks=4, comm_mode="flat",
              topo=None, M=4)
    legacy = plan_key(**kw)
    assert plan_key(**kw, chunk_overhead_ms=-1.0) == legacy   # default
    assert plan_key(**kw, chunk_overhead_ms=0.0) == legacy    # unset
    calibrated = plan_key(**kw, chunk_overhead_ms=0.07)
    assert calibrated != legacy and calibrated.endswith("_o0.07")


def test_resolve_chunk_overhead_ms():
    from repro.sched.cost import (DEFAULT_CHUNK_OVERHEAD_MS,
                                  resolve_chunk_overhead_ms)
    assert resolve_chunk_overhead_ms(None) == DEFAULT_CHUNK_OVERHEAD_MS
    assert resolve_chunk_overhead_ms(-1.0) == DEFAULT_CHUNK_OVERHEAD_MS
    assert resolve_chunk_overhead_ms(0.0) == DEFAULT_CHUNK_OVERHEAD_MS
    assert resolve_chunk_overhead_ms(0.2) == 0.2
    # the config default means "use the built-in constant"
    assert resolve_chunk_overhead_ms(LuffyConfig().chunk_overhead_ms) \
        == DEFAULT_CHUNK_OVERHEAD_MS


def test_finalize_metrics_masks_and_floats():
    import numpy as np
    from repro import train_lib
    m = train_lib.finalize_metrics(
        {"loss": np.float32(1.5), "inter_bytes_shipped": np.float32(0.0),
         "bucket": 1}, LuffyConfig(comm_mode="hier"))
    assert m["loss"] == 1.5 and isinstance(m["loss"], float)
    assert m["inter_bytes_shipped"] is None
    assert m["bucket"] == 1.0


# ---------------------------------------------------------------------------
# 8-device: traced probe exchange (subprocess)
# ---------------------------------------------------------------------------

def test_traced_exchange_8dev():
    """--trace invariants on a real hier exchange: every instrumented
    phase fires, the inclusive 'exchange' span covers the sum of its
    children's EXCLUSIVE times, and a jitted step records no phase
    spans (scan bodies are structurally silent)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import json
        import jax, jax.numpy as jnp
        from repro.config import LuffyConfig, reduced
        from repro.configs import get_config
        from repro.obs import trace as obs_trace
        from repro.obs.calibrate import probe_exchange

        cfg = reduced(get_config("moe-gpt2"), num_layers=2, d_model=64,
                      max_experts=4, seq_len_hint=32)
        luffy = LuffyConfig(enable_condensation=True,
                            enable_migration=True, condense_group=32)
        tr = obs_trace.activate(obs_trace.Tracer(fence=True))
        probe_exchange(cfg, luffy, seq_len=32)
        obs_trace.deactivate()
        names = {e["name"] for e in tr.spans()}
        required = {"plan_build", "condense", "dispatch", "expert_ffn",
                    "combine", "exchange"}
        assert required <= names, (required - names, names)
        (ex,) = tr.spans("exchange")
        t0, t1 = ex["ts"], ex["ts"] + ex["dur"]
        child_excl = sum(
            e["args"]["self_us"] for e in tr.spans()
            if e is not ex and e["ts"] >= t0 - 1e-6
            and e["ts"] + e["dur"] <= t1 + 1e-6)
        assert ex["dur"] >= child_excl - 1e-3, (ex["dur"], child_excl)

        tr2 = obs_trace.activate(obs_trace.Tracer(fence=True))
        def step(x):
            def body(c, _):
                with obs_trace.phase("scan_phase"):
                    c = c * 2.0
                return c, c
            out, _ = jax.lax.scan(body, x, None, length=3)
            return out
        jax.jit(step)(jnp.float32(1.0))
        obs_trace.deactivate()
        assert tr2.spans() == [], tr2.spans()
        print("OK8")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK8" in out.stdout
