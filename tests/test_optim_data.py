"""Optimizer + blocks unit tests and hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st   # optional dep; skips when absent

from repro import optim
from repro.config import OptimConfig
from repro.models import blocks as bk


def _quad_problem(name):
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    ocfg = OptimConfig(name=name, lr=0.1, warmup_steps=1, total_steps=200,
                       weight_decay=0.0)
    state = optim.init_opt_state(params, ocfg)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, m = optim.update(params, g, state, ocfg)
    return float(loss(params))


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
def test_optimizers_minimize_quadratic(name):
    assert _quad_problem(name) < 0.05


def test_adafactor_state_is_factored():
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((16,))}
    st_ = optim.init_opt_state(params, OptimConfig(name="adafactor"))
    assert isinstance(st_.nu["big"], dict)
    assert st_.nu["big"]["r"].shape == (256,)
    assert st_.nu["big"]["c"].shape == (512,)
    assert st_.mu["big"].dtype == jnp.bfloat16
    assert st_.nu["small"].shape == (16,)


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0 * np.sqrt(10)) < 1e-3
    total = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert abs(total - 1.0) < 1e-5


def test_lr_schedule_shape():
    cfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(optim.lr_schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6
    assert lrs[99] < lrs[50] < lrs[12]


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128]),
       st.sampled_from([None, 16, 32]), st.booleans())
def test_chunked_attention_property(seed, S, window, causal):
    """attend_chunked == attend for arbitrary shapes/windows/causality."""
    r = np.random.default_rng(seed)
    B, H, KV, hd = 2, 2, 1, 16
    q = jnp.asarray(r.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, S, KV, hd)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    posb = jnp.broadcast_to(pos[None], (B, S))
    mask = bk.make_attn_mask(posb, posb, causal=causal, window=window)
    o1 = bk.attend(q, k, v, mask, 0.25)
    o2 = bk.attend_chunked(q, k, v, pos, pos, 0.25, causal=causal,
                           window=window, chunked_window=False,
                           chunk_q=32, chunk_k=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_rope_rotation_invariant():
    """RoPE preserves norms and relative-position inner products."""
    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal((1, 8, 2, 32)), jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    y = bk.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> independent of p
    q = x[:, 0:1]
    dots = []
    for p in [0, 3]:
        qq = bk.apply_rope(q, jnp.asarray([[p]]), 10_000.0)
        vv = bk.apply_rope(q, jnp.asarray([[p + 2]]), 10_000.0)
        dots.append(float(jnp.sum(qq * vv)))
    assert abs(dots[0] - dots[1]) < 1e-3
