"""repro.plan: ExchangePlan build/execute equivalence with moe_core, the
planner-objective registry ("traffic" == legacy exactly, "overlap" never
worse in modeled exposed time), the shared phase-estimate model, and the
8-device golden grid {vanilla, migrate} × {condense} × {flat, hier} ×
{sync, pipeline} plus the pipelined serving prefill (DESIGN.md §7)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommContext, Topology
from repro.config import LuffyConfig, ModelConfig, MoEConfig
from repro.core import moe_layer as ml
from repro.plan import (ObjectiveContext, PlanEstimate,
                        available_objectives, build_exchange_plan,
                        estimate_exchange, execute_plan, get_objective,
                        plan_migration_with_objective, register_objective)
from repro.plan import objectives as obj

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# objective registry
# ---------------------------------------------------------------------------

def test_registry_lookup_and_error():
    assert set(available_objectives()) >= {"traffic", "overlap"}
    assert get_objective("traffic") is obj.traffic_objective
    with pytest.raises(ValueError, match="traffic"):
        get_objective("nope")


def test_registry_extensible():
    @register_objective("_test_identity")
    def identity_objective(counts, seq_lens, n_per_dev, *, ctx, q=3,
                           d_model=1024, speed=1e13):
        from repro.core.migration import identity_plan
        return identity_plan(counts.shape[0], n_per_dev)

    try:
        assert "_test_identity" in available_objectives()
        plan = plan_migration_with_objective(
            np.ones((4, 2)), np.arange(4.0), 2, objective="_test_identity")
        np.testing.assert_array_equal(np.asarray(plan.perm), np.arange(4))
    finally:
        obj.OBJECTIVES.pop("_test_identity")


def _instance(seed, n_slots, M):
    r = np.random.default_rng(seed)
    counts = (r.random((n_slots, M)) ** 3)
    counts = (counts / counts.sum(1, keepdims=True) * 100)
    counts = counts + r.random(counts.shape) * 1e-3   # break ties
    lens = r.integers(10, 100, n_slots).astype(np.float64)
    return counts.astype(np.float64), lens


def test_traffic_objective_reproduces_legacy_plans():
    """"traffic" through the registry == the pre-registry planner calls,
    both with and without a hierarchical topology."""
    from repro.core import migration as mig
    topo = Topology(2, 2)
    counts, lens = _instance(0, 8, 4)
    for ctx, link_cost in ((ObjectiveContext(topo=topo), topo.link_cost()),
                           (ObjectiveContext(topo=None), None),
                           (ObjectiveContext(topo=Topology.flat(4)), None)):
        got = plan_migration_with_objective(counts, lens, 2,
                                            objective="traffic", ctx=ctx,
                                            q=2)
        want = mig.plan_migration_np(counts, lens, 2, q=2,
                                     link_cost=link_cost)
        np.testing.assert_array_equal(np.asarray(got.assign),
                                      np.asarray(want.assign))
        assert float(got.traffic_after) == float(want.traffic_after)


# ---------------------------------------------------------------------------
# "overlap" objective: exposed-time model + never-worse guarantee
# ---------------------------------------------------------------------------

def _inter_bound_ctx(topo, chunks=4):
    """A pipeline where the inter-node phase is the bottleneck stage —
    the regime the overlap objective exists for."""
    return ObjectiveContext(topo=topo, ffn_ms=5.0, dispatch_intra_ms=1.0,
                            dispatch_inter_ms=8.0, chunks=chunks,
                            row_bytes=4096.0)


def test_exposed_link_cost_amplifies_inter_node_cost():
    topo = Topology(2, 4)                       # bw_ratio 4
    ctx = _inter_bound_ctx(topo, chunks=4)
    cost = obj.exposed_link_cost(ctx)
    assert cost[0, 1] == 1.0                    # intra normalized
    # hidden intra (1/n) vs exposed inter (1) -> n * bw_ratio
    assert cost[0, 4] == pytest.approx(4 * topo.bw_ratio)
    # sync (1 chunk) degenerates to the plain link-cost matrix
    sync = obj.exposed_link_cost(
        ObjectiveContext(topo=topo, ffn_ms=5.0, dispatch_intra_ms=1.0,
                         dispatch_inter_ms=8.0, chunks=1))
    np.testing.assert_allclose(sync, topo.link_cost())


def test_overlap_objective_never_worse_2x4():
    """Satellite acceptance: on a 2×4 hier topology the "overlap" plan's
    modeled exposed time is never worse than the "traffic" plan's, and
    the portfolio actually wins on some instances."""
    topo = Topology(2, 4)
    ctx = _inter_bound_ctx(topo)
    M, n_per = topo.num_devices, 2
    strictly_better = 0
    for seed in range(40):
        counts, lens = _instance(seed, M * n_per, M)
        p_t = plan_migration_with_objective(counts, lens, n_per,
                                            objective="traffic", ctx=ctx)
        p_o = plan_migration_with_objective(counts, lens, n_per,
                                            objective="overlap", ctx=ctx)
        t_t = float(obj.plan_exposed_ms(counts, np.asarray(p_t.assign),
                                        ctx))
        t_o = float(obj.plan_exposed_ms(counts, np.asarray(p_o.assign),
                                        ctx))
        assert t_o <= t_t + 1e-9, (seed, t_o, t_t)
        # the overlap plan is still a valid capacity-respecting bijection
        perm = np.asarray(p_o.perm)
        assert sorted(perm.tolist()) == list(range(M * n_per))
        assert (np.bincount(np.asarray(p_o.assign), minlength=M)
                == n_per).all()
        if t_o < t_t - 1e-9:
            strictly_better += 1
    assert strictly_better >= 1


def test_overlap_objective_traced_matches_host():
    """jax backend (inside jit) == numpy backend for both objectives."""
    topo = Topology(2, 4)
    ctx = _inter_bound_ctx(topo)
    for seed in (3, 7):
        counts, lens = _instance(seed, 16, 8)

        @jax.jit
        def go(c, l):
            p = plan_migration_with_objective(c, l, 2, objective="overlap",
                                              ctx=ctx)
            return p.assign, p.perm

        a, perm = go(jnp.asarray(counts, jnp.float32),
                     jnp.asarray(lens, jnp.float32))
        p_np = plan_migration_with_objective(counts, lens, 2,
                                             objective="overlap", ctx=ctx)
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(p_np.assign))
        np.testing.assert_array_equal(np.asarray(perm),
                                      np.asarray(p_np.perm))


def test_overlap_degenerates_without_hierarchy_or_pipeline():
    """Flat fabric or sync execution: nothing to hide, so "overlap"
    returns the traffic plan exactly."""
    counts, lens = _instance(1, 8, 4)
    flat_ctx = ObjectiveContext(topo=Topology.flat(4), chunks=8)
    sync_ctx = _inter_bound_ctx(Topology(2, 2), chunks=1)
    for ctx in (flat_ctx, sync_ctx):
        p_t = plan_migration_with_objective(counts, lens, 2,
                                            objective="traffic", ctx=ctx)
        p_o = plan_migration_with_objective(counts, lens, 2,
                                            objective="overlap", ctx=ctx)
        np.testing.assert_array_equal(np.asarray(p_t.assign),
                                      np.asarray(p_o.assign))


# ---------------------------------------------------------------------------
# phase estimates
# ---------------------------------------------------------------------------

def test_estimate_exchange_contracts():
    topo = Topology(2, 4)
    est = estimate_exchange(4096, 2, 64, topo=topo, r_cond=0.25,
                            locality=0.4, ffn_ms=3.0, chunks=4)
    assert isinstance(est, PlanEstimate)
    assert est.chunks == 4
    assert est.overlap_ms <= est.sync_ms
    assert est.inter_dispatch_bytes <= est.flat_inter_dispatch_bytes
    assert est.intra_combine_bytes == pytest.approx(
        est.intra_dispatch_bytes * 0.6)
    assert est.inter_combine_bytes == pytest.approx(
        est.inter_dispatch_bytes * 0.6)
    assert est.combine_ms < est.dispatch_ms         # locality gain
    assert est.speedup == pytest.approx(est.sync_ms / est.overlap_ms)
    # planning search picks the best chunk count over 1..16
    opt = estimate_exchange(4096, 2, 64, topo=topo, r_cond=0.25,
                            locality=0.4, ffn_ms=3.0, chunks=None)
    assert opt.overlap_ms <= est.overlap_ms + 1e-12
    # flat fabric: no inter-node bytes, dedup changes nothing
    flat = estimate_exchange(4096, 2, 64, topo=Topology.flat(8),
                             ffn_ms=3.0, chunks=2)
    assert flat.inter_dispatch_bytes == 0.0
    assert flat.intra_dispatch_bytes == flat.flat_intra_dispatch_bytes


# ---------------------------------------------------------------------------
# build/execute == moe_core (single device, eager: bitwise)
# ---------------------------------------------------------------------------

def _mk(num_experts=4, top_k=2, shared=1):
    return ModelConfig(
        name="t", kind="decoder", family="moe", num_layers=2,
        d_model=32, d_ff=64, vocab_size=128,
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, d_ff=64,
                      num_shared_experts=shared),
        layer_ffn_pattern=("moe",), compute_dtype="float32",
        param_dtype="float32")


@pytest.mark.parametrize("condense", [False, True])
def test_build_execute_matches_moe_core_single_device(rng, condense):
    from repro.core.gating import gate_apply
    from repro.models.blocks import _dtype
    cfg = _mk()
    p = ml.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    sb = {"labels": jnp.zeros((2, 16), jnp.int32),
          "seq_len": jnp.full((2,), 16, jnp.int32)}
    luffy = LuffyConfig(enable_condensation=condense,
                        enable_migration=False, condense_group=16)
    thr = jnp.float32(0.9)
    y1, sb1, s1, aux1 = ml.moe_core(p, x, dict(sb), cfg, luffy,
                                    mode="vanilla", capacity=256,
                                    axis_name=None, threshold=thr,
                                    group_size=16)
    comm = CommContext.local()
    xn = ml._rms(x.reshape(-1, cfg.d_model),
                 p["norm"]["scale"]).astype(_dtype(cfg.compute_dtype))
    gate = gate_apply(p["router"], xn, cfg.moe.top_k)
    plan = build_exchange_plan(gate, xn, cfg, luffy, comm, mode="vanilla",
                               capacity=256, sideband=sb, threshold=thr,
                               group_size=16)
    y2, aux2 = execute_plan(p, x, dict(sb), plan, cfg)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    for a, b in zip(aux1, aux2.moe):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    if condense:
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(aux2.s_next))
    # plan shape/static contracts
    assert plan.comm.mode == "local" and plan.comm.size() == 1
    assert plan.chunks.n_chunks == 1 and not plan.pipelined
    assert plan.estimate is None            # no topology to price
    assert plan.objective == "traffic"
    assert plan.expert_idx.shape == (32, cfg.moe.top_k)
    assert plan.condense == condense


def test_comm_context_local_identity():
    c = CommContext.local()
    assert c.size() == 1 and c.index() == 0 and c.axis_name is None
    x = jnp.arange(8.0).reshape(2, 4)
    np.testing.assert_array_equal(np.asarray(c.all_to_all(x)),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(c.combine(x)), np.asarray(x))
    assert c.link_cost() is None
    # ensure(): the one call-boundary normalization
    assert CommContext.ensure(c, "model") is c
    assert CommContext.ensure(None, None).mode == "local"
    assert CommContext.ensure(None, "model").mode == "flat"


# ---------------------------------------------------------------------------
# 8-device golden grid + serving prefill (subprocesses, like test_comm)
# ---------------------------------------------------------------------------

def _run(script_body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import itertools
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CommContext, Topology, make_mesh, shard_map
        from repro.configs import get_config
        from repro.config import reduced, LuffyConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.dist import DistContext, make_dist
        from repro.data import SyntheticLM
        from repro.core.moe_layer import capacity_for
    """) + textwrap.dedent(script_body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_golden_grid_8dev_bit_identity():
    """Golden equivalence: the build/execute forward is invariant across
    {sync, pipeline} × {flat, hier} for {vanilla, migrate} ×
    {condense on/off} on one hierarchical 8-device mesh — i.e. exactly
    the pre-refactor guarantees, now through the ExchangePlan API. The
    "overlap" objective under sync (1 chunk) must also be bit-identical
    (it degenerates to "traffic"), and under a pipelined executor it must
    still train to a finite loss with a valid slot bijection."""
    out = _run("""
        cfg = reduced(get_config("moe-gpt2"), num_layers=2, d_model=128)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        shape = ShapeConfig("t", 64, 8, "train")
        data = SyntheticLM(cfg, shape)
        b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        cap = capacity_for(cfg.moe, 64, cfg.moe.num_experts, slack=8.0)
        mesh = make_mesh((2, 2, 2), ("data", "node", "local"))
        dist = DistContext(mesh, batch_axes=("data", "node", "local"),
                           seq_axis=None, fsdp_axes=("data",),
                           model_axis=("node", "local"),
                           topology=Topology(2, 2))

        def loss(luffy):
            l, m = jax.jit(lambda p, bb: model.train_loss(
                p, bb, jnp.float32(0.4), luffy=luffy, dist=dist,
                capacity=cap))(params, b)
            return float(l), {k: float(v) for k, v in m.items()}

        for mig, cond in itertools.product((True, False), repeat=2):
            base = LuffyConfig(enable_condensation=cond,
                               enable_migration=mig, combine_slack=4.0,
                               condense_group=32, comm_mode="flat")
            l0, m0 = loss(base)
            # inter_bytes_dedup is the one metric ALLOWED to differ when
            # comm_mode flips: the flat wire ships every copy, so its
            # ledger reports dedup == flat by design (DESIGN.md §5)
            variants = [
                (dataclasses.replace(base, comm_mode="hier"), True),
                (dataclasses.replace(base, exec_mode="pipeline",
                                     pipeline_chunks=3), False),
                (dataclasses.replace(base, comm_mode="hier",
                                     exec_mode="pipeline",
                                     pipeline_chunks=3), True),
                (dataclasses.replace(base, plan_objective="overlap"),
                 False),
            ]
            for i, (v, hier) in enumerate(variants):
                lv, mv = loss(v)
                assert l0 == lv, (mig, cond, i, l0, lv)
                for k in m0:
                    if hier and k == "inter_bytes_dedup":
                        continue
                    assert m0[k] == mv[k], (mig, cond, i, k)
        # pipelined "overlap" objective: a different (still valid) plan is
        # allowed — require a finite loss and healthy ledger instead
        ov = LuffyConfig(enable_condensation=True, enable_migration=True,
                         combine_slack=4.0, condense_group=32,
                         comm_mode="hier", exec_mode="pipeline",
                         pipeline_chunks=3, plan_objective="overlap")
        lo, mo = loss(ov)
        assert np.isfinite(lo), lo
        assert mo["traffic_after"] <= mo["traffic_before"] + 1e-5
        assert 0.0 <= mo["local_frac"] <= 1.0
        print("OK")
    """)
    assert "OK" in out


def test_build_execute_matches_moe_core_8dev_shardmap():
    """Direct ExchangePlan API == moe_core inside shard_map, on the
    hardest combo (hier comm × pipeline × migrate × condense)."""
    out = _run("""
        from repro.core import moe_layer as ml
        from repro.core.gating import gate_apply
        from repro.plan import build_exchange_plan, execute_plan
        from repro.models.blocks import _dtype

        cfg = dataclasses.replace(
            reduced(get_config("moe-gpt2"), num_layers=2, d_model=64),
            compute_dtype="float32")
        p = ml.moe_init(jax.random.PRNGKey(1), cfg)
        mesh = make_mesh((2, 2, 2), ("data", "node", "local"))
        topo = Topology(2, 2)
        comm = CommContext.build("hier", ("node", "local"), topo)
        luffy = LuffyConfig(enable_condensation=True, enable_migration=True,
                            combine_slack=4.0, condense_group=16,
                            comm_mode="hier", exec_mode="pipeline",
                            pipeline_chunks=3)
        n_seq, S, d = 2, 32, cfg.d_model
        r = np.random.default_rng(0)
        x = jnp.asarray(r.standard_normal((16, S, d)), jnp.float32)
        lbl = jnp.zeros((16, S), jnp.int32)
        slen = jnp.asarray(r.integers(S // 2, S + 1, (16,)), jnp.int32)
        cap = ml.capacity_for(cfg.moe, n_seq * S, cfg.moe.num_experts,
                              slack=4.0)
        thr = jnp.float32(0.5)

        def inner_core(p_l, x_l, lbl_l, sl_l):
            sb = {"labels": lbl_l, "seq_len": sl_l}
            y, sb2, s_next, aux = ml.moe_core(
                p_l, x_l, sb, cfg, luffy, mode="migrate", capacity=cap,
                comm=comm, threshold=thr, group_size=16,
                combine_slack=4.0)
            return y, sb2["labels"], sb2["seq_len"], s_next

        def inner_plan(p_l, x_l, lbl_l, sl_l):
            sb = {"labels": lbl_l, "seq_len": sl_l}
            xn = ml._rms(x_l.reshape(-1, d), p_l["norm"]["scale"]
                         ).astype(_dtype(cfg.compute_dtype))
            gate = gate_apply(p_l["router"], xn, cfg.moe.top_k)
            plan = build_exchange_plan(
                gate, xn, cfg, luffy, comm, mode="migrate", capacity=cap,
                sideband=sb, threshold=thr, group_size=16,
                combine_slack=4.0)
            assert plan.pipelined and plan.chunks.n_chunks == 3
            assert plan.estimate is not None
            assert plan.migrate and plan.condense
            y, aux = execute_plan(p_l, x_l, sb, plan, cfg)
            return y, aux.sideband["labels"], aux.sideband["seq_len"], \\
                aux.s_next

        ba = ("data", "node", "local")
        ma = ("node", "local")
        p_specs = jax.tree.map(lambda _: P(), p)
        p_specs["experts"] = jax.tree.map(lambda _: P(ma, None, None),
                                          p["experts"])
        specs = dict(
            in_specs=(p_specs,
                      P(ba, None, None), P(ba, None), P(ba)),
            out_specs=(P(ba, None, None), P(ba, None), P(ba),
                       P(ba, None, None)))
        f1 = jax.jit(shard_map(inner_core, mesh=mesh, **specs))
        f2 = jax.jit(shard_map(inner_plan, mesh=mesh, **specs))
        o1 = f1(p, x, lbl, slen)
        o2 = f2(p, x, lbl, slen)
        for a, b in zip(o1, o2):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        # the migrated seq_len multiset is preserved (slot bijection)
        assert sorted(np.asarray(o1[2]).tolist()) == \\
            sorted(np.asarray(slen).tolist())
        print("OK")
    """)
    assert "OK" in out


def test_prefill_pipeline_matches_sync_8dev():
    """Acceptance: serve_lib.prefill runs through the shared
    build/execute core with exec_mode="pipeline" (inherited chunking).
    Prefill's small per-shard capacity (~24 rows) makes XLA's CPU dot
    emitter pick a different fusion for the chunked einsums than the
    monolithic one, so sync vs pipeline agree to the last ulp region
    (≤2e-6 on f32 logits) rather than bitwise — a pre-existing backend
    artifact (the seed path reproduces it exactly; at train capacities
    the golden grid above IS bitwise). The plan objective must not
    change vanilla-mode serving outputs at all."""
    out = _run("""
        from repro import serve_lib

        cfg = dataclasses.replace(
            reduced(get_config("moe-gpt2"), num_layers=2, d_model=128),
            compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh = make_mesh((2, 4), ("data", "model"))
        B, S = 4, 64
        dist = make_dist(mesh, "prefill", B, moe_arch=True)
        assert dist.seq_axis is not None      # prefill shards the sequence
        r = np.random.default_rng(0)
        toks = jnp.asarray(r.integers(1, cfg.vocab_size, (B, S)), jnp.int32)

        def pf(luffy):
            lg, _ = jax.jit(lambda p, t: serve_lib.prefill(
                p, cfg, luffy, dist, t, S))(params, toks)
            return np.asarray(lg)

        sync = pf(LuffyConfig(enable_condensation=False,
                              enable_migration=False))
        pipe = pf(LuffyConfig(enable_condensation=False,
                              enable_migration=False,
                              exec_mode="pipeline", pipeline_chunks=3))
        ov = pf(LuffyConfig(enable_condensation=False,
                            enable_migration=False, exec_mode="pipeline",
                            pipeline_chunks=3, plan_objective="overlap"))
        np.testing.assert_allclose(sync, pipe, atol=2e-6, rtol=0)
        assert np.array_equal(pipe, ov)   # objective: same vanilla plan
        assert np.isfinite(sync).all()
        print("OK")
    """)
    assert "OK" in out
