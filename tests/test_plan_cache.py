"""Plan lifecycle (DESIGN.md §9): cross-layer reuse bit-identity +
counters on the 8-device golden grid, forced-mismatch rebuild,
ExchangePlan serialization round-trip / version rejection, the keyed
PlanCache with disk spill, and the zero-planning serving prefill."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st   # optional dep; skips when absent

from repro.comm import CommContext
from repro.config import LuffyConfig, ModelConfig, MoEConfig
from repro.core import moe_layer as ml
from repro.core.gating import gate_apply
from repro.core.migration import home_plan, plan_migration_np
from repro.plan import (PlanCache, PlanFormatError, PlanSignature,
                        build_exchange_plan, build_plan_template,
                        estimate_planning_ms, estimate_revalidate_ms,
                        execute_plan, from_bytes, instantiate_plan,
                        next_signature, plan_key,
                        routing_signature_matches, to_bytes)
from repro.plan import exchange as pexch
from repro.plan import serial as pserial

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _mk(num_experts=4, top_k=2, shared=1):
    return ModelConfig(
        name="t", kind="decoder", family="moe", num_layers=2,
        d_model=32, d_ff=64, vocab_size=128,
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, d_ff=64,
                      num_shared_experts=shared),
        layer_ffn_pattern=("moe",), compute_dtype="float32",
        param_dtype="float32")


def _single_device_plan(condense=True, capacity=256):
    from repro.models.blocks import _dtype
    cfg = _mk()
    p = ml.moe_init(jax.random.PRNGKey(0), cfg)
    r = np.random.default_rng(1)
    x = jnp.asarray(r.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    sb = {"labels": jnp.zeros((2, 16), jnp.int32),
          "seq_len": jnp.full((2,), 16, jnp.int32)}
    luffy = LuffyConfig(enable_condensation=condense,
                        enable_migration=False, condense_group=16)
    xn = ml._rms(x.reshape(-1, cfg.d_model),
                 p["norm"]["scale"]).astype(_dtype(cfg.compute_dtype))
    gate = gate_apply(p["router"], xn, cfg.moe.top_k)
    plan = build_exchange_plan(
        gate, xn, cfg, luffy, CommContext.local(), mode="vanilla",
        capacity=capacity, sideband=sb, threshold=jnp.float32(0.9),
        group_size=16)
    return cfg, p, x, sb, plan


# ---------------------------------------------------------------------------
# signature helpers (host backend — shared with the traced fast path)
# ---------------------------------------------------------------------------

def test_signature_match_and_next_frame():
    r = np.random.default_rng(0)
    counts = np.floor(r.random((8, 4)) * 50).astype(np.float64)
    lens = r.permutation(np.arange(10, 18)).astype(np.float64)
    plan = plan_migration_np(counts, lens, 2)
    sig = next_signature(counts, lens, np.asarray(plan.perm))
    # the next frame observes the permuted rows -> match
    assert bool(routing_signature_matches(
        sig, np.asarray(sig.counts), np.asarray(sig.lens)))
    # any routing drift -> mismatch
    drift = np.asarray(sig.counts).copy()
    drift[0, 0] += 1.0
    assert not bool(routing_signature_matches(
        sig, drift, np.asarray(sig.lens)))
    # shape drift (different batch) -> mismatch, not an error
    assert not bool(routing_signature_matches(
        sig, np.zeros((4, 4)), np.zeros(4)))


def test_reuse_equals_replan_on_stable_frame():
    """The core reuse guarantee, host-side: when the signature matches,
    the greedy re-derives the current placement, so ``home_plan`` is
    bit-for-bit the plan a full replan would return."""
    for seed in range(10):
        rr = np.random.default_rng(seed)
        counts = np.floor(rr.random((8, 4)) * 50).astype(np.float64)
        lens = rr.permutation(np.arange(20, 28)).astype(np.float64)
        p1 = plan_migration_np(counts, lens, 2)
        sig = next_signature(counts, lens, np.asarray(p1.perm))
        c2, l2 = np.asarray(sig.counts), np.asarray(sig.lens)
        p2 = plan_migration_np(c2, l2, 2)          # what "off" would do
        hp = home_plan(c2, 2)                      # what reuse emits
        np.testing.assert_array_equal(np.asarray(p2.assign),
                                      np.asarray(hp.assign))
        np.testing.assert_array_equal(np.asarray(p2.perm),
                                      np.asarray(hp.perm))
        assert float(p2.traffic_after) == float(hp.traffic_after)
        assert float(p2.traffic_before) == float(hp.traffic_before)


def test_planning_cost_model_sane():
    assert estimate_planning_ms(64, 8) > estimate_planning_ms(16, 8) > 0
    assert estimate_revalidate_ms(64, 8) < estimate_planning_ms(64, 8)


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("condense", [False, True])
def test_serial_roundtrip_executes_identically(condense):
    cfg, p, x, sb, plan = _single_device_plan(condense=condense)
    data = to_bytes(plan)
    plan2 = from_bytes(data)
    # static fields survive
    assert plan2.mode == plan.mode and plan2.capacity == plan.capacity
    assert plan2.chunks == plan.chunks
    assert plan2.objective == plan.objective
    assert plan2.comm.mode == plan.comm.mode
    assert (plan2.estimate is None) == (plan.estimate is None)
    assert plan2.condense == condense
    # every array field round-trips bit-exactly
    for f in pserial._ARRAY_FIELDS:
        a, b = getattr(plan, f), getattr(plan2, f)
        if a is None:
            assert b is None
        else:
            assert np.asarray(a).dtype == np.asarray(b).dtype, f
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the deserialized plan executes bit-identically
    y1, aux1 = execute_plan(p, x, dict(sb), plan, cfg)
    y2, aux2 = execute_plan(p, x, dict(sb), plan2, cfg)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    for a, b in zip(aux1.moe, aux2.moe):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serial_rejects_version_and_magic():
    _, _, _, _, plan = _single_device_plan(condense=False)
    data = bytearray(to_bytes(plan))
    # version bump -> rejected, not misread
    bad = bytes(data[:4]) + bytes([data[4] + 1, data[5]]) + bytes(data[6:])
    with pytest.raises(PlanFormatError, match="version"):
        from_bytes(bad)
    # foreign magic -> rejected
    with pytest.raises(PlanFormatError, match="magic"):
        from_bytes(b"NOPE" + bytes(data[4:]))
    # truncated payload -> rejected
    with pytest.raises(PlanFormatError):
        from_bytes(bytes(data[:-8]))


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_serial_roundtrip_property(data):
    """to_bytes ∘ from_bytes identity across dtypes/shapes for the
    traced-array payload (signature + routing fields)."""
    shape = data.draw(st.tuples(st.integers(1, 7), st.integers(1, 5)),
                      label="shape")
    dtype = data.draw(st.sampled_from(
        ["float32", "int32", "bfloat16", "bool"]), label="dtype")
    r = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    n, k = shape
    raw = r.standard_normal((n, k)) * 8
    if dtype == "bool":
        arr = jnp.asarray(raw > 0)
    else:
        arr = jnp.asarray(raw).astype(jnp.dtype(dtype))
    _, _, _, _, plan = _single_device_plan(condense=False)
    sig = PlanSignature(arr, jnp.arange(n, dtype=jnp.float32),
                        jnp.float32(1.0))
    plan = plan._replace(signature=sig,
                         gate_weights=arr.astype(jnp.float32)
                         if dtype == "bool" else arr)
    plan2 = from_bytes(to_bytes(plan))
    np.testing.assert_array_equal(np.asarray(plan2.signature.counts),
                                  np.asarray(arr))
    assert np.asarray(plan2.signature.counts).dtype == \
        np.asarray(arr).dtype
    np.testing.assert_array_equal(np.asarray(plan2.gate_weights),
                                  np.asarray(plan.gate_weights))


# ---------------------------------------------------------------------------
# PlanCache
# ---------------------------------------------------------------------------

def test_plan_cache_memory_disk_and_eviction(tmp_path):
    cfg = _mk()
    luffy = LuffyConfig(enable_condensation=False, enable_migration=False)
    cache = PlanCache(tmp_path, mem_capacity=2)
    keys = []
    for n_seq in (1, 2, 4):
        key = plan_key(n_seq=n_seq, seq_len=16, d_model=cfg.d_model,
                       capacity=64, top_k=2, num_experts=4,
                       mode="vanilla", objective="traffic",
                       exec_mode="sync", pipeline_chunks=1,
                       comm_mode="local", topo=None, M=1)
        tmpl = build_plan_template(cfg, luffy, n_seq=n_seq, seq_len=16,
                                   capacity=64)
        cache.put(key, tmpl)
        keys.append(key)
    # LRU evicted the first entry from memory but its spill file remains
    assert len(cache) == 2
    assert (tmp_path / f"{keys[0]}.plan").exists()
    got = cache.get(keys[0])
    assert got is not None and got.capacity == 64
    assert cache.disk_loads == 1
    # a cold cache over the same directory serves all entries from disk
    cold = PlanCache(tmp_path)
    for k in keys:
        assert cold.get(k) is not None
    assert cold.disk_loads == 3
    # corrupt file -> miss, never a wrong plan
    (tmp_path / f"{keys[1]}.plan").write_bytes(b"garbage")
    assert PlanCache(tmp_path).get(keys[1]) is None
    # distinct shapes never collide
    assert len(set(keys)) == 3


def test_template_instantiate_matches_build_single_device():
    from repro.models.blocks import _dtype
    cfg = _mk()
    p = ml.moe_init(jax.random.PRNGKey(0), cfg)
    r = np.random.default_rng(7)
    x = jnp.asarray(r.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    sb = {"labels": jnp.zeros((2, 16), jnp.int32),
          "seq_len": jnp.asarray([12, 16], jnp.int32)}
    nl = LuffyConfig(enable_condensation=False, enable_migration=False)
    comm = CommContext.local()
    xn = ml._rms(x.reshape(-1, cfg.d_model),
                 p["norm"]["scale"]).astype(_dtype(cfg.compute_dtype))
    gate = gate_apply(p["router"], xn, cfg.moe.top_k)
    built = build_exchange_plan(gate, xn, cfg, nl, comm, mode="vanilla",
                                capacity=64, sideband=sb)
    tmpl = from_bytes(to_bytes(build_plan_template(
        cfg, nl, n_seq=2, seq_len=16, capacity=64)))
    inst = instantiate_plan(tmpl, gate, xn, cfg, comm, capacity=64,
                            sideband=sb)
    assert inst.chunks == built.chunks and inst.pipelined == built.pipelined
    y1, _ = execute_plan(p, x, dict(sb), built, cfg)
    y2, _ = execute_plan(p, x, dict(sb), inst, cfg)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_prefill_warm_cache_zero_planning_calls(tmp_path):
    """Acceptance: a warm PlanCache prefill performs ZERO
    build_exchange_plan calls (every MoE sublayer instantiates the
    cached template) and its logits are bit-identical to the uncached
    forward."""
    from repro import serve_lib
    from repro.configs import get_config
    from repro.config import reduced
    from repro.dist import single_device
    from repro.models.model import build_model
    from repro.plan.cache import precompute_prefill_plans

    cfg = dataclasses.replace(
        reduced(get_config("moe-gpt2"), num_layers=2, d_model=64),
        compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dist = single_device()
    nl = LuffyConfig(enable_condensation=False, enable_migration=False)
    B, S = 2, 32
    r = np.random.default_rng(0)
    toks = jnp.asarray(r.integers(1, cfg.vocab_size, (B, S)), jnp.int32)

    cache = PlanCache(tmp_path)
    key = precompute_prefill_plans(cfg, nl, dist, B, S, cache)
    assert cache.get(key) is not None

    base = pexch.BUILD_CALLS
    cold = jax.jit(lambda p, t: serve_lib.prefill(
        p, cfg, nl, dist, t, S)[0]).lower(params, toks)
    built_cold = pexch.BUILD_CALLS - base
    # one build per MoE pattern position (the layer scan traces once)
    assert built_cold == 1

    base = pexch.BUILD_CALLS
    warm = jax.jit(lambda p, t: serve_lib.prefill(
        p, cfg, nl, dist, t, S, plan_cache=cache)[0]).lower(params, toks)
    assert pexch.BUILD_CALLS - base == 0   # zero planning on request path
    assert cache.hits >= 1

    lg_cold = np.asarray(cold.compile()(params, toks))
    lg_warm = np.asarray(warm.compile()(params, toks))
    np.testing.assert_array_equal(lg_cold, lg_warm)
    assert np.isfinite(lg_cold).all()


# ---------------------------------------------------------------------------
# 8-device golden grid (subprocesses, like test_plan/test_comm)
# ---------------------------------------------------------------------------

def _run(script_body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CommContext, Topology, make_mesh, shard_map
        from repro.configs import get_config
        from repro.config import reduced, LuffyConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.dist import DistContext, make_dist
        from repro.data import SyntheticLM
        from repro.core.moe_layer import capacity_for
    """) + textwrap.dedent(script_body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_plan_reuse_golden_grid_8dev():
    """Acceptance (ISSUE 4): on the 8-device golden grid,
    plan_reuse="signature" is bit-identical to "off" both when routing
    drifts (revalidation fails, stale plans are rebuilt) and when
    routing is stable (the full-replan count per forward drops from
    one-per-MoE-sublayer to 1, asserted via the plan_reuse ledger);
    "always" trusts the carry and still trains to a finite loss."""
    out = _run("""
        cfg = reduced(get_config("moe-gpt2"), num_layers=3, d_model=128)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        shape = ShapeConfig("t", 64, 16, "train")
        data = SyntheticLM(cfg, shape)
        b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        # strictly distinct lengths: the greedy's order is tie-free, so
        # its per-sequence decisions are frame-invariant (DESIGN.md §9)
        b["seq_len"] = jnp.asarray(
            np.random.default_rng(0).permutation(np.arange(48, 64)),
            jnp.int32)
        cap = capacity_for(cfg.moe, 64, cfg.moe.num_experts, slack=8.0)
        mesh = make_mesh((2, 2, 2), ("data", "node", "local"))
        dist = DistContext(mesh, batch_axes=("data", "node", "local"),
                           seq_axis=None, fsdp_axes=("data",),
                           model_axis=("node", "local"),
                           topology=Topology(2, 2))

        def loss(params, luffy):
            l, m = jax.jit(lambda p, bb: model.train_loss(
                p, bb, jnp.float32(0.4), luffy=luffy, dist=dist,
                capacity=cap))(params, b)
            return float(l), {k: float(v) for k, v in m.items()}

        COUNTERS = ("plans_built", "plans_reused", "plan_reuse_mismatch")
        base = LuffyConfig(enable_condensation=False,
                           enable_migration=True, combine_slack=4.0,
                           condense_group=32)

        # -- drifting routing: per-layer routers differ, reuse never
        # fires, every sublayer replans -> bit-identical by graph parity
        l0, m0 = loss(params, base)
        l1, m1 = loss(params,
                      dataclasses.replace(base, plan_reuse="signature"))
        assert l0 == l1, (l0, l1)
        for k in m0:
            if k not in COUNTERS:
                assert m0[k] == m1[k], (k, m0[k], m1[k])
        assert m0["plans_built"] == 3.0 and m1["plans_built"] == 3.0
        assert m1["plans_reused"] == 0.0
        # forced mismatch: the stale carried plan was rebuilt, not
        # silently executed — one mismatch per post-seed sublayer
        assert m1["plan_reuse_mismatch"] == 2.0, m1

        # -- stable routing (zeroed routers: top-k ties resolve to the
        # same experts for every token at every layer): plan once,
        # execute N times, still bit-identical to "off"
        stable = dict(params)
        stable["layers"] = [dict(params["layers"][0])]
        stable["layers"][0]["moe"] = dict(params["layers"][0]["moe"])
        stable["layers"][0]["moe"]["router"] = {
            "w_gate": jnp.zeros_like(
                params["layers"][0]["moe"]["router"]["w_gate"])}
        l2, m2 = loss(stable, base)
        l3, m3 = loss(stable,
                      dataclasses.replace(base, plan_reuse="signature"))
        assert l2 == l3, (l2, l3)
        for k in m2:
            if k not in COUNTERS:
                assert m2[k] == m3[k], (k, m2[k], m3[k])
        assert m2["plans_built"] == 3.0            # off: one per sublayer
        assert m3["plans_built"] == 1.0, m3        # signature: plan ONCE
        assert m3["plans_reused"] == 2.0
        assert m3["plan_reuse_mismatch"] == 0.0

        # -- "always": trusts the carry without revalidation
        l4, m4 = loss(stable,
                      dataclasses.replace(base, plan_reuse="always"))
        assert np.isfinite(l4)
        assert m4["plans_built"] == 1.0 and m4["plans_reused"] == 2.0

        # -- "overlap" objective: the portfolio may execute a plan the
        # pure greedy would not re-derive, so reuse must stay disabled
        # (carry never validates) while graph parity keeps the modes
        # bit-identical
        ovl = dataclasses.replace(base, plan_objective="overlap")
        l7, m7 = loss(stable, ovl)
        l8, m8 = loss(stable,
                      dataclasses.replace(ovl, plan_reuse="signature"))
        assert l7 == l8, (l7, l8)
        for k in m7:
            assert m7[k] == m8[k], (k, m7[k], m8[k])
        assert m8["plans_built"] == 3.0 and m8["plans_reused"] == 0.0

        # -- condensation on: rep-map rebuilt per sublayer changes the
        # routing signature, so reuse must revalidate (never silently
        # execute a stale plan) and stay bit-identical to "off"
        cond = dataclasses.replace(base, enable_condensation=True)
        l5, m5 = loss(params, cond)
        l6, m6 = loss(params,
                      dataclasses.replace(cond, plan_reuse="signature"))
        assert l5 == l6, (l5, l6)
        for k in m5:
            if k not in COUNTERS:
                assert m5[k] == m6[k], (k, m5[k], m6[k])
        assert m6["plans_built"] + m6["plans_reused"] == 3.0
        print("OK")
    """)
    assert "OK" in out


def test_objective_planned_chunk_count_8dev():
    """Satellite: pipeline_chunks=0 lets build_exchange_plan pick
    ChunkPlan.n_chunks from estimate_exchange(chunks=None)'s search;
    an explicit positive value still overrides."""
    out = _run("""
        from repro.core import moe_layer as ml
        from repro.core.gating import gate_apply
        from repro.plan import build_exchange_plan, estimate_exchange
        from repro.models.blocks import _dtype

        cfg = dataclasses.replace(
            reduced(get_config("moe-gpt2"), num_layers=2, d_model=64),
            compute_dtype="float32")
        p = ml.moe_init(jax.random.PRNGKey(1), cfg)
        mesh = make_mesh((2, 2, 2), ("data", "node", "local"))
        topo = Topology(2, 2)
        comm = CommContext.build("hier", ("node", "local"), topo)
        n_seq, S, d = 2, 32, cfg.d_model
        cap = ml.capacity_for(cfg.moe, n_seq * S, cfg.moe.num_experts,
                              slack=4.0)
        r = np.random.default_rng(0)
        x = jnp.asarray(r.standard_normal((16, S, d)), jnp.float32)
        lbl = jnp.zeros((16, S), jnp.int32)
        slen = jnp.full((16,), S, jnp.int32)

        def chunks_for(luffy):
            def inner(p_l, x_l, lbl_l, sl_l):
                sb = {"labels": lbl_l, "seq_len": sl_l}
                xn = ml._rms(x_l.reshape(-1, d), p_l["norm"]["scale"]
                             ).astype(_dtype(cfg.compute_dtype))
                gate = gate_apply(p_l["router"], xn, cfg.moe.top_k)
                plan = build_exchange_plan(
                    gate, xn, cfg, luffy, comm, mode="vanilla",
                    capacity=cap, sideband=sb)
                inner.n_chunks = plan.chunks.n_chunks
                return x_l
            ba = ("data", "node", "local")
            p_specs = jax.tree.map(lambda _: P(), p)
            p_specs["experts"] = jax.tree.map(
                lambda _: P(("node", "local"), None, None), p["experts"])
            jax.jit(shard_map(
                inner, mesh=mesh,
                in_specs=(p_specs, P(ba, None, None), P(ba, None), P(ba)),
                out_specs=P(ba, None, None))).lower(p, x, lbl, slen)
            return inner.n_chunks

        auto = LuffyConfig(enable_condensation=False,
                           enable_migration=False, exec_mode="pipeline",
                           pipeline_chunks=0, plan_objective="overlap")
        fixed = dataclasses.replace(auto, pipeline_chunks=2)
        # the planned count == the estimate search at this shape
        T = n_seq * S
        want = estimate_exchange(
            T, cfg.moe.top_k, d, topo=topo, bytes_per_el=4,
            ffn_ms=cfg.moe.num_experts * cap * 4.0 * d * cfg.moe.d_ff
            / auto.gpu_speed * 1e3, chunks=None).chunks
        from repro.sched import plan_chunks
        assert chunks_for(auto) == plan_chunks(cap, want).n_chunks, \\
            (chunks_for(auto), want)
        assert chunks_for(fixed) == plan_chunks(cap, 2).n_chunks
        print("OK")
    """)
    assert "OK" in out
